"""Model-size presets, shared by model.py / aot.py / tests.

The sizes stand in for the paper's OPT-1.3b / 13b / 30b family (see
DESIGN.md substitution table): they scale the transformer-block count so the
layer-wise sparsity axis (the paper's core knob) stays meaningful, while
remaining runnable on CPU PJRT.

``seq_buckets`` drive sequence-length bucketing in the rust runtime: one
forward executable is exported per bucket, and the trainer picks the smallest
bucket that fits the batch. This is how the fixed-shape XLA world reproduces
the paper's "shorter inputs -> less forward compute" behaviour (Fig. 6).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    max_seq: int
    seq_buckets: tuple[int, ...]
    train_batch: int
    eval_batch: int

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


SIZES: dict[str, ModelConfig] = {
    # test-scale model: fast enough for cargo-test integration runs
    "opt-micro": ModelConfig(
        name="opt-micro", vocab=512, d_model=64, n_layers=4, n_heads=4,
        max_seq=64, seq_buckets=(16, 32, 64), train_batch=8, eval_batch=16,
    ),
    # stands in for OPT-1.3b (Table 2: 11 tasks)
    "opt-tiny": ModelConfig(
        name="opt-tiny", vocab=2048, d_model=128, n_layers=6, n_heads=8,
        max_seq=64, seq_buckets=(16, 32, 64), train_batch=8, eval_batch=16,
    ),
    # stands in for OPT-13b (Table 1: the headline grid)
    "opt-small": ModelConfig(
        name="opt-small", vocab=4096, d_model=256, n_layers=8, n_heads=8,
        max_seq=64, seq_buckets=(16, 32, 64), train_batch=8, eval_batch=16,
    ),
    # stands in for OPT-30b (Table 3) and the ~100M-param e2e driver
    "opt-base": ModelConfig(
        name="opt-base", vocab=16384, d_model=768, n_layers=12, n_heads=12,
        max_seq=64, seq_buckets=(32, 64), train_batch=4, eval_batch=8,
    ),
}


def param_count(cfg: ModelConfig) -> int:
    """Total parameter count (embeddings tied with the LM head, OPT-style)."""
    d, f = cfg.d_model, cfg.d_ff
    block = 4 * d * d + 4 * d + 2 * d * f + f + d + 4 * d  # attn + mlp + 2 LN
    return (cfg.vocab + cfg.max_seq) * d + cfg.n_layers * block + 2 * d

"""AOT exporter: lower every executable the rust runtime needs to HLO text.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(proto.id() <= INT_MAX); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per model size this writes into artifacts/<size>/:
    zo_axpy_<len>.hlo.txt            one per distinct layer-unit length
    forward_loss_s<S>.hlo.txt        scalar ZO objective,    per seq bucket
    example_losses_s<S>.hlo.txt      eval option scoring,    per seq bucket
    predict_s<S>.hlo.txt             greedy decode,          per seq bucket
    forward_backward_s<S>.hlo.txt    FO substrate (tuple),   per seq bucket
    params_init.bin                  concatenated f32 init for all units
    manifest.json                    everything rust needs to wire it up

Python runs once at build time; the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import peft as P
from .configs import SIZES, ModelConfig, param_count
from .kernels.zo_axpy import zo_axpy
from .kernels.zo_axpy_masked import zo_axpy_masked


def to_hlo_text(lowered, return_tuple: bool) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_size(cfg: ModelConfig, out_dir: str, use_pallas: bool, verbose: bool = True,
                with_peft: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    lens = M.unit_lens(cfg)
    names = [n for n, _ in M.unit_specs(cfg)]
    k = len(lens)
    f32, i32 = jnp.float32, jnp.int32
    unit_specs = [_spec((n,), f32) for n in lens]
    files: dict[str, str] = {}

    def emit(fname: str, lowered, return_tuple: bool):
        text = to_hlo_text(lowered, return_tuple)
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        files[fname.removesuffix(".hlo.txt")] = fname
        if verbose:
            print(f"  wrote {fname} ({len(text) // 1024} KiB)")

    # --- L1 kernel: one zo_axpy executable per distinct unit length --------
    axpy_lens = sorted(set(lens))
    if with_peft:
        axpy_lens = sorted(set(axpy_lens + [P.lora_unit_len(cfg), P.prefix_unit_len(cfg)]))
    for n in axpy_lens:
        low = jax.jit(lambda p, s, c: zo_axpy(p, s, c)).lower(
            _spec((n,), f32), _spec((), i32), _spec((), f32)
        )
        emit(f"zo_axpy_{n}.hlo.txt", low, return_tuple=False)
        # Sparse-MeZO comparison kernel (element-wise magnitude mask)
        low_m = jax.jit(lambda p, r, t, s, c: zo_axpy_masked(p, r, t, s, c)).lower(
            _spec((n,), f32), _spec((n,), f32), _spec((), f32), _spec((), i32), _spec((), f32)
        )
        emit(f"zo_axpy_masked_{n}.hlo.txt", low_m, return_tuple=False)

    # --- L2 model executables, one per sequence bucket ---------------------
    for s in cfg.seq_buckets:
        bt, be = cfg.train_batch, cfg.eval_batch
        tok_t = _spec((bt, s), i32)
        tgt_t = _spec((bt, s), i32)
        msk_t = _spec((bt, s), f32)
        tok_e = _spec((be, s), i32)
        tgt_e = _spec((be, s), i32)
        msk_e = _spec((be, s), f32)

        def loss_fn(*args):
            return M.mean_loss(list(args[:k]), args[k], args[k + 1], args[k + 2], cfg, use_pallas)

        emit(
            f"forward_loss_s{s}.hlo.txt",
            jax.jit(loss_fn).lower(*unit_specs, tok_t, tgt_t, msk_t),
            return_tuple=False,
        )

        def exloss_fn(*args):
            return M.example_losses(
                list(args[:k]), args[k], args[k + 1], args[k + 2], cfg, use_pallas
            )

        emit(
            f"example_losses_s{s}.hlo.txt",
            jax.jit(exloss_fn).lower(*unit_specs, tok_e, tgt_e, msk_e),
            return_tuple=False,
        )

        def predict_fn(*args):
            return M.predict_tokens(list(args[:k]), args[k], cfg, use_pallas)

        emit(
            f"predict_s{s}.hlo.txt",
            jax.jit(predict_fn).lower(*unit_specs, tok_e),
            return_tuple=False,
        )

        def fb_fn(*args):
            # ref attention path: leaner reverse-mode HLO (see model.loss_and_grads)
            return M.loss_and_grads(list(args[:k]), args[k], args[k + 1], args[k + 2], cfg)

        emit(
            f"forward_backward_s{s}.hlo.txt",
            jax.jit(fb_fn).lower(*unit_specs, tok_t, tgt_t, msk_t),
            return_tuple=True,
        )

        # --- PEFT executables (Table 4): adapter units follow base units ---
        if with_peft:
            for mode, ulen in (("lora", P.lora_unit_len(cfg)), ("prefix", P.prefix_unit_len(cfg))):
                peft_specs = [_spec((ulen,), f32) for _ in range(cfg.n_layers)]
                kp = k + cfg.n_layers

                def peft_loss(*args, _mode=mode):
                    return P.mean_loss_peft(
                        list(args[:k]), list(args[k:kp]),
                        args[kp], args[kp + 1], args[kp + 2], cfg, _mode,
                    )

                emit(
                    f"forward_loss_{mode}_s{s}.hlo.txt",
                    jax.jit(peft_loss).lower(*unit_specs, *peft_specs, tok_t, tgt_t, msk_t),
                    return_tuple=False,
                )

                def peft_exloss(*args, _mode=mode):
                    return P.example_losses_peft(
                        list(args[:k]), list(args[k:kp]),
                        args[kp], args[kp + 1], args[kp + 2], cfg, _mode,
                    )

                emit(
                    f"example_losses_{mode}_s{s}.hlo.txt",
                    jax.jit(peft_exloss).lower(*unit_specs, *peft_specs, tok_e, tgt_e, msk_e),
                    return_tuple=False,
                )

                def peft_predict(*args, _mode=mode):
                    return P.predict_tokens_peft(
                        list(args[:k]), list(args[k:kp]), args[kp], cfg, _mode,
                    )

                emit(
                    f"predict_{mode}_s{s}.hlo.txt",
                    jax.jit(peft_predict).lower(*unit_specs, *peft_specs, tok_e),
                    return_tuple=False,
                )

    # --- initial parameters (rust never re-implements init) ----------------
    units = M.init_units(cfg, seed=0)
    with open(os.path.join(out_dir, "params_init.bin"), "wb") as f:
        for u in units:
            f.write(u.astype("<f4").tobytes())

    manifest = {
        "name": cfg.name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "max_seq": cfg.max_seq,
        "seq_buckets": list(cfg.seq_buckets),
        "train_batch": cfg.train_batch,
        "eval_batch": cfg.eval_batch,
        "unit_names": names,
        "unit_lens": lens,
        "axpy_lens": axpy_lens,
        "param_count": param_count(cfg),
        "use_pallas_forward": bool(use_pallas),
        "init_file": "params_init.bin",
        "files": files,
    }
    if with_peft:
        manifest["lora_unit_len"] = P.lora_unit_len(cfg)
        manifest["prefix_unit_len"] = P.prefix_unit_len(cfg)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"  manifest.json ({param_count(cfg):,} params, {k} units)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="opt-micro,opt-tiny,opt-small",
                    help="comma-separated size names (see configs.SIZES), or 'all'")
    ap.add_argument("--out-root", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--no-peft", action="store_true",
                    help="skip the Table-4 LoRA/prefix executables")
    ap.add_argument("--no-pallas-forward", action="store_true",
                    help="lower the forward pass with the jnp reference ops instead of "
                         "the Pallas kernels (perf-pass ablation; zo_axpy stays Pallas)")
    args = ap.parse_args()
    sizes = list(SIZES) if args.sizes == "all" else args.sizes.split(",")
    for s in sizes:
        cfg = SIZES[s]
        print(f"[aot] exporting {s} -> {args.out_root}/{s}")
        export_size(cfg, os.path.join(args.out_root, s), use_pallas=not args.no_pallas_forward,
                    with_peft=not args.no_peft)


if __name__ == "__main__":
    main()

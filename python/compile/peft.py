"""PEFT forward passes for the paper's Table 4: LoRA and prefix tuning.

Under PEFT the ZO optimizer perturbs/updates only small per-block adapter
units; the frozen base units stay forward arguments. One adapter unit per
transformer block is the unit of LeZO's layer-wise sparsity, mirroring the
paper's LeZO(LoRA)/LeZO(prefix) rows.

Flat adapter layouts (kept in sync with rust/src/peft/mod.rs):
    LoRA unit   = [A_q (D,R) | B_q (R,D) | A_v (D,R) | B_v (R,D)]  (4*D*R)
    prefix unit = [K_pre (P,D) | V_pre (P,D)]                      (2*P*D)

LoRA (Hu et al. 2022): W_q' = W_q + (alpha/r) * A_q @ B_q, same for W_v;
B = 0 at init so the initial delta is exactly zero.

Prefix tuning (Li & Liang 2021): P learned key/value positions prepended to
every block's attention; all queries may attend the prefix (no causal
restriction on prefix positions).

PEFT executables lower through the jnp reference attention: interpret-mode
Pallas brings no benefit at build time and the prefix path needs a
rectangular (S x (P+S)) mask the square-causal kernel does not model.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels.ref import layernorm_ref
from .model import (
    _gelu,
    _position_xent,
    block_spec,
    embed_spec,
    final_spec,
    unflatten,
)

LORA_RANK = 8
LORA_ALPHA = 16.0
PREFIX_TOKENS = 5


def lora_unit_len(cfg: ModelConfig) -> int:
    return 4 * cfg.d_model * LORA_RANK


def prefix_unit_len(cfg: ModelConfig) -> int:
    return 2 * PREFIX_TOKENS * cfg.d_model


def _split_lora(unit: jnp.ndarray, d: int) -> tuple[jnp.ndarray, ...]:
    r = LORA_RANK
    q = d * r
    a_q = unit[0 * q : 1 * q].reshape(d, r)
    b_q = unit[1 * q : 2 * q].reshape(r, d)
    a_v = unit[2 * q : 3 * q].reshape(d, r)
    b_v = unit[3 * q : 4 * q].reshape(r, d)
    return a_q, b_q, a_v, b_v


def _split_prefix(unit: jnp.ndarray, d: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    p = PREFIX_TOKENS
    k_pre = unit[: p * d].reshape(p, d)
    v_pre = unit[p * d :].reshape(p, d)
    return k_pre, v_pre


def _heads(x: jnp.ndarray, nh: int, dh: int) -> jnp.ndarray:
    """[B,S,D] -> [B*H, S, Dh]."""
    b, s, _ = x.shape
    return x.reshape(b, s, nh, dh).transpose(0, 2, 1, 3).reshape(b * nh, s, dh)


def _unheads(x: jnp.ndarray, b: int, nh: int, dh: int) -> jnp.ndarray:
    s = x.shape[1]
    return x.reshape(b, nh, s, dh).transpose(0, 2, 1, 3).reshape(b, s, nh * dh)


def _attention_peft(
    h: jnp.ndarray,
    p: dict,
    cfg: ModelConfig,
    lora: tuple[jnp.ndarray, ...] | None,
    prefix: tuple[jnp.ndarray, jnp.ndarray] | None,
) -> jnp.ndarray:
    b, s, d = h.shape
    nh, dh = cfg.n_heads, cfg.d_head
    q = h @ p["wq"] + p["bq"]
    k = h @ p["wk"] + p["bk"]
    v = h @ p["wv"] + p["bv"]
    if lora is not None:
        a_q, b_q, a_v, b_v = lora
        scale = np.float32(LORA_ALPHA / LORA_RANK)
        q = q + scale * ((h @ a_q) @ b_q)
        v = v + scale * ((h @ a_v) @ b_v)
    qh, kh, vh = _heads(q, nh, dh), _heads(k, nh, dh), _heads(v, nh, dh)

    n_pre = 0
    if prefix is not None:
        k_pre, v_pre = prefix
        n_pre = k_pre.shape[0]
        # [P,D] -> [1,P,H,Dh] -> broadcast over batch -> [B*H, P, Dh]
        def pre_heads(x):
            xh = x.reshape(1, n_pre, nh, dh).transpose(0, 2, 1, 3)
            xh = jnp.broadcast_to(xh, (b, nh, n_pre, dh))
            return xh.reshape(b * nh, n_pre, dh)

        kh = jnp.concatenate([pre_heads(k_pre), kh], axis=1)
        vh = jnp.concatenate([pre_heads(v_pre), vh], axis=1)

    scores = jnp.einsum("bqd,bkd->bqk", qh, kh) / np.float32(np.sqrt(dh))
    # causal over real positions; prefix positions always visible
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(n_pre + s)[None, :]
    mask = ki < (qi + n_pre + 1)
    scores = jnp.where(mask[None], scores, np.float32(-1e30))
    attn = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", attn, vh)
    o = _unheads(o, b, nh, dh)
    return o @ p["wo"] + p["bo"]


def forward_logits_peft(
    units: Sequence[jnp.ndarray],
    peft_units: Sequence[jnp.ndarray],
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    mode: str,
) -> jnp.ndarray:
    """tokens i32[B,S] -> logits f32[B,S,V] with per-block adapters."""
    assert mode in ("lora", "prefix")
    assert len(peft_units) == cfg.n_layers
    emb = unflatten(units[0], embed_spec(cfg))
    s = tokens.shape[1]
    h = emb["tok_emb"][tokens] + emb["pos_emb"][:s][None]
    for i in range(cfg.n_layers):
        p = unflatten(units[1 + i], block_spec(cfg))
        lora = _split_lora(peft_units[i], cfg.d_model) if mode == "lora" else None
        prefix = _split_prefix(peft_units[i], cfg.d_model) if mode == "prefix" else None
        hn = layernorm_ref(h, p["ln1_g"], p["ln1_b"])
        h = h + _attention_peft(hn, p, cfg, lora, prefix)
        hm = layernorm_ref(h, p["ln2_g"], p["ln2_b"])
        h = h + (_gelu(hm @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"])
    fin = unflatten(units[-1], final_spec(cfg))
    h = layernorm_ref(h, fin["lnf_g"], fin["lnf_b"])
    return h @ unflatten(units[0], embed_spec(cfg))["tok_emb"].T


def mean_loss_peft(units, peft_units, tokens, targets, mask, cfg: ModelConfig, mode: str):
    logits = forward_logits_peft(units, peft_units, tokens, cfg, mode)
    xent = _position_xent(logits, targets)
    return jnp.sum(xent * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def example_losses_peft(units, peft_units, tokens, targets, mask, cfg: ModelConfig, mode: str):
    logits = forward_logits_peft(units, peft_units, tokens, cfg, mode)
    xent = _position_xent(logits, targets)
    return jnp.sum(xent * mask, axis=-1) / jnp.maximum(jnp.sum(mask, axis=-1), 1.0)


def predict_tokens_peft(units, peft_units, tokens, cfg: ModelConfig, mode: str):
    logits = forward_logits_peft(units, peft_units, tokens, cfg, mode)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def init_peft_units(cfg: ModelConfig, mode: str, seed: int = 0) -> list[np.ndarray]:
    """Reference init (rust re-implements this deterministically on its own
    RNG; the python version exists for the pytest oracle)."""
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(cfg.n_layers):
        if mode == "lora":
            d, r = cfg.d_model, LORA_RANK
            a_q = rng.normal(0.0, 0.02, size=(d, r)).astype(np.float32)
            b_q = np.zeros((r, d), dtype=np.float32)
            a_v = rng.normal(0.0, 0.02, size=(d, r)).astype(np.float32)
            b_v = np.zeros((r, d), dtype=np.float32)
            out.append(np.concatenate([x.reshape(-1) for x in (a_q, b_q, a_v, b_v)]))
        else:
            out.append(
                rng.normal(0.0, 0.02, size=(prefix_unit_len(cfg),)).astype(np.float32)
            )
    return out

"""Build-time compile path: JAX model + Pallas kernels -> AOT HLO artifacts.

Never imported at runtime; the rust coordinator only sees artifacts/*.hlo.txt.
"""

"""L2: OPT-style decoder-only LM over *flat per-layer parameter vectors*.

The flat vectors are the whole point: a "layer unit" (embedding table, one
transformer block, final LN) is the unit of LeZO's sparsity, so the model is
written to consume one f32[len] vector per unit and un-flatten internally.
The rust coordinator then stores parameters as a Vec<PjRtBuffer> and skips
whole buffers during perturbation/update - the paper's computation saving,
made structural.

Unit layout (index order = executable argument order):
    unit 0:            embedding  = [tok_emb (V,D) | pos_emb (S,D)]
    units 1..n_layers: block      = [ln1_g, ln1_b, Wq, bq, Wk, bk, Wv, bv,
                                     Wo, bo, ln2_g, ln2_b, W1, b1, W2, b2]
    unit n_layers+1:   final LN   = [lnf_g, lnf_b]
LM head is tied to tok_emb (OPT-style).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels.attention import mha_causal
from .kernels.layernorm import layernorm

# ---------------------------------------------------------------------------
# Unit specs: (name, shape) lists defining the flat layout.
# ---------------------------------------------------------------------------


def embed_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    return [("tok_emb", (cfg.vocab, cfg.d_model)), ("pos_emb", (cfg.max_seq, cfg.d_model))]


def block_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    d, f = cfg.d_model, cfg.d_ff
    return [
        ("ln1_g", (d,)), ("ln1_b", (d,)),
        ("wq", (d, d)), ("bq", (d,)),
        ("wk", (d, d)), ("bk", (d,)),
        ("wv", (d, d)), ("bv", (d,)),
        ("wo", (d, d)), ("bo", (d,)),
        ("ln2_g", (d,)), ("ln2_b", (d,)),
        ("w1", (d, f)), ("b1", (f,)),
        ("w2", (f, d)), ("b2", (d,)),
    ]


def final_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    d = cfg.d_model
    return [("lnf_g", (d,)), ("lnf_b", (d,))]


def spec_len(spec: Sequence[tuple[str, tuple[int, ...]]]) -> int:
    return int(sum(np.prod(s) for _, s in spec))


def unit_specs(cfg: ModelConfig) -> list[tuple[str, list[tuple[str, tuple[int, ...]]]]]:
    """All layer units in argument order: [(unit_name, field_spec), ...]."""
    units = [("embed", embed_spec(cfg))]
    units += [(f"block_{i}", block_spec(cfg)) for i in range(cfg.n_layers)]
    units += [("final_ln", final_spec(cfg))]
    return units


def unit_lens(cfg: ModelConfig) -> list[int]:
    return [spec_len(s) for _, s in unit_specs(cfg)]


def unflatten(vec: jnp.ndarray, spec: Sequence[tuple[str, tuple[int, ...]]]) -> dict:
    """Split one flat unit vector into named arrays (differentiable)."""
    out = {}
    off = 0
    for name, shape in spec:
        n = int(np.prod(shape))
        out[name] = vec[off : off + n].reshape(shape)
        off += n
    return out


# ---------------------------------------------------------------------------
# Initialization (written to artifacts as raw f32; rust never re-implements it)
# ---------------------------------------------------------------------------


def init_units(cfg: ModelConfig, seed: int = 0) -> list[np.ndarray]:
    """GPT-2/OPT-style init: N(0, 0.02) weights, zero biases, unit gammas,
    residual-out projections scaled by 1/sqrt(2*n_layers)."""
    rng = np.random.RandomState(seed)
    resid_scale = 1.0 / np.sqrt(2.0 * cfg.n_layers)

    def init_field(name: str, shape: tuple[int, ...]) -> np.ndarray:
        if name.endswith("_g"):
            return np.ones(shape, dtype=np.float32)
        if name.endswith("_b") or name.startswith("b"):
            return np.zeros(shape, dtype=np.float32)
        w = rng.normal(0.0, 0.02, size=shape).astype(np.float32)
        if name in ("wo", "w2"):
            w *= resid_scale
        return w

    units = []
    for _, spec in unit_specs(cfg):
        flat = np.concatenate([init_field(n, s).reshape(-1) for n, s in spec])
        units.append(flat.astype(np.float32))
    return units


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _gelu(x: jnp.ndarray) -> jnp.ndarray:
    return 0.5 * x * (1.0 + jnp.tanh(np.float32(np.sqrt(2.0 / np.pi)) * (x + 0.044715 * x**3)))


def _attention(h: jnp.ndarray, p: dict, cfg: ModelConfig, use_pallas: bool) -> jnp.ndarray:
    b, s, d = h.shape
    nh, dh = cfg.n_heads, cfg.d_head
    q = h @ p["wq"] + p["bq"]
    k = h @ p["wk"] + p["bk"]
    v = h @ p["wv"] + p["bv"]
    # [B,S,D] -> [B*H, S, Dh]
    def split(x):
        return x.reshape(b, s, nh, dh).transpose(0, 2, 1, 3).reshape(b * nh, s, dh)

    q, k, v = split(q), split(k), split(v)
    if use_pallas:
        o = mha_causal(q, k, v)
    else:
        from .kernels.ref import mha_causal_ref

        o = mha_causal_ref(q, k, v)
    o = o.reshape(b, nh, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ p["wo"] + p["bo"]


def _layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, use_pallas: bool) -> jnp.ndarray:
    if use_pallas:
        rows = x.shape[0] * x.shape[1]
        return layernorm(x.reshape(rows, x.shape[2]), g, b).reshape(x.shape)
    from .kernels.ref import layernorm_ref

    return layernorm_ref(x, g, b)


def forward_logits(
    units: Sequence[jnp.ndarray],
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """tokens i32[B,S] -> logits f32[B,S,V]."""
    emb = unflatten(units[0], embed_spec(cfg))
    s = tokens.shape[1]
    h = emb["tok_emb"][tokens] + emb["pos_emb"][:s][None]
    for i in range(cfg.n_layers):
        p = unflatten(units[1 + i], block_spec(cfg))
        h = h + _attention(_layernorm(h, p["ln1_g"], p["ln1_b"], use_pallas), p, cfg, use_pallas)
        hm = _layernorm(h, p["ln2_g"], p["ln2_b"], use_pallas)
        h = h + (_gelu(hm @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"])
    fin = unflatten(units[-1], final_spec(cfg))
    h = _layernorm(h, fin["lnf_g"], fin["lnf_b"], use_pallas)
    return h @ unflatten(units[0], embed_spec(cfg))["tok_emb"].T


def _position_xent(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Per-position cross-entropy, f32[B,S]."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - gold


def mean_loss(units, tokens, targets, mask, cfg: ModelConfig, use_pallas: bool = True):
    """Mean LM loss over masked positions - the ZO objective (scalar f32).

    mask f32[B,S]: 1.0 where the position's target participates in the loss
    (for classification tasks this is just the verbalizer position)."""
    logits = forward_logits(units, tokens, cfg, use_pallas)
    xent = _position_xent(logits, targets)
    return jnp.sum(xent * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def example_losses(units, tokens, targets, mask, cfg: ModelConfig, use_pallas: bool = True):
    """Per-example mean masked loss, f32[B] - used for option scoring in eval."""
    logits = forward_logits(units, tokens, cfg, use_pallas)
    xent = _position_xent(logits, targets)
    per = jnp.sum(xent * mask, axis=-1) / jnp.maximum(jnp.sum(mask, axis=-1), 1.0)
    return per


def predict_tokens(units, tokens, cfg: ModelConfig, use_pallas: bool = True):
    """Greedy next-token prediction at every position, i32[B,S] - used for
    teacher-forced generation eval (span-F1 on SQuAD/DROP-like tasks)."""
    logits = forward_logits(units, tokens, cfg, use_pallas)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def loss_and_grads(units, tokens, targets, mask, cfg: ModelConfig, use_pallas: bool = False):
    """FO substrate: (loss, grads-per-unit). Used by the FT baseline and for
    in-repo pretraining. Pallas kernels default off here: interpret-mode
    pallas has no custom VJP and the ref path lowers to leaner HLO."""
    def f(us):
        return mean_loss(us, tokens, targets, mask, cfg, use_pallas)

    loss, grads = jax.value_and_grad(f)(list(units))
    return (loss, *grads)

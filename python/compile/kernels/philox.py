"""Counter-based Philox-4x32-10 RNG + Box-Muller, in pure jnp uint32 ops.

This is the numerical core of LeZO's memory trick: the perturbation vector
``z ~ N(0, I)`` is *regenerated* from ``(seed, element_index)`` instead of
being stored, so perturb (+mu), flip (-2mu), restore (+mu), and update
(-eta*g) all see bit-identical ``z`` without any extra memory.

Everything here is plain elementwise uint32/f32 arithmetic so it lowers
cleanly both inside a Pallas kernel (interpret=True) and in ordinary jitted
jax code, and it round-trips through HLO text to the rust runtime.

Reference: Salmon et al., "Parallel random numbers: as easy as 1, 2, 3"
(SC'11). Constants are the canonical Philox-4x32 constants.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Canonical Philox-4x32 round constants.
PHILOX_M0 = np.uint32(0xD2511F53)
PHILOX_M1 = np.uint32(0xCD9E8D57)
PHILOX_W0 = np.uint32(0x9E3779B9)  # golden ratio
PHILOX_W1 = np.uint32(0xBB67AE85)  # sqrt(3) - 1

# Key word 1 is a domain separator ("LeZO") so the perturbation stream can
# never collide with any other Philox user keyed on the same seed.
LEZO_KEY1 = np.uint32(0x4C655A4F)

ROUNDS = 10


def _u32(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.uint32)


def mulhilo32(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full 32x32 -> 64 bit product as (hi, lo) uint32 words.

    Implemented with 16-bit partial products so it needs no 64-bit integer
    support (jax defaults to 32-bit ints; XLA CPU handles this fine).
    All intermediate products fit in uint32: (2^16-1)^2 < 2^32.
    """
    a = _u32(a)
    b = _u32(b)
    lo = a * b  # wraps mod 2^32, which is exactly the low word
    ah = a >> np.uint32(16)
    al = a & np.uint32(0xFFFF)
    bh = b >> np.uint32(16)
    bl = b & np.uint32(0xFFFF)
    mid1 = ah * bl
    mid2 = al * bh
    carry = (
        ((al * bl) >> np.uint32(16))
        + (mid1 & np.uint32(0xFFFF))
        + (mid2 & np.uint32(0xFFFF))
    )
    hi = ah * bh + (mid1 >> np.uint32(16)) + (mid2 >> np.uint32(16)) + (carry >> np.uint32(16))
    return hi, lo


def philox4x32(
    c0: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    k0: jnp.ndarray,
    k1: jnp.ndarray,
    rounds: int = ROUNDS,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Philox-4x32 block cipher over counter words c0..c3 with key (k0, k1).

    Vectorized: every argument may be an array; shapes broadcast.
    Returns four uint32 words of high-quality pseudo-random bits.
    """
    c0, c1, c2, c3 = _u32(c0), _u32(c1), _u32(c2), _u32(c3)
    k0, k1 = _u32(k0), _u32(k1)
    for _ in range(rounds):
        hi0, lo0 = mulhilo32(PHILOX_M0, c0)
        hi1, lo1 = mulhilo32(PHILOX_M1, c2)
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0
        k0 = k0 + PHILOX_W0
        k1 = k1 + PHILOX_W1
    return c0, c1, c2, c3


def uniform01(bits: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 bits -> f32 uniform in the *open* interval (0, 1).

    Top 23 bits scaled by 2^-23, plus a 2^-24 offset: every value is exactly
    representable in f32, the max is 1 - 2^-24 < 1 and the min is 2^-24 > 0,
    so log(u) stays finite (no rounding-to-1.0 as with a 24-bit mantissa).
    """
    return (bits >> np.uint32(9)).astype(jnp.float32) * np.float32(1.0 / (1 << 23)) + np.float32(
        1.0 / (1 << 24)
    )


def boxmuller(r0: jnp.ndarray, r1: jnp.ndarray) -> jnp.ndarray:
    """One standard normal per (r0, r1) pair of uint32 words (cosine branch)."""
    u1 = uniform01(r0)
    u2 = uniform01(r1)
    radius = jnp.sqrt(np.float32(-2.0) * jnp.log(u1))
    theta = np.float32(2.0 * np.pi) * u2
    return radius * jnp.cos(theta)


def gauss_from_index(idx: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """z[i] ~ N(0, 1), a pure function of (seed, i).

    ``idx`` is the *global* element index (uint32) of each parameter inside
    its layer unit; ``seed`` is the per-(step, layer) seed chosen by the rust
    coordinator. Counter = (idx, 0, 0, 0), key = (seed, LEZO_KEY1).
    """
    idx = _u32(idx)
    seed = _u32(seed)
    zero = jnp.zeros_like(idx)
    r0, r1, _, _ = philox4x32(idx, zero, zero, zero, seed, jnp.broadcast_to(LEZO_KEY1, seed.shape))
    return boxmuller(r0, r1)

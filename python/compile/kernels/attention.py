"""Causal multi-head attention as a Pallas kernel (flash-attention style).

The forward pass is the other half of a ZO step's cost; this kernel is the
forward hot spot. Structure follows the flash-attention HBM<->VMEM schedule,
re-thought for TPU per DESIGN.md:

  grid = (batch*heads, q_blocks); each grid step holds one (Bq, Dh) query
  tile in VMEM and loops over (Bk, Dh) key/value tiles with an online-softmax
  accumulator (m, l, acc). The two contractions (q k^T and p v) are MXU-shaped
  matmuls; on real TPU they would run in bf16 on the systolic array.

interpret=True for CPU PJRT; the same code lowers to Mosaic on real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = np.float32(-1e30)


def _mha_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int, seq: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0] * np.float32(scale)  # [Bq, Dh]
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], ki * block_k, block_k, axis=0)  # [Bk, Dh]
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], ki * block_k, block_k, axis=0)
        s = q @ k.T  # [Bq, Bk] - MXU contraction
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)  # causal mask
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v  # [Bq, Dh] - MXU contraction
        return m_new, l_new, acc

    dh = q_ref.shape[-1]
    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    # Causality: key blocks strictly after this query block are fully masked,
    # so the loop stops early (dynamic fori bound lowers to a while loop).
    last_kb = (qi * block_q + block_q - 1) // block_k + 1
    m, l, acc = jax.lax.fori_loop(0, last_kb, body, (m0, l0, acc0))
    o_ref[0] = acc / l[:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def mha_causal(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, block_q: int = 32, block_k: int = 32):
    """Causal MHA over [BH, S, Dh] tensors (batch and heads pre-merged).

    Returns f32[BH, S, Dh].
    """
    bh, seq, dh = q.shape
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    assert seq % block_q == 0 and seq % block_k == 0, (seq, block_q, block_k)
    scale = 1.0 / float(np.sqrt(dh))
    kernel = functools.partial(
        _mha_kernel, block_q=block_q, block_k=block_k, seq=seq, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, seq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, dh), jnp.float32),
        interpret=True,
    )(q, k, v)


def mha_vmem_bytes(seq: int, dh: int, block_q: int = 32, block_k: int = 32) -> int:
    """VMEM estimate per grid step (perf notes): q tile + full k/v + acc."""
    return 4 * (block_q * dh + 2 * seq * dh + block_q * block_k + 2 * block_q * dh)

"""pack4 variant of the zo_axpy kernel — the §Perf L1 iteration.

``zo_axpy`` (the baseline) runs one full Philox-4x32-10 block cipher per
element and keeps one Box-Muller normal from it, discarding half the entropy
(words r2, r3) and the sine branch. Philox yields 4 words = 2 Box-Muller
pairs = 4 normals (cos+sin per pair), so the cipher — ~80% of the kernel's
arithmetic — can be amortized over 4 elements:

    group g = i // 4 runs Philox once on counter (g, 0, 0, 0);
    element i gets normal  [cos(p01), sin(p01), cos(p23), sin(p23)][i % 4].

The stream is still a pure function of (seed, i) — all four phases of
Algorithm 1 regenerate identical z — it is simply a *different* stream than
the baseline kernel's, so the two variants must not be mixed within one
fine-tuning run (the aot exporter emits one or the other for all units).

Measured on CPU PJRT this cuts the perturb stage by ~3x (EXPERIMENTS.md
§Perf); on TPU the kernel is DMA-bound so the win is headroom, not latency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .philox import LEZO_KEY1, philox4x32, uniform01
from .zo_axpy import DEFAULT_BLOCK


def _gauss4_from_group(group: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """f32[n, 4] standard normals for n counter groups (one Philox each)."""
    zero = jnp.zeros_like(group)
    r0, r1, r2, r3 = philox4x32(
        group, zero, zero, zero, seed, jnp.broadcast_to(LEZO_KEY1, seed.shape)
    )

    def bm_pair(a, b):
        u1 = uniform01(a)
        u2 = uniform01(b)
        radius = jnp.sqrt(np.float32(-2.0) * jnp.log(u1))
        theta = np.float32(2.0 * np.pi) * u2
        return radius * jnp.cos(theta), radius * jnp.sin(theta)

    n0, n1 = bm_pair(r0, r1)
    n2, n3 = bm_pair(r2, r3)
    return jnp.stack([n0, n1, n2, n3], axis=-1)


def gauss_from_index_pack4(idx: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """z[i] ~ N(0,1) as a pure function of (seed, i), 4 elements per cipher.

    ``idx`` must be a contiguous, 4-aligned range for the packed layout to be
    exact (the Pallas grid guarantees this; the generic fallback handles any
    index vector at 4x cost).
    """
    idx = jnp.asarray(idx, dtype=jnp.uint32)
    group = idx >> np.uint32(2)
    slot = (idx & np.uint32(3)).astype(jnp.int32)
    quad = _gauss4_from_group(group, seed)
    return jnp.take_along_axis(quad, slot[:, None], axis=-1)[:, 0]


def _pack4_kernel(seed_ref, coeff_ref, p_ref, o_ref, *, block: int):
    start = pl.program_id(0) * block
    # block is a multiple of 4: run block//4 ciphers, get (block//4, 4)
    groups = (jnp.uint32(start) >> np.uint32(2)) + jnp.arange(
        block // 4, dtype=jnp.uint32
    )
    z = _gauss4_from_group(groups, seed_ref[0]).reshape(block)
    o_ref[...] = p_ref[...] + coeff_ref[0] * z


@functools.partial(jax.jit, static_argnames=("block",))
def zo_axpy_pack4(
    p: jnp.ndarray, seed: jnp.ndarray, coeff: jnp.ndarray, block: int = DEFAULT_BLOCK
):
    """out = p + coeff * z_pack4(seed); 4 normals per Philox call."""
    n = p.shape[0]
    block = min(block, max(256, 1 << (n - 1).bit_length()))
    block = max(4, (block // 4) * 4)
    n_pad = ((n + block - 1) // block) * block
    p_pad = jnp.pad(p, (0, n_pad - n)) if n_pad != n else p
    seed_arr = jnp.reshape(seed, (1,)).astype(jnp.int32)
    coeff_arr = jnp.reshape(coeff, (1,)).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_pack4_kernel, block=block),
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # seed: broadcast
            pl.BlockSpec((1,), lambda i: (0,)),  # coeff: broadcast
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=True,
    )(seed_arr, coeff_arr, p_pad)
    return out[:n]


def zo_axpy_pack4_np(p: np.ndarray, seed: int, coeff: float) -> np.ndarray:
    """Pure-numpy oracle for the pack4 stream."""
    idx = np.arange(p.shape[0], dtype=np.uint32)
    z = np.asarray(gauss_from_index_pack4(jnp.asarray(idx), jnp.uint32(seed)))
    return (p + np.float32(coeff) * z).astype(np.float32)

"""Row-wise LayerNorm as a Pallas kernel.

Small but on the forward hot path (2 per block + final). Tiled over rows:
each grid step normalizes a [BLOCK_ROWS, D] tile held in VMEM; gamma/beta
are broadcast into every step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

EPS = np.float32(1e-5)


def _ln_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    o_ref[...] = (x - mean) * jax.lax.rsqrt(var + EPS) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, block_rows: int = 64):
    """LayerNorm over the last axis of f32[rows, d]."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    return pl.pallas_call(
        _ln_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=True,
    )(x, gamma, beta)

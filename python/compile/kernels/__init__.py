"""L1: Pallas kernels for LeZO's compute hot spots.

- zo_axpy: the paper's contribution - fused seeded-Gaussian perturb/update.
- attention / layernorm: forward-pass hot spots.
- philox: the counter-based RNG shared by kernel and references.
"""

from .attention import mha_causal
from .layernorm import layernorm
from .philox import gauss_from_index, philox4x32
from .zo_axpy import zo_axpy

__all__ = ["zo_axpy", "mha_causal", "layernorm", "gauss_from_index", "philox4x32"]

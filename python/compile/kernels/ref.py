"""Pure oracles for every kernel, independent of the Pallas implementations.

- Philox / Box-Muller: re-implemented in *numpy* uint64 arithmetic (masked to
  32 bits), so a bug in the 16-bit-partial-product trick in philox.py cannot
  hide: the integer streams must match bit-exactly.
- attention / layernorm / axpy: straightforward jnp math (softmax attention,
  textbook LN), compared with allclose tolerances.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PHILOX_M0 = np.uint64(0xD2511F53)
PHILOX_M1 = np.uint64(0xCD9E8D57)
PHILOX_W0 = np.uint64(0x9E3779B9)
PHILOX_W1 = np.uint64(0xBB67AE85)
LEZO_KEY1 = np.uint64(0x4C655A4F)
MASK32 = np.uint64(0xFFFFFFFF)


def philox4x32_np(counter: np.ndarray, key: np.ndarray, rounds: int = 10) -> np.ndarray:
    """Reference Philox-4x32 on uint64-masked arithmetic.

    counter: uint array [..., 4]; key: uint array [..., 2].
    Returns uint32 array [..., 4].
    """
    c = [counter[..., i].astype(np.uint64) & MASK32 for i in range(4)]
    k0 = key[..., 0].astype(np.uint64) & MASK32
    k1 = key[..., 1].astype(np.uint64) & MASK32
    for _ in range(rounds):
        prod0 = PHILOX_M0 * c[0]
        prod1 = PHILOX_M1 * c[2]
        hi0, lo0 = prod0 >> np.uint64(32), prod0 & MASK32
        hi1, lo1 = prod1 >> np.uint64(32), prod1 & MASK32
        c = [hi1 ^ c[1] ^ k0, lo1, hi0 ^ c[3] ^ k1, lo0]
        k0 = (k0 + PHILOX_W0) & MASK32
        k1 = (k1 + PHILOX_W1) & MASK32
    return np.stack([w.astype(np.uint32) for w in c], axis=-1)


def gauss_from_index_np(idx: np.ndarray, seed: int) -> np.ndarray:
    """Reference for philox.gauss_from_index (mirrors its f32 arithmetic)."""
    idx = np.asarray(idx, dtype=np.uint64) & MASK32
    counter = np.zeros(idx.shape + (4,), dtype=np.uint64)
    counter[..., 0] = idx
    key = np.empty(idx.shape + (2,), dtype=np.uint64)
    key[..., 0] = np.uint64(seed) & MASK32
    key[..., 1] = LEZO_KEY1
    r = philox4x32_np(counter, key)
    u1 = (r[..., 0] >> np.uint32(9)).astype(np.float32) * np.float32(1.0 / (1 << 23)) + np.float32(
        1.0 / (1 << 24)
    )
    u2 = (r[..., 1] >> np.uint32(9)).astype(np.float32) * np.float32(1.0 / (1 << 23)) + np.float32(
        1.0 / (1 << 24)
    )
    radius = np.sqrt(np.float32(-2.0) * np.log(u1), dtype=np.float32)
    theta = np.float32(2.0 * np.pi) * u2
    return (radius * np.cos(theta, dtype=np.float32)).astype(np.float32)


def zo_axpy_np(p: np.ndarray, seed: int, coeff: float) -> np.ndarray:
    """Reference for the fused perturb/update kernel."""
    idx = np.arange(p.shape[0], dtype=np.uint64)
    return (p + np.float32(coeff) * gauss_from_index_np(idx, seed)).astype(np.float32)


def mha_causal_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Dense causal softmax attention oracle over [BH, S, Dh]."""
    _, seq, dh = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-5) * gamma + beta

"""The LeZO perturb/update Pallas kernel: fused seeded-Gaussian axpy.

    out[i] = p[i] + coeff * z(seed, i),   z(seed, i) ~ N(0, 1)

One kernel serves all four uses in Algorithm 1 of the paper, because the
Gaussian stream is a pure function of (seed, i):

    perturb   coeff = +mu
    flip      coeff = -2 mu
    restore   coeff = +mu
    update    coeff = -eta * projected_grad

TPU mapping (see DESIGN.md "Hardware adaptation"): the flat parameter vector
is tiled into BLOCK-sized VMEM blocks via BlockSpec; each grid step streams
one block HBM->VMEM, regenerates its slice of the Philox stream from the
global element index (no inter-block state), and writes one block back.
Traffic is 1 load + 1 store per element - bandwidth-bound, the arithmetic
(Philox + Box-Muller, ~60 flops/elem) hides under the DMA on real hardware.

We lower with interpret=True (CPU PJRT cannot execute Mosaic custom-calls);
interpret mode emits plain vectorized HLO for the same computation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .philox import gauss_from_index

# Default block: 64K f32 = 256 KiB in, 256 KiB out -> comfortably inside a
# 16 MiB VMEM even with double buffering. Swept in the perf pass.
DEFAULT_BLOCK = 65536


def _zo_axpy_kernel(seed_ref, coeff_ref, p_ref, o_ref, *, block: int):
    """One grid step: perturb one BLOCK-slice of the parameter vector."""
    start = pl.program_id(0) * block
    # Global element indices for this block; uint32 arithmetic is exact for
    # any realistic layer-unit size (< 2^32 elements).
    idx = jnp.uint32(start) + jnp.arange(block, dtype=jnp.uint32)
    z = gauss_from_index(idx, seed_ref[0])
    o_ref[...] = p_ref[...] + coeff_ref[0] * z


@functools.partial(jax.jit, static_argnames=("block",))
def zo_axpy(p: jnp.ndarray, seed: jnp.ndarray, coeff: jnp.ndarray, block: int = DEFAULT_BLOCK):
    """Fused seeded-Gaussian axpy over a flat f32 parameter vector.

    Args:
      p:     f32[n] flat parameter (layer-unit) vector.
      seed:  i32 scalar - per-(step, layer) seed from the coordinator.
      coeff: f32 scalar - +mu / -2mu / +mu / -eta*g.
      block: VMEM tile size (elements).

    Returns: f32[n] = p + coeff * z(seed).
    """
    n = p.shape[0]
    block = min(block, max(256, 1 << (n - 1).bit_length()))  # no oversized tiles
    n_pad = ((n + block - 1) // block) * block
    p_pad = jnp.pad(p, (0, n_pad - n)) if n_pad != n else p
    seed_arr = jnp.reshape(seed, (1,)).astype(jnp.int32)
    coeff_arr = jnp.reshape(coeff, (1,)).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_zo_axpy_kernel, block=block),
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # seed: broadcast
            pl.BlockSpec((1,), lambda i: (0,)),  # coeff: broadcast
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=True,
    )(seed_arr, coeff_arr, p_pad)
    return out[:n] if n_pad != n else out


def zo_axpy_vmem_bytes(block: int = DEFAULT_BLOCK) -> int:
    """Estimated VMEM footprint of one grid step (for DESIGN.md S8 perf notes)."""
    in_block = block * 4  # p tile
    out_block = block * 4  # o tile
    scratch = block * 4 * 6  # philox words + boxmuller temps (upper bound)
    return 2 * (in_block + out_block) + scratch  # x2: double buffering

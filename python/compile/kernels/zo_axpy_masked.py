"""Masked seeded-Gaussian axpy — the Sparse-MeZO (Liu et al., 2024) baseline.

    out[i] = p[i] + coeff * z(seed, i) * [ |p_ref[i]| <= tau ]

Sparse-MeZO perturbs/updates only *small-magnitude* parameters. Unlike LeZO's
structural layer skip, the mask is element-wise: every element is still
loaded and a predicate evaluated, so the perturb/update *memory traffic does
not shrink* — 2 loads + 1 store per element versus LeZO skipping whole units.
That asymmetry is the paper's criticism, and exporting this kernel lets the
bench measure it rather than assert it.

``p_ref`` is the unperturbed parameter vector at step start (the coordinator
passes the pre-step buffer), so the mask is stable across the perturb / flip
/ restore / update phases of a step — required for the restore identity. The
threshold ``tau`` is computed per unit by the coordinator (a magnitude
quantile — Sparse-MeZO's ranking step, whose cost the bench also reports).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .philox import gauss_from_index
from .zo_axpy import DEFAULT_BLOCK


def _masked_kernel(seed_ref, coeff_ref, tau_ref, p_ref, ref_ref, o_ref, *, block: int):
    start = pl.program_id(0) * block
    idx = jnp.uint32(start) + jnp.arange(block, dtype=jnp.uint32)
    z = gauss_from_index(idx, seed_ref[0])
    mask = (jnp.abs(ref_ref[...]) <= tau_ref[0]).astype(jnp.float32)
    o_ref[...] = p_ref[...] + coeff_ref[0] * z * mask


@functools.partial(jax.jit, static_argnames=("block",))
def zo_axpy_masked(
    p: jnp.ndarray,
    p_ref: jnp.ndarray,
    tau: jnp.ndarray,
    seed: jnp.ndarray,
    coeff: jnp.ndarray,
    block: int = DEFAULT_BLOCK,
):
    """out = p + coeff * z(seed) * (|p_ref| <= tau), elementwise."""
    n = p.shape[0]
    block = min(block, max(256, 1 << (n - 1).bit_length()))
    n_pad = ((n + block - 1) // block) * block
    pad = lambda x: jnp.pad(x, (0, n_pad - n)) if n_pad != n else x
    seed_arr = jnp.reshape(seed, (1,)).astype(jnp.int32)
    coeff_arr = jnp.reshape(coeff, (1,)).astype(jnp.float32)
    tau_arr = jnp.reshape(tau, (1,)).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_masked_kernel, block=block),
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # seed: broadcast
            pl.BlockSpec((1,), lambda i: (0,)),  # coeff: broadcast
            pl.BlockSpec((1,), lambda i: (0,)),  # tau: broadcast
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(seed_arr, coeff_arr, tau_arr, pad(p), pad(p_ref))
    return out[:n]


def zo_axpy_masked_np(p, p_ref, tau, seed, coeff):
    """Pure-numpy oracle (mirrors ref.zo_axpy_np)."""
    import numpy as np

    from .ref import gauss_from_index_np

    z = gauss_from_index_np(np.arange(p.shape[0], dtype=np.uint32), seed)
    mask = (np.abs(p_ref) <= tau).astype(np.float32)
    return (p + coeff * z * mask).astype(np.float32)

"""AOT exporter: artifact completeness + HLO-text invariants + manifest contract."""

import json
import os

import numpy as np
import pytest

from compile import model as M
from compile.aot import export_size, to_hlo_text
from compile.configs import SIZES, param_count

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "opt-micro")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        export_size(SIZES["opt-micro"], ART, use_pallas=True, verbose=False)
    with open(path) as f:
        return json.load(f)


def test_manifest_fields(manifest):
    cfg = SIZES["opt-micro"]
    assert manifest["name"] == "opt-micro"
    assert manifest["unit_lens"] == M.unit_lens(cfg)
    assert manifest["param_count"] == param_count(cfg)
    # axpy lens cover every model unit plus the PEFT adapter units
    expected = set(M.unit_lens(cfg))
    from compile import peft as P

    expected |= {P.lora_unit_len(cfg), P.prefix_unit_len(cfg)}
    assert sorted(manifest["axpy_lens"]) == sorted(expected)
    assert manifest["seq_buckets"] == list(cfg.seq_buckets)


def test_all_files_exist(manifest):
    for fname in manifest["files"].values():
        assert os.path.exists(os.path.join(ART, fname)), fname


def test_expected_executable_set(manifest):
    keys = set(manifest["files"])
    for s in manifest["seq_buckets"]:
        for stem in ("forward_loss", "example_losses", "predict", "forward_backward"):
            assert f"{stem}_s{s}" in keys
    for n in manifest["axpy_lens"]:
        assert f"zo_axpy_{n}" in keys


def test_init_bin_size_and_content(manifest):
    path = os.path.join(ART, manifest["init_file"])
    data = np.fromfile(path, dtype="<f4")
    assert data.size == manifest["param_count"]
    units = M.init_units(SIZES["opt-micro"], seed=0)
    np.testing.assert_array_equal(data, np.concatenate(units))


def test_hlo_text_parses_as_module(manifest):
    """Every artifact must start with an HloModule header (text interchange)."""
    for fname in manifest["files"].values():
        with open(os.path.join(ART, fname)) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), fname


def test_hlo_has_no_custom_calls(manifest):
    """interpret=True must have lowered Pallas to plain HLO: a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for fname in manifest["files"].values():
        with open(os.path.join(ART, fname)) as f:
            text = f.read()
        assert "custom-call" not in text, fname


def test_forward_loss_param_arity(manifest):
    """forward_loss takes n_units + 3 parameters, in unit order."""
    fname = manifest["files"][f"forward_loss_s{manifest['seq_buckets'][0]}"]
    with open(os.path.join(ART, fname)) as f:
        text = f.read()
    entry = [l for l in text.splitlines() if l.startswith("ENTRY")]
    assert len(entry) == 1
    n_params = entry[0].count("parameter")
    # some HLO texts put params on separate lines; fall back to counting
    if n_params == 0:
        n_params = text.count(" = f32[")  # loose; arity check below is primary
    expected = len(manifest["unit_lens"]) + 3
    assert f"parameter({expected - 1})" in text  # last arg index exists
    assert f"parameter({expected})" not in text  # and no more

"""Philox RNG: bit-exactness vs the numpy oracle + statistical quality.

This is the correctness keystone of the whole system: the rust coordinator
relies on perturb(+mu) / flip(-2mu) / restore(+mu) / update(-eta*g) all
regenerating *identical* z from (seed, index).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.philox import (
    LEZO_KEY1,
    boxmuller,
    gauss_from_index,
    mulhilo32,
    philox4x32,
    uniform01,
)

U32 = st.integers(min_value=0, max_value=2**32 - 1)


@given(U32, U32)
@settings(max_examples=200, deadline=None)
def test_mulhilo32_matches_u64_product(a, b):
    hi, lo = mulhilo32(jnp.uint32(a), jnp.uint32(b))
    prod = (a * b) & ((1 << 64) - 1)
    assert int(lo) == prod & 0xFFFFFFFF
    assert int(hi) == prod >> 32


@given(U32, U32, U32, U32, U32, U32)
@settings(max_examples=50, deadline=None)
def test_philox_scalar_matches_numpy_oracle(c0, c1, c2, c3, k0, k1):
    got = philox4x32(
        jnp.uint32(c0), jnp.uint32(c1), jnp.uint32(c2), jnp.uint32(c3),
        jnp.uint32(k0), jnp.uint32(k1),
    )
    counter = np.array([c0, c1, c2, c3], dtype=np.uint64)
    key = np.array([k0, k1], dtype=np.uint64)
    want = ref.philox4x32_np(counter, key)
    assert [int(w) for w in got] == [int(w) for w in want]


def test_philox_known_vector():
    """Canonical test vector from the Random123 distribution:
    philox4x32-10 of counter=ffffffff^4, key=ffffffff^2."""
    ff = jnp.uint32(0xFFFFFFFF)
    got = philox4x32(ff, ff, ff, ff, ff, ff)
    assert [hex(int(w)) for w in got] == ["0x408f276d", "0x41c83b0e", "0xa20bc7c6", "0x6d5451fd"]


def test_philox_zero_vector():
    """Canonical test vector: all-zero counter and key."""
    z = jnp.uint32(0)
    got = philox4x32(z, z, z, z, z, z)
    assert [hex(int(w)) for w in got] == ["0x6627e8d5", "0xe169c58d", "0xbc57ac4c", "0x9b00dbd8"]


@given(st.integers(0, 2**31 - 1), st.integers(0, 2**20))
@settings(max_examples=50, deadline=None)
def test_gauss_deterministic_and_matches_ref(seed, start):
    idx = np.arange(start, start + 64, dtype=np.uint64)
    a = np.asarray(gauss_from_index(jnp.asarray(idx, jnp.uint32), jnp.uint32(seed)))
    b = np.asarray(gauss_from_index(jnp.asarray(idx, jnp.uint32), jnp.uint32(seed)))
    c = ref.gauss_from_index_np(idx, seed)
    np.testing.assert_array_equal(a, b)  # bit-identical across calls
    np.testing.assert_allclose(a, c, rtol=0, atol=5e-7)


def test_gauss_streams_differ_across_seeds():
    idx = jnp.arange(256, dtype=jnp.uint32)
    a = np.asarray(gauss_from_index(idx, jnp.uint32(1)))
    b = np.asarray(gauss_from_index(idx, jnp.uint32(2)))
    assert np.abs(a - b).max() > 0.1


def test_uniform01_open_interval():
    bits = jnp.asarray([0, 1, 2**32 - 1, 2**31], dtype=jnp.uint32)
    u = np.asarray(uniform01(bits))
    assert (u > 0).all() and (u < 1).all()


@pytest.mark.parametrize("seed", [0, 1, 12345, 2**31 - 1])
def test_gauss_moments(seed):
    n = 200_000
    z = np.asarray(gauss_from_index(jnp.arange(n, dtype=jnp.uint32), jnp.uint32(seed)))
    assert abs(z.mean()) < 4.0 / np.sqrt(n), z.mean()
    assert abs(z.std() - 1.0) < 0.01, z.std()
    # excess kurtosis of N(0,1) is 0; sampling std ~ sqrt(24/n)
    kurt = ((z - z.mean()) ** 4).mean() / z.var() ** 2 - 3.0
    assert abs(kurt) < 6 * np.sqrt(24.0 / n), kurt


def test_gauss_no_correlation_between_adjacent():
    n = 100_000
    z = np.asarray(gauss_from_index(jnp.arange(n, dtype=jnp.uint32), jnp.uint32(7)))
    r = np.corrcoef(z[:-1], z[1:])[0, 1]
    assert abs(r) < 0.02, r


def test_domain_separator_is_lezo():
    assert int(LEZO_KEY1) == int.from_bytes(b"LeZO", "big")


def test_boxmuller_range_sane():
    r = np.random.RandomState(3).randint(0, 2**32, size=(10000, 2), dtype=np.uint64)
    z = np.asarray(boxmuller(jnp.asarray(r[:, 0], jnp.uint32), jnp.asarray(r[:, 1], jnp.uint32)))
    assert np.isfinite(z).all()
    assert np.abs(z).max() < 8.0  # 24-bit uniforms bound the tail

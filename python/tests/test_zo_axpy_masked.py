"""L1 tests: the Sparse-MeZO masked axpy kernel vs its numpy oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.zo_axpy_masked import zo_axpy_masked, zo_axpy_masked_np


def run(p, p_ref, tau, seed, coeff):
    return np.asarray(
        zo_axpy_masked(
            jnp.asarray(p), jnp.asarray(p_ref), jnp.float32(tau),
            jnp.int32(seed), jnp.float32(coeff),
        )
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    coeff=st.floats(min_value=-2.0, max_value=2.0, allow_nan=False),
    tau=st.floats(min_value=0.0, max_value=3.0, allow_nan=False),
)
def test_matches_oracle(n, seed, coeff, tau):
    rng = np.random.RandomState(n % 1000)
    p = rng.randn(n).astype(np.float32)
    out = run(p, p, tau, seed, coeff)
    ref = zo_axpy_masked_np(p, p, tau, seed, coeff)
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_tau_zero_is_identity_almost_surely():
    p = np.random.RandomState(1).randn(512).astype(np.float32) + 5.0  # |p| > 0
    out = run(p, p, 0.0, 7, 1.0)
    np.testing.assert_array_equal(out, p)


def test_tau_inf_equals_unmasked():
    from compile.kernels.zo_axpy import zo_axpy

    p = np.random.RandomState(2).randn(300).astype(np.float32)
    masked = run(p, p, 1e30, 11, 0.5)
    unmasked = np.asarray(zo_axpy(jnp.asarray(p), jnp.int32(11), jnp.float32(0.5)))
    np.testing.assert_allclose(masked, unmasked, atol=1e-6)


def test_mask_uses_reference_not_current():
    # mask comes from p_ref: with p_ref all-large, nothing moves even if p small
    p = np.zeros(100, dtype=np.float32)
    p_ref = np.full(100, 10.0, dtype=np.float32)
    out = run(p, p_ref, 1.0, 3, 1.0)
    np.testing.assert_array_equal(out, p)


def test_perturb_flip_restore_identity():
    # stable mask across phases -> exact restore (the step invariant)
    rng = np.random.RandomState(3)
    p0 = rng.randn(1000).astype(np.float32)
    tau, seed, mu = 0.6, 99, 1e-3
    p1 = run(p0, p0, tau, seed, +mu)
    p2 = run(p1, p0, tau, seed, -2 * mu)
    p3 = run(p2, p0, tau, seed, +mu)
    np.testing.assert_allclose(p3, p0, atol=1e-6)

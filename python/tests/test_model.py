"""L2 model: shapes, losses, gradients, and the flat-unit contract with rust."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import SIZES, param_count

CFG = SIZES["opt-micro"]


@pytest.fixture(scope="module")
def units():
    return [jnp.asarray(u) for u in M.init_units(CFG, seed=0)]


def _batch(b=2, s=16, seed=0):
    rs = np.random.RandomState(seed)
    tokens = jnp.asarray(rs.randint(0, CFG.vocab, size=(b, s)), jnp.int32)
    targets = jnp.asarray(rs.randint(0, CFG.vocab, size=(b, s)), jnp.int32)
    mask = jnp.asarray((rs.rand(b, s) > 0.3).astype(np.float32))
    return tokens, targets, mask


def test_unit_lens_match_param_count():
    assert sum(M.unit_lens(CFG)) == param_count(CFG)


def test_unit_count_is_layers_plus_two():
    assert len(M.unit_specs(CFG)) == CFG.n_layers + 2


def test_unflatten_round_trip():
    spec = M.block_spec(CFG)
    n = M.spec_len(spec)
    vec = jnp.arange(n, dtype=jnp.float32)
    parts = M.unflatten(vec, spec)
    flat_again = jnp.concatenate([parts[name].reshape(-1) for name, _ in spec])
    np.testing.assert_array_equal(np.asarray(flat_again), np.asarray(vec))


def test_logits_shape(units):
    tokens, _, _ = _batch()
    logits = M.forward_logits(units, tokens, CFG, use_pallas=False)
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_pallas_and_ref_forward_agree(units):
    """The Pallas forward (attention + LN kernels) must equal the jnp path."""
    tokens, targets, mask = _batch(seed=5)
    a = M.mean_loss(units, tokens, targets, mask, CFG, use_pallas=True)
    b = M.mean_loss(units, tokens, targets, mask, CFG, use_pallas=False)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4)


def test_initial_loss_near_uniform(units):
    """At init a tied-embedding LM should put loss near ln(V)."""
    tokens, targets, mask = _batch(b=4, s=32, seed=1)
    loss = float(M.mean_loss(units, tokens, targets, mask, CFG, use_pallas=False))
    assert abs(loss - np.log(CFG.vocab)) < 1.0, loss


def test_example_losses_consistent_with_mean(units):
    tokens, targets, mask = _batch(b=4, s=16, seed=2)
    per = M.example_losses(units, tokens, targets, mask, CFG, use_pallas=False)
    assert per.shape == (4,)
    # mean over positions (mask-weighted) vs per-example means
    total = float(M.mean_loss(units, tokens, targets, mask, CFG, use_pallas=False))
    weights = np.asarray(mask.sum(axis=-1))
    recombined = float((np.asarray(per) * weights).sum() / weights.sum())
    np.testing.assert_allclose(recombined, total, rtol=1e-5)


def test_mask_excludes_positions(units):
    """Loss must ignore masked-out positions entirely."""
    tokens, targets, mask = _batch(b=2, s=16, seed=3)
    t2 = targets.at[:, 0].set((targets[:, 0] + 1) % CFG.vocab)
    m0 = mask.at[:, 0].set(0.0)
    a = float(M.mean_loss(units, tokens, targets, m0, CFG, use_pallas=False))
    b = float(M.mean_loss(units, tokens, t2, m0, CFG, use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_grads_match_finite_differences(units):
    """FO substrate check: directional derivative vs central finite diff."""
    tokens, targets, mask = _batch(b=2, s=16, seed=4)
    outs = M.loss_and_grads(units, tokens, targets, mask, CFG)
    grads = outs[1:]
    rs = np.random.RandomState(0)
    # probe the final-LN unit (small, well-conditioned)
    u = len(units) - 1
    direction = jnp.asarray(rs.randn(units[u].shape[0]).astype(np.float32))
    direction = direction / jnp.linalg.norm(direction)
    eps = 1e-2
    def loss_at(t):
        us = list(units)
        us[u] = units[u] + t * direction
        return float(M.mean_loss(us, tokens, targets, mask, CFG, use_pallas=False))
    fd = (loss_at(eps) - loss_at(-eps)) / (2 * eps)
    analytic = float(jnp.dot(grads[u], direction))
    np.testing.assert_allclose(analytic, fd, rtol=2e-2, atol=1e-4)


def test_sgd_steps_decrease_loss(units):
    """A few FO steps on a fixed batch must reduce the loss."""
    tokens, targets, mask = _batch(b=4, s=16, seed=6)
    us = list(units)
    first = None
    for _ in range(5):
        outs = M.loss_and_grads(us, tokens, targets, mask, CFG)
        loss, grads = float(outs[0]), outs[1:]
        if first is None:
            first = loss
        us = [u - 0.5 * g for u, g in zip(us, grads)]
    last = float(M.mean_loss(us, tokens, targets, mask, CFG, use_pallas=False))
    assert last < first - 0.05, (first, last)


def test_predict_tokens_shape_dtype(units):
    tokens, _, _ = _batch(b=2, s=16)
    pred = M.predict_tokens(units, tokens, CFG, use_pallas=False)
    assert pred.shape == (2, 16) and pred.dtype == jnp.int32
    assert int(pred.min()) >= 0 and int(pred.max()) < CFG.vocab


def test_zo_spsa_step_decreases_loss_in_expectation(units):
    """End-to-end ZO sanity at the L2 level: averaged over seeds, the SPSA
    update direction correlates with the true gradient (Lemma 1)."""
    from compile.kernels.ref import gauss_from_index_np

    tokens, targets, mask = _batch(b=4, s=16, seed=7)
    us = [np.asarray(u) for u in units]
    mu, eta = 1e-2, 2e-2

    def loss_of(np_units):
        return float(
            M.mean_loss([jnp.asarray(u) for u in np_units], tokens, targets, mask, CFG, False)
        )

    base = loss_of(us)
    improved = 0
    trials = 6
    for seed in range(trials):
        plus = [u + mu * gauss_from_index_np(np.arange(u.size, dtype=np.uint64), seed * 31 + i)
                for i, u in enumerate(us)]
        minus = [u - mu * gauss_from_index_np(np.arange(u.size, dtype=np.uint64), seed * 31 + i)
                 for i, u in enumerate(us)]
        g = (loss_of(plus) - loss_of(minus)) / (2 * mu)
        stepped = [u - eta * g * gauss_from_index_np(np.arange(u.size, dtype=np.uint64), seed * 31 + i)
                   for i, u in enumerate(us)]
        if loss_of(stepped) < base:
            improved += 1
    assert improved >= trials // 2, f"only {improved}/{trials} SPSA steps improved"

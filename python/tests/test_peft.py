"""L2 PEFT tests: LoRA/prefix forward passes vs the base model oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import peft as P
from compile.configs import SIZES

CFG = SIZES["opt-micro"]


@pytest.fixture(scope="module")
def units():
    return [jnp.asarray(u) for u in M.init_units(CFG, seed=0)]


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.RandomState(1)
    return jnp.asarray(rng.randint(10, CFG.vocab, size=(2, 16)), dtype=jnp.int32)


def test_lora_zero_init_equals_base(units, tokens):
    # B = 0 at init -> adapter delta is exactly zero
    peft_units = [jnp.asarray(u) for u in P.init_peft_units(CFG, "lora", seed=0)]
    base = M.forward_logits(units, tokens, CFG, use_pallas=False)
    lora = P.forward_logits_peft(units, peft_units, tokens, CFG, "lora")
    np.testing.assert_allclose(np.asarray(base), np.asarray(lora), atol=1e-4)


def test_lora_nonzero_b_changes_logits(units, tokens):
    peft_units = [jnp.asarray(u) for u in P.init_peft_units(CFG, "lora", seed=0)]
    # set B_q of block 0 nonzero
    u0 = np.asarray(peft_units[0]).copy()
    q = CFG.d_model * P.LORA_RANK
    u0[q : 2 * q] = 0.05
    peft_units[0] = jnp.asarray(u0)
    base = M.forward_logits(units, tokens, CFG, use_pallas=False)
    lora = P.forward_logits_peft(units, peft_units, tokens, CFG, "lora")
    assert not np.allclose(np.asarray(base), np.asarray(lora), atol=1e-5)


def test_prefix_changes_logits_everywhere(units, tokens):
    peft_units = [jnp.asarray(u) for u in P.init_peft_units(CFG, "prefix", seed=3)]
    base = M.forward_logits(units, tokens, CFG, use_pallas=False)
    pre = P.forward_logits_peft(units, peft_units, tokens, CFG, "prefix")
    assert pre.shape == base.shape
    # prefixes attend into every position, so logits shift broadly
    diff = np.abs(np.asarray(pre) - np.asarray(base)).mean()
    assert diff > 1e-6


def test_prefix_zero_prefix_is_not_identity(units, tokens):
    # zero K/V prefix still contributes softmax mass (score 0 -> weight>0),
    # so it must NOT equal the base model: guards against silently dropping
    # the prefix path
    zero_units = [jnp.zeros(P.prefix_unit_len(CFG)) for _ in range(CFG.n_layers)]
    base = M.forward_logits(units, tokens, CFG, use_pallas=False)
    pre = P.forward_logits_peft(units, zero_units, tokens, CFG, "prefix")
    assert not np.allclose(np.asarray(base), np.asarray(pre), atol=1e-6)


def test_causality_preserved_under_peft(units):
    # changing a late token must not affect earlier positions' logits
    rng = np.random.RandomState(2)
    t1 = rng.randint(10, CFG.vocab, size=(1, 12)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 7) % CFG.vocab
    for mode in ("lora", "prefix"):
        peft_units = [jnp.asarray(u) for u in P.init_peft_units(CFG, mode, seed=1)]
        l1 = P.forward_logits_peft(units, peft_units, jnp.asarray(t1), CFG, mode)
        l2 = P.forward_logits_peft(units, peft_units, jnp.asarray(t2), CFG, mode)
        np.testing.assert_allclose(
            np.asarray(l1)[0, :-1], np.asarray(l2)[0, :-1], atol=1e-4,
            err_msg=f"{mode}: future token leaked into the past",
        )


def test_example_losses_match_mean_loss(units, tokens):
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones(tokens.shape, dtype=jnp.float32)
    for mode in ("lora", "prefix"):
        peft_units = [jnp.asarray(u) for u in P.init_peft_units(CFG, mode, seed=2)]
        per = P.example_losses_peft(units, peft_units, tokens, targets, mask, CFG, mode)
        mean = P.mean_loss_peft(units, peft_units, tokens, targets, mask, CFG, mode)
        assert per.shape == (tokens.shape[0],)
        np.testing.assert_allclose(float(jnp.mean(per)), float(mean), rtol=1e-5)


def test_unit_len_contract_with_rust():
    # must match rust/src/peft/mod.rs
    assert P.lora_unit_len(CFG) == 4 * CFG.d_model * P.LORA_RANK
    assert P.prefix_unit_len(CFG) == 2 * P.PREFIX_TOKENS * CFG.d_model


def test_predict_tokens_peft_shape(units, tokens):
    peft_units = [jnp.asarray(u) for u in P.init_peft_units(CFG, "lora", seed=0)]
    preds = P.predict_tokens_peft(units, peft_units, tokens, CFG, "lora")
    assert preds.shape == tokens.shape
    assert preds.dtype == jnp.int32

"""The LeZO perturb/update kernel vs its oracle, plus the algorithmic
invariants the rust coordinator depends on (Algorithm 1 of the paper)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.zo_axpy import zo_axpy, zo_axpy_vmem_bytes


def _rand(n, seed=0):
    return np.random.RandomState(seed).randn(n).astype(np.float32)


@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    coeff=st.floats(min_value=-2.0, max_value=2.0, allow_nan=False, width=32),
    block=st.sampled_from([256, 1024, 4096]),
)
@settings(max_examples=40, deadline=None)
def test_matches_oracle_over_shapes_and_blocks(n, seed, coeff, block):
    """Hypothesis sweep: arbitrary length (padding paths!), seed, coeff, tile."""
    p = _rand(n, seed % 97)
    got = np.asarray(zo_axpy(jnp.asarray(p), jnp.int32(seed), jnp.float32(coeff), block=block))
    want = ref.zo_axpy_np(p, seed, coeff)
    np.testing.assert_allclose(got, want, rtol=0, atol=2e-5)


def test_block_size_does_not_change_result():
    """The Philox stream is indexed globally, so tiling is invisible."""
    p = _rand(10_000)
    outs = [
        np.asarray(zo_axpy(jnp.asarray(p), jnp.int32(5), jnp.float32(0.1), block=b))
        for b in (256, 1024, 65536)
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_perturb_flip_restore_identity():
    """perturb(+mu) . flip(-2mu) . restore(+mu) == identity (fp tolerance) -
    the invariant that lets MeZO/LeZO keep zero optimizer state."""
    p = _rand(4096, 1)
    mu = 1e-3
    a = zo_axpy(jnp.asarray(p), jnp.int32(99), jnp.float32(mu))
    b = zo_axpy(a, jnp.int32(99), jnp.float32(-2 * mu))
    c = zo_axpy(b, jnp.int32(99), jnp.float32(mu))
    np.testing.assert_allclose(np.asarray(c), p, rtol=0, atol=1e-6)


def test_update_direction_matches_regenerated_z():
    """update(-eta*g) moves exactly along the z used for the perturbation."""
    p = _rand(2048, 2)
    eta_g = 0.01
    updated = np.asarray(zo_axpy(jnp.asarray(p), jnp.int32(7), jnp.float32(-eta_g)))
    z = ref.gauss_from_index_np(np.arange(2048, dtype=np.uint64), 7)
    np.testing.assert_allclose(updated, p - np.float32(eta_g) * z, rtol=0, atol=1e-6)


def test_different_layers_get_independent_streams():
    """The coordinator derives one seed per (step, layer); streams must differ."""
    p = np.zeros(1024, dtype=np.float32)
    za = np.asarray(zo_axpy(jnp.asarray(p), jnp.int32(1000), jnp.float32(1.0)))
    zb = np.asarray(zo_axpy(jnp.asarray(p), jnp.int32(1001), jnp.float32(1.0)))
    assert np.abs(za - zb).max() > 0.1
    # and each is standard normal
    assert abs(za.mean()) < 0.15 and abs(za.std() - 1.0) < 0.1


def test_coeff_zero_is_identity():
    p = _rand(777, 3)
    out = np.asarray(zo_axpy(jnp.asarray(p), jnp.int32(4), jnp.float32(0.0)))
    np.testing.assert_array_equal(out, p)


@pytest.mark.parametrize("block", [1024, 65536])
def test_vmem_estimate_under_budget(block):
    """Perf-model sanity: the default tile fits VMEM with double buffering."""
    assert zo_axpy_vmem_bytes(block) < 16 * 1024 * 1024

"""pack4 ablation kernel (EXPERIMENTS.md §Perf iteration 2 — measured,
reverted on CPU, kept in-tree as the TPU-oriented variant)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.zo_axpy_pack4 import (
    gauss_from_index_pack4,
    zo_axpy_pack4,
    zo_axpy_pack4_np,
)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    coeff=st.floats(min_value=-1.0, max_value=1.0, allow_nan=False),
)
def test_matches_oracle(n, seed, coeff):
    p = np.random.RandomState(n % 997).randn(n).astype(np.float32)
    out = np.asarray(zo_axpy_pack4(jnp.asarray(p), jnp.int32(seed), jnp.float32(coeff)))
    ref = zo_axpy_pack4_np(p, seed, coeff)
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_deterministic_and_seed_sensitive():
    p = jnp.zeros(1024, dtype=jnp.float32)
    a = np.asarray(zo_axpy_pack4(p, jnp.int32(7), jnp.float32(1.0)))
    b = np.asarray(zo_axpy_pack4(p, jnp.int32(7), jnp.float32(1.0)))
    c = np.asarray(zo_axpy_pack4(p, jnp.int32(8), jnp.float32(1.0)))
    np.testing.assert_array_equal(a, b)
    assert not np.allclose(a, c)


def test_gaussian_moments():
    idx = jnp.arange(200_000, dtype=jnp.uint32)
    z = np.asarray(gauss_from_index_pack4(idx, jnp.uint32(3)))
    assert abs(z.mean()) < 0.01
    assert abs(z.var() - 1.0) < 0.02
    # all four slots individually standard normal (the packing is sound)
    for s in range(4):
        zs = z[s::4]
        assert abs(zs.mean()) < 0.02, f"slot {s}"
        assert abs(zs.var() - 1.0) < 0.03, f"slot {s}"


def test_perturb_flip_restore_identity():
    p0 = np.random.RandomState(5).randn(2000).astype(np.float32)
    mu = 1e-3
    p = jnp.asarray(p0)
    p = zo_axpy_pack4(p, jnp.int32(11), jnp.float32(+mu))
    p = zo_axpy_pack4(p, jnp.int32(11), jnp.float32(-2 * mu))
    p = zo_axpy_pack4(p, jnp.int32(11), jnp.float32(+mu))
    np.testing.assert_allclose(np.asarray(p), p0, atol=1e-6)


def test_stream_differs_from_baseline():
    # pack4 is a *different* stream than the baseline kernel — the exporter
    # must never mix them within one artifact set
    from compile.kernels.zo_axpy import zo_axpy

    p = jnp.zeros(512, dtype=jnp.float32)
    a = np.asarray(zo_axpy(p, jnp.int32(3), jnp.float32(1.0)))
    b = np.asarray(zo_axpy_pack4(p, jnp.int32(3), jnp.float32(1.0)))
    assert not np.allclose(a, b)

"""Pallas causal attention + layernorm kernels vs dense jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.attention import mha_causal, mha_vmem_bytes
from compile.kernels.layernorm import layernorm


def _qkv(bh, s, dh, seed=0):
    rs = np.random.RandomState(seed)
    return [jnp.asarray(rs.randn(bh, s, dh).astype(np.float32)) for _ in range(3)]


@pytest.mark.parametrize("bh,s,dh", [(1, 16, 8), (4, 32, 16), (8, 64, 32), (2, 64, 64)])
def test_matches_dense_oracle(bh, s, dh):
    q, k, v = _qkv(bh, s, dh, seed=s + dh)
    got = mha_causal(q, k, v)
    want = ref.mha_causal_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@given(
    bh=st.integers(1, 4),
    s_pow=st.integers(4, 6),  # seq 16..64
    dh=st.sampled_from([8, 16, 32]),
    bq=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
)
@settings(max_examples=20, deadline=None)
def test_block_shapes_do_not_change_result(bh, s_pow, dh, bq, bk):
    s = 1 << s_pow
    q, k, v = _qkv(bh, s, dh, seed=s_pow)
    got = mha_causal(q, k, v, block_q=min(bq, s), block_k=min(bk, s))
    want = ref.mha_causal_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_causality_future_kv_irrelevant():
    """Changing k/v at positions > t must not change the output at t."""
    q, k, v = _qkv(2, 32, 16, seed=9)
    out1 = np.asarray(mha_causal(q, k, v))
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-99.0)
    out2 = np.asarray(mha_causal(q, k2, v2))
    np.testing.assert_allclose(out1[:, :20], out2[:, :20], rtol=1e-6, atol=1e-6)
    assert np.abs(out1[:, 20:] - out2[:, 20:]).max() > 1.0


def test_first_position_attends_only_to_itself():
    q, k, v = _qkv(1, 16, 8, seed=11)
    out = np.asarray(mha_causal(q, k, v))
    np.testing.assert_allclose(out[0, 0], np.asarray(v)[0, 0], rtol=1e-5, atol=1e-5)


def test_uniform_values_softmax_mean():
    """With constant v, output must equal v regardless of scores."""
    q, k, _ = _qkv(2, 32, 16, seed=13)
    v = jnp.ones((2, 32, 16), dtype=jnp.float32) * 3.5
    out = np.asarray(mha_causal(q, k, v))
    np.testing.assert_allclose(out, 3.5, rtol=1e-5, atol=1e-5)


def test_vmem_estimate_under_budget():
    assert mha_vmem_bytes(seq=2048, dh=64) < 16 * 1024 * 1024


# --- layernorm ------------------------------------------------------------


@given(
    rows_pow=st.integers(0, 7),
    d=st.sampled_from([8, 64, 256]),
    block=st.sampled_from([1, 16, 64]),
)
@settings(max_examples=20, deadline=None)
def test_layernorm_matches_ref(rows_pow, d, block):
    rows = 1 << rows_pow
    block = min(block, rows)
    if rows % block != 0:
        block = 1
    rs = np.random.RandomState(rows + d)
    x = jnp.asarray(rs.randn(rows, d).astype(np.float32))
    g = jnp.asarray(rs.randn(d).astype(np.float32))
    b = jnp.asarray(rs.randn(d).astype(np.float32))
    got = layernorm(x, g, b, block_rows=block)
    want = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_layernorm_output_statistics():
    """With gamma=1, beta=0 each row is zero-mean unit-variance."""
    x = jnp.asarray(np.random.RandomState(0).randn(64, 128).astype(np.float32) * 5 + 3)
    out = np.asarray(layernorm(x, jnp.ones(128), jnp.zeros(128)))
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-2)

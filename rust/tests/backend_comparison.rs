//! The differential harness pinning the tentpole invariant: `backend=sharded`
//! at any shard count is `to_bits`-identical to `backend=native` — per-step
//! losses, the eval history, and the final parameters — because replicas
//! apply the same seeded op sequence in the same order and exchange only
//! `(probe, loss)` scalars.
//!
//! Two levels:
//! - engine-level: one `SpsaEngine` stepping a `NativeBackend` sequentially
//!   vs one stepping a `ShardedBackend` through the plan fan-out executor,
//!   across the optimizer zoo, both precisions, and LeZO active subsets;
//! - trainer-level: whole `Trainer::run` reports, including a crash@K inside
//!   a sharded run resumed under a *different* shard count (the fingerprint
//!   deliberately excludes `shards`) against an uninterrupted native twin;
//! - process-level: `shard_transport=socket` against REAL `lezo worker`
//!   processes spawned from the built binary — the socket trajectory must
//!   match the thread and native ones bitwise, including under injected
//!   transport faults, a worker killed mid-run (degraded continuation),
//!   and a coordinator crash@K resumed onto the same workers.

use lezo::config::{Method, RunConfig, ShardTransport};
use lezo::coordinator::metrics::StageTimes;
use lezo::coordinator::optim::make_optimizer;
use lezo::coordinator::spsa::{SpsaEngine, TunableUnits, ZoStep};
use lezo::coordinator::trainer::TrainReport;
use lezo::coordinator::{Trainer, ZoOptKind};
use lezo::data::batch::Batch;
use lezo::peft::PeftMode;
use lezo::runtime::backend::{Backend, BackendKind, Precision};
use lezo::runtime::{NativeBackend, ShardedBackend};
use std::path::PathBuf;

const CRASH: &str = "injected crash";

/// Trainer-level runs resolve env overrides; any LEZO_* override would
/// change (or re-route) the trajectory under comparison.
fn env_overridden() -> bool {
    for var in [
        "LEZO_FAULTS",
        "LEZO_ZO_OPT",
        "LEZO_PRECISION",
        "LEZO_BACKEND",
        "LEZO_SHARDS",
        "LEZO_NET_TIMEOUT_MS",
        "LEZO_NET_RETRIES",
    ] {
        if std::env::var(var).map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED: {var} is set and would override the run under test");
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// engine level
// ---------------------------------------------------------------------------

fn nano_batch(spec: &lezo::model::spec::ModelSpec) -> Batch {
    let seqs: Vec<Vec<u32>> = (0..spec.train_batch)
        .map(|r| (0..12u32).map(|i| 20 + ((r as u32 + i) % 50)).collect())
        .collect();
    Batch::lm_batch(&seqs, spec.train_batch, 16).unwrap()
}

/// Drive `steps` ZO steps of `kind` on one backend; `fanout` selects the
/// plan fan-out executor (sharded) vs the sequential path (native).
fn drive<B: Backend>(
    backend: &B,
    kind: ZoOptKind,
    steps: u64,
    fanout: bool,
) -> (Vec<ZoStep>, Vec<Vec<f32>>) {
    let host = backend.initial_params("").unwrap().0;
    let mut units = TunableUnits::from_host(backend, &host).unwrap();
    // a LeZO-style sparse active set: everything but unit 1
    let active: Vec<usize> = (0..units.n_units()).filter(|&k| k != 1).collect();
    let batch = nano_batch(backend.spec());
    let prepared = backend.prepare_batch(&batch).unwrap();
    let eng = SpsaEngine::new(backend, 1e-3, 11).unwrap();
    let mut opt = make_optimizer(kind);
    let mut times = StageTimes::default();
    let mut zs = Vec::new();
    for step in 0..steps {
        let s = if fanout {
            eng.zo_step_fanout(
                step,
                &mut units,
                &active,
                1e-3,
                opt.as_mut(),
                PeftMode::Full,
                None,
                &prepared,
                &mut |_| Ok(None),
                &mut times,
            )
            .unwrap()
        } else {
            let mut loss = |u: &TunableUnits<B>| {
                backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
            };
            eng.zo_step_opt(step, &mut units, &active, 1e-3, opt.as_mut(), &mut loss, &mut times)
                .unwrap()
        };
        zs.push(s);
    }
    (zs, units.to_host(backend).unwrap())
}

fn assert_trajectories_bit_identical(
    (nat_zs, nat_params): &(Vec<ZoStep>, Vec<Vec<f32>>),
    (sh_zs, sh_params): &(Vec<ZoStep>, Vec<Vec<f32>>),
    what: &str,
) {
    for (step, (a, b)) in nat_zs.iter().zip(sh_zs).enumerate() {
        assert_eq!(a.loss_plus.to_bits(), b.loss_plus.to_bits(), "{what}: step {step} l+");
        assert_eq!(a.loss_minus.to_bits(), b.loss_minus.to_bits(), "{what}: step {step} l-");
        assert_eq!(
            a.projected_grad.to_bits(),
            b.projected_grad.to_bits(),
            "{what}: step {step} grad"
        );
        assert_eq!(a.active_params, b.active_params, "{what}: step {step}");
        assert_eq!(a.skipped, b.skipped, "{what}: step {step}");
    }
    assert_eq!(nat_params.len(), sh_params.len(), "{what}: unit count");
    for (k, (ua, ub)) in nat_params.iter().zip(sh_params).enumerate() {
        assert_eq!(ua.len(), ub.len(), "{what}: unit {k} len");
        for (i, (x, y)) in ua.iter().zip(ub).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: unit {k} param {i}: {x} vs {y}");
        }
    }
}

#[test]
fn fanout_matches_sequential_across_zoo_shards_and_precisions() {
    // the full engine-level matrix: every (shards, rule, precision) cell
    // must reproduce the native sequential trajectory bit-for-bit — the
    // zoo covers both probe schedules (fzoo is one-sided batched), and the
    // precision axis covers the bf16 and block-quantized shadow paths
    for &shards in &[1usize, 2, 4] {
        for kind in [ZoOptKind::Sgd, ZoOptKind::Adam, ZoOptKind::Fzoo] {
            for precision in
                [Precision::F32, Precision::Bf16, Precision::Int8, Precision::Int4]
            {
                let native =
                    NativeBackend::preset("opt-nano").unwrap().with_precision(precision);
                let sharded =
                    ShardedBackend::preset_with_precision("opt-nano", shards, precision).unwrap();
                let nat = drive(&native, kind, 3, false);
                let sh = drive(&sharded, kind, 3, true);
                let what = format!("{shards} shards / {kind} / {precision}");
                assert_trajectories_bit_identical(&nat, &sh, &what);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// trainer level
// ---------------------------------------------------------------------------

fn fresh_root(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("lezo_cmp_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d.to_str().unwrap().to_string()
}

fn nano_cfg(tag: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "opt-nano".into();
    cfg.backend = BackendKind::Native;
    cfg.method = Method::Lezo;
    cfg.drop_layers = 1;
    cfg.steps = 4;
    cfg.eval_every = 2;
    cfg.eval_examples = 4;
    cfg.train_examples = 8;
    cfg.mean_len = 8;
    cfg.lr = 1e-4;
    cfg.artifacts_root = fresh_root(tag);
    cfg
}

fn run(cfg: &RunConfig) -> anyhow::Result<TrainReport> {
    Trainer::new(cfg.clone()).run()
}

/// Everything a sharded run must reproduce from its native twin, bitwise
/// (wall-clock fields excluded — time is the one thing that may differ).
fn assert_reports_bit_identical(sharded: &TrainReport, native: &TrainReport, what: &str) {
    assert_eq!(sharded.losses.len(), native.losses.len(), "{what}: loss count");
    for (i, (a, b)) in sharded.losses.iter().zip(&native.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: loss[{i}] {a} vs {b}");
    }
    assert_eq!(sharded.history.len(), native.history.len(), "{what}: history length");
    for (a, b) in sharded.history.iter().zip(&native.history) {
        assert_eq!(a.step, b.step, "{what}: eval step");
        assert_eq!(a.metric.to_bits(), b.metric.to_bits(), "{what}: metric at step {}", a.step);
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "{what}: train_loss at step {}",
            a.step
        );
    }
    assert_eq!(sharded.final_metric.to_bits(), native.final_metric.to_bits(), "{what}: final");
    assert_eq!(sharded.best_metric.to_bits(), native.best_metric.to_bits(), "{what}: best");
    assert_eq!(sharded.stage_times.steps, native.stage_times.steps, "{what}: stage steps");
    assert_eq!(sharded.zo_state_bytes, native.zo_state_bytes, "{what}: zo state bytes");
}

#[test]
fn trainer_runs_match_native_at_every_shard_count() {
    if env_overridden() {
        return;
    }
    let native = run(&nano_cfg("tr_native")).unwrap();
    assert_eq!(native.backend, "native");
    for shards in [1usize, 2, 4] {
        let mut cfg = nano_cfg(&format!("tr_sh{shards}"));
        cfg.backend = BackendKind::Sharded;
        cfg.shards = shards;
        let sharded = run(&cfg).unwrap();
        assert_eq!(sharded.backend, "sharded");
        assert_reports_bit_identical(&sharded, &native, &format!("{shards} shards"));
    }
}

#[test]
fn sparse_mezo_runs_match_on_the_broadcast_path() {
    // Sparse-MeZO never fans out (element-wise masked sweeps), but under
    // backend=sharded its mutations broadcast — lockstep must still hold
    if env_overridden() {
        return;
    }
    let mut cfg = nano_cfg("smezo_native");
    cfg.method = Method::Smezo;
    cfg.drop_layers = 0;
    let native = run(&cfg).unwrap();
    let mut cfg = nano_cfg("smezo_sharded");
    cfg.method = Method::Smezo;
    cfg.drop_layers = 0;
    cfg.backend = BackendKind::Sharded;
    cfg.shards = 2;
    let sharded = run(&cfg).unwrap();
    assert_reports_bit_identical(&sharded, &native, "smezo");
}

#[test]
fn bf16_trainer_runs_match_bitwise() {
    if env_overridden() {
        return;
    }
    let mut cfg = nano_cfg("bf16_native");
    cfg.precision = Precision::Bf16;
    let native = run(&cfg).unwrap();
    assert_eq!(native.precision, Precision::Bf16);
    let mut cfg = nano_cfg("bf16_sharded");
    cfg.precision = Precision::Bf16;
    cfg.backend = BackendKind::Sharded;
    cfg.shards = 2;
    let sharded = run(&cfg).unwrap();
    assert_eq!(sharded.precision, Precision::Bf16);
    assert_reports_bit_identical(&sharded, &native, "bf16");
}

#[test]
fn sharded_crash_resume_reshards_and_matches_the_clean_native_run() {
    // crash@2 inside a 2-shard run, then resume with 4 shards: the config
    // fingerprint deliberately excludes the worker geometry, so an elastic
    // re-shard resumes onto the exact trajectory of the uninterrupted
    // native twin
    if env_overridden() {
        return;
    }
    let mut clean_cfg = nano_cfg("crash_clean");
    clean_cfg.save_every = 1;
    let clean = run(&clean_cfg).unwrap();

    let mut cfg = nano_cfg("crash_sharded");
    cfg.backend = BackendKind::Sharded;
    cfg.shards = 2;
    cfg.save_every = 1;
    cfg.faults = "crash@2".into();
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains(CRASH), "{err}");
    let state = PathBuf::from(cfg.artifact_dir()).join("train_state.ckpt");
    assert!(state.exists(), "a resumable state must exist after the crash");

    cfg.faults.clear();
    cfg.shards = 4;
    let resumed = run(&cfg).unwrap();
    assert_eq!(resumed.resumed_from, Some(2));
    assert_eq!(resumed.backend, "sharded");
    assert_reports_bit_identical(&resumed, &clean, "crash@2 + re-shard 2->4");
    assert!(!state.exists(), "a completed run must delete its resume state");
}

#[test]
fn nan_loss_fault_fires_identically_under_fanout() {
    // the injected-NaN boundary (first forward of the step) maps to eval 0
    // of the plan; both executors must skip the same step and record the
    // same NaN placeholder
    if env_overridden() {
        return;
    }
    let mut a = nano_cfg("nan_native");
    a.faults = "nan-loss@2".into();
    a.set("on_nonfinite", "skip-step").unwrap();
    let native = run(&a).unwrap();
    assert!(native.losses[1].is_nan(), "step 2's loss is the NaN placeholder");

    let mut b = nano_cfg("nan_sharded");
    b.backend = BackendKind::Sharded;
    b.shards = 2;
    b.faults = "nan-loss@2".into();
    b.set("on_nonfinite", "skip-step").unwrap();
    let sharded = run(&b).unwrap();
    assert!(sharded.losses[1].is_nan());
    assert_reports_bit_identical(&sharded, &native, "nan-loss skip-step");
}

#[test]
fn quant_trainer_runs_match_bitwise() {
    // the quantized twins of `bf16_trainer_runs_match_bitwise`: the shadow
    // re-quantization protocol must not perturb the fanned-out trajectory
    if env_overridden() {
        return;
    }
    for precision in [Precision::Int8, Precision::Int4] {
        let tag = format!("{precision}");
        let mut cfg = nano_cfg(&format!("{tag}_native"));
        cfg.precision = precision;
        let native = run(&cfg).unwrap();
        assert_eq!(native.precision, precision);
        let mut cfg = nano_cfg(&format!("{tag}_sharded"));
        cfg.precision = precision;
        cfg.backend = BackendKind::Sharded;
        cfg.shards = 2;
        let sharded = run(&cfg).unwrap();
        assert_eq!(sharded.precision, precision);
        assert_reports_bit_identical(&sharded, &native, &tag);
    }
}

#[test]
fn sharded_io_err_on_save_then_crash_still_resumes_to_the_clean_run() {
    // the missing fault-matrix row: sharded x io-err@save x resume. The
    // first save attempt fails (warn-and-continue), the run then crashes
    // after step 2 — the surviving step-2 save must carry a resume that
    // lands on the clean native trajectory, bitwise
    if env_overridden() {
        return;
    }
    let mut clean_cfg = nano_cfg("shioerr_clean");
    clean_cfg.save_every = 1;
    let clean = run(&clean_cfg).unwrap();

    let mut cfg = nano_cfg("shioerr");
    cfg.backend = BackendKind::Sharded;
    cfg.shards = 2;
    cfg.save_every = 1;
    cfg.faults = "io-err@save:1,crash@2".into();
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains(CRASH), "{err}");
    let state = PathBuf::from(cfg.artifact_dir()).join("train_state.ckpt");
    assert!(state.exists(), "the step-2 save must survive the failed first attempt");

    cfg.faults.clear();
    let resumed = run(&cfg).unwrap();
    assert_eq!(resumed.resumed_from, Some(2));
    assert_reports_bit_identical(&resumed, &clean, "sharded io-err@save + crash@2");
}

// ---------------------------------------------------------------------------
// process level: shard_transport=socket against real spawned workers
// ---------------------------------------------------------------------------

/// A fleet of real `lezo worker --listen 127.0.0.1:0` processes spawned
/// from the built binary. Each worker announces its ephemeral port on
/// stdout; the guard kills whatever is still alive on drop. Workers are
/// long-lived services: one fleet serves many runs in sequence, because
/// every run's `INIT` resets worker state.
struct WorkerFleet {
    procs: Vec<std::process::Child>,
    addrs: Vec<String>,
}

impl WorkerFleet {
    fn spawn(n: usize) -> WorkerFleet {
        use std::io::BufRead;
        let exe = env!("CARGO_BIN_EXE_lezo");
        let mut procs = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..n {
            let mut child = std::process::Command::new(exe)
                .args(["worker", "--listen", "127.0.0.1:0"])
                .stdout(std::process::Stdio::piped())
                .stderr(std::process::Stdio::null())
                .spawn()
                .expect("spawning `lezo worker` from the built binary");
            let stdout = child.stdout.take().unwrap();
            let mut line = String::new();
            std::io::BufReader::new(stdout).read_line(&mut line).unwrap();
            let addr = line
                .trim()
                .strip_prefix("worker listening on ")
                .unwrap_or_else(|| panic!("unexpected worker banner {line:?}"))
                .to_string();
            procs.push(child);
            addrs.push(addr);
        }
        WorkerFleet { procs, addrs }
    }

    /// The comma-joined value for the `workers` config key.
    fn workers_key(&self) -> String {
        self.addrs.join(",")
    }
}

impl Drop for WorkerFleet {
    fn drop(&mut self) {
        for c in &mut self.procs {
            c.kill().ok();
            c.wait().ok();
        }
    }
}

/// `nano_cfg` wired for socket transport against `fleet`.
fn socket_cfg(tag: &str, fleet: &WorkerFleet) -> RunConfig {
    let mut cfg = nano_cfg(tag);
    cfg.backend = BackendKind::Sharded;
    cfg.shards = fleet.addrs.len();
    cfg.shard_transport = ShardTransport::Socket;
    cfg.workers = fleet.workers_key();
    cfg
}

#[test]
fn socket_trainer_matches_thread_and_native_across_zoo_and_precisions() {
    // the tentpole acceptance matrix: {zo-sgd, zo-adam, fzoo} x {f32, bf16}
    // under LeZO sparsity (nano_cfg is method=lezo, drop_layers=1), each
    // cell run three ways — native, in-process thread shards, and socket
    // shards over real worker processes — all three bitwise identical
    if env_overridden() {
        return;
    }
    let fleet = WorkerFleet::spawn(2);
    for kind in [ZoOptKind::Sgd, ZoOptKind::Adam, ZoOptKind::Fzoo] {
        for precision in [Precision::F32, Precision::Bf16] {
            let cell = format!("{kind}/{precision}");
            let tag = cell.replace(['-', '/'], "_");
            let mut cfg = nano_cfg(&format!("skt_nat_{tag}"));
            cfg.zo_opt = kind;
            cfg.precision = precision;
            let native = run(&cfg).unwrap();

            let mut cfg = nano_cfg(&format!("skt_thr_{tag}"));
            cfg.zo_opt = kind;
            cfg.precision = precision;
            cfg.backend = BackendKind::Sharded;
            cfg.shards = 2;
            let thread = run(&cfg).unwrap();
            assert_reports_bit_identical(&thread, &native, &format!("{cell} thread"));

            let mut cfg = socket_cfg(&format!("skt_skt_{tag}"), &fleet);
            cfg.zo_opt = kind;
            cfg.precision = precision;
            let socket = run(&cfg).unwrap();
            assert_eq!(socket.backend, "sharded");
            assert_reports_bit_identical(&socket, &native, &format!("{cell} socket"));
        }
    }
}

#[test]
fn socket_worker_killed_mid_run_degrades_and_still_matches_native() {
    // worker-crash@2:1 kills the shard-1 process at step 2's plan receipt.
    // The coordinator must detect the death within its bounded retries,
    // re-partition the remaining evals over the survivor, and finish on
    // the EXACT native trajectory — degradation is a latency event, never
    // a numerics event
    if env_overridden() {
        return;
    }
    let native = run(&nano_cfg("skt_kill_native")).unwrap();

    let fleet = WorkerFleet::spawn(2);
    let mut cfg = socket_cfg("skt_kill", &fleet);
    cfg.faults = "worker-crash@2:1".into();
    cfg.net_timeout_ms = 2_000;
    let degraded = run(&cfg).unwrap();
    assert_reports_bit_identical(&degraded, &native, "worker killed at step 2");

    // the shard-1 process really died with the injected exit code
    let mut fleet = fleet;
    let status = fleet.procs[1]
        .wait()
        .expect("shard 1 must have exited after the injected worker-crash");
    assert_eq!(status.code(), Some(3), "worker-crash exits with code 3");
}

#[test]
fn socket_transport_faults_recover_within_retries_bitwise() {
    // one run absorbing all three wire faults: a swallowed reply at step 2,
    // a stalled (but in-budget) reply at step 3, and a CRC-corrupted reply
    // at step 4. Every recovery is an idempotent resend served from the
    // worker's reply cache, so the trajectory is untouched
    if env_overridden() {
        return;
    }
    let native = run(&nano_cfg("skt_net_native")).unwrap();

    let fleet = WorkerFleet::spawn(2);
    let mut cfg = socket_cfg("skt_net", &fleet);
    cfg.faults = "net-drop@2,net-delay@3:100,net-corrupt@4".into();
    let recovered = run(&cfg).unwrap();
    assert_reports_bit_identical(&recovered, &native, "net-drop + net-delay + net-corrupt");
}

#[test]
fn socket_delay_beyond_timeout_still_lands_on_the_native_trajectory() {
    // a stall longer than net_timeout_ms looks exactly like a dead peer.
    // Whether the coordinator's retries reach the worker's cached reply or
    // exhaust and degrade to the survivor, the answer must be the same
    // bits — that invariance is what makes the timeout knob safe to tune
    if env_overridden() {
        return;
    }
    let native = run(&nano_cfg("skt_slow_native")).unwrap();

    let fleet = WorkerFleet::spawn(2);
    let mut cfg = socket_cfg("skt_slow", &fleet);
    cfg.faults = "net-delay@2:600".into();
    cfg.net_timeout_ms = 250;
    cfg.net_retries = 6;
    let slow = run(&cfg).unwrap();
    assert_reports_bit_identical(&slow, &native, "delay beyond timeout");
}

#[test]
fn socket_crash_resume_composes_and_reuses_the_same_workers() {
    // robustness features compose: a coordinator crash@2 under socket
    // transport leaves a resumable state; the resumed run re-INITs the
    // SAME still-running worker processes and completes on the clean
    // native trajectory. Also proves a worker fleet survives its
    // coordinator dying mid-run
    if env_overridden() {
        return;
    }
    let mut clean_cfg = nano_cfg("skt_crash_clean");
    clean_cfg.save_every = 1;
    let clean = run(&clean_cfg).unwrap();

    let fleet = WorkerFleet::spawn(2);
    let mut cfg = socket_cfg("skt_crash", &fleet);
    cfg.save_every = 1;
    cfg.faults = "crash@2".into();
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains(CRASH), "{err}");
    let state = PathBuf::from(cfg.artifact_dir()).join("train_state.ckpt");
    assert!(state.exists(), "a resumable state must exist after the crash");

    cfg.faults.clear();
    let resumed = run(&cfg).unwrap();
    assert_eq!(resumed.resumed_from, Some(2));
    assert_reports_bit_identical(&resumed, &clean, "socket crash@2 + resume");
    assert!(!state.exists(), "a completed run must delete its resume state");
}

#[test]
fn quant_crash_resume_matches_the_clean_run() {
    // the quantized-precision x crash/resume row: shadows never reach the
    // checkpoint (masters stay f32), so a resumed int8 run re-quantizes
    // from the restored masters and lands on the clean trajectory, bitwise
    if env_overridden() {
        return;
    }
    for precision in [Precision::Int8, Precision::Int4] {
        let tag = format!("qcrash_{precision}");
        let mut clean_cfg = nano_cfg(&format!("{tag}_clean"));
        clean_cfg.precision = precision;
        clean_cfg.save_every = 1;
        let clean = run(&clean_cfg).unwrap();

        let mut cfg = nano_cfg(&tag);
        cfg.precision = precision;
        cfg.save_every = 1;
        cfg.faults = "crash@2".into();
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains(CRASH), "{err}");

        cfg.faults.clear();
        let resumed = run(&cfg).unwrap();
        assert_eq!(resumed.resumed_from, Some(2), "{precision}");
        assert_eq!(resumed.precision, precision);
        assert_reports_bit_identical(&resumed, &clean, &tag);
    }
}

//! Wire-protocol conformance tests for the socket shard transport
//! (`runtime/transport.rs`): the frame envelope, the handshake, and the
//! StepPlan/Batch codecs.
//!
//! The failure-policy contract under test is "no silent wrong answers":
//! a frame truncated at ANY byte boundary and a frame corrupted at ANY
//! payload or CRC byte must surface as a named error — never as a
//! successfully decoded frame. The serialization determinism test is the
//! kernel-twin analogue for the wire: the encoded bytes of a [`StepPlan`]
//! must not depend on the worker-thread count, because socket-mode
//! bitwise identity rests on every replica receiving identical plans.
//!
//! [`StepPlan`]: lezo::runtime::plan::StepPlan

use lezo::coordinator::optim::ProbeSchedule;
use lezo::coordinator::spsa::{SpsaEngine, TunableUnits};
use lezo::data::batch::Batch;
use lezo::runtime::backend::Backend;
use lezo::runtime::native::parallel::with_threads;
use lezo::runtime::plan::{PlanPhase, StepPlan};
use lezo::runtime::transport::{
    crc32, decode_batch, decode_frame, decode_plan, encode_batch_into, encode_plan, expect_hello,
    frame_bytes, read_frame, read_frame_opt, write_frame, write_hello, Cur, MAX_FRAME, T_HBEA,
    T_LOSS, T_PLAN, WIRE_MAGIC, WIRE_VERSION,
};
use lezo::runtime::NativeBackend;
use std::io::Cursor;

/// Deterministic junk payload (no RNG needed — the envelope is agnostic
/// to payload content, only length and bytes matter).
fn payload(n: usize) -> Vec<u8> {
    (0..n).map(|i| (i as u32).wrapping_mul(2_654_435_761) as u8).collect()
}

// ---------------------------------------------------------------------------
// envelope: round-trip property over sizes and tags
// ---------------------------------------------------------------------------

#[test]
fn frame_round_trips_across_sizes_and_tags() {
    for &n in &[0usize, 1, 3, 4, 7, 8, 12, 255, 256, 1024, 65_537] {
        for tag in [T_PLAN, T_LOSS, T_HBEA] {
            let p = payload(n);
            let bytes = frame_bytes(&tag, &p);
            assert_eq!(bytes.len(), 4 + 8 + n + 4, "envelope overhead is fixed");

            // pure slice decode
            let (got_tag, got) = decode_frame(&bytes, "rt").unwrap();
            assert_eq!(got_tag, tag);
            assert_eq!(got, p);

            // stream write -> stream read
            let mut wire = Vec::new();
            write_frame(&mut wire, &tag, &p).unwrap();
            assert_eq!(wire, bytes, "write_frame emits exactly frame_bytes");
            let mut r = Cursor::new(&wire);
            let (got_tag, got) = read_frame(&mut r, "rt").unwrap();
            assert_eq!((got_tag, got), (tag, p));
        }
    }
}

#[test]
fn back_to_back_frames_read_cleanly_then_eof_is_none() {
    let mut wire = Vec::new();
    write_frame(&mut wire, &T_PLAN, &payload(9)).unwrap();
    write_frame(&mut wire, &T_LOSS, &payload(0)).unwrap();
    let mut r = Cursor::new(&wire);
    assert_eq!(read_frame_opt(&mut r, "seq").unwrap().unwrap().0, T_PLAN);
    assert_eq!(read_frame_opt(&mut r, "seq").unwrap().unwrap().0, T_LOSS);
    // a close at a frame boundary is clean (Ok(None)), not an error
    assert!(read_frame_opt(&mut r, "seq").unwrap().is_none());
    // but a caller awaiting a reply treats it as a named error
    let mut r = Cursor::new(&wire[wire.len()..]);
    let e = read_frame(&mut r, "reply wait").unwrap_err().to_string();
    assert!(e.contains("reply wait") && e.contains("closed by peer"), "{e}");
}

// ---------------------------------------------------------------------------
// truncation: EVERY strict prefix of a valid frame must be rejected
// ---------------------------------------------------------------------------

#[test]
fn truncation_at_every_byte_boundary_is_a_named_error() {
    let frame = frame_bytes(&T_PLAN, &payload(21)); // 37 bytes total
    for cut in 0..frame.len() {
        let err = decode_frame(&frame[..cut], "trunc")
            .expect_err(&format!("a {cut}-byte prefix of a {}-byte frame decoded", frame.len()));
        let msg = err.to_string();
        assert!(msg.contains("trunc"), "error must carry the caller label: {msg}");
        assert!(
            msg.contains("truncated at byte offset"),
            "truncation at cut {cut} must name the offset: {msg}"
        );
    }
    // and the stream reader distinguishes the three loss sites by name
    let header_cut = &frame[..7]; // mid-header
    let e = read_frame_opt(&mut Cursor::new(header_cut), "rx").unwrap_err().to_string();
    assert!(e.contains("mid-frame header"), "{e}");
    let payload_cut = &frame[..12 + 10]; // mid-payload
    let e = read_frame_opt(&mut Cursor::new(payload_cut), "rx").unwrap_err().to_string();
    assert!(e.contains("mid-payload"), "{e}");
    let crc_cut = &frame[..frame.len() - 2]; // mid-CRC
    let e = read_frame_opt(&mut Cursor::new(crc_cut), "rx").unwrap_err().to_string();
    assert!(e.contains("before CRC"), "{e}");
}

// ---------------------------------------------------------------------------
// corruption: a flipped byte in payload or CRC must fail the checksum
// ---------------------------------------------------------------------------

#[test]
fn corruption_at_every_payload_and_crc_byte_is_rejected() {
    let p = payload(33);
    let frame = frame_bytes(&T_LOSS, &p);
    let payload_start = 12;
    // every payload byte and every stored-CRC byte, every single-bit flip
    // of the byte would do — 0xFF flips all eight, the strongest smoke
    for i in payload_start..frame.len() {
        let mut bad = frame.clone();
        bad[i] ^= 0xFF;
        let err = decode_frame(&bad, "crc").expect_err(&format!("flip at byte {i} decoded"));
        let msg = err.to_string();
        assert!(msg.contains("CRC mismatch"), "flip at byte {i}: {msg}");
        assert!(msg.contains("LOSS"), "error names the frame tag: {msg}");
        // the stream reader agrees byte-for-byte with the slice decoder
        let e = read_frame(&mut Cursor::new(&bad), "crc").unwrap_err().to_string();
        assert!(e.contains("CRC mismatch"), "stream flip at byte {i}: {e}");
    }
}

#[test]
fn hostile_length_fields_are_capped_or_truncation_errors() {
    let mut frame = frame_bytes(&T_PLAN, &payload(8));
    // length far beyond the cap: rejected before any allocation
    frame[4..12].copy_from_slice(&(MAX_FRAME + 1).to_le_bytes());
    let e = decode_frame(&frame, "cap").unwrap_err().to_string();
    assert!(e.contains("exceeds") && e.contains("cap"), "{e}");
    // length one past the available bytes: a truncation error, not a read
    // past the end
    let mut frame = frame_bytes(&T_PLAN, &payload(8));
    frame[4..12].copy_from_slice(&9u64.to_le_bytes());
    let e = decode_frame(&frame, "cap").unwrap_err().to_string();
    assert!(e.contains("truncated at byte offset"), "{e}");
}

// ---------------------------------------------------------------------------
// handshake: bad magic and version skew are distinct named rejections
// ---------------------------------------------------------------------------

#[test]
fn handshake_rejects_version_mismatch_and_bad_magic() {
    // our own hello is accepted
    let mut hello = Vec::new();
    write_hello(&mut hello).unwrap();
    assert_eq!(&hello[..8], WIRE_MAGIC);
    expect_hello(&mut Cursor::new(&hello), "self").unwrap();

    // same magic, future version: the error names both versions
    let mut skew = WIRE_MAGIC.to_vec();
    skew.extend_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
    let e = expect_hello(&mut Cursor::new(&skew), "peer").unwrap_err().to_string();
    assert!(
        e.contains("wire version mismatch")
            && e.contains(&format!("v{}", WIRE_VERSION + 1))
            && e.contains(&format!("v{WIRE_VERSION}")),
        "{e}"
    );

    // wrong magic: an http client, not an old lezo
    let mut junk = b"GET / HT".to_vec();
    junk.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    let e = expect_hello(&mut Cursor::new(&junk), "peer").unwrap_err().to_string();
    assert!(e.contains("not a lezo wire endpoint"), "{e}");

    // a short hello is a named close, not a hang or a panic
    let e = expect_hello(&mut Cursor::new(&hello[..5]), "peer").unwrap_err().to_string();
    assert!(e.contains("closed during handshake"), "{e}");
}

#[test]
fn crc_is_the_checkpoint_ieee_polynomial() {
    // pinned so the wire and the checkpoint envelope can never drift apart
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    assert_eq!(crc32(b""), 0);
}

// ---------------------------------------------------------------------------
// codecs: plan/batch round-trips consume every byte
// ---------------------------------------------------------------------------

fn build_plans(schedule: ProbeSchedule) -> Vec<StepPlan> {
    let backend = NativeBackend::preset("opt-nano").unwrap();
    let host = backend.initial_params("").unwrap().0;
    let units = TunableUnits::from_host(&backend, &host).unwrap();
    let engine = SpsaEngine::new(&backend, 1e-3, 7).unwrap();
    let active: Vec<usize> = (0..units.n_units()).collect();
    (0..4u64).map(|step| engine.step_plan(step, &units, &active, schedule).unwrap()).collect()
}

#[test]
fn real_step_plans_round_trip_bitwise() {
    for schedule in [ProbeSchedule::TwoSided, ProbeSchedule::OneSided { probes: 3 }] {
        for plan in build_plans(schedule) {
            let bytes = encode_plan(&plan);
            let mut cur = Cur::new(&bytes, "plan");
            let got = decode_plan(&mut cur).unwrap();
            cur.finish().unwrap(); // no trailing bytes allowed
            assert_eq!(got, plan);
            assert_eq!(encode_plan(&got), bytes, "re-encoding is byte-stable");
        }
    }
}

#[test]
fn truncated_plan_bytes_never_decode() {
    let plan = &build_plans(ProbeSchedule::TwoSided)[0];
    let bytes = encode_plan(plan);
    for cut in 0..bytes.len() {
        let mut cur = Cur::new(&bytes[..cut], "plan");
        let ok = decode_plan(&mut cur).is_ok() && cur.finish().is_ok();
        assert!(!ok, "a {cut}-byte prefix of a {}-byte plan decoded cleanly", bytes.len());
    }
}

#[test]
fn batch_round_trips_bitwise() {
    let seqs: Vec<Vec<u32>> =
        (0..5).map(|r| (0..12u32).map(|s| 20 + (r * 7 + s * 3) % 200).collect()).collect();
    let batch = Batch::lm_batch(&seqs, 5, 16).unwrap();
    let mut bytes = Vec::new();
    encode_batch_into(&mut bytes, &batch);
    let mut cur = Cur::new(&bytes, "batch");
    let got = decode_batch(&mut cur).unwrap();
    cur.finish().unwrap();
    assert_eq!(got, batch);
}

// ---------------------------------------------------------------------------
// determinism twin: plan bytes are identical across worker-thread counts
// ---------------------------------------------------------------------------

/// FNV-1a over the encoded plan bytes — same digest idiom as
/// `kernel_twins.rs`, so a mismatch prints one number, not two dumps.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[test]
fn plan_serialization_is_thread_count_invariant() {
    let digest_at = |threads: usize| -> Vec<u64> {
        with_threads(threads, || {
            let mut out = Vec::new();
            for schedule in [ProbeSchedule::TwoSided, ProbeSchedule::OneSided { probes: 2 }] {
                for plan in build_plans(schedule) {
                    // sanity: the plan actually has sweep work in it
                    assert!(plan
                        .phases
                        .iter()
                        .any(|p| matches!(p, PlanPhase::Sweep(ops) if !ops.is_empty())));
                    out.push(fnv1a(&encode_plan(&plan)));
                }
            }
            out
        })
    };
    let one = digest_at(1);
    for threads in [2, 5] {
        assert_eq!(
            digest_at(threads),
            one,
            "encoded StepPlan bytes differ between 1 and {threads} worker threads"
        );
    }
}

//! Kill-and-resume drills: deterministic injected crashes at every phase
//! boundary, then a resume that must be bit-identical to the uninterrupted
//! twin — losses, history, reports, and (via the saved state at a common
//! cut point) the parameters themselves. Crashes are in-process errors
//! carrying the injected-crash marker, so the on-disk state is exactly what
//! a real crash at that boundary would leave behind.

use lezo::config::{Method, RunConfig};
use lezo::coordinator::trainer::TrainReport;
use lezo::coordinator::{Trainer, ZoOptKind};
use lezo::model::checkpoint;
use lezo::runtime::backend::{BackendKind, Precision};
use std::path::PathBuf;

const CRASH: &str = "injected crash";

/// These tests drive full runs, so any LEZO_* override in the environment
/// would change the trajectory under test.
fn env_overridden() -> bool {
    for var in ["LEZO_FAULTS", "LEZO_ZO_OPT", "LEZO_PRECISION", "LEZO_BACKEND"] {
        if std::env::var(var).map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED: {var} is set and would override the run under test");
            return true;
        }
    }
    false
}

/// Fresh artifact root per (test, tag) so parallel tests never share state.
fn fresh_root(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("lezo_crash_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d.to_str().unwrap().to_string()
}

fn nano_cfg(tag: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "opt-nano".into();
    cfg.backend = BackendKind::Native;
    cfg.method = Method::Mezo;
    cfg.steps = 4;
    cfg.eval_every = 2;
    cfg.eval_examples = 4;
    cfg.train_examples = 8;
    cfg.mean_len = 8;
    cfg.lr = 1e-4;
    cfg.save_every = 1;
    cfg.artifacts_root = fresh_root(tag);
    cfg
}

fn state_path(cfg: &RunConfig) -> PathBuf {
    PathBuf::from(cfg.artifact_dir()).join("train_state.ckpt")
}

fn run(cfg: &RunConfig) -> anyhow::Result<TrainReport> {
    Trainer::new(cfg.clone()).run()
}

/// Bit-level equality for every value a resumed run must reproduce exactly.
/// Wall-clock fields are deliberately excluded: time is the one thing a
/// resume cannot (and need not) replay.
fn assert_reports_bit_identical(resumed: &TrainReport, clean: &TrainReport, what: &str) {
    assert_eq!(resumed.losses.len(), clean.losses.len(), "{what}: loss count");
    for (i, (a, b)) in resumed.losses.iter().zip(&clean.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: loss[{i}] {a} vs {b}");
    }
    assert_eq!(resumed.history.len(), clean.history.len(), "{what}: history length");
    for (a, b) in resumed.history.iter().zip(&clean.history) {
        assert_eq!(a.step, b.step, "{what}: eval step");
        assert_eq!(a.metric.to_bits(), b.metric.to_bits(), "{what}: metric at step {}", a.step);
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "{what}: train_loss at step {}",
            a.step
        );
    }
    assert_eq!(resumed.final_metric.to_bits(), clean.final_metric.to_bits(), "{what}: final");
    assert_eq!(resumed.best_metric.to_bits(), clean.best_metric.to_bits(), "{what}: best");
    assert_eq!(resumed.stage_times.steps, clean.stage_times.steps, "{what}: stage steps");
    assert_eq!(resumed.zo_state_bytes, clean.zo_state_bytes, "{what}: zo state bytes");
    assert!(
        (resumed.stage_times.total() - resumed.train_secs).abs() < 1e-9,
        "{what}: accounting invariant must survive resume"
    );
}

#[test]
fn crash_and_resume_is_bit_identical_at_every_phase_boundary() {
    if env_overridden() {
        return;
    }
    // the uninterrupted twin: same trajectory, its own artifact root
    let clean = run(&nano_cfg("phases_clean")).unwrap();
    assert_eq!(clean.resumed_from, None);

    for (phase, resume_at) in [
        ("end", 2u64),         // crash after step 2 completed (state saved)
        ("post-perturb", 1),   // crash inside step 2: state is from step 1
        ("post-eval", 1),
        ("pre-save", 1),
        ("mid-save", 1),
    ] {
        let mut cfg = nano_cfg(&format!("phase_{phase}"));
        cfg.faults = format!("crash@2:{phase}");
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains(CRASH), "{phase}: {err}");
        assert!(state_path(&cfg).exists(), "{phase}: a resumable state must exist");

        cfg.faults.clear();
        let resumed = run(&cfg).unwrap();
        assert_eq!(resumed.resumed_from, Some(resume_at), "{phase}");
        assert_reports_bit_identical(&resumed, &clean, phase);
        assert!(
            !state_path(&cfg).exists(),
            "{phase}: a completed run must delete its resume state"
        );
    }
}

#[test]
fn mid_save_crash_leaves_a_torn_tmp_never_a_torn_checkpoint() {
    if env_overridden() {
        return;
    }
    let mut cfg = nano_cfg("torn");
    cfg.faults = "crash@2:mid-save".into();
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains(CRASH) && err.contains("mid-save"), "{err}");
    let path = state_path(&cfg);
    let tmp = checkpoint::tmp_path(&path);
    assert!(tmp.exists(), "the torn half-write must land on the temp path");
    // the final path still holds step 1's complete state (atomic rename
    // protocol: a crash mid-write can never corrupt the checkpoint itself)
    let st = checkpoint::load_state(&path).unwrap();
    assert_eq!(st.step, 1);
    // and the torn temp file itself fails to load with a clean error
    assert!(checkpoint::load_state(&tmp).is_err());
}

#[test]
fn saved_params_are_bit_identical_between_resumed_and_clean_runs() {
    if env_overridden() {
        return;
    }
    // Interrupt at step 2, resume, crash again at step 5's end: the state
    // file then holds the resumed run's parameters at a common cut point.
    let mut a = nano_cfg("params_resumed");
    a.steps = 8;
    a.faults = "crash@2".into();
    assert!(run(&a).unwrap_err().to_string().contains(CRASH));
    a.faults = "crash@5".into();
    assert!(run(&a).unwrap_err().to_string().contains(CRASH));
    let sa = checkpoint::load_state(&state_path(&a)).unwrap();
    assert_eq!(sa.step, 5);

    // the clean twin crashes only once, at the same cut point
    let mut b = nano_cfg("params_clean");
    b.steps = 8;
    b.faults = "crash@5".into();
    assert!(run(&b).unwrap_err().to_string().contains(CRASH));
    let sb = checkpoint::load_state(&state_path(&b)).unwrap();
    assert_eq!(sb.step, 5);

    assert_eq!(sa.params.len(), sb.params.len());
    for (k, (ua, ub)) in sa.params.iter().zip(&sb.params).enumerate() {
        assert_eq!(ua.len(), ub.len(), "unit {k}");
        for (i, (x, y)) in ua.iter().zip(ub).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "unit {k} param {i}: {x} vs {y}");
        }
    }
    for (a, b) in sa.grads.iter().zip(&sb.grads) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(sa.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
               sb.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>());
    assert_eq!(sa.history, sb.history);
}

#[test]
fn every_zo_optimizer_resumes_bit_identically() {
    if env_overridden() {
        return;
    }
    for kind in [
        ZoOptKind::Sgd,
        ZoOptKind::Momentum,
        ZoOptKind::Adam,
        ZoOptKind::SignSgd,
        ZoOptKind::Fzoo,
    ] {
        let mut clean_cfg = nano_cfg(&format!("zoo_clean_{kind}"));
        clean_cfg.zo_opt = kind;
        let clean = run(&clean_cfg).unwrap();

        // crash mid-run: the stateful rules (momentum/adam) must rebuild
        // their seed-replay windows from the recorded projected gradients
        let mut cfg = nano_cfg(&format!("zoo_{kind}"));
        cfg.zo_opt = kind;
        cfg.faults = "crash@3:post-perturb".into();
        assert!(run(&cfg).unwrap_err().to_string().contains(CRASH), "{kind}");
        cfg.faults.clear();
        let resumed = run(&cfg).unwrap();
        assert_eq!(resumed.resumed_from, Some(2), "{kind}");
        assert_reports_bit_identical(&resumed, &clean, &kind.to_string());
    }
}

#[test]
fn zo_resume_is_bit_identical_under_bf16_too() {
    if env_overridden() {
        return;
    }
    for kind in [ZoOptKind::Sgd, ZoOptKind::Adam] {
        let mut clean_cfg = nano_cfg(&format!("bf16_clean_{kind}"));
        clean_cfg.precision = Precision::Bf16;
        clean_cfg.zo_opt = kind;
        let clean = run(&clean_cfg).unwrap();
        assert_eq!(clean.precision, Precision::Bf16);

        let mut cfg = nano_cfg(&format!("bf16_{kind}"));
        cfg.precision = Precision::Bf16;
        cfg.zo_opt = kind;
        cfg.faults = "crash@2".into();
        assert!(run(&cfg).unwrap_err().to_string().contains(CRASH), "{kind}");
        cfg.faults.clear();
        let resumed = run(&cfg).unwrap();
        assert_eq!(resumed.resumed_from, Some(2), "{kind}");
        assert_reports_bit_identical(&resumed, &clean, &format!("bf16/{kind}"));
    }
}

#[test]
fn ft_resume_restores_adam_moments_bit_identically() {
    if env_overridden() {
        return;
    }
    for precision in [Precision::F32, Precision::Bf16] {
        let mut clean_cfg = nano_cfg(&format!("ft_clean_{precision}"));
        clean_cfg.method = Method::Ft;
        clean_cfg.lr = 1e-3;
        clean_cfg.precision = precision;
        let clean = run(&clean_cfg).unwrap();
        assert!(clean.fo_state_bytes > 0);

        let mut cfg = nano_cfg(&format!("ft_{precision}"));
        cfg.method = Method::Ft;
        cfg.lr = 1e-3;
        cfg.precision = precision;
        cfg.faults = "crash@2".into();
        assert!(run(&cfg).unwrap_err().to_string().contains(CRASH), "{precision}");
        cfg.faults.clear();
        let resumed = run(&cfg).unwrap();
        assert_eq!(resumed.resumed_from, Some(2), "{precision}");
        assert_reports_bit_identical(&resumed, &clean, &format!("ft/{precision}"));
    }
}

#[test]
fn nan_loss_is_a_hard_error_naming_the_step_by_default() {
    if env_overridden() {
        return;
    }
    let mut cfg = nano_cfg("nan_err_zo");
    cfg.save_every = 0;
    cfg.faults = "nan-loss@2".into();
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains("non-finite loss") && err.contains("step 2"), "{err}");

    let mut cfg = nano_cfg("nan_err_ft");
    cfg.method = Method::Ft;
    cfg.lr = 1e-3;
    cfg.save_every = 0;
    cfg.faults = "nan-loss@2".into();
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains("non-finite loss") && err.contains("step 2"), "{err}");
}

#[test]
fn skip_step_policy_records_the_skip_and_resumes_bit_identically() {
    if env_overridden() {
        return;
    }
    let mut clean_cfg = nano_cfg("skip_clean");
    clean_cfg.faults = "nan-loss@2".into();
    clean_cfg.set("on_nonfinite", "skip-step").unwrap();
    let clean = run(&clean_cfg).unwrap();
    assert!(clean.losses[1].is_nan(), "the skipped step's loss is recorded as NaN");
    assert_eq!(clean.losses.len(), 4);
    assert_eq!(clean.stage_times.steps, 4, "skipped steps still count");

    // crash after the skipped step: the resume replay must know step 2 fed
    // nothing into the selector or the optimizer
    let mut cfg = nano_cfg("skip_resume");
    cfg.faults = "nan-loss@2,crash@3".into();
    cfg.set("on_nonfinite", "skip-step").unwrap();
    assert!(run(&cfg).unwrap_err().to_string().contains(CRASH));
    cfg.faults.clear();
    let resumed = run(&cfg).unwrap();
    assert_eq!(resumed.resumed_from, Some(3));
    assert_reports_bit_identical(&resumed, &clean, "skip-step");
}

#[test]
fn io_err_on_save_is_warn_and_continue() {
    if env_overridden() {
        return;
    }
    let clean = run(&nano_cfg("ioerr_clean")).unwrap();

    let mut cfg = nano_cfg("ioerr");
    cfg.faults = "io-err@save:1".into();
    let report = run(&cfg).unwrap();
    assert_eq!(report.resumed_from, None);
    // an io error on one save attempt must not perturb the math at all
    assert_reports_bit_identical(&report, &clean, "io-err");
}

#[test]
fn resume_rejects_config_drift_naming_the_field() {
    if env_overridden() {
        return;
    }
    let mut cfg = nano_cfg("drift");
    cfg.faults = "crash@2".into();
    assert!(run(&cfg).unwrap_err().to_string().contains(CRASH));
    cfg.faults.clear();

    let mut drifted = cfg.clone();
    drifted.lr = 5e-4;
    let err = run(&drifted).unwrap_err().to_string();
    assert!(err.contains("lr"), "{err}");

    let mut drifted = cfg.clone();
    drifted.steps = 9;
    let err = run(&drifted).unwrap_err().to_string();
    assert!(err.contains("steps"), "{err}");

    // resume=never starts fresh in the same dir instead of erroring
    let mut fresh = cfg.clone();
    fresh.steps = 9;
    fresh.resume = "never".into();
    let report = run(&fresh).unwrap();
    assert_eq!(report.resumed_from, None);
    assert_eq!(report.losses.len(), 9);
}

#[test]
fn explicit_resume_path_and_kind_mismatch_are_hard_errors() {
    if env_overridden() {
        return;
    }
    let mut cfg = nano_cfg("explicit");
    cfg.resume = format!("{}/does_not_exist.ckpt", cfg.artifacts_root);
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains("does_not_exist.ckpt"), "{err}");

    // a ZO state cannot seed an ft run (and the config fingerprint would
    // differ anyway — the kind check fires first with a clearer message)
    let mut cfg = nano_cfg("kind_mismatch");
    cfg.faults = "crash@2".into();
    assert!(run(&cfg).unwrap_err().to_string().contains(CRASH));
    cfg.faults.clear();
    cfg.method = Method::Ft;
    cfg.lr = 1e-3;
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains("cannot resume"), "{err}");
}

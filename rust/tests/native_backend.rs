//! Hermetic end-to-end tests over the pure-Rust native backend.
//!
//! Everything here runs with ZERO external artifacts — no PJRT plugin, no
//! AOT HLO, no Python. This is the suite that makes the LeZO algorithm
//! testable on any machine: the full perturb -> forward -> flip -> forward
//! -> restore -> update loop, the layer selector, Sparse-MeZO, evaluation,
//! and trainer-level reproducibility. The PJRT twin of these invariants
//! lives in rust/tests/integration.rs (feature `pjrt` + artifacts).
//!
//! Hyperparameters of the convergence smoke test were calibrated against a
//! Python simulation of the identical algorithm (same Philox stream, same
//! SplitMix64 seed derivation, same model math): at lr=1e-2, mu=1e-3 the
//! fixed-batch loss drops ~0.15 nats in 30 steps across seeds, so the
//! asserted 0.05 margin has >= 3x headroom.

use lezo::config::{Method, RunConfig};
use lezo::coordinator::fo::{FoEngine, FoOptimizer};
use lezo::coordinator::optim::{make_optimizer, ZoOptKind};
use lezo::coordinator::metrics::StageTimes;
use lezo::coordinator::spsa::{SpsaEngine, TunableUnits};
use lezo::coordinator::{trainer, Trainer};
use lezo::data::batch::Batch;
use lezo::peft::PeftMode;
use lezo::runtime::backend::{Backend, BackendKind};
use lezo::runtime::{NativeBackend, NativeBuf, Precision};

fn nano_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "opt-nano".into();
    cfg.backend = BackendKind::Native;
    cfg.steps = 4;
    cfg.eval_every = 4;
    cfg.eval_examples = 8;
    cfg.train_examples = 16;
    cfg.mean_len = 10;
    cfg.lr = 1e-4;
    cfg
}

/// Fixed overfit batch shared by the convergence tests (mirrors the
/// calibration simulation exactly).
fn fixed_batch(rows: usize, seq: usize) -> Batch {
    let seqs: Vec<Vec<u32>> = (0..rows)
        .map(|r| (0..seq as u32).map(|s| 20 + ((r as u32 * 7 + s * 3) % 200)).collect())
        .collect();
    Batch::lm_batch(&seqs, rows, seq).unwrap()
}

// ---------------------------------------------------------------------------
// Engine-level invariants (the acceptance criterion: a full ZO training
// step — perturb/forward/flip/forward/restore/update — with no artifacts)
// ---------------------------------------------------------------------------

#[test]
fn e2e_convergence_zo_overfits_a_fixed_batch() {
    let backend = NativeBackend::preset("opt-nano").unwrap();
    let host = backend.initial_params("").unwrap().0;
    let mut units = TunableUnits::from_host(&backend, &host).unwrap();
    let engine = SpsaEngine::new(&backend, 1e-3, 7).unwrap();
    let active: Vec<usize> = (0..units.n_units()).collect();
    let batch = fixed_batch(4, 16);
    let prepared = backend.prepare_batch(&batch).unwrap();
    let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
        backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
    };
    let mut times = StageTimes::default();
    let mut losses = Vec::new();
    for step in 0..30u64 {
        let zs = engine
            .zo_step(step, &mut units, &active, 1e-2, &mut loss_fn, &mut times)
            .unwrap();
        assert!(zs.loss().is_finite(), "step {step}: loss diverged");
        losses.push(zs.loss());
    }
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[25..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first - 0.05,
        "ZO must overfit the fixed batch: first-5 mean {first:.4}, last-5 mean {last:.4}"
    );
    assert_eq!(times.steps, 30);
    assert!(times.forward_secs > 0.0 && times.perturb_secs > 0.0);
}

#[test]
fn e2e_perturb_flip_restore_round_trips_parameters() {
    let backend = NativeBackend::preset("opt-nano").unwrap();
    let host = backend.initial_params("").unwrap().0;
    let mut units = TunableUnits::from_host(&backend, &host).unwrap();
    let engine = SpsaEngine::new(&backend, 1e-3, 3).unwrap();
    let active: Vec<usize> = (0..units.n_units()).collect();
    let batch = fixed_batch(2, 16);
    let prepared = backend.prepare_batch(&batch).unwrap();
    let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
        backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
    };
    // lr = 0: the step reduces to perturb -> flip -> restore, an identity
    let mut times = StageTimes::default();
    engine.zo_step(0, &mut units, &active, 0.0, &mut loss_fn, &mut times).unwrap();
    let after = units.to_host(&backend).unwrap();
    for (k, (a, o)) in after.iter().zip(&host).enumerate() {
        for (x, y) in a.iter().zip(o) {
            assert!((x - y).abs() < 1e-5, "unit {k}: {x} vs {y} (restore drift)");
        }
    }
}

#[test]
fn e2e_thread_count_invariance_bit_identical_runs() {
    // The native kernels use fixed chunk partitioning and no cross-chunk
    // reductions, so a 5-step training run must produce bit-identical
    // losses and updated parameters at any worker-thread count.
    use lezo::runtime::native::parallel;
    if std::env::var("LEZO_THREADS").map(|s| !s.is_empty()).unwrap_or(false) {
        eprintln!(
            "SKIPPED e2e_thread_count_invariance_bit_identical_runs: LEZO_THREADS overrides \
             the scoped thread setting"
        );
        return;
    }
    let mut runs = Vec::new();
    for threads in [1usize, 8] {
        // scoped override: the setting is local to this thread for the
        // duration of the run, so concurrently running tests (which go
        // through Trainer::run's own scoped override) cannot clobber it
        let run = parallel::with_threads(threads, || {
            let backend = NativeBackend::preset("opt-nano").unwrap();
            let host = backend.initial_params("").unwrap().0;
            let mut units = TunableUnits::from_host(&backend, &host).unwrap();
            let engine = SpsaEngine::new(&backend, 1e-3, 21).unwrap();
            let active: Vec<usize> = (0..units.n_units()).collect();
            let batch = fixed_batch(4, 16);
            let prepared = backend.prepare_batch(&batch).unwrap();
            let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
                backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
            };
            let mut times = StageTimes::default();
            let mut losses = Vec::new();
            for step in 0..5u64 {
                losses.push(
                    engine
                        .zo_step(step, &mut units, &active, 1e-3, &mut loss_fn, &mut times)
                        .unwrap()
                        .loss(),
                );
            }
            (losses, units.to_host(&backend).unwrap())
        });
        runs.push(run);
    }
    assert_eq!(runs[0].0, runs[1].0, "losses must be bit-identical across thread counts");
    assert_eq!(runs[0].1, runs[1].1, "params must be bit-identical across thread counts");
}

#[test]
fn e2e_identical_run_seed_identical_step_trajectory() {
    let mut trajectories = Vec::new();
    for _ in 0..2 {
        let backend = NativeBackend::preset("opt-nano").unwrap();
        let host = backend.initial_params("").unwrap().0;
        let mut units = TunableUnits::from_host(&backend, &host).unwrap();
        let engine = SpsaEngine::new(&backend, 1e-3, 42).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        let batch = fixed_batch(2, 16);
        let prepared = backend.prepare_batch(&batch).unwrap();
        let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
            backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
        };
        let mut times = StageTimes::default();
        let mut losses = Vec::new();
        for step in 0..5u64 {
            losses.push(
                engine
                    .zo_step(step, &mut units, &active, 1e-3, &mut loss_fn, &mut times)
                    .unwrap()
                    .loss(),
            );
        }
        trajectories.push((losses, units.to_host(&backend).unwrap()));
    }
    assert_eq!(trajectories[0].0, trajectories[1].0, "losses must be bit-identical");
    assert_eq!(trajectories[0].1, trajectories[1].1, "parameters must be bit-identical");
}

// ---------------------------------------------------------------------------
// Reduced precision (precision=bf16): the forward runs over bf16 shadows,
// the f32 masters stay the only trainable state
// ---------------------------------------------------------------------------

fn bf16_backend() -> NativeBackend {
    NativeBackend::preset("opt-nano").unwrap().with_precision(Precision::Bf16)
}

#[test]
fn e2e_convergence_zo_overfits_a_fixed_batch_in_bf16() {
    // Same protocol as the f32 convergence smoke above, with the loss
    // probes executed by the bf16 forward. Calibrated against the
    // numpy/ml_dtypes twin of the identical bf16 rounding schedule: at
    // run_seed 7 the fixed-batch loss drops 0.137 nats over 30 steps
    // (0.035..0.17 across 5 seeds), so the asserted 0.04 margin has >3x
    // headroom at this seed.
    let backend = bf16_backend();
    let host = backend.initial_params("").unwrap().0;
    let mut units = TunableUnits::from_host(&backend, &host).unwrap();
    let engine = SpsaEngine::new(&backend, 1e-3, 7).unwrap();
    let active: Vec<usize> = (0..units.n_units()).collect();
    let batch = fixed_batch(4, 16);
    let prepared = backend.prepare_batch(&batch).unwrap();
    let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
        backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
    };
    let mut times = StageTimes::default();
    let mut losses = Vec::new();
    for step in 0..30u64 {
        let zs = engine
            .zo_step(step, &mut units, &active, 1e-2, &mut loss_fn, &mut times)
            .unwrap();
        assert!(zs.loss().is_finite(), "step {step}: bf16 loss diverged");
        losses.push(zs.loss());
    }
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[25..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first - 0.04,
        "bf16 ZO must overfit the fixed batch: first-5 mean {first:.4}, last-5 mean {last:.4}"
    );
}

#[test]
fn e2e_bf16_masters_bit_identical_to_f32_mode_under_identical_coefficients() {
    // The sweeps mutate the f32 masters through the identical kernels in
    // both precision modes; only the loss *values* (and hence the update
    // coefficient) can differ. Scripting the loss pins the coefficients,
    // so three full perturb/forward/flip/forward/restore/update steps must
    // leave the masters bit-identical across modes.
    let mut finals = Vec::new();
    for precision in [Precision::F32, Precision::Bf16] {
        let backend =
            NativeBackend::preset("opt-nano").unwrap().with_precision(precision);
        let host = backend.initial_params("").unwrap().0;
        let mut units = TunableUnits::from_host(&backend, &host).unwrap();
        let engine = SpsaEngine::new(&backend, 1e-3, 11).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        let mut times = StageTimes::default();
        let mut calls = 0u32;
        // alternating constants: projected grad 0.25/(2 mu) != 0, so the
        // update sweep really moves the masters
        let mut loss_fn = |_: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
            calls += 1;
            Ok(if calls % 2 == 1 { 1.0 } else { 0.75 })
        };
        for step in 0..3u64 {
            engine.zo_step(step, &mut units, &active, 1e-3, &mut loss_fn, &mut times).unwrap();
        }
        finals.push(units.to_host(&backend).unwrap());
    }
    for (k, (a, b)) in finals[0].iter().zip(&finals[1]).enumerate() {
        assert!(
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "unit {k}: masters must be bit-identical across precision modes"
        );
    }
}

#[test]
fn e2e_bf16_perturb_flip_restore_round_trips_like_f32_mode() {
    // lr = 0 with the real bf16 forward: the step is perturb -> flip ->
    // restore over the f32 masters. The masters must land bit-identical
    // to the f32-mode run of the same step (the update coefficient is
    // -0.0 * g in both modes — an exact no-op on the restored masters).
    let mut finals = Vec::new();
    for precision in [Precision::F32, Precision::Bf16] {
        let backend =
            NativeBackend::preset("opt-nano").unwrap().with_precision(precision);
        let host = backend.initial_params("").unwrap().0;
        let mut units = TunableUnits::from_host(&backend, &host).unwrap();
        let engine = SpsaEngine::new(&backend, 1e-3, 3).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        let batch = fixed_batch(2, 16);
        let prepared = backend.prepare_batch(&batch).unwrap();
        let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
            backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
        };
        let mut times = StageTimes::default();
        for step in 0..2u64 {
            engine.zo_step(step, &mut units, &active, 0.0, &mut loss_fn, &mut times).unwrap();
        }
        // restore drift vs the initial state stays within fp tolerance
        let after = units.to_host(&backend).unwrap();
        for (k, (a, o)) in after.iter().zip(&host).enumerate() {
            for (x, y) in a.iter().zip(o) {
                assert!((x - y).abs() < 1e-5, "{precision:?} unit {k}: {x} vs {y}");
            }
        }
        finals.push(after);
    }
    for (k, (a, b)) in finals[0].iter().zip(&finals[1]).enumerate() {
        assert!(
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "unit {k}: lr=0 masters must match f32 mode bit for bit"
        );
    }
}

#[test]
fn e2e_bf16_thread_count_invariance_bit_identical_runs() {
    // The bf16 kernels inherit the fixed-chunk determinism rule: a 5-step
    // bf16 training run must be bit-identical at any worker-thread count.
    use lezo::runtime::native::parallel;
    if std::env::var("LEZO_THREADS").map(|s| !s.is_empty()).unwrap_or(false) {
        eprintln!(
            "SKIPPED e2e_bf16_thread_count_invariance_bit_identical_runs: LEZO_THREADS wins"
        );
        return;
    }
    let mut runs = Vec::new();
    for threads in [1usize, 8] {
        let run = parallel::with_threads(threads, || {
            let backend = bf16_backend();
            let host = backend.initial_params("").unwrap().0;
            let mut units = TunableUnits::from_host(&backend, &host).unwrap();
            let engine = SpsaEngine::new(&backend, 1e-3, 21).unwrap();
            let active: Vec<usize> = (0..units.n_units()).collect();
            let batch = fixed_batch(4, 16);
            let prepared = backend.prepare_batch(&batch).unwrap();
            let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
                backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
            };
            let mut times = StageTimes::default();
            let mut losses = Vec::new();
            for step in 0..5u64 {
                losses.push(
                    engine
                        .zo_step(step, &mut units, &active, 1e-3, &mut loss_fn, &mut times)
                        .unwrap()
                        .loss(),
                );
            }
            (losses, units.to_host(&backend).unwrap())
        });
        runs.push(run);
    }
    assert_eq!(runs[0].0, runs[1].0, "bf16 losses must be bit-identical across thread counts");
    assert_eq!(runs[0].1, runs[1].1, "params must be bit-identical across thread counts");
}

#[test]
fn e2e_bf16_sparse_step_recasts_only_active_units() {
    // The LeZO + bf16 composition the PR is about: a sparse step leaves
    // dropped units' shadows fresh (no re-quantization traffic), and the
    // next forward re-casts exactly the touched ones.
    let backend = bf16_backend();
    let host = backend.initial_params("").unwrap().0;
    let mut units = TunableUnits::from_host(&backend, &host).unwrap();
    let engine = SpsaEngine::new(&backend, 1e-3, 9).unwrap();
    let batch = fixed_batch(2, 16);
    let prepared = backend.prepare_batch(&batch).unwrap();
    // materialize all shadows with one forward
    let refs = units.unit_refs();
    backend.forward_loss(PeftMode::Full, &refs, &prepared).unwrap();
    let dropped = 2usize; // a block unit LeZO skips this step
    let shadow_before = units.bufs[dropped].shadow_bits();
    let active: Vec<usize> = (0..units.n_units()).filter(|&k| k != dropped).collect();
    let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
        backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
    };
    let mut times = StageTimes::default();
    engine.zo_step(0, &mut units, &active, 1e-3, &mut loss_fn, &mut times).unwrap();
    assert!(
        units.bufs[dropped].shadow_is_fresh(),
        "dropped unit's shadow must stay fresh through the whole step"
    );
    assert_eq!(
        units.bufs[dropped].shadow_bits(),
        shadow_before,
        "dropped unit's shadow must be bit-unchanged"
    );
    for &k in &active {
        // the restore + update sweeps ran after the last forward, so the
        // active shadows must be stale (invalidation really tracked them)
        assert!(
            !units.bufs[k].shadow_is_fresh(),
            "active unit {k}'s shadow must be stale after restore/update"
        );
        assert_eq!(
            units.bufs[k].shadow_bits(),
            lezo::runtime::native::bf16::cast(units.bufs[k].data()),
            "active unit {k}'s refreshed shadow must equal a fresh full re-cast"
        );
    }
}

// ---------------------------------------------------------------------------
// PEFT (native adapter forwards — the paper's Table 4, hermetic since they
// landed; before that `peft=lora|prefix` was a hard "use pjrt" error)
// ---------------------------------------------------------------------------

/// A briefly FO-pretrained base: a random-init model is nearly flat along
/// the adapter directions (adapters only steer attention, and attention
/// over near-uniform logits barely moves the loss), so the convergence and
/// FD tests first take a few native-backward Adam steps on the fixed
/// batch — exactly what the calibration sim does.
fn pretrained_base(backend: &NativeBackend, batch: &Batch, steps: usize) -> Vec<Vec<f32>> {
    let mut params = backend.initial_params("").unwrap().0;
    let eng = FoEngine::new(backend);
    let mut opt = FoOptimizer::adam(0.9, 0.999, 1e-8);
    for _ in 0..steps {
        eng.fo_step(&mut params, batch, &mut opt, 1e-2).unwrap();
    }
    params
}

/// Adapter units with LoRA B re-randomized (init has B = 0 — the delta
/// path would be dead) — matches the calibration sim's setup.
fn nonzero_peft_units(backend: &NativeBackend, mode: PeftMode, seed: u64) -> Vec<Vec<f32>> {
    let spec = backend.spec();
    lezo::peft::init_peft_units_nonzero_b(mode, spec.n_layers, spec.d_model, seed)
}

/// Shared ZO-over-adapters loop: returns the per-step losses.
#[allow(clippy::too_many_arguments)]
fn run_peft_zo(
    backend: &NativeBackend,
    base: &[Vec<f32>],
    peft_host: &[Vec<f32>],
    mode: PeftMode,
    batch: &Batch,
    steps: u64,
    lr: f32,
    mu: f32,
) -> Vec<f32> {
    let base_bufs: Vec<NativeBuf> =
        base.iter().map(|u| backend.upload(u).unwrap()).collect();
    let mut units = TunableUnits::from_host(backend, peft_host).unwrap();
    let engine = SpsaEngine::new(backend, mu, 7).unwrap();
    let active: Vec<usize> = (0..units.n_units()).collect();
    let prepared = backend.prepare_batch(batch).unwrap();
    let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
        let mut args: Vec<&NativeBuf> = base_bufs.iter().collect();
        args.extend(u.bufs.iter());
        backend.forward_loss(mode, &args, &prepared)
    };
    let mut times = StageTimes::default();
    let mut losses = Vec::new();
    for step in 0..steps {
        let zs = engine
            .zo_step(step, &mut units, &active, lr, &mut loss_fn, &mut times)
            .unwrap();
        assert!(zs.loss().is_finite(), "{mode} step {step}: loss diverged");
        losses.push(zs.loss());
    }
    losses
}

#[test]
fn e2e_convergence_zo_over_lora_adapters() {
    // Calibrated against a jax sim of the identical algorithm (5 FO-Adam
    // pretrain steps, then 150 SPSA steps over the adapter units at
    // lr=0.05, mu=1e-2): min loss drop across 10 seeds was 0.0064, so the
    // asserted 0.002 margin has >= 3x headroom.
    let backend = NativeBackend::preset("opt-nano").unwrap();
    let batch = fixed_batch(4, 16);
    let base = pretrained_base(&backend, &batch, 5);
    let spec = backend.spec();
    let peft_host =
        lezo::peft::init_peft_units(PeftMode::Lora, spec.n_layers, spec.d_model, 0);
    let losses = run_peft_zo(&backend, &base, &peft_host, PeftMode::Lora, &batch, 150, 0.05, 1e-2);
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[145..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first - 0.002,
        "ZO over LoRA adapters must reduce the fixed-batch loss: \
         first-5 mean {first:.4}, last-5 mean {last:.4}"
    );
}

#[test]
fn e2e_convergence_zo_over_prefix_adapters() {
    // Same calibration protocol (10 FO pretrain steps, 100 SPSA steps at
    // lr=1.0, mu=1e-2): min drop across 10 sim seeds was 0.0035 vs the
    // asserted 0.001 — >= 3x headroom.
    let backend = NativeBackend::preset("opt-nano").unwrap();
    let batch = fixed_batch(4, 16);
    let base = pretrained_base(&backend, &batch, 10);
    let spec = backend.spec();
    let peft_host =
        lezo::peft::init_peft_units(PeftMode::Prefix, spec.n_layers, spec.d_model, 0);
    let losses =
        run_peft_zo(&backend, &base, &peft_host, PeftMode::Prefix, &batch, 100, 1.0, 1e-2);
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[95..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first - 0.001,
        "ZO over prefix adapters must reduce the fixed-batch loss: \
         first-5 mean {first:.4}, last-5 mean {last:.4}"
    );
}

#[test]
fn e2e_peft_round_trip_restores_adapters_and_never_touches_base() {
    // lr = 0 reduces a ZO step to perturb -> flip -> restore over the
    // adapter units; the frozen base must stay bit-identical through the
    // whole step (it is only ever a forward argument).
    for mode in [PeftMode::Lora, PeftMode::Prefix] {
        let backend = NativeBackend::preset("opt-nano").unwrap();
        let spec = backend.spec().clone();
        let base_host = backend.initial_params("").unwrap().0;
        let base_bufs: Vec<NativeBuf> =
            base_host.iter().map(|u| backend.upload(u).unwrap()).collect();
        let peft_host = lezo::peft::init_peft_units(mode, spec.n_layers, spec.d_model, 3);
        let mut units = TunableUnits::from_host(&backend, &peft_host).unwrap();
        let engine = SpsaEngine::new(&backend, 1e-2, 5).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        let batch = fixed_batch(2, 16);
        let prepared = backend.prepare_batch(&batch).unwrap();
        let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
            let mut args: Vec<&NativeBuf> = base_bufs.iter().collect();
            args.extend(u.bufs.iter());
            backend.forward_loss(mode, &args, &prepared)
        };
        let mut times = StageTimes::default();
        engine.zo_step(0, &mut units, &active, 0.0, &mut loss_fn, &mut times).unwrap();
        let after = units.to_host(&backend).unwrap();
        for (k, (a, o)) in after.iter().zip(&peft_host).enumerate() {
            for (x, y) in a.iter().zip(o) {
                assert!((x - y).abs() < 1e-5, "{mode} adapter {k}: {x} vs {y} (restore drift)");
            }
        }
        for (k, (b, o)) in base_bufs.iter().zip(&base_host).enumerate() {
            assert!(
                b.iter().zip(o).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{mode}: base unit {k} must stay bit-unchanged through a ZO step"
            );
        }
    }
}

#[test]
fn peft_adapter_fd_directional_derivative_is_consistent() {
    // Central-difference SPSA gradients along the regenerated Philox
    // direction at eps and 2*eps must agree to O(eps^2) — the adapter
    // paths are a smooth, correctly wired function of the adapter units.
    // Tolerances calibrated on a 10-seed jax sim: worst |g1 - g2| stayed
    // under 0.1 * max|g| + 3e-3 with >= 2x headroom, and every |g|
    // exceeded 1e-3 (asserted floor 3e-4).
    for mode in [PeftMode::Lora, PeftMode::Prefix] {
        let backend = NativeBackend::preset("opt-nano").unwrap();
        let batch = fixed_batch(4, 16);
        let base = pretrained_base(&backend, &batch, 5);
        let base_bufs: Vec<NativeBuf> =
            base.iter().map(|u| backend.upload(u).unwrap()).collect();
        let peft_host = nonzero_peft_units(&backend, mode, 1);
        let mut units = TunableUnits::from_host(&backend, &peft_host).unwrap();
        let engine = SpsaEngine::new(&backend, 1e-2, 11).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        let prepared = backend.prepare_batch(&batch).unwrap();
        let loss = |u: &TunableUnits<NativeBackend>| -> f32 {
            let mut args: Vec<&NativeBuf> = base_bufs.iter().collect();
            args.extend(u.bufs.iter());
            backend.forward_loss(mode, &args, &prepared).unwrap()
        };
        let mut g_at = |eps: f32| -> f32 {
            engine.apply(0, &mut units, &active, eps).unwrap();
            let lp = loss(&units);
            engine.apply(0, &mut units, &active, -2.0 * eps).unwrap();
            let lm = loss(&units);
            engine.apply(0, &mut units, &active, eps).unwrap();
            (lp - lm) / (2.0 * eps)
        };
        let g1 = g_at(1e-2);
        let g2 = g_at(2e-2);
        let mag = g1.abs().max(g2.abs());
        assert!(mag > 3e-4, "{mode}: vacuous FD check (|g| = {mag})");
        assert!(
            (g1 - g2).abs() <= 0.1 * mag + 3e-3,
            "{mode}: FD gradients disagree: g(1e-2) = {g1}, g(2e-2) = {g2}"
        );
    }
}

#[test]
fn trainer_peft_runs_hermetically_via_method_aliases() {
    // `method=lezo-lora` / `lezo-prefix` (one token setting method+peft)
    // drive the full trainer loop — sampling, selector over adapter
    // units, eval option scoring — natively with zero artifacts.
    for (alias, expect_peft) in
        [("lezo-lora", PeftMode::Lora), ("lezo-prefix", PeftMode::Prefix)]
    {
        let mut cfg = nano_cfg();
        cfg.set("method", alias).unwrap();
        cfg.drop_layers = 1;
        cfg.steps = 3;
        cfg.eval_every = 3;
        cfg.lr = 1e-3;
        cfg.mu = 1e-2;
        assert_eq!(cfg.method, Method::Lezo, "{alias}");
        assert_eq!(cfg.peft, expect_peft, "{alias}");
        let r = Trainer::new(cfg).run().unwrap();
        assert_eq!(r.backend, "native", "{alias}");
        assert_eq!(r.losses.len(), 3, "{alias}");
        assert!(r.losses.iter().all(|l| l.is_finite()), "{alias}");
        assert!((0.0..=1.0).contains(&r.final_metric), "{alias}");
        assert!(
            r.active_param_fraction < 1.0,
            "{alias}: LeZO must drop adapter units ({})",
            r.active_param_fraction
        );
    }
}

// ---------------------------------------------------------------------------
// FO substrate (the paper's FT baseline, hermetic since the native backward)
// ---------------------------------------------------------------------------

#[test]
fn e2e_fo_adam_beats_zo_sgd_in_steps_to_loss() {
    // The relation every headline table is anchored on: first-order Adam
    // reaches a given loss in far fewer steps than ZO-SGD (paying 12x the
    // memory for it). Calibrated against the Python twin (jax): FO-Adam at
    // lr=1e-2 drops ~5.6 nats in 20 steps on this fixed batch, ZO ~0.1 —
    // the asserted margins below have >10x headroom.
    let backend = NativeBackend::preset("opt-nano").unwrap();
    let host = backend.initial_params("").unwrap().0;
    let batch = fixed_batch(4, 16);

    // FO-Adam
    let eng = FoEngine::new(&backend);
    let mut fo_params = host.clone();
    let mut opt = FoOptimizer::adam(0.9, 0.999, 1e-8);
    let mut fo_losses = Vec::new();
    for _ in 0..20 {
        fo_losses.push(eng.fo_step(&mut fo_params, &batch, &mut opt, 1e-2).unwrap());
    }

    // ZO-SGD (same budget, same batch; hyper-parameters of the convergence
    // smoke test above)
    let mut units = TunableUnits::from_host(&backend, &host).unwrap();
    let zo = SpsaEngine::new(&backend, 1e-3, 7).unwrap();
    let active: Vec<usize> = (0..units.n_units()).collect();
    let prepared = backend.prepare_batch(&batch).unwrap();
    let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
        backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
    };
    let mut times = StageTimes::default();
    let mut zo_losses = Vec::new();
    for step in 0..20u64 {
        let zs = zo.zo_step(step, &mut units, &active, 1e-2, &mut loss_fn, &mut times).unwrap();
        zo_losses.push(zs.loss());
    }

    let l0 = fo_losses[0];
    let steps_to = |losses: &[f32], target: f32| -> Option<usize> {
        losses.iter().position(|&l| l <= target)
    };
    let target = l0 - 0.2;
    let fo_steps = steps_to(&fo_losses, target);
    let zo_steps = steps_to(&zo_losses, target);
    assert!(fo_steps.is_some(), "FO-Adam never dropped 0.2 nats: {fo_losses:?}");
    match zo_steps {
        None => {} // ZO never got there in 20 steps — FO wins outright
        Some(z) => assert!(
            fo_steps.unwrap() < z,
            "FO must reach loss {target} in fewer steps: FO {fo_steps:?} vs ZO {z}"
        ),
    }
    assert!(
        fo_losses.last().unwrap() + 0.5 < *zo_losses.last().unwrap(),
        "after 20 steps FO-Adam must be far ahead: FO {:?} vs ZO {:?}",
        fo_losses.last(),
        zo_losses.last()
    );
}

// ---------------------------------------------------------------------------
// ZO optimizer zoo (coordinator/optim.rs): every update rule converges on
// the fixed batch, momentum/adam reach a target loss in fewer steps than
// plain ZO-SGD, and each variant is seed-pinned reproducible.
//
// Margins are calibrated against the Python twin (jax, python/compile/model:
// same architecture, init distribution, batch, and update-rule recursions)
// across 7 seeds — asserted margins sit at <= half the observed minimum.
// ---------------------------------------------------------------------------

/// One fixed-batch ZO trajectory under `kind` (engine seed 7, mu=1e-3).
fn run_zo_variant(kind: ZoOptKind, lr: f32, steps: u64) -> Vec<f32> {
    let backend = NativeBackend::preset("opt-nano").unwrap();
    let host = backend.initial_params("").unwrap().0;
    let mut units = TunableUnits::from_host(&backend, &host).unwrap();
    let engine = SpsaEngine::new(&backend, 1e-3, 7).unwrap();
    let active: Vec<usize> = (0..units.n_units()).collect();
    let batch = fixed_batch(4, 16);
    let prepared = backend.prepare_batch(&batch).unwrap();
    let mut loss_fn = |u: &TunableUnits<NativeBackend>| -> anyhow::Result<f32> {
        backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
    };
    let mut opt = make_optimizer(kind);
    let mut times = StageTimes::default();
    let mut losses = Vec::new();
    for step in 0..steps {
        let zs = engine
            .zo_step_opt(step, &mut units, &active, lr, opt.as_mut(), &mut loss_fn, &mut times)
            .unwrap();
        assert!(zs.loss().is_finite(), "{kind} step {step}: loss diverged");
        losses.push(zs.loss());
    }
    losses
}

#[test]
fn e2e_zo_variants_each_overfit_the_fixed_batch() {
    // Calibrated 30-step first-5 vs last-5 drops (min over 7 sim seeds):
    // momentum@1e-3 +0.075, adam@3e-3 +0.050, sign@3e-3 +0.052,
    // fzoo@3e-3 +0.090 — each asserted margin has >= 1.6x headroom.
    for (kind, lr, margin) in [
        (ZoOptKind::Momentum, 1e-3f32, 0.04f32),
        (ZoOptKind::Adam, 3e-3, 0.03),
        (ZoOptKind::SignSgd, 3e-3, 0.025),
        (ZoOptKind::Fzoo, 3e-3, 0.04),
    ] {
        let losses = run_zo_variant(kind, lr, 30);
        let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let last: f32 = losses[25..].iter().sum::<f32>() / 5.0;
        assert!(
            last < first - margin,
            "{kind} must overfit the fixed batch: first-5 mean {first:.4}, last-5 mean {last:.4}"
        );
    }
}

#[test]
fn e2e_zo_momentum_and_adam_beat_sgd_in_steps_to_loss() {
    // The zoo's reason to exist: seed-replay momentum/adam reach a target
    // loss in fewer steps than the plain rule. Trajectories are smoothed
    // (window 5) before the crossing test because single ZO losses bounce
    // with the probe direction. Calibration (7 sim seeds, 60 steps, target
    // = start - 0.08 nats): sgd@1e-3 crosses at step 30..None, momentum@1e-3
    // at 10..26, adam@3e-3 at 20..38 — the variant led by >= 6 steps at
    // every seed, so the strict `<` below has headroom.
    let sgd = run_zo_variant(ZoOptKind::Sgd, 1e-3, 60);
    let momentum = run_zo_variant(ZoOptKind::Momentum, 1e-3, 60);
    let adam = run_zo_variant(ZoOptKind::Adam, 3e-3, 60);

    let smoothed = |xs: &[f32]| -> Vec<f32> {
        xs.windows(5).map(|w| w.iter().sum::<f32>() / 5.0).collect()
    };
    let s_sgd = smoothed(&sgd);
    let target = s_sgd[0] - 0.08;
    let steps_to = |xs: &[f32]| smoothed(xs).iter().position(|&l| l <= target);

    let sgd_steps = steps_to(&sgd);
    for (name, variant) in [("zo-sgd-momentum", &momentum), ("zo-adam", &adam)] {
        let v = steps_to(variant)
            .unwrap_or_else(|| panic!("{name} never dropped 0.08 nats: {variant:?}"));
        match sgd_steps {
            None => {} // plain ZO-SGD never got there — the variant wins outright
            Some(s) => assert!(
                v < s,
                "{name} must reach loss {target:.3} in fewer steps: {v} vs sgd {s}"
            ),
        }
    }
}

#[test]
fn zo_variants_are_seed_pinned_reproducible_and_distinct() {
    // Same seed + same rule => bit-identical trajectory; different rules
    // diverge (momentum's step 0 equals sgd's by construction, so the
    // comparison looks at whole 8-step trajectories, which separate once
    // the replay history kicks in).
    let kinds = [
        ZoOptKind::Sgd,
        ZoOptKind::Momentum,
        ZoOptKind::Adam,
        ZoOptKind::SignSgd,
        ZoOptKind::Fzoo,
    ];
    let mut trajectories = Vec::new();
    for kind in kinds {
        let a = run_zo_variant(kind, 1e-3, 8);
        let b = run_zo_variant(kind, 1e-3, 8);
        assert_eq!(a, b, "{kind}: same seed must replay bit-identically");
        trajectories.push((kind, a));
    }
    for i in 0..trajectories.len() {
        for j in i + 1..trajectories.len() {
            assert_ne!(
                trajectories[i].1, trajectories[j].1,
                "{} and {} must produce different trajectories",
                trajectories[i].0, trajectories[j].0
            );
        }
    }
}

#[test]
fn e2e_pretrain_then_finetune_without_artifacts() {
    // The full hermetic pipeline the paper assumes a pretrained model for:
    // `pretrain` (FO-Adam on the synthetic corpus, native backward) writes
    // pretrained.ckpt, and a ZO fine-tune in the same artifact dir adopts
    // it as its initial state — zero AOT artifacts anywhere.
    let root = std::env::temp_dir().join(format!("lezo_pretrain_e2e_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let mut cfg = RunConfig::default();
    cfg.model = "opt-nano".into();
    cfg.backend = BackendKind::Native;
    cfg.artifacts_root = root.to_str().unwrap().to_string();

    let (first, last) = trainer::pretrain(&cfg, 12, 1e-2, 0, 0).unwrap();
    assert!(first.is_finite() && last.is_finite());
    assert!(
        last < first - 0.1,
        "12 pretrain steps must visibly reduce the LM loss: {first} -> {last}"
    );
    let ckpt = root.join("opt-nano").join("pretrained.ckpt");
    assert!(ckpt.exists(), "pretrain must write {}", ckpt.display());

    // the resolved backend adopts the checkpoint automatically
    let source = match trainer::resolve_backend(&cfg).unwrap() {
        trainer::ResolvedBackend::Native(b) => {
            let (init, source) = b.initial_params("").unwrap();
            assert_eq!(init.len(), b.spec().n_units());
            source
        }
        #[cfg(feature = "pjrt")]
        trainer::ResolvedBackend::Pjrt(_) => unreachable!("backend=native was requested"),
    };
    assert!(source.contains("pretrained.ckpt"), "initial params came from {source}");

    // and a short ZO fine-tune runs end to end from it
    let mut ft = nano_cfg();
    ft.artifacts_root = cfg.artifacts_root.clone();
    ft.method = Method::Lezo;
    ft.drop_layers = 1;
    ft.steps = 2;
    ft.eval_every = 2;
    let r = Trainer::new(ft).run().unwrap();
    assert_eq!(r.backend, "native");
    assert!(r.losses.iter().all(|l| l.is_finite()));
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn trainer_ft_report_has_step0_eval_and_consistent_times() {
    let mut cfg = nano_cfg();
    cfg.method = Method::Ft;
    cfg.steps = 3;
    cfg.eval_every = 3;
    cfg.lr = 1e-3;
    let r = Trainer::new(cfg).run().unwrap();
    assert_eq!(r.backend, "native");
    assert_eq!(r.losses.len(), 3);
    // parity with the ZO report: an origin point at step 0, then the eval
    let steps: Vec<u64> = r.history.iter().map(|p| p.step).collect();
    assert_eq!(steps, vec![0, 3]);
    assert!(r.best_metric > f64::MIN && r.final_metric >= 0.0);
    assert!(r.fo_state_bytes > 0);
    // stage attribution: sampling lands in `other`, so the total equals
    // train_secs and non_forward_fraction is comparable with ZO reports
    assert!((r.stage_times.total() - r.train_secs).abs() < 1e-9);
    assert!(r.stage_times.forward_secs > 0.0);
    assert!((0.0..=1.0).contains(&r.stage_times.non_forward_fraction()));
}

// ---------------------------------------------------------------------------
// Trainer-level runs (data sampling, selector, eval — the whole loop)
// ---------------------------------------------------------------------------

#[test]
fn trainer_mezo_equals_lezo_with_zero_drop() {
    // MeZO is the drop=0 special case: identical trajectories, bit-for-bit.
    let mut a = nano_cfg();
    a.method = Method::Mezo;
    a.drop_layers = 0;
    let mut b = a.clone();
    b.method = Method::Lezo;
    let ra = Trainer::new(a).run().unwrap();
    let rb = Trainer::new(b).run().unwrap();
    assert_eq!(ra.losses, rb.losses, "loss trajectories must match exactly");
    assert_eq!(ra.final_metric, rb.final_metric);
    assert_eq!(ra.backend, "native");
}

#[test]
fn trainer_runs_are_reproducible_and_seed_sensitive() {
    let mut cfg = nano_cfg();
    cfg.method = Method::Lezo;
    cfg.drop_layers = 1;
    let r1 = Trainer::new(cfg.clone()).run().unwrap();
    let r2 = Trainer::new(cfg.clone()).run().unwrap();
    assert_eq!(r1.losses, r2.losses);
    assert_eq!(r1.final_metric, r2.final_metric);
    cfg.seed = 99;
    let r3 = Trainer::new(cfg).run().unwrap();
    assert_ne!(r1.losses, r3.losses, "different seeds must differ");
}

#[test]
fn trainer_lezo_drops_cut_active_params() {
    let mut mezo = nano_cfg();
    mezo.method = Method::Mezo;
    let mut lezo = nano_cfg();
    lezo.method = Method::Lezo;
    lezo.drop_layers = 1; // of opt-nano's 2 blocks
    let rm = Trainer::new(mezo).run().unwrap();
    let rl = Trainer::new(lezo).run().unwrap();
    assert!((rm.active_param_fraction - 1.0).abs() < 1e-9, "MeZO touches everything");
    assert!(
        rl.active_param_fraction < rm.active_param_fraction,
        "LeZO must touch fewer parameters per step: {} vs {}",
        rl.active_param_fraction,
        rm.active_param_fraction
    );
    assert!(rl.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn trainer_smezo_baseline_runs_natively() {
    let mut cfg = nano_cfg();
    cfg.method = Method::Smezo;
    cfg.steps = 3;
    cfg.eval_every = 3;
    let r = Trainer::new(cfg).run().unwrap();
    assert_eq!(r.losses.len(), 3);
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(r.stage_times.other_secs >= 0.0, "ranking time is accounted");
}

#[test]
fn trainer_zero_shot_and_icl_run_natively() {
    for method in [Method::ZeroShot, Method::Icl] {
        let mut cfg = nano_cfg();
        cfg.method = method;
        let r = Trainer::new(cfg).run().unwrap();
        assert!((0.0..=1.0).contains(&r.final_metric), "{method}");
        assert_eq!(r.stage_times.steps, 0, "no training steps for {method}");
    }
}

#[test]
fn trainer_all_selection_policies_run_natively() {
    for policy in ["uniform", "round-robin", "stratified", "weighted"] {
        let mut cfg = nano_cfg();
        cfg.method = Method::Lezo;
        cfg.drop_layers = 1;
        cfg.steps = 3;
        cfg.eval_every = 3;
        cfg.set("policy", policy).unwrap();
        let r = Trainer::new(cfg).run().unwrap();
        assert_eq!(r.losses.len(), 3, "{policy}");
        assert!(r.losses.iter().all(|l| l.is_finite()), "{policy}");
    }
}

#[test]
fn trainer_all_task_kinds_run_natively() {
    for task in ["sst2", "copa", "squad"] {
        let mut cfg = nano_cfg();
        cfg.task = task.into();
        cfg.method = Method::Lezo;
        cfg.steps = 2;
        cfg.eval_every = 2;
        let r = Trainer::new(cfg).run().unwrap();
        assert!((0.0..=1.0).contains(&r.final_metric), "{task}");
        assert_eq!(r.losses.len(), 2, "{task}");
    }
}

#[test]
fn requesting_pjrt_without_support_fails_loudly() {
    // backend=pjrt in a build without the feature (or without artifacts)
    // must error, not silently fall back to native.
    let mut cfg = nano_cfg();
    cfg.backend = BackendKind::Pjrt;
    let result = Trainer::new(cfg).run();
    if !cfg!(feature = "pjrt") {
        let err = result.unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    } else if let Ok(r) = result {
        assert_eq!(r.backend, "pjrt");
    }
}

#[test]
fn auto_backend_falls_back_to_native_without_artifacts() {
    // opt-nano never has artifacts, so `auto` must resolve to native.
    // LEZO_BACKEND steers `auto`, so the fallback is only observable in a
    // clean environment — skip (visibly) otherwise.
    if std::env::var("LEZO_BACKEND").map(|s| !s.is_empty()).unwrap_or(false) {
        eprintln!("SKIPPED auto_backend_falls_back_to_native_without_artifacts: LEZO_BACKEND set");
        return;
    }
    let mut cfg = nano_cfg();
    cfg.backend = BackendKind::Auto;
    cfg.method = Method::ZeroShot;
    let r = Trainer::new(cfg).run().unwrap();
    assert_eq!(r.backend, "native");
}

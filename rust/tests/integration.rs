//! Integration tests over the real PJRT runtime and the opt-micro artifacts
//! (feature `pjrt`).
//!
//! These exercise the full L3 -> runtime -> (AOT'd L2/L1) stack: algorithm
//! invariants that only hold if every layer composes correctly. The same
//! invariants run hermetically on the native backend in
//! rust/tests/native_backend.rs; this file checks the PJRT implementation
//! agrees. Tests skip (visibly, via `require_artifacts!`) when
//! `make artifacts` has not been run.
#![cfg(feature = "pjrt")]

use lezo::config::{Method, RunConfig};
use lezo::coordinator::metrics::StageTimes;
use lezo::coordinator::spsa::{SpsaEngine, TunableUnits};
use lezo::coordinator::{LayerSelector, Trainer};
use lezo::data::batch::Batch;
use lezo::eval::Evaluator;
use lezo::model::Manifest;
use lezo::peft::PeftMode;
use lezo::require_artifacts;
use lezo::runtime::backend::{default_artifact_dir, Backend};
use lezo::runtime::PjrtBackend;
use std::path::PathBuf;

fn art() -> PathBuf {
    default_artifact_dir("opt-micro")
}

fn open() -> PjrtBackend {
    PjrtBackend::open(&art()).unwrap()
}

fn micro_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "opt-micro".into();
    cfg.artifacts_root = art().parent().unwrap().to_str().unwrap().into();
    cfg.steps = 8;
    cfg.eval_every = 8;
    cfg.eval_examples = 16;
    cfg.train_examples = 32;
    cfg.lr = 1e-4;
    cfg
}

fn tunable(backend: &PjrtBackend) -> TunableUnits<PjrtBackend> {
    let host = backend.initial_params("").unwrap().0;
    TunableUnits::from_host(backend, &host).unwrap()
}

fn lm_prepared(
    backend: &PjrtBackend,
    seq: usize,
) -> <PjrtBackend as Backend>::PreparedBatch {
    let m = backend.manifest();
    let seqs: Vec<Vec<u32>> = (0..m.train_batch)
        .map(|r| (0..seq as u32).map(|i| 20 + (r as u32 * 7 + i) % 90).collect())
        .collect();
    let batch = Batch::lm_batch(&seqs, m.train_batch, seq).unwrap();
    backend.prepare_batch(&batch).unwrap()
}

// ---------------------------------------------------------------------------
// ZO-step invariants across the FFI
// ---------------------------------------------------------------------------

#[test]
fn mezo_equals_lezo_with_zero_drop() {
    // MeZO is the drop=0 special case: identical trajectories, bit-for-bit.
    require_artifacts!();
    let mut a = micro_cfg();
    a.method = Method::Mezo;
    a.drop_layers = 0;
    let mut b = a.clone();
    b.method = Method::Lezo;
    let ra = Trainer::new(a).run().unwrap();
    let rb = Trainer::new(b).run().unwrap();
    assert_eq!(ra.backend, "pjrt");
    assert_eq!(ra.losses, rb.losses, "loss trajectories must match exactly");
    assert_eq!(ra.final_metric, rb.final_metric);
}

#[test]
fn run_is_reproducible_across_processes_worth_of_state() {
    require_artifacts!();
    let mut cfg = micro_cfg();
    cfg.method = Method::Lezo;
    cfg.drop_layers = 2;
    let r1 = Trainer::new(cfg.clone()).run().unwrap();
    let r2 = Trainer::new(cfg).run().unwrap();
    assert_eq!(r1.losses, r2.losses);
    assert_eq!(r1.final_metric, r2.final_metric);
}

#[test]
fn different_seeds_different_trajectories() {
    require_artifacts!();
    let mut cfg = micro_cfg();
    cfg.method = Method::Mezo;
    let r1 = Trainer::new(cfg.clone()).run().unwrap();
    cfg.seed = 99;
    let r2 = Trainer::new(cfg).run().unwrap();
    assert_ne!(r1.losses, r2.losses);
}

#[test]
fn spsa_probe_losses_bracket_base_loss() {
    // l+ and l- must both be finite and straddle the unperturbed loss in
    // expectation; at tiny mu they should be within O(mu) of each other.
    require_artifacts!();
    let backend = open();
    let eng = SpsaEngine::new(&backend, 1e-4, 3).unwrap();
    let mut units = tunable(&backend);
    let active: Vec<usize> = (0..units.n_units()).collect();
    let prepared = lm_prepared(&backend, 16);
    let mut loss = |u: &TunableUnits<PjrtBackend>| -> anyhow::Result<f32> {
        backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
    };
    let base = loss(&units).unwrap();
    let mut times = StageTimes::default();
    let step = eng.zo_step(0, &mut units, &active, 0.0, &mut loss, &mut times).unwrap();
    assert!(step.loss_plus.is_finite() && step.loss_minus.is_finite());
    assert!((step.loss_plus - base).abs() < 0.1, "mu=1e-4 probe moved too far");
    assert!((step.loss_minus - base).abs() < 0.1);
    // lr = 0: parameters must be exactly restored
    let after = loss(&units).unwrap();
    assert!((after - base).abs() < 1e-4, "{base} vs {after}");
}

#[test]
fn lezo_step_timing_is_cheaper_than_mezo() {
    // the paper's computation claim at the step level: dropping layers
    // shrinks perturb+update wall time
    require_artifacts!();
    let mut mezo = micro_cfg();
    mezo.method = Method::Mezo;
    mezo.steps = 30;
    mezo.eval_every = 30;
    mezo.eval_examples = 8;
    let mut lezo = mezo.clone();
    lezo.method = Method::Lezo;
    lezo.drop_layers = 3;
    let rm = Trainer::new(mezo).run().unwrap();
    let rl = Trainer::new(lezo).run().unwrap();
    let (pm, _, um, _) = rm.stage_times.per_step_ms();
    let (pl, _, ul, _) = rl.stage_times.per_step_ms();
    assert!(
        pl + ul < pm + um,
        "LeZO perturb+update {:.1}ms must beat MeZO {:.1}ms",
        pl + ul,
        pm + um
    );
    assert!(rl.active_param_fraction < rm.active_param_fraction);
}

// ---------------------------------------------------------------------------
// Evaluator over the real executables
// ---------------------------------------------------------------------------

#[test]
fn evaluator_scores_all_task_kinds() {
    require_artifacts!();
    let backend = open();
    let units = tunable(&backend);
    let ev = Evaluator::new(&backend);
    for task_name in ["sst2", "copa", "squad"] {
        let task = lezo::tasks::make_task(task_name).unwrap();
        let examples = lezo::tasks::eval_set(task.as_ref(), 11, 24, 12);
        let metric = ev.evaluate(task.kind(), &units.unit_refs(), &examples).unwrap();
        assert!(
            (0.0..=1.0).contains(&metric.value),
            "{task_name}: {}",
            metric.value
        );
        assert_eq!(metric.n_examples, 24);
    }
}

#[test]
fn untrained_model_scores_near_chance() {
    // params_init (not the pretrained ckpt) must sit near the task's chance
    // level — guards against leakage through the scoring path
    require_artifacts!();
    let backend = open();
    let host = backend.manifest().read_init_params().unwrap();
    let units = TunableUnits::from_host(&backend, &host).unwrap();
    let ev = Evaluator::new(&backend);
    let task = lezo::tasks::make_task("sst2").unwrap();
    let examples = lezo::tasks::eval_set(task.as_ref(), 123, 80, 12);
    let metric = ev.option_accuracy(&units.unit_refs(), &examples).unwrap();
    assert!(
        (0.3..=0.7).contains(&metric.value),
        "untrained sst2 acc {} should be near 0.5",
        metric.value
    );
}

// ---------------------------------------------------------------------------
// PEFT path (needs the peft executables; skipped on older artifacts)
// ---------------------------------------------------------------------------

#[test]
fn lora_zero_init_matches_base_loss() {
    // LoRA B=0 at init: the adapter forward must equal the base forward.
    require_artifacts!("opt-micro", peft);
    let backend = open();
    let m = backend.manifest().clone();
    let units = tunable(&backend);
    let peft_host = lezo::peft::init_peft_units(PeftMode::Lora, m.n_layers, m.d_model, 0);
    let peft_bufs: Vec<_> = peft_host.iter().map(|u| backend.upload(u).unwrap()).collect();
    let prepared = lm_prepared(&backend, 16);

    let base_loss =
        backend.forward_loss(PeftMode::Full, &units.unit_refs(), &prepared).unwrap();
    let mut args = units.unit_refs();
    args.extend(peft_bufs.iter());
    let lora_loss = backend.forward_loss(PeftMode::Lora, &args, &prepared).unwrap();
    assert!(
        (base_loss - lora_loss).abs() < 1e-4,
        "zero-init LoRA must be a no-op: {base_loss} vs {lora_loss}"
    );
}

#[test]
fn peft_training_runs_and_moves_loss() {
    require_artifacts!("opt-micro", peft);
    for peft in [PeftMode::Lora, PeftMode::Prefix] {
        let mut cfg = micro_cfg();
        cfg.method = Method::Lezo;
        cfg.peft = peft;
        cfg.drop_layers = 2;
        cfg.lr = 1e-3;
        cfg.mu = 1e-2;
        cfg.steps = 6;
        cfg.eval_every = 6;
        let r = Trainer::new(cfg).run().unwrap();
        assert_eq!(r.losses.len(), 6);
        assert!(r.losses.iter().all(|l| l.is_finite()), "{peft:?}");
        // perturbed params per step < full model (the whole point of PEFT)
        assert!(r.active_param_fraction <= 1.0);
    }
}

// ---------------------------------------------------------------------------
// Selector / batching properties against the real manifest
// ---------------------------------------------------------------------------

#[test]
fn selector_covers_all_blocks_on_real_manifest() {
    require_artifacts!();
    let m = Manifest::load(&art()).unwrap();
    let sel = LayerSelector::new(
        m.block_unit_indices(),
        vec![0, m.n_units() - 1],
        m.n_layers - 1, // keep exactly one block per step
        7,
    )
    .unwrap();
    let mut seen = std::collections::HashSet::new();
    for t in 0..100 {
        for u in sel.active_units(t) {
            seen.insert(u);
        }
    }
    assert_eq!(seen.len(), m.n_units(), "every unit must eventually be active");
}

#[test]
fn zero_shot_and_icl_run_end_to_end() {
    require_artifacts!();
    for method in [Method::ZeroShot, Method::Icl] {
        let mut cfg = micro_cfg();
        cfg.method = method;
        let r = Trainer::new(cfg).run().unwrap();
        assert!((0.0..=1.0).contains(&r.final_metric), "{method}");
        assert_eq!(r.stage_times.steps, 0, "no training steps for {method}");
    }
}

#[test]
fn ft_beats_zo_in_few_steps() {
    // FO with Adam must make visible progress in 30 steps where ZO cannot —
    // the paper's accuracy-vs-memory trade
    require_artifacts!();
    let mut cfg = micro_cfg();
    cfg.method = Method::Ft;
    cfg.steps = 30;
    cfg.eval_every = 30;
    cfg.eval_examples = 50;
    cfg.lr = 1e-3;
    let r = Trainer::new(cfg).run().unwrap();
    let first = r.losses.first().copied().unwrap();
    let last = r.losses.last().copied().unwrap();
    assert!(last < first, "FT loss must drop: {first} -> {last}");
}

#[test]
fn smezo_step_slower_but_converging_path_runs() {
    // Sparse-MeZO baseline: runs, restores correctly, and its step is NOT
    // cheaper than MeZO's (the paper's criticism, as an executable assert)
    require_artifacts!();
    let m = Manifest::load(&art()).unwrap();
    if !m.files.contains_key(&format!("zo_axpy_masked_{}", m.unit_lens[0])) {
        eprintln!("SKIPPED: artifacts lack masked kernels");
        return;
    }
    let mut mezo = micro_cfg();
    mezo.method = Method::Mezo;
    mezo.steps = 20;
    mezo.eval_every = 20;
    mezo.eval_examples = 8;
    let mut smezo = mezo.clone();
    smezo.method = Method::Smezo;
    let rm = Trainer::new(mezo).run().unwrap();
    let rs = Trainer::new(smezo).run().unwrap();
    assert!(rs.losses.iter().all(|l| l.is_finite()));
    let (pm, _, um, _) = rm.stage_times.per_step_ms();
    let (ps, _, us, _) = rs.stage_times.per_step_ms();
    assert!(
        ps + us > pm + um,
        "element-wise masking must not beat dense perturb+update: {:.1} vs {:.1}",
        ps + us,
        pm + um
    );
}

#[test]
fn selection_policies_all_train() {
    require_artifacts!();
    for policy in ["uniform", "round-robin", "stratified", "weighted"] {
        let mut cfg = micro_cfg();
        cfg.method = Method::Lezo;
        cfg.drop_layers = 3;
        cfg.steps = 6;
        cfg.eval_every = 6;
        cfg.eval_examples = 8;
        cfg.set("policy", policy).unwrap();
        let r = Trainer::new(cfg).run().unwrap();
        assert_eq!(r.losses.len(), 6, "{policy}");
        assert!(r.losses.iter().all(|l| l.is_finite()), "{policy}");
    }
}

// ---------------------------------------------------------------------------
// Cross-backend agreement: PJRT vs the native reference
// ---------------------------------------------------------------------------

#[test]
fn native_and_pjrt_zo_axpy_agree() {
    require_artifacts!();
    let pjrt = open();
    let native = lezo::runtime::NativeBackend::preset("opt-micro").unwrap();
    let n = pjrt.spec().unit_lens()[1];
    let host: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).cos()).collect();
    let pb = pjrt.upload(&host).unwrap();
    let nb = native.upload(&host).unwrap();
    let a = pjrt.download(&pjrt.zo_axpy(&pb, n, 77, 0.5).unwrap()).unwrap();
    let b = native.download(&native.zo_axpy(&nb, n, 77, 0.5).unwrap()).unwrap();
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!((x - y).abs() < 3e-5, "idx {i}: pjrt {x} vs native {y}");
    }
}

//! The unified scalar-twin differential harness (one registry, every
//! reduced-precision kernel).
//!
//! Each registry entry is a named case `fn(seed) -> Vec<u32>`: it sweeps
//! seeded shapes/masks inside, asserts its own twin pin, and returns a
//! bitwise digest of everything it computed. The driver then runs every
//! case at several worker-thread counts and requires the digests to be
//! identical — so one test sweeps the full (shape, mask, precision,
//! thread-count) grid and any new kernel twin is covered by adding one
//! `TwinCase` line.
//!
//! The pins, by strength:
//! - **exact**: a quantized kernel must be `to_bits`-equal to its f32 twin
//!   run on the *dequantized* weights (`twin_q(x) == twin_f32(dequant(x))`
//!   — the kernels share the accumulation order by construction), and
//!   every SIMD dispatcher must be `to_bits`-equal to its public scalar
//!   fallback.
//! - **calibrated**: a composed reduced-precision forward vs the f32
//!   *masters* carries real quantization error; those cases assert the
//!   documented per-precision tolerance (bf16/int8 1e-2, int4 2e-1) on
//!   the mean loss while still pinning the dequantized twin bitwise.
//!
//! `LEZO_THREADS` (set in one CI job) takes precedence over the harness's
//! `with_threads` override; the digest comparison is then trivially
//! against the same count, which is exactly the point — results must not
//! depend on either knob.

use lezo::model::ModelSpec;
use lezo::peft::PeftMode;
use lezo::rng::Rng;
use lezo::runtime::native::bf16;
use lezo::runtime::native::forward;
use lezo::runtime::native::kernels::{self, ForwardScratch};
use lezo::runtime::native::parallel::with_threads;
use lezo::runtime::native::quant::{self, QuantMode, QuantView};
use lezo::runtime::native::simd;

struct TwinCase {
    name: &'static str,
    run: fn(u64) -> Vec<u32>,
}

const REGISTRY: &[TwinCase] = &[
    TwinCase { name: "simd-dot", run: simd_dot_twin },
    TwinCase { name: "simd-axpy-decode", run: simd_axpy_decode_twin },
    TwinCase { name: "quantize-roundtrip-int8", run: |s| quantize_roundtrip_twin(QuantMode::Int8, s) },
    TwinCase { name: "quantize-roundtrip-int4", run: |s| quantize_roundtrip_twin(QuantMode::Int4, s) },
    TwinCase { name: "matmul-int8", run: |s| matmul_twin(QuantMode::Int8, s) },
    TwinCase { name: "matmul-int4", run: |s| matmul_twin(QuantMode::Int4, s) },
    TwinCase { name: "layernorm-int8", run: |s| layernorm_twin(QuantMode::Int8, s) },
    TwinCase { name: "layernorm-int4", run: |s| layernorm_twin(QuantMode::Int4, s) },
    TwinCase { name: "fused-head-int8", run: |s| fused_head_twin(QuantMode::Int8, s) },
    TwinCase { name: "fused-head-int4", run: |s| fused_head_twin(QuantMode::Int4, s) },
    TwinCase { name: "family-bf16", run: family_bf16_twin },
    TwinCase { name: "family-int8", run: |s| family_quant_twin(QuantMode::Int8, 1e-2, s) },
    TwinCase { name: "family-int4", run: |s| family_quant_twin(QuantMode::Int4, 2e-1, s) },
];

fn gen(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn qpair(mode: QuantMode, src: &[f32]) -> (Vec<f32>, Vec<u8>) {
    quant::quantize(mode, src).unwrap()
}

// -- simd dispatchers vs their public scalar fallbacks ----------------------

const LENS: &[usize] = &[0, 1, 3, 4, 7, 8, 15, 16, 31, 64, 257, 1000];

fn simd_dot_twin(seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut digest = Vec::new();
    for &n in LENS {
        let a = gen(&mut rng, n, 1.0);
        let b = gen(&mut rng, n, 1.0);
        let d = simd::dot(&a, &b);
        assert_eq!(d.to_bits(), simd::dot_scalar(&a, &b).to_bits(), "dot len {n}");
        let ab = bf16::cast(&a);
        let bb = bf16::cast(&b);
        let db = simd::dot_bf16(&ab, &bb);
        assert_eq!(db.to_bits(), simd::dot_bf16_scalar(&ab, &bb).to_bits(), "dot_bf16 len {n}");
        digest.push(d.to_bits());
        digest.push(db.to_bits());
    }
    digest
}

fn simd_axpy_decode_twin(seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut digest = Vec::new();
    for &n in LENS {
        let x = rng.gaussian() as f32;
        let w = gen(&mut rng, n, 0.5);
        let wb = bf16::cast(&w);
        let base = gen(&mut rng, n, 1.0);

        let mut acc = base.clone();
        let mut acc_s = base.clone();
        simd::axpy_row(&mut acc, x, &w);
        simd::axpy_row_scalar(&mut acc_s, x, &w);
        assert_eq!(bits(&acc), bits(&acc_s), "axpy_row len {n}");

        let mut accb = base.clone();
        let mut accb_s = base.clone();
        simd::axpy_row_bf16(&mut accb, x, &wb);
        simd::axpy_row_bf16_scalar(&mut accb_s, x, &wb);
        assert_eq!(bits(&accb), bits(&accb_s), "axpy_row_bf16 len {n}");

        let codes: Vec<u8> = (0..n)
            .map(|_| ((rng.gaussian() * 40.0).clamp(-127.0, 127.0) as i32 as i8) as u8)
            .collect();
        let scale = 0.03125f32;
        let mut dec = vec![0.0f32; n];
        let mut dec_s = vec![0.0f32; n];
        simd::decode_i8(&codes, scale, &mut dec);
        simd::decode_i8_scalar(&codes, scale, &mut dec_s);
        assert_eq!(bits(&dec), bits(&dec_s), "decode_i8 len {n}");

        digest.extend(bits(&acc));
        digest.extend(bits(&accb));
        digest.extend(bits(&dec));
    }
    digest
}

// -- quantizer: error bound, view consistency, non-finite hard error --------

fn quantize_roundtrip_twin(mode: QuantMode, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut digest = Vec::new();
    for &n in &[1usize, 63, 64, 65, 129, 1000] {
        let x = gen(&mut rng, n, 0.2);
        let (scales, codes) = qpair(mode, &x);
        let view = QuantView::new(mode, &scales, &codes, n);
        // absmax quantization error bound: |dequant - x| <= scale/2 per
        // element of each block (plus f32 rounding slack)
        for (i, (&xi, yi)) in x.iter().zip(view.dequant()).enumerate() {
            let scale = scales[i / quant::QBLOCK];
            assert!(
                (yi - xi).abs() <= 0.51 * scale + 1e-30,
                "{mode} n={n} i={i}: {yi} vs {xi} (scale {scale})"
            );
        }
        // sub-views decode the same bits as the bulk path
        if n > 2 {
            let sub = view.split_to(1, n - 1);
            let bulk = view.dequant();
            for (j, v) in sub.dequant().iter().enumerate() {
                assert_eq!(v.to_bits(), bulk[1 + j].to_bits(), "{mode} n={n} sub j={j}");
            }
        }
        digest.extend(scales.iter().map(|s| s.to_bits()));
        digest.extend(codes.iter().map(|&c| c as u32));
    }
    // a non-finite input is a hard error naming the flat index
    let mut bad = gen(&mut rng, 70, 0.2);
    bad[66] = f32::NAN;
    let err = quant::quantize(mode, &bad).unwrap_err().to_string();
    assert!(err.contains("non-finite") && err.contains("flat index 66"), "{err}");
    digest
}

// -- quantized kernels vs the f32 twin on dequantized weights ---------------

fn matmul_twin(mode: QuantMode, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut digest = Vec::new();
    for &(n_rows, din, dout) in
        &[(1usize, 3usize, 5usize), (4, 16, 9), (3, 63, 64), (2, 65, 33), (5, 64, 130), (2, 130, 96)]
    {
        let x = gen(&mut rng, n_rows * din, 1.0);
        let w = gen(&mut rng, din * dout, 0.1);
        let b = gen(&mut rng, dout, 0.1);
        let (ws, wc) = qpair(mode, &w);
        let (bs, bc) = qpair(mode, &b);
        let wv = QuantView::new(mode, &ws, &wc, w.len());
        let bv = QuantView::new(mode, &bs, &bc, b.len());
        let mut out_q = vec![0.0f32; n_rows * dout];
        kernels::matmul_bias_into_quant(&x, &wv, &bv, &mut out_q, n_rows, din, dout);
        let mut out_f = vec![0.0f32; n_rows * dout];
        kernels::matmul_bias_into(&x, &wv.dequant(), &bv.dequant(), &mut out_f, n_rows, din, dout);
        assert_eq!(bits(&out_q), bits(&out_f), "{mode} matmul {n_rows}x{din}x{dout}");
        digest.extend(bits(&out_q));
    }
    digest
}

fn layernorm_twin(mode: QuantMode, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut digest = Vec::new();
    for &(n, d) in &[(1usize, 8usize), (9, 33), (4, 64), (3, 130)] {
        let x = gen(&mut rng, n * d, 1.0);
        let gamma = gen(&mut rng, d, 0.3);
        let beta = gen(&mut rng, d, 0.3);
        let (gs, gc) = qpair(mode, &gamma);
        let (bs, bc) = qpair(mode, &beta);
        let gv = QuantView::new(mode, &gs, &gc, d);
        let bv = QuantView::new(mode, &bs, &bc, d);
        let mut out_q = vec![0.0f32; n * d];
        kernels::layernorm_into_quant(&x, &gv, &bv, &mut out_q, d);
        let mut out_f = vec![0.0f32; n * d];
        kernels::layernorm_into(&x, &gv.dequant(), &bv.dequant(), &mut out_f, d);
        assert_eq!(bits(&out_q), bits(&out_f), "{mode} layernorm {n}x{d}");
        digest.extend(bits(&out_q));
    }
    digest
}

fn fused_head_twin(mode: QuantMode, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut digest = Vec::new();
    for &(n, vocab, d) in &[(7usize, 64usize, 16usize), (10, 130, 32), (5, 127, 10), (3, 513, 8)] {
        let hf = gen(&mut rng, n * d, 1.0);
        let emb = gen(&mut rng, vocab * d, 0.1);
        let targets: Vec<i32> = (0..n).map(|i| ((i * 37 + 11) % vocab) as i32).collect();
        // seeded mask pattern with real holes (including position 0)
        let mask: Vec<f32> =
            (0..n).map(|_| if rng.gaussian() > 0.4 { 0.0 } else { 1.0 }).collect();
        let (es, ec) = qpair(mode, &emb);
        let ev = QuantView::new(mode, &es, &ec, emb.len());
        let deq = ev.dequant();

        let mut xent_q = vec![0.0f32; n];
        kernels::fused_masked_xent_quant(&hf, &ev, &targets, &mask, n, vocab, d, &mut xent_q);
        let mut xent_f = vec![0.0f32; n];
        kernels::fused_masked_xent(&hf, &deq, &targets, &mask, n, vocab, d, &mut xent_f);
        assert_eq!(bits(&xent_q), bits(&xent_f), "{mode} xent n={n} vocab={vocab}");

        let mut preds_q = vec![0i32; n];
        kernels::fused_argmax_quant(&hf, &ev, n, vocab, d, &mut preds_q);
        let mut preds_f = vec![0i32; n];
        kernels::fused_argmax(&hf, &deq, n, vocab, d, &mut preds_f);
        assert_eq!(preds_q, preds_f, "{mode} argmax n={n} vocab={vocab}");

        digest.extend(bits(&xent_q));
        digest.extend(preds_q.iter().map(|&p| p as u32));
    }
    digest
}

// -- composed forwards: bitwise vs the dequantized twin, calibrated vs the
// -- f32 masters -------------------------------------------------------------

fn family_inputs(rng: &mut Rng, spec: &ModelSpec, rows: usize, seq: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let n = rows * seq;
    let tokens: Vec<i32> = (0..n).map(|i| 15 + (i % 95) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i * 29 + 3) % spec.vocab) as i32).collect();
    let mask: Vec<f32> = (0..n).map(|_| if rng.gaussian() > 0.8 { 0.0 } else { 1.0 }).collect();
    (tokens, targets, mask)
}

fn family_bf16_twin(seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let s = ModelSpec::preset("opt-nano").unwrap();
    let host = s.init_units(seed);
    let (rows, seq) = (2usize, 8usize);
    let (tokens, targets, mask) = family_inputs(&mut rng, &s, rows, seq);
    let mut scratch = ForwardScratch::new();
    let refs: Vec<&[f32]> = host.iter().map(|u| u.as_slice()).collect();
    let lf = forward::mean_loss_peft(
        &s, &refs, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq, &mut scratch,
    )
    .unwrap();
    let shadows: Vec<Vec<u16>> = host.iter().map(|u| bf16::cast(u)).collect();
    let brefs: Vec<&[u16]> = shadows.iter().map(|u| u.as_slice()).collect();
    let lb = forward::mean_loss_bf16_peft(
        &s, &brefs, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq, &mut scratch,
    )
    .unwrap();
    let rel = (lb - lf).abs() / lf.abs().max(1e-6);
    assert!(rel <= 1e-2, "bf16 {lb} vs f32 {lf} (rel {rel})");
    vec![lb.to_bits(), lf.to_bits()]
}

fn family_quant_twin(mode: QuantMode, tol: f32, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let s = ModelSpec::preset("opt-nano").unwrap();
    let host = s.init_units(seed);
    let (rows, seq) = (2usize, 8usize);
    let (tokens, targets, mask) = family_inputs(&mut rng, &s, rows, seq);
    let mut scratch = ForwardScratch::new();
    let refs: Vec<&[f32]> = host.iter().map(|u| u.as_slice()).collect();
    let lf = forward::mean_loss_peft(
        &s, &refs, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq, &mut scratch,
    )
    .unwrap();

    let pairs: Vec<(Vec<f32>, Vec<u8>)> = host.iter().map(|u| qpair(mode, u)).collect();
    let views: Vec<QuantView<'_>> = pairs
        .iter()
        .zip(&host)
        .map(|((sc, c), u)| QuantView::new(mode, sc, c, u.len()))
        .collect();
    let deq: Vec<Vec<f32>> = views.iter().map(|v| v.dequant()).collect();
    let deq_refs: Vec<&[f32]> = deq.iter().map(|u| u.as_slice()).collect();

    // exact pin: quant family == f32 family on the dequantized units
    let lq = forward::mean_loss_quant_peft(
        &s, &views, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq, &mut scratch,
    )
    .unwrap();
    let ld = forward::mean_loss_peft(
        &s, &deq_refs, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq, &mut scratch,
    )
    .unwrap();
    assert_eq!(lq.to_bits(), ld.to_bits(), "{mode} mean_loss vs dequantized twin");

    let eq = forward::example_losses_quant_peft(
        &s, &views, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq, &mut scratch,
    )
    .unwrap();
    let ed = forward::example_losses_peft(
        &s, &deq_refs, PeftMode::Full, &[], &tokens, &targets, &mask, rows, seq, &mut scratch,
    )
    .unwrap();
    assert_eq!(bits(&eq), bits(&ed), "{mode} example_losses vs dequantized twin");

    let pq =
        forward::predict_quant_peft(&s, &views, PeftMode::Full, &[], &tokens, rows, seq, &mut scratch)
            .unwrap();
    let pd =
        forward::predict_peft(&s, &deq_refs, PeftMode::Full, &[], &tokens, rows, seq, &mut scratch)
            .unwrap();
    assert_eq!(pq, pd, "{mode} predict vs dequantized twin");

    // calibrated pin: the quantization error vs the f32 masters
    let rel = (lq - lf).abs() / lf.abs().max(1e-6);
    assert!(rel <= tol, "{mode} {lq} vs f32 {lf} (rel {rel}, tol {tol})");

    let mut digest = vec![lq.to_bits(), lf.to_bits()];
    digest.extend(bits(&eq));
    digest.extend(pq.iter().map(|&p| p as u32));
    digest
}

// -- driver ------------------------------------------------------------------

/// FNV-1a over the case name: each case gets a stable, distinct seed.
fn case_seed(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[test]
fn registry_is_nonempty_and_uniquely_named() {
    assert!(REGISTRY.len() >= 13);
    let mut names: Vec<&str> = REGISTRY.iter().map(|c| c.name).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), REGISTRY.len(), "duplicate case names");
}

#[test]
fn twin_registry_is_bitwise_pinned_and_thread_count_invariant() {
    for case in REGISTRY {
        let seed = case_seed(case.name);
        let base = with_threads(1, || (case.run)(seed));
        assert!(!base.is_empty(), "{}: empty digest", case.name);
        for &t in &[2usize, 5] {
            let d = with_threads(t, || (case.run)(seed));
            assert_eq!(d, base, "{}: output bits changed at {t} threads", case.name);
        }
    }
}

#[test]
fn simd_dispatch_is_consistent_with_runtime_detection() {
    // `active()` is a pure capability probe: calling it twice agrees, and
    // the dispatchers above were already pinned to the scalar twins
    // whichever path is taken on this machine.
    assert_eq!(simd::active(), simd::active());
}

//! `cargo bench` — microbenchmarks of the ZO hot path (hand-rolled harness;
//! criterion is not vendored in this offline image).
//!
//! Covers: per-unit zo_axpy latency, forward-pass latency per bucket, and a
//! full MeZO-vs-LeZO step comparison — the raw numbers behind Figs. 2 and 4.
//! Backend-generic: the native backend runs with zero artifacts on any
//! machine; with `--features pjrt` and exported artifacts the same harness
//! times the PJRT runtime. For the full table/figure regeneration use
//! `lezo bench <id>`.
//!
//! Usage: `cargo bench -- [native:MODEL|pjrt:MODEL ...]`
//! (default: `native:opt-micro`, plus every pjrt model with artifacts).

use lezo::coordinator::metrics::StageTimes;
use lezo::coordinator::spsa::{SpsaEngine, TunableUnits};
use lezo::data::batch::Batch;
use lezo::peft::PeftMode;
use lezo::runtime::backend::Backend;
use lezo::runtime::NativeBackend;
use std::time::Instant;

fn time_ms<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    1e3 * t.elapsed().as_secs_f64() / iters as f64
}

fn lm_batch(spec: &lezo::model::ModelSpec, seq: usize) -> Batch {
    let seqs: Vec<Vec<u32>> = (0..spec.train_batch)
        .map(|r| (0..seq as u32).map(|i| 20 + (r as u32 + i) % 100).collect())
        .collect();
    Batch::lm_batch(&seqs, spec.train_batch, seq).unwrap()
}

fn bench_backend<B: Backend>(backend: &B, iters: usize) {
    let spec = backend.spec().clone();
    println!(
        "\n== {} [{}] ({} params, {} blocks) ==",
        spec.name,
        backend.name(),
        spec.param_count(),
        spec.n_layers
    );
    backend.warm_zo().unwrap();
    let host = backend.initial_params("").unwrap().0;

    // --- zo_axpy per unit length ---
    let mut seen = std::collections::BTreeSet::new();
    for &n in spec.unit_lens().iter().filter(|&&n| seen.insert(n)) {
        let p = backend.upload(&vec![0.1f32; n]).unwrap();
        let ms = time_ms(iters, || {
            let _ = backend.zo_axpy(&p, n, 1, 1e-3).unwrap();
        });
        let gbs = (8.0 * n as f64) / (ms / 1e3) / 1e9; // 1 load + 1 store, f32
        println!("  zo_axpy[{n:>9}] {ms:>8.3} ms  ({gbs:.2} GB/s effective)");
    }

    // --- forward per bucket ---
    let units = TunableUnits::<B>::from_host(backend, &host).unwrap();
    for &s in &spec.seq_buckets {
        let batch = lm_batch(&spec, s);
        let prepared = backend.prepare_batch(&batch).unwrap();
        let refs = units.unit_refs();
        let ms = time_ms((iters + 1) / 2, || {
            let _ = backend.forward_loss(PeftMode::Full, &refs, &prepared).unwrap();
        });
        println!("  forward_loss[s{s:>3}] {ms:>7.2} ms (batch {})", spec.train_batch);
    }

    // --- full ZO step: MeZO vs LeZO(75%) ---
    let batch = lm_batch(&spec, 32);
    let prepared = backend.prepare_batch(&batch).unwrap();
    let drop = (3 * spec.n_layers) / 4;
    for (name, active) in [
        ("MeZO step      ", (0..spec.n_units()).collect::<Vec<_>>()),
        (
            "LeZO step (75%)",
            (0..spec.n_units()).filter(|&k| k == 0 || k > drop).collect::<Vec<_>>(),
        ),
    ] {
        let eng = SpsaEngine::new(backend, 1e-3, 1).unwrap();
        let mut tun = TunableUnits::<B>::from_host(backend, &host).unwrap();
        let mut times = StageTimes::default();
        let mut loss = |u: &TunableUnits<B>| -> anyhow::Result<f32> {
            backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
        };
        let t = Instant::now();
        for step in 0..iters as u64 {
            eng.zo_step(step, &mut tun, &active, 1e-5, &mut loss, &mut times).unwrap();
        }
        let ms = 1e3 * t.elapsed().as_secs_f64() / iters as f64;
        let (p, f, u, _) = times.per_step_ms();
        println!(
            "  {name} {ms:>7.1} ms/step (perturb {p:.1} + forward {f:.1} + update {u:.1}), non-forward {:.0}%",
            100.0 * times.non_forward_fraction()
        );
    }
}

fn run_target(target: &str, iters: usize) {
    match target.split_once(':') {
        Some(("native", model)) => match NativeBackend::preset(model) {
            Ok(b) => bench_backend(&b, iters),
            Err(e) => eprintln!("[skip] {target}: {e}"),
        },
        Some(("pjrt", model)) => {
            #[cfg(feature = "pjrt")]
            {
                let dir = lezo::runtime::backend::default_artifact_dir(model);
                if !lezo::runtime::backend::artifacts_available(&dir) {
                    eprintln!("[skip] {target}: no artifacts");
                    return;
                }
                match lezo::runtime::PjrtBackend::open(&dir) {
                    Ok(b) => bench_backend(&b, iters),
                    Err(e) => eprintln!("[skip] {target}: {e}"),
                }
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = model;
                eprintln!("[skip] {target}: built without the pjrt feature");
            }
        }
        _ => eprintln!("[skip] {target}: use native:MODEL or pjrt:MODEL"),
    }
}

fn main() {
    // honor `cargo bench -- <backend:model>`
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let targets: Vec<String> = if args.is_empty() {
        let mut t = vec!["native:opt-micro".to_string()];
        if cfg!(feature = "pjrt") {
            for m in ["opt-micro", "opt-tiny", "opt-small"] {
                t.push(format!("pjrt:{m}"));
            }
        }
        t
    } else {
        args
    };
    let iters: usize =
        std::env::var("LEZO_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(15);
    println!("ZO hot-path microbenchmarks");
    for t in &targets {
        run_target(t, iters);
    }
}

//! `cargo bench` — microbenchmarks of the ZO hot path (hand-rolled harness;
//! criterion is not vendored in this offline image).
//!
//! Covers: per-unit zo_axpy latency, forward-pass latency per bucket, and a
//! full MeZO-vs-LeZO step comparison — the raw numbers behind Figs. 2 and 4.
//! For the full table/figure regeneration use `lezo bench <id>`.

use lezo::coordinator::metrics::StageTimes;
use lezo::coordinator::spsa::{SpsaEngine, TunableUnits};
use lezo::data::batch::Batch;
use lezo::model::{Manifest, ParamStore};
use lezo::runtime::exes::{ExeRegistry, Family};
use lezo::runtime::{run1, Runtime};
use std::path::PathBuf;
use std::time::Instant;

fn art(model: &str) -> PathBuf {
    let root = std::env::var("LEZO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    PathBuf::from(root).join(model)
}

fn time_ms<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    1e3 * t.elapsed().as_secs_f64() / iters as f64
}

fn bench_model(model: &str) {
    let dir = art(model);
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] {model}: no artifacts");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let m = Manifest::load(&dir).unwrap();
    let reg = ExeRegistry::new(m.clone());
    reg.warm_zo(&rt).unwrap();
    let store = ParamStore::load_init(&rt, &m).unwrap();
    println!("\n== {model} ({} params, {} blocks) ==", m.param_count, m.n_layers);

    // --- zo_axpy per unit length ---
    for &n in &m.axpy_lens {
        if !m.unit_lens.contains(&n) {
            continue; // PEFT-only lengths: skip in the full-model bench
        }
        let exe = reg.get(&rt, Family::ZoAxpy, n).unwrap();
        let p = rt.vec_f32(&vec![0.1f32; n]).unwrap();
        let seed = rt.scalar_i32(1).unwrap();
        let c = rt.scalar_f32(1e-3).unwrap();
        let ms = time_ms(20, || {
            let _ = run1(&exe, &[&p, &seed, &c]).unwrap();
        });
        let gbs = (8.0 * n as f64) / (ms / 1e3) / 1e9; // 1 load + 1 store, f32
        println!("  zo_axpy[{n:>9}] {ms:>8.3} ms  ({gbs:.2} GB/s effective)");
    }

    // --- forward per bucket ---
    let units = store.unit_refs();
    for &s in &m.seq_buckets {
        let exe = reg.get(&rt, Family::ForwardLoss, s).unwrap();
        let seqs: Vec<Vec<u32>> = (0..m.train_batch)
            .map(|r| (0..s as u32).map(|i| 20 + (r as u32 + i) % 100).collect())
            .collect();
        let b = Batch::lm_batch(&seqs, m.train_batch, s).unwrap();
        let tok = rt.mat_i32(&b.tokens, b.rows, s).unwrap();
        let tgt = rt.mat_i32(&b.targets, b.rows, s).unwrap();
        let msk = rt.mat_f32(&b.mask, b.rows, s).unwrap();
        let mut args: Vec<&xla::PjRtBuffer> = units.clone();
        args.push(&tok);
        args.push(&tgt);
        args.push(&msk);
        let ms = time_ms(10, || {
            let _ = run1(&exe, &args).unwrap();
        });
        println!("  forward_loss[s{s:>3}] {ms:>7.2} ms (batch {})", m.train_batch);
    }

    // --- full ZO step: MeZO vs LeZO(75%) ---
    let seqs: Vec<Vec<u32>> = (0..m.train_batch)
        .map(|r| (0..32u32).map(|i| 20 + (r as u32 + i) % 100).collect())
        .collect();
    let b = Batch::lm_batch(&seqs, m.train_batch, 32).unwrap();
    let tok = rt.mat_i32(&b.tokens, b.rows, 32).unwrap();
    let tgt = rt.mat_i32(&b.targets, b.rows, 32).unwrap();
    let msk = rt.mat_f32(&b.mask, b.rows, 32).unwrap();
    let fwd = reg.get(&rt, Family::ForwardLoss, 32).unwrap();
    let drop = (3 * m.n_layers) / 4;
    for (name, active) in [
        ("MeZO step      ", (0..m.n_units()).collect::<Vec<_>>()),
        (
            "LeZO step (75%)",
            (0..m.n_units()).filter(|&k| k == 0 || k > drop).collect::<Vec<_>>(),
        ),
    ] {
        let eng = SpsaEngine::new(&rt, &reg, 1e-3, 1).unwrap();
        let bufs = (0..store.n_units())
            .map(|k| rt.vec_f32(&rt.read_vec_f32(store.unit(k)).unwrap()).unwrap())
            .collect();
        let mut tun = TunableUnits { bufs, lens: m.unit_lens.clone() };
        let mut times = StageTimes::default();
        let mut loss = |u: &TunableUnits| -> anyhow::Result<f32> {
            let mut args: Vec<&xla::PjRtBuffer> = u.bufs.iter().collect();
            args.push(&tok);
            args.push(&tgt);
            args.push(&msk);
            rt.read_scalar_f32(&run1(&fwd, &args)?)
        };
        let t = Instant::now();
        let iters = 15;
        for step in 0..iters {
            eng.zo_step(step, &mut tun, &active, 1e-5, &mut loss, &mut times).unwrap();
        }
        let ms = 1e3 * t.elapsed().as_secs_f64() / iters as f64;
        let (p, f, u, _) = times.per_step_ms();
        println!(
            "  {name} {ms:>7.1} ms/step (perturb {p:.1} + forward {f:.1} + update {u:.1}), non-forward {:.0}%",
            100.0 * times.non_forward_fraction()
        );
    }
}

fn main() {
    // honor `cargo bench -- <model>`
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let models: Vec<String> = if args.is_empty() {
        vec!["opt-micro".into(), "opt-tiny".into(), "opt-small".into()]
    } else {
        args
    };
    println!("ZO hot-path microbenchmarks (PJRT CPU)");
    for m in &models {
        bench_model(m);
    }
}

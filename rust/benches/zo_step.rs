//! `cargo bench` — microbenchmarks of the ZO hot path (hand-rolled harness;
//! criterion is not vendored in this offline image).
//!
//! Covers: per-unit zo_axpy latency (allocating and in-place), forward-pass
//! latency per bucket, a full MeZO-vs-LeZO step comparison — the raw
//! numbers behind Figs. 2 and 4 — the optimizer-zoo step variants
//! (`zo-sgd-momentum`, `zo-adam`, `zo-sign-sgd`, `fzoo`: the per-rule
//! update/schedule overhead on the dense full-model step), and the four
//! Table-4 PEFT step variants (`mezo-lora`, `lezo-lora`, `mezo-prefix`,
//! `lezo-prefix`: adapter units tunable over a frozen base, with their
//! tunable-parameter counts in the `steps[].tunable_params` JSON field),
//! plus `mezo-sharded` rows — the dense step fanned across 1/2/4 lockstep
//! replicas via the sharded backend, carrying a `shards` count and a
//! `scaling` speedup-vs-1-backend column — and their `mezo-sharded-socket`
//! twins (shards 1/2/4 at f32/bf16), the same fan-out dispatched to real
//! spawned `lezo worker` processes over the framed socket transport. Every
//! step row carries a `transport` field (`none`/`thread`/`socket`) and the
//! socket rows a per-step `rt_ms` round-trip-latency split — wall time
//! inside the forward stage that was dispatch + wire + wait rather than
//! worker compute (JSON version 7).
//! Backend-generic: the native backend
//! runs with zero artifacts on any machine; with `--features pjrt` and
//! exported artifacts the same harness times the PJRT runtime. For the full
//! table/figure regeneration use `lezo bench <id>`.
//!
//! **Precision axis:** every native target is benchmarked once per forward
//! precision (`f32`, `bf16`, `int8`, `int4`) — and every JSON entry
//! carries a `"precision"` field, so the per-precision ms and GB/s deltas
//! are machine-readable across PRs. Forward entries additionally carry a
//! modeled `"bytes"` field (`elsize * (params + rows*seq*vocab*d_model)`:
//! each parameter streamed once plus the fused LM head's tok_emb stream
//! per position — the two dominant terms) and the GB/s derived from it;
//! by construction bf16 moves 0.5x the f32 bytes, int8 0.265625x (one
//! code byte plus a shared f32 scale per 64-element block), and int4
//! 0.140625x — the measured ms shows how much of that lands as
//! wall-clock. The zo_axpy rows keep the 8-bytes-per-element f32 model in
//! every precision: the sweeps always mutate the f32 masters (shadow
//! invalidation is a flag store), so their reduced-precision rows measure
//! that those modes do NOT regress the perturb/update path (JSON
//! version 7).
//!
//! Besides the stdout table, every run writes a machine-readable report to
//! `BENCH_native.json` (override with `LEZO_BENCH_JSON=<path>`) so the perf
//! trajectory is tracked across PRs: per-kernel ms + effective GB/s,
//! MeZO-vs-LeZO step times, the perturb/forward/update stage split from
//! `StageTimes`, and a checkpoint-overhead row (`checkpoint[]`: atomic
//! `save_state` wall-clock + serialized envelope bytes — the per-save cost
//! behind `save_every`). CI smoke-checks that the file is produced and
//! well-formed.
//!
//! Usage: `cargo bench -- [native:MODEL|pjrt:MODEL ...]`
//! (default: `native:opt-micro`, plus every pjrt model with artifacts).
//! Env: `LEZO_BENCH_ITERS` (default 15), `LEZO_THREADS`, `LEZO_BENCH_JSON`.

use lezo::coordinator::metrics::StageTimes;
use lezo::coordinator::optim::{make_optimizer, ZoOptKind, ZoOptimizer, ZoSgd, FZOO_PROBES};
use lezo::coordinator::spsa::{SpsaEngine, TunableUnits};
use lezo::data::batch::Batch;
use lezo::model::checkpoint::{self, HistPoint, TrainState};
use lezo::peft::PeftMode;
use lezo::runtime::backend::{Backend, Precision};
use lezo::runtime::native::parallel;
use lezo::runtime::{NativeBackend, ShardedBackend};
use std::fmt::Write as _;
use std::time::Instant;

fn time_ms<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    1e3 * t.elapsed().as_secs_f64() / iters as f64
}

fn lm_batch(spec: &lezo::model::ModelSpec, seq: usize) -> Batch {
    let seqs: Vec<Vec<u32>> = (0..spec.train_batch)
        .map(|r| (0..seq as u32).map(|i| 20 + (r as u32 + i) % 100).collect())
        .collect();
    Batch::lm_batch(&seqs, spec.train_batch, seq).unwrap()
}

fn precision_tag<B: Backend>(backend: &B) -> &'static str {
    match backend.precision() {
        Precision::F32 => "f32",
        Precision::Bf16 => "bf16",
        Precision::Int8 => "int8",
        Precision::Int4 => "int4",
    }
}

/// Modeled bytes per stored scalar of the streamed weights: f32 4, bf16 2,
/// and for the block-quantized modes the code bytes plus the amortized
/// per-64-element f32 scale (int8 `1 + 4/64 = 1.0625`, int4
/// `0.5 + 4/64 = 0.5625`) — the same model as
/// `quant::QuantMode::bytes_per_element`.
fn elsize_bytes(precision: Precision) -> f64 {
    match precision {
        Precision::F32 => 4.0,
        Precision::Bf16 => 2.0,
        Precision::Int8 => 1.0625,
        Precision::Int4 => 0.5625,
    }
}

/// Modeled bytes of one fused forward at `elsize` bytes per stored scalar:
/// every parameter streamed once plus the fused LM head's tok_emb stream
/// per position (the bandwidth-dominant terms; activations are lower
/// order). The per-precision ratios of this model vs f32 are exactly 0.5
/// (bf16), 0.265625 (int8), and 0.140625 (int4) — the measured ms tells
/// how much of it the hardware realizes.
fn forward_bytes_model(
    spec: &lezo::model::ModelSpec,
    rows: usize,
    seq: usize,
    elsize: f64,
) -> f64 {
    elsize * (spec.param_count() + rows * seq * spec.vocab * spec.d_model) as f64
}

// ---------------------------------------------------------------------------
// Machine-readable report (hand-rolled writer; serde is not vendored)
// ---------------------------------------------------------------------------

struct KernelStat {
    kernel: &'static str,
    precision: &'static str,
    len: usize,
    ms: f64,
    gbs: f64,
}

struct ForwardStat {
    precision: &'static str,
    seq: usize,
    batch: usize,
    ms: f64,
    /// Modeled traffic of one forward (see [`forward_bytes_model`]).
    bytes: f64,
    /// `bytes / ms`-derived effective bandwidth.
    gbs: f64,
}

struct StepStat {
    name: &'static str,
    precision: &'static str,
    ms_per_step: f64,
    perturb_ms: f64,
    forward_ms: f64,
    update_ms: f64,
    non_forward_fraction: f64,
    /// Modeled forward traffic per step (two probes).
    forward_bytes: f64,
    /// Size of the ZO-tunable parameter space: the full model for
    /// `mezo`/`lezo75`, the per-block adapter units for the PEFT variants.
    tunable_params: usize,
    /// Worker replicas behind the row: 0 for single-backend (sequential)
    /// rows, N for the `mezo-sharded` plan fan-out rows.
    shards: usize,
    /// Speedup of this row vs its single-backend reference at the same
    /// precision (`mezo` ms / this row's ms); NaN (JSON null) for
    /// sequential rows, which have no reference.
    scaling: f64,
    /// How evals were dispatched: `none` (single backend, sequential),
    /// `thread` (in-process sharded replicas), or `socket` (spawned
    /// `lezo worker` processes over the framed wire).
    transport: &'static str,
    /// Per-step socket round-trip latency (`StageTimes::rt_secs`): wall
    /// time inside the forward stage that was dispatch + wire + wait, not
    /// worker compute. A sub-split of `forward_ms`; zero off-socket.
    rt_ms: f64,
}

struct CheckpointStat {
    precision: &'static str,
    /// Wall-clock of one atomic `save_state` (serialize + tmp write + fsync
    /// + rename) of a full-model TrainState to local disk.
    save_ms: f64,
    /// Serialized envelope size — dominated by the f32 params, so for a
    /// given model it is precision-independent (masters stay f32).
    bytes: usize,
}

struct TargetReport {
    backend: &'static str,
    model: String,
    params: usize,
    blocks: usize,
    kernels: Vec<KernelStat>,
    forward: Vec<ForwardStat>,
    steps: Vec<StepStat>,
    checkpoint: Vec<CheckpointStat>,
}

impl TargetReport {
    /// Empty report for one (backend, model) target; `bench_into` appends
    /// one set of rows per precision pass.
    fn new(backend: &'static str, spec: &lezo::model::ModelSpec) -> TargetReport {
        TargetReport {
            backend,
            model: spec.name.clone(),
            params: spec.param_count(),
            blocks: spec.n_layers,
            kernels: vec![],
            forward: vec![],
            steps: vec![],
            checkpoint: vec![],
        }
    }
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".to_string()
    }
}

fn report_json(iters: usize, targets: &[TargetReport]) -> String {
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\n  \"version\": 7,\n  \"iters\": {iters},\n  \"threads\": {},\n  \"targets\": [",
        parallel::effective_threads()
    );
    for (ti, t) in targets.iter().enumerate() {
        if ti > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "\n    {{\n      \"backend\": \"{}\",\n      \"model\": \"{}\",\n      \
             \"params\": {},\n      \"blocks\": {},\n      \"zo_axpy\": [",
            t.backend, t.model, t.params, t.blocks
        );
        for (i, k) in t.kernels.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n        {{\"kernel\": \"{}\", \"precision\": \"{}\", \"len\": {}, \
                 \"ms\": {}, \"gbs\": {}}}",
                k.kernel,
                k.precision,
                k.len,
                json_num(k.ms),
                json_num(k.gbs)
            );
        }
        s.push_str("\n      ],\n      \"forward_loss\": [");
        for (i, f) in t.forward.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n        {{\"precision\": \"{}\", \"seq\": {}, \"batch\": {}, \"ms\": {}, \
                 \"bytes\": {}, \"gbs\": {}}}",
                f.precision,
                f.seq,
                f.batch,
                json_num(f.ms),
                json_num(f.bytes),
                json_num(f.gbs)
            );
        }
        s.push_str("\n      ],\n      \"steps\": [");
        for (i, st) in t.steps.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n        {{\"name\": \"{}\", \"precision\": \"{}\", \"ms_per_step\": {}, \
                 \"perturb_ms\": {}, \"forward_ms\": {}, \"update_ms\": {}, \
                 \"non_forward_fraction\": {}, \"forward_bytes\": {}, \"tunable_params\": {}, \
                 \"shards\": {}, \"scaling\": {}, \"transport\": \"{}\", \"rt_ms\": {}}}",
                st.name,
                st.precision,
                json_num(st.ms_per_step),
                json_num(st.perturb_ms),
                json_num(st.forward_ms),
                json_num(st.update_ms),
                json_num(st.non_forward_fraction),
                json_num(st.forward_bytes),
                st.tunable_params,
                st.shards,
                json_num(st.scaling),
                st.transport,
                json_num(st.rt_ms)
            );
        }
        s.push_str("\n      ],\n      \"checkpoint\": [");
        for (i, c) in t.checkpoint.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n        {{\"precision\": \"{}\", \"save_ms\": {}, \"bytes\": {}}}",
                c.precision,
                json_num(c.save_ms),
                c.bytes
            );
        }
        s.push_str("\n      ]\n    }");
    }
    s.push_str("\n  ]\n}\n");
    s
}

// ---------------------------------------------------------------------------
// Benchmarks
// ---------------------------------------------------------------------------

/// Bench one backend instance (one precision) and append its rows to
/// `report` — native targets call this twice, once per precision.
fn bench_into<B: Backend>(backend: &B, iters: usize, report: &mut TargetReport) {
    let spec = backend.spec().clone();
    let prec = precision_tag(backend);
    let elsize = elsize_bytes(backend.precision());
    println!(
        "\n== {} [{} {prec}] ({} params, {} blocks, {} threads) ==",
        spec.name,
        backend.name(),
        spec.param_count(),
        spec.n_layers,
        parallel::effective_threads()
    );
    backend.warm_zo().unwrap();
    let host = backend.initial_params("").unwrap().0;

    // --- zo_axpy per unit length: allocating and in-place ---
    // (always f32 master traffic — the bf16 rows pin that the reduced
    // precision mode does not regress the sweeps)
    let mut seen = std::collections::BTreeSet::new();
    for &n in spec.unit_lens().iter().filter(|&&n| seen.insert(n)) {
        let p = backend.upload(&vec![0.1f32; n]).unwrap();
        let ms = time_ms(iters, || {
            let _ = backend.zo_axpy(&p, n, 1, 1e-3).unwrap();
        });
        let gbs = (8.0 * n as f64) / (ms / 1e3) / 1e9; // 1 load + 1 store, f32
        println!("  zo_axpy        [{n:>9}] {ms:>8.3} ms  ({gbs:.2} GB/s effective)");
        report.kernels.push(KernelStat { kernel: "zo_axpy", precision: prec, len: n, ms, gbs });

        let mut q = backend.upload(&vec![0.1f32; n]).unwrap();
        let ms = time_ms(iters, || {
            backend.zo_axpy_inplace(&mut q, n, 1, 1e-3).unwrap();
        });
        let gbs = (8.0 * n as f64) / (ms / 1e3) / 1e9;
        println!("  zo_axpy_inplace[{n:>9}] {ms:>8.3} ms  ({gbs:.2} GB/s effective)");
        report
            .kernels
            .push(KernelStat { kernel: "zo_axpy_inplace", precision: prec, len: n, ms, gbs });
    }

    // --- forward per bucket ---
    let units = TunableUnits::<B>::from_host(backend, &host).unwrap();
    for &s in &spec.seq_buckets {
        let batch = lm_batch(&spec, s);
        let prepared = backend.prepare_batch(&batch).unwrap();
        let refs = units.unit_refs();
        let ms = time_ms((iters + 1) / 2, || {
            let _ = backend.forward_loss(PeftMode::Full, &refs, &prepared).unwrap();
        });
        let bytes = forward_bytes_model(&spec, spec.train_batch, s, elsize);
        let gbs = bytes / (ms / 1e3) / 1e9;
        println!(
            "  forward_loss[s{s:>3}] {ms:>7.2} ms (batch {}, {gbs:.2} GB/s modeled)",
            spec.train_batch
        );
        report.forward.push(ForwardStat {
            precision: prec,
            seq: s,
            batch: spec.train_batch,
            ms,
            bytes,
            gbs,
        });
    }

    // --- full ZO step: MeZO vs LeZO(75%) ---
    let batch = lm_batch(&spec, 32);
    let prepared = backend.prepare_batch(&batch).unwrap();
    let step_fwd_bytes = 2.0 * forward_bytes_model(&spec, spec.train_batch, 32, elsize);
    let drop = lezo::bench::paper_drop(spec.n_layers);
    for (name, active) in [
        ("mezo", (0..spec.n_units()).collect::<Vec<_>>()),
        ("lezo75", (0..spec.n_units()).filter(|&k| k == 0 || k > drop).collect::<Vec<_>>()),
    ] {
        let mut tun = TunableUnits::<B>::from_host(backend, &host).unwrap();
        let mut loss = |u: &TunableUnits<B>| -> anyhow::Result<f32> {
            backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
        };
        let st = time_zo_steps(
            name,
            prec,
            step_fwd_bytes,
            backend,
            &mut tun,
            &active,
            iters,
            1e-3,
            1e-5,
            &mut ZoSgd,
            &mut loss,
        );
        println!(
            "  {name:<15} {:>7.1} ms/step (perturb {:.1} + forward {:.1} + update {:.1}), non-forward {:.0}%",
            st.ms_per_step, st.perturb_ms, st.forward_ms, st.update_ms,
            100.0 * st.non_forward_fraction
        );
        report.steps.push(st);
    }

    // --- optimizer-zoo step variants (dense full-model schedule) ---
    // what each update rule costs on top of the classic step: the replay
    // sweeps of momentum/adam, and fzoo's one-sided batched forwards
    for (name, kind) in [
        ("zo-sgd-momentum", ZoOptKind::Momentum),
        ("zo-adam", ZoOptKind::Adam),
        ("zo-sign-sgd", ZoOptKind::SignSgd),
        ("fzoo", ZoOptKind::Fzoo),
    ] {
        let mut tun = TunableUnits::<B>::from_host(backend, &host).unwrap();
        let mut opt = make_optimizer(kind);
        let active: Vec<usize> = (0..spec.n_units()).collect();
        let fwd_bytes = if kind == ZoOptKind::Fzoo {
            // one-sided batched: FZOO_PROBES + 1 forwards per step, vs 2
            (FZOO_PROBES as f64 + 1.0) / 2.0 * step_fwd_bytes
        } else {
            step_fwd_bytes
        };
        let mut loss = |u: &TunableUnits<B>| -> anyhow::Result<f32> {
            backend.forward_loss(PeftMode::Full, &u.unit_refs(), &prepared)
        };
        let st = time_zo_steps(
            name,
            prec,
            fwd_bytes,
            backend,
            &mut tun,
            &active,
            iters,
            1e-3,
            1e-5,
            opt.as_mut(),
            &mut loss,
        );
        println!(
            "  {name:<15} {:>7.1} ms/step (perturb {:.1} + forward {:.1} + update {:.1}), non-forward {:.0}%",
            st.ms_per_step, st.perturb_ms, st.forward_ms, st.update_ms,
            100.0 * st.non_forward_fraction
        );
        report.steps.push(st);
    }

    // --- PEFT ZO steps (Table 4): adapter units tunable, base frozen ---
    // one shared frozen-base upload for all four variants
    let base_bufs: Vec<B::Buffer> = host.iter().map(|u| backend.upload(u).unwrap()).collect();
    for (name, mode, drop) in [
        ("mezo-lora", PeftMode::Lora, 0usize),
        ("lezo-lora", PeftMode::Lora, spec.n_layers / 2),
        ("mezo-prefix", PeftMode::Prefix, 0),
        ("lezo-prefix", PeftMode::Prefix, lezo::bench::paper_drop(spec.n_layers)),
    ] {
        if !backend.supports_peft(mode) {
            eprintln!("  [skip] {name}: backend lacks the {mode} executables");
            continue;
        }
        let peft_host = lezo::peft::init_peft_units(mode, spec.n_layers, spec.d_model, 0);
        let mut tun = TunableUnits::<B>::from_host(backend, &peft_host).unwrap();
        // LeZO over PEFT: drop whole adapter units (paper Table 4 captions)
        let active: Vec<usize> = (drop..spec.n_layers).collect();
        let mut loss = |u: &TunableUnits<B>| -> anyhow::Result<f32> {
            let mut args: Vec<&B::Buffer> = base_bufs.iter().collect();
            args.extend(u.bufs.iter());
            backend.forward_loss(mode, &args, &prepared)
        };
        let st = time_zo_steps(
            name,
            prec,
            step_fwd_bytes,
            backend,
            &mut tun,
            &active,
            iters,
            1e-2,
            1e-3,
            &mut ZoSgd,
            &mut loss,
        );
        println!(
            "  {name:<15} {:>7.1} ms/step (perturb {:.1} + forward {:.1} + update {:.1}), \
             {} tunable params",
            st.ms_per_step, st.perturb_ms, st.forward_ms, st.update_ms, st.tunable_params
        );
        report.steps.push(st);
    }

    // --- checkpoint overhead: one atomic save of a full-model TrainState ---
    // the per-save cost the trainer pays every `save_every` steps (serialize
    // + tmp write + fsync + rename); bytes is the envelope size on disk
    let drill_steps = 64u64;
    let st = TrainState {
        config: format!("bench model={} precision={prec}", spec.name),
        kind: "zo".to_string(),
        step: drill_steps,
        params: host.clone(),
        losses: (0..drill_steps).map(|s| 2.0 + (s as f32) * 1e-3).collect(),
        grads: (0..drill_steps).map(|s| (s as f32) * 1e-4 - 3e-3).collect(),
        skipped: vec![false; drill_steps as usize],
        history: (0..4)
            .map(|i| HistPoint {
                step: i * 16,
                train_secs: i as f64,
                metric: 0.5 + 0.01 * i as f64,
                train_loss: 2.0,
            })
            .collect(),
        stage_secs: [1.0, 2.0, 0.5, 0.1],
        stage_steps: drill_steps,
        ..Default::default()
    };
    let bytes = st.to_bytes().len();
    let ckpt_path = std::env::temp_dir().join(format!(
        "lezo_bench_ckpt_{}_{prec}_{}.ckpt",
        spec.name,
        std::process::id()
    ));
    let save_ms = time_ms(iters, || {
        checkpoint::save_state(&ckpt_path, &st).unwrap();
    });
    std::fs::remove_file(&ckpt_path).ok();
    println!(
        "  checkpoint save {save_ms:>7.2} ms  ({:.2} MB atomic write+fsync)",
        bytes as f64 / 1e6
    );
    report.checkpoint.push(CheckpointStat { precision: prec, save_ms, bytes });
}

/// Shared step-timing tail of the full-model and PEFT step benches: run
/// `iters` ZO steps and fold the timings into one [`StepStat`], so the
/// timing protocol and the `BENCH_native.json` row shape exist once.
#[allow(clippy::too_many_arguments)]
fn time_zo_steps<B: Backend>(
    name: &'static str,
    precision: &'static str,
    forward_bytes: f64,
    backend: &B,
    tun: &mut TunableUnits<B>,
    active: &[usize],
    iters: usize,
    mu: f32,
    lr: f32,
    opt: &mut dyn ZoOptimizer,
    loss: &mut dyn FnMut(&TunableUnits<B>) -> anyhow::Result<f32>,
) -> StepStat {
    let eng = SpsaEngine::new(backend, mu, 1).unwrap();
    let mut times = StageTimes::default();
    let t = Instant::now();
    for step in 0..iters as u64 {
        eng.zo_step_opt(step, tun, active, lr, opt, loss, &mut times).unwrap();
    }
    let ms = 1e3 * t.elapsed().as_secs_f64() / iters as f64;
    let (p, f, u, _) = times.per_step_ms();
    StepStat {
        name,
        precision,
        ms_per_step: ms,
        perturb_ms: p,
        forward_ms: f,
        update_ms: u,
        non_forward_fraction: times.non_forward_fraction(),
        forward_bytes,
        tunable_params: tun.param_count(),
        shards: 0,
        scaling: f64::NAN,
        transport: "none",
        rt_ms: 0.0,
    }
}

/// Sharded plan fan-out rows: the dense classic step (`mezo` schedule,
/// zo-sgd) re-timed through `ShardedBackend` at 1/2/4 replicas, at both
/// precisions. The `scaling` field is the speedup vs the same-precision
/// single-backend `mezo` row already in `report` — the headline number of
/// the data-parallel backend (per-step losses are bit-identical to native
/// by construction, so any scaling > 1 is free accuracy-wise).
fn bench_sharded_into(model: &str, iters: usize, report: &mut TargetReport) {
    for precision in [Precision::F32, Precision::Bf16, Precision::Int8, Precision::Int4] {
        let prec = match precision {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        };
        let base_ms = report
            .steps
            .iter()
            .find(|s| s.name == "mezo" && s.precision == prec)
            .map(|s| s.ms_per_step)
            .unwrap_or(f64::NAN);
        for shards in [1usize, 2, 4] {
            let backend = match ShardedBackend::preset_with_precision(model, shards, precision) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("  [skip] mezo-sharded x{shards} [{prec}]: {e}");
                    continue;
                }
            };
            let spec = backend.spec().clone();
            let elsize = elsize_bytes(precision);
            backend.warm_zo().unwrap();
            let host = backend.initial_params("").unwrap().0;
            let mut tun = TunableUnits::from_host(&backend, &host).unwrap();
            let active: Vec<usize> = (0..spec.n_units()).collect();
            let prepared = backend.prepare_batch(&lm_batch(&spec, 32)).unwrap();
            let eng = SpsaEngine::new(&backend, 1e-3, 1).unwrap();
            let mut opt = ZoSgd;
            let mut times = StageTimes::default();
            let t = Instant::now();
            for step in 0..iters as u64 {
                eng.zo_step_fanout(
                    step,
                    &mut tun,
                    &active,
                    1e-5,
                    &mut opt,
                    PeftMode::Full,
                    None,
                    &prepared,
                    &mut |_| Ok(None),
                    &mut times,
                )
                .unwrap();
            }
            let ms = 1e3 * t.elapsed().as_secs_f64() / iters as f64;
            let (p, f, u, _) = times.per_step_ms();
            let st = StepStat {
                name: "mezo-sharded",
                precision: prec,
                ms_per_step: ms,
                perturb_ms: p,
                forward_ms: f,
                update_ms: u,
                non_forward_fraction: times.non_forward_fraction(),
                forward_bytes: 2.0 * forward_bytes_model(&spec, spec.train_batch, 32, elsize),
                tunable_params: tun.param_count(),
                shards,
                scaling: base_ms / ms,
                transport: "thread",
                rt_ms: times.per_step_rt_ms(),
            };
            println!(
                "  mezo-sharded x{shards} [{prec}] {:>7.1} ms/step ({:.2}x vs 1-backend mezo)",
                st.ms_per_step, st.scaling
            );
            report.steps.push(st);
        }
    }
}

// ---------------------------------------------------------------------------
// socket transport rows
// ---------------------------------------------------------------------------

/// Spawned `lezo worker --listen 127.0.0.1:0` processes; each announces
/// its ephemeral port on stdout. Killed on drop.
struct BenchWorkers {
    procs: Vec<std::process::Child>,
    addrs: Vec<String>,
}

impl BenchWorkers {
    fn spawn(n: usize) -> anyhow::Result<BenchWorkers> {
        use std::io::BufRead;
        let exe = env!("CARGO_BIN_EXE_lezo");
        let mut fleet = BenchWorkers { procs: vec![], addrs: vec![] };
        for _ in 0..n {
            let mut child = std::process::Command::new(exe)
                .args(["worker", "--listen", "127.0.0.1:0"])
                .stdout(std::process::Stdio::piped())
                .stderr(std::process::Stdio::null())
                .spawn()?;
            let stdout = child.stdout.take().unwrap();
            let mut line = String::new();
            std::io::BufReader::new(stdout).read_line(&mut line)?;
            let addr = line
                .trim()
                .strip_prefix("worker listening on ")
                .ok_or_else(|| anyhow::anyhow!("unexpected worker banner {line:?}"))?
                .to_string();
            fleet.procs.push(child);
            fleet.addrs.push(addr);
        }
        Ok(fleet)
    }
}

impl Drop for BenchWorkers {
    fn drop(&mut self) {
        for c in &mut self.procs {
            c.kill().ok();
            c.wait().ok();
        }
    }
}

/// `mezo-sharded-socket` rows: the identical dense fan-out dispatched to
/// real worker processes over the framed socket transport, at 1/2/4 shards
/// and f32/bf16. Beyond `scaling` vs the single-backend `mezo` row, each
/// row splits out `rt_ms` — the per-step wall time that was dispatch +
/// wire + wait rather than worker compute — so the transport tax is
/// tracked separately from the compute it hides.
fn bench_socket_into(model: &str, iters: usize, report: &mut TargetReport) {
    use lezo::runtime::transport::{SocketOpts, DEFAULT_NET_RETRIES, DEFAULT_NET_TIMEOUT_MS};
    for precision in [Precision::F32, Precision::Bf16] {
        let prec = match precision {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            _ => unreachable!(),
        };
        let base_ms = report
            .steps
            .iter()
            .find(|s| s.name == "mezo" && s.precision == prec)
            .map(|s| s.ms_per_step)
            .unwrap_or(f64::NAN);
        for shards in [1usize, 2, 4] {
            let fleet = match BenchWorkers::spawn(shards) {
                Ok(f) => f,
                Err(e) => {
                    eprintln!("  [skip] mezo-sharded-socket x{shards} [{prec}]: {e}");
                    continue;
                }
            };
            let opts = SocketOpts {
                workers: fleet.addrs.clone(),
                model: model.to_string(),
                precision,
                artifact_dir: String::new(),
                faults: String::new(),
                timeout_ms: DEFAULT_NET_TIMEOUT_MS,
                retries: DEFAULT_NET_RETRIES,
            };
            let replica = match NativeBackend::preset(model) {
                Ok(b) => b.with_precision(precision),
                Err(e) => {
                    eprintln!("  [skip] mezo-sharded-socket x{shards} [{prec}]: {e}");
                    continue;
                }
            };
            let backend = match ShardedBackend::connect_socket(replica, &opts) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("  [skip] mezo-sharded-socket x{shards} [{prec}]: {e}");
                    continue;
                }
            };
            let spec = backend.spec().clone();
            let elsize = elsize_bytes(precision);
            backend.warm_zo().unwrap();
            let host = backend.initial_params("").unwrap().0;
            let mut tun = TunableUnits::from_host(&backend, &host).unwrap();
            let active: Vec<usize> = (0..spec.n_units()).collect();
            let prepared = backend.prepare_batch(&lm_batch(&spec, 32)).unwrap();
            let eng = SpsaEngine::new(&backend, 1e-3, 1).unwrap();
            let mut opt = ZoSgd;
            let mut times = StageTimes::default();
            let t = Instant::now();
            for step in 0..iters as u64 {
                eng.zo_step_fanout(
                    step,
                    &mut tun,
                    &active,
                    1e-5,
                    &mut opt,
                    PeftMode::Full,
                    None,
                    &prepared,
                    &mut |_| Ok(None),
                    &mut times,
                )
                .unwrap();
            }
            let ms = 1e3 * t.elapsed().as_secs_f64() / iters as f64;
            let (p, f, u, _) = times.per_step_ms();
            let st = StepStat {
                name: "mezo-sharded-socket",
                precision: prec,
                ms_per_step: ms,
                perturb_ms: p,
                forward_ms: f,
                update_ms: u,
                non_forward_fraction: times.non_forward_fraction(),
                forward_bytes: 2.0 * forward_bytes_model(&spec, spec.train_batch, 32, elsize),
                tunable_params: tun.param_count(),
                shards,
                scaling: base_ms / ms,
                transport: "socket",
                rt_ms: times.per_step_rt_ms(),
            };
            println!(
                "  mezo-sharded-socket x{shards} [{prec}] {:>7.1} ms/step \
                 ({:.2}x vs 1-backend mezo, rt {:.2} ms/step)",
                st.ms_per_step, st.scaling, st.rt_ms
            );
            report.steps.push(st);
        }
    }
}

fn run_target(target: &str, iters: usize) -> Option<TargetReport> {
    match target.split_once(':') {
        Some(("native", model)) => match NativeBackend::preset(model) {
            Ok(b32) => {
                let mut report = TargetReport::new(b32.name(), b32.spec());
                bench_into(&b32, iters, &mut report);
                // the reduced-precision twins of every row (native targets
                // are benchmarked once per precision: bf16 shadows, then
                // the int8/int4 block-quantized shadows)
                for precision in [Precision::Bf16, Precision::Int8, Precision::Int4] {
                    let b = NativeBackend::preset(model).unwrap().with_precision(precision);
                    bench_into(&b, iters, &mut report);
                }
                // the data-parallel twin: same dense step fanned across
                // 1/2/4 lockstep replicas, with its scaling vs the rows
                // above (`shards`/`scaling` fields)
                bench_sharded_into(model, iters, &mut report);
                // and its multi-process twin: the identical fan-out over
                // spawned `lezo worker` processes, with the round-trip
                // latency split out per row (`transport`/`rt_ms` fields)
                bench_socket_into(model, iters, &mut report);
                Some(report)
            }
            Err(e) => {
                eprintln!("[skip] {target}: {e}");
                None
            }
        },
        Some(("pjrt", model)) => {
            #[cfg(feature = "pjrt")]
            {
                let dir = lezo::runtime::backend::default_artifact_dir(model);
                if !lezo::runtime::backend::artifacts_available(&dir) {
                    eprintln!("[skip] {target}: no artifacts");
                    return None;
                }
                match lezo::runtime::PjrtBackend::open(&dir) {
                    Ok(b) => {
                        let mut report = TargetReport::new(b.name(), b.spec());
                        bench_into(&b, iters, &mut report);
                        Some(report)
                    }
                    Err(e) => {
                        eprintln!("[skip] {target}: {e}");
                        None
                    }
                }
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = model;
                eprintln!("[skip] {target}: built without the pjrt feature");
                None
            }
        }
        _ => {
            eprintln!("[skip] {target}: use native:MODEL or pjrt:MODEL");
            None
        }
    }
}

fn main() {
    // the strict-env rule: an unparseable LEZO_THREADS or LEZO_PRECISION
    // is a hard error naming the bad value, even here (the bench times
    // both precisions itself, but a typo'd env must not pass silently)
    if let Err(e) = parallel::check_env() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    if let Err(e) = lezo::runtime::backend::env_precision() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    if let Err(e) = lezo::coordinator::optim::env_zo_opt() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    // honor `cargo bench -- <backend:model>`
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let targets: Vec<String> = if args.is_empty() {
        let mut t = vec!["native:opt-micro".to_string()];
        if cfg!(feature = "pjrt") {
            for m in ["opt-micro", "opt-tiny", "opt-small"] {
                t.push(format!("pjrt:{m}"));
            }
        }
        t
    } else {
        args
    };
    let iters: usize =
        std::env::var("LEZO_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(15);
    println!("ZO hot-path microbenchmarks");
    let reports: Vec<TargetReport> = targets.iter().filter_map(|t| run_target(t, iters)).collect();

    let path =
        std::env::var("LEZO_BENCH_JSON").unwrap_or_else(|_| "BENCH_native.json".to_string());
    match std::fs::write(&path, report_json(iters, &reports)) {
        Ok(()) => println!("\nwrote {path} ({} targets)", reports.len()),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

//! Synthetic vocabulary layout shared by every model size (min vocab 512).
//!
//! Token-id space is partitioned into fixed regions: special tokens,
//! verbalizers (the single-token "answers" MeZO-style classification
//! predicts), lexicons with planted semantics (positive/negative sentiment,
//! entities, word-sense cues, topics) and filler. The filler region scales
//! with the model's vocab so bigger models see a richer distribution.

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const SEP: u32 = 2;
pub const EOS: u32 = 3;
pub const Q: u32 = 4; // question marker
pub const ANS: u32 = 5; // answer marker (generation tasks)
pub const PRON: u32 = 6; // pronoun marker (WSC-like)
pub const MARK: u32 = 7; // countable marker (DROP-like)
pub const NEG: u32 = 8; // negation marker (CB-like contradiction)
pub const AGREE: u32 = 9; // agreement marker (WSC-like rule)

// Verbalizers: single-token answers.
pub const V_YES: u32 = 16;
pub const V_NO: u32 = 17;
pub const V_MAYBE: u32 = 18;
pub const V_POS: u32 = 19;
pub const V_NEG: u32 = 20;
pub const V_TRUE: u32 = 21;
pub const V_FALSE: u32 = 22;

/// Digit verbalizers d0..d9 (DROP-like counting answers).
pub const DIGIT_BASE: u32 = 32;
pub fn digit(n: usize) -> u32 {
    debug_assert!(n < 10);
    DIGIT_BASE + n as u32
}

// Lexicons with planted semantics.
pub const LEX_POS: std::ops::Range<u32> = 48..80; // "positive sentiment" words
pub const LEX_NEG: std::ops::Range<u32> = 80..112; // "negative sentiment" words
pub const ENTITIES: std::ops::Range<u32> = 112..176; // named entities
pub const SENSE_A: std::ops::Range<u32> = 176..192; // sense-A cue words (WiC)
pub const SENSE_B: std::ops::Range<u32> = 192..208; // sense-B cue words
pub const POLYSEMOUS: std::ops::Range<u32> = 208..224; // ambiguous words (WiC)

/// Topic groups (Copa-like causal continuity): N_TOPICS groups of
/// TOPIC_WIDTH consecutive tokens each.
pub const TOPIC_BASE: u32 = 224;
pub const N_TOPICS: usize = 16;
pub const TOPIC_WIDTH: usize = 6;

pub fn topic_tokens(topic: usize) -> std::ops::Range<u32> {
    debug_assert!(topic < N_TOPICS);
    let start = TOPIC_BASE + (topic * TOPIC_WIDTH) as u32;
    start..start + TOPIC_WIDTH as u32
}

/// First filler id; filler extends to the model's vocab size.
pub const FILLER_BASE: u32 = TOPIC_BASE + (N_TOPICS * TOPIC_WIDTH) as u32; // 320

pub fn filler_range(vocab: usize) -> std::ops::Range<u32> {
    debug_assert!(vocab >= 512, "vocab must be >= 512");
    FILLER_BASE..vocab as u32
}

/// Human-readable rendering for debugging / example dumps.
pub fn render(tok: u32) -> String {
    match tok {
        PAD => "<pad>".into(),
        BOS => "<bos>".into(),
        SEP => "<sep>".into(),
        EOS => "<eos>".into(),
        Q => "<q>".into(),
        ANS => "<ans>".into(),
        PRON => "<pron>".into(),
        MARK => "<mark>".into(),
        NEG => "<not>".into(),
        AGREE => "<agr>".into(),
        V_YES => "yes".into(),
        V_NO => "no".into(),
        V_MAYBE => "maybe".into(),
        V_POS => "positive".into(),
        V_NEG => "negative".into(),
        V_TRUE => "true".into(),
        V_FALSE => "false".into(),
        t if (DIGIT_BASE..DIGIT_BASE + 10).contains(&t) => format!("{}", t - DIGIT_BASE),
        t if LEX_POS.contains(&t) => format!("good{}", t - LEX_POS.start),
        t if LEX_NEG.contains(&t) => format!("bad{}", t - LEX_NEG.start),
        t if ENTITIES.contains(&t) => format!("Ent{}", t - ENTITIES.start),
        t if SENSE_A.contains(&t) => format!("cueA{}", t - SENSE_A.start),
        t if SENSE_B.contains(&t) => format!("cueB{}", t - SENSE_B.start),
        t if POLYSEMOUS.contains(&t) => format!("poly{}", t - POLYSEMOUS.start),
        t if t >= TOPIC_BASE && t < FILLER_BASE => {
            let rel = (t - TOPIC_BASE) as usize;
            format!("t{}w{}", rel / TOPIC_WIDTH, rel % TOPIC_WIDTH)
        }
        t => format!("w{t}"),
    }
}

pub fn render_seq(toks: &[u32]) -> String {
    toks.iter().map(|&t| render(t)).collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_disjoint_and_ordered() {
        // every named region must be disjoint; check boundaries
        assert!(LEX_POS.end <= LEX_NEG.start);
        assert!(LEX_NEG.end <= ENTITIES.start);
        assert!(ENTITIES.end <= SENSE_A.start);
        assert!(SENSE_A.end <= SENSE_B.start);
        assert!(SENSE_B.end <= POLYSEMOUS.start);
        assert!(POLYSEMOUS.end <= TOPIC_BASE);
        assert_eq!(FILLER_BASE, TOPIC_BASE + (N_TOPICS * TOPIC_WIDTH) as u32);
        assert!(FILLER_BASE < 512, "layout must fit the smallest vocab");
    }

    #[test]
    fn digits_map() {
        assert_eq!(digit(0), DIGIT_BASE);
        assert_eq!(digit(9), DIGIT_BASE + 9);
    }

    #[test]
    fn topics_within_bounds() {
        for t in 0..N_TOPICS {
            let r = topic_tokens(t);
            assert!(r.end <= FILLER_BASE);
            assert_eq!(r.len(), TOPIC_WIDTH);
        }
    }

    #[test]
    fn filler_nonempty_for_min_vocab() {
        let r = filler_range(512);
        assert!(r.len() >= 100);
    }

    #[test]
    fn render_round_trips_visually() {
        assert_eq!(render(PAD), "<pad>");
        assert_eq!(render(V_YES), "yes");
        assert_eq!(render(digit(3)), "3");
        assert_eq!(render(LEX_POS.start), "good0");
        assert!(render(FILLER_BASE + 5).starts_with('w'));
        let s = render_seq(&[BOS, V_YES, EOS]);
        assert_eq!(s, "<bos> yes <eos>");
    }
}

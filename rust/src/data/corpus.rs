//! Synthetic pretraining corpus.
//!
//! Stands in for OPT's pretraining data (DESIGN.md substitution table): a
//! mixture of (a) plain filler sentences with a planted bigram structure
//! (so the LM objective has learnable signal) and (b) task-formatted
//! documents whose labels follow each task's rule only with its
//! `pretrain_hint` probability. This mirrors the real mechanism that makes
//! MeZO-style fine-tuning work — the pretrained model already almost knows
//! the task format, and fine-tuning sharpens it.

use crate::data::vocab as v;
use crate::rng::Rng;
use crate::tasks::{make_task, Task, ALL_TASKS};

pub struct CorpusGen {
    vocab: usize,
    max_seq: usize,
    tasks: Vec<Box<dyn Task>>,
    /// fraction of documents that are task-formatted (vs plain filler)
    task_frac: f64,
}

impl CorpusGen {
    pub fn new(vocab: usize, max_seq: usize) -> CorpusGen {
        let tasks = ALL_TASKS.iter().map(|n| make_task(n).unwrap()).collect();
        CorpusGen { vocab, max_seq, tasks, task_frac: 0.7 }
    }

    /// One document (token sequence, <= max_seq).
    pub fn doc(&self, rng: &mut Rng) -> Vec<u32> {
        if rng.bool(self.task_frac) {
            self.task_doc(rng)
        } else {
            self.filler_doc(rng)
        }
    }

    /// Task-formatted document with a hint-strength-noisy label.
    fn task_doc(&self, rng: &mut Rng) -> Vec<u32> {
        let task = &self.tasks[rng.below(self.tasks.len())];
        // keep room for the continuation
        let mean = (self.max_seq / 2).max(8);
        let mut ex = task.gen(rng, mean);
        if !ex.options.is_empty() && !rng.bool(task.pretrain_hint()) {
            // corrupt the label: pick a wrong option
            let wrong = (ex.gold + 1 + rng.below(ex.options.len() - 1)) % ex.options.len();
            ex.gold = wrong;
        }
        let inst = ex.train_instance();
        let mut doc = inst.prompt;
        doc.extend(&inst.continuation);
        if *doc.last().unwrap() != v::EOS {
            doc.push(v::EOS);
        }
        doc.truncate(self.max_seq);
        doc
    }

    /// Plain sentence with bigram structure: each filler token prefers a
    /// successor in a fixed window (deterministic function of the token), so
    /// the LM can reduce loss below uniform.
    fn filler_doc(&self, rng: &mut Rng) -> Vec<u32> {
        let len = rng.range(8, self.max_seq - 2);
        let range = v::filler_range(self.vocab);
        let width = (range.end - range.start) as usize;
        let mut doc = vec![v::BOS];
        let mut cur = range.start + rng.below(width) as u32;
        for _ in 0..len {
            doc.push(cur);
            cur = if rng.bool(0.8) {
                // planted bigram: successor within a small window of f(cur)
                let base = ((cur as u64).wrapping_mul(2654435761) % width as u64) as usize;
                range.start + ((base + rng.below(4)) % width) as u32
            } else {
                range.start + rng.below(width) as u32
            };
        }
        doc.push(v::EOS);
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn docs_fit_and_are_in_vocab() {
        let g = CorpusGen::new(512, 64);
        let mut rng = Rng::new(1);
        for _ in 0..300 {
            let d = g.doc(&mut rng);
            assert!(d.len() <= 64);
            assert!(d.len() >= 3);
            assert!(d.iter().all(|&t| (t as usize) < 512));
            assert_eq!(d[0], v::BOS);
        }
    }

    #[test]
    fn mixture_contains_both_kinds() {
        let g = CorpusGen::new(512, 64);
        let mut rng = Rng::new(2);
        let mut with_sep = 0;
        let n = 300;
        for _ in 0..n {
            let d = g.doc(&mut rng);
            if d.contains(&v::SEP) || d.contains(&v::ANS) {
                with_sep += 1;
            }
        }
        // ~70% task docs
        assert!((0.5..0.9).contains(&(with_sep as f64 / n as f64)), "{with_sep}/{n}");
    }

    #[test]
    fn bigram_structure_is_predictable() {
        // the most frequent successor of a filler token should dominate
        let g = CorpusGen::new(512, 64);
        let mut rng = Rng::new(3);
        let mut next_counts: std::collections::HashMap<u32, std::collections::HashMap<u32, usize>> =
            Default::default();
        for _ in 0..2000 {
            let d = g.filler_doc(&mut rng);
            for w in d.windows(2) {
                if v::filler_range(512).contains(&w[0]) && v::filler_range(512).contains(&w[1]) {
                    *next_counts.entry(w[0]).or_default().entry(w[1]).or_insert(0) += 1;
                }
            }
        }
        // aggregate: for tokens with >= 20 observations, the top-4 successor
        // mass should be well above uniform (4/192 = 2%)
        let mut dominated = 0;
        let mut total = 0;
        for (_, succ) in next_counts.iter() {
            let n: usize = succ.values().sum();
            if n < 20 {
                continue;
            }
            total += 1;
            let mut counts: Vec<usize> = succ.values().copied().collect();
            counts.sort_unstable_by(|a, b| b.cmp(a));
            let top4: usize = counts.iter().take(4).sum();
            if top4 as f64 / n as f64 > 0.5 {
                dominated += 1;
            }
        }
        assert!(total > 20, "need data");
        assert!(dominated as f64 / total as f64 > 0.8, "{dominated}/{total}");
    }
}

//! Data substrate: vocabulary layout, batch assembly, pretraining corpus.

pub mod batch;
pub mod corpus;
pub mod vocab;

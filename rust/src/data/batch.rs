//! Batch assembly: (tokens, targets, mask) triples shaped for the AOT'd
//! executables, with next-token-shift targets, right padding, and
//! sequence-length bucketing.
//!
//! Layout contract with L2 (model.py): targets[i] = token that position i
//! must predict (i.e. tokens[i+1] of the unpadded stream); mask[i] = 1.0
//! where the prediction participates in the loss.

use crate::data::vocab::PAD;
use anyhow::{ensure, Result};

/// One training/scoring instance before padding: the prompt and the
/// continuation whose tokens are predicted (loss-masked).
#[derive(Debug, Clone)]
pub struct Instance {
    pub prompt: Vec<u32>,
    pub continuation: Vec<u32>,
}

impl Instance {
    pub fn total_len(&self) -> usize {
        self.prompt.len() + self.continuation.len()
    }
}

/// A padded batch ready for upload.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub rows: usize,
    pub seq: usize,
}

impl Batch {
    /// Build a batch of `rows` from up to `rows` instances (rows beyond the
    /// instance count are fully padded/masked-out). `seq` is the bucket.
    pub fn from_instances(instances: &[Instance], rows: usize, seq: usize) -> Result<Batch> {
        ensure!(instances.len() <= rows, "too many instances for batch");
        let mut tokens = vec![PAD as i32; rows * seq];
        let mut targets = vec![PAD as i32; rows * seq];
        let mut mask = vec![0.0f32; rows * seq];
        for (r, inst) in instances.iter().enumerate() {
            let total = inst.total_len();
            ensure!(total <= seq, "instance length {total} exceeds bucket {seq}");
            ensure!(!inst.prompt.is_empty(), "empty prompt");
            let stream: Vec<u32> =
                inst.prompt.iter().chain(inst.continuation.iter()).copied().collect();
            for (i, &t) in stream.iter().enumerate() {
                tokens[r * seq + i] = t as i32;
            }
            // next-token targets over the real stream
            for i in 0..total - 1 {
                targets[r * seq + i] = stream[i + 1] as i32;
            }
            // loss over continuation predictions: positions P-1 .. P+C-2
            let p = inst.prompt.len();
            for i in 0..inst.continuation.len() {
                mask[r * seq + (p - 1 + i)] = 1.0;
            }
        }
        Ok(Batch { tokens, targets, mask, rows, seq })
    }

    /// Full-LM batch (pretraining): every next-token prediction counts.
    pub fn lm_batch(seqs: &[Vec<u32>], rows: usize, seq: usize) -> Result<Batch> {
        ensure!(seqs.len() <= rows, "too many sequences for batch");
        let mut tokens = vec![PAD as i32; rows * seq];
        let mut targets = vec![PAD as i32; rows * seq];
        let mut mask = vec![0.0f32; rows * seq];
        for (r, s) in seqs.iter().enumerate() {
            ensure!(s.len() <= seq, "sequence too long for bucket");
            for (i, &t) in s.iter().enumerate() {
                tokens[r * seq + i] = t as i32;
            }
            for i in 0..s.len().saturating_sub(1) {
                targets[r * seq + i] = s[i + 1] as i32;
                mask[r * seq + i] = 1.0;
            }
        }
        Ok(Batch { tokens, targets, mask, rows, seq })
    }

    /// Count of loss-participating positions.
    pub fn active_positions(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }
}

/// Pick the smallest bucket fitting the longest instance.
pub fn bucket_for_instances(buckets: &[usize], instances: &[Instance]) -> Result<usize> {
    let need = instances.iter().map(Instance::total_len).max().unwrap_or(1);
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= need)
        .min()
        .ok_or_else(|| anyhow::anyhow!("instances need {need} tokens, larger than any bucket"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(p: &[u32], c: &[u32]) -> Instance {
        Instance { prompt: p.to_vec(), continuation: c.to_vec() }
    }

    #[test]
    fn shift_targets_and_mask() {
        let b = Batch::from_instances(&[inst(&[1, 10, 2], &[16])], 1, 8).unwrap();
        assert_eq!(&b.tokens[..4], &[1, 10, 2, 16]);
        // targets: position i predicts tokens[i+1]
        assert_eq!(&b.targets[..3], &[10, 2, 16]);
        // only the SEP position (index 2 = prompt_len-1) predicts the verbalizer
        assert_eq!(b.mask[2], 1.0);
        assert_eq!(b.active_positions(), 1);
    }

    #[test]
    fn multi_token_continuation_mask() {
        let b = Batch::from_instances(&[inst(&[1, 5], &[7, 8, 3])], 1, 8).unwrap();
        // positions 1, 2, 3 predict 7, 8, 3
        assert_eq!(b.mask[1], 1.0);
        assert_eq!(b.mask[2], 1.0);
        assert_eq!(b.mask[3], 1.0);
        assert_eq!(b.active_positions(), 3);
        assert_eq!(b.targets[1], 7);
        assert_eq!(b.targets[2], 8);
        assert_eq!(b.targets[3], 3);
    }

    #[test]
    fn padding_rows_are_masked_out() {
        let b = Batch::from_instances(&[inst(&[1, 2], &[3])], 4, 8).unwrap();
        assert_eq!(b.rows, 4);
        for r in 1..4 {
            for i in 0..8 {
                assert_eq!(b.mask[r * 8 + i], 0.0);
                assert_eq!(b.tokens[r * 8 + i], PAD as i32);
            }
        }
    }

    #[test]
    fn rejects_oversize() {
        let long = inst(&[1; 10], &[2; 10]);
        assert!(Batch::from_instances(&[long], 1, 16).is_err());
        assert!(Batch::from_instances(&vec![inst(&[1], &[2]); 3], 2, 8).is_err());
    }

    #[test]
    fn lm_batch_masks_everything_but_padding() {
        let b = Batch::lm_batch(&[vec![1, 2, 3, 4]], 2, 8).unwrap();
        assert_eq!(b.active_positions(), 3); // 3 next-token predictions
        assert_eq!(&b.targets[..3], &[2, 3, 4]);
        assert_eq!(b.mask[3], 0.0); // last real token predicts nothing
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        let buckets = [16, 32, 64];
        let short = [inst(&[1; 4], &[1])];
        assert_eq!(bucket_for_instances(&buckets, &short).unwrap(), 16);
        let medium = [inst(&[1; 20], &[1; 5])];
        assert_eq!(bucket_for_instances(&buckets, &medium).unwrap(), 32);
        let too_long = [inst(&[1; 70], &[1])];
        assert!(bucket_for_instances(&buckets, &too_long).is_err());
    }
}

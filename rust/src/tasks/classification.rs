//! Classification tasks (verbalizer-scored): the SuperGLUE stand-ins.
//!
//! Every task plants a decodable rule over data::vocab's semantic regions.
//! Budget discipline: each generator keeps prompt + continuation <= 64
//! tokens at any mean_len (content length is clamped).

use super::{content_len, filler, Example, Task, TaskKind};
use crate::data::vocab as v;
use crate::rng::Rng;

const VOCAB: usize = 512; // generators only use the always-present id space

fn lex_tok(rng: &mut Rng, r: &std::ops::Range<u32>) -> u32 {
    r.start + rng.below((r.end - r.start) as usize) as u32
}

/// SST-2: sentence contains positive- and negative-lexicon words; the label
/// follows the majority sentiment.
pub struct Sst2Like;

impl Task for Sst2Like {
    fn name(&self) -> &'static str {
        "sst2"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Classification
    }
    fn chance(&self) -> f64 {
        0.5
    }
    fn pretrain_hint(&self) -> f64 {
        0.75
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 58);
        let positive = rng.bool(0.5);
        let k_sent = (len / 4).clamp(2, 10);
        let k_major = (k_sent * 2).div_ceil(3).max(k_sent / 2 + 1);
        let (maj, min_) = if positive {
            (&v::LEX_POS, &v::LEX_NEG)
        } else {
            (&v::LEX_NEG, &v::LEX_POS)
        };
        let mut sent = Vec::with_capacity(len);
        for _ in 0..k_major {
            sent.push(lex_tok(rng, maj));
        }
        for _ in k_major..k_sent {
            sent.push(lex_tok(rng, min_));
        }
        sent.extend(filler(rng, len - k_sent, VOCAB));
        rng.shuffle(&mut sent);
        let mut prompt = vec![v::BOS];
        prompt.extend(sent);
        prompt.push(v::SEP);
        Example {
            prompt,
            options: vec![vec![v::V_POS], vec![v::V_NEG]],
            gold: if positive { 0 } else { 1 },
            answer: vec![],
        }
    }
}

/// RTE: premise + hypothesis; entailment iff every hypothesis content token
/// occurs in the premise.
pub struct RteLike;

impl Task for RteLike {
    fn name(&self) -> &'static str {
        "rte"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Classification
    }
    fn chance(&self) -> f64 {
        0.5
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 48);
        let premise = filler(rng, len, VOCAB);
        let entail = rng.bool(0.5);
        let hyp_len = 3.min(premise.len());
        let hyp: Vec<u32> = if entail {
            // subset of the premise
            let idx = rng.sample_indices(premise.len(), hyp_len);
            idx.into_iter().map(|i| premise[i]).collect()
        } else {
            // at least 2 novel tokens (filler is wide enough that collisions
            // are rare; we re-roll collisions explicitly)
            let mut h = Vec::with_capacity(hyp_len);
            h.push(premise[rng.below(premise.len())]); // one shared is fine
            while h.len() < hyp_len {
                let t = filler(rng, 1, VOCAB)[0];
                if !premise.contains(&t) {
                    h.push(t);
                }
            }
            h
        };
        let mut prompt = vec![v::BOS];
        prompt.extend(&premise);
        prompt.push(v::Q);
        prompt.extend(&hyp);
        prompt.push(v::SEP);
        Example {
            prompt,
            options: vec![vec![v::V_YES], vec![v::V_NO]],
            gold: if entail { 0 } else { 1 },
            answer: vec![],
        }
    }
}

/// CB: 3-way: entail (hyp ⊂ premise), contradiction (hyp ⊂ premise but
/// negated with the NEG marker), neutral (hyp disjoint).
pub struct CbLike;

impl Task for CbLike {
    fn name(&self) -> &'static str {
        "cb"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Classification
    }
    fn chance(&self) -> f64 {
        1.0 / 3.0
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 46);
        let premise = filler(rng, len, VOCAB);
        let class = rng.below(3); // 0 entail, 1 contradict, 2 neutral
        let hyp_len = 3.min(premise.len());
        let mut hyp = Vec::new();
        match class {
            0 | 1 => {
                let idx = rng.sample_indices(premise.len(), hyp_len);
                if class == 1 {
                    hyp.push(v::NEG);
                }
                hyp.extend(idx.into_iter().map(|i| premise[i]));
            }
            _ => {
                while hyp.len() < hyp_len {
                    let t = filler(rng, 1, VOCAB)[0];
                    if !premise.contains(&t) {
                        hyp.push(t);
                    }
                }
            }
        }
        let mut prompt = vec![v::BOS];
        prompt.extend(&premise);
        prompt.push(v::Q);
        prompt.extend(&hyp);
        prompt.push(v::SEP);
        Example {
            prompt,
            options: vec![vec![v::V_YES], vec![v::V_NO], vec![v::V_MAYBE]],
            gold: class,
            answer: vec![],
        }
    }
}

/// BoolQ: passage + entity query; yes iff the queried entity occurs in the
/// passage.
pub struct BoolqLike;

impl Task for BoolqLike {
    fn name(&self) -> &'static str {
        "boolq"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Classification
    }
    fn chance(&self) -> f64 {
        0.5
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 54);
        let mut passage = filler(rng, len, VOCAB);
        // sprinkle 2-4 entities into the passage
        let n_ents = rng.range(2, 4).min(passage.len());
        let mut present = Vec::new();
        for i in rng.sample_indices(passage.len(), n_ents) {
            let e = lex_tok(rng, &v::ENTITIES);
            passage[i] = e;
            present.push(e);
        }
        let yes = rng.bool(0.5);
        let query = if yes {
            *rng.choice(&present)
        } else {
            loop {
                let e = lex_tok(rng, &v::ENTITIES);
                if !present.contains(&e) {
                    break e;
                }
            }
        };
        let mut prompt = vec![v::BOS];
        prompt.extend(&passage);
        prompt.push(v::Q);
        prompt.push(query);
        prompt.push(v::SEP);
        Example {
            prompt,
            options: vec![vec![v::V_YES], vec![v::V_NO]],
            gold: if yes { 0 } else { 1 },
            answer: vec![],
        }
    }
}

/// WSC: two entities; the AGREE marker follows the pronoun's true referent.
/// Query: does the pronoun refer to the queried entity?
pub struct WscLike;

impl Task for WscLike {
    fn name(&self) -> &'static str {
        "wsc"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Classification
    }
    fn chance(&self) -> f64 {
        0.5
    }
    fn pretrain_hint(&self) -> f64 {
        0.65
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 44).max(6);
        let e1 = lex_tok(rng, &v::ENTITIES);
        let e2 = loop {
            let e = lex_tok(rng, &v::ENTITIES);
            if e != e1 {
                break e;
            }
        };
        let referent_is_e1 = rng.bool(0.5);
        let gap1 = len / 3;
        let gap2 = len / 3;
        let mut sent = vec![e1];
        if referent_is_e1 {
            sent.push(v::AGREE);
        }
        sent.extend(filler(rng, gap1, VOCAB));
        sent.push(e2);
        if !referent_is_e1 {
            sent.push(v::AGREE);
        }
        sent.extend(filler(rng, gap2, VOCAB));
        sent.push(v::PRON);
        let query_e1 = rng.bool(0.5);
        let query = if query_e1 { e1 } else { e2 };
        let yes = query_e1 == referent_is_e1;
        let mut prompt = vec![v::BOS];
        prompt.extend(&sent);
        prompt.push(v::Q);
        prompt.push(query);
        prompt.push(v::SEP);
        Example {
            prompt,
            options: vec![vec![v::V_YES], vec![v::V_NO]],
            gold: if yes { 0 } else { 1 },
            answer: vec![],
        }
    }
}

/// WiC: a polysemous word appears in two contexts, each with a sense cue;
/// yes iff both cues come from the same sense class.
pub struct WicLike;

impl Task for WicLike {
    fn name(&self) -> &'static str {
        "wic"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Classification
    }
    fn chance(&self) -> f64 {
        0.5
    }
    fn pretrain_hint(&self) -> f64 {
        0.65
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 44).max(8);
        let half = len / 2;
        let w = lex_tok(rng, &v::POLYSEMOUS);
        let same = rng.bool(0.5);
        let sense1_a = rng.bool(0.5);
        let sense2_a = if same { sense1_a } else { !sense1_a };
        let cue = |rng: &mut Rng, is_a: bool| {
            if is_a {
                lex_tok(rng, &v::SENSE_A)
            } else {
                lex_tok(rng, &v::SENSE_B)
            }
        };
        let ctx = |rng: &mut Rng, is_a: bool, budget: usize| {
            let mut c = filler(rng, budget.saturating_sub(2), VOCAB);
            let pos = if c.is_empty() { 0 } else { rng.below(c.len() + 1) };
            c.insert(pos, w);
            c.insert(pos + 1, cue(rng, is_a));
            c
        };
        let c1 = ctx(rng, sense1_a, half);
        let c2 = ctx(rng, sense2_a, half);
        let mut prompt = vec![v::BOS];
        prompt.extend(&c1);
        prompt.push(v::SEP);
        prompt.extend(&c2);
        prompt.push(v::SEP);
        Example {
            prompt,
            options: vec![vec![v::V_YES], vec![v::V_NO]],
            gold: if same { 0 } else { 1 },
            answer: vec![],
        }
    }
}

/// MultiRC: passage of (entity, attribute) adjacent pairs; question asks
/// whether candidate attribute a is paired with entity e.
pub struct MultircLike;

impl Task for MultircLike {
    fn name(&self) -> &'static str {
        "multirc"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Classification
    }
    fn chance(&self) -> f64 {
        0.5
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 50).max(8);
        let n_pairs = (len / 6).clamp(2, 5);
        let mut ents = Vec::new();
        let mut attrs = Vec::new();
        for _ in 0..n_pairs {
            loop {
                let e = lex_tok(rng, &v::ENTITIES);
                if !ents.contains(&e) {
                    ents.push(e);
                    break;
                }
            }
            loop {
                let a = lex_tok(rng, &v::LEX_POS); // attributes drawn from a lexicon
                if !attrs.contains(&a) {
                    attrs.push(a);
                    break;
                }
            }
        }
        // passage: filler with (e_i, a_i) pairs embedded adjacently
        let fill_total = len.saturating_sub(2 * n_pairs);
        let mut passage = Vec::with_capacity(len);
        for i in 0..n_pairs {
            passage.extend(filler(rng, fill_total / n_pairs, VOCAB));
            passage.push(ents[i]);
            passage.push(attrs[i]);
        }
        let yes = rng.bool(0.5);
        let qi = rng.below(n_pairs);
        let (qe, qa) = if yes {
            (ents[qi], attrs[qi])
        } else {
            // mismatched pair (attribute from a different pair)
            let mut aj = rng.below(n_pairs);
            if aj == qi {
                aj = (aj + 1) % n_pairs;
            }
            (ents[qi], attrs[aj])
        };
        let mut prompt = vec![v::BOS];
        prompt.extend(&passage);
        prompt.push(v::Q);
        prompt.push(qe);
        prompt.push(qa);
        prompt.push(v::SEP);
        Example {
            prompt,
            options: vec![vec![v::V_YES], vec![v::V_NO]],
            gold: if yes { 0 } else { 1 },
            answer: vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Verify the planted rules are actually decodable from the tokens —
    /// i.e. a perfect model could reach 100%.
    #[test]
    fn sst2_rule_is_decodable() {
        let t = Sst2Like;
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            let pos = ex.prompt.iter().filter(|t| v::LEX_POS.contains(t)).count();
            let neg = ex.prompt.iter().filter(|t| v::LEX_NEG.contains(t)).count();
            let decoded = if pos > neg { 0 } else { 1 };
            assert_eq!(decoded, ex.gold, "pos={pos} neg={neg}");
        }
    }

    #[test]
    fn rte_rule_is_decodable() {
        let t = RteLike;
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            let qpos = ex.prompt.iter().position(|&t| t == v::Q).unwrap();
            let premise = &ex.prompt[1..qpos];
            let hyp = &ex.prompt[qpos + 1..ex.prompt.len() - 1];
            let subset = hyp.iter().all(|h| premise.contains(h));
            assert_eq!(if subset { 0 } else { 1 }, ex.gold);
        }
    }

    #[test]
    fn boolq_rule_is_decodable() {
        let t = BoolqLike;
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            let n = ex.prompt.len();
            let query = ex.prompt[n - 2];
            let passage = &ex.prompt[1..n - 3];
            let present = passage.contains(&query);
            assert_eq!(if present { 0 } else { 1 }, ex.gold);
        }
    }

    #[test]
    fn wsc_rule_is_decodable() {
        let t = WscLike;
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            let n = ex.prompt.len();
            let query = ex.prompt[n - 2];
            // referent = entity immediately followed by AGREE
            let agree_pos = ex.prompt.iter().position(|&t| t == v::AGREE).unwrap();
            let referent = ex.prompt[agree_pos - 1];
            assert_eq!(if query == referent { 0 } else { 1 }, ex.gold);
        }
    }

    #[test]
    fn wic_rule_is_decodable() {
        let t = WicLike;
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            let cues: Vec<bool> = ex
                .prompt
                .iter()
                .filter(|t| v::SENSE_A.contains(t) || v::SENSE_B.contains(t))
                .map(|t| v::SENSE_A.contains(t))
                .collect();
            assert_eq!(cues.len(), 2, "exactly two cues");
            assert_eq!(if cues[0] == cues[1] { 0 } else { 1 }, ex.gold);
        }
    }

    #[test]
    fn multirc_rule_is_decodable() {
        let t = MultircLike;
        let mut rng = Rng::new(6);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            let n = ex.prompt.len();
            let (qe, qa) = (ex.prompt[n - 3], ex.prompt[n - 2]);
            let passage = &ex.prompt[1..n - 4];
            let paired = passage.windows(2).any(|w| w[0] == qe && w[1] == qa);
            assert_eq!(if paired { 0 } else { 1 }, ex.gold);
        }
    }

    #[test]
    fn cb_three_classes_all_emitted() {
        let t = CbLike;
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 3];
        for _ in 0..300 {
            counts[t.gen(&mut rng, 20).gold] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            assert!(*c > 50, "class {i}: {c}");
        }
    }

    #[test]
    fn wsc_agree_marker_present_exactly_once() {
        let t = WscLike;
        let mut rng = Rng::new(8);
        for _ in 0..100 {
            let ex = t.gen(&mut rng, 16);
            let n = ex.prompt.iter().filter(|&&t| t == v::AGREE).count();
            assert_eq!(n, 1);
        }
    }
}

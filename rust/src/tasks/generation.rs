//! Generation tasks: SQuAD-like extraction (copy the marked span) and
//! DROP-like discrete reasoning (count the markers). Teacher-forced
//! evaluation: the model predicts the answer tokens after the ANS marker;
//! the metric is token F1 (and exact match).

use super::{content_len, filler, Example, Task, TaskKind};
use crate::data::vocab as v;
use crate::rng::Rng;

const VOCAB: usize = 512;

/// SQuAD: the passage embeds `MARK` followed by a 1-3 token span; the
/// question asks for that span (pure extraction — an attention copy task).
pub struct SquadLike;

impl Task for SquadLike {
    fn name(&self) -> &'static str {
        "squad"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Generation
    }
    fn chance(&self) -> f64 {
        0.0
    }
    fn pretrain_hint(&self) -> f64 {
        0.8
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 48).max(8);
        let span_len = rng.range(1, 3);
        let span: Vec<u32> = (0..span_len)
            .map(|_| v::ENTITIES.start + rng.below((v::ENTITIES.end - v::ENTITIES.start) as usize) as u32)
            .collect();
        let mut passage = filler(rng, len.saturating_sub(span_len + 1), VOCAB);
        let pos = rng.below(passage.len() + 1);
        let mut with_span = passage.split_off(pos);
        passage.push(v::MARK);
        passage.extend(&span);
        passage.append(&mut with_span);
        let mut prompt = vec![v::BOS];
        prompt.extend(&passage);
        prompt.push(v::Q);
        prompt.push(v::MARK);
        prompt.push(v::ANS);
        let mut answer = span;
        answer.push(v::EOS);
        Example { prompt, options: vec![], gold: 0, answer }
    }
}

/// DROP: the passage contains 1..=5 MARK tokens; the answer is the count as
/// a digit verbalizer.
pub struct DropLike;

impl Task for DropLike {
    fn name(&self) -> &'static str {
        "drop"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::Generation
    }
    fn chance(&self) -> f64 {
        0.0
    }
    fn pretrain_hint(&self) -> f64 {
        0.7
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 50).max(10);
        let count = rng.range(1, 5);
        let mut passage = filler(rng, len.saturating_sub(count), VOCAB);
        for i in rng.sample_indices(passage.len(), count.min(passage.len())) {
            passage[i] = v::MARK;
        }
        let mut prompt = vec![v::BOS];
        prompt.extend(&passage);
        prompt.push(v::Q);
        prompt.push(v::MARK);
        prompt.push(v::ANS);
        Example { prompt, options: vec![], gold: 0, answer: vec![v::digit(count), v::EOS] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squad_answer_is_the_marked_span() {
        let t = SquadLike;
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            // find MARK inside the passage (not the one in the question tail)
            let body = &ex.prompt[..ex.prompt.len() - 3];
            let mpos = body.iter().position(|&t| t == v::MARK).unwrap();
            let span_len = ex.answer.len() - 1; // strip EOS
            let span = &body[mpos + 1..mpos + 1 + span_len];
            assert_eq!(span, &ex.answer[..span_len]);
            assert_eq!(*ex.answer.last().unwrap(), v::EOS);
            assert!(span.iter().all(|t| v::ENTITIES.contains(t)));
        }
    }

    #[test]
    fn drop_answer_counts_marks() {
        let t = DropLike;
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            let body = &ex.prompt[..ex.prompt.len() - 3];
            let count = body.iter().filter(|&&t| t == v::MARK).count();
            assert!(count >= 1);
            assert_eq!(ex.answer[0], v::digit(count));
        }
    }

    #[test]
    fn generation_examples_have_no_options() {
        let mut rng = Rng::new(3);
        for task in [&SquadLike as &dyn Task, &DropLike] {
            let ex = task.gen(&mut rng, 16);
            assert!(ex.options.is_empty());
            assert!(!ex.answer.is_empty());
            // train instance predicts the answer tokens
            let ti = ex.train_instance();
            assert_eq!(ti.continuation, ex.answer);
        }
    }

    #[test]
    fn drop_count_distribution_covers_range() {
        let t = DropLike;
        let mut rng = Rng::new(4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            let ex = t.gen(&mut rng, 30);
            seen.insert(ex.answer[0]);
        }
        assert!(seen.len() >= 4, "count diversity: {seen:?}");
    }
}

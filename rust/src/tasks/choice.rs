//! Multiple-choice tasks: Copa-like (continuation plausibility) and
//! ReCoRD-like (cloze over passage entities). Options are multi-token
//! continuations scored by per-option mean LM loss, exactly as MeZO
//! evaluates multiple-choice SuperGLUE tasks.

use super::{content_len, filler, Example, Task, TaskKind};
use crate::data::vocab as v;
use crate::rng::Rng;

const VOCAB: usize = 512;

/// Copa: premise drawn from one topic group; the correct continuation
/// shares the topic, the distractor comes from another topic.
pub struct CopaLike;

impl Task for CopaLike {
    fn name(&self) -> &'static str {
        "copa"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::MultipleChoice
    }
    fn chance(&self) -> f64 {
        0.5
    }
    fn pretrain_hint(&self) -> f64 {
        0.75
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 40).max(6);
        let topic = rng.below(v::N_TOPICS);
        let wrong_topic = (topic + 1 + rng.below(v::N_TOPICS - 1)) % v::N_TOPICS;
        let topic_tok = |rng: &mut Rng, t: usize| {
            let r = v::topic_tokens(t);
            r.start + rng.below(v::TOPIC_WIDTH) as u32
        };
        // premise: half topic tokens, half filler
        let k_topic = (len / 2).clamp(2, 8);
        let mut premise: Vec<u32> = (0..k_topic).map(|_| topic_tok(rng, topic)).collect();
        premise.extend(filler(rng, len - k_topic, VOCAB));
        rng.shuffle(&mut premise);
        // continuations: 4 tokens topic-pure + EOS
        let cont = |rng: &mut Rng, t: usize| -> Vec<u32> {
            let mut c: Vec<u32> = (0..4).map(|_| topic_tok(rng, t)).collect();
            c.push(v::EOS);
            c
        };
        let good = cont(rng, topic);
        let bad = cont(rng, wrong_topic);
        let gold = rng.below(2);
        let options = if gold == 0 { vec![good, bad] } else { vec![bad, good] };
        let mut prompt = vec![v::BOS];
        prompt.extend(&premise);
        prompt.push(v::SEP);
        Example { prompt, options, gold, answer: vec![] }
    }
}

/// ReCoRD: passage mentions several entities; exactly one is adjacent to
/// the MARK token. Cloze: which entity was marked? Options are the
/// passage's entities.
pub struct RecordLike;

impl Task for RecordLike {
    fn name(&self) -> &'static str {
        "record"
    }
    fn kind(&self) -> TaskKind {
        TaskKind::MultipleChoice
    }
    fn chance(&self) -> f64 {
        0.25
    }

    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example {
        let len = content_len(rng, mean_len, 48).max(12);
        let n_ents = 4usize;
        let mut ents = Vec::with_capacity(n_ents);
        while ents.len() < n_ents {
            let e = v::ENTITIES.start + rng.below((v::ENTITIES.end - v::ENTITIES.start) as usize) as u32;
            if !ents.contains(&e) {
                ents.push(e);
            }
        }
        let starred = rng.below(n_ents);
        // passage: each entity embedded in filler; the starred one gets MARK
        let seg = (len / n_ents).max(2);
        let mut passage = Vec::with_capacity(len + n_ents * 2);
        for (i, &e) in ents.iter().enumerate() {
            passage.extend(filler(rng, seg.saturating_sub(2), VOCAB));
            if i == starred {
                passage.push(v::MARK);
            }
            passage.push(e);
        }
        let mut prompt = vec![v::BOS];
        prompt.extend(&passage);
        prompt.push(v::Q);
        prompt.push(v::MARK);
        prompt.push(v::SEP);
        let options: Vec<Vec<u32>> = ents.iter().map(|&e| vec![e]).collect();
        Example { prompt, options, gold: starred, answer: vec![] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copa_gold_shares_topic_with_premise() {
        let t = CopaLike;
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 20);
            assert_eq!(ex.options.len(), 2);
            // find the premise's dominant topic
            let topic_of = |tok: u32| -> Option<usize> {
                if (v::TOPIC_BASE..v::FILLER_BASE).contains(&tok) {
                    Some(((tok - v::TOPIC_BASE) as usize) / v::TOPIC_WIDTH)
                } else {
                    None
                }
            };
            let mut counts = [0usize; v::N_TOPICS];
            for &tok in &ex.prompt {
                if let Some(t) = topic_of(tok) {
                    counts[t] += 1;
                }
            }
            let premise_topic = counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0;
            let gold_topic = topic_of(ex.options[ex.gold][0]).unwrap();
            let other_topic = topic_of(ex.options[1 - ex.gold][0]).unwrap();
            assert_eq!(gold_topic, premise_topic);
            assert_ne!(other_topic, premise_topic);
        }
    }

    #[test]
    fn copa_options_end_with_eos() {
        let t = CopaLike;
        let mut rng = Rng::new(2);
        let ex = t.gen(&mut rng, 16);
        for o in &ex.options {
            assert_eq!(*o.last().unwrap(), v::EOS);
        }
    }

    #[test]
    fn record_marked_entity_is_gold() {
        let t = RecordLike;
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let ex = t.gen(&mut rng, 24);
            assert_eq!(ex.options.len(), 4);
            // the entity right after MARK inside the passage is the answer
            let body = &ex.prompt[..ex.prompt.len() - 3]; // strip Q MARK SEP
            let mpos = body.iter().position(|&t| t == v::MARK).unwrap();
            let marked = body[mpos + 1];
            assert_eq!(vec![marked], ex.options[ex.gold]);
        }
    }

    #[test]
    fn record_gold_uniform_over_positions() {
        let t = RecordLike;
        let mut rng = Rng::new(4);
        let mut counts = [0usize; 4];
        for _ in 0..400 {
            counts[t.gen(&mut rng, 20).gold] += 1;
        }
        for c in counts {
            assert!(c > 60, "{counts:?}");
        }
    }
}

//! Synthetic task suite mirroring the paper's evaluation: SuperGLUE-shaped
//! classification, multiple choice, and extractive/counting generation.
//!
//! Each task plants a latent rule in token space (see data::vocab for the
//! semantic regions) and exposes the MeZO-style interface: a prompt whose
//! continuation is scored by LM loss. Task difficulty and mean input length
//! are controlled so the paper's axes (Fig. 3 sparsity, Fig. 6 length) can
//! be swept causally.

pub mod choice;
pub mod classification;
pub mod generation;

use crate::data::batch::Instance;
use crate::rng::Rng;
use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Fixed verbalizer set; metric = accuracy.
    Classification,
    /// Example-specific candidate continuations; metric = accuracy.
    MultipleChoice,
    /// Free-form answer; metric = token F1 (teacher-forced).
    Generation,
}

/// One evaluation example.
#[derive(Debug, Clone)]
pub struct Example {
    /// Tokens up to (and including) the position whose continuation is
    /// predicted (ends with SEP / ANS marker).
    pub prompt: Vec<u32>,
    /// Candidate continuations (classification: verbalizers; choice:
    /// multi-token endings; generation: empty).
    pub options: Vec<Vec<u32>>,
    /// Index of the correct option (classification / choice).
    pub gold: usize,
    /// Gold answer tokens (generation only).
    pub answer: Vec<u32>,
}

impl Example {
    /// Training instance: prompt + gold continuation.
    pub fn train_instance(&self) -> Instance {
        let continuation = if self.options.is_empty() {
            self.answer.clone()
        } else {
            self.options[self.gold].clone()
        };
        Instance { prompt: self.prompt.clone(), continuation }
    }

    /// Scoring instances, one per option.
    pub fn option_instances(&self) -> Vec<Instance> {
        self.options
            .iter()
            .map(|opt| Instance { prompt: self.prompt.clone(), continuation: opt.clone() })
            .collect()
    }
}

pub trait Task {
    fn name(&self) -> &'static str;
    fn kind(&self) -> TaskKind;
    /// Generate one example with ~mean_len content tokens.
    fn gen(&self, rng: &mut Rng, mean_len: usize) -> Example;
    /// Chance accuracy (for sanity assertions and table context).
    fn chance(&self) -> f64;
    /// How strongly the pretraining corpus hints at the rule (0.5 = none).
    fn pretrain_hint(&self) -> f64 {
        0.70
    }
}

/// Names of all tasks, in the paper's Table-2 order.
pub const ALL_TASKS: [&str; 11] = [
    "sst2", "rte", "cb", "boolq", "wsc", "wic", "multirc", "copa", "record", "squad", "drop",
];

/// The Table-1 subset (8 tasks).
pub const TABLE1_TASKS: [&str; 8] =
    ["sst2", "rte", "cb", "boolq", "wsc", "wic", "copa", "squad"];

pub fn make_task(name: &str) -> Result<Box<dyn Task>> {
    Ok(match name {
        "sst2" => Box::new(classification::Sst2Like),
        "rte" => Box::new(classification::RteLike),
        "cb" => Box::new(classification::CbLike),
        "boolq" => Box::new(classification::BoolqLike),
        "wsc" => Box::new(classification::WscLike),
        "wic" => Box::new(classification::WicLike),
        "multirc" => Box::new(classification::MultircLike),
        "copa" => Box::new(choice::CopaLike),
        "record" => Box::new(choice::RecordLike),
        "squad" => Box::new(generation::SquadLike),
        "drop" => Box::new(generation::DropLike),
        _ => bail!("unknown task '{name}' (one of {:?})", ALL_TASKS),
    })
}

/// Deterministic eval set for (task, seed): same examples for every method,
/// as in the paper's fixed test extraction.
pub fn eval_set(task: &dyn Task, seed: u64, n: usize, mean_len: usize) -> Vec<Example> {
    let mut rng = Rng::new(crate::rng::derive(seed, crate::rng::purpose::EVAL, 0));
    (0..n).map(|_| task.gen(&mut rng, mean_len)).collect()
}

/// Sample a content length around the mean (uniform ±25%, floor 4).
pub(crate) fn content_len(rng: &mut Rng, mean_len: usize, max: usize) -> usize {
    let lo = (mean_len * 3 / 4).max(4);
    let hi = (mean_len * 5 / 4).max(lo + 1).min(max);
    rng.range(lo.min(max), hi)
}

/// Fill with random filler tokens.
pub(crate) fn filler(rng: &mut Rng, n: usize, vocab: usize) -> Vec<u32> {
    let r = crate::data::vocab::filler_range(vocab);
    (0..n).map(|_| r.start + rng.below((r.end - r.start) as usize) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab;

    const VOCAB: usize = 512;
    const MAX_TOTAL: usize = 64;

    #[test]
    fn registry_covers_all_tasks() {
        for name in ALL_TASKS {
            let t = make_task(name).unwrap();
            assert_eq!(t.name(), name);
        }
        assert!(make_task("nope").is_err());
    }

    #[test]
    fn examples_fit_the_largest_bucket() {
        // property sweep: every task, several lengths/seeds, must fit 64 tokens
        for name in ALL_TASKS {
            let t = make_task(name).unwrap();
            let mut rng = Rng::new(1);
            for mean_len in [8, 16, 24, 40] {
                for _ in 0..50 {
                    let ex = t.gen(&mut rng, mean_len);
                    let ti = ex.train_instance();
                    assert!(
                        ti.total_len() <= MAX_TOTAL,
                        "{name} mean={mean_len}: train len {}",
                        ti.total_len()
                    );
                    for oi in ex.option_instances() {
                        assert!(oi.total_len() <= MAX_TOTAL, "{name}: option too long");
                    }
                }
            }
        }
    }

    #[test]
    fn gold_indices_valid_and_tokens_in_vocab() {
        for name in ALL_TASKS {
            let t = make_task(name).unwrap();
            let mut rng = Rng::new(2);
            for _ in 0..100 {
                let ex = t.gen(&mut rng, 20);
                if !ex.options.is_empty() {
                    assert!(ex.gold < ex.options.len(), "{name}");
                } else {
                    assert!(!ex.answer.is_empty(), "{name}: generation needs an answer");
                }
                for &tok in ex
                    .prompt
                    .iter()
                    .chain(ex.options.iter().flatten())
                    .chain(ex.answer.iter())
                {
                    assert!((tok as usize) < VOCAB, "{name}: token {tok} out of vocab");
                }
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        // classification tasks should emit each class a reasonable fraction
        for name in ["sst2", "rte", "boolq", "wsc", "wic", "multirc"] {
            let t = make_task(name).unwrap();
            let mut rng = Rng::new(3);
            let n = 600;
            let ones = (0..n).filter(|_| t.gen(&mut rng, 20).gold == 1).count();
            let frac = ones as f64 / n as f64;
            assert!((0.3..=0.7).contains(&frac), "{name}: class-1 frac {frac}");
        }
    }

    #[test]
    fn eval_set_is_deterministic() {
        let t = make_task("sst2").unwrap();
        let a = eval_set(t.as_ref(), 9, 20, 16);
        let b = eval_set(t.as_ref(), 9, 20, 16);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.gold, y.gold);
        }
        let c = eval_set(t.as_ref(), 10, 20, 16);
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn mean_length_is_controlled() {
        // Fig. 6 axis: generated prompt length must track mean_len
        let t = make_task("sst2").unwrap();
        let mut rng = Rng::new(4);
        let mut lens = vec![];
        for _ in 0..200 {
            lens.push(t.gen(&mut rng, 32).prompt.len() as f64);
        }
        let m = crate::stats::mean(&lens);
        assert!((28.0..=44.0).contains(&m), "mean prompt len {m}");
        let mut rng = Rng::new(4);
        let mut short = vec![];
        for _ in 0..200 {
            short.push(t.gen(&mut rng, 10).prompt.len() as f64);
        }
        assert!(crate::stats::mean(&short) < m - 10.0);
    }

    #[test]
    fn prompts_end_with_separator_or_ans() {
        for name in ALL_TASKS {
            let t = make_task(name).unwrap();
            let mut rng = Rng::new(5);
            let ex = t.gen(&mut rng, 16);
            let last = *ex.prompt.last().unwrap();
            assert!(
                last == vocab::SEP || last == vocab::ANS,
                "{name}: prompt ends with {last}"
            );
            assert_eq!(ex.prompt[0], vocab::BOS, "{name}: prompt starts with BOS");
        }
    }
}

//! Small statistics toolkit used by eval and the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp: NaNs sort to the ends instead of panicking mid-run
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Accuracy over (prediction, gold) pairs.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hits as f64 / pred.len() as f64
}

/// Token-level (bag-of-tokens) F1 between predicted and gold token lists —
/// the SQuAD/DROP metric.
pub fn token_f1(pred: &[u32], gold: &[u32]) -> f64 {
    if pred.is_empty() && gold.is_empty() {
        return 1.0;
    }
    if pred.is_empty() || gold.is_empty() {
        return 0.0;
    }
    // multiset intersection
    let mut gold_counts = std::collections::HashMap::new();
    for &g in gold {
        *gold_counts.entry(g).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for &p in pred {
        if let Some(c) = gold_counts.get_mut(&p) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / pred.len() as f64;
    let recall = overlap as f64 / gold.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

/// Exact match between token lists.
pub fn exact_match(pred: &[u32], gold: &[u32]) -> f64 {
    if pred == gold {
        1.0
    } else {
        0.0
    }
}

/// Online mean/std accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Format "mean±std" with one decimal, matching the paper's tables.
pub fn fmt_mean_std(values: &[f64]) -> String {
    format!("{:.1}±{:.1}", 100.0 * mean(values), 100.0 * std(values))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - 1.2909944487).abs() < 1e-9);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[0], &[0]), 1.0);
    }

    #[test]
    fn f1_identical() {
        assert_eq!(token_f1(&[1, 2, 3], &[1, 2, 3]), 1.0);
    }

    #[test]
    fn f1_disjoint() {
        assert_eq!(token_f1(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // pred {1,2}, gold {2,3}: overlap 1, p=0.5, r=0.5, f1=0.5
        assert!((token_f1(&[1, 2], &[2, 3]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_multiset_semantics() {
        // pred has 2 copies of token 7, gold has 1: overlap is 1, not 2
        let f1 = token_f1(&[7, 7], &[7]);
        let p = 0.5;
        let r = 1.0;
        assert!((f1 - 2.0 * p * r / (p + r)).abs() < 1e-12);
    }

    #[test]
    fn f1_empty_conventions() {
        assert_eq!(token_f1(&[], &[]), 1.0);
        assert_eq!(token_f1(&[1], &[]), 0.0);
        assert_eq!(token_f1(&[], &[1]), 0.0);
    }

    #[test]
    fn exact_match_works() {
        assert_eq!(exact_match(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(exact_match(&[1, 2], &[2, 1]), 0.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [0.5, 1.5, 2.5, 9.0, -3.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn fmt_matches_paper_style() {
        assert_eq!(fmt_mean_std(&[0.914, 0.912, 0.910]), "91.2±0.2");
    }
}

//! ModelSpec: the architecture contract, independent of any artifact dir.
//!
//! Mirrors `python/compile/configs.py` (size presets) and the flat unit
//! layout of `python/compile/model.py`:
//!
//! ```text
//!   unit 0:            embedding  = [tok_emb (V,D) | pos_emb (S,D)]
//!   units 1..n_layers: block      = [ln1_g, ln1_b, Wq, bq, Wk, bk, Wv, bv,
//!                                    Wo, bo, ln2_g, ln2_b, W1, b1, W2, b2]
//!   unit n_layers+1:   final LN   = [lnf_g, lnf_b]
//! ```
//!
//! The PJRT backend derives its spec from the artifact manifest; the native
//! backend builds it from a preset — both feed the same backend-generic
//! trainer, so shape logic lives here exactly once.

use crate::rng::{derive, purpose, Rng};
use anyhow::{bail, ensure, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub seq_buckets: Vec<usize>,
    pub train_batch: usize,
    pub eval_batch: usize,
}

impl ModelSpec {
    /// Size presets, kept in sync with `python/compile/configs.py`.
    /// `opt-nano` is a rust-side extra: small enough for debug-mode tests.
    pub fn preset(name: &str) -> Result<ModelSpec> {
        let (vocab, d_model, n_layers, n_heads, train_batch, eval_batch) = match name {
            "opt-nano" => (512, 32, 2, 2, 4, 8),
            "opt-micro" => (512, 64, 4, 4, 8, 16),
            "opt-tiny" => (2048, 128, 6, 8, 8, 16),
            "opt-small" => (4096, 256, 8, 8, 8, 16),
            "opt-base" => (16384, 768, 12, 12, 4, 8),
            _ => bail!(
                "unknown model preset '{name}' (opt-nano|opt-micro|opt-tiny|opt-small|opt-base)"
            ),
        };
        let seq_buckets =
            if name == "opt-base" { vec![32, 64] } else { vec![16, 32, 64] };
        let spec = ModelSpec {
            name: name.to_string(),
            vocab,
            d_model,
            n_layers,
            n_heads,
            max_seq: 64,
            seq_buckets,
            train_batch,
            eval_batch,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Derive the spec from a loaded artifact manifest (PJRT path).
    pub fn from_manifest(m: &crate::model::Manifest) -> ModelSpec {
        ModelSpec {
            name: m.name.clone(),
            vocab: m.vocab,
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            max_seq: m.max_seq,
            seq_buckets: m.seq_buckets.clone(),
            train_batch: m.train_batch,
            eval_batch: m.eval_batch,
        }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.vocab >= 512, "vocab must be >= 512 (vocab layout contract)");
        ensure!(self.n_heads > 0 && self.d_model % self.n_heads == 0, "heads must divide d_model");
        ensure!(self.n_layers > 0, "need at least one block");
        ensure!(!self.seq_buckets.is_empty(), "need at least one sequence bucket");
        ensure!(
            self.seq_buckets.iter().all(|&b| b <= self.max_seq),
            "seq bucket exceeds max_seq"
        );
        Ok(())
    }

    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn n_units(&self) -> usize {
        self.n_layers + 2
    }

    /// Flat length of the embedding unit: tok_emb (V,D) | pos_emb (S,D).
    pub fn embed_len(&self) -> usize {
        (self.vocab + self.max_seq) * self.d_model
    }

    /// Flat length of one transformer-block unit.
    pub fn block_len(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ff();
        // 2 LN (g+b), 4 attn mats + biases, 2 MLP mats + biases
        4 * d * d + 2 * d * f + f + 9 * d
    }

    /// Flat length of the final-LN unit.
    pub fn final_len(&self) -> usize {
        2 * self.d_model
    }

    pub fn unit_lens(&self) -> Vec<usize> {
        let mut lens = Vec::with_capacity(self.n_units());
        lens.push(self.embed_len());
        lens.extend(std::iter::repeat(self.block_len()).take(self.n_layers));
        lens.push(self.final_len());
        lens
    }

    pub fn unit_names(&self) -> Vec<String> {
        let mut names = Vec::with_capacity(self.n_units());
        names.push("embed".to_string());
        names.extend((0..self.n_layers).map(|i| format!("block_{i}")));
        names.push("final_ln".to_string());
        names
    }

    pub fn param_count(&self) -> usize {
        self.unit_lens().iter().sum()
    }

    /// Indices of transformer-block units (the sparsifiable set under the
    /// paper's policy; unit 0 is the embedding, the last unit the final LN).
    pub fn block_unit_indices(&self) -> Vec<usize> {
        (1..=self.n_layers).collect()
    }

    /// Smallest bucket that fits `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Result<usize> {
        self.seq_buckets
            .iter()
            .copied()
            .filter(|&b| b >= len)
            .min()
            .ok_or_else(|| anyhow::anyhow!("sequence length {len} exceeds largest bucket"))
    }

    /// GPT-2/OPT-style init, mirroring `model.py::init_units`: N(0, 0.02)
    /// weights, zero biases, unit gammas, residual-out projections (wo, w2)
    /// scaled by 1/sqrt(2*n_layers). Deterministic per (spec, seed); drawn
    /// from the coordinator RNG, so native-backend runs need no artifacts.
    pub fn init_units(&self, seed: u64) -> Vec<Vec<f32>> {
        let d = self.d_model;
        let f = self.d_ff();
        let resid_scale = 1.0 / (2.0 * self.n_layers as f64).sqrt();
        let mut rng = Rng::new(derive(seed, purpose::INIT, 0x11A7));
        let mut gauss = |n: usize, scale: f64, out: &mut Vec<f32>| {
            out.extend((0..n).map(|_| (rng.gaussian() * 0.02 * scale) as f32));
        };

        let mut units = Vec::with_capacity(self.n_units());

        // embedding: tok_emb then pos_emb, both N(0, 0.02)
        let mut emb = Vec::with_capacity(self.embed_len());
        gauss(self.embed_len(), 1.0, &mut emb);
        units.push(emb);

        for _ in 0..self.n_layers {
            let mut u = Vec::with_capacity(self.block_len());
            u.extend(std::iter::repeat(1.0f32).take(d)); // ln1_g
            u.extend(std::iter::repeat(0.0f32).take(d)); // ln1_b
            gauss(d * d, 1.0, &mut u); // wq
            u.extend(std::iter::repeat(0.0f32).take(d)); // bq
            gauss(d * d, 1.0, &mut u); // wk
            u.extend(std::iter::repeat(0.0f32).take(d)); // bk
            gauss(d * d, 1.0, &mut u); // wv
            u.extend(std::iter::repeat(0.0f32).take(d)); // bv
            gauss(d * d, resid_scale, &mut u); // wo
            u.extend(std::iter::repeat(0.0f32).take(d)); // bo
            u.extend(std::iter::repeat(1.0f32).take(d)); // ln2_g
            u.extend(std::iter::repeat(0.0f32).take(d)); // ln2_b
            gauss(d * f, 1.0, &mut u); // w1
            u.extend(std::iter::repeat(0.0f32).take(f)); // b1
            gauss(f * d, resid_scale, &mut u); // w2
            u.extend(std::iter::repeat(0.0f32).take(d)); // b2
            debug_assert_eq!(u.len(), self.block_len());
            units.push(u);
        }

        let mut fin = Vec::with_capacity(self.final_len());
        fin.extend(std::iter::repeat(1.0f32).take(d)); // lnf_g
        fin.extend(std::iter::repeat(0.0f32).take(d)); // lnf_b
        units.push(fin);
        units
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist_and_validate() {
        for name in ["opt-nano", "opt-micro", "opt-tiny", "opt-small", "opt-base"] {
            let s = ModelSpec::preset(name).unwrap();
            assert_eq!(s.name, name);
            assert_eq!(s.n_units(), s.n_layers + 2);
            assert_eq!(s.unit_lens().len(), s.n_units());
            assert_eq!(s.unit_names().len(), s.n_units());
        }
        assert!(ModelSpec::preset("opt-giga").is_err());
    }

    #[test]
    fn param_count_matches_configs_py_formula() {
        // configs.py: block = 4dd + 4d + 2df + f + d + 4d; total =
        // (V + S) * d + n_layers * block + 2d
        for name in ["opt-micro", "opt-tiny", "opt-small", "opt-base"] {
            let s = ModelSpec::preset(name).unwrap();
            let (d, f) = (s.d_model, s.d_ff());
            let block = 4 * d * d + 4 * d + 2 * d * f + f + d + 4 * d;
            let want = (s.vocab + s.max_seq) * d + s.n_layers * block + 2 * d;
            assert_eq!(s.param_count(), want, "{name}");
        }
    }

    #[test]
    fn micro_matches_manifest_scale() {
        // opt-micro dims pinned to configs.py
        let s = ModelSpec::preset("opt-micro").unwrap();
        assert_eq!((s.vocab, s.d_model, s.n_layers, s.n_heads), (512, 64, 4, 4));
        assert_eq!(s.seq_buckets, vec![16, 32, 64]);
        assert_eq!(s.bucket_for(17).unwrap(), 32);
        assert!(s.bucket_for(65).is_err());
    }

    #[test]
    fn init_units_layout_and_statistics() {
        let s = ModelSpec::preset("opt-nano").unwrap();
        let units = s.init_units(0);
        assert_eq!(units.len(), s.n_units());
        for (u, len) in units.iter().zip(s.unit_lens()) {
            assert_eq!(u.len(), len);
        }
        // ln gammas are exactly 1, biases exactly 0
        let d = s.d_model;
        let block = &units[1];
        assert!(block[..d].iter().all(|&x| x == 1.0), "ln1_g");
        assert!(block[d..2 * d].iter().all(|&x| x == 0.0), "ln1_b");
        // final unit: gammas then betas
        let fin = units.last().unwrap();
        assert!(fin[..d].iter().all(|&x| x == 1.0));
        assert!(fin[d..].iter().all(|&x| x == 0.0));
        // embedding is N(0, 0.02): sane statistics
        let emb = &units[0];
        let mean = emb.iter().map(|&x| x as f64).sum::<f64>() / emb.len() as f64;
        let var = emb.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / emb.len() as f64;
        assert!(mean.abs() < 2e-3, "mean={mean}");
        assert!((var.sqrt() - 0.02).abs() < 2e-3, "std={}", var.sqrt());
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let s = ModelSpec::preset("opt-nano").unwrap();
        assert_eq!(s.init_units(1), s.init_units(1));
        assert_ne!(s.init_units(1)[0], s.init_units(2)[0]);
    }
}

//! Checkpoint I/O: own binary format (no serde offline).
//!
//! Two formats share the `LEZOCKPT` magic and differ by version:
//!
//! Version 1 — plain parameter checkpoint (`pretrained.ckpt`, `checkpoint=`):
//!   magic  [8]  b"LEZOCKPT"
//!   version u32 (= 1)
//!   step    u64
//!   n_units u32
//!   lens    [n_units] u64
//!   data    concat of f32 unit vectors
//!   crc     u32 (crc32 of data bytes)
//!
//! Version 2 — [`TrainState`] resume envelope (`train_state.ckpt`): the full
//! mid-run training state. Because perturbations are regenerated from
//! `zo_probe_seed(run_seed, step, probe, unit)` and the optimizer zoo keeps
//! seed-replay scalar history only, the envelope is RNG-free by construction:
//! params + step + per-step scalars are enough to resume bit-identically.
//!   magic  [8]  b"LEZOCKPT"
//!   version u32 (= 2)
//!   n_sections u32 (= 7)
//!   then, per section: tag [4] | len u64 | payload | crc u32 (of payload)
//!   sections in order: META PARM LOSS GRAD SKIP HIST FOPT
//!
//! All writes go through [`write_atomic`] (temp file + fsync + rename +
//! parent-dir fsync), so a crash mid-write can never leave a torn file under
//! the real name — at worst a stale `*.tmp` that the next save overwrites.

use anyhow::{anyhow, bail, ensure, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"LEZOCKPT";
const VERSION: u32 = 1;
/// Version tag of the [`TrainState`] resume envelope.
pub const STATE_VERSION: u32 = 2;
const STATE_SECTIONS: u32 = 7;

/// CRC-32 (IEEE), bit-reflected, table-free (fine for checkpoint sizes).
fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Temp-file sibling used by [`write_atomic`] (`<name>.tmp` in the same dir,
/// so the final `rename` never crosses a filesystem boundary). Public so the
/// fault-injection harness can plant a torn temp file where a mid-save crash
/// would leave one.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Crash-safe file write: temp file in the same directory, `fsync`, `rename`
/// over the target, then `fsync` the parent directory so the rename itself is
/// durable. Readers only ever see the old bytes or the new bytes, never a mix.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    std::fs::create_dir_all(&dir).ok();
    let tmp = tmp_path(path);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    // Directory fsync is best-effort: opening a directory read-only works on
    // unix; elsewhere the rename is already the strongest primitive we have.
    if let Ok(d) = std::fs::File::open(&dir) {
        d.sync_all().ok();
    }
    Ok(())
}

/// Byte cursor over a fully-read file: every short read is a clean error
/// naming the absolute byte offset, never a panic.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
    label: String,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8], label: String) -> Self {
        Cur { buf, off: 0, label }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let have = self.buf.len() - self.off;
        ensure!(
            n <= have,
            "{}: truncated at byte offset {} (need {n} more bytes, {have} left of {})",
            self.label,
            self.off,
            self.buf.len()
        );
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Element count * width with overflow checked against the file size, so a
    /// corrupt length field errors instead of attempting a huge allocation.
    fn sized(&mut self, n: usize, width: usize) -> Result<&'a [u8]> {
        let bytes = n
            .checked_mul(width)
            .ok_or_else(|| anyhow!("{}: implausible element count {n}", self.label))?;
        self.take(bytes)
    }
}

fn f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

fn f64s(bytes: &[u8]) -> Vec<f64> {
    bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect()
}

fn u64s(bytes: &[u8]) -> Vec<u64> {
    bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect()
}

#[derive(Debug)]
pub struct Checkpoint {
    pub step: u64,
    pub units: Vec<Vec<f32>>,
}

pub fn save(path: &Path, step: u64, units: &[Vec<f32>]) -> Result<()> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&step.to_le_bytes());
    out.extend_from_slice(&(units.len() as u32).to_le_bytes());
    for u in units {
        out.extend_from_slice(&(u.len() as u64).to_le_bytes());
    }
    let data_start = out.len();
    for u in units {
        for &x in u {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    let crc = crc32(&out[data_start..]);
    out.extend_from_slice(&crc.to_le_bytes());
    write_atomic(path, &out)
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    let mut cur = Cur::new(&bytes, path.display().to_string());
    let magic = cur.take(8)?;
    ensure!(magic == MAGIC, "{}: not a LeZO checkpoint", path.display());
    let version = cur.u32()?;
    ensure!(version == VERSION, "unsupported checkpoint version {version}");
    let step = cur.u64()?;
    let n_units = cur.u32()? as usize;
    ensure!(n_units < 10_000, "implausible unit count {n_units}");
    let lens: Vec<usize> = u64s(cur.sized(n_units, 8)?).iter().map(|&l| l as usize).collect();
    let total: usize = lens.iter().try_fold(0usize, |acc, &l| acc.checked_add(l)).ok_or_else(
        || anyhow!("{}: implausible unit lengths", path.display()),
    )?;
    let data_bytes = cur.sized(total, 4)?;
    let want_crc = cur.u32()?;
    let got_crc = crc32(data_bytes);
    ensure!(
        want_crc == got_crc,
        "{}: checksum mismatch (corrupt checkpoint)",
        path.display()
    );
    let data = f32s(data_bytes);
    let mut units = Vec::with_capacity(n_units);
    let mut off = 0usize;
    for len in lens {
        units.push(data[off..off + len].to_vec());
        off += len;
    }
    Ok(Checkpoint { step, units })
}

/// One convergence-history point inside a [`TrainState`] (mirrors the
/// trainer's `EvalPoint` without a layering dependency on the coordinator).
#[derive(Debug, Clone, PartialEq)]
pub struct HistPoint {
    pub step: u64,
    pub train_secs: f64,
    pub metric: f64,
    pub train_loss: f32,
}

/// The version-2 resume envelope: everything `Trainer::run_zo`/`run_fo` need
/// to continue a run bit-identically. RNG-free by construction — perturbation
/// noise and batch order are regenerated from `(run_seed, step)`-derived
/// streams, and ZO optimizer state is rebuilt by replaying the stored
/// per-step projected gradients (`grads`) through the seed-replay rules.
#[derive(Debug, Clone, Default)]
pub struct TrainState {
    /// Canonical run-config fingerprint string; resume under a different
    /// configuration is rejected by comparing this field.
    pub config: String,
    /// `"zo"` or `"fo"` — which trainer loop wrote the state.
    pub kind: String,
    /// Completed optimization steps.
    pub step: u64,
    /// Tunable units (full-model units, or adapter units under PEFT) as f32
    /// masters — the authoritative precision, so bf16 resume is exact too.
    pub params: Vec<Vec<f32>>,
    /// Per completed step: recorded training loss (NaN for skipped steps).
    pub losses: Vec<f32>,
    /// Per completed step: projected gradient (ZO only; replay input for
    /// seed-replay optimizer state and the weighted selector).
    pub grads: Vec<f32>,
    /// Per completed step: true if `on_nonfinite=skip-step` skipped it.
    pub skipped: Vec<bool>,
    /// Convergence history (eval points) accumulated so far.
    pub history: Vec<HistPoint>,
    /// Stage-time accounting: perturb/forward/update/other seconds.
    pub stage_secs: [f64; 4],
    /// Steps counted by the stage timer.
    pub stage_steps: u64,
    /// First-order (ft) optimizer step count; 0 for ZO runs.
    pub fo_t: u64,
    /// First-order Adam first-moment buffers (empty for ZO runs).
    pub fo_m: Vec<Vec<f64>>,
    /// First-order Adam second-moment buffers (empty for ZO runs).
    pub fo_v: Vec<Vec<f64>>,
}

fn push_section(out: &mut Vec<u8>, tag: &[u8; 4], payload: &[u8]) {
    out.extend_from_slice(tag);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
}

fn push_f32_units(out: &mut Vec<u8>, units: &[Vec<f32>]) {
    out.extend_from_slice(&(units.len() as u32).to_le_bytes());
    for u in units {
        out.extend_from_slice(&(u.len() as u64).to_le_bytes());
    }
    for u in units {
        for &x in u {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

impl TrainState {
    /// Serialize to the sectioned v2 byte layout (see module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&STATE_VERSION.to_le_bytes());
        out.extend_from_slice(&STATE_SECTIONS.to_le_bytes());

        let mut meta = Vec::new();
        meta.extend_from_slice(&self.step.to_le_bytes());
        for s in self.stage_secs {
            meta.extend_from_slice(&s.to_le_bytes());
        }
        meta.extend_from_slice(&self.stage_steps.to_le_bytes());
        meta.extend_from_slice(&self.fo_t.to_le_bytes());
        meta.extend_from_slice(&(self.kind.len() as u32).to_le_bytes());
        meta.extend_from_slice(self.kind.as_bytes());
        meta.extend_from_slice(&(self.config.len() as u32).to_le_bytes());
        meta.extend_from_slice(self.config.as_bytes());
        push_section(&mut out, b"META", &meta);

        let mut parm = Vec::new();
        push_f32_units(&mut parm, &self.params);
        push_section(&mut out, b"PARM", &parm);

        let mut loss = Vec::new();
        loss.extend_from_slice(&(self.losses.len() as u32).to_le_bytes());
        for &l in &self.losses {
            loss.extend_from_slice(&l.to_le_bytes());
        }
        push_section(&mut out, b"LOSS", &loss);

        let mut grad = Vec::new();
        grad.extend_from_slice(&(self.grads.len() as u32).to_le_bytes());
        for &g in &self.grads {
            grad.extend_from_slice(&g.to_le_bytes());
        }
        push_section(&mut out, b"GRAD", &grad);

        let mut skip = Vec::new();
        skip.extend_from_slice(&(self.skipped.len() as u32).to_le_bytes());
        skip.extend(self.skipped.iter().map(|&s| s as u8));
        push_section(&mut out, b"SKIP", &skip);

        let mut hist = Vec::new();
        hist.extend_from_slice(&(self.history.len() as u32).to_le_bytes());
        for h in &self.history {
            hist.extend_from_slice(&h.step.to_le_bytes());
            hist.extend_from_slice(&h.train_secs.to_le_bytes());
            hist.extend_from_slice(&h.metric.to_le_bytes());
            hist.extend_from_slice(&h.train_loss.to_le_bytes());
        }
        push_section(&mut out, b"HIST", &hist);

        let mut fopt = Vec::new();
        fopt.extend_from_slice(&(self.fo_m.len() as u32).to_le_bytes());
        fopt.extend_from_slice(&self.fo_t.to_le_bytes());
        for m in &self.fo_m {
            fopt.extend_from_slice(&(m.len() as u64).to_le_bytes());
        }
        for bufs in [&self.fo_m, &self.fo_v] {
            for b in bufs.iter() {
                for &x in b {
                    fopt.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        push_section(&mut out, b"FOPT", &fopt);
        out
    }

    fn from_bytes(bytes: &[u8], label: &str) -> Result<TrainState> {
        let mut cur = Cur::new(bytes, label.to_string());
        let magic = cur.take(8)?;
        ensure!(magic == MAGIC, "{label}: not a LeZO checkpoint");
        let version = cur.u32()?;
        ensure!(
            version == STATE_VERSION,
            "{label}: unsupported train-state version {version} (expected {STATE_VERSION})"
        );
        let n_sections = cur.u32()?;
        ensure!(
            n_sections == STATE_SECTIONS,
            "{label}: expected {STATE_SECTIONS} sections, found {n_sections}"
        );
        let mut st = TrainState::default();

        let meta = read_section(&mut cur, b"META")?;
        {
            let mut m = Cur::new(meta, format!("{label} [META]"));
            st.step = m.u64()?;
            for s in st.stage_secs.iter_mut() {
                *s = m.f64()?;
            }
            st.stage_steps = m.u64()?;
            st.fo_t = m.u64()?;
            let klen = m.u32()? as usize;
            st.kind = String::from_utf8(m.take(klen)?.to_vec())
                .map_err(|_| anyhow!("{label}: non-utf8 kind"))?;
            let clen = m.u32()? as usize;
            st.config = String::from_utf8(m.take(clen)?.to_vec())
                .map_err(|_| anyhow!("{label}: non-utf8 config fingerprint"))?;
        }

        let parm = read_section(&mut cur, b"PARM")?;
        {
            let mut p = Cur::new(parm, format!("{label} [PARM]"));
            let n = p.u32()? as usize;
            ensure!(n < 10_000, "{label}: implausible unit count {n}");
            let lens: Vec<usize> = u64s(p.sized(n, 8)?).iter().map(|&l| l as usize).collect();
            for &len in &lens {
                st.params.push(f32s(p.sized(len, 4)?));
            }
        }

        let loss = read_section(&mut cur, b"LOSS")?;
        {
            let mut l = Cur::new(loss, format!("{label} [LOSS]"));
            let n = l.u32()? as usize;
            st.losses = f32s(l.sized(n, 4)?);
        }

        let grad = read_section(&mut cur, b"GRAD")?;
        {
            let mut g = Cur::new(grad, format!("{label} [GRAD]"));
            let n = g.u32()? as usize;
            st.grads = f32s(g.sized(n, 4)?);
        }

        let skip = read_section(&mut cur, b"SKIP")?;
        {
            let mut s = Cur::new(skip, format!("{label} [SKIP]"));
            let n = s.u32()? as usize;
            st.skipped = s.sized(n, 1)?.iter().map(|&b| b != 0).collect();
        }

        let hist = read_section(&mut cur, b"HIST")?;
        {
            let mut h = Cur::new(hist, format!("{label} [HIST]"));
            let n = h.u32()? as usize;
            ensure!(n < 100_000_000, "{label}: implausible history length {n}");
            for _ in 0..n {
                st.history.push(HistPoint {
                    step: h.u64()?,
                    train_secs: h.f64()?,
                    metric: h.f64()?,
                    train_loss: h.f32()?,
                });
            }
        }

        let fopt = read_section(&mut cur, b"FOPT")?;
        {
            let mut f = Cur::new(fopt, format!("{label} [FOPT]"));
            let n = f.u32()? as usize;
            ensure!(n < 10_000, "{label}: implausible fo-state unit count {n}");
            let fo_t = f.u64()?;
            ensure!(fo_t == st.fo_t, "{label}: META/FOPT step-count mismatch");
            let lens: Vec<usize> = u64s(f.sized(n, 8)?).iter().map(|&l| l as usize).collect();
            for &len in &lens {
                st.fo_m.push(f64s(f.sized(len, 8)?));
            }
            for &len in &lens {
                st.fo_v.push(f64s(f.sized(len, 8)?));
            }
        }

        ensure!(
            st.losses.len() == st.step as usize
                && st.grads.len() == st.step as usize
                && st.skipped.len() == st.step as usize,
            "{label}: per-step record count does not match step {} (loss {}, grad {}, skip {})",
            st.step,
            st.losses.len(),
            st.grads.len(),
            st.skipped.len()
        );
        Ok(st)
    }
}

fn read_section<'a>(cur: &mut Cur<'a>, tag: &[u8; 4]) -> Result<&'a [u8]> {
    let label = cur.label.clone();
    let at = cur.off;
    let got = cur.take(4)?;
    ensure!(
        got == tag,
        "{label}: expected section {} at byte offset {at}, found {:?}",
        String::from_utf8_lossy(tag),
        String::from_utf8_lossy(got)
    );
    let len = cur.u64()? as usize;
    let payload = cur.take(len)?;
    let want = cur.u32()?;
    let got_crc = crc32(payload);
    ensure!(
        want == got_crc,
        "{label}: section {} checksum mismatch (corrupt train state)",
        String::from_utf8_lossy(tag)
    );
    Ok(payload)
}

/// Atomically persist a [`TrainState`] resume envelope.
pub fn save_state(path: &Path, state: &TrainState) -> Result<()> {
    write_atomic(path, &state.to_bytes())
}

/// Load a v2 [`TrainState`] envelope; truncation and corruption are clean
/// errors naming the byte offset / section, never panics.
pub fn load_state(path: &Path) -> Result<TrainState> {
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    TrainState::from_bytes(&bytes, &path.display().to_string())
}

/// Resolve initial parameters for a run: explicit checkpoint if configured,
/// else `<artifact_dir>/pretrained.ckpt` if present, else params_init.bin.
pub fn resolve_initial(
    manifest: &crate::model::Manifest,
    explicit: &str,
) -> Result<(Vec<Vec<f32>>, String)> {
    if !explicit.is_empty() {
        let ck = load(Path::new(explicit))?;
        ensure!(
            ck.units.len() == manifest.n_units(),
            "checkpoint {} has {} units, model has {}",
            explicit,
            ck.units.len(),
            manifest.n_units()
        );
        for (u, &len) in ck.units.iter().zip(&manifest.unit_lens) {
            ensure!(u.len() == len, "checkpoint unit length mismatch");
        }
        return Ok((ck.units, explicit.to_string()));
    }
    let pretrained = manifest.dir.join("pretrained.ckpt");
    if pretrained.exists() {
        let ck = load(&pretrained)?;
        if ck.units.len() == manifest.n_units()
            && ck.units.iter().zip(&manifest.unit_lens).all(|(u, &l)| u.len() == l)
        {
            return Ok((ck.units, pretrained.display().to_string()));
        }
        return Err(anyhow!("{} exists but does not match the model", pretrained.display()));
    }
    Ok((manifest.read_init_params()?, "params_init.bin".to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lezo_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn round_trip() {
        let units = vec![vec![1.0f32, -2.5, 3.25], vec![0.0; 100], (0..7).map(|i| i as f32).collect()];
        let path = tmp("rt");
        save(&path, 42, &units).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.step, 42);
        assert_eq!(ck.units, units);
        // the atomic writer must not leave its temp file behind
        assert!(!tmp_path(&path).exists(), "stale {}", tmp_path(&path).display());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn detects_corruption() {
        let units = vec![vec![1.0f32; 64]];
        let path = tmp("corrupt");
        save(&path, 1, &units).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0xFF; // flip a data byte
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmp("magic");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxx").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc32_known_value() {
        // IEEE CRC-32 of "123456789" is 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn empty_units_ok() {
        let path = tmp("empty");
        save(&path, 0, &[]).unwrap();
        let ck = load(&path).unwrap();
        assert!(ck.units.is_empty());
        std::fs::remove_file(&path).ok();
    }

    /// Satellite: truncating a valid v1 checkpoint anywhere must yield a clean
    /// error that names a byte offset (or an earlier structural error), never
    /// a panic. Every header boundary plus sampled interior offsets.
    #[test]
    fn v1_truncation_names_offset() {
        let units = vec![vec![1.5f32; 9], vec![-2.0f32; 33]];
        let path = tmp("trunc1");
        save(&path, 7, &units).unwrap();
        let full = std::fs::read(&path).unwrap();
        // section boundaries of the v1 layout
        let boundaries = [0usize, 8, 12, 20, 24, 32, 40, full.len() - 4];
        let interior: Vec<usize> = (0..full.len()).step_by(11).collect();
        for &cut in boundaries.iter().chain(interior.iter()) {
            if cut >= full.len() {
                continue;
            }
            std::fs::write(&path, &full[..cut]).unwrap();
            let err = load(&path).unwrap_err().to_string();
            assert!(
                err.contains("byte offset") || err.contains("not a LeZO checkpoint"),
                "cut at {cut}: unexpected error: {err}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    fn sample_state() -> TrainState {
        TrainState {
            config: "model=opt-nano seed=0 lr=0.0001".into(),
            kind: "zo".into(),
            step: 3,
            params: vec![vec![0.5f32, -1.25, 2.0], vec![9.0f32; 17]],
            losses: vec![1.0, f32::NAN, 0.5],
            grads: vec![0.1, f32::NAN, -0.2],
            skipped: vec![false, true, false],
            history: vec![
                HistPoint { step: 0, train_secs: 0.0, metric: 0.5, train_loss: 1.0 },
                HistPoint { step: 2, train_secs: 1.5, metric: 0.75, train_loss: 0.5 },
            ],
            stage_secs: [0.1, 0.7, 0.05, 0.15],
            stage_steps: 3,
            fo_t: 0,
            fo_m: vec![],
            fo_v: vec![],
        }
    }

    #[test]
    fn state_round_trip_bitwise() {
        let st = sample_state();
        let path = tmp("state_rt");
        save_state(&path, &st).unwrap();
        let got = load_state(&path).unwrap();
        assert_eq!(got.config, st.config);
        assert_eq!(got.kind, st.kind);
        assert_eq!(got.step, st.step);
        assert_eq!(got.params, st.params);
        // NaN-carrying vectors compare by bits
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&got.losses), bits(&st.losses));
        assert_eq!(bits(&got.grads), bits(&st.grads));
        assert_eq!(got.skipped, st.skipped);
        assert_eq!(got.history, st.history);
        assert_eq!(got.stage_secs, st.stage_secs);
        assert_eq!(got.stage_steps, st.stage_steps);
        assert!(!tmp_path(&path).exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn state_round_trip_fo() {
        let mut st = sample_state();
        st.kind = "fo".into();
        st.fo_t = 3;
        st.fo_m = vec![vec![0.25f64, -0.5], vec![1e-9f64; 5]];
        st.fo_v = vec![vec![0.01f64, 0.02], vec![3e-4f64; 5]];
        let path = tmp("state_fo");
        save_state(&path, &st).unwrap();
        let got = load_state(&path).unwrap();
        assert_eq!(got.fo_t, 3);
        assert_eq!(got.fo_m, st.fo_m);
        assert_eq!(got.fo_v, st.fo_v);
        std::fs::remove_file(&path).ok();
    }

    /// Satellite property test: truncate the v2 envelope at every section
    /// boundary and a dense sample of interior offsets — always a clean error,
    /// never a panic; truncation errors name the byte offset.
    #[test]
    fn state_truncation_names_offset() {
        let mut st = sample_state();
        st.fo_m = vec![vec![1.0f64; 4]];
        st.fo_v = vec![vec![2.0f64; 4]];
        st.fo_t = 3;
        let full = st.to_bytes();
        let path = tmp("state_trunc");
        // compute section boundaries by walking the layout
        let mut boundaries = vec![0usize, 8, 12, 16];
        let mut off = 16usize;
        while off < full.len() {
            let len =
                u64::from_le_bytes(full[off + 4..off + 12].try_into().unwrap()) as usize;
            off += 4 + 8 + len + 4;
            boundaries.push(off);
        }
        assert_eq!(off, full.len(), "boundary walk must land on the file end");
        let interior: Vec<usize> = (0..full.len()).step_by(13).collect();
        for &cut in boundaries.iter().chain(interior.iter()) {
            if cut >= full.len() {
                continue;
            }
            std::fs::write(&path, &full[..cut]).unwrap();
            let err = load_state(&path).unwrap_err().to_string();
            assert!(
                err.contains("byte offset") || err.contains("not a LeZO checkpoint"),
                "cut at {cut}: unexpected error: {err}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn state_section_corruption_detected() {
        let st = sample_state();
        let mut bytes = st.to_bytes();
        // flip a byte inside the PARM payload (after META)
        let n = bytes.len();
        bytes[n / 2] ^= 0x55;
        let path = tmp("state_corrupt");
        std::fs::write(&path, &bytes).unwrap();
        // the flip may land in a payload (checksum error) or on a section
        // header (structural error) — either way: clean error, no panic
        let err = load_state(&path).unwrap_err().to_string();
        assert!(!err.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn state_rejects_v1_file_and_vice_versa() {
        let path = tmp("state_cross");
        save(&path, 3, &[vec![1.0f32; 8]]).unwrap();
        let err = load_state(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        save_state(&path, &sample_state()).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}

//! Checkpoint I/O: own binary format (no serde offline).
//!
//! Layout (little-endian):
//!   magic  [8]  b"LEZOCKPT"
//!   version u32 (= 1)
//!   step    u64
//!   n_units u32
//!   lens    [n_units] u64
//!   data    concat of f32 unit vectors
//!   crc     u32 (crc32 of data bytes)

use anyhow::{anyhow, ensure, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LEZOCKPT";
const VERSION: u32 = 1;

/// CRC-32 (IEEE), bit-reflected, table-free (fine for checkpoint sizes).
fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[derive(Debug)]
pub struct Checkpoint {
    pub step: u64,
    pub units: Vec<Vec<f32>>,
}

pub fn save(path: &Path, step: u64, units: &[Vec<f32>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut data_bytes = Vec::new();
    for u in units {
        for &x in u {
            data_bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&step.to_le_bytes())?;
    f.write_all(&(units.len() as u32).to_le_bytes())?;
    for u in units {
        f.write_all(&(u.len() as u64).to_le_bytes())?;
    }
    f.write_all(&data_bytes)?;
    f.write_all(&crc32(&data_bytes).to_le_bytes())?;
    f.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "{}: not a LeZO checkpoint", path.display());
    let mut u32b = [0u8; 4];
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    ensure!(version == VERSION, "unsupported checkpoint version {version}");
    f.read_exact(&mut u64b)?;
    let step = u64::from_le_bytes(u64b);
    f.read_exact(&mut u32b)?;
    let n_units = u32::from_le_bytes(u32b) as usize;
    ensure!(n_units < 10_000, "implausible unit count {n_units}");
    let mut lens = Vec::with_capacity(n_units);
    for _ in 0..n_units {
        f.read_exact(&mut u64b)?;
        lens.push(u64::from_le_bytes(u64b) as usize);
    }
    let total: usize = lens.iter().sum();
    let mut data_bytes = vec![0u8; total * 4];
    f.read_exact(&mut data_bytes)?;
    f.read_exact(&mut u32b)?;
    let want_crc = u32::from_le_bytes(u32b);
    let got_crc = crc32(&data_bytes);
    ensure!(
        want_crc == got_crc,
        "{}: checksum mismatch (corrupt checkpoint)",
        path.display()
    );
    let mut units = Vec::with_capacity(n_units);
    let mut off = 0usize;
    for len in lens {
        let mut v = Vec::with_capacity(len);
        for i in 0..len {
            let b = &data_bytes[4 * (off + i)..4 * (off + i) + 4];
            v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        off += len;
        units.push(v);
    }
    Ok(Checkpoint { step, units })
}

/// Resolve initial parameters for a run: explicit checkpoint if configured,
/// else `<artifact_dir>/pretrained.ckpt` if present, else params_init.bin.
pub fn resolve_initial(
    manifest: &crate::model::Manifest,
    explicit: &str,
) -> Result<(Vec<Vec<f32>>, String)> {
    if !explicit.is_empty() {
        let ck = load(Path::new(explicit))?;
        ensure!(
            ck.units.len() == manifest.n_units(),
            "checkpoint {} has {} units, model has {}",
            explicit,
            ck.units.len(),
            manifest.n_units()
        );
        for (u, &len) in ck.units.iter().zip(&manifest.unit_lens) {
            ensure!(u.len() == len, "checkpoint unit length mismatch");
        }
        return Ok((ck.units, explicit.to_string()));
    }
    let pretrained = manifest.dir.join("pretrained.ckpt");
    if pretrained.exists() {
        let ck = load(&pretrained)?;
        if ck.units.len() == manifest.n_units()
            && ck.units.iter().zip(&manifest.unit_lens).all(|(u, &l)| u.len() == l)
        {
            return Ok((ck.units, pretrained.display().to_string()));
        }
        return Err(anyhow!("{} exists but does not match the model", pretrained.display()));
    }
    Ok((manifest.read_init_params()?, "params_init.bin".to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lezo_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn round_trip() {
        let units = vec![vec![1.0f32, -2.5, 3.25], vec![0.0; 100], (0..7).map(|i| i as f32).collect()];
        let path = tmp("rt");
        save(&path, 42, &units).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.step, 42);
        assert_eq!(ck.units, units);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn detects_corruption() {
        let units = vec![vec![1.0f32; 64]];
        let path = tmp("corrupt");
        save(&path, 1, &units).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0xFF; // flip a data byte
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmp("magic");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxx").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc32_known_value() {
        // IEEE CRC-32 of "123456789" is 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn empty_units_ok() {
        let path = tmp("empty");
        save(&path, 0, &[]).unwrap();
        let ck = load(&path).unwrap();
        assert!(ck.units.is_empty());
        std::fs::remove_file(&path).ok();
    }
}

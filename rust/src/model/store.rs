//! ParamStore: model parameters as device-resident PjRtBuffers, one flat
//! f32 vector per layer unit (the unit of LeZO sparsity).
//!
//! The ZO hot loop mutates units by *replacing* buffers with executable
//! outputs (PJRT buffers are immutable); parameters never round-trip through
//! the host during training. Host copies exist only for checkpointing and
//! the FO baseline.

use crate::model::manifest::Manifest;
use crate::runtime::Runtime;
use anyhow::{ensure, Result};

pub struct ParamStore {
    units: Vec<xla::PjRtBuffer>,
    lens: Vec<usize>,
    names: Vec<String>,
}

impl ParamStore {
    /// Upload host vectors (one per unit) to the device.
    pub fn from_host(rt: &Runtime, manifest: &Manifest, host: &[Vec<f32>]) -> Result<ParamStore> {
        ensure!(host.len() == manifest.n_units(), "unit count mismatch");
        let mut units = Vec::with_capacity(host.len());
        for (u, &len) in host.iter().zip(&manifest.unit_lens) {
            ensure!(u.len() == len, "unit length mismatch: {} vs {}", u.len(), len);
            units.push(rt.vec_f32(u)?);
        }
        Ok(ParamStore {
            units,
            lens: manifest.unit_lens.clone(),
            names: manifest.unit_names.clone(),
        })
    }

    /// Load the python-side initialization (params_init.bin).
    pub fn load_init(rt: &Runtime, manifest: &Manifest) -> Result<ParamStore> {
        let host = manifest.read_init_params()?;
        Self::from_host(rt, manifest, &host)
    }

    pub fn n_units(&self) -> usize {
        self.units.len()
    }

    pub fn unit_len(&self, k: usize) -> usize {
        self.lens[k]
    }

    pub fn unit_name(&self, k: usize) -> &str {
        &self.names[k]
    }

    pub fn unit(&self, k: usize) -> &xla::PjRtBuffer {
        &self.units[k]
    }

    /// All unit buffers in argument order (prefix of every model call).
    pub fn unit_refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.units.iter().collect()
    }

    /// Replace a unit with an executable output (the ZO perturb/update path).
    pub fn replace_unit(&mut self, k: usize, buf: xla::PjRtBuffer) {
        self.units[k] = buf;
    }

    /// Download all units (checkpointing, FO baseline).
    pub fn to_host(&self, rt: &Runtime) -> Result<Vec<Vec<f32>>> {
        self.units.iter().map(|b| rt.read_vec_f32(b)).collect()
    }

    /// Total parameters.
    pub fn param_count(&self) -> usize {
        self.lens.iter().sum()
    }

    /// L2 norm of all parameters (diagnostics; one device->host pass).
    pub fn global_norm(&self, rt: &Runtime) -> Result<f64> {
        let mut acc = 0.0f64;
        for b in &self.units {
            let v = rt.read_vec_f32(b)?;
            acc += v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        }
        Ok(acc.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::default_artifact_dir;
    use std::path::PathBuf;

    fn art() -> PathBuf {
        default_artifact_dir("opt-micro")
    }

    #[test]
    fn init_round_trip() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let m = Manifest::load(&art()).unwrap();
        let store = ParamStore::load_init(&rt, &m).unwrap();
        assert_eq!(store.n_units(), m.n_units());
        assert_eq!(store.param_count(), m.param_count);
        let host = store.to_host(&rt).unwrap();
        let orig = m.read_init_params().unwrap();
        assert_eq!(host, orig, "device round-trip must be lossless");
    }

    #[test]
    fn replace_unit_changes_only_that_unit() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let m = Manifest::load(&art()).unwrap();
        let mut store = ParamStore::load_init(&rt, &m).unwrap();
        let before = store.to_host(&rt).unwrap();
        let k = 1;
        let new_data = vec![0.5f32; store.unit_len(k)];
        let buf = rt.vec_f32(&new_data).unwrap();
        store.replace_unit(k, buf);
        let after = store.to_host(&rt).unwrap();
        assert_eq!(after[k], new_data);
        for i in 0..store.n_units() {
            if i != k {
                assert_eq!(after[i], before[i], "unit {i} must be untouched");
            }
        }
    }

    #[test]
    fn wrong_host_shape_rejected() {
        crate::require_artifacts!();
        let rt = Runtime::cpu().unwrap();
        let m = Manifest::load(&art()).unwrap();
        let mut host = m.read_init_params().unwrap();
        host[0].pop();
        assert!(ParamStore::from_host(&rt, &m, &host).is_err());
        host.pop();
        assert!(ParamStore::from_host(&rt, &m, &host).is_err());
    }
}

//! Parsed form of artifacts/<size>/manifest.json — the contract between the
//! python compile path and the rust runtime.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub seq_buckets: Vec<usize>,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub unit_names: Vec<String>,
    pub unit_lens: Vec<usize>,
    pub axpy_lens: Vec<usize>,
    pub param_count: usize,
    pub use_pallas_forward: bool,
    pub init_file: String,
    pub files: BTreeMap<String, String>,
    /// PEFT extension (present when aot exported --peft): per-block lora and
    /// prefix unit lengths.
    pub lora_unit_len: Option<usize>,
    pub prefix_unit_len: Option<usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let files = match j.get("files") {
            Some(Json::Obj(m)) => m
                .iter()
                .map(|(k, v)| {
                    v.as_str()
                        .map(|s| (k.clone(), s.to_string()))
                        .ok_or_else(|| anyhow!("non-string file entry {k}"))
                })
                .collect::<Result<BTreeMap<_, _>>>()?,
            _ => return Err(anyhow!("manifest missing files object")),
        };
        let m = Manifest {
            dir: dir.to_path_buf(),
            name: j.req_str("name")?,
            vocab: j.req_usize("vocab")?,
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            max_seq: j.req_usize("max_seq")?,
            seq_buckets: j.req_usize_arr("seq_buckets")?,
            train_batch: j.req_usize("train_batch")?,
            eval_batch: j.req_usize("eval_batch")?,
            unit_names: j.req_str_arr("unit_names")?,
            unit_lens: j.req_usize_arr("unit_lens")?,
            axpy_lens: j.req_usize_arr("axpy_lens")?,
            param_count: j.req_usize("param_count")?,
            use_pallas_forward: j
                .get("use_pallas_forward")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            init_file: j.req_str("init_file")?,
            files,
            lora_unit_len: j.get("lora_unit_len").and_then(Json::as_usize),
            prefix_unit_len: j.get("prefix_unit_len").and_then(Json::as_usize),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.unit_names.len() != self.unit_lens.len() {
            return Err(anyhow!("unit_names/unit_lens length mismatch"));
        }
        if self.unit_lens.iter().sum::<usize>() != self.param_count {
            return Err(anyhow!("unit_lens do not sum to param_count"));
        }
        if self.unit_names.len() != self.n_layers + 2 {
            return Err(anyhow!("expected n_layers+2 units"));
        }
        for n in &self.axpy_lens {
            if !self.files.contains_key(&format!("zo_axpy_{n}")) {
                return Err(anyhow!("manifest missing zo_axpy_{n}"));
            }
        }
        for s in &self.seq_buckets {
            for stem in ["forward_loss", "example_losses", "predict", "forward_backward"] {
                if !self.files.contains_key(&format!("{stem}_s{s}")) {
                    return Err(anyhow!("manifest missing {stem}_s{s}"));
                }
            }
        }
        Ok(())
    }

    pub fn n_units(&self) -> usize {
        self.unit_lens.len()
    }

    /// Indices of transformer-block units (the sparsifiable set under the
    /// paper's policy; unit 0 is the embedding, the last unit the final LN).
    pub fn block_unit_indices(&self) -> Vec<usize> {
        (1..=self.n_layers).collect()
    }

    pub fn file_path(&self, key: &str) -> Result<PathBuf> {
        let f = self
            .files
            .get(key)
            .ok_or_else(|| anyhow!("manifest has no executable '{key}'"))?;
        Ok(self.dir.join(f))
    }

    /// Smallest exported bucket that fits `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Result<usize> {
        self.seq_buckets
            .iter()
            .copied()
            .filter(|&b| b >= len)
            .min()
            .ok_or_else(|| anyhow!("sequence length {len} exceeds largest bucket"))
    }

    /// Read the initial parameters (concatenated little-endian f32).
    pub fn read_init_params(&self) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(&self.init_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != 4 * self.param_count {
            return Err(anyhow!(
                "{}: expected {} bytes, got {}",
                path.display(),
                4 * self.param_count,
                bytes.len()
            ));
        }
        let mut out = Vec::with_capacity(self.n_units());
        let mut off = 0usize;
        for &len in &self.unit_lens {
            let mut v = Vec::with_capacity(len);
            for i in 0..len {
                let b = &bytes[off + 4 * i..off + 4 * i + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += 4 * len;
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::default_artifact_dir;

    fn art_dir() -> PathBuf {
        default_artifact_dir("opt-micro")
    }

    #[test]
    fn loads_real_manifest() {
        crate::require_artifacts!();
        let m = Manifest::load(&art_dir()).unwrap();
        assert_eq!(m.name, "opt-micro");
        assert_eq!(m.n_units(), m.n_layers + 2);
        assert_eq!(m.block_unit_indices().len(), m.n_layers);
        assert_eq!(m.unit_lens.iter().sum::<usize>(), m.param_count);
        // manifest-derived spec agrees with the in-crate preset
        let spec = crate::model::ModelSpec::from_manifest(&m);
        assert_eq!(spec, crate::model::ModelSpec::preset("opt-micro").unwrap());
        assert_eq!(spec.unit_lens(), m.unit_lens, "spec layout must match the exporter");
    }

    #[test]
    fn bucket_selection() {
        crate::require_artifacts!();
        let m = Manifest::load(&art_dir()).unwrap();
        assert_eq!(m.bucket_for(1).unwrap(), 16);
        assert_eq!(m.bucket_for(16).unwrap(), 16);
        assert_eq!(m.bucket_for(17).unwrap(), 32);
        assert_eq!(m.bucket_for(64).unwrap(), 64);
        assert!(m.bucket_for(65).is_err());
    }

    #[test]
    fn init_params_match_lens() {
        crate::require_artifacts!();
        let m = Manifest::load(&art_dir()).unwrap();
        let units = m.read_init_params().unwrap();
        assert_eq!(units.len(), m.n_units());
        for (u, &len) in units.iter().zip(&m.unit_lens) {
            assert_eq!(u.len(), len);
        }
        // embedding init is N(0, 0.02): sane statistics
        let emb = &units[0];
        let mean = emb.iter().map(|&x| x as f64).sum::<f64>() / emb.len() as f64;
        assert!(mean.abs() < 1e-3);
    }

    #[test]
    fn missing_dir_is_contextual_error() {
        let err = Manifest::load(Path::new("/nonexistent/xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}

//! Model state: the architecture spec, the manifest contract, checkpoint
//! I/O, and (under the `pjrt` feature) the device-resident parameter store.

pub mod checkpoint;
pub mod manifest;
pub mod spec;
#[cfg(feature = "pjrt")]
pub mod store;

pub use manifest::Manifest;
pub use spec::ModelSpec;
#[cfg(feature = "pjrt")]
pub use store::ParamStore;

//! Model state: the manifest contract, the device-resident parameter store,
//! and checkpoint I/O.

pub mod checkpoint;
pub mod manifest;
pub mod store;

pub use manifest::Manifest;
pub use store::ParamStore;

//! Evaluation harness (DESIGN.md S13), generic over the runtime backend:
//! MeZO-style option scoring for classification and multiple choice (argmin
//! of per-option LM loss via the `example_losses` family) and teacher-forced
//! token-F1 for the generation tasks (via the `predict` family).

pub mod icl;

use crate::data::batch::{Batch, Instance};
use crate::peft::PeftMode;
use crate::runtime::backend::Backend;
use crate::tasks::{Example, TaskKind};
use anyhow::{ensure, Result};

/// One evaluation outcome: the metric value in [0, 1] plus its name
/// ("acc" or "f1", matching the paper's tables).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalMetric {
    pub value: f64,
    pub kind: &'static str,
    pub n_examples: usize,
}

impl EvalMetric {
    /// Percentage, as printed in the paper's tables.
    pub fn pct(&self) -> f64 {
        100.0 * self.value
    }
}

/// Evaluator bound to one backend. `peft` routes scoring through the
/// adapter-aware executable families when fine-tuning with LoRA / prefix
/// (Table 4); `units` is then base units followed by adapter units.
pub struct Evaluator<'b, B: Backend> {
    backend: &'b B,
    peft: PeftMode,
}

impl<'b, B: Backend> Evaluator<'b, B> {
    pub fn new(backend: &'b B) -> Evaluator<'b, B> {
        Evaluator { backend, peft: PeftMode::Full }
    }

    /// Route scoring through the PEFT families.
    pub fn with_peft(backend: &'b B, peft: PeftMode) -> Evaluator<'b, B> {
        Evaluator { backend, peft }
    }

    /// Per-instance mean masked LM loss, batched over the eval family.
    pub fn instance_losses(
        &self,
        units: &[&B::Buffer],
        instances: &[Instance],
    ) -> Result<Vec<f32>> {
        let spec = self.backend.spec();
        let rows = spec.eval_batch;
        let mut losses = Vec::with_capacity(instances.len());
        for chunk in instances.chunks(rows) {
            let seq = crate::data::batch::bucket_for_instances(&spec.seq_buckets, chunk)?;
            let batch = Batch::from_instances(chunk, rows, seq)?;
            let prepared = self.backend.prepare_batch(&batch)?;
            let per = self.backend.example_losses(self.peft, units, &prepared)?;
            ensure!(per.len() == rows, "example_losses returned {} rows", per.len());
            losses.extend_from_slice(&per[..chunk.len()]);
        }
        Ok(losses)
    }

    /// Classification / multiple choice: predict = argmin option loss.
    pub fn option_accuracy(
        &self,
        units: &[&B::Buffer],
        examples: &[Example],
    ) -> Result<EvalMetric> {
        ensure!(!examples.is_empty(), "empty eval set");
        // flatten all options, remember example boundaries
        let mut instances = Vec::new();
        let mut spans = Vec::with_capacity(examples.len());
        for ex in examples {
            ensure!(!ex.options.is_empty(), "option_accuracy on a generation example");
            let start = instances.len();
            instances.extend(ex.option_instances());
            spans.push(start..instances.len());
        }
        let losses = self.instance_losses(units, &instances)?;
        let mut correct = 0usize;
        for (ex, span) in examples.iter().zip(spans) {
            let opt_losses = &losses[span];
            let pred = opt_losses
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred == ex.gold {
                correct += 1;
            }
        }
        Ok(EvalMetric {
            value: correct as f64 / examples.len() as f64,
            kind: "acc",
            n_examples: examples.len(),
        })
    }

    /// Generation: teacher-forced greedy prediction over the answer span,
    /// scored by token-level F1 (the SQuAD/DROP metric shape).
    pub fn generation_f1(
        &self,
        units: &[&B::Buffer],
        examples: &[Example],
    ) -> Result<EvalMetric> {
        ensure!(!examples.is_empty(), "empty eval set");
        let spec = self.backend.spec();
        let rows = spec.eval_batch;
        let mut f1s = Vec::with_capacity(examples.len());
        for chunk in examples.chunks(rows) {
            let instances: Vec<Instance> =
                chunk.iter().map(|ex| ex.train_instance()).collect();
            let seq = crate::data::batch::bucket_for_instances(&spec.seq_buckets, &instances)?;
            let batch = Batch::from_instances(&instances, rows, seq)?;
            let prepared = self.backend.prepare_batch(&batch)?;
            let preds = self.backend.predict(self.peft, units, &prepared)?;
            ensure!(preds.len() == rows * seq);
            for (r, ex) in chunk.iter().enumerate() {
                let p = ex.prompt.len();
                let gold = &ex.answer;
                // position p-1+i predicts answer token i
                let predicted: Vec<u32> = (0..gold.len())
                    .map(|i| preds[r * seq + p - 1 + i] as u32)
                    .collect();
                f1s.push(token_f1(&predicted, gold));
            }
        }
        Ok(EvalMetric {
            value: crate::stats::mean(&f1s),
            kind: "f1",
            n_examples: examples.len(),
        })
    }

    /// Dispatch on task kind.
    pub fn evaluate(
        &self,
        kind: TaskKind,
        units: &[&B::Buffer],
        examples: &[Example],
    ) -> Result<EvalMetric> {
        match kind {
            TaskKind::Classification | TaskKind::MultipleChoice => {
                self.option_accuracy(units, examples)
            }
            TaskKind::Generation => self.generation_f1(units, examples),
        }
    }
}

/// Token-multiset F1 between predicted and gold answers (SQuAD metric over
/// token ids instead of whitespace words).
pub fn token_f1(pred: &[u32], gold: &[u32]) -> f64 {
    if pred.is_empty() && gold.is_empty() {
        return 1.0;
    }
    if pred.is_empty() || gold.is_empty() {
        return 0.0;
    }
    let mut gold_counts = std::collections::HashMap::new();
    for &g in gold {
        *gold_counts.entry(g).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for &p in pred {
        if let Some(c) = gold_counts.get_mut(&p) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / pred.len() as f64;
    let recall = overlap as f64 / gold.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_exact_match_is_one() {
        assert_eq!(token_f1(&[1, 2, 3], &[1, 2, 3]), 1.0);
    }

    #[test]
    fn f1_no_overlap_is_zero() {
        assert_eq!(token_f1(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // pred {1,2}, gold {2,3}: overlap 1, p=r=0.5, f1=0.5
        assert!((token_f1(&[1, 2], &[2, 3]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_multiset_semantics() {
        // duplicated token only counts as many times as gold has it
        let f1 = token_f1(&[5, 5, 5], &[5]);
        // overlap=1, p=1/3, r=1, f1=0.5
        assert!((f1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_empty_cases() {
        assert_eq!(token_f1(&[], &[]), 1.0);
        assert_eq!(token_f1(&[1], &[]), 0.0);
        assert_eq!(token_f1(&[], &[1]), 0.0);
    }

    #[test]
    fn f1_order_invariant() {
        assert_eq!(token_f1(&[1, 2, 3], &[3, 2, 1]), 1.0);
    }

    #[test]
    fn evaluator_scores_all_task_kinds_natively() {
        // full scoring stack over the native backend — no artifacts needed
        use crate::runtime::{Backend, NativeBackend};
        use crate::tasks::{eval_set, make_task};
        let b = NativeBackend::preset("opt-nano").unwrap();
        let host = b.initial_params("").unwrap().0;
        let bufs: Vec<_> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        let units: Vec<_> = bufs.iter().collect();
        let ev = Evaluator::new(&b);
        for task_name in ["sst2", "copa", "squad"] {
            let task = make_task(task_name).unwrap();
            let examples = eval_set(task.as_ref(), 11, 12, 10);
            let metric = ev.evaluate(task.kind(), &units, &examples).unwrap();
            assert!(
                (0.0..=1.0).contains(&metric.value),
                "{task_name}: {}",
                metric.value
            );
            assert_eq!(metric.n_examples, 12);
        }
    }

    #[test]
    fn untrained_model_scores_near_chance_natively() {
        use crate::runtime::{Backend, NativeBackend};
        use crate::tasks::{eval_set, make_task};
        let b = NativeBackend::preset("opt-nano").unwrap();
        let host = b.initial_params("").unwrap().0;
        let bufs: Vec<_> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        let units: Vec<_> = bufs.iter().collect();
        let ev = Evaluator::new(&b);
        let task = make_task("sst2").unwrap();
        let examples = eval_set(task.as_ref(), 123, 60, 10);
        let metric = ev.option_accuracy(&units, &examples).unwrap();
        assert!(
            (0.25..=0.75).contains(&metric.value),
            "untrained sst2 acc {} should be near 0.5",
            metric.value
        );
    }
}

//! In-context learning (ICL) baseline: k demonstrations concatenated in
//! front of the query prompt, scored with the same option-scoring
//! executables as everything else — no parameter updates (DESIGN.md S18).
//!
//! The paper uses 32 shots on 2048-token contexts; our buckets top out at 64
//! tokens, so demos are generated short and we pack *as many of the
//! requested shots as fit* (documented substitution, same mechanism).

use crate::data::vocab::EOS;
use crate::rng::Rng;
use crate::tasks::{Example, Task};

/// Mean content length used when generating demonstrations (kept short so
/// several fit a bucket).
pub const DEMO_MEAN_LEN: usize = 6;

/// Build the demonstration pool for a task (deterministic per seed).
pub fn demo_pool(task: &dyn Task, seed: u64, n: usize) -> Vec<Example> {
    let mut rng = Rng::new(crate::rng::derive(seed, crate::rng::purpose::DATA, 0xC1)); // icl tag
    (0..n).map(|_| task.gen(&mut rng, DEMO_MEAN_LEN)).collect()
}

/// Prefix tokens for up to `shots` demonstrations, greedily packed so that
/// `prefix + longest_continuation(query)` still fits `budget` tokens.
/// Demonstration format: `demo_prompt demo_gold <eos>` (without the BOS of
/// subsequent demos — the query keeps its own BOS at the front).
pub fn icl_prefix(demos: &[Example], shots: usize, query: &Example, budget: usize) -> Vec<u32> {
    let query_len = query.prompt.len()
        + query
            .options
            .iter()
            .map(Vec::len)
            .chain(std::iter::once(query.answer.len()))
            .max()
            .unwrap_or(0);
    let mut prefix: Vec<u32> = Vec::new();
    for demo in demos.iter().take(shots) {
        let inst = demo.train_instance();
        // strip the demo's BOS; keep the rest, then an EOS separator
        let demo_toks: Vec<u32> = inst
            .prompt
            .iter()
            .skip(1)
            .chain(inst.continuation.iter())
            .copied()
            .chain(std::iter::once(EOS))
            .collect();
        if 1 + prefix.len() + demo_toks.len() + (query_len - 1) > budget {
            break;
        }
        prefix.extend(demo_toks);
    }
    prefix
}

/// The query example with the ICL prefix spliced in after its BOS.
pub fn with_prefix(query: &Example, prefix: &[u32]) -> Example {
    let mut prompt = Vec::with_capacity(1 + prefix.len() + query.prompt.len() - 1);
    prompt.push(query.prompt[0]); // BOS
    prompt.extend_from_slice(prefix);
    prompt.extend_from_slice(&query.prompt[1..]);
    Example {
        prompt,
        options: query.options.clone(),
        gold: query.gold,
        answer: query.answer.clone(),
    }
}

/// Apply ICL packing to a whole eval set.
pub fn icl_eval_set(
    task: &dyn Task,
    seed: u64,
    shots: usize,
    eval: &[Example],
    budget: usize,
) -> Vec<Example> {
    let demos = demo_pool(task, seed, shots.max(1));
    eval.iter()
        .map(|ex| {
            let prefix = icl_prefix(&demos, shots, ex, budget);
            with_prefix(ex, &prefix)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::BOS;
    use crate::tasks::{eval_set, make_task};

    #[test]
    fn prefix_respects_budget() {
        let task = make_task("sst2").unwrap();
        let demos = demo_pool(task.as_ref(), 1, 8);
        let mut rng = Rng::new(2);
        let query = task.gen(&mut rng, 10);
        for budget in [16usize, 32, 64] {
            let prefix = icl_prefix(&demos, 8, &query, budget);
            let packed = with_prefix(&query, &prefix);
            let longest = packed
                .options
                .iter()
                .map(Vec::len)
                .max()
                .unwrap_or(packed.answer.len());
            assert!(
                packed.prompt.len() + longest <= budget,
                "budget {budget}: {} tokens",
                packed.prompt.len() + longest
            );
        }
    }

    #[test]
    fn zero_shots_is_identity_prompt() {
        let task = make_task("boolq").unwrap();
        let mut rng = Rng::new(3);
        let query = task.gen(&mut rng, 12);
        let packed = with_prefix(&query, &[]);
        assert_eq!(packed.prompt, query.prompt);
        assert_eq!(packed.gold, query.gold);
    }

    #[test]
    fn packed_prompt_keeps_bos_and_tail() {
        let task = make_task("sst2").unwrap();
        let demos = demo_pool(task.as_ref(), 1, 4);
        let mut rng = Rng::new(4);
        let query = task.gen(&mut rng, 8);
        let prefix = icl_prefix(&demos, 4, &query, 64);
        assert!(!prefix.is_empty(), "64-token budget must fit at least one short demo");
        let packed = with_prefix(&query, &prefix);
        assert_eq!(packed.prompt[0], BOS);
        // tail of the packed prompt is the original query (minus BOS)
        let tail = &packed.prompt[packed.prompt.len() - (query.prompt.len() - 1)..];
        assert_eq!(tail, &query.prompt[1..]);
    }

    #[test]
    fn icl_eval_set_is_deterministic_and_aligned() {
        let task = make_task("copa").unwrap();
        let ev = eval_set(task.as_ref(), 5, 10, 12);
        let a = icl_eval_set(task.as_ref(), 5, 4, &ev, 64);
        let b = icl_eval_set(task.as_ref(), 5, 4, &ev, 64);
        assert_eq!(a.len(), ev.len());
        for ((x, y), orig) in a.iter().zip(&b).zip(&ev) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.gold, orig.gold, "labels must be preserved");
        }
    }

    #[test]
    fn demos_are_short() {
        let task = make_task("rte").unwrap();
        for d in demo_pool(task.as_ref(), 7, 20) {
            assert!(d.train_instance().total_len() <= 24, "demo too long");
        }
    }
}

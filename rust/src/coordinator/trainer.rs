//! The training loop: data sampling, the ZO/FO engines, periodic evaluation,
//! checkpointing, and run reporting. One [`Trainer::run`] call reproduces one
//! cell of the paper's tables; the bench harness sweeps it.
//!
//! The loop is generic over the runtime [`Backend`]: `run()` resolves the
//! configured backend (config key `backend` / env `LEZO_BACKEND`; `auto`
//! picks PJRT when artifacts exist in a pjrt-enabled build, else the native
//! pure-Rust backend; `sharded` builds N identically configured native
//! replicas — `shards` key / `LEZO_SHARDS` env — whose lockstep fan-out is
//! bit-identical to native) and hands it to [`Trainer::run_with`], so the
//! full perturb -> forward -> flip -> forward -> restore -> update loop
//! runs end-to-end on any machine with zero external artifacts. The same is
//! true of the first-order paths since the native backward pass landed
//! (`method=ft` and [`pretrain`] run on any FO-capable backend,
//! `Backend::supports_fo`) and of the PEFT spaces since the native
//! adapter forwards landed: `peft=lora|prefix` (or the `mezo-lora` /
//! `lezo-prefix` method aliases) tunes per-block adapter units over the
//! frozen base on any backend whose `Backend::supports_peft` says yes.

use crate::config::{Method, RunConfig};
use crate::coordinator::faults::{CrashPhase, FaultPlan, NonFinitePolicy, SaveFault, CRASH_MARKER};
use crate::coordinator::fo::{FoEngine, FoOptimizer};
use crate::coordinator::metrics::{StageTimer, StageTimes};
use crate::coordinator::optim::{make_optimizer, resolve_zo_opt, ZoAdam, ZoOptKind, ZoOptimizer};
use crate::coordinator::policy::PolicySelector;
use crate::coordinator::spsa::{SpsaEngine, TunableUnits};
use crate::data::batch::{bucket_for_instances, Batch};
use crate::eval::{icl, EvalMetric, Evaluator};
use crate::model::checkpoint::{self, HistPoint, TrainState};
use crate::model::spec::ModelSpec;
use crate::peft::PeftMode;
use crate::rng::{derive, purpose, Rng};
use crate::runtime::backend::{Backend, BackendKind, Precision};
use crate::runtime::{NativeBackend, ShardedBackend};
use crate::tasks::{eval_set, make_task, Example, TaskKind};
use anyhow::{bail, ensure, Result};
use std::path::{Path, PathBuf};

/// One point on the convergence curve (Fig. 1): metric after `step` steps
/// and `train_secs` of *training* wall time (eval time excluded).
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub step: u64,
    pub train_secs: f64,
    pub metric: f64,
    pub train_loss: f32,
}

/// Everything a finished run reports; the bench harness consumes this.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub task: String,
    pub method: Method,
    /// Which backend executed the run ("native" / "sharded" / "pjrt").
    pub backend: &'static str,
    /// Forward-path precision the backend executed
    /// ([`Backend::precision`]; f32 masters stay authoritative either way).
    pub precision: Precision,
    pub metric_kind: &'static str,
    /// Final-checkpoint metric (paper: best-validation checkpoint; we keep
    /// both final and best).
    pub final_metric: f64,
    pub best_metric: f64,
    pub history: Vec<EvalPoint>,
    pub losses: Vec<f32>,
    pub stage_times: StageTimes,
    pub train_secs: f64,
    /// Mean fraction of parameters perturbed+updated per step (1.0 = MeZO).
    pub active_param_fraction: f64,
    /// Mean prompt token length of the training batches (Fig. 6 axis).
    pub mean_input_len: f64,
    /// Bytes of optimizer state held at the end of the run
    /// ([`FoOptimizer::state_bytes`]); 0 for ZO runs — the measured side of
    /// the paper's "FT costs 12x memory" comparison
    /// (`metrics::MemoryModel`).
    pub fo_state_bytes: usize,
    /// Bytes of ZO optimizer state ([`ZoOptimizer::state_bytes`]): the
    /// seed-replay history of the momentum/Adam rules — scalars, not
    /// parameter-sized moment buffers. 0 for stateless rules and FO runs;
    /// compare against `fo_state_bytes`.
    pub zo_state_bytes: usize,
    /// The ZO update rule the run executed (after the `LEZO_ZO_OPT`
    /// override); [`ZoOptKind::Sgd`] for non-ZO runs.
    pub zo_opt: ZoOptKind,
    /// `Some(k)` when the run resumed from a saved [`TrainState`] holding
    /// `k` completed steps; `None` for fresh runs.
    pub resumed_from: Option<u64>,
}

impl TrainReport {
    /// First training time at which the metric reached `target` (None if
    /// never) — the convergence-speedup measurement of Figs. 1 and 5.
    pub fn time_to_metric(&self, target: f64) -> Option<f64> {
        self.history.iter().find(|p| p.metric >= target).map(|p| p.train_secs)
    }

    pub fn steps_to_metric(&self, target: f64) -> Option<u64> {
        self.history.iter().find(|p| p.metric >= target).map(|p| p.step)
    }

    pub fn per_step_ms(&self) -> f64 {
        1e3 * self.stage_times.total() / self.stage_times.steps.max(1) as f64
    }
}

/// A concrete backend instance chosen for a run.
pub enum ResolvedBackend {
    Native(NativeBackend),
    /// N identically configured native replicas ([`ShardedBackend`]); the
    /// shard count comes from `cfg.shards` / `LEZO_SHARDS` (env wins).
    Sharded(ShardedBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(crate::runtime::PjrtBackend),
}

impl ResolvedBackend {
    pub fn name(&self) -> &'static str {
        match self {
            ResolvedBackend::Native(_) => "native",
            ResolvedBackend::Sharded(_) => "sharded",
            #[cfg(feature = "pjrt")]
            ResolvedBackend::Pjrt(_) => "pjrt",
        }
    }
}

/// The backend a config asks for. Precedence: an explicit (non-`auto`)
/// `cfg.backend` wins; otherwise the `LEZO_BACKEND` env var steers the
/// `auto` default. Env never overrides a programmatic/CLI choice — that
/// keeps test outcomes independent of the caller's environment.
pub fn requested_backend_kind(cfg: &RunConfig) -> Result<BackendKind> {
    if cfg.backend != BackendKind::Auto {
        return Ok(cfg.backend);
    }
    match std::env::var("LEZO_BACKEND") {
        Ok(s) if !s.is_empty() => s.parse(),
        _ => Ok(BackendKind::Auto),
    }
}

/// Resolve the backend for a run. `auto` prefers PJRT when the build has
/// the `pjrt` feature and the artifact dir exists, else falls back to the
/// native pure-Rust backend (preset looked up by `cfg.model`).
pub fn resolve_backend(cfg: &RunConfig) -> Result<ResolvedBackend> {
    let artifact_dir = std::path::PathBuf::from(cfg.artifact_dir());
    // precision: LEZO_PRECISION env wins over the config key (mirroring
    // threads/LEZO_THREADS); an unparseable env value is a hard error
    let precision = crate::runtime::backend::resolve_precision(cfg.precision)?;
    // native runs adopt the artifact dir when it exists: the spec comes
    // from its manifest (so exported sizes outside the preset list still
    // run natively) and initial params from params_init.bin /
    // pretrained.ckpt — results match across build flavors
    let native_replica = |dir: &std::path::Path| -> Result<NativeBackend> {
        let (spec, manifest) = crate::runtime::backend::resolve_model(&cfg.model, dir)?;
        let mut backend = NativeBackend::new(spec)?.with_precision(precision);
        ensure_precision(&backend, precision)?;
        if let Some(manifest) = manifest {
            backend = backend.with_artifacts(manifest)?;
        } else {
            // manifest-less dirs may still hold a pretrained.ckpt written
            // by the hermetic `lezo pretrain` path — adopt it
            backend = backend.with_checkpoint_dir(dir);
        }
        Ok(backend)
    };
    let native = |dir: std::path::PathBuf| -> Result<ResolvedBackend> {
        Ok(ResolvedBackend::Native(native_replica(&dir)?))
    };
    // a reduced-precision request must never silently run in f32: any
    // backend that cannot execute it is a hard error. PJRT is gated before
    // it is even opened (its artifact set has only f32 executables, and
    // under `--no-default-features` there is no instance to ask); every
    // *constructed* backend is additionally checked through the
    // capability-driven [`ensure_precision`], which is what a future
    // backend inherits by construction.
    let check_pjrt_precision = || -> Result<()> {
        ensure!(
            precision == Precision::F32,
            "backend=pjrt has no {precision} executables (precision is a native-backend \
             capability); use backend=native or precision=f32"
        );
        Ok(())
    };
    match requested_backend_kind(cfg)? {
        BackendKind::Native => native(artifact_dir),
        BackendKind::Sharded => {
            // N identically configured replicas: each goes through the same
            // precision/artifact adoption as a native run, so every replica
            // starts from the same bits as the run backend=native would
            let shards = crate::runtime::sharded::resolve_shards(cfg.shards)?;
            match cfg.shard_transport {
                crate::config::ShardTransport::Thread => {
                    let replicas = (0..shards)
                        .map(|_| native_replica(&artifact_dir))
                        .collect::<Result<Vec<_>>>()?;
                    Ok(ResolvedBackend::Sharded(ShardedBackend::from_replicas(replicas)?))
                }
                crate::config::ShardTransport::Socket => {
                    // one local replica answers reads/FO; remote `lezo
                    // worker` processes (one per shard) run the plan evals.
                    // The effective fault string travels to the workers at
                    // INIT so net faults are injected worker-side.
                    let addrs = cfg.worker_addrs();
                    ensure!(
                        shards >= 2,
                        "shard_transport=socket with shards=1 has no remote fan-out to \
                         tolerate faults on; use shard_transport=thread for a single shard, \
                         or set the `shards` config key (or LEZO_SHARDS) to >= 2"
                    );
                    ensure!(
                        addrs.len() == shards,
                        "socket transport needs one worker address per shard: the `workers` \
                         key lists {} address(es) but the resolved shard count is {shards} \
                         (adjust one of them, or unset LEZO_SHARDS if it is overriding)",
                        addrs.len()
                    );
                    let faults =
                        crate::coordinator::faults::resolve_faults_string(&cfg.faults)?;
                    let opts = crate::runtime::transport::SocketOpts {
                        workers: addrs,
                        model: cfg.model.clone(),
                        precision,
                        artifact_dir: cfg.artifact_dir(),
                        faults,
                        timeout_ms: cfg.net_timeout_ms,
                        retries: cfg.net_retries,
                    };
                    let backend =
                        ShardedBackend::connect_socket(native_replica(&artifact_dir)?, &opts)?;
                    Ok(ResolvedBackend::Sharded(backend))
                }
            }
        }
        BackendKind::Pjrt => {
            check_pjrt_precision()?;
            #[cfg(feature = "pjrt")]
            {
                let backend = crate::runtime::PjrtBackend::open(&artifact_dir)?;
                ensure_precision(&backend, precision)?;
                Ok(ResolvedBackend::Pjrt(backend))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = artifact_dir;
                bail!(
                    "backend=pjrt requested but this binary was built without the `pjrt` \
                     feature; rebuild with `cargo build --features pjrt` or use backend=native"
                )
            }
        }
        BackendKind::Auto => {
            // auto is capability-driven: prefer PJRT when artifacts exist,
            // unless the requested precision is something only the native
            // backend executes — then fall back to native instead of
            // erroring about a backend the user never asked for
            #[cfg(feature = "pjrt")]
            if crate::runtime::backend::artifacts_available(&artifact_dir) {
                if precision == Precision::F32 {
                    let backend = crate::runtime::PjrtBackend::open(&artifact_dir)?;
                    ensure_precision(&backend, precision)?;
                    return Ok(ResolvedBackend::Pjrt(backend));
                }
                crate::info!(
                    "backend=auto: artifacts present, but precision={precision} runs on the \
                     native backend only — using native"
                );
            }
            native(artifact_dir)
        }
    }
}

/// Capability gate shared by every resolved backend: requesting a
/// precision the backend cannot execute ([`Backend::supports_precision`])
/// is a hard error, never a silent f32 run.
fn ensure_precision<B: Backend>(backend: &B, precision: Precision) -> Result<()> {
    ensure!(
        backend.supports_precision(precision),
        "the {} backend cannot execute precision={precision} \
         (Backend::supports_precision); use backend=native or precision=f32",
        backend.name()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Crash safety: resume resolution, config fingerprint, runtime guards
// ---------------------------------------------------------------------------

/// NaN-safe "is `m` a better metric than `best`?" fold. `f64::max` silently
/// drops a NaN operand (IEEE returns the other one), which both hides a broken
/// eval and lets a NaN `best` survive forever; here a NaN metric is reported
/// loudly and never wins, while a NaN `best` yields to the first finite metric.
fn better_metric(best: f64, m: f64) -> f64 {
    if m.is_nan() {
        crate::info!("eval metric is NaN — kept in history but excluded from best-metric selection");
        return best;
    }
    if best.is_nan() || m.total_cmp(&best).is_gt() {
        m
    } else {
        best
    }
}

/// Trailing window the divergence guard averages over.
const DIVERGENCE_WINDOW: usize = 8;

/// Divergence guard: once at least [`DIVERGENCE_WINDOW`] finite losses exist,
/// halt when their trailing mean exceeds `factor` times the first finite loss.
/// A pure function of the loss record, so a resumed run (whose record is fully
/// restored) halts at exactly the step the uninterrupted run would.
fn divergence_reason(losses: &[f32], factor: f64) -> Option<String> {
    let finite: Vec<f64> = losses.iter().filter(|l| l.is_finite()).map(|&l| l as f64).collect();
    if finite.len() < DIVERGENCE_WINDOW {
        return None;
    }
    let start = finite[0];
    if start <= 0.0 {
        return None; // no positive loss scale to take a multiple of
    }
    let tail = &finite[finite.len() - DIVERGENCE_WINDOW..];
    let smoothed = tail.iter().sum::<f64>() / tail.len() as f64;
    (smoothed > factor * start).then(|| {
        format!(
            "smoothed loss {smoothed:.4} (mean of last {DIVERGENCE_WINDOW} finite losses) \
             exceeds divergence_factor={factor} x start loss {start:.4}"
        )
    })
}

/// Canonical fingerprint of everything that shapes a training trajectory.
/// Stored verbatim in every [`TrainState`] so resuming under a different run
/// configuration is rejected with an error naming the differing field — a
/// hash could only say "something differs".
///
/// Execution-geometry keys (`threads`, `shards`, `shard_transport`,
/// `workers`, `net_timeout_ms`, `net_retries`) are deliberately absent:
/// the native kernels are thread-count invariant and the sharded backend is
/// bit-identical to native at any shard count and over either transport, so
/// a run may resume under a different worker geometry — including moving
/// between in-process and socket shards — and still land on the same
/// trajectory. The
/// backend *name* stays in (native and sharded print the same bits, but a
/// fingerprint should say what actually executed the checkpointed steps).
fn run_config_string(
    cfg: &RunConfig,
    backend: &str,
    precision: Precision,
    zo_opt: ZoOptKind,
) -> String {
    format!(
        "model={} task={} method={} peft={} backend={backend} precision={precision} \
         zo_opt={zo_opt} drop_layers={} lr={} mu={} steps={} eval_every={} eval_examples={} \
         train_examples={} seed={} mean_len={} blocks_only={} policy={} smezo_keep={} \
         adam_beta1={} adam_beta2={} adam_eps={} checkpoint={}",
        cfg.model,
        cfg.task,
        cfg.method,
        cfg.peft,
        cfg.drop_layers,
        cfg.lr,
        cfg.mu,
        cfg.steps,
        cfg.eval_every,
        cfg.eval_examples,
        cfg.train_examples,
        cfg.seed,
        cfg.mean_len,
        cfg.blocks_only,
        cfg.policy,
        cfg.smezo_keep,
        cfg.adam_beta1,
        cfg.adam_beta2,
        cfg.adam_eps,
        cfg.checkpoint,
    )
}

/// Reject resume when the stored fingerprint differs, naming the first
/// differing `key=value` pair.
fn ensure_same_config(stored: &str, current: &str) -> Result<()> {
    if stored == current {
        return Ok(());
    }
    for (s, c) in stored.split_whitespace().zip(current.split_whitespace()) {
        if s != c {
            let key = c.split('=').next().unwrap_or(c);
            bail!(
                "cannot resume: the checkpoint was written under a different run config \
                 ({key}: checkpoint has '{s}', this run has '{c}'); use resume=never to \
                 start fresh"
            );
        }
    }
    bail!("cannot resume: the checkpoint's config fingerprint has a different shape than this run's");
}

/// Resolve the `resume` mode: `never` ignores any saved state, `auto` loads
/// the run's own `train_state.ckpt` when present, and anything else is an
/// explicit state path — whose absence is an error, because an explicit ask
/// must never silently start fresh.
fn resolve_resume(resume: &str, state_path: &Path) -> Result<Option<TrainState>> {
    match resume {
        "never" => Ok(None),
        "auto" => {
            if state_path.exists() {
                Ok(Some(checkpoint::load_state(state_path)?))
            } else {
                Ok(None)
            }
        }
        explicit => {
            let p = Path::new(explicit);
            ensure!(p.exists(), "resume={explicit}: no such train-state file");
            Ok(Some(checkpoint::load_state(p)?))
        }
    }
}

/// Write the train state, honoring injected save faults. An io error — real
/// or injected — is warn-and-continue: training still holds everything in
/// memory and the next `save_every` boundary retries. `crash@K:mid-save`
/// instead leaves a torn temp file (never the final path) and then crashes,
/// which is exactly what the atomic-rename protocol must survive on resume.
fn write_state(path: &Path, st: &TrainState, faults: &mut FaultPlan, s1: u64) -> Result<()> {
    let res = match faults.on_save_attempt(s1) {
        SaveFault::MidSave => {
            let bytes = st.to_bytes();
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent).ok();
            }
            std::fs::write(checkpoint::tmp_path(path), &bytes[..bytes.len() / 2]).ok();
            bail!("{CRASH_MARKER}: crash@{s1}:mid-save fault fired (torn temp file left behind)");
        }
        SaveFault::IoErr => Err(anyhow::anyhow!("injected io error (io-err@save)")),
        SaveFault::None => checkpoint::save_state(path, st),
    };
    if let Err(e) = res {
        crate::info!(
            "checkpoint save at step {s1} failed ({e:#}); training continues, the next \
             save_every boundary retries"
        );
    }
    Ok(())
}

fn to_hist(history: &[EvalPoint]) -> Vec<HistPoint> {
    history
        .iter()
        .map(|p| HistPoint {
            step: p.step,
            train_secs: p.train_secs,
            metric: p.metric,
            train_loss: p.train_loss,
        })
        .collect()
}

fn from_hist(history: &[HistPoint]) -> Vec<EvalPoint> {
    history
        .iter()
        .map(|h| EvalPoint {
            step: h.step,
            train_secs: h.train_secs,
            metric: h.metric,
            train_loss: h.train_loss,
        })
        .collect()
}

/// Trainer: configured once, `run()` executes the whole fine-tuning run.
pub struct Trainer {
    pub cfg: RunConfig,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> Trainer {
        Trainer { cfg }
    }

    /// Execute the configured run end to end on the resolved backend.
    pub fn run(&self) -> Result<TrainReport> {
        // surface a bad LEZO_THREADS as a clean CLI error up front (the
        // kernel-entry check would only panic mid-run)
        crate::runtime::native::parallel::check_env()?;
        // `threads` config key -> native kernel worker count (0 = auto),
        // scoped to this run via a thread-local override so concurrent
        // runs in one process cannot clobber each other; LEZO_THREADS
        // still wins at kernel entry. Library users driving `run_with`
        // directly use `parallel::with_threads` / `parallel::set_threads`.
        crate::runtime::native::parallel::with_threads(self.cfg.threads, || {
            match resolve_backend(&self.cfg)? {
                ResolvedBackend::Native(b) => self.run_with(&b),
                ResolvedBackend::Sharded(b) => self.run_with(&b),
                #[cfg(feature = "pjrt")]
                ResolvedBackend::Pjrt(b) => self.run_with(&b),
            }
        })
    }

    /// Execute the configured run on a caller-supplied backend.
    pub fn run_with<B: Backend>(&self, backend: &B) -> Result<TrainReport> {
        let cfg = &self.cfg;
        cfg.validate()?;
        // a bad LEZO_ZO_OPT is a hard error for every method (same
        // strictness as LEZO_THREADS / LEZO_PRECISION), even when the run
        // would never consult it
        crate::coordinator::optim::env_zo_opt()?;
        // same rule for the fault plan: a bad `faults` key or LEZO_FAULTS
        // env value fails every method up front, naming the variable
        let faults = FaultPlan::resolve(&cfg.faults)?;
        let spec = backend.spec().clone();
        let task = make_task(&cfg.task)?;
        let evals = eval_set(task.as_ref(), cfg.seed, cfg.eval_examples, cfg.mean_len);
        let (host_init, source) = backend.initial_params(&cfg.checkpoint)?;
        crate::info!(
            "run: backend={} model={} task={} method={} peft={} n_drop={} lr={} mu={} steps={} seed={} init={}",
            backend.name(), spec.name, cfg.task, cfg.method, cfg.peft, cfg.drop_layers,
            cfg.lr, cfg.mu, cfg.steps, cfg.seed, source
        );

        match cfg.method {
            Method::ZeroShot => {
                self.run_no_train(backend, &spec, task.as_ref(), &evals, &host_init, false)
            }
            Method::Icl => {
                self.run_no_train(backend, &spec, task.as_ref(), &evals, &host_init, true)
            }
            Method::Ft => self.run_fo(backend, &spec, task.as_ref(), &evals, host_init, faults),
            Method::Mezo | Method::Lezo | Method::Smezo => {
                self.run_zo(backend, &spec, task.as_ref(), &evals, host_init, faults)
            }
        }
    }

    // ---- no-training baselines ---------------------------------------------

    fn run_no_train<B: Backend>(
        &self,
        backend: &B,
        spec: &ModelSpec,
        task: &dyn crate::tasks::Task,
        evals: &[Example],
        host_init: &[Vec<f32>],
        use_icl: bool,
    ) -> Result<TrainReport> {
        // same "error, not silence" rule as the `ft-lora` alias rejection:
        // the no-training baselines score the base model only
        ensure!(
            self.cfg.peft == PeftMode::Full,
            "method={} evaluates the base model and cannot compose with peft={} \
             (zero-init adapters would be scored as if they mattered)",
            self.cfg.method,
            self.cfg.peft
        );
        let units = TunableUnits::from_host(backend, host_init)?;
        let ev = Evaluator::new(backend);
        let examples = if use_icl {
            let budget = *spec.seq_buckets.iter().max().unwrap();
            icl::icl_eval_set(task, self.cfg.seed, self.cfg.icl_shots, evals, budget)
        } else {
            evals.to_vec()
        };
        let metric = ev.evaluate(task.kind(), &units.unit_refs(), &examples)?;
        Ok(TrainReport {
            task: self.cfg.task.clone(),
            method: self.cfg.method,
            backend: backend.name(),
            precision: backend.precision(),
            metric_kind: metric.kind,
            final_metric: metric.value,
            best_metric: metric.value,
            history: vec![EvalPoint { step: 0, train_secs: 0.0, metric: metric.value, train_loss: 0.0 }],
            losses: vec![],
            stage_times: StageTimes::default(),
            train_secs: 0.0,
            active_param_fraction: 0.0,
            mean_input_len: crate::stats::mean(
                &examples.iter().map(|e| e.prompt.len() as f64).collect::<Vec<_>>(),
            ),
            fo_state_bytes: 0,
            zo_state_bytes: 0,
            zo_opt: ZoOptKind::Sgd,
            resumed_from: None,
        })
    }

    // ---- shared loop plumbing ----------------------------------------------

    /// Deterministic training pool + per-step batch sampler.
    fn train_pool(&self, task: &dyn crate::tasks::Task) -> Vec<Example> {
        let mut rng = Rng::new(derive(self.cfg.seed, purpose::DATA, 1));
        (0..self.cfg.train_examples.max(self.cfg.steps.min(64)))
            .map(|_| task.gen(&mut rng, self.cfg.mean_len))
            .collect()
    }

    fn sample_batch(
        &self,
        pool: &[Example],
        rng: &mut Rng,
        spec: &ModelSpec,
    ) -> Result<(Batch, f64)> {
        let rows = spec.train_batch;
        let instances: Vec<_> =
            (0..rows).map(|_| rng.choice(pool).train_instance()).collect();
        let mean_prompt = crate::stats::mean(
            &instances.iter().map(|i| i.prompt.len() as f64).collect::<Vec<_>>(),
        );
        let seq = bucket_for_instances(&spec.seq_buckets, &instances)?;
        Ok((Batch::from_instances(&instances, rows, seq)?, mean_prompt))
    }

    // ---- ZO (MeZO / LeZO / Sparse-MeZO) -------------------------------------

    fn run_zo<B: Backend>(
        &self,
        backend: &B,
        spec: &ModelSpec,
        task: &dyn crate::tasks::Task,
        evals: &[Example],
        host_init: Vec<Vec<f32>>,
        mut faults: FaultPlan,
    ) -> Result<TrainReport> {
        let cfg = &self.cfg;
        if cfg.method == Method::Mezo && cfg.drop_layers != 0 {
            bail!("MeZO is LeZO with drop_layers=0; got drop_layers={}", cfg.drop_layers);
        }
        // the update rule: LEZO_ZO_OPT env wins over the config key
        let zo_kind = resolve_zo_opt(cfg.zo_opt)?;
        if cfg.method == Method::Smezo {
            ensure!(cfg.drop_layers == 0, "Sparse-MeZO masks elements, not layers");
            ensure!(cfg.peft == PeftMode::Full, "Sparse-MeZO baseline is full-parameter");
            ensure!(
                zo_kind == ZoOptKind::Sgd,
                "Sparse-MeZO runs the masked classic rule only and cannot compose with \
                 zo_opt={zo_kind} (the element-wise mask bypasses the optimizer zoo); \
                 set the `zo_opt` config key to zo-sgd — or unset the LEZO_ZO_OPT env \
                 var, which overrides it — valid rules: {}",
                crate::coordinator::optim::ZO_OPT_NAMES
            );
        }
        let mut optimizer: Box<dyn ZoOptimizer> = match zo_kind {
            // reuse the FT baseline's adam_* config keys
            ZoOptKind::Adam => Box::new(ZoAdam::new(cfg.adam_beta1, cfg.adam_beta2, cfg.adam_eps)),
            k => make_optimizer(k),
        };

        // Sparse-MeZO: per-unit magnitude thresholds (the ranking step whose
        // cost the paper criticizes — timed into `other_secs`).
        let mut times = StageTimes::default();
        let taus: Vec<f32> = if cfg.method == Method::Smezo {
            let sw = crate::util::Stopwatch::start();
            let t: Vec<f32> = host_init
                .iter()
                .map(|u| {
                    let mut mags: Vec<f32> = u.iter().map(|x| x.abs()).collect();
                    // total_cmp: a NaN weight must not panic the ranking
                    // (NaNs sort above every |w|, so they stay masked out)
                    mags.sort_by(f32::total_cmp);
                    let idx = ((mags.len() as f64 - 1.0) * cfg.smezo_keep) as usize;
                    mags[idx]
                })
                .collect();
            times.other_secs += sw.secs();
            crate::info!("smezo: ranked {} units in {:.2}s", t.len(), times.other_secs);
            t
        } else {
            vec![]
        };

        // Tunable space: model units (full fine-tuning) or per-block adapter
        // units over frozen base units (PEFT).
        let (mut tunable, base) = self.tunable_space(backend, spec, &host_init)?;
        let mut selector = self.selector(spec, &tunable)?;
        let mut engine = SpsaEngine::new(backend, cfg.mu as f32, cfg.seed)?;
        engine.on_nonfinite = cfg.on_nonfinite;
        let evaluator = Evaluator::with_peft(backend, cfg.peft);

        let pool = self.train_pool(task);
        let mut data_rng = Rng::new(derive(cfg.seed, purpose::DATA, 2));
        let mut history = Vec::new();
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut grads: Vec<f32> = Vec::with_capacity(cfg.steps);
        let mut skipped: Vec<bool> = Vec::with_capacity(cfg.steps);
        let mut frac_acc = 0.0f64;
        let mut len_acc = 0.0f64;

        backend.warm_zo().ok(); // exclude one-time setup from step timing

        let eval_now = |tun: &TunableUnits<B>| -> Result<EvalMetric> {
            let mut units: Vec<&B::Buffer> = Vec::new();
            if let Some(base) = &base {
                units.extend(base.iter());
            }
            units.extend(tun.bufs.iter());
            evaluator.evaluate(task.kind(), &units, evals)
        };

        // ---- resume: restore params + replay the scalar trajectory --------
        // A TrainState stores no RNG and no parameter-sized optimizer state:
        // perturbations are regenerated from (seed, step) and every consumer
        // of history — the data RNG, the selector scores, the seed-replay
        // optimizer windows — is rebuilt by replaying the recorded scalar
        // projected gradients in order. That makes resume bit-identical by
        // construction rather than by serializing every moving part.
        let state_path = PathBuf::from(cfg.artifact_dir()).join("train_state.ckpt");
        let conf = run_config_string(cfg, backend.name(), backend.precision(), zo_kind);
        let start_step: u64 = match resolve_resume(&cfg.resume, &state_path)? {
            Some(st) => {
                ensure!(
                    st.kind == "zo",
                    "cannot resume: the state was written by a '{}' run, this is a ZO run",
                    st.kind
                );
                ensure_same_config(&st.config, &conf)?;
                ensure!(
                    st.step <= cfg.steps as u64,
                    "cannot resume: the state holds {} completed steps but steps={}",
                    st.step,
                    cfg.steps
                );
                ensure!(
                    st.params.len() == tunable.n_units()
                        && st.params.iter().map(Vec::len).eq(tunable.lens.iter().copied()),
                    "cannot resume: state param shapes do not match the tunable space"
                );
                for (k, u) in st.params.iter().enumerate() {
                    tunable.bufs[k] = backend.upload(u)?;
                }
                for s in 0..st.step {
                    let (_batch, mean_prompt) = self.sample_batch(&pool, &mut data_rng, spec)?;
                    let active = selector.next_active(s);
                    frac_acc += active.iter().map(|&k| tunable.lens[k]).sum::<usize>() as f64
                        / tunable.param_count() as f64;
                    len_acc += mean_prompt;
                    // skipped steps perturbed nothing and fed back nothing —
                    // only their batch sampling and unit selection happened
                    if !st.skipped[s as usize] {
                        let g = st.grads[s as usize];
                        if optimizer.stateful() {
                            let _ = optimizer.coeffs(s, &[g], &active, cfg.lr as f32);
                        }
                        selector.feedback(&active, g);
                    }
                }
                losses = st.losses;
                grads = st.grads;
                skipped = st.skipped;
                history = from_hist(&st.history);
                let [p, f, u, o] = st.stage_secs;
                times = StageTimes {
                    perturb_secs: p,
                    forward_secs: f,
                    update_secs: u,
                    other_secs: o,
                    rt_secs: 0.0, // diagnostic split, not persisted in state
                    steps: st.stage_steps,
                };
                crate::info!(
                    "resumed from step {} ({} of {} steps done, state {})",
                    st.step,
                    st.step,
                    cfg.steps,
                    state_path.display()
                );
                st.step
            }
            None => 0,
        };

        if start_step == 0 {
            let m0 = eval_now(&tunable)?;
            history.push(EvalPoint { step: 0, train_secs: 0.0, metric: m0.value, train_loss: 0.0 });
        }
        let mut best = f64::NAN;
        for p in &history {
            best = better_metric(best, p.metric);
        }

        for step in start_step..cfg.steps as u64 {
            let s1 = step + 1;
            // batch sampling/selection is bookkeeping, not model compute —
            // one StageTimer lap books it into `other` (exactly like
            // run_fo), and the engine fills perturb/forward/update. All
            // training time flows through `times`, so `train_secs` below is
            // `times.total()` by construction and the two can never
            // disagree — the invariant the FT baseline already pins.
            let mut t = StageTimer::start();
            let (batch, mean_prompt) = self.sample_batch(&pool, &mut data_rng, spec)?;
            let prepared = backend.prepare_batch(&batch)?;
            let active = selector.next_active(step);
            frac_acc += active.iter().map(|&k| tunable.lens[k]).sum::<usize>() as f64
                / tunable.param_count() as f64;
            len_acc += mean_prompt;
            times.other_secs += t.lap();

            let faults_ro = &faults;
            let mut fwd_calls = 0u32;
            let mut loss_fn = |tun: &TunableUnits<B>| -> Result<f32> {
                fwd_calls += 1;
                if fwd_calls == 1 {
                    // the first forward of a step runs on the +mu-perturbed
                    // params: the post-perturb crash boundary, and where an
                    // injected NaN loss enters the engine
                    faults_ro.check_crash(s1, CrashPhase::PostPerturb)?;
                    if faults_ro.nan_loss_at(s1) {
                        return Ok(f32::NAN);
                    }
                }
                let mut args: Vec<&B::Buffer> = Vec::new();
                if let Some(base) = &base {
                    args.extend(base.iter());
                }
                args.extend(tun.bufs.iter());
                backend.forward_loss(cfg.peft, &args, &prepared)
            };

            let zs = if cfg.method == Method::Smezo {
                // Sparse-MeZO's element-wise masked sweeps stay on the
                // sequential path on every backend (sharded broadcasts
                // them, so lockstep holds without fan-out)
                engine.zo_step_masked(step, &mut tunable, &taus, cfg.lr as f32, &mut loss_fn, &mut times)?
            } else if backend.supports_plan_fanout() {
                // plan fan-out: the backend owns the step execution and the
                // trainer's fault hook replaces the loss_fn counter — eval 0
                // is the step's first forward (the +mu point), exactly where
                // the sequential path's `fwd_calls == 1` boundary sits
                let mut inject = |e: usize| -> Result<Option<f32>> {
                    if e == 0 {
                        faults_ro.check_crash(s1, CrashPhase::PostPerturb)?;
                        if faults_ro.nan_loss_at(s1) {
                            return Ok(Some(f32::NAN));
                        }
                    }
                    Ok(None)
                };
                engine.zo_step_fanout(
                    step,
                    &mut tunable,
                    &active,
                    cfg.lr as f32,
                    optimizer.as_mut(),
                    cfg.peft,
                    base.as_deref(),
                    &prepared,
                    &mut inject,
                    &mut times,
                )?
            } else {
                engine.zo_step_opt(
                    step,
                    &mut tunable,
                    &active,
                    cfg.lr as f32,
                    optimizer.as_mut(),
                    &mut loss_fn,
                    &mut times,
                )?
            };
            if zs.skipped {
                crate::info!(
                    "step {s1}: non-finite loss — perturbation restored, update skipped \
                     (on_nonfinite=skip-step)"
                );
                skipped.push(true);
                grads.push(f32::NAN);
            } else {
                selector.feedback(&active, zs.projected_grad);
                skipped.push(false);
                grads.push(zs.projected_grad);
            }
            losses.push(zs.loss());

            if cfg.divergence_factor > 0.0 {
                if let Some(why) = divergence_reason(&losses, cfg.divergence_factor) {
                    bail!("divergence halt at step {s1}: {why} (lower lr or raise divergence_factor)");
                }
            }

            if s1 % cfg.eval_every as u64 == 0 || s1 == cfg.steps as u64 {
                let m = eval_now(&tunable)?;
                best = better_metric(best, m.value);
                history.push(EvalPoint {
                    step: s1,
                    train_secs: times.total(),
                    metric: m.value,
                    train_loss: zs.loss(),
                });
                crate::info!(
                    "step {s1}: loss={:.4} {}={:.1}% ({:.1}s train)",
                    zs.loss(), m.kind, m.pct(), times.total()
                );
            }
            faults.check_crash(s1, CrashPhase::PostEval)?;

            if cfg.save_every > 0 && s1 % cfg.save_every as u64 == 0 && s1 < cfg.steps as u64 {
                let mut ts = StageTimer::start();
                faults.check_crash(s1, CrashPhase::PreSave)?;
                let st = TrainState {
                    config: conf.clone(),
                    kind: "zo".into(),
                    step: s1,
                    params: tunable.to_host(backend)?,
                    losses: losses.clone(),
                    grads: grads.clone(),
                    skipped: skipped.clone(),
                    history: to_hist(&history),
                    stage_secs: [
                        times.perturb_secs,
                        times.forward_secs,
                        times.update_secs,
                        times.other_secs,
                    ],
                    stage_steps: times.steps,
                    ..Default::default()
                };
                write_state(&state_path, &st, &mut faults, s1)?;
                times.other_secs += ts.lap();
            }
            faults.check_crash(s1, CrashPhase::End)?;
        }

        if cfg.save_every > 0 || start_step > 0 {
            // a completed run leaves no state behind: resume=auto on the next
            // invocation starts fresh instead of resurrecting a finished run
            std::fs::remove_file(&state_path).ok();
        }

        let final_metric = history.last().map(|p| p.metric).unwrap_or(f64::NAN);
        Ok(TrainReport {
            task: cfg.task.clone(),
            method: cfg.method,
            backend: backend.name(),
            precision: backend.precision(),
            metric_kind: if task.kind() == TaskKind::Generation { "f1" } else { "acc" },
            final_metric,
            best_metric: best,
            history,
            losses,
            train_secs: times.total(),
            stage_times: times,
            active_param_fraction: frac_acc / cfg.steps.max(1) as f64,
            mean_input_len: len_acc / cfg.steps.max(1) as f64,
            fo_state_bytes: 0,
            zo_state_bytes: optimizer.state_bytes(),
            zo_opt: zo_kind,
            resumed_from: (start_step > 0).then_some(start_step),
        })
    }

    /// The tunable parameter space: the model units (full fine-tuning) or
    /// the per-block adapter units (PEFT). Returns (tunable, frozen base
    /// units when they must prefix every forward call).
    #[allow(clippy::type_complexity)]
    fn tunable_space<B: Backend>(
        &self,
        backend: &B,
        spec: &ModelSpec,
        host_init: &[Vec<f32>],
    ) -> Result<(TunableUnits<B>, Option<Vec<B::Buffer>>)> {
        match self.cfg.peft {
            PeftMode::Full => Ok((TunableUnits::from_host(backend, host_init)?, None)),
            mode => {
                ensure!(
                    backend.supports_peft(mode),
                    "the {} backend cannot run peft={mode} for this model (the pjrt backend \
                     needs adapter executables: re-export with `python -m compile.aot` — \
                     without `--no-peft`; the native backend runs every mode)",
                    backend.name()
                );
                // backend-authoritative: PJRT cross-checks the manifest's
                // exported adapter length against the in-crate layout
                let len = backend.peft_unit_len(mode)?;
                let host = crate::peft::init_peft_units(
                    mode,
                    spec.n_layers,
                    spec.d_model,
                    self.cfg.seed,
                );
                let bufs = host.iter().map(|u| backend.upload(u)).collect::<Result<Vec<_>>>()?;
                let base = host_init
                    .iter()
                    .map(|u| backend.upload(u))
                    .collect::<Result<Vec<_>>>()?;
                Ok((
                    TunableUnits { bufs, lens: vec![len; spec.n_layers] },
                    Some(base),
                ))
            }
        }
    }

    /// The layer selector over the tunable space (paper §4.1). Under full
    /// fine-tuning, blocks are sparsifiable and embedding/final-LN are
    /// always active (unless blocks_only=false). Under PEFT every per-block
    /// adapter unit is sparsifiable.
    fn selector<B: Backend>(
        &self,
        spec: &ModelSpec,
        tunable: &TunableUnits<B>,
    ) -> Result<PolicySelector> {
        let cfg = &self.cfg;
        match cfg.peft {
            PeftMode::Full => {
                let (sparsifiable, always) = if cfg.blocks_only {
                    (spec.block_unit_indices(), vec![0, spec.n_units() - 1])
                } else {
                    ((0..spec.n_units()).collect(), vec![])
                };
                PolicySelector::new(sparsifiable, always, cfg.drop_layers, cfg.seed, cfg.policy)
            }
            _ => PolicySelector::new(
                (0..tunable.n_units()).collect(),
                vec![],
                cfg.drop_layers,
                cfg.seed,
                cfg.policy,
            ),
        }
    }

    // ---- FO (the paper's FT baseline) ---------------------------------------

    fn run_fo<B: Backend>(
        &self,
        backend: &B,
        spec: &ModelSpec,
        task: &dyn crate::tasks::Task,
        evals: &[Example],
        mut host_params: Vec<Vec<f32>>,
        mut faults: FaultPlan,
    ) -> Result<TrainReport> {
        let cfg = &self.cfg;
        ensure!(
            cfg.peft == PeftMode::Full,
            "method=ft is full-parameter fine-tuning and cannot compose with peft={} — \
             there is no adapter backward pass yet (ROADMAP: 'PEFT backward'); it would \
             silently FO-tune the whole model under a PEFT label",
            cfg.peft
        );
        ensure!(
            backend.supports_fo(),
            "method=ft needs a first-order-capable backend (native, or pjrt with \
             forward_backward artifacts); the {} backend has no autodiff",
            backend.name()
        );
        let engine = FoEngine::new(backend);
        let mut opt = FoOptimizer::adam(cfg.adam_beta1, cfg.adam_beta2, cfg.adam_eps);
        let evaluator = Evaluator::new(backend);
        let pool = self.train_pool(task);
        let mut data_rng = Rng::new(derive(cfg.seed, purpose::DATA, 2));
        let mut history = Vec::new();
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut grads_log: Vec<f32> = Vec::with_capacity(cfg.steps);
        let mut skipped: Vec<bool> = Vec::with_capacity(cfg.steps);
        let mut train_secs = 0.0f64;
        let mut len_acc = 0.0f64;
        let mut times = StageTimes::default();

        let eval_now = |params: &[Vec<f32>]| -> Result<EvalMetric> {
            let units = TunableUnits::from_host(backend, params)?;
            evaluator.evaluate(task.kind(), &units.unit_refs(), evals)
        };

        // ---- resume: FO state is explicit (Adam moments), not replayed ----
        let state_path = PathBuf::from(cfg.artifact_dir()).join("train_state.ckpt");
        let conf = run_config_string(cfg, backend.name(), backend.precision(), ZoOptKind::Sgd);
        let start_step: u64 = match resolve_resume(&cfg.resume, &state_path)? {
            Some(st) => {
                ensure!(
                    st.kind == "fo",
                    "cannot resume: the state was written by a '{}' run, this is an ft run",
                    st.kind
                );
                ensure_same_config(&st.config, &conf)?;
                ensure!(
                    st.step <= cfg.steps as u64,
                    "cannot resume: the state holds {} completed steps but steps={}",
                    st.step,
                    cfg.steps
                );
                ensure!(
                    st.params.len() == host_params.len()
                        && st.params.iter().map(Vec::len).eq(host_params.iter().map(Vec::len)),
                    "cannot resume: state param shapes do not match the model"
                );
                host_params = st.params;
                opt.restore(st.fo_t, st.fo_m, st.fo_v);
                // only the data RNG needs replaying — fast-forward it by
                // re-sampling the already-consumed batches
                for _ in 0..st.step {
                    let (_batch, mean_prompt) = self.sample_batch(&pool, &mut data_rng, spec)?;
                    len_acc += mean_prompt;
                }
                losses = st.losses;
                grads_log = st.grads;
                skipped = st.skipped;
                history = from_hist(&st.history);
                let [p, f, u, o] = st.stage_secs;
                times = StageTimes {
                    perturb_secs: p,
                    forward_secs: f,
                    update_secs: u,
                    other_secs: o,
                    rt_secs: 0.0, // diagnostic split, not persisted in state
                    steps: st.stage_steps,
                };
                train_secs = times.total();
                crate::info!(
                    "resumed from step {} ({} of {} steps done, state {})",
                    st.step,
                    st.step,
                    cfg.steps,
                    state_path.display()
                );
                st.step
            }
            None => 0,
        };

        // step-0 eval: the FT convergence curve gets its origin point, like
        // run_zo — and `best`/`final` fall back to it, never to 0.0/f64::MIN
        if start_step == 0 {
            let m0 = eval_now(&host_params)?;
            history.push(EvalPoint { step: 0, train_secs: 0.0, metric: m0.value, train_loss: 0.0 });
        }
        let mut best = f64::NAN;
        for p in &history {
            best = better_metric(best, p.metric);
        }

        for step in start_step..cfg.steps as u64 {
            let s1 = step + 1;
            // one StageTimer, each boundary read exactly once: train_secs is
            // the sum of the same laps that feed stage_times, so the two
            // can never disagree
            let mut t = StageTimer::start();
            let (batch, mean_prompt) = self.sample_batch(&pool, &mut data_rng, spec)?;
            len_acc += mean_prompt;
            let sample_secs = t.lap();
            // FO has no perturbation sweep; the post-perturb boundary maps to
            // "after batch prep, before the fused forward+backward"
            faults.check_crash(s1, CrashPhase::PostPerturb)?;
            let (mut loss, grads) = engine.loss_and_grads(&host_params, &batch)?;
            if faults.nan_loss_at(s1) {
                loss = f32::NAN;
            }
            let grad_secs = t.lap();
            let skip = !loss.is_finite();
            if skip && cfg.on_nonfinite == NonFinitePolicy::Error {
                bail!(
                    "non-finite loss {loss} at step {s1} (method=ft); set \
                     on_nonfinite=skip-step to skip the update instead"
                );
            }
            if skip {
                crate::info!("FT step {s1}: non-finite loss — update skipped (on_nonfinite=skip-step)");
            } else {
                opt.update(&mut host_params, &grads, cfg.lr);
            }
            let update_secs = t.lap();
            // batch sampling is bookkeeping, not model compute — it lands in
            // `other` so non_forward_fraction() is comparable to ZO reports;
            // the fused forward+backward is FO's "forward" stage
            times.other_secs += sample_secs;
            times.forward_secs += grad_secs;
            times.update_secs += update_secs;
            times.steps += 1;
            train_secs += sample_secs + grad_secs + update_secs;
            losses.push(loss);
            grads_log.push(if skip { f32::NAN } else { 0.0 });
            skipped.push(skip);

            if cfg.divergence_factor > 0.0 {
                if let Some(why) = divergence_reason(&losses, cfg.divergence_factor) {
                    bail!("divergence halt at step {s1}: {why} (lower lr or raise divergence_factor)");
                }
            }

            if s1 % cfg.eval_every as u64 == 0 || s1 == cfg.steps as u64 {
                let m = eval_now(&host_params)?;
                best = better_metric(best, m.value);
                history.push(EvalPoint { step: s1, train_secs, metric: m.value, train_loss: loss });
                crate::info!("FT step {s1}: loss={loss:.4} {}={:.1}%", m.kind, m.pct());
            }
            faults.check_crash(s1, CrashPhase::PostEval)?;

            if cfg.save_every > 0 && s1 % cfg.save_every as u64 == 0 && s1 < cfg.steps as u64 {
                let mut ts = StageTimer::start();
                faults.check_crash(s1, CrashPhase::PreSave)?;
                let (fo_t, fo_m, fo_v) = opt.snapshot();
                let st = TrainState {
                    config: conf.clone(),
                    kind: "fo".into(),
                    step: s1,
                    params: host_params.clone(),
                    losses: losses.clone(),
                    grads: grads_log.clone(),
                    skipped: skipped.clone(),
                    history: to_hist(&history),
                    stage_secs: [
                        times.perturb_secs,
                        times.forward_secs,
                        times.update_secs,
                        times.other_secs,
                    ],
                    stage_steps: times.steps,
                    fo_t,
                    fo_m: fo_m.to_vec(),
                    fo_v: fo_v.to_vec(),
                };
                write_state(&state_path, &st, &mut faults, s1)?;
                // save time is training wall time: book it into both the
                // stage total and train_secs so the pinned invariant
                // `stage_times.total() == train_secs` survives checkpointing
                let secs = ts.lap();
                times.other_secs += secs;
                train_secs += secs;
            }
            faults.check_crash(s1, CrashPhase::End)?;
        }

        if cfg.save_every > 0 || start_step > 0 {
            std::fs::remove_file(&state_path).ok();
        }

        let final_metric = history.last().map(|p| p.metric).unwrap_or(f64::NAN);
        Ok(TrainReport {
            task: cfg.task.clone(),
            method: cfg.method,
            backend: backend.name(),
            precision: backend.precision(),
            metric_kind: if task.kind() == TaskKind::Generation { "f1" } else { "acc" },
            final_metric,
            best_metric: best,
            history,
            losses,
            stage_times: times,
            train_secs,
            active_param_fraction: 1.0,
            mean_input_len: len_acc / cfg.steps.max(1) as f64,
            fo_state_bytes: opt.state_bytes(),
            zo_state_bytes: 0,
            zo_opt: ZoOptKind::Sgd,
            resumed_from: (start_step > 0).then_some(start_step),
        })
    }
}

// ---------------------------------------------------------------------------
// Pretraining (in-repo substitute for OPT's pretrained weights)
// ---------------------------------------------------------------------------

/// Pretrain a model on the synthetic corpus with FO-Adam and write
/// `<cfg.artifact_dir()>/pretrained.ckpt`. All fine-tuning runs then start
/// from this checkpoint (`checkpoint::resolve_initial` picks it up under an
/// artifact manifest; the native backend's checkpoint-dir adoption picks it
/// up on fully hermetic, manifest-less runs). Runs on any FO-capable
/// backend: the native reference backward pass with zero artifacts, or the
/// PJRT `forward_backward` executables when artifacts exist.
pub fn pretrain(
    cfg: &RunConfig,
    steps: usize,
    lr: f64,
    seed: u64,
    log_every: usize,
) -> Result<(f32, f32)> {
    let dir = std::path::PathBuf::from(cfg.artifact_dir());
    crate::runtime::native::parallel::check_env()?;
    crate::runtime::native::parallel::with_threads(cfg.threads, || {
        match resolve_backend(cfg)? {
            ResolvedBackend::Native(b) => {
                // start from the same init a fresh fine-tune would use —
                // never from an existing pretrained.ckpt (that would make
                // re-pretraining silently resume from its own output)
                let init = match b.manifest() {
                    Some(m) => m.read_init_params()?,
                    None => b.spec().init_units(crate::runtime::native::NATIVE_INIT_SEED),
                };
                pretrain_with(&b, &dir, init, steps, lr, seed, log_every)
            }
            // sharding fans out ZO forward evaluations; FO pretraining has
            // exactly one forward+backward per step, so N replicas buy
            // nothing and the redirect keeps the checkpoint provenance
            // single-sourced
            ResolvedBackend::Sharded(_) => bail!(
                "pretrain is first-order and gains nothing from backend=sharded \
                 (one fused forward+backward per step); use backend=native"
            ),
            #[cfg(feature = "pjrt")]
            ResolvedBackend::Pjrt(b) => {
                let init = b.manifest().read_init_params()?;
                pretrain_with(&b, &dir, init, steps, lr, seed, log_every)
            }
        }
    })
}

/// The backend-generic pretraining loop behind [`pretrain`].
fn pretrain_with<B: Backend>(
    backend: &B,
    artifact_dir: &std::path::Path,
    mut params: Vec<Vec<f32>>,
    steps: usize,
    lr: f64,
    seed: u64,
    log_every: usize,
) -> Result<(f32, f32)> {
    use crate::data::corpus::CorpusGen;

    ensure!(
        backend.supports_fo(),
        "pretrain needs a first-order-capable backend; the {} backend has no autodiff",
        backend.name()
    );
    let spec = backend.spec();
    let engine = FoEngine::new(backend);
    let mut opt = FoOptimizer::adam(0.9, 0.999, 1e-8);
    let corpus = CorpusGen::new(spec.vocab, spec.max_seq);
    let mut rng = Rng::new(derive(seed, purpose::DATA, 0xC0));
    let seq = *spec.seq_buckets.iter().max().unwrap();
    let mut first_loss = 0.0f32;
    let mut last_loss = 0.0f32;
    for step in 0..steps {
        let docs: Vec<Vec<u32>> = (0..spec.train_batch)
            .map(|_| {
                let mut d = corpus.doc(&mut rng);
                d.truncate(seq);
                d
            })
            .collect();
        let batch = Batch::lm_batch(&docs, spec.train_batch, seq)?;
        let loss = engine.fo_step(&mut params, &batch, &mut opt, lr)?;
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        if log_every > 0 && (step + 1) % log_every == 0 {
            crate::info!("pretrain step {}: loss={loss:.4}", step + 1);
        }
    }
    checkpoint::save(&artifact_dir.join("pretrained.ckpt"), steps as u64, &params)?;
    crate::info!(
        "pretrained {} on the {} backend for {steps} steps: loss {first_loss:.3} -> {last_loss:.3}",
        spec.name,
        backend.name()
    );
    Ok((first_loss, last_loss))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_time_to_metric() {
        let mk = |step, t, m| EvalPoint { step, train_secs: t, metric: m, train_loss: 0.0 };
        let r = TrainReport {
            task: "sst2".into(),
            method: Method::Lezo,
            backend: "native",
            precision: Precision::F32,
            metric_kind: "acc",
            final_metric: 0.9,
            best_metric: 0.92,
            history: vec![mk(0, 0.0, 0.5), mk(100, 10.0, 0.8), mk(200, 20.0, 0.92)],
            losses: vec![],
            stage_times: StageTimes::default(),
            train_secs: 20.0,
            active_param_fraction: 0.5,
            mean_input_len: 20.0,
            fo_state_bytes: 0,
            zo_state_bytes: 0,
            zo_opt: ZoOptKind::Sgd,
            resumed_from: None,
        };
        assert_eq!(r.time_to_metric(0.8), Some(10.0));
        assert_eq!(r.steps_to_metric(0.9), Some(200));
        assert_eq!(r.time_to_metric(0.95), None);
    }

    #[test]
    fn mezo_rejects_nonzero_drop() {
        let mut cfg = RunConfig::default();
        cfg.model = "opt-nano".into();
        cfg.method = Method::Mezo;
        cfg.drop_layers = 3;
        cfg.steps = 1;
        assert!(Trainer::new(cfg).run().is_err());
    }

    #[test]
    fn ft_runs_on_native_backend() {
        // Until the native backward pass existed this was a hard error;
        // now the FT baseline runs hermetically, with a step-0 eval point
        // and stage times whose total matches train_secs by construction.
        let mut cfg = RunConfig::default();
        cfg.model = "opt-nano".into();
        cfg.backend = BackendKind::Native;
        cfg.method = Method::Ft;
        cfg.steps = 2;
        cfg.eval_every = 2;
        cfg.eval_examples = 4;
        cfg.train_examples = 8;
        cfg.mean_len = 8;
        cfg.lr = 1e-3;
        let r = Trainer::new(cfg).run().unwrap();
        assert_eq!(r.backend, "native");
        assert_eq!(r.losses.len(), 2);
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert_eq!(r.history.first().map(|p| p.step), Some(0), "FT curve needs its origin");
        assert!(r.best_metric >= r.history[0].metric);
        assert!(r.best_metric > f64::MIN && (0.0..=1.0).contains(&r.final_metric));
        assert!(r.fo_state_bytes > 0, "Adam state must be accounted");
        assert_eq!(r.stage_times.steps, 2);
        assert!(
            (r.stage_times.total() - r.train_secs).abs() < 1e-9,
            "stage total {} vs train {}",
            r.stage_times.total(),
            r.train_secs
        );
    }

    fn zo_nano_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.model = "opt-nano".into();
        cfg.backend = BackendKind::Native;
        cfg.method = Method::Mezo;
        cfg.steps = 2;
        cfg.eval_every = 2;
        cfg.eval_examples = 4;
        cfg.train_examples = 8;
        cfg.mean_len = 8;
        cfg.lr = 1e-4;
        cfg
    }

    #[test]
    fn zo_stage_times_match_train_secs() {
        // the ZO side of the accounting invariant the FT baseline pins:
        // sampling is booked to `other`, so the stage total IS the train
        // time — including Sparse-MeZO's pre-loop ranking cost
        for method in [Method::Mezo, Method::Smezo] {
            let mut cfg = zo_nano_cfg();
            cfg.method = method;
            let r = Trainer::new(cfg).run().unwrap();
            assert_eq!(r.stage_times.steps, 2, "{method}");
            assert!(r.stage_times.other_secs > 0.0, "{method}: sampling must be booked");
            assert!(
                (r.stage_times.total() - r.train_secs).abs() < 1e-9,
                "{method}: stage total {} vs train {}",
                r.stage_times.total(),
                r.train_secs
            );
            let last = r.history.last().unwrap();
            assert!(
                (last.train_secs - r.train_secs).abs() < 1e-9,
                "{method}: final eval point carries the same clock"
            );
        }
    }

    #[test]
    fn zo_opt_variants_run_and_report() {
        if std::env::var("LEZO_ZO_OPT").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED zo_opt_variants_run_and_report: LEZO_ZO_OPT wins");
            return;
        }
        for kind in [
            ZoOptKind::Sgd,
            ZoOptKind::Momentum,
            ZoOptKind::Adam,
            ZoOptKind::SignSgd,
            ZoOptKind::Fzoo,
        ] {
            let mut cfg = zo_nano_cfg();
            cfg.zo_opt = kind;
            let r = Trainer::new(cfg).run().unwrap();
            assert_eq!(r.zo_opt, kind);
            assert_eq!(r.losses.len(), 2, "{kind}");
            assert!(r.losses.iter().all(|l| l.is_finite()), "{kind}");
            assert!(
                (r.stage_times.total() - r.train_secs).abs() < 1e-9,
                "{kind}: accounting invariant holds for every rule"
            );
            match kind {
                ZoOptKind::Momentum | ZoOptKind::Adam => assert!(
                    r.zo_state_bytes > 0,
                    "{kind}: replay history must be accounted"
                ),
                _ => assert_eq!(r.zo_state_bytes, 0, "{kind}: stateless rule"),
            }
        }
    }

    #[test]
    fn smezo_rejects_non_sgd_zo_opt() {
        if std::env::var("LEZO_ZO_OPT").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED smezo_rejects_non_sgd_zo_opt: LEZO_ZO_OPT wins");
            return;
        }
        let mut cfg = zo_nano_cfg();
        cfg.method = Method::Smezo;
        cfg.zo_opt = ZoOptKind::Adam;
        let err = Trainer::new(cfg).run().unwrap_err().to_string();
        // actionable rejection: the offending rule, the valid set, and both
        // spellings of the knob (config key + env override)
        assert!(err.contains("zo_opt=zo-adam"), "{err}");
        assert!(err.contains(crate::coordinator::optim::ZO_OPT_NAMES), "{err}");
        assert!(err.contains("`zo_opt` config key"), "{err}");
        assert!(err.contains("LEZO_ZO_OPT"), "{err}");
    }

    #[test]
    fn sharded_trainer_run_is_bit_identical_to_native() {
        // trainer-level smoke of the tentpole invariant (the full matrix
        // lives in rust/tests/backend_comparison.rs): the whole run —
        // sampling, LeZO selection, steps, evals — under backend=sharded
        // must report the exact bits of the backend=native run
        if std::env::var("LEZO_SHARDS").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED sharded_trainer_run_is_bit_identical_to_native: LEZO_SHARDS wins");
            return;
        }
        let mut cfg = zo_nano_cfg();
        cfg.method = Method::Lezo;
        cfg.drop_layers = 1;
        let native = Trainer::new(cfg.clone()).run().unwrap();
        cfg.backend = BackendKind::Sharded;
        cfg.shards = 2;
        let sharded = Trainer::new(cfg).run().unwrap();
        assert_eq!(sharded.backend, "sharded");
        let bits = |r: &TrainReport| r.losses.iter().map(|l| l.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&native), bits(&sharded), "per-step losses must agree to_bits");
        assert_eq!(native.final_metric.to_bits(), sharded.final_metric.to_bits());
        assert_eq!(native.best_metric.to_bits(), sharded.best_metric.to_bits());
        assert_eq!(native.stage_times.steps, sharded.stage_times.steps);
    }

    #[test]
    fn trainer_rejects_panicky_configs_up_front() {
        // eval_every=0 used to be a modulo-by-zero panic mid-run in both
        // run_zo and run_fo; steps=0 an empty-pool index panic
        let mut cfg = zo_nano_cfg();
        cfg.eval_every = 0;
        let err = Trainer::new(cfg).run().unwrap_err();
        assert!(err.to_string().contains("eval_every"), "{err}");

        let mut cfg = zo_nano_cfg();
        cfg.steps = 0;
        cfg.train_examples = 0;
        let err = Trainer::new(cfg).run().unwrap_err();
        assert!(err.to_string().contains("steps"), "{err}");
    }

    #[test]
    fn peft_runs_on_native_backend() {
        // Until the native PEFT forwards existed this was a hard error;
        // now every Table-4 cell runs hermetically. The adapter units are
        // the tunable set (a tiny fraction of the model) and the frozen
        // base stays a forward argument.
        for peft in [PeftMode::Lora, PeftMode::Prefix] {
            let mut cfg = RunConfig::default();
            cfg.model = "opt-nano".into();
            cfg.backend = BackendKind::Native;
            cfg.method = Method::Lezo;
            cfg.peft = peft;
            cfg.drop_layers = 1;
            cfg.steps = 2;
            cfg.eval_every = 2;
            cfg.eval_examples = 4;
            cfg.train_examples = 8;
            cfg.mean_len = 8;
            cfg.lr = 1e-3;
            cfg.mu = 1e-2;
            let r = Trainer::new(cfg).run().unwrap();
            assert_eq!(r.backend, "native", "{peft}");
            assert_eq!(r.losses.len(), 2, "{peft}");
            assert!(r.losses.iter().all(|l| l.is_finite()), "{peft}");
            // LeZO over PEFT units: strictly fewer tunable params per step
            assert!(
                r.active_param_fraction < 1.0,
                "{peft}: dropped adapter units must shrink the active set"
            );
        }
    }

    #[test]
    fn bf16_zo_runs_on_native_backend() {
        if std::env::var("LEZO_PRECISION").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED bf16_zo_runs_on_native_backend: LEZO_PRECISION wins");
            return;
        }
        // both the dense (mezo) and sparse (lezo) sweeps: the lezo run
        // exercises the partial shadow re-cast path end to end
        for (method, drop) in [(Method::Mezo, 0usize), (Method::Lezo, 1)] {
            let mut cfg = RunConfig::default();
            cfg.model = "opt-nano".into();
            cfg.backend = BackendKind::Native;
            cfg.method = method;
            cfg.drop_layers = drop;
            cfg.precision = Precision::Bf16;
            cfg.steps = 2;
            cfg.eval_every = 2;
            cfg.eval_examples = 4;
            cfg.train_examples = 8;
            cfg.mean_len = 8;
            cfg.lr = 1e-4;
            let r = Trainer::new(cfg).run().unwrap();
            assert_eq!(r.backend, "native", "{method}");
            assert_eq!(r.precision, Precision::Bf16, "{method}");
            assert_eq!(r.losses.len(), 2, "{method}");
            assert!(r.losses.iter().all(|l| l.is_finite()), "{method}");
        }
    }

    #[test]
    fn pjrt_with_bf16_is_a_hard_error_not_a_silent_f32_run() {
        if std::env::var("LEZO_PRECISION").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED pjrt_with_bf16_is_a_hard_error: LEZO_PRECISION wins");
            return;
        }
        let mut cfg = RunConfig::default();
        cfg.model = "opt-nano".into();
        cfg.backend = BackendKind::Pjrt;
        cfg.precision = Precision::Bf16;
        let err = Trainer::new(cfg).run().unwrap_err();
        assert!(err.to_string().contains("precision"), "{err}");
    }

    #[test]
    fn quant_zo_runs_on_native_backend() {
        if std::env::var("LEZO_PRECISION").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED quant_zo_runs_on_native_backend: LEZO_PRECISION wins");
            return;
        }
        // both quantized modes, and for int8 both the dense (mezo) and
        // sparse (lezo) sweeps — the lezo run exercises the partial
        // shadow re-quantization path end to end
        for (precision, method, drop) in [
            (Precision::Int8, Method::Mezo, 0usize),
            (Precision::Int8, Method::Lezo, 1),
            (Precision::Int4, Method::Mezo, 0),
        ] {
            let mut cfg = RunConfig::default();
            cfg.model = "opt-nano".into();
            cfg.backend = BackendKind::Native;
            cfg.method = method;
            cfg.drop_layers = drop;
            cfg.precision = precision;
            cfg.steps = 2;
            cfg.eval_every = 2;
            cfg.eval_examples = 4;
            cfg.train_examples = 8;
            cfg.mean_len = 8;
            cfg.lr = 1e-4;
            let r = Trainer::new(cfg).run().unwrap();
            assert_eq!(r.backend, "native", "{precision}/{method}");
            assert_eq!(r.precision, precision, "{precision}/{method}");
            assert_eq!(r.losses.len(), 2, "{precision}/{method}");
            assert!(r.losses.iter().all(|l| l.is_finite()), "{precision}/{method}");
        }
    }

    #[test]
    fn pjrt_with_quantized_precision_is_a_hard_error_too() {
        if std::env::var("LEZO_PRECISION").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED pjrt_with_quantized_precision_is_a_hard_error: LEZO_PRECISION wins");
            return;
        }
        // same named-key error as the bf16 arm: a quantized request must
        // never silently run pjrt's f32 executables
        for precision in [Precision::Int8, Precision::Int4] {
            let mut cfg = RunConfig::default();
            cfg.model = "opt-nano".into();
            cfg.backend = BackendKind::Pjrt;
            cfg.precision = precision;
            let err = Trainer::new(cfg).run().unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("precision"), "{precision}: {msg}");
            assert!(msg.contains(&precision.to_string()), "{precision}: {msg}");
        }
    }

    #[test]
    fn ft_and_no_train_methods_reject_peft() {
        // the two-token spelling (`method=ft peft=lora`) must be as hard an
        // error as the `ft-lora` alias: no silent full-model run under a
        // PEFT label
        for method in [Method::Ft, Method::ZeroShot, Method::Icl] {
            let mut cfg = RunConfig::default();
            cfg.model = "opt-nano".into();
            cfg.backend = BackendKind::Native;
            cfg.method = method;
            cfg.peft = PeftMode::Lora;
            cfg.steps = 1;
            let err = Trainer::new(cfg).run().unwrap_err();
            assert!(err.to_string().contains("peft"), "{method}: {err}");
        }
    }

    #[test]
    fn better_metric_never_lets_nan_win_or_survive() {
        // f64::max would keep a stale f64::MIN/NaN best forever; this fold
        // excludes NaN metrics but lets the first finite one replace a NaN
        assert_eq!(better_metric(0.5, f64::NAN), 0.5);
        assert_eq!(better_metric(f64::NAN, 0.5), 0.5);
        assert!(better_metric(f64::NAN, f64::NAN).is_nan());
        assert_eq!(better_metric(0.5, 0.7), 0.7);
        assert_eq!(better_metric(0.7, 0.5), 0.7);
    }

    #[test]
    fn divergence_reason_is_a_pure_function_of_the_loss_record() {
        // under the window: never halts, even on garbage
        assert!(divergence_reason(&[f32::NAN, 100.0], 2.0).is_none());
        // flat losses: no halt
        let flat = vec![2.0f32; 32];
        assert!(divergence_reason(&flat, 3.0).is_none());
        // losses blown up to >3x the start: halt, and the reason names both
        let mut blown = vec![2.0f32; 16];
        blown.extend(std::iter::repeat(9.0).take(DIVERGENCE_WINDOW));
        let why = divergence_reason(&blown, 3.0).expect("must halt");
        assert!(why.contains("divergence_factor=3"), "{why}");
        // NaN losses are excluded from the smoothing, not poison
        blown.push(f32::NAN);
        assert!(divergence_reason(&blown, 3.0).is_some());
        // determinism: same record, same verdict
        assert_eq!(divergence_reason(&blown, 3.0), divergence_reason(&blown, 3.0));
    }

    #[test]
    fn config_fingerprint_names_the_differing_field() {
        let mut cfg = zo_nano_cfg();
        let a = run_config_string(&cfg, "native", Precision::F32, ZoOptKind::Sgd);
        assert!(ensure_same_config(&a, &a).is_ok());
        cfg.lr = 5e-4;
        let b = run_config_string(&cfg, "native", Precision::F32, ZoOptKind::Sgd);
        let err = ensure_same_config(&a, &b).unwrap_err().to_string();
        assert!(err.contains("lr"), "{err}");
        let c = run_config_string(&cfg, "native", Precision::Bf16, ZoOptKind::Sgd);
        let err = ensure_same_config(&b, &c).unwrap_err().to_string();
        assert!(err.contains("precision"), "{err}");
    }

    #[test]
    fn explicit_resume_path_must_exist() {
        let err = resolve_resume("definitely/not/here.ckpt", Path::new("unused"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("definitely/not/here.ckpt"), "{err}");
        // auto with no state: fresh start, not an error
        assert!(resolve_resume("auto", Path::new("also/not/here.ckpt")).unwrap().is_none());
        assert!(resolve_resume("never", Path::new("also/not/here.ckpt")).unwrap().is_none());
    }

    #[test]
    fn unknown_preset_without_artifacts_errors() {
        let mut cfg = RunConfig::default();
        cfg.model = "opt-giga".into();
        cfg.backend = BackendKind::Native;
        assert!(Trainer::new(cfg).run().is_err());
    }
}

//! The training loop: data sampling, the ZO/FO engines, periodic evaluation,
//! checkpointing, and run reporting. One [`Trainer::run`] call reproduces one
//! cell of the paper's tables; the bench harness sweeps it.

use crate::config::{Method, RunConfig};
use crate::coordinator::fo::{FoEngine, FoOptimizer};
use crate::coordinator::metrics::StageTimes;
use crate::coordinator::policy::PolicySelector;
use crate::coordinator::spsa::{SpsaEngine, TunableUnits};
use crate::data::batch::{bucket_for_instances, Batch};
use crate::data::corpus::CorpusGen;
use crate::eval::{icl, EvalMetric, Evaluator};
use crate::model::{checkpoint, Manifest, ParamStore};
use crate::peft::PeftMode;
use crate::rng::{derive, purpose, Rng};
use crate::runtime::exes::{ExeRegistry, Family};
use crate::runtime::{run1, Runtime};
use crate::tasks::{eval_set, make_task, Example, TaskKind};
use anyhow::{bail, Context, Result};

/// One point on the convergence curve (Fig. 1): metric after `step` steps
/// and `train_secs` of *training* wall time (eval time excluded).
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub step: u64,
    pub train_secs: f64,
    pub metric: f64,
    pub train_loss: f32,
}

/// Everything a finished run reports; the bench harness consumes this.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub task: String,
    pub method: Method,
    pub metric_kind: &'static str,
    /// Final-checkpoint metric (paper: best-validation checkpoint; we keep
    /// both final and best).
    pub final_metric: f64,
    pub best_metric: f64,
    pub history: Vec<EvalPoint>,
    pub losses: Vec<f32>,
    pub stage_times: StageTimes,
    pub train_secs: f64,
    /// Mean fraction of parameters perturbed+updated per step (1.0 = MeZO).
    pub active_param_fraction: f64,
    /// Mean prompt token length of the training batches (Fig. 6 axis).
    pub mean_input_len: f64,
}

impl TrainReport {
    /// First training time at which the metric reached `target` (None if
    /// never) — the convergence-speedup measurement of Figs. 1 and 5.
    pub fn time_to_metric(&self, target: f64) -> Option<f64> {
        self.history.iter().find(|p| p.metric >= target).map(|p| p.train_secs)
    }

    pub fn steps_to_metric(&self, target: f64) -> Option<u64> {
        self.history.iter().find(|p| p.metric >= target).map(|p| p.step)
    }

    pub fn per_step_ms(&self) -> f64 {
        1e3 * self.stage_times.total() / self.stage_times.steps.max(1) as f64
    }
}

/// Trainer: configured once, `run()` executes the whole fine-tuning run.
pub struct Trainer {
    pub cfg: RunConfig,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> Trainer {
        Trainer { cfg }
    }

    /// Execute the configured run end to end.
    pub fn run(&self) -> Result<TrainReport> {
        let cfg = &self.cfg;
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(std::path::Path::new(&cfg.artifact_dir()))?;
        let reg = ExeRegistry::new(manifest.clone());
        let task = make_task(&cfg.task)?;
        let evals = eval_set(task.as_ref(), cfg.seed, cfg.eval_examples, cfg.mean_len);

        let (host_init, source) = checkpoint::resolve_initial(&manifest, &cfg.checkpoint)?;
        crate::info!(
            "run: model={} task={} method={} peft={} n_drop={} lr={} mu={} steps={} seed={} init={}",
            cfg.model, cfg.task, cfg.method, cfg.peft, cfg.drop_layers,
            cfg.lr, cfg.mu, cfg.steps, cfg.seed, source
        );

        match cfg.method {
            Method::ZeroShot => self.run_no_train(&rt, &reg, &manifest, task.kind(), &evals, &host_init, false, task.as_ref()),
            Method::Icl => self.run_no_train(&rt, &reg, &manifest, task.kind(), &evals, &host_init, true, task.as_ref()),
            Method::Ft => self.run_fo(&rt, &reg, &manifest, task.as_ref(), &evals, host_init),
            Method::Mezo | Method::Lezo | Method::Smezo => {
                self.run_zo(&rt, &reg, &manifest, task.as_ref(), &evals, host_init)
            }
        }
    }

    // ---- no-training baselines ---------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn run_no_train(
        &self,
        rt: &Runtime,
        reg: &ExeRegistry,
        manifest: &Manifest,
        kind: TaskKind,
        evals: &[Example],
        host_init: &[Vec<f32>],
        use_icl: bool,
        task: &dyn crate::tasks::Task,
    ) -> Result<TrainReport> {
        let store = ParamStore::from_host(rt, manifest, host_init)?;
        let ev = Evaluator::new(rt, reg);
        let examples = if use_icl {
            let budget = *manifest.seq_buckets.iter().max().unwrap();
            icl::icl_eval_set(task, self.cfg.seed, self.cfg.icl_shots, evals, budget)
        } else {
            evals.to_vec()
        };
        let metric = ev.evaluate(kind, &store.unit_refs(), &examples)?;
        Ok(TrainReport {
            task: self.cfg.task.clone(),
            method: self.cfg.method,
            metric_kind: metric.kind,
            final_metric: metric.value,
            best_metric: metric.value,
            history: vec![EvalPoint { step: 0, train_secs: 0.0, metric: metric.value, train_loss: 0.0 }],
            losses: vec![],
            stage_times: StageTimes::default(),
            train_secs: 0.0,
            active_param_fraction: 0.0,
            mean_input_len: crate::stats::mean(
                &examples.iter().map(|e| e.prompt.len() as f64).collect::<Vec<_>>(),
            ),
        })
    }

    // ---- shared loop plumbing ----------------------------------------------

    /// Deterministic training pool + per-step batch sampler.
    fn train_pool(&self, task: &dyn crate::tasks::Task) -> Vec<Example> {
        let mut rng = Rng::new(derive(self.cfg.seed, purpose::DATA, 1));
        (0..self.cfg.train_examples.max(self.cfg.steps.min(64)))
            .map(|_| task.gen(&mut rng, self.cfg.mean_len))
            .collect()
    }

    fn sample_batch(
        &self,
        pool: &[Example],
        rng: &mut Rng,
        manifest: &Manifest,
    ) -> Result<(Batch, f64)> {
        let rows = manifest.train_batch;
        let instances: Vec<_> =
            (0..rows).map(|_| rng.choice(pool).train_instance()).collect();
        let mean_prompt = crate::stats::mean(
            &instances.iter().map(|i| i.prompt.len() as f64).collect::<Vec<_>>(),
        );
        let seq = bucket_for_instances(&manifest.seq_buckets, &instances)?;
        Ok((Batch::from_instances(&instances, rows, seq)?, mean_prompt))
    }

    // ---- ZO (MeZO / LeZO) ---------------------------------------------------

    fn run_zo(
        &self,
        rt: &Runtime,
        reg: &ExeRegistry,
        manifest: &Manifest,
        task: &dyn crate::tasks::Task,
        evals: &[Example],
        host_init: Vec<Vec<f32>>,
    ) -> Result<TrainReport> {
        let cfg = &self.cfg;
        if cfg.method == Method::Mezo && cfg.drop_layers != 0 {
            bail!("MeZO is LeZO with drop_layers=0; got drop_layers={}", cfg.drop_layers);
        }
        if cfg.method == Method::Smezo {
            anyhow::ensure!(cfg.drop_layers == 0, "Sparse-MeZO masks elements, not layers");
            anyhow::ensure!(cfg.peft == PeftMode::Full, "Sparse-MeZO baseline is full-parameter");
        }
        let store = ParamStore::from_host(rt, manifest, &host_init)?;

        // Sparse-MeZO: per-unit magnitude thresholds (the ranking step whose
        // cost the paper criticizes — timed into `other_secs`).
        let mut times = StageTimes::default();
        let taus: Vec<xla::PjRtBuffer> = if cfg.method == Method::Smezo {
            let sw = crate::util::Stopwatch::start();
            let t = host_init
                .iter()
                .map(|u| {
                    let mut mags: Vec<f32> = u.iter().map(|x| x.abs()).collect();
                    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    let idx = ((mags.len() as f64 - 1.0) * cfg.smezo_keep) as usize;
                    rt.scalar_f32(mags[idx])
                })
                .collect::<Result<Vec<_>>>()?;
            times.other_secs += sw.secs();
            crate::info!("smezo: ranked {} units in {:.2}s", t.len(), times.other_secs);
            t
        } else {
            vec![]
        };

        // Tunable space + forward families, by PEFT mode.
        let (mut tunable, base_refs_needed, fwd_fam, ev_fams) = self.tunable_space(rt, manifest, &store)?;
        let mut selector = self.selector(manifest, &tunable)?;
        let engine = SpsaEngine::new(rt, reg, cfg.mu as f32, cfg.seed)?;
        let evaluator = match ev_fams {
            Some((el, pr)) => Evaluator::with_families(rt, reg, el, pr),
            None => Evaluator::new(rt, reg),
        };

        let pool = self.train_pool(task);
        let mut data_rng = Rng::new(derive(cfg.seed, purpose::DATA, 2));
        let mut history = Vec::new();
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut train_secs = 0.0f64;
        let mut best = f64::MIN;
        let mut frac_acc = 0.0f64;
        let mut len_acc = 0.0f64;

        reg.warm_zo(rt).ok(); // exclude compilation from step timing

        let eval_now = |tun: &TunableUnits| -> Result<EvalMetric> {
            let mut units: Vec<&xla::PjRtBuffer> = Vec::new();
            if base_refs_needed {
                units.extend(store.unit_refs());
            }
            units.extend(tun.bufs.iter());
            evaluator.evaluate(task.kind(), &units, evals)
        };

        let m0 = eval_now(&tunable)?;
        history.push(EvalPoint { step: 0, train_secs: 0.0, metric: m0.value, train_loss: 0.0 });
        best = best.max(m0.value);

        for step in 0..cfg.steps as u64 {
            let sw = crate::util::Stopwatch::start();
            let (batch, mean_prompt) = self.sample_batch(&pool, &mut data_rng, manifest)?;
            let tok = rt.mat_i32(&batch.tokens, batch.rows, batch.seq)?;
            let tgt = rt.mat_i32(&batch.targets, batch.rows, batch.seq)?;
            let msk = rt.mat_f32(&batch.mask, batch.rows, batch.seq)?;
            let fwd_exe = reg.get(rt, fwd_fam, batch.seq)?;
            let active = selector.next_active(step);
            frac_acc += active.iter().map(|&k| tunable.lens[k]).sum::<usize>() as f64
                / tunable.param_count() as f64;
            len_acc += mean_prompt;

            let mut loss_fn = |tun: &TunableUnits| -> Result<f32> {
                let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
                if base_refs_needed {
                    args.extend(store.unit_refs());
                }
                args.extend(tun.bufs.iter());
                args.push(&tok);
                args.push(&tgt);
                args.push(&msk);
                let out = run1(&fwd_exe, &args)?;
                rt.read_scalar_f32(&out)
            };

            let zs = if cfg.method == Method::Smezo {
                engine.zo_step_masked(step, &mut tunable, &taus, cfg.lr as f32, &mut loss_fn, &mut times)?
            } else {
                engine.zo_step(step, &mut tunable, &active, cfg.lr as f32, &mut loss_fn, &mut times)?
            };
            selector.feedback(&active, zs.projected_grad);
            losses.push(zs.loss());
            train_secs += sw.secs();

            let s1 = step + 1;
            if s1 % cfg.eval_every as u64 == 0 || s1 == cfg.steps as u64 {
                let m = eval_now(&tunable)?;
                best = best.max(m.value);
                history.push(EvalPoint {
                    step: s1,
                    train_secs,
                    metric: m.value,
                    train_loss: zs.loss(),
                });
                crate::info!(
                    "step {s1}: loss={:.4} {}={:.1}% ({:.1}s train)",
                    zs.loss(), m.kind, m.pct(), train_secs
                );
            }
        }

        let final_metric = history.last().map(|p| p.metric).unwrap_or(m0.value);
        Ok(TrainReport {
            task: cfg.task.clone(),
            method: cfg.method,
            metric_kind: if task.kind() == TaskKind::Generation { "f1" } else { "acc" },
            final_metric,
            best_metric: best,
            history,
            losses,
            stage_times: times,
            train_secs,
            active_param_fraction: frac_acc / cfg.steps.max(1) as f64,
            mean_input_len: len_acc / cfg.steps.max(1) as f64,
        })
    }

    /// The tunable parameter space: the model units (full fine-tuning) or
    /// the per-block adapter units (PEFT). Returns (tunable, whether the
    /// frozen base units prefix every forward call, forward family,
    /// optional PEFT eval families).
    fn tunable_space(
        &self,
        rt: &Runtime,
        manifest: &Manifest,
        store: &ParamStore,
    ) -> Result<(TunableUnits, bool, Family, Option<(Family, Family)>)> {
        match self.cfg.peft {
            PeftMode::Full => {
                // clone the store's buffers as the tunable set (the store
                // itself stays the canonical base for checkpointing)
                let bufs = (0..store.n_units())
                    .map(|k| {
                        let host = rt.read_vec_f32(store.unit(k))?;
                        rt.vec_f32(&host)
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok((
                    TunableUnits { bufs, lens: manifest.unit_lens.clone() },
                    false,
                    Family::ForwardLoss,
                    None,
                ))
            }
            PeftMode::Lora => {
                let len = manifest
                    .lora_unit_len
                    .context("artifacts lack LoRA executables (re-run `make artifacts`)")?;
                let host = crate::peft::init_peft_units(
                    PeftMode::Lora,
                    manifest.n_layers,
                    manifest.d_model,
                    self.cfg.seed,
                );
                let bufs = host.iter().map(|u| rt.vec_f32(u)).collect::<Result<Vec<_>>>()?;
                Ok((
                    TunableUnits { bufs, lens: vec![len; manifest.n_layers] },
                    true,
                    Family::ForwardLossLora,
                    Some((Family::ExampleLossesLora, Family::PredictLora)),
                ))
            }
            PeftMode::Prefix => {
                let len = manifest
                    .prefix_unit_len
                    .context("artifacts lack prefix executables (re-run `make artifacts`)")?;
                let host = crate::peft::init_peft_units(
                    PeftMode::Prefix,
                    manifest.n_layers,
                    manifest.d_model,
                    self.cfg.seed,
                );
                let bufs = host.iter().map(|u| rt.vec_f32(u)).collect::<Result<Vec<_>>>()?;
                Ok((
                    TunableUnits { bufs, lens: vec![len; manifest.n_layers] },
                    true,
                    Family::ForwardLossPrefix,
                    Some((Family::ExampleLossesPrefix, Family::PredictPrefix)),
                ))
            }
        }
    }

    /// The layer selector over the tunable space (paper §4.1). Under full
    /// fine-tuning, blocks are sparsifiable and embedding/final-LN are
    /// always active (unless blocks_only=false). Under PEFT every per-block
    /// adapter unit is sparsifiable.
    fn selector(&self, manifest: &Manifest, tunable: &TunableUnits) -> Result<PolicySelector> {
        let cfg = &self.cfg;
        match cfg.peft {
            PeftMode::Full => {
                let (sparsifiable, always) = if cfg.blocks_only {
                    (
                        manifest.block_unit_indices(),
                        vec![0, manifest.n_units() - 1],
                    )
                } else {
                    ((0..manifest.n_units()).collect(), vec![])
                };
                PolicySelector::new(sparsifiable, always, cfg.drop_layers, cfg.seed, cfg.policy)
            }
            _ => PolicySelector::new(
                (0..tunable.n_units()).collect(),
                vec![],
                cfg.drop_layers,
                cfg.seed,
                cfg.policy,
            ),
        }
    }

    // ---- FO (the paper's FT baseline) ---------------------------------------

    fn run_fo(
        &self,
        rt: &Runtime,
        reg: &ExeRegistry,
        manifest: &Manifest,
        task: &dyn crate::tasks::Task,
        evals: &[Example],
        mut host_params: Vec<Vec<f32>>,
    ) -> Result<TrainReport> {
        let cfg = &self.cfg;
        let engine = FoEngine::new(rt, reg);
        let mut opt = FoOptimizer::adam(cfg.adam_beta1, cfg.adam_beta2, cfg.adam_eps);
        let evaluator = Evaluator::new(rt, reg);
        let pool = self.train_pool(task);
        let mut data_rng = Rng::new(derive(cfg.seed, purpose::DATA, 2));
        let mut history = Vec::new();
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut train_secs = 0.0f64;
        let mut best = f64::MIN;
        let mut len_acc = 0.0f64;
        let mut times = StageTimes::default();

        for step in 0..cfg.steps as u64 {
            let sw = crate::util::Stopwatch::start();
            let (batch, mean_prompt) = self.sample_batch(&pool, &mut data_rng, manifest)?;
            len_acc += mean_prompt;
            let loss = engine.fo_step(&mut host_params, &batch, &mut opt, cfg.lr)?;
            losses.push(loss);
            times.forward_secs += sw.secs(); // FO has no perturb/update split
            times.steps += 1;
            train_secs += sw.secs();

            let s1 = step + 1;
            if s1 % cfg.eval_every as u64 == 0 || s1 == cfg.steps as u64 {
                let store = ParamStore::from_host(rt, manifest, &host_params)?;
                let m = evaluator.evaluate(task.kind(), &store.unit_refs(), evals)?;
                best = best.max(m.value);
                history.push(EvalPoint { step: s1, train_secs, metric: m.value, train_loss: loss });
                crate::info!("FT step {s1}: loss={loss:.4} {}={:.1}%", m.kind, m.pct());
            }
        }

        let final_metric = history.last().map(|p| p.metric).unwrap_or(0.0);
        Ok(TrainReport {
            task: cfg.task.clone(),
            method: cfg.method,
            metric_kind: if task.kind() == TaskKind::Generation { "f1" } else { "acc" },
            final_metric,
            best_metric: best,
            history,
            losses,
            stage_times: times,
            train_secs,
            active_param_fraction: 1.0,
            mean_input_len: len_acc / cfg.steps.max(1) as f64,
        })
    }
}

// ---------------------------------------------------------------------------
// Pretraining (in-repo substitute for OPT's pretrained weights)
// ---------------------------------------------------------------------------

/// Pretrain a model on the synthetic corpus with FO-Adam and write
/// `<artifact_dir>/pretrained.ckpt`. All fine-tuning runs then start from
/// this checkpoint (checkpoint::resolve_initial picks it up automatically).
pub fn pretrain(
    artifact_dir: &std::path::Path,
    steps: usize,
    lr: f64,
    seed: u64,
    log_every: usize,
) -> Result<(f32, f32)> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(artifact_dir)?;
    let reg = ExeRegistry::new(manifest.clone());
    let engine = FoEngine::new(&rt, &reg);
    let mut params = manifest.read_init_params()?;
    let mut opt = FoOptimizer::adam(0.9, 0.999, 1e-8);
    let corpus = CorpusGen::new(manifest.vocab, manifest.max_seq);
    let mut rng = Rng::new(derive(seed, purpose::DATA, 0xC0));
    let seq = *manifest.seq_buckets.iter().max().unwrap();
    let mut first_loss = 0.0f32;
    let mut last_loss = 0.0f32;
    for step in 0..steps {
        let docs: Vec<Vec<u32>> = (0..manifest.train_batch)
            .map(|_| {
                let mut d = corpus.doc(&mut rng);
                d.truncate(seq);
                d
            })
            .collect();
        let batch = Batch::lm_batch(&docs, manifest.train_batch, seq)?;
        let loss = engine.fo_step(&mut params, &batch, &mut opt, lr)?;
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        if log_every > 0 && (step + 1) % log_every == 0 {
            crate::info!("pretrain step {}: loss={loss:.4}", step + 1);
        }
    }
    checkpoint::save(&artifact_dir.join("pretrained.ckpt"), steps as u64, &params)?;
    crate::info!(
        "pretrained {} for {steps} steps: loss {first_loss:.3} -> {last_loss:.3}",
        manifest.name
    );
    Ok((first_loss, last_loss))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_time_to_metric() {
        let mk = |step, t, m| EvalPoint { step, train_secs: t, metric: m, train_loss: 0.0 };
        let r = TrainReport {
            task: "sst2".into(),
            method: Method::Lezo,
            metric_kind: "acc",
            final_metric: 0.9,
            best_metric: 0.92,
            history: vec![mk(0, 0.0, 0.5), mk(100, 10.0, 0.8), mk(200, 20.0, 0.92)],
            losses: vec![],
            stage_times: StageTimes::default(),
            train_secs: 20.0,
            active_param_fraction: 0.5,
            mean_input_len: 20.0,
        };
        assert_eq!(r.time_to_metric(0.8), Some(10.0));
        assert_eq!(r.steps_to_metric(0.9), Some(200));
        assert_eq!(r.time_to_metric(0.95), None);
    }

    #[test]
    fn mezo_rejects_nonzero_drop() {
        let mut cfg = RunConfig::default();
        cfg.method = Method::Mezo;
        cfg.drop_layers = 3;
        cfg.steps = 1;
        // fails before touching the runtime only if artifacts exist; if they
        // don't, the manifest error fires first — both are errors.
        assert!(Trainer::new(cfg).run().is_err());
    }
}

//! First-order substrate: SGD + Adam over the backend's `forward_backward`
//! family. This is the paper's "FT (12x memory)" baseline and the in-repo
//! pretraining path (DESIGN.md S11).
//!
//! Unlike the ZO hot loop, FO deliberately round-trips gradients through the
//! host: Adam moments live in Rust, mirroring the paper's point that FO
//! fine-tuning pays for gradients + optimizer state + activations while ZO
//! pays for parameters only (`metrics::MemoryModel`). Both in-tree backends
//! are FO-capable: the native backend via its reference backward pass
//! (`runtime/native/backward.rs`, zero artifacts) and PJRT via the AOT'd
//! `forward_backward` executables. A backend without autodiff would report
//! `supports_fo() == false` and the trainer refuses `method=ft` up front.

use crate::data::batch::Batch;
use crate::runtime::backend::Backend;
use anyhow::Result;

/// Which FO update rule to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FoRule {
    Sgd,
    Adam,
}

/// Adam state (one moment pair per unit), plus plain-SGD as the degenerate
/// case. Host-resident by design (see module docs).
pub struct FoOptimizer {
    rule: FoRule,
    beta1: f64,
    beta2: f64,
    eps: f64,
    t: u64,
    m: Vec<Vec<f64>>,
    v: Vec<Vec<f64>>,
}

impl FoOptimizer {
    pub fn sgd() -> FoOptimizer {
        FoOptimizer { rule: FoRule::Sgd, beta1: 0.0, beta2: 0.0, eps: 0.0, t: 0, m: vec![], v: vec![] }
    }

    pub fn adam(beta1: f64, beta2: f64, eps: f64) -> FoOptimizer {
        FoOptimizer { rule: FoRule::Adam, beta1, beta2, eps, t: 0, m: vec![], v: vec![] }
    }

    /// Bytes of optimizer state currently held (memory accounting).
    pub fn state_bytes(&self) -> usize {
        8 * (self.m.iter().map(Vec::len).sum::<usize>()
            + self.v.iter().map(Vec::len).sum::<usize>())
    }

    /// Borrow the full optimizer state for checkpointing: `(t, m, v)`.
    /// Unlike the ZO rules there is no seed-replay shortcut — FO moments are
    /// parameter-sized and must travel in the resume envelope verbatim.
    pub fn snapshot(&self) -> (u64, &[Vec<f64>], &[Vec<f64>]) {
        (self.t, &self.m, &self.v)
    }

    /// Restore checkpointed state (the inverse of [`Self::snapshot`]).
    /// Empty moment buffers mean "not yet lazily initialized" and are valid.
    pub fn restore(&mut self, t: u64, m: Vec<Vec<f64>>, v: Vec<Vec<f64>>) {
        self.t = t;
        self.m = m;
        self.v = v;
    }

    /// Apply one update in place: `params[k][i] -= lr * step(g)`.
    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f64) {
        debug_assert_eq!(params.len(), grads.len());
        match self.rule {
            FoRule::Sgd => {
                for (p, g) in params.iter_mut().zip(grads) {
                    for (pi, gi) in p.iter_mut().zip(g) {
                        *pi -= (lr * *gi as f64) as f32;
                    }
                }
            }
            FoRule::Adam => {
                if self.m.is_empty() {
                    self.m = params.iter().map(|p| vec![0.0; p.len()]).collect();
                    self.v = params.iter().map(|p| vec![0.0; p.len()]).collect();
                }
                self.t += 1;
                let bc1 = 1.0 - self.beta1.powi(self.t as i32);
                let bc2 = 1.0 - self.beta2.powi(self.t as i32);
                for k in 0..params.len() {
                    let (p, g) = (&mut params[k], &grads[k]);
                    let (m, v) = (&mut self.m[k], &mut self.v[k]);
                    for i in 0..p.len() {
                        let gi = g[i] as f64;
                        m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                        v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                        let mhat = m[i] / bc1;
                        let vhat = v[i] / bc2;
                        p[i] -= (lr * mhat / (vhat.sqrt() + self.eps)) as f32;
                    }
                }
            }
        }
    }
}

/// FO engine: runs the backend's forward_backward and applies the optimizer.
/// Parameters are mirrored on the host between steps.
pub struct FoEngine<'b, B: Backend> {
    backend: &'b B,
}

impl<'b, B: Backend> FoEngine<'b, B> {
    pub fn new(backend: &'b B) -> FoEngine<'b, B> {
        FoEngine { backend }
    }

    /// Compute (loss, grads) for a batch against host-side parameters.
    pub fn loss_and_grads(
        &self,
        host_params: &[Vec<f32>],
        batch: &Batch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        self.backend.forward_backward(host_params, batch)
    }

    /// One FO step over a host parameter mirror.
    pub fn fo_step(
        &self,
        host_params: &mut Vec<Vec<f32>>,
        batch: &Batch,
        opt: &mut FoOptimizer,
        lr: f64,
    ) -> Result<f32> {
        let (loss, grads) = self.loss_and_grads(host_params, batch)?;
        opt.update(host_params, &grads, lr);
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_moves_toward_minimum() {
        // pure optimizer math: minimize (x-3)^2 elementwise
        let mut opt = FoOptimizer::adam(0.9, 0.999, 1e-8);
        let mut p = vec![vec![0.0f32; 4]];
        for _ in 0..200 {
            let g: Vec<f32> = p[0].iter().map(|&x| 2.0 * (x - 3.0)).collect();
            opt.update(&mut p, &[g], 0.1);
        }
        for &x in &p[0] {
            assert!((x - 3.0).abs() < 0.1, "x={x}");
        }
        assert!(opt.state_bytes() > 0);
    }

    #[test]
    fn sgd_matches_hand_rule() {
        let mut opt = FoOptimizer::sgd();
        let mut p = vec![vec![1.0f32, 2.0]];
        opt.update(&mut p, &[vec![0.5, -1.0]], 0.1);
        assert!((p[0][0] - 0.95).abs() < 1e-6);
        assert!((p[0][1] - 2.1).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_matches_closed_form() {
        // After one update from zero state: m = (1-b1)g, v = (1-b2)g^2, so
        // mhat = g, vhat = g^2 and the step is exactly lr * g/(|g| + eps) —
        // a sign step scaled by lr, independent of gradient magnitude.
        let (b1, b2, eps, lr) = (0.9, 0.999, 1e-8, 0.05);
        let mut opt = FoOptimizer::adam(b1, b2, eps);
        let p0 = vec![1.0f32, -2.0, 0.5, 3.0];
        let g = vec![0.3f32, -1.7, 0.0, 4.2e-3];
        let mut p = vec![p0.clone()];
        opt.update(&mut p, &[g.clone()], lr);
        for ((&pv, &p0v), &gv) in p[0].iter().zip(&p0).zip(&g) {
            let want = p0v as f64 - lr * gv as f64 / ((gv as f64).abs() + eps);
            assert!(
                (pv as f64 - want).abs() < 1e-6,
                "{pv} vs closed form {want} (g={gv})"
            );
        }
        // zero gradient: exactly no movement (0 / (0 + eps) = 0)
        assert_eq!(p[0][2], p0[2]);
        assert_eq!(opt.state_bytes(), 2 * 8 * p0.len());
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        // run 10 Adam steps; snapshot at step 6 into a fresh optimizer and
        // finish both copies — the resumed trajectory must be bit-equal
        let grads_for =
            |p: &[Vec<f32>]| vec![p[0].iter().map(|&x| 2.0 * (x - 3.0)).collect::<Vec<f32>>()];
        let mut full_opt = FoOptimizer::adam(0.9, 0.999, 1e-8);
        let mut full_p = vec![vec![0.5f32, -1.0, 2.0]];
        let mut resumed_opt = FoOptimizer::adam(0.9, 0.999, 1e-8);
        let mut resumed_p = full_p.clone();
        for s in 0..10 {
            if s == 6 {
                let (t, m, v) = full_opt.snapshot();
                resumed_opt.restore(t, m.to_vec(), v.to_vec());
                resumed_p = full_p.clone();
            }
            let g = grads_for(&full_p);
            full_opt.update(&mut full_p, &g, 0.05);
            if s >= 6 {
                let g = grads_for(&resumed_p);
                resumed_opt.update(&mut resumed_p, &g, 0.05);
            }
        }
        assert_eq!(full_p, resumed_p, "restored Adam must continue bit-identically");
        assert_eq!(full_opt.state_bytes(), resumed_opt.state_bytes());
    }

    #[test]
    fn lr_zero_fo_step_is_an_exact_noop() {
        // The FO twin of `lr_zero_step_is_an_exact_restore_of_every_unit`:
        // a full forward_backward + Adam update at lr=0 must leave every
        // unit bit-identical (moments update, parameters do not).
        use crate::runtime::backend::Backend as _;
        use crate::runtime::NativeBackend;
        let b = NativeBackend::preset("opt-nano").unwrap();
        let eng = FoEngine::new(&b);
        let mut params = b.initial_params("").unwrap().0;
        let orig = params.clone();
        let seqs: Vec<Vec<u32>> =
            (0..2u32).map(|r| (0..12u32).map(|i| 20 + r + i).collect()).collect();
        let batch = Batch::lm_batch(&seqs, 2, 16).unwrap();
        let mut opt = FoOptimizer::adam(0.9, 0.999, 1e-8);
        let loss = eng.fo_step(&mut params, &batch, &mut opt, 0.0).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(params, orig, "lr=0 must be an exact no-op on every unit");
        assert!(opt.state_bytes() > 0, "moments still accumulate");
    }

    #[test]
    fn native_backend_supports_fo() {
        use crate::runtime::backend::Backend as _;
        use crate::runtime::NativeBackend;
        let b = NativeBackend::preset("opt-nano").unwrap();
        assert!(b.supports_fo());
        let eng = FoEngine::new(&b);
        let mut params = b.initial_params("").unwrap().0;
        let seqs: Vec<Vec<u32>> =
            (0..2u32).map(|r| (0..12u32).map(|i| 20 + r + i).collect()).collect();
        let batch = Batch::lm_batch(&seqs, 2, 16).unwrap();
        let (l0, grads) = eng.loss_and_grads(&params, &batch).unwrap();
        assert!(l0.is_finite() && l0 > 0.0);
        assert_eq!(grads.len(), params.len());
        // a few SGD steps on a fixed batch must reduce the loss
        let mut opt = FoOptimizer::sgd();
        for _ in 0..5 {
            eng.fo_step(&mut params, &batch, &mut opt, 0.5).unwrap();
        }
        let (l1, _) = eng.loss_and_grads(&params, &batch).unwrap();
        assert!(l1 < l0, "loss must decrease: {l0} -> {l1}");
        // mis-shaped host params stay a clear error
        let bad = vec![vec![0.0f32; 4]];
        assert!(eng.loss_and_grads(&bad, &batch).is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn grads_decrease_loss() {
        use crate::runtime::backend::default_artifact_dir;
        use crate::runtime::PjrtBackend;
        crate::require_artifacts!();
        let b = PjrtBackend::open(&default_artifact_dir("opt-micro")).unwrap();
        let m = b.manifest().clone();
        let eng = FoEngine::new(&b);
        let mut params = m.read_init_params().unwrap();
        // toy LM batch
        let seqs: Vec<Vec<u32>> = (0..m.train_batch)
            .map(|r| (0..12u32).map(|i| 10 + ((r as u32 + i) % 50)).collect())
            .collect();
        let batch = Batch::lm_batch(&seqs, m.train_batch, 16).unwrap();
        let (l0, grads) = eng.loss_and_grads(&params, &batch).unwrap();
        assert!(l0.is_finite() && l0 > 0.0);
        assert_eq!(grads.len(), params.len());
        let mut opt = FoOptimizer::sgd();
        for _ in 0..5 {
            eng.fo_step(&mut params, &batch, &mut opt, 0.5).unwrap();
        }
        let (l1, _) = eng.loss_and_grads(&params, &batch).unwrap();
        assert!(l1 < l0, "loss must decrease: {l0} -> {l1}");
    }
}

//! LayerSelector: the core of LeZO (Section 4.1 of the paper).
//!
//! Each step, `n_drop` of the sparsifiable units (transformer blocks) are
//! randomly *dropped*: they are skipped during perturbation and updating,
//! never during the forward pass. Over many steps every layer is visited,
//! so the procedure remains full-parameter fine-tuning. MeZO is exactly
//! `n_drop = 0`.

use crate::rng::{derive, purpose, Rng};

#[derive(Debug, Clone)]
pub struct LayerSelector {
    /// Unit indices eligible for dropping (the paper: transformer blocks).
    sparsifiable: Vec<usize>,
    /// Unit indices always perturbed+updated (embedding, final LN — unless
    /// the run sparsifies those too).
    always_active: Vec<usize>,
    n_drop: usize,
    run_seed: u64,
}

impl LayerSelector {
    pub fn new(
        sparsifiable: Vec<usize>,
        always_active: Vec<usize>,
        n_drop: usize,
        run_seed: u64,
    ) -> anyhow::Result<LayerSelector> {
        anyhow::ensure!(
            n_drop <= sparsifiable.len(),
            "cannot drop {n_drop} of {} sparsifiable units",
            sparsifiable.len()
        );
        Ok(LayerSelector { sparsifiable, always_active, n_drop, run_seed })
    }

    pub fn n_drop(&self) -> usize {
        self.n_drop
    }

    /// Sparsity rho over the sparsifiable pool.
    pub fn rho(&self) -> f64 {
        if self.sparsifiable.is_empty() {
            0.0
        } else {
            self.n_drop as f64 / self.sparsifiable.len() as f64
        }
    }

    /// Active (perturbed + updated) unit indices for a step. Deterministic
    /// per (run_seed, step): re-invoking for the same step returns the same
    /// set — the update phase relies on this.
    pub fn active_units(&self, step: u64) -> Vec<usize> {
        let mut rng = Rng::new(derive(self.run_seed, purpose::SELECTOR, step));
        let keep = self.sparsifiable.len() - self.n_drop;
        let kept = rng.sample_indices(self.sparsifiable.len(), keep);
        let mut active: Vec<usize> = self.always_active.clone();
        active.extend(kept.into_iter().map(|i| self.sparsifiable[i]));
        active.sort_unstable();
        active
    }

    /// Fraction of *parameters* active at a step (for the computation-saving
    /// accounting in the benches).
    pub fn active_param_fraction(&self, unit_lens: &[usize], step: u64) -> f64 {
        let total: usize = self
            .always_active
            .iter()
            .chain(self.sparsifiable.iter())
            .map(|&k| unit_lens[k])
            .sum();
        let active: usize = self.active_units(step).iter().map(|&k| unit_lens[k]).sum();
        active as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn sel(n_drop: usize) -> LayerSelector {
        LayerSelector::new((1..=8).collect(), vec![0, 9], n_drop, 42).unwrap()
    }

    #[test]
    fn deterministic_per_step() {
        let s = sel(6);
        assert_eq!(s.active_units(7), s.active_units(7));
        // different steps usually differ
        let distinct: HashSet<Vec<usize>> = (0..20).map(|t| s.active_units(t)).collect();
        assert!(distinct.len() > 10);
    }

    #[test]
    fn mezo_special_case_keeps_everything() {
        let s = sel(0);
        for t in 0..5 {
            assert_eq!(s.active_units(t), (0..=9).collect::<Vec<_>>());
        }
        assert_eq!(s.rho(), 0.0);
    }

    #[test]
    fn drop_count_respected() {
        for n in 0..=8 {
            let s = sel(n);
            for t in 0..10 {
                let active = s.active_units(t);
                assert_eq!(active.len(), 2 + (8 - n), "n={n}");
                // always-active present
                assert!(active.contains(&0) && active.contains(&9));
            }
        }
    }

    #[test]
    fn full_drop_leaves_always_active_only() {
        let s = sel(8);
        assert_eq!(s.active_units(3), vec![0, 9]);
        assert_eq!(s.rho(), 1.0);
    }

    #[test]
    fn over_drop_rejected() {
        assert!(LayerSelector::new(vec![1, 2], vec![0], 3, 0).is_err());
    }

    #[test]
    fn coverage_over_steps_every_block_visited() {
        // property (paper §4.1): dynamic selection achieves full-parameter
        // tuning over multiple steps
        let s = sel(6); // keep only 2 of 8 per step
        let mut seen: HashSet<usize> = HashSet::new();
        for t in 0..200 {
            for u in s.active_units(t) {
                seen.insert(u);
            }
        }
        assert_eq!(seen.len(), 10, "all units eventually active");
    }

    #[test]
    fn selection_is_uniform_over_blocks() {
        let s = sel(4); // keep 4 of 8
        let mut counts = vec![0usize; 11];
        let trials = 4000;
        for t in 0..trials {
            for u in s.active_units(t) {
                counts[u] += 1;
            }
        }
        for b in 1..=8 {
            let frac = counts[b] as f64 / trials as f64;
            assert!((frac - 0.5).abs() < 0.05, "block {b}: {frac}");
        }
    }

    #[test]
    fn active_param_fraction_tracks_rho() {
        let lens = vec![100, 50, 50, 50, 50, 50, 50, 50, 50, 10]; // emb=100, blocks=50x8, ln=10
        let s = sel(4);
        let f = s.active_param_fraction(&lens, 0);
        // active = 110 + 4*50 = 310 of 510
        assert!((f - 310.0 / 510.0).abs() < 1e-12);
    }

    #[test]
    fn different_run_seeds_give_different_schedules() {
        let a = LayerSelector::new((1..=8).collect(), vec![0], 4, 1).unwrap();
        let b = LayerSelector::new((1..=8).collect(), vec![0], 4, 2).unwrap();
        let same = (0..20).filter(|&t| a.active_units(t) == b.active_units(t)).count();
        assert!(same < 10);
    }

    // ---- property sweep: random (n_units, n_drop, seed) configurations ----

    #[test]
    fn property_active_set_size_matches_sparsity_ratio() {
        // for ANY configuration, |active| == always + (sparsifiable - drop)
        // and rho == drop / sparsifiable — the sparsity accounting the bench
        // relies on
        let mut rng = crate::rng::Rng::new(0xA11);
        for _ in 0..200 {
            let n_sparse = rng.range(1, 24);
            let n_always = rng.range(0, 3);
            let n_drop = rng.range(0, n_sparse);
            let sparsifiable: Vec<usize> = (n_always..n_always + n_sparse).collect();
            let always: Vec<usize> = (0..n_always).collect();
            let s =
                LayerSelector::new(sparsifiable, always, n_drop, rng.next_u64()).unwrap();
            assert!((s.rho() - n_drop as f64 / n_sparse as f64).abs() < 1e-12);
            for t in 0..8 {
                let active = s.active_units(t);
                assert_eq!(active.len(), n_always + n_sparse - n_drop);
                // sorted, deduped, in range
                assert!(active.windows(2).all(|w| w[0] < w[1]));
                assert!(active.iter().all(|&u| u < n_always + n_sparse));
                // always-active present
                for u in 0..n_always {
                    assert!(active.contains(&u));
                }
            }
        }
    }

    #[test]
    fn property_every_unit_touched_over_a_window() {
        // full-parameter coverage (paper §4.1): over a window of steps every
        // sparsifiable unit is active at least once — for any drop < n
        let mut rng = crate::rng::Rng::new(0xB22);
        for _ in 0..30 {
            let n_sparse = rng.range(2, 16);
            let n_drop = rng.range(0, n_sparse - 1); // keep >= 1
            let keep = n_sparse - n_drop;
            let s = LayerSelector::new(
                (0..n_sparse).collect(),
                vec![],
                n_drop,
                rng.next_u64(),
            )
            .unwrap();
            // coupon-collector bound with margin: ~ (n/keep) * ln(n) * 8
            let window = (8.0 * (n_sparse as f64 / keep as f64)
                * (n_sparse as f64).ln().max(1.0))
            .ceil() as u64
                * 4
                + 16;
            let mut seen = HashSet::new();
            for t in 0..window {
                for u in s.active_units(t) {
                    seen.insert(u);
                }
            }
            assert_eq!(
                seen.len(),
                n_sparse,
                "n={n_sparse} drop={n_drop} window={window}: coverage incomplete"
            );
        }
    }

    #[test]
    fn property_zero_sparsity_reduces_to_mezo() {
        // drop = 0 (sparsity 0.0) must activate EVERY unit EVERY step
        let mut rng = crate::rng::Rng::new(0xC33);
        for _ in 0..50 {
            let n_sparse = rng.range(1, 20);
            let s = LayerSelector::new(
                (1..=n_sparse).collect(),
                vec![0],
                0,
                rng.next_u64(),
            )
            .unwrap();
            for t in 0..5 {
                assert_eq!(s.active_units(t), (0..=n_sparse).collect::<Vec<_>>());
            }
        }
    }
}

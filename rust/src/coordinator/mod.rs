//! The L3 coordinator — the paper's system contribution.
//!
//! - [`selector`]: dynamic layer-wise sparsity (which units are perturbed +
//!   updated each step; MeZO is the `n_drop = 0` special case).
//! - [`spsa`]: the ZO probe schedule — seeded perturbation via the AOT'd
//!   `zo_axpy` kernel, forward probes, coefficient application (Algorithm 1).
//! - [`optim`]: the pluggable ZO update rules (zo-sgd, momentum, adam,
//!   sign-sgd, fzoo) mapping projected gradients to per-unit coefficients,
//!   with seed-replay optimizer state instead of moment buffers.
//! - [`fo`]: the first-order substrate (SGD / Adam over the backend's
//!   `forward_backward` — the native reference backward pass, or the AOT'd
//!   executable under PJRT) — the paper's "FT" baseline and the in-repo
//!   pretraining path.
//! - [`trainer`]: the training loop gluing data, engine, eval and
//!   checkpointing together — including atomic `TrainState` saves and
//!   bit-identical resume.
//! - [`faults`]: deterministic fault injection (`faults` key / `LEZO_FAULTS`)
//!   and the non-finite-loss policy, so crash recovery is testable.
//! - [`metrics`]: per-stage wall-time accounting (Figs. 2/4/5/6) and the
//!   analytic memory model (the "FT = 12x memory" comparison).

pub mod faults;
pub mod fo;
pub mod metrics;
pub mod optim;
pub mod policy;
pub mod selector;
pub mod spsa;
pub mod trainer;

pub use faults::{FaultPlan, NonFinitePolicy};
pub use optim::{make_optimizer, ZoOptKind, ZoOptimizer};
pub use policy::{Policy, PolicySelector};
pub use selector::LayerSelector;
pub use spsa::SpsaEngine;
pub use trainer::{TrainReport, Trainer};

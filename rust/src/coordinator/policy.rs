//! Layer-selection policies — the ablation axis behind the paper's §4.1
//! design choice ("we employ a simple and efficient random selection
//! strategy, avoiding the need for new parameter modules").
//!
//! The paper picks uniform random per step. Plausible alternatives that
//! other work uses (LISA's importance sampling, round-robin freezing
//! schedules) are implemented here so `lezo bench ablation` can show what
//! the choice costs or buys:
//!
//! - [`Policy::Uniform`]   — the paper: fresh uniform sample per step.
//! - [`Policy::RoundRobin`] — deterministic rotation; every block is active
//!   exactly `keep` out of every `N` steps (FreezeOut/AutoFreeze-shaped).
//! - [`Policy::Stratified`] — random but coverage-balanced: a reshuffled
//!   permutation is consumed in windows, so within each epoch of
//!   ceil(N/keep) steps every block is active at least once.
//! - [`Policy::Weighted`]  — importance-proportional sampling from running
//!   per-block scores fed back by the trainer (|projected grad| credit, the
//!   LISA-like variant). Costs O(N) state — still negligible.

use crate::rng::{derive, purpose, Rng};
use anyhow::Result;
use std::str::FromStr;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Uniform,
    RoundRobin,
    Stratified,
    Weighted,
}

impl FromStr for Policy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "uniform" | "random" => Policy::Uniform,
            "round-robin" | "roundrobin" | "rr" => Policy::RoundRobin,
            "stratified" => Policy::Stratified,
            "weighted" | "importance" => Policy::Weighted,
            _ => anyhow::bail!("unknown policy '{s}' (uniform|round-robin|stratified|weighted)"),
        })
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Policy::Uniform => "uniform",
            Policy::RoundRobin => "round-robin",
            Policy::Stratified => "stratified",
            Policy::Weighted => "weighted",
        })
    }
}

/// Stateful selector generalizing [`super::selector::LayerSelector`] to the
/// ablation policies. `Uniform` reproduces the paper's selector exactly
/// (same seed derivation), so the default path is unchanged.
#[derive(Debug, Clone)]
pub struct PolicySelector {
    sparsifiable: Vec<usize>,
    always_active: Vec<usize>,
    n_drop: usize,
    run_seed: u64,
    policy: Policy,
    /// Weighted policy: running importance score per sparsifiable slot.
    scores: Vec<f64>,
    /// Stratified policy: current permutation + cursor.
    perm: Vec<usize>,
    cursor: usize,
}

impl PolicySelector {
    pub fn new(
        sparsifiable: Vec<usize>,
        always_active: Vec<usize>,
        n_drop: usize,
        run_seed: u64,
        policy: Policy,
    ) -> Result<PolicySelector> {
        anyhow::ensure!(n_drop <= sparsifiable.len(), "cannot drop more units than exist");
        let n = sparsifiable.len();
        Ok(PolicySelector {
            sparsifiable,
            always_active,
            n_drop,
            run_seed,
            policy,
            scores: vec![1.0; n],
            perm: (0..n).collect(),
            cursor: 0,
        })
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    fn keep(&self) -> usize {
        self.sparsifiable.len() - self.n_drop
    }

    /// Active units for `step`. Unlike the paper's stateless uniform
    /// selector, some policies advance internal state — call exactly once
    /// per step (the trainer does).
    pub fn next_active(&mut self, step: u64) -> Vec<usize> {
        let n = self.sparsifiable.len();
        let keep = self.keep();
        let kept_slots: Vec<usize> = match self.policy {
            Policy::Uniform => {
                let mut rng = Rng::new(derive(self.run_seed, purpose::SELECTOR, step));
                rng.sample_indices(n, keep)
            }
            Policy::RoundRobin => {
                (0..keep).map(|i| ((step as usize * keep) + i) % n.max(1)).collect()
            }
            Policy::Stratified => {
                let mut out = Vec::with_capacity(keep);
                for _ in 0..keep {
                    if self.cursor == 0 {
                        let mut rng =
                            Rng::new(derive(self.run_seed, purpose::SELECTOR, step ^ 0x57A7));
                        rng.shuffle(&mut self.perm);
                    }
                    // A mid-call reshuffle (keep not dividing n) can
                    // re-surface a slot already taken this step; swap the
                    // first fresh slot forward so the active set keeps its
                    // exact size. perm stays a permutation, so the epoch
                    // coverage guarantee is unaffected.
                    if out.contains(&self.perm[self.cursor]) {
                        if let Some(j) =
                            (self.cursor + 1..n).find(|&j| !out.contains(&self.perm[j]))
                        {
                            self.perm.swap(self.cursor, j);
                        }
                    }
                    out.push(self.perm[self.cursor]);
                    self.cursor = (self.cursor + 1) % n.max(1);
                }
                out
            }
            Policy::Weighted => {
                // weighted sampling without replacement (Efraimidis-Spirakis
                // keys: u^(1/w) ranking)
                let mut rng = Rng::new(derive(self.run_seed, purpose::SELECTOR, step));
                let mut keyed: Vec<(f64, usize)> = self
                    .scores
                    .iter()
                    .enumerate()
                    .map(|(i, &w)| {
                        let u = rng.f64().max(1e-12);
                        (u.powf(1.0 / w.max(1e-9)), i)
                    })
                    .collect();
                keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                keyed.into_iter().take(keep).map(|(_, i)| i).collect()
            }
        };
        let mut active: Vec<usize> = self.always_active.clone();
        active.extend(kept_slots.into_iter().map(|i| self.sparsifiable[i]));
        active.sort_unstable();
        active.dedup();
        active
    }

    /// Feedback for the Weighted policy: credit the units that were active
    /// for a step with the magnitude of its projected gradient (EMA).
    pub fn feedback(&mut self, active: &[usize], projected_grad: f32) {
        if self.policy != Policy::Weighted {
            return;
        }
        let g = (projected_grad.abs() as f64).min(1e3);
        for (slot, &unit) in self.sparsifiable.iter().enumerate() {
            if active.contains(&unit) {
                self.scores[slot] = 0.9 * self.scores[slot] + 0.1 * (g + 1e-3);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn sel(policy: Policy, n_drop: usize) -> PolicySelector {
        PolicySelector::new((1..=8).collect(), vec![0, 9], n_drop, 42, policy).unwrap()
    }

    #[test]
    fn parse_display_round_trip() {
        for p in ["uniform", "round-robin", "stratified", "weighted"] {
            let parsed: Policy = p.parse().unwrap();
            assert_eq!(parsed.to_string(), p);
        }
        assert!("nope".parse::<Policy>().is_err());
    }

    #[test]
    fn uniform_matches_paper_selector() {
        // exact-match against the paper's LayerSelector: same derivation
        let paper =
            crate::coordinator::LayerSelector::new((1..=8).collect(), vec![0, 9], 5, 42).unwrap();
        let mut ours = sel(Policy::Uniform, 5);
        for t in 0..20 {
            assert_eq!(ours.next_active(t), paper.active_units(t), "step {t}");
        }
    }

    #[test]
    fn all_policies_respect_drop_count() {
        for p in [Policy::Uniform, Policy::RoundRobin, Policy::Stratified, Policy::Weighted] {
            let mut s = sel(p, 6);
            for t in 0..30 {
                let a = s.next_active(t);
                assert_eq!(a.len(), 2 + 2, "{p}: {a:?}");
                assert!(a.contains(&0) && a.contains(&9));
            }
        }
    }

    #[test]
    fn round_robin_covers_exactly_per_cycle() {
        let mut s = sel(Policy::RoundRobin, 6); // keep 2 of 8 -> cycle 4 steps
        let mut counts = vec![0usize; 11];
        for t in 0..4 {
            for u in s.next_active(t) {
                counts[u] += 1;
            }
        }
        for b in 1..=8 {
            assert_eq!(counts[b], 1, "block {b} must appear exactly once per cycle");
        }
    }

    #[test]
    fn stratified_covers_every_epoch() {
        let mut s = sel(Policy::Stratified, 6); // keep 2/8 -> epoch 4 steps
        for epoch in 0..5u64 {
            let mut seen = HashSet::new();
            for t in epoch * 4..(epoch + 1) * 4 {
                for u in s.next_active(t) {
                    seen.insert(u);
                }
            }
            assert_eq!(seen.len(), 10, "epoch {epoch} must touch all units");
        }
    }

    #[test]
    fn weighted_prefers_high_score_blocks() {
        let mut s = sel(Policy::Weighted, 4); // keep 4 of 8
        // boost block 3's score via feedback
        for t in 0..2000u64 {
            let a = s.next_active(t);
            let g = if a.contains(&3) { 5.0 } else { 0.01 };
            s.feedback(&a, g);
        }
        let mut counts = vec![0usize; 11];
        let mut probe = s.clone();
        for t in 2000..4000u64 {
            for u in probe.next_active(t) {
                counts[u] += 1;
            }
        }
        let block3 = counts[3] as f64;
        let others =
            (1..=8).filter(|&b| b != 3).map(|b| counts[b] as f64).sum::<f64>() / 7.0;
        assert!(block3 > others, "credited block must be sampled more: {block3} vs {others}");
    }

    #[test]
    fn weighted_without_feedback_is_roughly_uniform() {
        let mut s = sel(Policy::Weighted, 4);
        let mut counts = vec![0usize; 11];
        for t in 0..4000u64 {
            for u in s.next_active(t) {
                counts[u] += 1;
            }
        }
        for b in 1..=8 {
            let frac = counts[b] as f64 / 4000.0;
            assert!((frac - 0.5).abs() < 0.05, "block {b}: {frac}");
        }
    }

    const ALL_POLICIES: [Policy; 4] =
        [Policy::Uniform, Policy::RoundRobin, Policy::Stratified, Policy::Weighted];

    // ---- property sweep: random configurations, all policies ----------------

    #[test]
    fn property_active_set_size_matches_sparsity_ratio_all_policies() {
        let mut rng = crate::rng::Rng::new(0xD44);
        for policy in ALL_POLICIES {
            for _ in 0..50 {
                let n_sparse = rng.range(1, 16);
                let n_always = rng.range(0, 2);
                let n_drop = rng.range(0, n_sparse);
                let mut s = PolicySelector::new(
                    (n_always..n_always + n_sparse).collect(),
                    (0..n_always).collect(),
                    n_drop,
                    rng.next_u64(),
                    policy,
                )
                .unwrap();
                for t in 0..6 {
                    let active = s.next_active(t);
                    assert_eq!(
                        active.len(),
                        n_always + n_sparse - n_drop,
                        "{policy} n={n_sparse} drop={n_drop}"
                    );
                    assert!(active.windows(2).all(|w| w[0] < w[1]), "{policy}: not sorted/deduped");
                }
            }
        }
    }

    #[test]
    fn property_every_unit_touched_over_a_window_all_policies() {
        // full-parameter coverage holds for every policy as long as at
        // least one sparsifiable unit is kept per step
        let mut rng = crate::rng::Rng::new(0xE55);
        for policy in ALL_POLICIES {
            for _ in 0..10 {
                let n_sparse = rng.range(2, 12);
                let n_drop = rng.range(0, n_sparse - 1);
                let keep = n_sparse - n_drop;
                let mut s = PolicySelector::new(
                    (0..n_sparse).collect(),
                    vec![],
                    n_drop,
                    rng.next_u64(),
                    policy,
                )
                .unwrap();
                let window =
                    (40.0 * (n_sparse as f64 / keep as f64) * (n_sparse as f64).ln().max(1.0))
                        .ceil() as u64
                        + 16;
                let mut seen = HashSet::new();
                for t in 0..window {
                    for u in s.next_active(t) {
                        seen.insert(u);
                    }
                }
                assert_eq!(
                    seen.len(),
                    n_sparse,
                    "{policy} n={n_sparse} drop={n_drop} window={window}"
                );
            }
        }
    }

    #[test]
    fn property_zero_sparsity_reduces_to_mezo_all_policies() {
        // sparsity 0.0: every policy must activate all units every step
        let mut rng = crate::rng::Rng::new(0xF66);
        for policy in ALL_POLICIES {
            for _ in 0..20 {
                let n_sparse = rng.range(1, 12);
                let mut s = PolicySelector::new(
                    (1..=n_sparse).collect(),
                    vec![0],
                    0,
                    rng.next_u64(),
                    policy,
                )
                .unwrap();
                for t in 0..4 {
                    assert_eq!(
                        s.next_active(t),
                        (0..=n_sparse).collect::<Vec<_>>(),
                        "{policy} must reduce to MeZO at drop 0"
                    );
                }
            }
        }
    }
}

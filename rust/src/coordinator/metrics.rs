//! Stage-level timing (Fig. 2 / 4 / 5 / 6 instrumentation) and the memory
//! accounting model behind the paper's "FT costs 12x" comparison.

use std::time::Instant;

/// Cumulative wall time per ZO-step stage.
#[derive(Debug, Clone, Default)]
pub struct StageTimes {
    pub perturb_secs: f64,
    pub forward_secs: f64,
    pub update_secs: f64,
    pub other_secs: f64,
    /// Socket-transport round-trip latency: wall time inside the forward
    /// stage that was *not* worker compute (dispatch + wire + wait). A
    /// sub-split of `forward_secs`, so it is excluded from [`total`]; zero
    /// for thread transport and single-backend runs.
    ///
    /// [`total`]: StageTimes::total
    pub rt_secs: f64,
    pub steps: u64,
}

impl StageTimes {
    pub fn total(&self) -> f64 {
        self.perturb_secs + self.forward_secs + self.update_secs + self.other_secs
    }

    pub fn per_step_ms(&self) -> (f64, f64, f64, f64) {
        let n = self.steps.max(1) as f64;
        (
            1e3 * self.perturb_secs / n,
            1e3 * self.forward_secs / n,
            1e3 * self.update_secs / n,
            1e3 * self.other_secs / n,
        )
    }

    /// Per-step socket round-trip latency in ms (see [`StageTimes::rt_secs`]).
    pub fn per_step_rt_ms(&self) -> f64 {
        1e3 * self.rt_secs / self.steps.max(1) as f64
    }

    /// Fraction of step time spent outside the forward pass — the paper's
    /// headline observation is that this exceeds 0.5 for MeZO.
    pub fn non_forward_fraction(&self) -> f64 {
        let t = self.total();
        if t <= 0.0 {
            0.0
        } else {
            (t - self.forward_secs) / t
        }
    }

    pub fn merge(&mut self, other: &StageTimes) {
        self.perturb_secs += other.perturb_secs;
        self.forward_secs += other.forward_secs;
        self.update_secs += other.update_secs;
        self.other_secs += other.other_secs;
        self.rt_secs += other.rt_secs;
        self.steps += other.steps;
    }
}

/// Scoped stage timer.
pub struct StageTimer {
    start: Instant,
}

impl StageTimer {
    pub fn start() -> StageTimer {
        StageTimer { start: Instant::now() }
    }

    pub fn lap(&mut self) -> f64 {
        let t = self.start.elapsed().as_secs_f64();
        self.start = Instant::now();
        t
    }
}

/// Analytic fine-tuning memory model (bytes), mirroring the paper's Table-1
/// "FT (12x memory)" comparison. ZO keeps parameters only; FO-Adam keeps
/// parameters + gradients + two moment buffers + activations.
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    pub params: usize,
    pub batch: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_layers: usize,
}

impl MemoryModel {
    pub fn zo_bytes(&self) -> usize {
        4 * self.params // fp32 weights; z is regenerated, never stored
    }

    pub fn adam_bytes(&self) -> usize {
        // weights + grads + m + v
        let opt = 4 * 4 * self.params;
        opt + self.activation_bytes()
    }

    pub fn activation_bytes(&self) -> usize {
        // per layer: ~ (attn scores + 4 residual-width tensors + mlp 4x)
        let per_layer = self.batch * self.seq * (self.d_model * 10 + self.seq);
        4 * per_layer * self.n_layers
    }

    pub fn ft_over_zo(&self) -> f64 {
        self.adam_bytes() as f64 / self.zo_bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sane() {
        let s = StageTimes {
            perturb_secs: 3.0,
            forward_secs: 4.0,
            update_secs: 2.0,
            other_secs: 1.0,
            rt_secs: 0.5,
            steps: 10,
        };
        // rt is a sub-split of forward time, not an additional stage
        assert!((s.total() - 10.0).abs() < 1e-12);
        assert!((s.per_step_rt_ms() - 50.0).abs() < 1e-12);
        assert!((s.non_forward_fraction() - 0.6).abs() < 1e-12);
        let (p, f, u, o) = s.per_step_ms();
        assert_eq!((p, f, u, o), (300.0, 400.0, 200.0, 100.0));
    }

    #[test]
    fn merge_accumulates() {
        let mut a = StageTimes { perturb_secs: 1.0, steps: 2, ..Default::default() };
        let b = StageTimes {
            perturb_secs: 2.0,
            forward_secs: 5.0,
            rt_secs: 0.25,
            steps: 3,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.perturb_secs, 3.0);
        assert_eq!(a.forward_secs, 5.0);
        assert_eq!(a.rt_secs, 0.25);
        assert_eq!(a.steps, 5);
    }

    #[test]
    fn empty_is_safe() {
        let s = StageTimes::default();
        assert_eq!(s.non_forward_fraction(), 0.0);
    }

    #[test]
    fn memory_model_ft_multiple() {
        // at small batch the Adam-state 4x dominates; activations push the
        // multiple toward the paper's ~12x as batch*seq grows vs params
        let m = MemoryModel { params: 237_000, batch: 16, seq: 64, d_model: 64, n_layers: 4 };
        let r = m.ft_over_zo();
        assert!(r > 4.0, "{r}");
        let big_batch = MemoryModel { batch: 64, ..m };
        assert!(big_batch.ft_over_zo() > r);
    }

    #[test]
    fn timer_laps() {
        let mut t = StageTimer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let a = t.lap();
        assert!(a >= 0.001);
        let b = t.lap();
        assert!(b < a + 0.05);
    }
}

//! The ZO engine: layer-wise sparse SPSA + ZO-SGD (Algorithm 1 of the paper).
//!
//! One optimization step is
//! ```text
//!   perturb   P[l] += mu * z_l        for l in active      (zo_axpy, c=+mu)
//!   forward   l+ = L(P)
//!   flip      P[l] -= 2 mu * z_l      for l in active      (zo_axpy, c=-2mu)
//!   forward   l- = L(P)
//!   restore   P[l] += mu * z_l        for l in active      (zo_axpy, c=+mu)
//!   g = (l+ - l-) / (2 mu)
//!   update    P[l] -= lr * g * z_l    for l in active      (zo_axpy, c=-lr*g)
//! ```
//! The perturbation `z_l` is *regenerated* inside the AOT'd Pallas kernel
//! from `(seed, element index)` — MeZO's memory trick, made structural: the
//! same `(step, unit)` seed re-derives the identical Gaussian stream in all
//! four phases, so `z` is never materialized host- or device-side.
//!
//! LeZO's computation saving is the `active` set: dropped units are skipped
//! in all four axpy phases (but never in the forward pass). MeZO is the
//! `active = all units` special case.

use crate::coordinator::metrics::{StageTimer, StageTimes};
use crate::rng::zo_seed;
use crate::runtime::exes::{ExeRegistry, Family};
use crate::runtime::{run1, Runtime};
use anyhow::Result;

/// A set of tunable flat units living on the device. For full-parameter
/// fine-tuning these are the model's layer units; under PEFT they are the
/// per-block adapter units (the base model stays frozen).
pub struct TunableUnits {
    pub bufs: Vec<xla::PjRtBuffer>,
    pub lens: Vec<usize>,
}

impl TunableUnits {
    pub fn n_units(&self) -> usize {
        self.bufs.len()
    }

    pub fn param_count(&self) -> usize {
        self.lens.iter().sum()
    }
}

/// Outcome of one ZO step.
#[derive(Debug, Clone, Copy)]
pub struct ZoStep {
    pub loss_plus: f32,
    pub loss_minus: f32,
    /// SPSA projected gradient (l+ - l-) / (2 mu).
    pub projected_grad: f32,
    /// Parameters touched this step (perturbed + updated).
    pub active_params: usize,
}

impl ZoStep {
    /// The reported training loss for the step (mean of the two probes,
    /// an O(mu^2)-accurate estimate of L(theta)).
    pub fn loss(&self) -> f32 {
        0.5 * (self.loss_plus + self.loss_minus)
    }
}

/// The SPSA/ZO-SGD engine. Stateless across steps apart from the registry
/// caches; all step-dependent randomness derives from `(run_seed, step)`.
pub struct SpsaEngine<'r> {
    rt: &'r Runtime,
    reg: &'r ExeRegistry,
    pub mu: f32,
    pub run_seed: u64,
    /// Cached device scalars for the two constant coefficients (+mu, -2mu);
    /// avoids two host->device uploads per unit per step.
    c_plus: xla::PjRtBuffer,
    c_flip: xla::PjRtBuffer,
}

impl<'r> SpsaEngine<'r> {
    pub fn new(rt: &'r Runtime, reg: &'r ExeRegistry, mu: f32, run_seed: u64) -> Result<Self> {
        anyhow::ensure!(mu > 0.0, "perturbation scale mu must be positive");
        Ok(SpsaEngine {
            rt,
            reg,
            mu,
            run_seed,
            c_plus: rt.scalar_f32(mu)?,
            c_flip: rt.scalar_f32(-2.0 * mu)?,
        })
    }

    /// `unit <- unit + c * z(seed)` for one flat unit (in-place replace).
    fn axpy(
        &self,
        units: &mut TunableUnits,
        k: usize,
        seed: i32,
        c: &xla::PjRtBuffer,
    ) -> Result<()> {
        let exe = self.reg.get(self.rt, Family::ZoAxpy, units.lens[k])?;
        let seed_b = self.rt.scalar_i32(seed)?;
        let out = run1(&exe, &[&units.bufs[k], &seed_b, c])?;
        units.bufs[k] = out;
        Ok(())
    }

    /// Apply `c * z` to every active unit.
    fn sweep(
        &self,
        units: &mut TunableUnits,
        active: &[usize],
        step: u64,
        c: &xla::PjRtBuffer,
    ) -> Result<()> {
        for &k in active {
            let seed = zo_seed(self.run_seed, step, k);
            self.axpy(units, k, seed, c)?;
        }
        Ok(())
    }

    /// One full Algorithm-1 step. `loss` is called twice with the current
    /// unit buffers; it captures whatever else the forward pass needs
    /// (frozen base units, the uploaded batch). Stage wall-times accumulate
    /// into `times` (Fig. 2 instrumentation).
    pub fn zo_step(
        &self,
        step: u64,
        units: &mut TunableUnits,
        active: &[usize],
        lr: f32,
        loss: &mut dyn FnMut(&TunableUnits) -> Result<f32>,
        times: &mut StageTimes,
    ) -> Result<ZoStep> {
        debug_assert!(active.iter().all(|&k| k < units.n_units()));
        let mut t = StageTimer::start();

        // perturb +mu
        self.sweep(units, active, step, &self.c_plus)?;
        times.perturb_secs += t.lap();
        let loss_plus = loss(units)?;
        times.forward_secs += t.lap();

        // flip to -mu
        self.sweep(units, active, step, &self.c_flip)?;
        times.perturb_secs += t.lap();
        let loss_minus = loss(units)?;
        times.forward_secs += t.lap();

        // restore to theta
        self.sweep(units, active, step, &self.c_plus)?;
        times.perturb_secs += t.lap();

        // ZO-SGD update with the regenerated stream
        let projected_grad = (loss_plus - loss_minus) / (2.0 * self.mu);
        let coeff = self.rt.scalar_f32(-lr * projected_grad)?;
        self.sweep(units, active, step, &coeff)?;
        times.update_secs += t.lap();
        times.steps += 1;

        let active_params = active.iter().map(|&k| units.lens[k]).sum();
        Ok(ZoStep { loss_plus, loss_minus, projected_grad, active_params })
    }

    // ---- Sparse-MeZO (element-wise magnitude mask) -------------------------

    /// Masked sweep: `unit <- unit + c * z * [|pref| <= tau]` over every
    /// unit. `pref` is the unperturbed snapshot taken at step start so the
    /// mask stays identical across the four phases.
    fn masked_sweep(
        &self,
        units: &mut TunableUnits,
        pref: &[xla::PjRtBuffer],
        taus: &[xla::PjRtBuffer],
        step: u64,
        c: &xla::PjRtBuffer,
    ) -> Result<()> {
        for k in 0..units.n_units() {
            let exe = self.reg.get(self.rt, Family::ZoAxpyMasked, units.lens[k])?;
            let seed_b = self.rt.scalar_i32(zo_seed(self.run_seed, step, k))?;
            let out = run1(&exe, &[&units.bufs[k], &pref[k], &taus[k], &seed_b, c])?;
            units.bufs[k] = out;
        }
        Ok(())
    }

    /// One Sparse-MeZO step (the related-work baseline): same SPSA schedule
    /// as [`Self::zo_step`] but with an element-wise magnitude mask instead
    /// of LeZO's structural layer skip. Every unit's buffer is streamed
    /// through the masked kernel in all four phases — the computation does
    /// NOT shrink with sparsity, which is exactly the asymmetry the paper
    /// criticizes (and the bench measures).
    pub fn zo_step_masked(
        &self,
        step: u64,
        units: &mut TunableUnits,
        taus: &[xla::PjRtBuffer],
        lr: f32,
        loss: &mut dyn FnMut(&TunableUnits) -> Result<f32>,
        times: &mut StageTimes,
    ) -> Result<ZoStep> {
        anyhow::ensure!(taus.len() == units.n_units(), "one tau per unit");
        let mut t = StageTimer::start();

        // snapshot: PJRT buffers are immutable, so the pre-step handles ARE
        // the reference; the first perturb replaces them in `units` while we
        // keep them alive here (Sparse-MeZO's extra state, held one step).
        let mut pref: Vec<xla::PjRtBuffer> = Vec::with_capacity(units.n_units());
        for k in 0..units.n_units() {
            let exe = self.reg.get(self.rt, Family::ZoAxpyMasked, units.lens[k])?;
            let seed_b = self.rt.scalar_i32(zo_seed(self.run_seed, step, k))?;
            let out =
                run1(&exe, &[&units.bufs[k], &units.bufs[k], &taus[k], &seed_b, &self.c_plus])?;
            pref.push(std::mem::replace(&mut units.bufs[k], out));
        }
        times.perturb_secs += t.lap();
        let loss_plus = loss(units)?;
        times.forward_secs += t.lap();

        self.masked_sweep(units, &pref, taus, step, &self.c_flip)?;
        times.perturb_secs += t.lap();
        let loss_minus = loss(units)?;
        times.forward_secs += t.lap();

        self.masked_sweep(units, &pref, taus, step, &self.c_plus)?;
        times.perturb_secs += t.lap();

        let projected_grad = (loss_plus - loss_minus) / (2.0 * self.mu);
        let coeff = self.rt.scalar_f32(-lr * projected_grad)?;
        self.masked_sweep(units, &pref, taus, step, &coeff)?;
        times.update_secs += t.lap();
        times.steps += 1;

        Ok(ZoStep {
            loss_plus,
            loss_minus,
            projected_grad,
            active_params: units.param_count(), // traffic-wise everything is touched
        })
    }

    /// Perturb-only probe (used by tests and the Lemma-3 bench): applies
    /// `c*z` for `(step, active)` and returns nothing. Calling with `c` and
    /// then `-c` must be an identity to fp tolerance.
    pub fn apply(
        &self,
        step: u64,
        units: &mut TunableUnits,
        active: &[usize],
        c: f32,
    ) -> Result<()> {
        let cb = self.rt.scalar_f32(c)?;
        self.sweep(units, active, step, &cb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Manifest, ParamStore};
    use std::path::PathBuf;

    fn art() -> PathBuf {
        let root = std::env::var("LEZO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        PathBuf::from(root).join("opt-micro")
    }

    fn have() -> bool {
        art().join("manifest.json").exists()
    }

    fn setup() -> (Runtime, Manifest) {
        (Runtime::cpu().unwrap(), Manifest::load(&art()).unwrap())
    }

    fn tunable(rt: &Runtime, m: &Manifest) -> TunableUnits {
        let store = ParamStore::load_init(rt, m).unwrap();
        let lens = m.unit_lens.clone();
        let bufs = (0..store.n_units())
            .map(|k| {
                let host = rt.read_vec_f32(store.unit(k)).unwrap();
                rt.vec_f32(&host).unwrap()
            })
            .collect();
        TunableUnits { bufs, lens }
    }

    #[test]
    fn perturb_then_inverse_is_identity() {
        if !have() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let (rt, m) = setup();
        let reg = ExeRegistry::new(m.clone());
        let eng = SpsaEngine::new(&rt, &reg, 1e-3, 7).unwrap();
        let mut units = tunable(&rt, &m);
        let orig: Vec<Vec<f32>> =
            units.bufs.iter().map(|b| rt.read_vec_f32(b).unwrap()).collect();
        let active: Vec<usize> = (0..units.n_units()).collect();
        eng.apply(3, &mut units, &active, 0.5).unwrap();
        eng.apply(3, &mut units, &active, -0.5).unwrap();
        for (k, o) in orig.iter().enumerate() {
            let now = rt.read_vec_f32(&units.bufs[k]).unwrap();
            for (a, b) in now.iter().zip(o) {
                assert!((a - b).abs() < 1e-4, "unit {k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn zo_step_restores_inactive_and_moves_active() {
        if !have() {
            return;
        }
        let (rt, m) = setup();
        let reg = ExeRegistry::new(m.clone());
        let eng = SpsaEngine::new(&rt, &reg, 1e-2, 11).unwrap();
        let mut units = tunable(&rt, &m);
        let orig: Vec<Vec<f32>> =
            units.bufs.iter().map(|b| rt.read_vec_f32(b).unwrap()).collect();
        // drop unit 2: it must come back bit-comparable after the step
        let active: Vec<usize> = (0..units.n_units()).filter(|&k| k != 2).collect();
        let mut times = StageTimes::default();
        // a loss with a real gradient signal: distance of unit 1 to zero
        let mut loss = |u: &TunableUnits| -> Result<f32> {
            let v = rt.read_vec_f32(&u.bufs[1])?;
            Ok(v.iter().map(|x| x * x).sum::<f32>())
        };
        let step =
            eng.zo_step(0, &mut units, &active, 1e-3, &mut loss, &mut times).unwrap();
        assert!(step.projected_grad.is_finite());
        assert_eq!(
            step.active_params,
            active.iter().map(|&k| m.unit_lens[k]).sum::<usize>()
        );
        let u2 = rt.read_vec_f32(&units.bufs[2]).unwrap();
        assert_eq!(u2, orig[2], "dropped unit must be untouched");
        let u1 = rt.read_vec_f32(&units.bufs[1]).unwrap();
        assert_ne!(u1, orig[1], "active unit must be updated");
        // restore invariant: theta' = theta - lr*g*z, so theta' - theta is
        // proportional to z; re-applying +lr*g*z recovers theta
        assert_eq!(times.steps, 1);
        assert!(times.perturb_secs > 0.0 && times.forward_secs > 0.0);
    }

    #[test]
    fn same_seed_same_trajectory() {
        if !have() {
            return;
        }
        let (rt, m) = setup();
        let reg = ExeRegistry::new(m.clone());
        let mut final_states = vec![];
        for _ in 0..2 {
            let eng = SpsaEngine::new(&rt, &reg, 1e-3, 42).unwrap();
            let mut units = tunable(&rt, &m);
            let active: Vec<usize> = (0..units.n_units()).collect();
            let mut times = StageTimes::default();
            let mut loss = |u: &TunableUnits| -> Result<f32> {
                let v = rt.read_vec_f32(&u.bufs[0])?;
                Ok(v.iter().take(100).sum::<f32>())
            };
            for t in 0..3 {
                eng.zo_step(t, &mut units, &active, 1e-4, &mut loss, &mut times).unwrap();
            }
            final_states.push(rt.read_vec_f32(&units.bufs[0]).unwrap());
        }
        assert_eq!(final_states[0], final_states[1], "run must be reproducible");
    }
}

//! The ZO engine: the SPSA *probe schedule* (Algorithm 1 of the paper),
//! generic over the runtime [`Backend`]. The *update rule* is pluggable —
//! a [`ZoOptimizer`] from [`crate::coordinator::optim`] maps the step's
//! projected gradient(s) to per-unit [`Coeff`]s which this engine applies
//! as seeded axpys.
//!
//! The classic two-sided step is
//! ```text
//!   perturb   P[l] += mu * z_l        for l in active      (zo_axpy, c=+mu)
//!   forward   l+ = L(P)
//!   flip      P[l] -= 2 mu * z_l      for l in active      (zo_axpy, c=-2mu)
//!   forward   l- = L(P)
//!   restore   P[l] += mu * z_l        for l in active      (zo_axpy, c=+mu)
//!   g = (l+ - l-) / (2 mu)
//!   update    P[l] += c_l * z_l       per optimizer Coeff  (zo_axpy)
//! ```
//! and the one-sided batched schedule ([`ProbeSchedule::OneSided`], used
//! by the FZOO-style rule) probes `B` independent directions against one
//! baseline forward, yielding `B` projected gradients per step.
//!
//! The perturbation `z_l` is *regenerated* inside the backend's zo_axpy
//! kernel from `(seed, element index)` — MeZO's memory trick, made
//! structural: the same `(step, probe, unit)` seed re-derives the
//! identical Gaussian stream in every phase, so `z` is never materialized.
//! A [`Coeff`] may reference a *past* step's `(step, unit)` pair — that is
//! the seed-replay trick the momentum/Adam rules use for their first
//! moment (see `optim` module docs).
//!
//! LeZO's computation saving is the `active` set: dropped units are skipped
//! in all axpy phases (but never in the forward pass). MeZO is the
//! `active = all units` special case. The engine itself never touches
//! PJRT or host floats — it only routes unit handles through the backend,
//! so the identical code path runs natively and on-device.

use crate::coordinator::faults::NonFinitePolicy;
use crate::coordinator::metrics::{StageTimer, StageTimes};
use crate::coordinator::optim::{Coeff, ProbeSchedule, ZoOptimizer, ZoSgd};
use crate::peft::PeftMode;
use crate::rng::{zo_probe_seed, zo_seed};
use crate::runtime::backend::Backend;
use crate::runtime::plan::{EvalSpec, PlanPhase, PlanResult, StepPlan, SweepOp};
use anyhow::{bail, Result};

/// A set of tunable flat units living on the backend. For full-parameter
/// fine-tuning these are the model's layer units; under PEFT they are the
/// per-block adapter units (the base model stays frozen).
pub struct TunableUnits<B: Backend> {
    pub bufs: Vec<B::Buffer>,
    pub lens: Vec<usize>,
}

impl<B: Backend> TunableUnits<B> {
    /// Upload host vectors (one per unit).
    pub fn from_host(backend: &B, host: &[Vec<f32>]) -> Result<TunableUnits<B>> {
        let bufs = host.iter().map(|u| backend.upload(u)).collect::<Result<Vec<_>>>()?;
        Ok(TunableUnits { bufs, lens: host.iter().map(Vec::len).collect() })
    }

    /// Download every unit (checkpointing, tests).
    pub fn to_host(&self, backend: &B) -> Result<Vec<Vec<f32>>> {
        self.bufs.iter().map(|b| backend.download(b)).collect()
    }

    pub fn n_units(&self) -> usize {
        self.bufs.len()
    }

    pub fn param_count(&self) -> usize {
        self.lens.iter().sum()
    }

    /// Unit handles in forward-argument order.
    pub fn unit_refs(&self) -> Vec<&B::Buffer> {
        self.bufs.iter().collect()
    }
}

/// Outcome of one ZO step.
#[derive(Debug, Clone, Copy)]
pub struct ZoStep {
    pub loss_plus: f32,
    pub loss_minus: f32,
    /// SPSA projected gradient (l+ - l-) / (2 mu).
    pub projected_grad: f32,
    /// Parameters touched this step (perturbed + updated).
    pub active_params: usize,
    /// True when a non-finite forward loss made the engine restore the
    /// perturbation and skip the update (`on_nonfinite=skip-step`).
    pub skipped: bool,
}

impl ZoStep {
    /// The reported training loss for the step (mean of the two probes,
    /// an O(mu^2)-accurate estimate of L(theta)).
    pub fn loss(&self) -> f32 {
        0.5 * (self.loss_plus + self.loss_minus)
    }
}

/// The SPSA/ZO-SGD engine. Stateless across steps; all step-dependent
/// randomness derives from `(run_seed, step)`.
pub struct SpsaEngine<'b, B: Backend> {
    backend: &'b B,
    pub mu: f32,
    pub run_seed: u64,
    /// What a non-finite forward loss does: hard error (default), or restore
    /// the perturbation and skip the step (`on_nonfinite=skip-step`).
    pub on_nonfinite: NonFinitePolicy,
}

impl<'b, B: Backend> SpsaEngine<'b, B> {
    pub fn new(backend: &'b B, mu: f32, run_seed: u64) -> Result<Self> {
        anyhow::ensure!(mu > 0.0, "perturbation scale mu must be positive");
        Ok(SpsaEngine { backend, mu, run_seed, on_nonfinite: NonFinitePolicy::default() })
    }

    /// `unit <- unit + c * z(seed)` for one flat unit. Routed through the
    /// backend's in-place kernel: on the native backend the four sweeps of
    /// a step allocate nothing; device backends fall back to the trait's
    /// allocate-and-swap default.
    fn axpy(&self, units: &mut TunableUnits<B>, k: usize, seed: i32, c: f32) -> Result<()> {
        self.backend.zo_axpy_inplace(&mut units.bufs[k], units.lens[k], seed, c)
    }

    /// Apply `c * z` to every active unit along probe-0 (the classic
    /// SPSA direction).
    fn sweep(
        &self,
        units: &mut TunableUnits<B>,
        active: &[usize],
        step: u64,
        c: f32,
    ) -> Result<()> {
        self.probe_sweep(units, active, step, 0, c)
    }

    /// Apply `c * z` to every active unit along probe `probe`. Probe 0 uses
    /// the pre-zoo seed derivation bit-for-bit (see [`zo_probe_seed`]).
    fn probe_sweep(
        &self,
        units: &mut TunableUnits<B>,
        active: &[usize],
        step: u64,
        probe: u64,
        c: f32,
    ) -> Result<()> {
        for &k in active {
            let seed = zo_probe_seed(self.run_seed, step, probe, k);
            self.axpy(units, k, seed, c)?;
        }
        Ok(())
    }

    /// Apply an optimizer's update coefficients: `unit += c * z(step, probe)`
    /// per [`Coeff`]. Coefficients may replay past steps' directions — the
    /// Philox invariant guarantees the regenerated stream is the one that
    /// step perturbed with.
    fn apply_coeffs(&self, units: &mut TunableUnits<B>, coeffs: &[Coeff]) -> Result<()> {
        for c in coeffs {
            debug_assert!(c.unit < units.n_units());
            let seed = zo_probe_seed(self.run_seed, c.step, c.probe, c.unit);
            self.axpy(units, c.unit, seed, c.c)?;
        }
        Ok(())
    }

    /// One full Algorithm-1 step under the classic ZO-SGD rule. Delegates
    /// to [`Self::zo_step_opt`] with a throwaway [`ZoSgd`] so there is
    /// exactly ONE step code path — `zo_opt=zo-sgd` being bit-identical to
    /// the pre-zoo trajectory is structural, not an accident of testing.
    pub fn zo_step(
        &self,
        step: u64,
        units: &mut TunableUnits<B>,
        active: &[usize],
        lr: f32,
        loss: &mut dyn FnMut(&TunableUnits<B>) -> Result<f32>,
        times: &mut StageTimes,
    ) -> Result<ZoStep> {
        self.zo_step_opt(step, units, active, lr, &mut ZoSgd, loss, times)
    }

    /// Resolve a non-finite forward loss once the perturbation has already
    /// been restored: error with the exact location, or mark the step
    /// skipped. Skipped steps still count toward the stage timer so resumed
    /// and uninterrupted runs agree on step accounting.
    #[allow(clippy::too_many_arguments)]
    fn nonfinite(
        &self,
        step: u64,
        probe: u64,
        l: f32,
        active: &[usize],
        active_params: usize,
        times: &mut StageTimes,
    ) -> Result<ZoStep> {
        match self.on_nonfinite {
            NonFinitePolicy::Error => bail!(
                "non-finite loss {l} at step {}, probe {probe} (active units {active:?}); \
                 set on_nonfinite=skip-step to restore the perturbation and skip instead",
                step + 1
            ),
            NonFinitePolicy::SkipStep => {
                times.steps += 1;
                Ok(ZoStep {
                    loss_plus: l,
                    loss_minus: f32::NAN,
                    projected_grad: f32::NAN,
                    active_params,
                    skipped: true,
                })
            }
        }
    }

    // ---- StepPlan: build / execute / consume -------------------------------

    /// Apply one plan op: `unit <- unit + coeff * z(seed)`. The seed was
    /// precomputed at plan-build time, so this is the only place an
    /// executor touches parameters.
    fn axpy_op(&self, units: &mut TunableUnits<B>, op: &SweepOp) -> Result<()> {
        debug_assert_eq!(units.lens[op.unit], op.len);
        self.backend.zo_axpy_inplace(&mut units.bufs[op.unit], op.len, op.seed, op.coeff)
    }

    /// Emit the [`StepPlan`] for one ZO step: the ordered sweep/eval phases
    /// of the schedule, with every axpy seed precomputed. The phase order
    /// reproduces the imperative step exactly — for `OneSided` the plan
    /// places each probe's eval *before* its `-mu` restore sweep, with that
    /// same restore as the eval's recovery, so finite and aborting
    /// executions issue the identical op sequence the old code did.
    pub fn step_plan(
        &self,
        step: u64,
        units: &TunableUnits<B>,
        active: &[usize],
        schedule: ProbeSchedule,
    ) -> Result<StepPlan> {
        debug_assert!(active.iter().all(|&k| k < units.n_units()));
        let ops = |probe: u64, coeff: f32| -> Vec<SweepOp> {
            active
                .iter()
                .map(|&unit| SweepOp {
                    unit,
                    len: units.lens[unit],
                    seed: zo_probe_seed(self.run_seed, step, probe, unit),
                    coeff,
                })
                .collect()
        };
        Ok(match schedule {
            ProbeSchedule::TwoSided => StepPlan {
                step,
                schedule,
                phases: vec![
                    PlanPhase::Sweep(ops(0, self.mu)),
                    PlanPhase::Eval { idx: 0 },
                    PlanPhase::Sweep(ops(0, -2.0 * self.mu)),
                    PlanPhase::Eval { idx: 1 },
                    PlanPhase::Sweep(ops(0, self.mu)),
                ],
                evals: vec![EvalSpec { probe: 0 }, EvalSpec { probe: 0 }],
                recovery: vec![ops(0, -self.mu), ops(0, self.mu)],
            },
            ProbeSchedule::OneSided { probes } => {
                anyhow::ensure!(probes >= 1, "one-sided schedule needs >= 1 probe");
                let mut phases = vec![PlanPhase::Eval { idx: 0 }];
                let mut evals = vec![EvalSpec { probe: 0 }];
                // baseline eval: nothing perturbed yet, nothing to recover
                let mut recovery = vec![Vec::new()];
                for p in 0..probes as u64 {
                    phases.push(PlanPhase::Sweep(ops(p, self.mu)));
                    phases.push(PlanPhase::Eval { idx: evals.len() });
                    phases.push(PlanPhase::Sweep(ops(p, -self.mu)));
                    evals.push(EvalSpec { probe: p });
                    // aborting at probe p's eval must still undo its +mu
                    // sweep — the very op the finite path runs next anyway
                    recovery.push(ops(p, -self.mu));
                }
                StepPlan { step, schedule, phases, evals, recovery }
            }
        })
    }

    /// The sequential plan executor: walk the phases in order against this
    /// engine's backend, checking each loss as it lands. On the first
    /// non-finite loss the eval's recovery sweep restores theta and the
    /// remaining phases are skipped.
    fn run_plan_seq(
        &self,
        plan: &StepPlan,
        units: &mut TunableUnits<B>,
        loss: &mut dyn FnMut(&TunableUnits<B>) -> Result<f32>,
        times: &mut StageTimes,
    ) -> Result<PlanResult> {
        let mut t = StageTimer::start();
        let mut losses = Vec::with_capacity(plan.evals.len());
        for phase in &plan.phases {
            match phase {
                PlanPhase::Sweep(ops) => {
                    for op in ops {
                        self.axpy_op(units, op)?;
                    }
                    times.perturb_secs += t.lap();
                }
                PlanPhase::Eval { idx } => {
                    debug_assert_eq!(*idx, losses.len());
                    let l = loss(units)?;
                    times.forward_secs += t.lap();
                    losses.push(l);
                    if !l.is_finite() {
                        for op in &plan.recovery[*idx] {
                            self.axpy_op(units, op)?;
                        }
                        times.perturb_secs += t.lap();
                        return Ok(PlanResult { losses, aborted: Some(*idx) });
                    }
                }
            }
        }
        Ok(PlanResult { losses, aborted: None })
    }

    /// Consume a plan's gathered `(probe, loss)` scalars: map them to
    /// projected gradients, let the optimizer turn those into [`Coeff`]s,
    /// and apply the update. This is the only step stage that depends on
    /// the losses, so it runs after *any* executor — sequential or fan-out.
    fn finish_step(
        &self,
        plan: &StepPlan,
        res: PlanResult,
        units: &mut TunableUnits<B>,
        active: &[usize],
        lr: f32,
        opt: &mut dyn ZoOptimizer,
        times: &mut StageTimes,
    ) -> Result<ZoStep> {
        let active_params = active.iter().map(|&k| units.lens[k]).sum();
        if let Some(e) = res.aborted {
            // the executor already restored theta; decide the policy
            let probe = plan.evals[e].probe;
            return self.nonfinite(plan.step, probe, res.losses[e], active, active_params, times);
        }
        let mut t = StageTimer::start();
        match plan.schedule {
            ProbeSchedule::TwoSided => {
                let (loss_plus, loss_minus) = (res.losses[0], res.losses[1]);
                let projected_grad = (loss_plus - loss_minus) / (2.0 * self.mu);
                let coeffs = opt.coeffs(plan.step, &[projected_grad], active, lr);
                self.apply_coeffs(units, &coeffs)?;
                times.update_secs += t.lap();
                times.steps += 1;
                Ok(ZoStep { loss_plus, loss_minus, projected_grad, active_params, skipped: false })
            }
            ProbeSchedule::OneSided { .. } => {
                let l0 = res.losses[0];
                let gs: Vec<f32> = res.losses[1..].iter().map(|&lp| (lp - l0) / self.mu).collect();
                let coeffs = opt.coeffs(plan.step, &gs, active, lr);
                self.apply_coeffs(units, &coeffs)?;
                times.update_secs += t.lap();
                times.steps += 1;

                // one-sided probes share the baseline: report it as both
                // endpoints so loss() is the unperturbed training loss
                let g_mean = gs.iter().sum::<f32>() / gs.len() as f32;
                Ok(ZoStep {
                    loss_plus: l0,
                    loss_minus: l0,
                    projected_grad: g_mean,
                    active_params,
                    skipped: false,
                })
            }
        }
    }

    /// One ZO step under a pluggable update rule. The optimizer picks the
    /// probe schedule (two-sided classic, or one-sided batched) and maps
    /// the projected gradient(s) to update coefficients; the engine owns
    /// perturbation, forwards, and coefficient application. `loss` captures
    /// whatever else the forward pass needs (frozen base units, the
    /// uploaded batch). Stage wall-times accumulate into `times` (Fig. 2
    /// instrumentation).
    ///
    /// Since PR 8 this is plan build + the sequential executor + the loss
    /// consumer — the identical op sequence the pre-plan imperative body
    /// issued (pinned by `plan_executor_is_bit_identical_to_zo_step`).
    pub fn zo_step_opt(
        &self,
        step: u64,
        units: &mut TunableUnits<B>,
        active: &[usize],
        lr: f32,
        opt: &mut dyn ZoOptimizer,
        loss: &mut dyn FnMut(&TunableUnits<B>) -> Result<f32>,
        times: &mut StageTimes,
    ) -> Result<ZoStep> {
        debug_assert!(active.iter().all(|&k| k < units.n_units()));
        let plan = self.step_plan(step, units, active, opt.schedule())?;
        let res = self.run_plan_seq(&plan, units, loss, times)?;
        self.finish_step(&plan, res, units, active, lr, opt, times)
    }

    /// One ZO step routed through the backend's plan **fan-out** executor
    /// ([`Backend::run_zo_plan`]) instead of the sequential one — the
    /// sharded backend distributes the plan's forward evaluations across
    /// worker replicas and gathers only `(probe, loss)` scalars. The
    /// optimizer update still happens here, broadcast through
    /// `zo_axpy_inplace` like every other sweep. `inject` is the trainer's
    /// fault hook, called once per eval index in eval order.
    #[allow(clippy::too_many_arguments)]
    pub fn zo_step_fanout(
        &self,
        step: u64,
        units: &mut TunableUnits<B>,
        active: &[usize],
        lr: f32,
        opt: &mut dyn ZoOptimizer,
        peft: PeftMode,
        base: Option<&[B::Buffer]>,
        batch: &B::PreparedBatch,
        inject: &mut dyn FnMut(usize) -> Result<Option<f32>>,
        times: &mut StageTimes,
    ) -> Result<ZoStep> {
        debug_assert!(active.iter().all(|&k| k < units.n_units()));
        let plan = self.step_plan(step, units, active, opt.schedule())?;
        let res =
            self.backend.run_zo_plan(&plan, &mut units.bufs, peft, base, batch, inject, times)?;
        self.finish_step(&plan, res, units, active, lr, opt, times)
    }

    // ---- Sparse-MeZO (element-wise magnitude mask) -------------------------

    /// Masked sweep: `unit <- unit + c * z * [|pref| <= tau]` over every
    /// unit. `pref` is the unperturbed snapshot taken at step start so the
    /// mask stays identical across the four phases.
    fn masked_sweep(
        &self,
        units: &mut TunableUnits<B>,
        pref: &[B::Buffer],
        taus: &[f32],
        step: u64,
        c: f32,
    ) -> Result<()> {
        for k in 0..units.n_units() {
            let seed = zo_seed(self.run_seed, step, k);
            self.backend.zo_axpy_masked_inplace(
                &mut units.bufs[k],
                &pref[k],
                taus[k],
                units.lens[k],
                seed,
                c,
            )?;
        }
        Ok(())
    }

    /// One Sparse-MeZO step (the related-work baseline): same SPSA schedule
    /// as [`Self::zo_step`] but with an element-wise magnitude mask instead
    /// of LeZO's structural layer skip. Every unit's buffer is streamed
    /// through the masked kernel in all four phases — the computation does
    /// NOT shrink with sparsity, which is exactly the asymmetry the paper
    /// criticizes (and the bench measures).
    pub fn zo_step_masked(
        &self,
        step: u64,
        units: &mut TunableUnits<B>,
        taus: &[f32],
        lr: f32,
        loss: &mut dyn FnMut(&TunableUnits<B>) -> Result<f32>,
        times: &mut StageTimes,
    ) -> Result<ZoStep> {
        anyhow::ensure!(taus.len() == units.n_units(), "one tau per unit");
        let mut t = StageTimer::start();

        // snapshot: the first perturb goes through the *allocating* masked
        // kernel, so the pre-step handles ARE the reference — we keep them
        // alive here (Sparse-MeZO's extra state, held one step) while the
        // fresh buffers replace them in `units`. The later sweeps mutate
        // `units` in place against this stable snapshot.
        let mut pref: Vec<B::Buffer> = Vec::with_capacity(units.n_units());
        for k in 0..units.n_units() {
            let seed = zo_seed(self.run_seed, step, k);
            let out = self.backend.zo_axpy_masked(
                &units.bufs[k],
                &units.bufs[k],
                taus[k],
                units.lens[k],
                seed,
                self.mu,
            )?;
            pref.push(std::mem::replace(&mut units.bufs[k], out));
        }
        times.perturb_secs += t.lap();
        let loss_plus = loss(units)?;
        times.forward_secs += t.lap();
        if !loss_plus.is_finite() {
            self.masked_sweep(units, &pref, taus, step, -self.mu)?;
            times.perturb_secs += t.lap();
            let all: Vec<usize> = (0..units.n_units()).collect();
            return self.nonfinite(step, 0, loss_plus, &all, units.param_count(), times);
        }

        self.masked_sweep(units, &pref, taus, step, -2.0 * self.mu)?;
        times.perturb_secs += t.lap();
        let loss_minus = loss(units)?;
        times.forward_secs += t.lap();
        if !loss_minus.is_finite() {
            self.masked_sweep(units, &pref, taus, step, self.mu)?;
            times.perturb_secs += t.lap();
            let all: Vec<usize> = (0..units.n_units()).collect();
            return self.nonfinite(step, 0, loss_minus, &all, units.param_count(), times);
        }

        self.masked_sweep(units, &pref, taus, step, self.mu)?;
        times.perturb_secs += t.lap();

        let projected_grad = (loss_plus - loss_minus) / (2.0 * self.mu);
        self.masked_sweep(units, &pref, taus, step, -lr * projected_grad)?;
        times.update_secs += t.lap();
        times.steps += 1;

        Ok(ZoStep {
            loss_plus,
            loss_minus,
            projected_grad,
            active_params: units.param_count(), // traffic-wise everything is touched
            skipped: false,
        })
    }

    /// Perturb-only probe (used by tests and the Lemma-3 bench): applies
    /// `c*z` for `(step, active)` and returns nothing. Calling with `c` and
    /// then `-c` must be an identity to fp tolerance.
    pub fn apply(
        &self,
        step: u64,
        units: &mut TunableUnits<B>,
        active: &[usize],
        c: f32,
    ) -> Result<()> {
        self.sweep(units, active, step, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelSpec;
    use crate::runtime::NativeBackend;

    // All engine invariants run hermetically on the native backend; the
    // identical code path executes on PJRT (rust/tests/integration.rs).

    fn setup() -> (NativeBackend, ModelSpec) {
        let b = NativeBackend::preset("opt-nano").unwrap();
        let spec = b.spec().clone();
        (b, spec)
    }

    fn tunable(b: &NativeBackend, spec: &ModelSpec) -> TunableUnits<NativeBackend> {
        TunableUnits::from_host(b, &spec.init_units(0)).unwrap()
    }

    #[test]
    fn perturb_then_inverse_is_identity() {
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-3, 7).unwrap();
        let mut units = tunable(&b, &spec);
        let orig = units.to_host(&b).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        eng.apply(3, &mut units, &active, 0.5).unwrap();
        eng.apply(3, &mut units, &active, -0.5).unwrap();
        let now = units.to_host(&b).unwrap();
        for (k, (a, o)) in now.iter().zip(&orig).enumerate() {
            for (x, y) in a.iter().zip(o) {
                assert!((x - y).abs() < 1e-4, "unit {k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn zo_step_restores_inactive_and_moves_active() {
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-2, 11).unwrap();
        let mut units = tunable(&b, &spec);
        let orig = units.to_host(&b).unwrap();
        // drop unit 2: it must come back bit-comparable after the step
        let active: Vec<usize> = (0..units.n_units()).filter(|&k| k != 2).collect();
        let mut times = StageTimes::default();
        // a loss with a real gradient signal: distance of unit 1 to zero
        let mut loss = |u: &TunableUnits<NativeBackend>| -> Result<f32> {
            let v = b.download(&u.bufs[1])?;
            Ok(v.iter().map(|x| x * x).sum::<f32>())
        };
        let step = eng.zo_step(0, &mut units, &active, 1e-3, &mut loss, &mut times).unwrap();
        assert!(step.projected_grad.is_finite());
        assert_eq!(
            step.active_params,
            active.iter().map(|&k| spec.unit_lens()[k]).sum::<usize>()
        );
        let after = units.to_host(&b).unwrap();
        assert_eq!(after[2], orig[2], "dropped unit must be untouched");
        assert_ne!(after[1], orig[1], "active unit must be updated");
        assert_eq!(times.steps, 1);
        assert!(times.perturb_secs >= 0.0 && times.forward_secs >= 0.0);
    }

    #[test]
    fn lr_zero_step_is_an_exact_restore_of_every_unit() {
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-3, 5).unwrap();
        let mut units = tunable(&b, &spec);
        let orig = units.to_host(&b).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        let mut times = StageTimes::default();
        let mut loss = |_: &TunableUnits<NativeBackend>| -> Result<f32> { Ok(1.0) };
        eng.zo_step(0, &mut units, &active, 0.0, &mut loss, &mut times).unwrap();
        let after = units.to_host(&b).unwrap();
        for (k, (a, o)) in after.iter().zip(&orig).enumerate() {
            for (x, y) in a.iter().zip(o) {
                assert!((x - y).abs() < 1e-5, "unit {k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn same_seed_same_trajectory() {
        let (b, spec) = setup();
        let mut final_states = vec![];
        for _ in 0..2 {
            let eng = SpsaEngine::new(&b, 1e-3, 42).unwrap();
            let mut units = tunable(&b, &spec);
            let active: Vec<usize> = (0..units.n_units()).collect();
            let mut times = StageTimes::default();
            let mut loss = |u: &TunableUnits<NativeBackend>| -> Result<f32> {
                let v = b.download(&u.bufs[0])?;
                Ok(v.iter().take(100).sum::<f32>())
            };
            for t in 0..3 {
                eng.zo_step(t, &mut units, &active, 1e-4, &mut loss, &mut times).unwrap();
            }
            final_states.push(b.download(&units.bufs[0]).unwrap());
        }
        assert_eq!(final_states[0], final_states[1], "run must be reproducible");
    }

    #[test]
    fn zo_step_opt_sgd_is_bit_identical_to_zo_step() {
        // the zoo's anchor invariant: routing the classic rule through the
        // ZoOptimizer plumbing must reproduce the exact same trajectory —
        // same seeds, same axpy order, same f32 coefficients
        use crate::coordinator::optim::ZoSgd;
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-3, 42).unwrap();
        let mut classic = tunable(&b, &spec);
        let mut via_opt = tunable(&b, &spec);
        let active: Vec<usize> = (0..classic.n_units()).filter(|&k| k != 1).collect();
        let mut times = StageTimes::default();
        let mut loss = |u: &TunableUnits<NativeBackend>| -> Result<f32> {
            let v = b.download(&u.bufs[0])?;
            Ok(v.iter().take(100).sum::<f32>())
        };
        let mut opt = ZoSgd;
        for t in 0..3 {
            let a = eng.zo_step(t, &mut classic, &active, 1e-3, &mut loss, &mut times).unwrap();
            let c = eng
                .zo_step_opt(t, &mut via_opt, &active, 1e-3, &mut opt, &mut loss, &mut times)
                .unwrap();
            assert_eq!(a.loss_plus, c.loss_plus);
            assert_eq!(a.projected_grad, c.projected_grad);
        }
        assert_eq!(
            classic.to_host(&b).unwrap(),
            via_opt.to_host(&b).unwrap(),
            "zo-sgd through the optimizer plumbing must be bit-identical"
        );
    }

    #[test]
    fn plan_executor_is_bit_identical_to_zo_step() {
        // the tentpole invariant: zo_step (now plan build + sequential
        // executor) must reproduce the pre-plan imperative op sequence
        // exactly — written out longhand here via the public perturb-only
        // API, same seeds, same order, same f32 coefficients
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-3, 42).unwrap();
        let mut planned = tunable(&b, &spec);
        let mut longhand = tunable(&b, &spec);
        let active: Vec<usize> = (0..planned.n_units()).filter(|&k| k != 1).collect();
        let mut times = StageTimes::default();
        let (mu, lr) = (eng.mu, 1e-3f32);
        let mut loss = |u: &TunableUnits<NativeBackend>| -> Result<f32> {
            let v = b.download(&u.bufs[0])?;
            Ok(v.iter().take(100).sum::<f32>())
        };
        for t in 0..3 {
            let zs = eng.zo_step(t, &mut planned, &active, lr, &mut loss, &mut times).unwrap();

            // the old imperative two-sided body, spelled out
            eng.apply(t, &mut longhand, &active, mu).unwrap();
            let lp = loss(&longhand).unwrap();
            eng.apply(t, &mut longhand, &active, -2.0 * mu).unwrap();
            let lm = loss(&longhand).unwrap();
            eng.apply(t, &mut longhand, &active, mu).unwrap();
            let g = (lp - lm) / (2.0 * mu);
            // zo-sgd's coeffs are a probe-0 sweep with c = -lr * g
            eng.apply(t, &mut longhand, &active, -lr * g).unwrap();

            assert_eq!(zs.loss_plus.to_bits(), lp.to_bits(), "step {t}: loss+");
            assert_eq!(zs.loss_minus.to_bits(), lm.to_bits(), "step {t}: loss-");
            assert_eq!(zs.projected_grad.to_bits(), g.to_bits(), "step {t}: grad");
        }
        assert_eq!(
            planned.to_host(&b).unwrap(),
            longhand.to_host(&b).unwrap(),
            "plan executor must be bit-identical to the imperative step"
        );
    }

    #[test]
    fn step_plan_shapes_match_the_schedules() {
        use crate::runtime::plan::PlanPhase;
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-3, 7).unwrap();
        let units = tunable(&b, &spec);
        let active = vec![0usize, 3];

        let two = eng.step_plan(4, &units, &active, ProbeSchedule::TwoSided).unwrap();
        assert_eq!(two.phases.len(), 5);
        assert_eq!(two.evals.len(), 2);
        assert_eq!(two.recovery.len(), 2);
        assert_eq!(two.touched_units(), active, "only active units appear in sweeps");
        let coeffs: Vec<f32> = two
            .phases
            .iter()
            .filter_map(|p| match p {
                PlanPhase::Sweep(ops) => Some(ops[0].coeff),
                _ => None,
            })
            .collect();
        assert_eq!(coeffs, vec![eng.mu, -2.0 * eng.mu, eng.mu]);
        // probe-0 plan seeds are the classic zo_seed derivation, bit-for-bit
        match &two.phases[0] {
            PlanPhase::Sweep(ops) => {
                for op in ops {
                    assert_eq!(op.seed, zo_seed(eng.run_seed, 4, op.unit));
                    assert_eq!(op.len, units.lens[op.unit]);
                }
            }
            other => panic!("expected sweep, got {other:?}"),
        }

        let one = eng
            .step_plan(4, &units, &active, ProbeSchedule::OneSided { probes: 3 })
            .unwrap();
        assert_eq!(one.phases.len(), 1 + 3 * 3, "baseline eval + (sweep, eval, sweep) per probe");
        assert_eq!(one.evals.len(), 4);
        assert_eq!(one.evals.iter().map(|e| e.probe).collect::<Vec<_>>(), vec![0, 0, 1, 2]);
        assert!(one.recovery[0].is_empty(), "baseline eval needs no recovery");
        for r in &one.recovery[1..] {
            assert!(r.iter().all(|op| op.coeff == -eng.mu));
        }
        assert!(eng.step_plan(4, &units, &active, ProbeSchedule::OneSided { probes: 0 }).is_err());
    }

    #[test]
    fn one_sided_lr_zero_step_restores_every_unit() {
        use crate::coordinator::optim::ZoFzoo;
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-3, 13).unwrap();
        let mut units = tunable(&b, &spec);
        let orig = units.to_host(&b).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        let mut times = StageTimes::default();
        let mut opt = ZoFzoo::new(4);
        let mut loss = |_: &TunableUnits<NativeBackend>| -> Result<f32> { Ok(1.0) };
        let zs = eng
            .zo_step_opt(0, &mut units, &active, 0.0, &mut opt, &mut loss, &mut times)
            .unwrap();
        assert_eq!(zs.loss(), 1.0, "one-sided loss is the baseline forward");
        // 5 forwards: baseline + one per probe
        let after = units.to_host(&b).unwrap();
        for (k, (a, o)) in after.iter().zip(&orig).enumerate() {
            for (x, y) in a.iter().zip(o) {
                assert!((x - y).abs() < 1e-4, "unit {k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn momentum_replay_never_touches_dropped_units() {
        // a unit outside every step's active set must be bit-untouched even
        // though the optimizer replays history across steps
        use crate::coordinator::optim::ZoMomentum;
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-2, 21).unwrap();
        let mut units = tunable(&b, &spec);
        let orig = units.to_host(&b).unwrap();
        let active: Vec<usize> = (0..units.n_units()).filter(|&k| k != 2).collect();
        let mut times = StageTimes::default();
        let mut opt = ZoMomentum::new(0.9);
        let mut loss = |u: &TunableUnits<NativeBackend>| -> Result<f32> {
            let v = b.download(&u.bufs[1])?;
            Ok(v.iter().map(|x| x * x).sum::<f32>())
        };
        for t in 0..4 {
            eng.zo_step_opt(t, &mut units, &active, 1e-3, &mut opt, &mut loss, &mut times)
                .unwrap();
        }
        let after = units.to_host(&b).unwrap();
        assert_eq!(after[2], orig[2], "dropped unit must be untouched by replay");
        assert_ne!(after[1], orig[1], "active unit must move");
        assert!(opt.state_bytes() > 0);
    }

    #[test]
    fn nonfinite_loss_is_a_hard_error_by_default() {
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-3, 3).unwrap();
        let mut units = tunable(&b, &spec);
        let active: Vec<usize> = (0..units.n_units()).collect();
        let mut times = StageTimes::default();
        let mut loss = |_: &TunableUnits<NativeBackend>| -> Result<f32> { Ok(f32::NAN) };
        let err = eng
            .zo_step(4, &mut units, &active, 1e-3, &mut loss, &mut times)
            .unwrap_err()
            .to_string();
        assert!(err.contains("non-finite loss"), "{err}");
        assert!(err.contains("step 5") && err.contains("probe 0"), "{err}");
        assert_eq!(times.steps, 0);
    }

    #[test]
    fn skip_step_policy_restores_params_and_skips_update() {
        use crate::coordinator::faults::NonFinitePolicy;
        let (b, spec) = setup();
        let mut eng = SpsaEngine::new(&b, 1e-3, 3).unwrap();
        eng.on_nonfinite = NonFinitePolicy::SkipStep;
        let mut units = tunable(&b, &spec);
        let orig = units.to_host(&b).unwrap();
        let active: Vec<usize> = (0..units.n_units()).collect();
        let mut times = StageTimes::default();
        // second forward (the -mu probe) is the non-finite one
        let mut calls = 0u32;
        let mut loss = |_: &TunableUnits<NativeBackend>| -> Result<f32> {
            calls += 1;
            Ok(if calls == 2 { f32::INFINITY } else { 1.0 })
        };
        let zs = eng.zo_step(0, &mut units, &active, 1e-3, &mut loss, &mut times).unwrap();
        assert!(zs.skipped);
        assert!(zs.loss().is_nan(), "skipped step reports the raw non-finite loss");
        assert_eq!(times.steps, 1, "skipped steps still count in stage accounting");
        let after = units.to_host(&b).unwrap();
        for (k, (a, o)) in after.iter().zip(&orig).enumerate() {
            for (x, y) in a.iter().zip(o) {
                assert!((x - y).abs() < 1e-5, "unit {k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn masked_step_with_lr_zero_restores_exactly() {
        let (b, spec) = setup();
        let eng = SpsaEngine::new(&b, 1e-3, 9).unwrap();
        let mut units = tunable(&b, &spec);
        let orig = units.to_host(&b).unwrap();
        // mask in roughly the small half of each unit
        let taus: Vec<f32> = orig
            .iter()
            .map(|u| {
                let mut mags: Vec<f32> = u.iter().map(|x| x.abs()).collect();
                mags.sort_by(f32::total_cmp);
                mags[mags.len() / 2]
            })
            .collect();
        let mut times = StageTimes::default();
        let mut loss = |_: &TunableUnits<NativeBackend>| -> Result<f32> { Ok(0.5) };
        let zs = eng.zo_step_masked(0, &mut units, &taus, 0.0, &mut loss, &mut times).unwrap();
        assert_eq!(zs.active_params, units.param_count());
        let after = units.to_host(&b).unwrap();
        for (a, o) in after.iter().zip(&orig) {
            for (x, y) in a.iter().zip(o) {
                assert!((x - y).abs() < 1e-5, "{x} vs {y}");
            }
        }
    }
}

//! The pluggable ZO optimizer zoo: update rules over the SPSA projected
//! gradient, decoupled from the probe schedule in [`crate::coordinator::spsa`].
//!
//! The split: `SpsaEngine` owns *perturbation* (which seeds, which sweeps,
//! how the probes are scheduled — two-sided classic or one-sided batched)
//! and a [`ZoOptimizer`] owns the *update rule* — it maps the step's
//! projected gradient(s) to a list of [`Coeff`]s, each "add `c * z(seed)`
//! to unit `k`", which the engine applies through the backend's
//! `zo_axpy_inplace`. Because an update is nothing but seeded axpys, every
//! rule runs on every backend and composes with LeZO's layer-wise active
//! set for free (the selector stays orthogonal: it picks which units a
//! step perturbs; the rule decides how hard to push along each stored
//! direction).
//!
//! ## Seed-replay optimizer state (the memory story)
//!
//! MeZO's trick stores no perturbation; the same idea extends to momentum
//! and Adam. A first moment over SPSA steps is a sum of rank-1 directions,
//! `m_t = sum_s w(t-s) * g_s * z_s`, and `z_s` is regenerated from
//! `(run_seed, step s, unit)` on demand — so the optimizer state is the
//! scalar history `(step, g_s, active set)`, **not** a parameter-sized
//! moment buffer. The replay window is truncated where the decay weight
//! drops below [`REPLAY_TOL`] (the dropped tail contributes less than
//! `REPLAY_TOL * sum |g|` of the momentum norm). [`ZoOptimizer::state_bytes`]
//! reports the measured bytes of that history — the number that lands in
//! `TrainReport::zo_state_bytes` next to the FO baseline's parameter-sized
//! `fo_state_bytes`.
//!
//! Adam's second moment is the one thing a per-unit coefficient *cannot*
//! express element-wise (it would need a stored per-element `v`, exactly
//! the buffer this design refuses to materialize), so [`ZoAdam`] keeps a
//! **scalar** second moment over the projected gradient: since
//! `E[(g z_i)^2] = g^2`, the scalar `v_t` tracks the per-element second
//! moment in expectation, preserving Adam's step-size normalization
//! without the memory.

use anyhow::Result;
use std::collections::VecDeque;
use std::fmt;
use std::str::FromStr;

/// Replay weights below this are truncated from the momentum window.
pub const REPLAY_TOL: f64 = 1e-4;

/// Default momentum decay for `zo-sgd-momentum`.
pub const MOMENTUM_BETA: f32 = 0.9;

/// Default probe count of the one-sided batched (FZOO-style) schedule.
pub const FZOO_PROBES: usize = 4;

/// Which ZO update rule drives a run (config key `zo_opt`, env
/// `LEZO_ZO_OPT` — env wins, mirroring `precision`/`LEZO_PRECISION`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ZoOptKind {
    /// Today's rule: `theta -= lr * g * z` (bit-identical, test-pinned).
    #[default]
    Sgd,
    /// Heavy-ball momentum over seed-replayed directions.
    Momentum,
    /// Adam with a replayed first moment and a scalar second moment.
    Adam,
    /// `theta -= lr * sign(g) * z` — magnitude-free steps.
    SignSgd,
    /// FZOO-style one-sided batched perturbations with a
    /// variance-normalized step size.
    Fzoo,
}

pub const ZO_OPT_NAMES: &str = "zo-sgd|zo-sgd-momentum|zo-adam|zo-sign-sgd|fzoo";

impl FromStr for ZoOptKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "zo-sgd" | "sgd" => ZoOptKind::Sgd,
            "zo-sgd-momentum" | "zo-momentum" | "momentum" => ZoOptKind::Momentum,
            "zo-adam" | "adam" => ZoOptKind::Adam,
            "zo-sign-sgd" | "sign-sgd" | "sign" => ZoOptKind::SignSgd,
            "fzoo" | "zo-fzoo" => ZoOptKind::Fzoo,
            _ => anyhow::bail!("unknown zo optimizer '{s}' ({ZO_OPT_NAMES})"),
        })
    }
}

impl fmt::Display for ZoOptKind {
    /// Canonical names: what reports print and what the bench JSON rows
    /// are keyed by.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ZoOptKind::Sgd => "zo-sgd",
            ZoOptKind::Momentum => "zo-sgd-momentum",
            ZoOptKind::Adam => "zo-adam",
            ZoOptKind::SignSgd => "zo-sign-sgd",
            ZoOptKind::Fzoo => "fzoo",
        })
    }
}

/// `LEZO_ZO_OPT`: unset/empty means "no override"; anything else must
/// parse as an optimizer — an unparseable value is a hard error naming the
/// bad value (the same strictness rule as `LEZO_THREADS` /
/// `LEZO_PRECISION`), never a silent fall-through to the default.
pub fn env_zo_opt() -> Result<Option<ZoOptKind>> {
    match std::env::var("LEZO_ZO_OPT") {
        Err(_) => Ok(None),
        Ok(v) if v.is_empty() => Ok(None),
        Ok(v) => v.parse().map(Some).map_err(|_| {
            anyhow::anyhow!("LEZO_ZO_OPT='{v}' is not a zo optimizer ({ZO_OPT_NAMES})")
        }),
    }
}

/// Resolve the update rule for a run: the `LEZO_ZO_OPT` env override wins
/// (mirroring `LEZO_PRECISION`), else the config key's value.
pub fn resolve_zo_opt(requested: ZoOptKind) -> Result<ZoOptKind> {
    Ok(env_zo_opt()?.unwrap_or(requested))
}

/// How the engine probes the loss for one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeSchedule {
    /// Classic SPSA: perturb `+mu`, flip to `-mu`, restore — two forwards,
    /// one direction (probe 0).
    TwoSided,
    /// One-sided batched: one baseline forward, then `probes` independent
    /// directions each perturbed `+mu` and restored — `probes + 1`
    /// forwards, `probes` projected gradients.
    OneSided { probes: usize },
}

/// One seeded axpy of an update: `unit += c * z(run_seed, step, probe, unit)`
/// (seed via [`crate::rng::zo_probe_seed`]; probe 0 is the classic stream).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Coeff {
    pub step: u64,
    pub probe: u64,
    pub unit: usize,
    pub c: f32,
}

/// A ZO update rule. Stateful across steps (replay history); the engine
/// calls [`Self::coeffs`] exactly once per step, in step order.
pub trait ZoOptimizer {
    fn kind(&self) -> ZoOptKind;

    /// The probe schedule this rule needs. The engine consults it once per
    /// step; `gs` handed to [`Self::coeffs`] has one entry per probe
    /// (length 1 under [`ProbeSchedule::TwoSided`]).
    fn schedule(&self) -> ProbeSchedule {
        ProbeSchedule::TwoSided
    }

    /// Map this step's projected gradient(s) to update coefficients.
    /// `active` is the step's LeZO active set (the units that were
    /// perturbed); returned coefficients may also reference *past* steps'
    /// units (seed replay) — never a probe/step pair that was not
    /// perturbed under that seed.
    fn coeffs(&mut self, step: u64, gs: &[f32], active: &[usize], lr: f32) -> Vec<Coeff>;

    /// Measured bytes of optimizer state currently held (the ZO side of
    /// the paper's memory comparison; 0 for stateless rules).
    fn state_bytes(&self) -> usize {
        0
    }

    /// True if the rule carries state across steps that resume must rebuild
    /// by replaying the stored per-step projected gradients through
    /// [`Self::coeffs`] (the seed-replay rules: momentum, adam). Stateless
    /// rules skip the replay — their update depends on the step alone.
    fn stateful(&self) -> bool {
        false
    }
}

/// Build the default-hyperparameter optimizer for `kind`. The trainer
/// special-cases [`ZoAdam`] to reuse the `adam_*` config keys.
pub fn make_optimizer(kind: ZoOptKind) -> Box<dyn ZoOptimizer> {
    match kind {
        ZoOptKind::Sgd => Box::new(ZoSgd),
        ZoOptKind::Momentum => Box::new(ZoMomentum::new(MOMENTUM_BETA)),
        ZoOptKind::Adam => Box::new(ZoAdam::new(0.9, 0.999, 1e-8)),
        ZoOptKind::SignSgd => Box::new(ZoSignSgd),
        ZoOptKind::Fzoo => Box::new(ZoFzoo::new(FZOO_PROBES)),
    }
}

// ---------------------------------------------------------------------------
// zo-sgd (the bit-identity anchor)
// ---------------------------------------------------------------------------

/// Plain ZO-SGD: one coefficient `-lr * g` per active unit, in active-set
/// order — the exact axpy sequence (same seeds, same `f32` product) the
/// pre-zoo engine issued, so `zo_opt=zo-sgd` is bit-identical to the old
/// trajectory (pinned in `spsa::tests`).
pub struct ZoSgd;

impl ZoOptimizer for ZoSgd {
    fn kind(&self) -> ZoOptKind {
        ZoOptKind::Sgd
    }

    fn coeffs(&mut self, step: u64, gs: &[f32], active: &[usize], lr: f32) -> Vec<Coeff> {
        debug_assert_eq!(gs.len(), 1);
        let c = -lr * gs[0];
        active.iter().map(|&unit| Coeff { step, probe: 0, unit, c }).collect()
    }
}

// ---------------------------------------------------------------------------
// Seed-replay history shared by momentum and Adam
// ---------------------------------------------------------------------------

struct Hist {
    step: u64,
    g: f32,
    active: Vec<usize>,
}

fn replay_bytes(hist: &VecDeque<Hist>) -> usize {
    // step (8) + g (4) + one usize per stored active unit — the honest
    // size of what replay actually keeps, vs. 4 bytes/param for a dense
    // moment buffer
    hist.iter().map(|h| 8 + 4 + 8 * h.active.len()).sum()
}

/// Decay window: ages with `beta^age < REPLAY_TOL` are truncated.
fn replay_window(beta: f32) -> usize {
    debug_assert!((0.0..1.0).contains(&beta));
    (REPLAY_TOL.ln() / (beta as f64).ln()).ceil() as usize
}

// ---------------------------------------------------------------------------
// zo-sgd-momentum
// ---------------------------------------------------------------------------

/// Heavy-ball momentum, seed-replayed: `m_t = sum_s beta^(t-s) g_s z_s`
/// and the step applies `-lr * m_t` — i.e. coefficient
/// `-lr * beta^age * g_s` on every unit that was active at step `s`.
pub struct ZoMomentum {
    beta: f32,
    window: usize,
    hist: VecDeque<Hist>,
}

impl ZoMomentum {
    pub fn new(beta: f32) -> ZoMomentum {
        ZoMomentum { beta, window: replay_window(beta), hist: VecDeque::new() }
    }
}

impl ZoOptimizer for ZoMomentum {
    fn kind(&self) -> ZoOptKind {
        ZoOptKind::Momentum
    }

    fn coeffs(&mut self, step: u64, gs: &[f32], active: &[usize], lr: f32) -> Vec<Coeff> {
        debug_assert_eq!(gs.len(), 1);
        self.hist.push_back(Hist { step, g: gs[0], active: active.to_vec() });
        if self.hist.len() > self.window {
            self.hist.pop_front();
        }
        let newest = self.hist.len() - 1;
        let mut out = Vec::new();
        for (i, h) in self.hist.iter().enumerate() {
            let w = self.beta.powi((newest - i) as i32);
            let c = -lr * w * h.g;
            out.extend(h.active.iter().map(|&unit| Coeff { step: h.step, probe: 0, unit, c }));
        }
        out
    }

    fn state_bytes(&self) -> usize {
        replay_bytes(&self.hist)
    }

    fn stateful(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// zo-adam
// ---------------------------------------------------------------------------

/// Adam over seed-replayed directions: bias-corrected first moment
/// `m_t = sum_s (1-b1) b1^(t-s) g_s z_s`, **scalar** second moment
/// `v_t = b2 v_{t-1} + (1-b2) g_t^2` (see module docs for why element-wise
/// `v` is out of reach for a coefficient-based update, and why the scalar
/// matches it in expectation). Per-entry coefficient:
/// `-lr * (1-b1) * b1^age * g_s / bc1 / (sqrt(v_t/bc2) + eps)`.
pub struct ZoAdam {
    beta1: f32,
    beta2: f64,
    eps: f64,
    window: usize,
    t: u64,
    v: f64,
    hist: VecDeque<Hist>,
}

impl ZoAdam {
    pub fn new(beta1: f64, beta2: f64, eps: f64) -> ZoAdam {
        ZoAdam {
            beta1: beta1 as f32,
            beta2,
            eps,
            window: replay_window(beta1 as f32),
            t: 0,
            v: 0.0,
            hist: VecDeque::new(),
        }
    }
}

impl ZoOptimizer for ZoAdam {
    fn kind(&self) -> ZoOptKind {
        ZoOptKind::Adam
    }

    fn coeffs(&mut self, step: u64, gs: &[f32], active: &[usize], lr: f32) -> Vec<Coeff> {
        debug_assert_eq!(gs.len(), 1);
        let g = gs[0];
        self.t += 1;
        self.v = self.beta2 * self.v + (1.0 - self.beta2) * (g as f64) * (g as f64);
        let bc1 = 1.0 - (self.beta1 as f64).powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let denom = (self.v / bc2).sqrt() + self.eps;
        let scale = (lr as f64 * (1.0 - self.beta1 as f64) / (bc1 * denom)) as f32;

        self.hist.push_back(Hist { step, g, active: active.to_vec() });
        if self.hist.len() > self.window {
            self.hist.pop_front();
        }
        let newest = self.hist.len() - 1;
        let mut out = Vec::new();
        for (i, h) in self.hist.iter().enumerate() {
            let w = self.beta1.powi((newest - i) as i32);
            let c = -scale * w * h.g;
            out.extend(h.active.iter().map(|&unit| Coeff { step: h.step, probe: 0, unit, c }));
        }
        out
    }

    fn state_bytes(&self) -> usize {
        // the scalar moment + step counter ride along with the history
        16 + replay_bytes(&self.hist)
    }

    fn stateful(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// zo-sign-sgd
// ---------------------------------------------------------------------------

/// Sign-SGD over the projected gradient: `-lr * sign(g) * z`. The sign is
/// of the *scalar* `g` (an element-wise `sign(g * z_i)` would need a
/// dedicated kernel; over the rank-1 SPSA direction the scalar sign is
/// the natural analogue and keeps the update a plain seeded axpy).
pub struct ZoSignSgd;

impl ZoOptimizer for ZoSignSgd {
    fn kind(&self) -> ZoOptKind {
        ZoOptKind::SignSgd
    }

    fn coeffs(&mut self, step: u64, gs: &[f32], active: &[usize], lr: f32) -> Vec<Coeff> {
        debug_assert_eq!(gs.len(), 1);
        // f32::signum(0.0) is 1.0 — a zero projected gradient must mean
        // "no step", not a full-size one
        let s = if gs[0] > 0.0 {
            1.0
        } else if gs[0] < 0.0 {
            -1.0
        } else {
            0.0
        };
        let c = -lr * s;
        active.iter().map(|&unit| Coeff { step, probe: 0, unit, c }).collect()
    }
}

// ---------------------------------------------------------------------------
// fzoo (one-sided batched)
// ---------------------------------------------------------------------------

/// FZOO-style rule: `probes` one-sided projected gradients per step
/// (`g_b = (L(theta + mu z_b) - L(theta)) / mu`), averaged into the
/// descent direction `(1/B) sum_b g_b z_b`, with the step size normalized
/// by the batch's gradient spread: `lr_eff = lr / (std(g) + eps)`. Low
/// spread = consistent signal = a confident (larger) step — the Adam-like
/// adaptivity FZOO gets without any moment state.
pub struct ZoFzoo {
    probes: usize,
    eps: f64,
}

impl ZoFzoo {
    pub fn new(probes: usize) -> ZoFzoo {
        assert!(probes >= 2, "variance normalization needs >= 2 probes");
        ZoFzoo { probes, eps: 1e-8 }
    }
}

impl ZoOptimizer for ZoFzoo {
    fn kind(&self) -> ZoOptKind {
        ZoOptKind::Fzoo
    }

    fn schedule(&self) -> ProbeSchedule {
        ProbeSchedule::OneSided { probes: self.probes }
    }

    fn coeffs(&mut self, step: u64, gs: &[f32], active: &[usize], lr: f32) -> Vec<Coeff> {
        debug_assert_eq!(gs.len(), self.probes);
        let n = gs.len() as f64;
        let mean = gs.iter().map(|&g| g as f64).sum::<f64>() / n;
        let var = gs.iter().map(|&g| (g as f64 - mean).powi(2)).sum::<f64>() / n;
        let lr_eff = lr as f64 / (var.sqrt() + self.eps);
        let mut out = Vec::with_capacity(gs.len() * active.len());
        for (b, &g) in gs.iter().enumerate() {
            let c = (-lr_eff * g as f64 / n) as f32;
            out.extend(
                active.iter().map(|&unit| Coeff { step, probe: b as u64, unit, c }),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_display_round_trip() {
        for name in ["zo-sgd", "zo-sgd-momentum", "zo-adam", "zo-sign-sgd", "fzoo"] {
            let k: ZoOptKind = name.parse().unwrap();
            assert_eq!(k.to_string(), name);
        }
    }

    #[test]
    fn seed_replay_state_rebuilds_bit_identically() {
        // the resume contract: a fresh optimizer fed the stored per-step
        // projected gradients must produce bit-identical coefficients on the
        // next live step — there is no hidden state outside (step, g, active)
        for kind in [ZoOptKind::Momentum, ZoOptKind::Adam] {
            let gs = [0.3f32, -0.7, 0.05, 1.2, -0.01];
            let actives: Vec<Vec<usize>> =
                vec![vec![0, 1, 2], vec![0, 2], vec![1, 2], vec![0, 1], vec![2]];
            let mut live = make_optimizer(kind);
            assert!(live.stateful());
            for (s, (&g, a)) in gs.iter().zip(&actives).enumerate() {
                let _ = live.coeffs(s as u64, &[g], a, 1e-3);
            }
            let mut replayed = make_optimizer(kind);
            for (s, (&g, a)) in gs.iter().zip(&actives).enumerate() {
                let _ = replayed.coeffs(s as u64, &[g], a, 1e-3);
            }
            let next = live.coeffs(5, &[0.9], &[0, 1, 2], 1e-3);
            let rebuilt = replayed.coeffs(5, &[0.9], &[0, 1, 2], 1e-3);
            assert_eq!(next, rebuilt, "{kind:?} replay must be exact");
            assert_eq!(live.state_bytes(), replayed.state_bytes());
        }
        assert!(!make_optimizer(ZoOptKind::Sgd).stateful());
        assert!(!make_optimizer(ZoOptKind::SignSgd).stateful());
        assert!(!make_optimizer(ZoOptKind::Fzoo).stateful());
    }

    #[test]
    fn kind_aliases_parse() {
        assert_eq!("sign".parse::<ZoOptKind>().unwrap(), ZoOptKind::SignSgd);
        assert_eq!("momentum".parse::<ZoOptKind>().unwrap(), ZoOptKind::Momentum);
        assert_eq!("adam".parse::<ZoOptKind>().unwrap(), ZoOptKind::Adam);
        assert_eq!("sgd".parse::<ZoOptKind>().unwrap(), ZoOptKind::Sgd);
    }

    #[test]
    fn bad_kind_error_names_the_valid_set() {
        let err = "turbo".parse::<ZoOptKind>().unwrap_err().to_string();
        assert!(err.contains("turbo"), "{err}");
        for name in ["zo-sgd", "zo-adam", "fzoo"] {
            assert!(err.contains(name), "{err} must list {name}");
        }
    }

    #[test]
    fn resolve_passes_through_without_env() {
        if std::env::var("LEZO_ZO_OPT").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED resolve_passes_through_without_env: LEZO_ZO_OPT wins");
            return;
        }
        assert_eq!(resolve_zo_opt(ZoOptKind::Adam).unwrap(), ZoOptKind::Adam);
        assert_eq!(resolve_zo_opt(ZoOptKind::Sgd).unwrap(), ZoOptKind::Sgd);
    }

    #[test]
    fn sgd_coeffs_are_the_classic_rule() {
        let mut opt = ZoSgd;
        let cs = opt.coeffs(7, &[2.0], &[0, 2, 3], 0.5);
        assert_eq!(cs.len(), 3);
        for (c, unit) in cs.iter().zip([0usize, 2, 3]) {
            assert_eq!((c.step, c.probe, c.unit), (7, 0, unit));
            assert_eq!(c.c, -0.5 * 2.0);
        }
        assert_eq!(opt.state_bytes(), 0);
        assert_eq!(opt.schedule(), ProbeSchedule::TwoSided);
    }

    #[test]
    fn momentum_replays_decayed_history() {
        // 3 steps with g = 1, 10, 100 on shifting active sets: step 2's
        // coefficients must be -lr * beta^age * g_s on each step's own set
        let beta = 0.5f32;
        let lr = 0.1f32;
        let mut opt = ZoMomentum::new(beta);
        opt.coeffs(0, &[1.0], &[0, 1], lr);
        opt.coeffs(1, &[10.0], &[1], lr);
        let cs = opt.coeffs(2, &[100.0], &[0, 2], lr);
        // expected: step0 (age 2, units 0,1), step1 (age 1, unit 1),
        // step2 (age 0, units 0,2)
        assert_eq!(cs.len(), 5);
        let find = |step: u64, unit: usize| {
            cs.iter().find(|c| c.step == step && c.unit == unit).unwrap().c
        };
        assert!((find(0, 0) - (-lr * 0.25 * 1.0)).abs() < 1e-7);
        assert!((find(0, 1) - (-lr * 0.25 * 1.0)).abs() < 1e-7);
        assert!((find(1, 1) - (-lr * 0.5 * 10.0)).abs() < 1e-7);
        assert!((find(2, 0) - (-lr * 1.0 * 100.0)).abs() < 1e-7);
        assert!((find(2, 2) - (-lr * 1.0 * 100.0)).abs() < 1e-7);
        assert!(cs.iter().all(|c| c.probe == 0), "replay stays on the classic stream");
        assert!(opt.state_bytes() > 0, "history is accounted");
    }

    #[test]
    fn momentum_window_truncates_history() {
        let mut opt = ZoMomentum::new(0.5);
        let window = replay_window(0.5); // ~14 at beta=0.5
        for step in 0..(window as u64 + 20) {
            opt.coeffs(step, &[1.0], &[0], 1e-3);
        }
        assert_eq!(opt.hist.len(), window, "window must bound the history");
        let bytes = opt.state_bytes();
        opt.coeffs(10_000, &[1.0], &[0], 1e-3);
        assert_eq!(opt.state_bytes(), bytes, "steady-state bytes are flat");
    }

    #[test]
    fn adam_first_step_is_a_sign_step() {
        // t=1 closed form (mirrors fo::adam_first_step_matches_closed_form):
        // mhat = g, vhat = g^2 -> coefficient = -lr * g / (|g| + eps)
        let (lr, eps) = (0.05f32, 1e-8);
        for g in [0.3f32, -1.7, 4.2e-3] {
            let mut opt = ZoAdam::new(0.9, 0.999, eps);
            let cs = opt.coeffs(0, &[g], &[1], lr);
            assert_eq!(cs.len(), 1);
            let want = -(lr as f64) * g as f64 / (g.abs() as f64 + eps);
            assert!(
                (cs[0].c as f64 - want).abs() < 1e-7,
                "g={g}: {} vs closed form {want}",
                cs[0].c
            );
        }
        // zero gradient: exactly no movement
        let mut opt = ZoAdam::new(0.9, 0.999, eps);
        let cs = opt.coeffs(0, &[0.0], &[1], lr);
        assert_eq!(cs[0].c, 0.0);
    }

    #[test]
    fn adam_replays_history_and_accounts_state() {
        let mut opt = ZoAdam::new(0.9, 0.999, 1e-8);
        opt.coeffs(0, &[1.0], &[0, 1], 1e-3);
        let cs = opt.coeffs(1, &[2.0], &[1, 2], 1e-3);
        // both steps' directions contribute
        assert!(cs.iter().any(|c| c.step == 0 && c.unit == 0));
        assert!(cs.iter().any(|c| c.step == 1 && c.unit == 2));
        assert!(opt.state_bytes() > 16, "history + scalar moment accounted");
    }

    #[test]
    fn sign_sgd_is_magnitude_free_and_zero_safe() {
        let mut opt = ZoSignSgd;
        assert_eq!(opt.coeffs(0, &[123.4], &[0], 0.1)[0].c, -0.1);
        assert_eq!(opt.coeffs(0, &[-0.001], &[0], 0.1)[0].c, 0.1);
        assert_eq!(opt.coeffs(0, &[0.0], &[0], 0.1)[0].c, 0.0, "sign(0) must be 0");
        assert_eq!(opt.state_bytes(), 0);
    }

    #[test]
    fn fzoo_normalizes_by_gradient_spread() {
        let mut opt = ZoFzoo::new(4);
        assert_eq!(opt.schedule(), ProbeSchedule::OneSided { probes: 4 });
        let gs = [1.0f32, 3.0, 5.0, 7.0]; // mean 4, pop std sqrt(5)
        let cs = opt.coeffs(0, &gs, &[0, 1], 0.1);
        assert_eq!(cs.len(), 8, "one coefficient per (probe, unit)");
        let lr_eff = 0.1 / (5.0f64.sqrt() + 1e-8);
        for c in &cs {
            let want = -(lr_eff * gs[c.probe as usize] as f64 / 4.0) as f32;
            assert!((c.c - want).abs() < 1e-9, "{c:?} vs {want}");
        }
        // probes are distinct streams, units within a probe share c
        assert_eq!(cs.iter().filter(|c| c.probe == 2).count(), 2);
        assert_eq!(opt.state_bytes(), 0);
    }

    #[test]
    fn make_optimizer_matches_kind() {
        for kind in
            [ZoOptKind::Sgd, ZoOptKind::Momentum, ZoOptKind::Adam, ZoOptKind::SignSgd, ZoOptKind::Fzoo]
        {
            assert_eq!(make_optimizer(kind).kind(), kind);
        }
    }
}

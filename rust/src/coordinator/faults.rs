//! Deterministic fault injection for crash-safety tests, plus the
//! non-finite-loss policy knob.
//!
//! A fault plan is a comma-separated list parsed from the `faults` config key
//! (the `LEZO_FAULTS` env var overrides it, like `LEZO_PRECISION`; an
//! unparseable env value is a hard error naming the variable):
//!
//! ```text
//! nan-loss@K            first forward loss of step K returns NaN
//! crash@K               injected crash after step K completes (post-save)
//! crash@K:post-perturb  crash after step K's first perturbation sweep
//! crash@K:post-eval     crash after step K's eval, before any save
//! crash@K:pre-save      crash immediately before writing step K's state
//! crash@K:mid-save      crash mid-write: leaves a torn temp file behind
//! io-err@save:N         the N-th state save attempt fails with an io error
//! net-drop@K            socket mode: worker drops the connection instead of
//!                       replying to step K's plan request (fires once)
//! net-delay@K:ms        socket mode: worker sleeps `ms` before computing
//!                       step K's plan (drills the timeout/heartbeat path)
//! net-corrupt@K         socket mode: worker corrupts the CRC of step K's
//!                       reply frame, then drops the connection (fires once)
//! worker-crash@K:shard  socket mode: the named shard's worker process exits
//!                       hard on step K's plan request (degradation drill)
//! ```
//!
//! Steps are the 1-based step counter the trainer logs. "Crashes" are
//! propagated as ordinary errors carrying [`CRASH_MARKER`], so kill-and-resume
//! tests run in-process while the on-disk state is exactly what a real crash
//! at that boundary would leave. The `net-*` / `worker-crash` faults are
//! injected on the *worker* side of the socket transport (the coordinator
//! forwards the effective fault string over the wire at INIT), so the
//! coordinator's retry / degradation machinery is exercised for real.

use anyhow::{bail, ensure, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::str::FromStr;

/// Substring present in every injected-crash error, so tests and CI can tell
/// an injected crash from a real failure.
pub const CRASH_MARKER: &str = "injected crash";

/// What to do when a forward probe returns a non-finite loss.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NonFinitePolicy {
    /// Hard error naming the step, probe and loss value (the default).
    #[default]
    Error,
    /// Restore the perturbation, skip the update, record the step as skipped.
    SkipStep,
}

impl FromStr for NonFinitePolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "error" => Ok(NonFinitePolicy::Error),
            "skip-step" | "skip_step" => Ok(NonFinitePolicy::SkipStep),
            other => bail!("unknown on_nonfinite policy '{other}' (expected error|skip-step)"),
        }
    }
}

impl fmt::Display for NonFinitePolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            NonFinitePolicy::Error => "error",
            NonFinitePolicy::SkipStep => "skip-step",
        })
    }
}

/// Phase boundaries at which an injected crash can fire within a step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CrashPhase {
    /// After the first perturbation sweep (the first forward of the step).
    PostPerturb,
    /// After the step's eval block, before any checkpoint write.
    PostEval,
    /// Immediately before the state write begins.
    PreSave,
    /// Mid-write: the temp file is half-written, then the crash fires.
    MidSave,
    /// After the step fully completes (including a successful save).
    End,
}

impl CrashPhase {
    fn parse(s: &str) -> Result<CrashPhase> {
        Ok(match s {
            "post-perturb" => CrashPhase::PostPerturb,
            "post-eval" => CrashPhase::PostEval,
            "pre-save" => CrashPhase::PreSave,
            "mid-save" => CrashPhase::MidSave,
            "end" => CrashPhase::End,
            other => bail!(
                "unknown crash phase '{other}' (expected post-perturb|post-eval|pre-save|mid-save|end)"
            ),
        })
    }

    fn name(self) -> &'static str {
        match self {
            CrashPhase::PostPerturb => "post-perturb",
            CrashPhase::PostEval => "post-eval",
            CrashPhase::PreSave => "pre-save",
            CrashPhase::MidSave => "mid-save",
            CrashPhase::End => "end",
        }
    }
}

/// Outcome the checkpoint writer should simulate for the current save.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaveFault {
    None,
    /// This save attempt fails with an io error (training continues).
    IoErr,
    /// Write a torn temp file, then crash.
    MidSave,
}

/// A parsed, deterministic fault plan. An empty plan (the default) costs a
/// handful of set lookups per step and injects nothing.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    nan_loss: BTreeSet<u64>,
    crashes: Vec<(u64, CrashPhase)>,
    io_err_saves: BTreeSet<u64>,
    save_attempts: u64,
    net_drop: BTreeSet<u64>,
    net_delay: BTreeMap<u64, u64>,
    net_corrupt: BTreeSet<u64>,
    worker_crash: Vec<(u64, usize)>,
}

impl FaultPlan {
    /// Resolve the effective plan: `LEZO_FAULTS` wins over the config key, and
    /// an unparseable env value is a hard error naming the variable (same
    /// strictness rule as `LEZO_PRECISION` / `LEZO_ZO_OPT`).
    pub fn resolve(cfg_faults: &str) -> Result<FaultPlan> {
        match std::env::var("LEZO_FAULTS") {
            Ok(v) if !v.trim().is_empty() => FaultPlan::parse(&v)
                .map_err(|e| anyhow::anyhow!("invalid LEZO_FAULTS='{v}': {e}")),
            _ => FaultPlan::parse(cfg_faults),
        }
    }

    /// Parse the fault grammar (see module docs). Empty input is the empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let Some((kind, at)) = tok.split_once('@') else {
                bail!("fault '{tok}' is not <kind>@<where> (e.g. nan-loss@120, crash@250, io-err@save:2)");
            };
            match kind {
                "nan-loss" => {
                    let step: u64 = at
                        .parse()
                        .map_err(|_| anyhow::anyhow!("nan-loss step '{at}' is not an integer"))?;
                    ensure!(step > 0, "nan-loss step must be >= 1 (steps are 1-based)");
                    plan.nan_loss.insert(step);
                }
                "crash" => {
                    let (step_s, phase) = match at.split_once(':') {
                        Some((k, p)) => (k, CrashPhase::parse(p)?),
                        None => (at, CrashPhase::End),
                    };
                    let step: u64 = step_s
                        .parse()
                        .map_err(|_| anyhow::anyhow!("crash step '{step_s}' is not an integer"))?;
                    ensure!(step > 0, "crash step must be >= 1 (steps are 1-based)");
                    plan.crashes.push((step, phase));
                }
                "io-err" => {
                    let Some(n_s) = at.strip_prefix("save:") else {
                        bail!("io-err fault '{tok}' must be io-err@save:<N>");
                    };
                    let n: u64 = n_s
                        .parse()
                        .map_err(|_| anyhow::anyhow!("io-err save index '{n_s}' is not an integer"))?;
                    ensure!(n > 0, "io-err save index is 1-based");
                    plan.io_err_saves.insert(n);
                }
                "net-drop" => {
                    let step: u64 = at
                        .parse()
                        .map_err(|_| anyhow::anyhow!("net-drop step '{at}' is not an integer"))?;
                    ensure!(step > 0, "net-drop step must be >= 1 (steps are 1-based)");
                    plan.net_drop.insert(step);
                }
                "net-delay" => {
                    let Some((step_s, ms_s)) = at.split_once(':') else {
                        bail!("net-delay fault '{tok}' must be net-delay@<step>:<ms>");
                    };
                    let step: u64 = step_s.parse().map_err(|_| {
                        anyhow::anyhow!("net-delay step '{step_s}' is not an integer")
                    })?;
                    let ms: u64 = ms_s.parse().map_err(|_| {
                        anyhow::anyhow!("net-delay duration '{ms_s}' is not a millisecond count")
                    })?;
                    ensure!(step > 0, "net-delay step must be >= 1 (steps are 1-based)");
                    plan.net_delay.insert(step, ms);
                }
                "net-corrupt" => {
                    let step: u64 = at.parse().map_err(|_| {
                        anyhow::anyhow!("net-corrupt step '{at}' is not an integer")
                    })?;
                    ensure!(step > 0, "net-corrupt step must be >= 1 (steps are 1-based)");
                    plan.net_corrupt.insert(step);
                }
                "worker-crash" => {
                    let Some((step_s, shard_s)) = at.split_once(':') else {
                        bail!("worker-crash fault '{tok}' must be worker-crash@<step>:<shard>");
                    };
                    let step: u64 = step_s.parse().map_err(|_| {
                        anyhow::anyhow!("worker-crash step '{step_s}' is not an integer")
                    })?;
                    let shard: usize = shard_s.parse().map_err(|_| {
                        anyhow::anyhow!("worker-crash shard '{shard_s}' is not a shard index")
                    })?;
                    ensure!(step > 0, "worker-crash step must be >= 1 (steps are 1-based)");
                    plan.worker_crash.push((step, shard));
                }
                other => bail!(
                    "unknown fault kind '{other}' (expected nan-loss|crash|io-err|net-drop|net-delay|net-corrupt|worker-crash)"
                ),
            }
        }
        Ok(plan)
    }

    /// True if the plan injects nothing (fast-path check for the hot loop).
    pub fn is_empty(&self) -> bool {
        self.nan_loss.is_empty()
            && self.crashes.is_empty()
            && self.io_err_saves.is_empty()
            && self.net_drop.is_empty()
            && self.net_delay.is_empty()
            && self.net_corrupt.is_empty()
            && self.worker_crash.is_empty()
    }

    /// Should the first forward loss of 1-based step `s1` return NaN?
    pub fn nan_loss_at(&self, s1: u64) -> bool {
        self.nan_loss.contains(&s1)
    }

    /// Fire an injected crash if one is scheduled at `(s1, phase)`.
    pub fn check_crash(&self, s1: u64, phase: CrashPhase) -> Result<()> {
        if self.crashes.iter().any(|&(k, p)| k == s1 && p == phase) {
            bail!("{CRASH_MARKER}: crash@{s1}:{} fault fired", phase.name());
        }
        Ok(())
    }

    /// Is a mid-save crash scheduled at step `s1`? (Checked by the state
    /// writer so the torn temp file can be produced before the crash fires.)
    pub fn mid_save_at(&self, s1: u64) -> bool {
        self.crashes.iter().any(|&(k, p)| k == s1 && p == CrashPhase::MidSave)
    }

    /// Socket mode (worker side): should the worker drop the connection
    /// instead of replying to 1-based step `s1`'s plan request?
    pub fn net_drop_at(&self, s1: u64) -> bool {
        self.net_drop.contains(&s1)
    }

    /// Socket mode (worker side): sleep this many milliseconds before
    /// computing 1-based step `s1`'s plan, if scheduled.
    pub fn net_delay_at(&self, s1: u64) -> Option<u64> {
        self.net_delay.get(&s1).copied()
    }

    /// Socket mode (worker side): should the worker corrupt the CRC of
    /// 1-based step `s1`'s reply frame?
    pub fn net_corrupt_at(&self, s1: u64) -> bool {
        self.net_corrupt.contains(&s1)
    }

    /// Socket mode (worker side): should worker `shard` exit hard on
    /// 1-based step `s1`'s plan request?
    pub fn worker_crash_at(&self, s1: u64, shard: usize) -> bool {
        self.worker_crash.iter().any(|&(k, s)| k == s1 && s == shard)
    }

    /// Account one state-save attempt and report what it should do. The save
    /// counter advances on every attempt, so `io-err@save:N` hits exactly the
    /// N-th write of the run.
    pub fn on_save_attempt(&mut self, s1: u64) -> SaveFault {
        self.save_attempts += 1;
        if self.mid_save_at(s1) {
            SaveFault::MidSave
        } else if self.io_err_saves.contains(&self.save_attempts) {
            SaveFault::IoErr
        } else {
            SaveFault::None
        }
    }
}

/// Resolve the *effective* fault string the same way [`FaultPlan::resolve`]
/// does (env wins, strict), returning the raw string so the coordinator can
/// forward it verbatim to socket workers at INIT. Validates by parsing.
pub fn resolve_faults_string(cfg_faults: &str) -> Result<String> {
    match std::env::var("LEZO_FAULTS") {
        Ok(v) if !v.trim().is_empty() => {
            FaultPlan::parse(&v).map_err(|e| anyhow::anyhow!("invalid LEZO_FAULTS='{v}': {e}"))?;
            Ok(v)
        }
        _ => {
            FaultPlan::parse(cfg_faults)?;
            Ok(cfg_faults.to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let p = FaultPlan::parse("nan-loss@120,crash@250,io-err@save:2").unwrap();
        assert!(p.nan_loss_at(120) && !p.nan_loss_at(121));
        assert!(p.check_crash(250, CrashPhase::End).is_err());
        assert!(p.check_crash(250, CrashPhase::PostEval).is_ok());
        assert!(p.check_crash(249, CrashPhase::End).is_ok());
        let mut p = p;
        assert_eq!(p.on_save_attempt(1), SaveFault::None);
        assert_eq!(p.on_save_attempt(2), SaveFault::IoErr);
        assert_eq!(p.on_save_attempt(3), SaveFault::None);
    }

    #[test]
    fn parses_crash_phases() {
        for (s, phase) in [
            ("crash@3:post-perturb", CrashPhase::PostPerturb),
            ("crash@3:post-eval", CrashPhase::PostEval),
            ("crash@3:pre-save", CrashPhase::PreSave),
            ("crash@3:mid-save", CrashPhase::MidSave),
            ("crash@3", CrashPhase::End),
        ] {
            let p = FaultPlan::parse(s).unwrap();
            let err = p.check_crash(3, phase).unwrap_err().to_string();
            assert!(err.contains(CRASH_MARKER), "{err}");
        }
    }

    #[test]
    fn mid_save_is_visible_to_the_writer() {
        let mut p = FaultPlan::parse("crash@4:mid-save").unwrap();
        assert!(p.mid_save_at(4));
        assert_eq!(p.on_save_attempt(4), SaveFault::MidSave);
    }

    #[test]
    fn rejects_bad_grammar() {
        for bad in [
            "bogus",
            "nan-loss@x",
            "nan-loss@0",
            "crash@",
            "crash@5:mid",
            "io-err@load:1",
            "io-err@save:0",
            "explode@9",
            "net-drop@x",
            "net-drop@0",
            "net-delay@3",
            "net-delay@3:fast",
            "net-corrupt@zero",
            "worker-crash@2",
            "worker-crash@2:one",
            "net-bogus@1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should not parse");
        }
        let err = FaultPlan::parse("net-bogus@1").unwrap_err().to_string();
        assert!(err.contains("unknown fault kind 'net-bogus'"), "{err}");
        assert!(err.contains("worker-crash"), "{err}");
    }

    #[test]
    fn parses_net_faults() {
        let p =
            FaultPlan::parse("net-drop@2,net-delay@3:250,net-corrupt@4,worker-crash@5:1").unwrap();
        assert!(!p.is_empty());
        assert!(p.net_drop_at(2) && !p.net_drop_at(3));
        assert_eq!(p.net_delay_at(3), Some(250));
        assert_eq!(p.net_delay_at(2), None);
        assert!(p.net_corrupt_at(4) && !p.net_corrupt_at(5));
        assert!(p.worker_crash_at(5, 1));
        assert!(!p.worker_crash_at(5, 0) && !p.worker_crash_at(4, 1));
    }

    #[test]
    fn faults_string_resolution_validates() {
        if std::env::var("LEZO_FAULTS").map(|v| v.trim().is_empty()).unwrap_or(true) {
            assert_eq!(resolve_faults_string("net-drop@2").unwrap(), "net-drop@2");
            assert!(resolve_faults_string("net-bogus@1").is_err());
        }
    }

    #[test]
    fn empty_plan_is_empty() {
        let p = FaultPlan::parse("").unwrap();
        assert!(p.is_empty());
        assert!(p.check_crash(1, CrashPhase::End).is_ok());
        let p = FaultPlan::parse(" , ").unwrap();
        assert!(p.is_empty());
    }

    #[test]
    fn nonfinite_policy_round_trips() {
        for p in [NonFinitePolicy::Error, NonFinitePolicy::SkipStep] {
            assert_eq!(p.to_string().parse::<NonFinitePolicy>().unwrap(), p);
        }
        assert!("explode".parse::<NonFinitePolicy>().is_err());
    }
}

//! Deterministic fault injection for crash-safety tests, plus the
//! non-finite-loss policy knob.
//!
//! A fault plan is a comma-separated list parsed from the `faults` config key
//! (the `LEZO_FAULTS` env var overrides it, like `LEZO_PRECISION`; an
//! unparseable env value is a hard error naming the variable):
//!
//! ```text
//! nan-loss@K            first forward loss of step K returns NaN
//! crash@K               injected crash after step K completes (post-save)
//! crash@K:post-perturb  crash after step K's first perturbation sweep
//! crash@K:post-eval     crash after step K's eval, before any save
//! crash@K:pre-save      crash immediately before writing step K's state
//! crash@K:mid-save      crash mid-write: leaves a torn temp file behind
//! io-err@save:N         the N-th state save attempt fails with an io error
//! ```
//!
//! Steps are the 1-based step counter the trainer logs. "Crashes" are
//! propagated as ordinary errors carrying [`CRASH_MARKER`], so kill-and-resume
//! tests run in-process while the on-disk state is exactly what a real crash
//! at that boundary would leave.

use anyhow::{bail, ensure, Result};
use std::collections::BTreeSet;
use std::fmt;
use std::str::FromStr;

/// Substring present in every injected-crash error, so tests and CI can tell
/// an injected crash from a real failure.
pub const CRASH_MARKER: &str = "injected crash";

/// What to do when a forward probe returns a non-finite loss.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NonFinitePolicy {
    /// Hard error naming the step, probe and loss value (the default).
    #[default]
    Error,
    /// Restore the perturbation, skip the update, record the step as skipped.
    SkipStep,
}

impl FromStr for NonFinitePolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "error" => Ok(NonFinitePolicy::Error),
            "skip-step" | "skip_step" => Ok(NonFinitePolicy::SkipStep),
            other => bail!("unknown on_nonfinite policy '{other}' (expected error|skip-step)"),
        }
    }
}

impl fmt::Display for NonFinitePolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            NonFinitePolicy::Error => "error",
            NonFinitePolicy::SkipStep => "skip-step",
        })
    }
}

/// Phase boundaries at which an injected crash can fire within a step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CrashPhase {
    /// After the first perturbation sweep (the first forward of the step).
    PostPerturb,
    /// After the step's eval block, before any checkpoint write.
    PostEval,
    /// Immediately before the state write begins.
    PreSave,
    /// Mid-write: the temp file is half-written, then the crash fires.
    MidSave,
    /// After the step fully completes (including a successful save).
    End,
}

impl CrashPhase {
    fn parse(s: &str) -> Result<CrashPhase> {
        Ok(match s {
            "post-perturb" => CrashPhase::PostPerturb,
            "post-eval" => CrashPhase::PostEval,
            "pre-save" => CrashPhase::PreSave,
            "mid-save" => CrashPhase::MidSave,
            "end" => CrashPhase::End,
            other => bail!(
                "unknown crash phase '{other}' (expected post-perturb|post-eval|pre-save|mid-save|end)"
            ),
        })
    }

    fn name(self) -> &'static str {
        match self {
            CrashPhase::PostPerturb => "post-perturb",
            CrashPhase::PostEval => "post-eval",
            CrashPhase::PreSave => "pre-save",
            CrashPhase::MidSave => "mid-save",
            CrashPhase::End => "end",
        }
    }
}

/// Outcome the checkpoint writer should simulate for the current save.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaveFault {
    None,
    /// This save attempt fails with an io error (training continues).
    IoErr,
    /// Write a torn temp file, then crash.
    MidSave,
}

/// A parsed, deterministic fault plan. An empty plan (the default) costs a
/// handful of set lookups per step and injects nothing.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    nan_loss: BTreeSet<u64>,
    crashes: Vec<(u64, CrashPhase)>,
    io_err_saves: BTreeSet<u64>,
    save_attempts: u64,
}

impl FaultPlan {
    /// Resolve the effective plan: `LEZO_FAULTS` wins over the config key, and
    /// an unparseable env value is a hard error naming the variable (same
    /// strictness rule as `LEZO_PRECISION` / `LEZO_ZO_OPT`).
    pub fn resolve(cfg_faults: &str) -> Result<FaultPlan> {
        match std::env::var("LEZO_FAULTS") {
            Ok(v) if !v.trim().is_empty() => FaultPlan::parse(&v)
                .map_err(|e| anyhow::anyhow!("invalid LEZO_FAULTS='{v}': {e}")),
            _ => FaultPlan::parse(cfg_faults),
        }
    }

    /// Parse the fault grammar (see module docs). Empty input is the empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let Some((kind, at)) = tok.split_once('@') else {
                bail!("fault '{tok}' is not <kind>@<where> (e.g. nan-loss@120, crash@250, io-err@save:2)");
            };
            match kind {
                "nan-loss" => {
                    let step: u64 = at
                        .parse()
                        .map_err(|_| anyhow::anyhow!("nan-loss step '{at}' is not an integer"))?;
                    ensure!(step > 0, "nan-loss step must be >= 1 (steps are 1-based)");
                    plan.nan_loss.insert(step);
                }
                "crash" => {
                    let (step_s, phase) = match at.split_once(':') {
                        Some((k, p)) => (k, CrashPhase::parse(p)?),
                        None => (at, CrashPhase::End),
                    };
                    let step: u64 = step_s
                        .parse()
                        .map_err(|_| anyhow::anyhow!("crash step '{step_s}' is not an integer"))?;
                    ensure!(step > 0, "crash step must be >= 1 (steps are 1-based)");
                    plan.crashes.push((step, phase));
                }
                "io-err" => {
                    let Some(n_s) = at.strip_prefix("save:") else {
                        bail!("io-err fault '{tok}' must be io-err@save:<N>");
                    };
                    let n: u64 = n_s
                        .parse()
                        .map_err(|_| anyhow::anyhow!("io-err save index '{n_s}' is not an integer"))?;
                    ensure!(n > 0, "io-err save index is 1-based");
                    plan.io_err_saves.insert(n);
                }
                other => bail!("unknown fault kind '{other}' (expected nan-loss|crash|io-err)"),
            }
        }
        Ok(plan)
    }

    /// True if the plan injects nothing (fast-path check for the hot loop).
    pub fn is_empty(&self) -> bool {
        self.nan_loss.is_empty() && self.crashes.is_empty() && self.io_err_saves.is_empty()
    }

    /// Should the first forward loss of 1-based step `s1` return NaN?
    pub fn nan_loss_at(&self, s1: u64) -> bool {
        self.nan_loss.contains(&s1)
    }

    /// Fire an injected crash if one is scheduled at `(s1, phase)`.
    pub fn check_crash(&self, s1: u64, phase: CrashPhase) -> Result<()> {
        if self.crashes.iter().any(|&(k, p)| k == s1 && p == phase) {
            bail!("{CRASH_MARKER}: crash@{s1}:{} fault fired", phase.name());
        }
        Ok(())
    }

    /// Is a mid-save crash scheduled at step `s1`? (Checked by the state
    /// writer so the torn temp file can be produced before the crash fires.)
    pub fn mid_save_at(&self, s1: u64) -> bool {
        self.crashes.iter().any(|&(k, p)| k == s1 && p == CrashPhase::MidSave)
    }

    /// Account one state-save attempt and report what it should do. The save
    /// counter advances on every attempt, so `io-err@save:N` hits exactly the
    /// N-th write of the run.
    pub fn on_save_attempt(&mut self, s1: u64) -> SaveFault {
        self.save_attempts += 1;
        if self.mid_save_at(s1) {
            SaveFault::MidSave
        } else if self.io_err_saves.contains(&self.save_attempts) {
            SaveFault::IoErr
        } else {
            SaveFault::None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let p = FaultPlan::parse("nan-loss@120,crash@250,io-err@save:2").unwrap();
        assert!(p.nan_loss_at(120) && !p.nan_loss_at(121));
        assert!(p.check_crash(250, CrashPhase::End).is_err());
        assert!(p.check_crash(250, CrashPhase::PostEval).is_ok());
        assert!(p.check_crash(249, CrashPhase::End).is_ok());
        let mut p = p;
        assert_eq!(p.on_save_attempt(1), SaveFault::None);
        assert_eq!(p.on_save_attempt(2), SaveFault::IoErr);
        assert_eq!(p.on_save_attempt(3), SaveFault::None);
    }

    #[test]
    fn parses_crash_phases() {
        for (s, phase) in [
            ("crash@3:post-perturb", CrashPhase::PostPerturb),
            ("crash@3:post-eval", CrashPhase::PostEval),
            ("crash@3:pre-save", CrashPhase::PreSave),
            ("crash@3:mid-save", CrashPhase::MidSave),
            ("crash@3", CrashPhase::End),
        ] {
            let p = FaultPlan::parse(s).unwrap();
            let err = p.check_crash(3, phase).unwrap_err().to_string();
            assert!(err.contains(CRASH_MARKER), "{err}");
        }
    }

    #[test]
    fn mid_save_is_visible_to_the_writer() {
        let mut p = FaultPlan::parse("crash@4:mid-save").unwrap();
        assert!(p.mid_save_at(4));
        assert_eq!(p.on_save_attempt(4), SaveFault::MidSave);
    }

    #[test]
    fn rejects_bad_grammar() {
        for bad in [
            "bogus",
            "nan-loss@x",
            "nan-loss@0",
            "crash@",
            "crash@5:mid",
            "io-err@load:1",
            "io-err@save:0",
            "explode@9",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn empty_plan_is_empty() {
        let p = FaultPlan::parse("").unwrap();
        assert!(p.is_empty());
        assert!(p.check_crash(1, CrashPhase::End).is_ok());
        let p = FaultPlan::parse(" , ").unwrap();
        assert!(p.is_empty());
    }

    #[test]
    fn nonfinite_policy_round_trips() {
        for p in [NonFinitePolicy::Error, NonFinitePolicy::SkipStep] {
            assert_eq!(p.to_string().parse::<NonFinitePolicy>().unwrap(), p);
        }
        assert!("explode".parse::<NonFinitePolicy>().is_err());
    }
}

//! Run configuration: every knob of a fine-tuning run, plus the paper's
//! hyper-parameter grids (Table 5) as presets.
//!
//! Configs are built from CLI `key=value` overrides on top of a preset, and
//! can be round-tripped through a simple `key = value` config-file format
//! (no serde offline; the format is intentionally trivial).

use crate::coordinator::faults::{FaultPlan, NonFinitePolicy};
use crate::coordinator::optim::ZoOptKind;
use crate::coordinator::policy::Policy;
use crate::peft::PeftMode;
use crate::runtime::backend::{BackendKind, Precision};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;

/// Which optimizer drives the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// No training: score options with the pretrained model.
    ZeroShot,
    /// No training: k demonstrations concatenated in-context.
    Icl,
    /// First-order fine-tuning (Adam), the paper's "FT" baseline.
    Ft,
    /// MeZO (Malladi et al. 2023) == LeZO with 0 dropped layers.
    Mezo,
    /// LeZO: layer-wise sparse ZO (the paper's contribution).
    Lezo,
    /// Sparse-MeZO (Liu et al. 2024): element-wise magnitude-masked ZO —
    /// the related-work comparator the paper argues against (extra ranking
    /// work + mask state; perturb/update traffic does not shrink).
    Smezo,
}

impl FromStr for Method {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "zero-shot" | "zeroshot" => Method::ZeroShot,
            "icl" => Method::Icl,
            "ft" => Method::Ft,
            "mezo" => Method::Mezo,
            "lezo" => Method::Lezo,
            "smezo" | "sparse-mezo" => Method::Smezo,
            _ => bail!("unknown method '{s}' (zero-shot|icl|ft|mezo|lezo|smezo)"),
        })
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Method::ZeroShot => "zero-shot",
            Method::Icl => "icl",
            Method::Ft => "ft",
            Method::Mezo => "mezo",
            Method::Lezo => "lezo",
            Method::Smezo => "smezo",
        };
        write!(f, "{s}")
    }
}

/// `mezo-lora` / `lezo-prefix`-style aliases: one token naming the ZO
/// method plus its PEFT space — the paper's Table-4 row names and the
/// [`grids()`] keys. Accepted by the `method=` config key, which sets both
/// `method` and `peft`. Only `mezo`/`lezo` compose with a PEFT suffix
/// (Sparse-MeZO is full-parameter by construction).
pub fn method_peft_alias(s: &str) -> Option<(Method, PeftMode)> {
    let (m, p) = s.rsplit_once('-')?;
    let peft = match p {
        "lora" => PeftMode::Lora,
        "prefix" => PeftMode::Prefix,
        _ => return None,
    };
    let method = match m {
        "mezo" => Method::Mezo,
        "lezo" => Method::Lezo,
        _ => return None,
    };
    Some((method, peft))
}

/// Transport for `backend=sharded`: lockstep replicas in-process (scoped
/// threads, the default) or remote `lezo worker` processes reached over the
/// framed socket protocol (`runtime/transport.rs`). Results are bit-identical
/// either way — the transport moves only `StepPlan`s and `(eval, loss)`
/// scalars, never parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardTransport {
    #[default]
    Thread,
    Socket,
}

impl FromStr for ShardTransport {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "thread" => ShardTransport::Thread,
            "socket" => ShardTransport::Socket,
            other => bail!("unknown shard_transport '{other}' (expected thread|socket)"),
        })
    }
}

impl fmt::Display for ShardTransport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ShardTransport::Thread => "thread",
            ShardTransport::Socket => "socket",
        })
    }
}

/// Full description of one run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,        // model size name, e.g. "opt-micro"
    pub artifacts_root: String,
    /// Runtime backend: `auto` (PJRT when artifacts exist in a pjrt build,
    /// else the pure-Rust native backend), `native`, or `pjrt`. The
    /// `LEZO_BACKEND` env var steers `auto`; an explicit setting here wins.
    pub backend: BackendKind,
    pub task: String,         // task name, e.g. "sst2"
    pub method: Method,
    pub peft: PeftMode,
    /// Number of transformer blocks *dropped* (skipped) per ZO step — the
    /// paper's "Dropout Number" n. Sparsity rho = n / N over sparsifiable
    /// units. 0 == MeZO.
    pub drop_layers: usize,
    pub lr: f64,
    /// SPSA perturbation scale (the paper's mu / epsilon).
    pub mu: f64,
    pub steps: usize,
    pub eval_every: usize,
    pub eval_examples: usize,
    pub train_examples: usize,
    pub seed: u64,
    /// Demonstrations for ICL.
    pub icl_shots: usize,
    /// Mean content length of generated examples (tokens); tasks clamp to
    /// their bucket budget. Drives the Fig. 6 sweep.
    pub mean_len: usize,
    /// Adam hyper-parameters for the FT baseline.
    pub adam_beta1: f64,
    pub adam_beta2: f64,
    pub adam_eps: f64,
    /// Load pretrained weights from this checkpoint (empty = params_init.bin).
    pub checkpoint: String,
    /// Whether embedding / final-LN units are sparsifiable too (the paper
    /// sparsifies transformer blocks only; rho=1 in Fig. 3 drops all blocks
    /// and tunes only embedding+head, which is exactly this policy).
    pub blocks_only: bool,
    /// Layer-selection policy (the paper uses uniform; the others are the
    /// `lezo bench ablation` axis).
    pub policy: Policy,
    /// Sparse-MeZO: fraction of each unit's smallest-|w| elements that stay
    /// tunable (the magnitude mask).
    pub smezo_keep: f64,
    /// Worker replicas for `backend=sharded` (each holds a full parameter
    /// copy; a step's forward evaluations are partitioned across them —
    /// see `runtime/sharded.rs`). The `LEZO_SHARDS` env var overrides this,
    /// mirroring `threads`/`LEZO_THREADS`; zero is rejected either way.
    /// Results are bit-identical to `backend=native` at any shard count.
    pub shards: usize,
    /// How `backend=sharded` reaches its replicas: `thread` (in-process,
    /// the default) or `socket` (remote `lezo worker` processes listed in
    /// `workers`). Excluded from the checkpoint fingerprint like `shards` —
    /// a run may resume under either transport.
    pub shard_transport: ShardTransport,
    /// Comma-separated `host:port` worker addresses for
    /// `shard_transport=socket`, one per shard (start each with
    /// `lezo worker --listen <addr>`). Ignored for `thread`.
    pub workers: String,
    /// Socket transport: per-request timeout in milliseconds (the
    /// `LEZO_NET_TIMEOUT_MS` env var overrides; must be >= 1). Plan requests
    /// additionally stay live while worker heartbeats arrive.
    pub net_timeout_ms: u64,
    /// Socket transport: bounded attempts per request before the worker is
    /// declared dead (the `LEZO_NET_RETRIES` env var overrides; >= 1).
    pub net_retries: u32,
    /// Native-backend worker threads (0 = auto / available parallelism).
    /// The `LEZO_THREADS` env var overrides this at kernel-entry time.
    /// Results are bit-identical at any setting — the native kernels use
    /// fixed chunk partitioning (see `runtime/native/parallel.rs`).
    pub threads: usize,
    /// Forward-path numeric precision (`f32` default; `bf16` halves the
    /// streamed parameter/activation bytes of the forward families on the
    /// native backend; `int8`/`int4` stream absmax block-quantized weight
    /// shadows at ~0.27x/~0.14x of the f32 bytes, activations staying
    /// f32). The `LEZO_PRECISION` env var overrides this, mirroring
    /// `threads`/`LEZO_THREADS`. ZO perturb/update state stays f32 either
    /// way (see `runtime/native/mod.rs`, "Precision").
    pub precision: Precision,
    /// ZO update rule (the optimizer zoo; `coordinator/optim.rs`). The
    /// `LEZO_ZO_OPT` env var overrides this, mirroring
    /// `precision`/`LEZO_PRECISION`. Only meaningful for ZO methods;
    /// `zo-sgd` is the classic (and bit-pinned) default.
    pub zo_opt: ZoOptKind,
    /// Resume behavior: `auto` (pick up `<artifact_dir>/train_state.ckpt`
    /// when present — resumed runs are bit-identical to uninterrupted ones),
    /// `never`, or an explicit state-file path.
    pub resume: String,
    /// Write an atomic `TrainState` resume checkpoint every N steps
    /// (0 = disabled, the default — fault-free runs are byte-for-byte
    /// unchanged from the pre-checkpoint behavior).
    pub save_every: usize,
    /// Deterministic fault-injection plan (see `coordinator/faults.rs`),
    /// e.g. `nan-loss@120,crash@250,io-err@save:2`. The `LEZO_FAULTS` env
    /// var overrides this, mirroring `LEZO_PRECISION`. Empty = no faults.
    pub faults: String,
    /// What a non-finite forward loss does: `error` (default) names the
    /// exact step/probe; `skip-step` restores the perturbation and skips
    /// the update, recording the step as skipped.
    pub on_nonfinite: NonFinitePolicy,
    /// Divergence halt: abort when the smoothed recent loss exceeds this
    /// multiple of the start loss (0 = disabled, the default; must be >= 1
    /// when enabled).
    pub divergence_factor: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "opt-micro".into(),
            artifacts_root: "artifacts".into(),
            backend: BackendKind::Auto,
            task: "sst2".into(),
            method: Method::Lezo,
            peft: PeftMode::Full,
            drop_layers: 0,
            lr: 1e-6,
            mu: 1e-3,
            steps: 2000,
            eval_every: 500,
            eval_examples: 200,
            train_examples: 1000,
            seed: 0,
            icl_shots: 4,
            mean_len: 24,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            adam_eps: 1e-8,
            checkpoint: String::new(),
            blocks_only: true,
            policy: Policy::Uniform,
            smezo_keep: 0.5,
            shards: 2,
            shard_transport: ShardTransport::Thread,
            workers: String::new(),
            net_timeout_ms: crate::runtime::transport::DEFAULT_NET_TIMEOUT_MS,
            net_retries: crate::runtime::transport::DEFAULT_NET_RETRIES,
            threads: 0,
            precision: Precision::F32,
            zo_opt: ZoOptKind::Sgd,
            resume: "auto".into(),
            save_every: 0,
            faults: String::new(),
            on_nonfinite: NonFinitePolicy::Error,
            divergence_factor: 0.0,
        }
    }
}

impl RunConfig {
    pub fn artifact_dir(&self) -> String {
        format!("{}/{}", self.artifacts_root, self.model)
    }

    /// The `workers` key split into individual `host:port` addresses.
    pub fn worker_addrs(&self) -> Vec<String> {
        self.workers
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    }

    /// Apply one `key=value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        macro_rules! parse {
            () => {
                value.parse().map_err(|e| anyhow!("bad value for {key}: {e}"))?
            };
        }
        match key {
            "model" => self.model = value.to_string(),
            "artifacts" | "artifacts_root" => self.artifacts_root = value.to_string(),
            "backend" => self.backend = parse!(),
            "task" => self.task = value.to_string(),
            "method" => match method_peft_alias(value) {
                Some((m, p)) => {
                    self.method = m;
                    self.peft = p;
                }
                None => self.method = parse!(),
            },
            "peft" => self.peft = parse!(),
            "drop_layers" | "n" => self.drop_layers = parse!(),
            "lr" => self.lr = parse!(),
            "mu" | "eps" => self.mu = parse!(),
            "steps" => self.steps = parse!(),
            "eval_every" => self.eval_every = parse!(),
            "eval_examples" => self.eval_examples = parse!(),
            "train_examples" => self.train_examples = parse!(),
            "seed" => self.seed = parse!(),
            "icl_shots" => self.icl_shots = parse!(),
            "mean_len" => self.mean_len = parse!(),
            "checkpoint" => self.checkpoint = value.to_string(),
            "blocks_only" => self.blocks_only = parse!(),
            "policy" => self.policy = parse!(),
            "smezo_keep" => {
                let keep: f64 = parse!();
                if !(0.0..=1.0).contains(&keep) {
                    bail!("smezo_keep must be in [0, 1], got {keep}");
                }
                self.smezo_keep = keep;
            }
            "shards" => {
                let n: usize = parse!();
                if n == 0 {
                    bail!("shards must be a positive replica count, got 0");
                }
                self.shards = n;
            }
            "shard_transport" => self.shard_transport = parse!(),
            "workers" => self.workers = value.to_string(),
            "net_timeout_ms" => {
                let n: u64 = parse!();
                if n == 0 {
                    bail!("net_timeout_ms must be a positive number of milliseconds, got 0");
                }
                self.net_timeout_ms = n;
            }
            "net_retries" => {
                let n: u32 = parse!();
                if n == 0 {
                    bail!("net_retries must be a positive attempt count, got 0");
                }
                self.net_retries = n;
            }
            "threads" => self.threads = parse!(),
            "precision" => self.precision = parse!(),
            "zo_opt" => self.zo_opt = parse!(),
            "resume" => {
                if value.is_empty() {
                    bail!("resume must be auto|never|<state-file path>");
                }
                self.resume = value.to_string();
            }
            "save_every" => self.save_every = parse!(),
            "faults" => {
                // eager grammar check so a typo fails at the CLI, not mid-run
                FaultPlan::parse(value).map_err(|e| anyhow!("bad value for faults: {e}"))?;
                self.faults = value.to_string();
            }
            "on_nonfinite" | "on-nonfinite" => self.on_nonfinite = parse!(),
            "divergence_factor" => {
                let f: f64 = parse!();
                if !f.is_finite() || (f != 0.0 && f < 1.0) {
                    bail!("divergence_factor must be 0 (disabled) or >= 1, got {f}");
                }
                self.divergence_factor = f;
            }
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Apply a list of `key=value` strings.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<()> {
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .ok_or_else(|| anyhow!("override '{ov}' is not key=value"))?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Parse a `key = value` config file (comments with '#').
    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read config {path}: {e}"))?;
        let mut cfg = RunConfig::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("{path}:{}: not key=value", lineno + 1))?;
            cfg.set(k.trim(), v.trim())?;
        }
        Ok(cfg)
    }

    pub fn to_file_format(&self) -> String {
        format!(
            "model = {}\ntask = {}\nmethod = {}\npeft = {}\ndrop_layers = {}\nlr = {}\n\
             mu = {}\nsteps = {}\neval_every = {}\neval_examples = {}\ntrain_examples = {}\n\
             seed = {}\nicl_shots = {}\nmean_len = {}\nblocks_only = {}\nzo_opt = {}\n\
             shards = {}\nshard_transport = {}\nworkers = {}\nnet_timeout_ms = {}\n\
             net_retries = {}\nresume = {}\nsave_every = {}\non_nonfinite = {}\n\
             divergence_factor = {}\n",
            self.model, self.task, self.method, self.peft, self.drop_layers, self.lr,
            self.mu, self.steps, self.eval_every, self.eval_examples, self.train_examples,
            self.seed, self.icl_shots, self.mean_len, self.blocks_only, self.zo_opt,
            self.shards, self.shard_transport, self.workers, self.net_timeout_ms,
            self.net_retries, self.resume, self.save_every, self.on_nonfinite,
            self.divergence_factor,
        )
    }

    /// Cross-key sanity checks, run once at the top of every training/eval
    /// entry (`Trainer::run_with`). Per-key range checks live in [`Self::set`];
    /// this catches the combinations that would otherwise panic mid-run
    /// (modulo-by-zero eval cadence, an empty training pool).
    pub fn validate(&self) -> Result<()> {
        if self.eval_every == 0 {
            bail!("eval_every must be >= 1 (got 0; evaluation cadence is a modulus)");
        }
        let trains = matches!(self.method, Method::Ft | Method::Mezo | Method::Lezo | Method::Smezo);
        if trains && self.steps == 0 {
            bail!("steps must be >= 1 for training method '{}'", self.method);
        }
        if !(0.0..=1.0).contains(&self.smezo_keep) {
            bail!("smezo_keep must be in [0, 1], got {}", self.smezo_keep);
        }
        if !self.divergence_factor.is_finite()
            || (self.divergence_factor != 0.0 && self.divergence_factor < 1.0)
        {
            bail!(
                "divergence_factor must be 0 (disabled) or >= 1, got {}",
                self.divergence_factor
            );
        }
        if self.resume.is_empty() {
            bail!("resume must be auto|never|<state-file path>");
        }
        if self.shards == 0 {
            bail!("shards must be a positive replica count, got 0");
        }
        if self.shard_transport == ShardTransport::Socket {
            if self.shards < 2 {
                bail!(
                    "shard_transport=socket with shards=1 has no remote fan-out to tolerate \
                     faults on; use shard_transport=thread for a single shard, or set the \
                     `shards` config key (or LEZO_SHARDS) to >= 2 and list one worker address \
                     per shard in `workers`"
                );
            }
            let n_workers = self.worker_addrs().len();
            if n_workers == 0 {
                bail!(
                    "shard_transport=socket requires the `workers` config key: a \
                     comma-separated host:port list, one address per shard (start each \
                     worker with `lezo worker --listen <addr>`)"
                );
            }
            if n_workers != self.shards {
                bail!(
                    "socket transport needs one worker address per shard: the `workers` key \
                     lists {n_workers} address(es) but shards = {} (adjust one of them, or \
                     unset LEZO_SHARDS if it is overriding)",
                    self.shards
                );
            }
        }
        if self.net_timeout_ms == 0 {
            bail!("net_timeout_ms must be a positive number of milliseconds, got 0");
        }
        if self.net_retries == 0 {
            bail!("net_retries must be a positive attempt count, got 0");
        }
        FaultPlan::parse(&self.faults)
            .map_err(|e| anyhow!("faults key does not parse: {e}"))?;
        Ok(())
    }
}

/// The paper's Table-5 hyper-parameter grids, scaled to this testbed.
/// Grid search in the bench harness walks these.
pub fn grids() -> BTreeMap<&'static str, Vec<(&'static str, Vec<f64>)>> {
    let mut g = BTreeMap::new();
    g.insert(
        "lezo",
        vec![("lr", vec![5e-4, 2.5e-4, 1e-4]), ("mu", vec![1e-3])],
    );
    g.insert(
        "mezo",
        vec![("lr", vec![2e-4, 1e-4, 5e-5]), ("mu", vec![1e-3])],
    );
    g.insert(
        "lezo-prefix",
        vec![("lr", vec![3e-2, 1e-2]), ("mu", vec![1e-1])],
    );
    g.insert(
        "mezo-prefix",
        vec![("lr", vec![1e-2, 1e-3]), ("mu", vec![1e-1])],
    );
    g.insert(
        "lezo-lora",
        vec![("lr", vec![1e-2, 5e-3, 3e-3]), ("mu", vec![1e-2])],
    );
    g.insert(
        "mezo-lora",
        vec![("lr", vec![5e-3, 3e-3]), ("mu", vec![1e-2])],
    );
    g.insert("ft", vec![("lr", vec![1e-3, 3e-4, 1e-4])]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.method, Method::Lezo);
        assert_eq!(c.drop_layers, 0);
        assert!(c.blocks_only);
    }

    #[test]
    fn overrides_apply() {
        let mut c = RunConfig::default();
        c.apply_overrides(&[
            "method=mezo".into(),
            "lr=1e-5".into(),
            "drop_layers=3".into(),
            "task=boolq".into(),
        ])
        .unwrap();
        assert_eq!(c.method, Method::Mezo);
        assert_eq!(c.lr, 1e-5);
        assert_eq!(c.drop_layers, 3);
        assert_eq!(c.task, "boolq");
    }

    #[test]
    fn bad_override_rejected() {
        let mut c = RunConfig::default();
        assert!(c.apply_overrides(&["nope=1".into()]).is_err());
        assert!(c.apply_overrides(&["lr".into()]).is_err());
        assert!(c.apply_overrides(&["method=sgd".into()]).is_err());
        assert!(c.apply_overrides(&["backend=gpu".into()]).is_err());
    }

    #[test]
    fn threads_key_parses() {
        let mut c = RunConfig::default();
        assert_eq!(c.threads, 0, "default is auto");
        c.apply_overrides(&["threads=4".into()]).unwrap();
        assert_eq!(c.threads, 4);
        assert!(c.apply_overrides(&["threads=many".into()]).is_err());
    }

    #[test]
    fn precision_key_parses() {
        let mut c = RunConfig::default();
        assert_eq!(c.precision, Precision::F32, "default is f32");
        c.apply_overrides(&["precision=bf16".into()]).unwrap();
        assert_eq!(c.precision, Precision::Bf16);
        c.apply_overrides(&["precision=int8".into()]).unwrap();
        assert_eq!(c.precision, Precision::Int8);
        c.apply_overrides(&["precision=int4".into()]).unwrap();
        assert_eq!(c.precision, Precision::Int4);
        c.apply_overrides(&["precision=f32".into()]).unwrap();
        assert_eq!(c.precision, Precision::F32);
        assert!(c.apply_overrides(&["precision=fp8".into()]).is_err());
    }

    #[test]
    fn zo_opt_key_parses() {
        let mut c = RunConfig::default();
        assert_eq!(c.zo_opt, ZoOptKind::Sgd, "default is the classic rule");
        c.apply_overrides(&["zo_opt=zo-adam".into()]).unwrap();
        assert_eq!(c.zo_opt, ZoOptKind::Adam);
        c.apply_overrides(&["zo_opt=sign".into()]).unwrap();
        assert_eq!(c.zo_opt, ZoOptKind::SignSgd);
        c.apply_overrides(&["zo_opt=fzoo".into()]).unwrap();
        assert_eq!(c.zo_opt, ZoOptKind::Fzoo);
        // unknown value: error names the valid set
        let err = c.apply_overrides(&["zo_opt=turbo".into()]).unwrap_err().to_string();
        assert!(err.contains("zo-sgd-momentum"), "{err}");
        assert!(err.contains("fzoo"), "{err}");
    }

    #[test]
    fn zo_opt_round_trips_through_file_format() {
        let mut c0 = RunConfig::default();
        c0.set("zo_opt", "zo-sgd-momentum").unwrap();
        assert!(c0.to_file_format().contains("zo_opt = zo-sgd-momentum"));
        let path = std::env::temp_dir().join("lezo_cfg_test_zoopt.conf");
        std::fs::write(&path, c0.to_file_format()).unwrap();
        let c1 = RunConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c1.zo_opt, ZoOptKind::Momentum);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn crash_safety_keys_parse() {
        let mut c = RunConfig::default();
        assert_eq!(c.resume, "auto", "default resume mode is auto");
        assert_eq!(c.save_every, 0, "checkpointing is off by default");
        assert!(c.faults.is_empty());
        assert_eq!(c.on_nonfinite, NonFinitePolicy::Error);
        assert_eq!(c.divergence_factor, 0.0);

        c.apply_overrides(&[
            "resume=never".into(),
            "save_every=25".into(),
            "faults=nan-loss@120,crash@250,io-err@save:2".into(),
            "on_nonfinite=skip-step".into(),
            "divergence_factor=10".into(),
        ])
        .unwrap();
        assert_eq!(c.resume, "never");
        assert_eq!(c.save_every, 25);
        assert_eq!(c.faults, "nan-loss@120,crash@250,io-err@save:2");
        assert_eq!(c.on_nonfinite, NonFinitePolicy::SkipStep);
        assert_eq!(c.divergence_factor, 10.0);
        // the hyphenated spelling from the paper issue is accepted too
        c.set("on-nonfinite", "error").unwrap();
        assert_eq!(c.on_nonfinite, NonFinitePolicy::Error);
        // a path-valued resume is any other string
        c.set("resume", "some/dir/train_state.ckpt").unwrap();
        assert_eq!(c.resume, "some/dir/train_state.ckpt");

        // bad values fail at the CLI, naming the problem
        assert!(c.set("resume", "").is_err());
        assert!(c.set("faults", "explode@9").is_err());
        assert!(c.set("on_nonfinite", "ignore").is_err());
        for bad in ["0.5", "-1", "NaN"] {
            assert!(c.set("divergence_factor", bad).is_err(), "{bad}");
        }
        assert_eq!(c.divergence_factor, 10.0, "failed sets must not clobber");
    }

    #[test]
    fn crash_safety_keys_round_trip_through_file_format() {
        let mut c0 = RunConfig::default();
        c0.set("save_every", "50").unwrap();
        c0.set("on_nonfinite", "skip-step").unwrap();
        c0.set("divergence_factor", "8").unwrap();
        c0.set("resume", "never").unwrap();
        let path = std::env::temp_dir().join("lezo_cfg_test_crash.conf");
        std::fs::write(&path, c0.to_file_format()).unwrap();
        let c1 = RunConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c1.save_every, 50);
        assert_eq!(c1.on_nonfinite, NonFinitePolicy::SkipStep);
        assert_eq!(c1.divergence_factor, 8.0);
        assert_eq!(c1.resume, "never");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn smezo_keep_range_checked_at_parse_time() {
        let mut c = RunConfig::default();
        c.set("smezo_keep", "0.25").unwrap();
        assert_eq!(c.smezo_keep, 0.25);
        c.set("smezo_keep", "0").unwrap();
        c.set("smezo_keep", "1").unwrap();
        for bad in ["-0.1", "1.5", "NaN"] {
            let err = c.set("smezo_keep", bad).unwrap_err().to_string();
            assert!(err.contains("[0, 1]"), "{bad}: {err}");
        }
        assert_eq!(c.smezo_keep, 1.0, "failed sets must not clobber");
    }

    #[test]
    fn validate_rejects_panicky_configs() {
        let ok = RunConfig::default();
        ok.validate().unwrap();

        let mut c = RunConfig::default();
        c.eval_every = 0;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("eval_every"), "{err}");

        let mut c = RunConfig::default();
        c.method = Method::Mezo;
        c.steps = 0;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("steps"), "{err}");
        assert!(err.contains("mezo"), "error names the method: {err}");
        // zero steps is fine for no-train methods
        c.method = Method::ZeroShot;
        c.validate().unwrap();

        let mut c = RunConfig::default();
        c.smezo_keep = f64::NAN; // set via field to bypass the parse check
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("smezo_keep"), "{err}");
    }

    #[test]
    fn backend_key_parses() {
        let mut c = RunConfig::default();
        assert_eq!(c.backend, BackendKind::Auto);
        c.apply_overrides(&["backend=native".into()]).unwrap();
        assert_eq!(c.backend, BackendKind::Native);
        c.apply_overrides(&["backend=sharded".into()]).unwrap();
        assert_eq!(c.backend, BackendKind::Sharded);
        c.apply_overrides(&["backend=pjrt".into()]).unwrap();
        assert_eq!(c.backend, BackendKind::Pjrt);
    }

    #[test]
    fn shards_key_parses_and_rejects_zero() {
        let mut c = RunConfig::default();
        assert_eq!(c.shards, 2, "default shard count");
        c.apply_overrides(&["shards=4".into()]).unwrap();
        assert_eq!(c.shards, 4);
        let err = c.apply_overrides(&["shards=0".into()]).unwrap_err().to_string();
        assert!(err.contains("positive"), "{err}");
        assert!(c.apply_overrides(&["shards=lots".into()]).is_err());
        assert_eq!(c.shards, 4, "failed sets must not clobber");
        // the file format round-trips the key
        assert!(c.to_file_format().contains("shards = 4"));
        // validate catches a field-level zero too
        c.shards = 0;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("shards"), "{err}");
    }

    #[test]
    fn shard_transport_keys_parse_and_round_trip() {
        let mut c = RunConfig::default();
        assert_eq!(c.shard_transport, ShardTransport::Thread, "default is in-process");
        assert!(c.workers.is_empty());
        assert_eq!(c.net_timeout_ms, 5_000);
        assert_eq!(c.net_retries, 3);

        c.apply_overrides(&[
            "shard_transport=socket".into(),
            "workers=127.0.0.1:7001, 127.0.0.1:7002".into(),
            "net_timeout_ms=250".into(),
            "net_retries=5".into(),
        ])
        .unwrap();
        assert_eq!(c.shard_transport, ShardTransport::Socket);
        assert_eq!(c.worker_addrs(), vec!["127.0.0.1:7001", "127.0.0.1:7002"]);
        assert_eq!((c.net_timeout_ms, c.net_retries), (250, 5));

        // bad values fail at the CLI, naming the valid set / range
        let err = c.set("shard_transport", "carrier-pigeon").unwrap_err().to_string();
        assert!(err.contains("thread|socket"), "{err}");
        assert!(c.set("net_timeout_ms", "0").is_err());
        assert!(c.set("net_retries", "0").is_err());
        assert!(c.set("net_timeout_ms", "soon").is_err());

        // the file format round-trips every new key
        let path = std::env::temp_dir().join("lezo_cfg_test_transport.conf");
        std::fs::write(&path, c.to_file_format()).unwrap();
        let c1 = RunConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c1.shard_transport, ShardTransport::Socket);
        assert_eq!(c1.worker_addrs(), c.worker_addrs());
        assert_eq!((c1.net_timeout_ms, c1.net_retries), (250, 5));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn validate_rejects_inconsistent_socket_configs() {
        // socket with a single shard: actionable rejection
        let mut c = RunConfig::default();
        c.set("shard_transport", "socket").unwrap();
        c.set("workers", "127.0.0.1:7001").unwrap();
        c.shards = 1;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("shards=1") && err.contains("shard_transport=thread"), "{err}");

        // socket without worker addresses
        let mut c = RunConfig::default();
        c.set("shard_transport", "socket").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("workers") && err.contains("lezo worker --listen"), "{err}");

        // worker count must match the shard count
        c.set("workers", "127.0.0.1:7001").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("1 address") && err.contains("shards = 2"), "{err}");

        // a consistent socket config passes
        c.set("workers", "127.0.0.1:7001,127.0.0.1:7002").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn file_round_trip() {
        let c0 = {
            let mut c = RunConfig::default();
            c.apply_overrides(&["method=ft".into(), "steps=77".into(), "mu=0.5".into()])
                .unwrap();
            c
        };
        let path = std::env::temp_dir().join("lezo_cfg_test.conf");
        std::fs::write(&path, c0.to_file_format()).unwrap();
        let c1 = RunConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c1.method, Method::Ft);
        assert_eq!(c1.steps, 77);
        assert_eq!(c1.mu, 0.5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn config_file_comments_and_blanks() {
        let path = std::env::temp_dir().join("lezo_cfg_test2.conf");
        std::fs::write(&path, "# comment\n\nmethod = mezo # inline\nsteps=5\n").unwrap();
        let c = RunConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.method, Method::Mezo);
        assert_eq!(c.steps, 5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn method_parse_display_round_trip() {
        for m in ["zero-shot", "icl", "ft", "mezo", "lezo", "smezo"] {
            let parsed: Method = m.parse().unwrap();
            assert_eq!(parsed.to_string(), m);
        }
    }

    #[test]
    fn method_peft_aliases_set_both_keys() {
        for (alias, method, peft) in [
            ("mezo-lora", Method::Mezo, PeftMode::Lora),
            ("lezo-lora", Method::Lezo, PeftMode::Lora),
            ("mezo-prefix", Method::Mezo, PeftMode::Prefix),
            ("lezo-prefix", Method::Lezo, PeftMode::Prefix),
        ] {
            let mut c = RunConfig::default();
            c.set("method", alias).unwrap();
            assert_eq!(c.method, method, "{alias}");
            assert_eq!(c.peft, peft, "{alias}");
            // every alias is also a Table-5 grid key
            assert!(grids().contains_key(alias), "{alias}");
        }
        // non-alias methods leave peft alone and still parse
        let mut c = RunConfig::default();
        c.set("peft", "lora").unwrap();
        c.set("method", "sparse-mezo").unwrap();
        assert_eq!(c.method, Method::Smezo);
        assert_eq!(c.peft, PeftMode::Lora, "plain method must not reset peft");
        // a PEFT suffix on a non-composable method is an error, not silence
        assert!(c.set("method", "smezo-lora").is_err());
        assert!(c.set("method", "ft-lora").is_err());
    }

    #[test]
    fn grids_contain_paper_methods() {
        let g = grids();
        for k in ["lezo", "mezo", "lezo-prefix", "mezo-prefix", "lezo-lora", "mezo-lora", "ft"] {
            assert!(g.contains_key(k), "{k}");
        }
    }
}

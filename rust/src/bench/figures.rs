//! Figures 1–6 of the paper, regenerated as printed series.

use super::{bench_config, lezo_lr, paper_drop};
use crate::config::Method;
use crate::coordinator::{TrainReport, Trainer};
use crate::util::render_table;
use anyhow::Result;
use std::fmt::Write as _;

fn n_layers(cfg: &crate::config::RunConfig) -> Result<usize> {
    Ok(super::model_spec_for(cfg)?.n_layers)
}

fn run_one(cfg: &crate::config::RunConfig) -> Result<TrainReport> {
    Trainer::new(cfg.clone()).run()
}

/// Fig. 1: accuracy vs wall-clock, LeZO vs MeZO on SST-2 — the paper's
/// headline 3.4x wall-clock speedup plot.
pub fn fig1(overrides: &[String]) -> Result<String> {
    let base = bench_config(overrides)?;
    let nl = n_layers(&base)?;
    let mut mezo = base.clone();
    mezo.method = Method::Mezo;
    mezo.drop_layers = 0;
    let mut lezo = base.clone();
    lezo.method = Method::Lezo;
    lezo.drop_layers = paper_drop(nl);
    lezo.lr = lezo_lr(base.lr);

    let rm = run_one(&mezo)?;
    let rl = run_one(&lezo)?;

    let mut out = String::from("Fig. 1 — accuracy vs training wall-time (SST-2)\n\n");
    let mut rows = Vec::new();
    for (name, r) in [("MeZO", &rm), ("LeZO", &rl)] {
        for p in &r.history {
            rows.push(vec![
                name.to_string(),
                p.step.to_string(),
                format!("{:.1}", p.train_secs),
                format!("{:.1}", 100.0 * p.metric),
            ]);
        }
    }
    out.push_str(&render_table(&["method", "step", "train_s", "acc%"], &rows));

    // speedups at MeZO's best accuracy
    let target = rm.best_metric.min(rl.best_metric);
    let comp = rm.per_step_ms() / rl.per_step_ms();
    writeln!(out, "\nper-step: MeZO {:.1} ms, LeZO {:.1} ms -> computation speedup {comp:.2}x",
        rm.per_step_ms(), rl.per_step_ms())?;
    if let (Some(tm), Some(tl)) = (rm.time_to_metric(target), rl.time_to_metric(target)) {
        writeln!(
            out,
            "time to {:.1}%: MeZO {tm:.1}s, LeZO {tl:.1}s -> wall-clock speedup {:.2}x",
            100.0 * target,
            tm / tl.max(1e-9)
        )?;
    }
    Ok(out)
}

/// Fig. 2: the stage-time split of a MeZO step — the paper's motivating
/// observation that perturb+update exceed 50% of step time.
pub fn fig2(overrides: &[String]) -> Result<String> {
    let base = bench_config(overrides)?;
    let models: Vec<String> = if overrides.iter().any(|o| o.starts_with("model=")) {
        vec![base.model.clone()]
    } else {
        // with artifacts: every exported size; without: the configured
        // model only (each extra size would retrain natively)
        let all: Vec<String> = ["opt-micro", "opt-tiny", "opt-small"]
            .iter()
            .map(|s| s.to_string())
            .filter(|m| {
                crate::runtime::backend::artifacts_available(std::path::Path::new(&format!(
                    "{}/{}",
                    base.artifacts_root, m
                )))
            })
            .collect();
        if all.is_empty() {
            vec![base.model.clone()]
        } else {
            all
        }
    };
    let mut out = String::from(
        "Fig. 2 — MeZO per-step stage split (paper: perturb+update > 50%)\n\n",
    );
    let mut rows = Vec::new();
    for model in models {
        let mut cfg = base.clone();
        cfg.model = model.clone();
        cfg.method = Method::Mezo;
        cfg.drop_layers = 0;
        cfg.steps = cfg.steps.min(60);
        cfg.eval_every = cfg.steps; // single final eval
        cfg.eval_examples = 16;
        let r = run_one(&cfg)?;
        let (p, f, u, o) = r.stage_times.per_step_ms();
        let total = p + f + u + o;
        rows.push(vec![
            model,
            format!("{p:.1}"),
            format!("{f:.1}"),
            format!("{u:.1}"),
            format!("{o:.1}"),
            format!("{:.0}%", 100.0 * (p + u + o) / total.max(1e-12)),
        ]);
    }
    out.push_str(&render_table(
        &["model", "perturb_ms", "forward_ms", "update_ms", "other_ms", "non-forward"],
        &rows,
    ));
    Ok(out)
}

/// Fig. 3: accuracy over the (learning rate × dropout number) surface on
/// SST-2 — LeZO tolerates (needs) larger LRs as sparsity grows; rho = 1
/// collapses.
pub fn fig3(overrides: &[String]) -> Result<String> {
    let base = bench_config(overrides)?;
    let nl = n_layers(&base)?;
    let drops: Vec<usize> = vec![0, nl / 4, nl / 2, 3 * nl / 4, nl];
    let lrs = [5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3]; // testbed scale (DESIGN.md §9)
    let mut out = String::from(
        "Fig. 3 — accuracy on SST-2 over (lr x dropout number), single seed\n\n",
    );
    let mut header = vec!["drop\\lr".to_string()];
    header.extend(lrs.iter().map(|l| format!("{l:.0e}")));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut rows = Vec::new();
    for &drop in &drops {
        let mut row = vec![format!("{drop}/{nl}")];
        for &lr in &lrs {
            let mut cfg = base.clone();
            cfg.method = if drop == 0 { Method::Mezo } else { Method::Lezo };
            cfg.drop_layers = drop;
            cfg.lr = lr;
            let r = run_one(&cfg)?;
            row.push(format!("{:.1}", 100.0 * r.best_metric));
        }
        rows.push(row);
    }
    out.push_str(&render_table(&header_refs, &rows));
    out.push_str("\nrow drop=0 is MeZO; the last row (all blocks dropped) tunes only\nembedding+head — the paper's rho=1 collapse.\n");
    Ok(out)
}

/// Fig. 4: per-step runtime and best accuracy vs sparsity.
pub fn fig4(overrides: &[String]) -> Result<String> {
    let base = bench_config(overrides)?;
    let nl = n_layers(&base)?;
    let mut out = String::from("Fig. 4 — sparsity vs per-step runtime and accuracy\n\n");
    let mut rows = Vec::new();
    for drop in 0..=nl {
        let mut cfg = base.clone();
        cfg.method = if drop == 0 { Method::Mezo } else { Method::Lezo };
        cfg.drop_layers = drop;
        if drop > 0 {
            cfg.lr = lezo_lr(base.lr);
        }
        let r = run_one(&cfg)?;
        rows.push(vec![
            format!("{drop}/{nl}"),
            format!("{:.2}", r.active_param_fraction),
            format!("{:.1}", r.per_step_ms()),
            format!("{:.1}", r.train_secs),
            format!("{:.1}", 100.0 * r.best_metric),
        ]);
    }
    out.push_str(&render_table(
        &["drop", "active_frac", "step_ms", "total_s", "best%"],
        &rows,
    ));
    Ok(out)
}

/// Fig. 5: per-task computation and convergence speedups of LeZO over MeZO.
pub fn fig5(overrides: &[String]) -> Result<String> {
    let base = bench_config(overrides)?;
    let nl = n_layers(&base)?;
    let tasks = crate::tasks::TABLE1_TASKS;
    let mut out = String::from("Fig. 5 — per-task speedups (LeZO / MeZO)\n\n");
    let mut rows = Vec::new();
    for task in tasks {
        let mut mezo = base.clone();
        mezo.task = task.into();
        mezo.method = Method::Mezo;
        let mut lezo = mezo.clone();
        lezo.method = Method::Lezo;
        lezo.drop_layers = paper_drop(nl);
        lezo.lr = lezo_lr(base.lr);
        let rm = run_one(&mezo)?;
        let rl = run_one(&lezo)?;
        let comp = rm.per_step_ms() / rl.per_step_ms();
        // convergence: time to the weaker of the two best metrics
        let target = rm.best_metric.min(rl.best_metric);
        let conv = match (rm.time_to_metric(target), rl.time_to_metric(target)) {
            (Some(tm), Some(tl)) if tl > 0.0 => format!("{:.2}x", tm / tl),
            _ => "n/a".to_string(),
        };
        rows.push(vec![
            task.to_string(),
            format!("{:.2}x", comp),
            conv,
            format!("{:.1}", 100.0 * rm.best_metric),
            format!("{:.1}", 100.0 * rl.best_metric),
        ]);
    }
    out.push_str(&render_table(
        &["task", "comp_speedup", "conv_speedup", "mezo_best%", "lezo_best%"],
        &rows,
    ));
    Ok(out)
}

/// Fig. 6: computational speedup vs mean input token length — longer inputs
/// dilute the perturb/update saving.
pub fn fig6(overrides: &[String]) -> Result<String> {
    let base = bench_config(overrides)?;
    let nl = n_layers(&base)?;
    let lens = [8usize, 16, 24, 32, 40];
    let mut out = String::from("Fig. 6 — input length vs computational speedup\n\n");
    let mut rows = Vec::new();
    for &len in &lens {
        let mut mezo = base.clone();
        mezo.method = Method::Mezo;
        mezo.mean_len = len;
        mezo.steps = mezo.steps.min(80);
        mezo.eval_every = mezo.steps;
        mezo.eval_examples = 16;
        let mut lezo = mezo.clone();
        lezo.method = Method::Lezo;
        lezo.drop_layers = paper_drop(nl);
        lezo.lr = lezo_lr(base.lr);
        let rm = run_one(&mezo)?;
        let rl = run_one(&lezo)?;
        rows.push(vec![
            format!("{len}"),
            format!("{:.1}", rm.mean_input_len),
            format!("{:.1}", rm.per_step_ms()),
            format!("{:.1}", rl.per_step_ms()),
            format!("{:.2}x", rm.per_step_ms() / rl.per_step_ms()),
        ]);
    }
    out.push_str(&render_table(
        &["mean_len", "measured_len", "mezo_ms", "lezo_ms", "speedup"],
        &rows,
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    // Figure benches are exercised end-to-end by `lezo bench` (integration);
    // unit coverage here is for the pure helpers.
    use super::*;

    #[test]
    fn n_layers_resolves_without_artifacts() {
        // falls back to the native preset when no manifest exists
        let mut cfg = crate::config::RunConfig::default();
        cfg.model = "opt-micro".into();
        assert_eq!(n_layers(&cfg).unwrap(), 4);
        cfg.model = "opt-small".into();
        assert_eq!(n_layers(&cfg).unwrap(), 8);
    }
}

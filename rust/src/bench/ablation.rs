//! Design-choice ablations (DESIGN.md calls these out): what the paper's
//! "simple uniform random" layer selection costs or buys against
//! round-robin, coverage-stratified, and importance-weighted policies.

use super::{bench_config, lezo_lr, model_spec_for, paper_drop};
use crate::config::Method;
use crate::coordinator::metrics::MemoryModel;
use crate::coordinator::policy::Policy;
use crate::coordinator::Trainer;
use crate::util::render_table;
use anyhow::Result;
use std::fmt::Write as _;

/// Compare selection policies at the paper's 75% sparsity on SST-2.
pub fn selector_policies(overrides: &[String]) -> Result<String> {
    let base = bench_config(overrides)?;
    let nl = model_spec_for(&base)?.n_layers;
    let mut out = String::from(
        "Ablation — layer-selection policy at 75% sparsity (paper: uniform)\n\n",
    );
    let mut rows = Vec::new();
    for policy in [Policy::Uniform, Policy::RoundRobin, Policy::Stratified, Policy::Weighted] {
        let mut cfg = base.clone();
        cfg.method = Method::Lezo;
        cfg.drop_layers = paper_drop(nl);
        cfg.lr = lezo_lr(base.lr);
        cfg.policy = policy;
        let r = Trainer::new(cfg).run()?;
        rows.push(vec![
            policy.to_string(),
            format!("{:.1}", 100.0 * r.best_metric),
            format!("{:.1}", 100.0 * r.final_metric),
            format!("{:.1}", r.per_step_ms()),
        ]);
    }
    out.push_str(&render_table(&["policy", "best%", "final%", "step_ms"], &rows));
    writeln!(
        out,
        "\nuniform is the paper's choice; stratified guarantees epoch coverage;\n\
         weighted is the LISA-like importance variant (O(N) extra state)."
    )?;
    out.push('\n');
    out.push_str(&sparse_mezo(overrides)?);
    Ok(out)
}

/// MeZO vs LeZO vs Sparse-MeZO (Liu et al. 2024): the paper's related-work
/// argument, measured. Sparse-MeZO's element-wise magnitude mask needs a
/// ranking pass and a per-step reference snapshot, and its perturb/update
/// phases still stream every element (2 loads + 1 store vs LeZO's skipped
/// units) — so its step is *slower* than MeZO's, not faster.
pub fn sparse_mezo(overrides: &[String]) -> Result<String> {
    let base = bench_config(overrides)?;
    let spec = model_spec_for(&base)?;
    let nl = spec.n_layers;
    let mut out = String::from("Ablation — LeZO vs Sparse-MeZO (element-wise masking)\n\n");
    let mut rows = Vec::new();
    for (label, method, drop, lr_mult) in [
        ("MeZO", Method::Mezo, 0usize, 1.0f64),
        ("LeZO (75%)", Method::Lezo, paper_drop(nl), 2.5),
        ("Sparse-MeZO (keep 50%)", Method::Smezo, 0, 2.0),
    ] {
        let mut cfg = base.clone();
        cfg.method = method;
        cfg.drop_layers = drop;
        cfg.lr = base.lr * lr_mult;
        let r = crate::coordinator::Trainer::new(cfg).run()?;
        let (p, f, u, o) = r.stage_times.per_step_ms();
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", 100.0 * r.best_metric),
            format!("{:.1}", p + f + u + o),
            format!("{:.1}", p + u),
            format!("{:.2}", o * r.stage_times.steps as f64 / 1e3),
        ]);
    }
    out.push_str(&render_table(
        &["method", "best%", "step_ms", "perturb+update_ms", "rank_s"],
        &rows,
    ));
    let mm = MemoryModel {
        params: spec.param_count(),
        batch: spec.train_batch,
        seq: 32,
        d_model: spec.d_model,
        n_layers: spec.n_layers,
    };
    writeln!(
        out,
        "\nmemory: ZO (MeZO/LeZO) = {:.1} MB weights only; Sparse-MeZO holds a\n\
         per-step reference snapshot of every perturbed unit (up to +100%\n\
         transient) plus the ranking state; FT-Adam = {:.1} MB ({:.1}x).",
        mm.zo_bytes() as f64 / 1e6,
        mm.adam_bytes() as f64 / 1e6,
        mm.ft_over_zo(),
    )?;
    Ok(out)
}

//! Tables 1–5 of the paper, regenerated on this testbed.
//!
//! Model substitutions (DESIGN.md §2): opt-small ↔ OPT-13B (Table 1),
//! opt-tiny ↔ OPT-1.3B (Table 2), opt-base ↔ OPT-30B (Table 3). The paper's
//! 75% layer sparsity becomes `drop = 3N/4` blocks of each model.

use super::{agg_pct, bench_config, fmt_pm, lezo_lr, model_spec_for, paper_drop, run_seeds};
use crate::config::{grids, Method, RunConfig};
use crate::coordinator::metrics::MemoryModel;
use crate::coordinator::optim::ZoOptKind;
use crate::coordinator::TrainReport;
use crate::model::ModelSpec;
use crate::peft::PeftMode;
use crate::tasks::{ALL_TASKS, TABLE1_TASKS};
use crate::util::render_table;
use anyhow::Result;
use std::fmt::Write as _;

pub const SEEDS: [u64; 3] = [0, 1, 2];

/// Seed count for the sweep: `bench_seeds=N` override (paper: 5; default 3
/// here; reduce for quick passes).
fn seeds_from(overrides: &[String]) -> Vec<u64> {
    for ov in overrides {
        if let Some(v) = ov.strip_prefix("bench_seeds=") {
            if let Ok(n) = v.parse::<usize>() {
                return SEEDS[..n.min(SEEDS.len())].to_vec();
            }
        }
    }
    SEEDS.to_vec()
}

fn strip_meta(overrides: &[String]) -> Vec<String> {
    overrides.iter().filter(|o| !o.starts_with("bench_seeds=")).cloned().collect()
}

fn n_layers_of(cfg: &RunConfig) -> Result<usize> {
    Ok(model_spec_for(cfg)?.n_layers)
}

/// Configure a method on top of a base config (Table-5 LR conventions).
fn method_cfg(base: &RunConfig, method: Method, n_layers: usize) -> RunConfig {
    let mut cfg = base.clone();
    cfg.method = method;
    match method {
        Method::Lezo => {
            cfg.drop_layers = paper_drop(n_layers);
            cfg.lr = lezo_lr(base.lr);
        }
        Method::Mezo => cfg.drop_layers = 0,
        Method::Ft => {
            cfg.drop_layers = 0;
            cfg.lr = 1e-3; // Adam scale, not SPSA scale
            // FO converges orders of magnitude faster per step (and each
            // step is far more expensive); paper used 5 epochs vs ZO's 20K
            cfg.steps = (base.steps / 10).clamp(30, 200);
            cfg.eval_every = cfg.steps;
        }
        _ => cfg.drop_layers = 0,
    }
    cfg
}

/// Per-method step cost aggregated across a grid's runs — feeds the FT
/// cost-profile footer of Table 1.
#[derive(Default)]
struct MethodCost {
    ms_per_step: Vec<f64>,
    non_forward: Vec<f64>,
    /// Max measured optimizer state across runs (`FoOptimizer::state_bytes`).
    fo_state_bytes: usize,
}

fn method_grid(
    tasks: &[&str],
    methods: &[Method],
    base: &RunConfig,
    seeds: &[u64],
    title: &str,
) -> Result<String> {
    let n_layers = n_layers_of(base)?;
    let mut header: Vec<&str> = vec!["Task"];
    let names: Vec<String> = methods.iter().map(|m| m.to_string()).collect();
    header.extend(names.iter().map(String::as_str));
    let mut rows = Vec::new();
    // column averages, paper's AVG. row
    let mut sums = vec![0.0f64; methods.len()];
    let mut costs: Vec<MethodCost> = methods.iter().map(|_| MethodCost::default()).collect();
    for &task in tasks {
        let mut row = vec![task.to_string()];
        for (mi, &method) in methods.iter().enumerate() {
            let mut cfg = method_cfg(base, method, n_layers);
            cfg.task = task.into();
            let reports = run_seeds(&cfg, seeds)?;
            for r in &reports {
                if r.stage_times.steps > 0 {
                    costs[mi].ms_per_step.push(r.per_step_ms());
                    costs[mi].non_forward.push(r.stage_times.non_forward_fraction());
                }
                costs[mi].fo_state_bytes = costs[mi].fo_state_bytes.max(r.fo_state_bytes);
            }
            let (m, s) = agg_pct(&reports);
            sums[mi] += m;
            row.push(fmt_pm(m, s));
        }
        rows.push(row);
    }
    let mut avg_row = vec!["AVG.".to_string()];
    for s in &sums {
        avg_row.push(format!("{:.1}", s / tasks.len() as f64));
    }
    rows.push(avg_row);
    let mut out = String::new();
    writeln!(out, "{title}")?;
    writeln!(
        out,
        "model={} precision={} drop(lezo)={} of {} blocks, seeds={:?}, {} steps\n",
        base.model,
        // the precision the runs actually execute (LEZO_PRECISION wins
        // over the config key), not the raw config value
        crate::runtime::backend::resolve_precision(base.precision)?,
        paper_drop(n_layers),
        n_layers,
        seeds,
        base.steps
    )?;
    out.push_str(&render_table(&header, &rows));
    if methods.contains(&Method::Ft) {
        out.push('\n');
        out.push_str(&ft_cost_profile(&model_spec_for(base)?, methods, &costs)?);
    }
    Ok(out)
}

/// The cost half of Table 1's "FT (12x memory)" annotation: measured step
/// time + stage attribution per training method, the measured Adam state,
/// and the analytic [`MemoryModel`] multiple. Emitted whenever the grid
/// includes the FT column (runs on any FO-capable backend, incl. native).
fn ft_cost_profile(spec: &ModelSpec, methods: &[Method], costs: &[MethodCost]) -> Result<String> {
    let header = ["Method", "ms/step", "non-forward", "opt state"];
    let mut rows = Vec::new();
    let mut ft_state = 0usize;
    for (&method, cost) in methods.iter().zip(costs) {
        if cost.ms_per_step.is_empty() {
            continue; // zero-shot / ICL: no training steps
        }
        if method == Method::Ft {
            ft_state = cost.fo_state_bytes;
        }
        rows.push(vec![
            method.to_string(),
            format!("{:.1}", crate::stats::mean(&cost.ms_per_step)),
            format!("{:.0}%", 100.0 * crate::stats::mean(&cost.non_forward)),
            if cost.fo_state_bytes > 0 {
                format!("{:.1} MB", cost.fo_state_bytes as f64 / 1e6)
            } else {
                "-".to_string()
            },
        ]);
    }
    let mem = MemoryModel {
        params: spec.param_count(),
        batch: spec.train_batch,
        seq: *spec.seq_buckets.iter().max().unwrap(),
        d_model: spec.d_model,
        n_layers: spec.n_layers,
    };
    let mut out = String::from("Step cost & memory (paper: \"FT = 12x memory\")\n");
    out.push_str(&render_table(&header, &rows));
    writeln!(
        out,
        "\nMemoryModel: ZO {:.1} MB vs FO-Adam {:.1} MB ({:.1}x); measured Adam state {:.1} MB",
        mem.zo_bytes() as f64 / 1e6,
        mem.adam_bytes() as f64 / 1e6,
        mem.ft_over_zo(),
        ft_state as f64 / 1e6,
    )?;
    Ok(out)
}

/// Table 1: the headline grid — opt-small (↔ OPT-13B) × 8 tasks ×
/// {zero-shot, ICL, FT, MeZO, LeZO}.
pub fn table1(overrides: &[String]) -> Result<String> {
    let seeds = seeds_from(overrides);
    let overrides = strip_meta(overrides);
    let base = bench_config(&overrides)?;
    let mut out = method_grid(
        &TABLE1_TASKS,
        &[Method::ZeroShot, Method::Icl, Method::Ft, Method::Mezo, Method::Lezo],
        &base,
        &seeds,
        "Table 1 — opt-small (↔ OPT-13B), LeZO sparsifies 75% of blocks",
    )?;
    out.push('\n');
    out.push_str(&zo_variant_profile(&base, &seeds)?);
    Ok(out)
}

/// The optimizer-zoo footer of Table 1: every ZO update rule under the
/// dense (MeZO) schedule on sst2 — accuracy, steps-to-accuracy-target,
/// step cost, and the seed-replay optimizer state. The target is 90% of
/// the best variant's mean final metric, so the column compares raw
/// convergence speed across rules at the same hyper-parameters.
fn zo_variant_profile(base: &RunConfig, seeds: &[u64]) -> Result<String> {
    let kinds = [
        ZoOptKind::Sgd,
        ZoOptKind::Momentum,
        ZoOptKind::Adam,
        ZoOptKind::SignSgd,
        ZoOptKind::Fzoo,
    ];
    let mut results = Vec::new();
    for &kind in &kinds {
        let mut cfg = base.clone();
        cfg.task = "sst2".into();
        cfg.method = Method::Mezo;
        cfg.drop_layers = 0;
        cfg.zo_opt = kind;
        results.push((kind, run_seeds(&cfg, seeds)?));
    }
    render_zo_variants(&results)
}

fn render_zo_variants(results: &[(ZoOptKind, Vec<TrainReport>)]) -> Result<String> {
    let mean_final = |rs: &[TrainReport]| {
        crate::stats::mean(&rs.iter().map(|r| r.final_metric).collect::<Vec<_>>())
    };
    let best = results.iter().map(|(_, rs)| mean_final(rs)).fold(f64::MIN, f64::max);
    let target = 0.9 * best;
    let header = ["zo_opt", "final", "steps-to-target", "ms/step", "zo state"];
    let mut rows = Vec::new();
    for (kind, rs) in results {
        let (m, s) = agg_pct(rs);
        let reached: Vec<f64> = rs
            .iter()
            .filter_map(|r| r.steps_to_metric(target))
            .map(|n| n as f64)
            .collect();
        let steps_col = if reached.is_empty() {
            "-".to_string()
        } else {
            format!("{:.0} ({}/{})", crate::stats::mean(&reached), reached.len(), rs.len())
        };
        let ms: Vec<f64> = rs.iter().map(|r| r.per_step_ms()).collect();
        let state = rs.iter().map(|r| r.zo_state_bytes).max().unwrap_or(0);
        rows.push(vec![
            kind.to_string(),
            fmt_pm(m, s),
            steps_col,
            format!("{:.1}", crate::stats::mean(&ms)),
            if state > 0 { format!("{state} B") } else { "-".to_string() },
        ]);
    }
    let mut out = String::new();
    writeln!(
        out,
        "ZO optimizer zoo (MeZO schedule, sst2; target = {:.1}% = 90% of best final)",
        100.0 * target
    )?;
    out.push_str(&render_table(&header, &rows));
    Ok(out)
}

/// Table 2: opt-tiny (↔ OPT-1.3B) × all 11 tasks × {zero-shot, ICL, MeZO, LeZO}.
pub fn table2(overrides: &[String]) -> Result<String> {
    let seeds = seeds_from(overrides);
    let overrides: Vec<String> = strip_meta(overrides);
    let overrides = overrides.as_slice();
    let mut base = bench_config(overrides)?;
    if !overrides.iter().any(|o| o.starts_with("model=")) {
        base.model = "opt-tiny".into();
    }
    method_grid(
        &ALL_TASKS,
        &[Method::ZeroShot, Method::Icl, Method::Mezo, Method::Lezo],
        &base,
        &seeds,
        "Table 2 — opt-tiny (↔ OPT-1.3B), LeZO sparsifies 75% of blocks",
    )
}

/// Table 3: opt-base (↔ OPT-30B) × {SST-2, BoolQ}.
pub fn table3(overrides: &[String]) -> Result<String> {
    let seeds = seeds_from(overrides);
    let overrides: Vec<String> = strip_meta(overrides);
    let overrides = overrides.as_slice();
    let mut base = bench_config(overrides)?;
    if !overrides.iter().any(|o| o.starts_with("model=")) {
        base.model = "opt-base".into();
    }
    if !overrides.iter().any(|o| o.starts_with("steps=")) {
        base.steps = 300; // the big model: keep the default CPU budget sane
        base.eval_every = 100;
    }
    method_grid(
        &["sst2", "boolq"],
        &[Method::ZeroShot, Method::Icl, Method::Mezo, Method::Lezo],
        &base,
        &seeds,
        "Table 3 — opt-base (↔ OPT-30B), LeZO sparsifies 75% of blocks",
    )
}

/// Table 4: ZO + PEFT — {MeZO, LeZO} × {LoRA, prefix} × 5 tasks.
/// LeZO(LoRA) sparsifies 50% of blocks, LeZO(prefix) 75% (paper caption).
///
/// Hermetic since the native PEFT forwards landed: every cell runs with
/// zero artifacts. Besides the accuracy grid, the output carries a
/// step-cost footer (per-method ms/step, non-forward fraction, and the
/// tunable-parameter count of the adapter space vs the full model) —
/// the measured side of "PEFT shrinks the ZO-perturbed space".
pub fn table4(overrides: &[String]) -> Result<String> {
    let seeds = seeds_from(overrides);
    let overrides = strip_meta(overrides);
    let base = bench_config(&overrides)?;
    let spec = model_spec_for(&base)?;
    let n_layers = spec.n_layers;
    let tasks = ["sst2", "cb", "boolq", "copa", "squad"];
    let g = grids();
    let variants: Vec<(String, Method, PeftMode, usize, f64, f64)> = vec![
        // (label, method, peft, drop, lr, mu)
        ("MeZO (LoRA)".into(), Method::Mezo, PeftMode::Lora, 0, g["mezo-lora"][0].1[0], 1e-2),
        ("MeZO (prefix)".into(), Method::Mezo, PeftMode::Prefix, 0, g["mezo-prefix"][0].1[0], 1e-1),
        ("LeZO (LoRA)".into(), Method::Lezo, PeftMode::Lora, n_layers / 2, g["lezo-lora"][0].1[0], 1e-2),
        ("LeZO (prefix)".into(), Method::Lezo, PeftMode::Prefix, paper_drop(n_layers), g["lezo-prefix"][0].1[0], 1e-1),
    ];
    let mut header: Vec<&str> = vec!["Method"];
    header.extend(tasks.iter());
    let mut rows = Vec::new();
    let mut costs: Vec<MethodCost> = variants.iter().map(|_| MethodCost::default()).collect();
    for (vi, (label, method, peft, drop, lr, mu)) in variants.iter().enumerate() {
        let mut row = vec![label.clone()];
        for &task in &tasks {
            let mut cfg = base.clone();
            cfg.task = task.into();
            cfg.method = *method;
            cfg.peft = *peft;
            cfg.drop_layers = *drop;
            cfg.lr = *lr;
            cfg.mu = *mu;
            let reports = run_seeds(&cfg, &seeds)?;
            for r in &reports {
                if r.stage_times.steps > 0 {
                    costs[vi].ms_per_step.push(r.per_step_ms());
                    costs[vi].non_forward.push(r.stage_times.non_forward_fraction());
                }
            }
            let (m, s) = agg_pct(&reports);
            row.push(fmt_pm(m, s));
        }
        rows.push(row);
    }
    let mut out = String::new();
    writeln!(
        out,
        "Table 4 — ZO + PEFT on {} [{}] (LeZO(LoRA) drops {} blocks, LeZO(prefix) drops {})\n",
        base.model,
        crate::runtime::backend::resolve_precision(base.precision)?,
        n_layers / 2,
        paper_drop(n_layers)
    )?;
    out.push_str(&render_table(&header, &rows));
    out.push('\n');
    out.push_str(&peft_cost_profile(&spec, &variants, &costs)?);
    Ok(out)
}

/// The Table-4 step-cost footer: measured ms/step and stage attribution
/// per PEFT variant plus the tunable-parameter count — adapter units are
/// the ZO-perturbed space, so the count also lands in `BENCH_native.json`
/// (the `steps[].tunable_params` field written by `cargo bench`).
fn peft_cost_profile(
    spec: &ModelSpec,
    variants: &[(String, Method, PeftMode, usize, f64, f64)],
    costs: &[MethodCost],
) -> Result<String> {
    let header = ["Method", "ms/step", "non-forward", "tunable params"];
    let total = spec.param_count();
    let mut rows = Vec::new();
    for ((label, _, peft, ..), cost) in variants.iter().zip(costs) {
        if cost.ms_per_step.is_empty() {
            continue;
        }
        let unit = match peft {
            PeftMode::Full => 0,
            PeftMode::Lora => crate::peft::lora_unit_len(spec.d_model),
            PeftMode::Prefix => crate::peft::prefix_unit_len(spec.d_model),
        };
        let tunable = spec.n_layers * unit;
        rows.push(vec![
            label.clone(),
            format!("{:.1}", crate::stats::mean(&cost.ms_per_step)),
            format!("{:.0}%", 100.0 * crate::stats::mean(&cost.non_forward)),
            format!("{tunable} ({:.2}% of {total})", 100.0 * tunable as f64 / total as f64),
        ]);
    }
    let mut out =
        String::from("PEFT step cost (adapter units are the whole ZO-perturbed space)\n");
    out.push_str(&render_table(&header, &rows));
    Ok(out)
}

/// Table 5: the hyper-parameter grids, as config presets.
pub fn table5() -> Result<String> {
    let mut out = String::from("Table 5 — hyper-parameter grids (testbed-scaled)\n\n");
    for (name, params) in grids() {
        writeln!(out, "{name}:")?;
        for (key, values) in params {
            writeln!(out, "  {key}: {values:?}")?;
        }
    }
    out.push_str("\nbatch size = manifest.train_batch; ZO runs use constant LR, 75% sparsity\n");
    out.push_str("(LoRA: 50%), mu per family above; FT uses Adam. See config::grids().\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_renders() {
        let t = table5().unwrap();
        for k in ["lezo", "mezo-lora", "ft"] {
            assert!(t.contains(k), "{k} missing");
        }
    }

    #[test]
    fn ft_cost_profile_renders_and_skips_no_step_methods() {
        let spec = ModelSpec::preset("opt-nano").unwrap();
        let methods = [Method::ZeroShot, Method::Ft, Method::Mezo];
        let costs = vec![
            MethodCost::default(),
            MethodCost {
                ms_per_step: vec![10.0, 14.0],
                non_forward: vec![0.35, 0.45],
                fo_state_bytes: 1_500_000,
            },
            MethodCost {
                ms_per_step: vec![2.0],
                non_forward: vec![0.6],
                fo_state_bytes: 0,
            },
        ];
        let t = ft_cost_profile(&spec, &methods, &costs).unwrap();
        assert!(t.contains("ft"), "{t}");
        assert!(t.contains("12.0"), "mean ms/step: {t}");
        assert!(t.contains("1.5 MB"), "measured Adam state: {t}");
        assert!(t.contains("MemoryModel"), "{t}");
        assert!(!t.contains("zero-shot"), "no-step methods are skipped: {t}");
    }

    #[test]
    fn peft_cost_profile_lists_tunable_param_counts() {
        let spec = ModelSpec::preset("opt-nano").unwrap();
        let variants: Vec<(String, Method, PeftMode, usize, f64, f64)> = vec![
            ("MeZO (LoRA)".into(), Method::Mezo, PeftMode::Lora, 0, 1e-3, 1e-2),
            ("MeZO (prefix)".into(), Method::Mezo, PeftMode::Prefix, 0, 1e-3, 1e-1),
        ];
        let costs = vec![
            MethodCost { ms_per_step: vec![3.0], non_forward: vec![0.2], fo_state_bytes: 0 },
            MethodCost { ms_per_step: vec![4.0], non_forward: vec![0.3], fo_state_bytes: 0 },
        ];
        let t = peft_cost_profile(&spec, &variants, &costs).unwrap();
        let lora = spec.n_layers * crate::peft::lora_unit_len(spec.d_model);
        let prefix = spec.n_layers * crate::peft::prefix_unit_len(spec.d_model);
        assert!(t.contains(&lora.to_string()), "{t}");
        assert!(t.contains(&prefix.to_string()), "{t}");
        assert!(t.contains("MeZO (LoRA)"), "{t}");
        assert!(t.contains("non-forward"), "{t}");
    }

    #[test]
    fn zo_variant_rows_render_targets_and_state() {
        use crate::coordinator::metrics::StageTimes;
        use crate::coordinator::trainer::EvalPoint;
        use crate::runtime::backend::Precision;
        let report = |final_metric: f64, reach_step: Option<u64>, zo_state_bytes: usize| {
            let mut history =
                vec![EvalPoint { step: 0, train_secs: 0.0, metric: 0.5, train_loss: 0.0 }];
            if let Some(s) = reach_step {
                history.push(EvalPoint {
                    step: s,
                    train_secs: 1.0,
                    metric: final_metric,
                    train_loss: 0.0,
                });
            }
            TrainReport {
                task: "sst2".into(),
                method: Method::Mezo,
                backend: "native",
                precision: Precision::F32,
                metric_kind: "acc",
                final_metric,
                best_metric: final_metric,
                history,
                losses: vec![],
                stage_times: StageTimes::default(),
                train_secs: 1.0,
                active_param_fraction: 1.0,
                mean_input_len: 20.0,
                fo_state_bytes: 0,
                zo_state_bytes,
                zo_opt: ZoOptKind::Sgd,
            }
        };
        let results = vec![
            (ZoOptKind::Sgd, vec![report(0.8, Some(900), 0)]),
            // best variant: target = 0.9 * 0.9 = 0.81, reached at step 400
            (ZoOptKind::Adam, vec![report(0.9, Some(400), 1234)]),
            (ZoOptKind::SignSgd, vec![report(0.6, None, 0)]),
        ];
        let t = render_zo_variants(&results).unwrap();
        assert!(t.contains("zo-adam"), "{t}");
        assert!(t.contains("400 (1/1)"), "adam reaches the target: {t}");
        assert!(t.contains("1234 B"), "replay state is shown: {t}");
        assert!(t.contains("81.0%"), "target is 90% of best final: {t}");
        // sgd's final 0.8 < 0.81 target, sign never reaches it
        assert!(t.contains('-'), "{t}");
    }

    #[test]
    fn method_cfg_applies_paper_conventions() {
        let base = RunConfig::default();
        let lezo = method_cfg(&base, Method::Lezo, 8);
        assert_eq!(lezo.drop_layers, 6);
        assert!(lezo.lr > base.lr);
        let mezo = method_cfg(&base, Method::Mezo, 8);
        assert_eq!(mezo.drop_layers, 0);
        assert_eq!(mezo.lr, base.lr);
    }
}

//! Lemma-3 convergence-rate check (the paper's theory section): on a
//! smooth synthetic objective, the number of SPSA steps to reach a fixed
//! loss should scale roughly linearly with the *effective* dimension
//! `rho * d` — shrinking the per-step active set speeds convergence per
//! step count measured in equally-sized problems.
//!
//! This bench runs entirely in Rust (no XLA): the point is the optimizer
//! mathematics, not the model substrate.

use crate::rng::Rng;
use crate::util::render_table;
use anyhow::Result;
use std::fmt::Write as _;

/// A d-dimensional quadratic split into `n_layers` equal "layers":
/// L(theta) = 0.5 * ||theta - theta*||^2.
struct Quadratic {
    opt: Vec<f64>,
}

impl Quadratic {
    fn new(d: usize, rng: &mut Rng) -> Quadratic {
        Quadratic { opt: (0..d).map(|_| rng.gaussian()).collect() }
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        0.5 * theta.iter().zip(&self.opt).map(|(t, o)| (t - o) * (t - o)).sum::<f64>()
    }
}

/// LeZO-SGD on the quadratic: layer-wise sparse SPSA with the same
/// seed-regeneration trick as the real engine. Returns steps to reach
/// `target_frac` of the initial loss (or `max_steps`).
fn lezo_steps_to_target(
    d: usize,
    n_layers: usize,
    drop: usize,
    lr: f64,
    mu: f64,
    target_frac: f64,
    max_steps: usize,
    seed: u64,
) -> usize {
    let mut rng = Rng::new(seed);
    let q = Quadratic::new(d, &mut rng);
    let mut theta = vec![0.0f64; d];
    let layer_len = d / n_layers;
    let l0 = q.loss(&theta);
    let target = target_frac * l0;
    let mut sel_rng = Rng::new(seed ^ 0x5E1E);
    for step in 0..max_steps {
        // pick active layers
        let kept = sel_rng.sample_indices(n_layers, n_layers - drop);
        // regenerate z per active layer from a per-(step, layer) seed
        let z_for = |layer: usize| -> Vec<f64> {
            let mut zr = Rng::new(crate::rng::derive(seed, step as u64, layer as u64));
            (0..layer_len).map(|_| zr.gaussian()).collect()
        };
        // perturb +mu
        let mut lp_theta = theta.clone();
        let mut lm_theta = theta.clone();
        for &l in &kept {
            let z = z_for(l);
            for i in 0..layer_len {
                lp_theta[l * layer_len + i] += mu * z[i];
                lm_theta[l * layer_len + i] -= mu * z[i];
            }
        }
        let g = (q.loss(&lp_theta) - q.loss(&lm_theta)) / (2.0 * mu);
        for &l in &kept {
            let z = z_for(l);
            for i in 0..layer_len {
                theta[l * layer_len + i] -= lr * g * z[i];
            }
        }
        if q.loss(&theta) <= target {
            return step + 1;
        }
    }
    max_steps
}

/// The bench: sweep rho over a fixed-d quadratic with the lemma's own
/// learning-rate schedule eta = 1/(4(rho d + 4) L). Lemma 3 bounds
/// T = O(rho d L / sigma^2) — the *step* count of sparse SPSA is no worse
/// than dense (empirically they tie on an isotropic quadratic: the larger
/// per-active-dim learning rate exactly offsets touching fewer dims), while
/// the *work* per step scales with rho. The reproduced shape is therefore
/// flops-to-target ~ rho, which is exactly the paper's computation-saving
/// claim, plus step-parity, which is the convergence-is-not-hurt claim.
pub fn lemma3(overrides: &[String]) -> Result<String> {
    // knobs via overrides: d=..., layers=..., seeds=...
    let mut d = 4096usize;
    let mut n_layers = 16usize;
    let mut n_seeds = 5usize;
    for ov in overrides {
        if let Some((k, v)) = ov.split_once('=') {
            match k {
                "d" => d = v.parse()?,
                "layers" => n_layers = v.parse()?,
                "seeds" => n_seeds = v.parse()?,
                _ => {} // benches share override namespaces; ignore others
            }
        }
    }
    let mu = 1e-4;
    let target = 0.5;
    let max_steps = 200_000;
    let mut out = String::from("Lemma 3 — steps-to-half-loss vs effective dimension rho*d\n");
    writeln!(out, "quadratic d={d}, {n_layers} layers, {n_seeds} seeds, lr=1/(4(rho*d+4))\n")?;
    let mut rows = Vec::new();
    let mut dense_mean = 0.0f64;
    let mut dense_work = 0.0f64;
    for drop in [0usize, n_layers / 4, n_layers / 2, 3 * n_layers / 4] {
        let rho = (n_layers - drop) as f64 / n_layers as f64;
        let rho_d = rho * d as f64;
        // Lemma-3 learning rate: eta = 1 / (4 (rho d + 4) L), L = 1 here
        let lr = 1.0 / (4.0 * (rho_d + 4.0));
        let steps: Vec<f64> = (0..n_seeds)
            .map(|s| {
                lezo_steps_to_target(d, n_layers, drop, lr, mu, target, max_steps, 1000 + s as u64)
                    as f64
            })
            .collect();
        let mean = crate::stats::mean(&steps);
        let work = mean * rho_d; // perturb/update flops-to-target (arb. units)
        if drop == 0 {
            dense_mean = mean;
            dense_work = work;
        }
        rows.push(vec![
            format!("{drop}/{n_layers}"),
            format!("{rho:.2}"),
            format!("{:.0}", rho_d),
            format!("{mean:.0}"),
            format!("{:.2}", mean / dense_mean.max(1.0)),
            format!("{:.2}", work / dense_work.max(1.0)),
            format!("{rho:.2}"),
        ]);
    }
    out.push_str(&render_table(
        &["drop", "rho", "rho*d", "steps", "T/T_dense", "work/work_dense", "predicted work ~rho"],
        &rows,
    ));
    out.push_str(
        "\nLemma 3: T = O(rho d L / sigma^2) -> step count does not degrade under\n\
         sparsity (measured T/T_dense ~= 1), so perturb/update work-to-target\n\
         scales like rho: full-parameter coverage at a fraction of the compute.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_loss_zero_at_optimum() {
        let mut rng = Rng::new(1);
        let q = Quadratic::new(16, &mut rng);
        assert!(q.loss(&q.opt) < 1e-12);
        assert!(q.loss(&vec![0.0; 16]) > 0.0);
    }

    #[test]
    fn spsa_converges_on_quadratic() {
        let steps = lezo_steps_to_target(256, 8, 0, 1.0 / (4.0 * 260.0), 1e-4, 0.5, 100_000, 7);
        assert!(steps < 100_000, "dense SPSA must reach half loss");
    }

    #[test]
    fn sparse_step_parity_and_cheaper_work() {
        // Lemma 3's shape on an isotropic quadratic: with the lemma's own lr
        // schedule, sparse SPSA needs about as many *steps* as dense (the
        // larger per-dim lr offsets touching fewer dims), so the perturb/
        // update *work* to target scales like rho.
        let avg = |drop: usize| -> f64 {
            let d = 1024;
            let rho_d = ((8 - drop) as f64 / 8.0) * d as f64;
            let lr = 1.0 / (4.0 * (rho_d + 4.0));
            (0..3)
                .map(|s| {
                    lezo_steps_to_target(d, 8, drop, lr, 1e-4, 0.5, 200_000, 100 + s) as f64
                })
                .sum::<f64>()
                / 3.0
        };
        let dense = avg(0);
        let sparse = avg(6); // rho = 0.25
        let ratio = sparse / dense;
        assert!(
            (0.5..2.0).contains(&ratio),
            "step counts should be comparable: sparse {sparse} vs dense {dense}"
        );
        let work_ratio = (sparse * 0.25) / dense;
        assert!(work_ratio < 0.6, "work-to-target must shrink ~rho: {work_ratio}");
    }
}

//! Bench harness (DESIGN.md S15): regenerates every table and figure of the
//! paper's evaluation on this testbed. Each entry prints the paper-shaped
//! rows/series and writes them under `bench_results/`.

pub mod ablation;
pub mod figures;
pub mod lemma;
pub mod tables;

use crate::config::RunConfig;
use crate::coordinator::{TrainReport, Trainer};
use crate::model::ModelSpec;
use crate::stats;
use anyhow::{bail, Result};
use std::io::Write;

/// All bench ids, in paper order, plus the design-choice ablations.
pub const ALL_BENCHES: [&str; 13] = [
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6",
    "table1", "table2", "table3", "table4", "table5",
    "lemma3", "ablation",
];

/// Run one bench by id. `overrides` are config `key=value`s applied to every
/// run in the sweep (e.g. `steps=200` for a quick pass).
pub fn run_bench(id: &str, overrides: &[String]) -> Result<()> {
    let out = match id {
        "fig1" => figures::fig1(overrides)?,
        "fig2" => figures::fig2(overrides)?,
        "fig3" => figures::fig3(overrides)?,
        "fig4" => figures::fig4(overrides)?,
        "fig5" => figures::fig5(overrides)?,
        "fig6" => figures::fig6(overrides)?,
        "table1" => tables::table1(overrides)?,
        "table2" => tables::table2(overrides)?,
        "table3" => tables::table3(overrides)?,
        "table4" => tables::table4(overrides)?,
        "table5" => tables::table5()?,
        "lemma3" => lemma::lemma3(overrides)?,
        "ablation" => ablation::selector_policies(overrides)?,
        "all" => {
            for b in ALL_BENCHES {
                run_bench(b, overrides)?;
            }
            return Ok(());
        }
        _ => bail!("unknown bench '{id}' (one of {ALL_BENCHES:?} or 'all')"),
    };
    println!("{out}");
    save(id, &out)?;
    Ok(())
}

/// Persist bench output under bench_results/<id>.txt.
pub fn save(id: &str, text: &str) -> Result<()> {
    let dir = std::path::Path::new("bench_results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{id}.txt"));
    let mut f = std::fs::File::create(&path)?;
    f.write_all(text.as_bytes())?;
    crate::info!("wrote {}", path.display());
    Ok(())
}

/// Run a config across seeds; returns per-seed reports.
pub fn run_seeds(base: &RunConfig, seeds: &[u64]) -> Result<Vec<TrainReport>> {
    seeds
        .iter()
        .map(|&s| {
            let mut cfg = base.clone();
            cfg.seed = s;
            Trainer::new(cfg).run()
        })
        .collect()
}

/// `mean±std` of the percentage metric across seed reports (best-checkpoint
/// selection, as in the paper).
pub fn agg_pct(reports: &[TrainReport]) -> (f64, f64) {
    let vals: Vec<f64> = reports.iter().map(|r| 100.0 * r.best_metric).collect();
    (stats::mean(&vals), stats::std(&vals))
}

pub fn fmt_pm(mean: f64, std: f64) -> String {
    if std > 0.0 {
        format!("{mean:.1}±{std:.1}")
    } else {
        format!("{mean:.1}")
    }
}

/// Default bench config: the Table-1 testbed (`opt-small` standing in for
/// OPT-13B) with a budget small enough for CPU sweeps. Overrides can scale
/// it up (`steps=2000 eval_every=500 ...`).
pub fn bench_config(overrides: &[String]) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    cfg.model = "opt-small".into();
    cfg.steps = 1500;
    cfg.eval_every = 300;
    cfg.eval_examples = 100;
    cfg.train_examples = 512;
    cfg.lr = 1e-4; // MeZO base LR at this scale (grid-searched; see Table 5)
    cfg.mu = 1e-3;
    cfg.apply_overrides(overrides)?;
    Ok(cfg)
}

/// Architecture spec for a bench config: the artifact manifest when one
/// exists, else the native preset — so every bench also runs artifact-free
/// on the native backend. One shared rule with the trainer and CLI
/// (`runtime::backend::resolve_model`).
pub fn model_spec_for(cfg: &RunConfig) -> Result<ModelSpec> {
    let dir = std::path::PathBuf::from(cfg.artifact_dir());
    Ok(crate::runtime::backend::resolve_model(&cfg.model, &dir)?.0)
}

/// The paper's sparsity preset: 75% of blocks dropped.
pub fn paper_drop(n_layers: usize) -> usize {
    (3 * n_layers) / 4
}

/// Per-model LR defaults mirroring Table 5's "LeZO needs a larger LR" rule.
pub fn lezo_lr(mezo_lr: f64) -> f64 {
    2.5 * mezo_lr
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_pm_shapes() {
        assert_eq!(fmt_pm(91.23, 0.456), "91.2±0.5");
        assert_eq!(fmt_pm(88.0, 0.0), "88.0");
    }

    #[test]
    fn bench_ids_dispatch() {
        assert!(run_bench("nope", &[]).is_err());
    }

    #[test]
    fn paper_drop_matches_tables() {
        assert_eq!(paper_drop(40), 30); // OPT-13B: 30 of 40
        assert_eq!(paper_drop(24), 18); // Table 2 caption: 18 of 24
        assert_eq!(paper_drop(48), 36); // OPT-30B: 36 of 48
        assert_eq!(paper_drop(8), 6); // opt-small here
    }

    #[test]
    fn bench_config_overrides() {
        let cfg = bench_config(&["steps=10".into(), "model=opt-micro".into()]).unwrap();
        assert_eq!(cfg.steps, 10);
        assert_eq!(cfg.model, "opt-micro");
    }
}

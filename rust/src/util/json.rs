//! Minimal JSON parser for artifact manifests.
//!
//! The offline vendor set has no serde, so we parse the small, trusted
//! manifest.json files emitted by python/compile/aot.py ourselves. Supports
//! the full JSON value grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough for any manifest we will ever emit.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: required typed accessors with contextual errors.
    pub fn req_str(&self, key: &str) -> anyhow::Result<String> {
        self.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("manifest missing string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing numeric field '{key}'"))
    }

    pub fn req_usize_arr(&self, key: &str) -> anyhow::Result<Vec<usize>> {
        let arr = self
            .get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing array field '{key}'"))?;
        arr.iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("non-numeric in '{key}'")))
            .collect()
    }

    pub fn req_str_arr(&self, key: &str) -> anyhow::Result<Vec<String>> {
        let arr = self
            .get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing array field '{key}'"))?;
        arr.iter()
            .map(|v| {
                v.as_str().map(str::to_string).ok_or_else(|| anyhow::anyhow!("non-string in '{key}'"))
            })
            .collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn req_accessors() {
        let j = Json::parse(r#"{"n": "x", "k": 3, "a": [1,2], "s": ["p","q"]}"#).unwrap();
        assert_eq!(j.req_str("n").unwrap(), "x");
        assert_eq!(j.req_usize("k").unwrap(), 3);
        assert_eq!(j.req_usize_arr("a").unwrap(), vec![1, 2]);
        assert_eq!(j.req_str_arr("s").unwrap(), vec!["p", "q"]);
        assert!(j.req_str("missing").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
 "name": "opt-micro", "vocab": 512, "unit_lens": [49984, 36864],
 "files": {"zo_axpy_128": "zo_axpy_128.hlo.txt"}, "use_pallas_forward": true}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req_str("name").unwrap(), "opt-micro");
        assert_eq!(j.get("use_pallas_forward").unwrap().as_bool(), Some(true));
        assert_eq!(
            j.get("files").unwrap().get("zo_axpy_128").unwrap().as_str(),
            Some("zo_axpy_128.hlo.txt")
        );
    }
}

//! Shared utilities: JSON parsing, logging, timing.

pub mod json;

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LOG_LEVEL: AtomicU8 = AtomicU8::new(1); // 0 = quiet, 1 = info, 2 = debug

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(1) { eprintln!("[lezo] {}", format!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) { eprintln!("[lezo:debug] {}", format!($($arg)*)); }
    };
}

/// Wall-clock stopwatch returning seconds.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let t = self.secs();
        self.start = Instant::now();
        t
    }
}

/// Run `f`, retrying up to `attempts` times with doubling backoff starting
/// at `base_ms`. Used around host<->device buffer transfers (PJRT uploads /
/// downloads), which on real accelerators can fail transiently; bounded, so a
/// persistent fault still surfaces as an error naming the operation and every
/// attempt's failure.
pub fn retry_with_backoff<T>(
    label: &str,
    attempts: u32,
    base_ms: u64,
    f: impl FnMut() -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    retry_with_backoff_deadline(label, attempts, base_ms, None, f)
}

/// [`retry_with_backoff`] with an overall deadline: retries stop once
/// `deadline` passes, even if attempts remain, and a backoff sleep never
/// overshoots it. `None` behaves exactly like the plain variant (attempt
/// count is the only bound). Used by the socket transport so a wedged worker
/// cannot stall the coordinator beyond its per-request budget, and by PJRT
/// transfers so transient-retry loops are wall-clock bounded too.
pub fn retry_with_backoff_deadline<T>(
    label: &str,
    attempts: u32,
    base_ms: u64,
    deadline: Option<Instant>,
    mut f: impl FnMut() -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    debug_assert!(attempts >= 1);
    let mut delay_ms = base_ms;
    let mut last_err = None;
    let mut tried = 0u32;
    for attempt in 1..=attempts.max(1) {
        tried = attempt;
        match f() {
            Ok(v) => return Ok(v),
            Err(e) => {
                let out_of_time = deadline.is_some_and(|d| Instant::now() >= d);
                if attempt < attempts && !out_of_time {
                    let mut sleep_ms = delay_ms;
                    if let Some(d) = deadline {
                        let left = d.saturating_duration_since(Instant::now()).as_millis() as u64;
                        sleep_ms = sleep_ms.min(left);
                    }
                    crate::info!(
                        "{label}: attempt {attempt}/{attempts} failed ({e:#}); retrying in {sleep_ms}ms"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(sleep_ms));
                    delay_ms = delay_ms.saturating_mul(2);
                    last_err = Some(e);
                } else {
                    last_err = Some(if out_of_time && attempt < attempts {
                        e.context(format!("{label}: deadline exceeded after {attempt} attempts"))
                    } else {
                        e
                    });
                    if out_of_time {
                        break;
                    }
                }
            }
        }
    }
    let e = last_err.expect("attempts >= 1 implies at least one error");
    Err(e.context(format!("{label}: failed after {tried} attempts")))
}

/// Render an aligned text table (used by the bench harness to print the
/// paper's tables).
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push_str("  ");
            }
            line.push_str(&format!("{:width$}", c, width = widths[i]));
        }
        line.trim_end().to_string()
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }

    #[test]
    fn retry_succeeds_after_transient_failures() {
        let mut calls = 0;
        let v = retry_with_backoff("upload", 4, 0, || {
            calls += 1;
            if calls < 3 {
                anyhow::bail!("transient")
            }
            Ok(42)
        })
        .unwrap();
        assert_eq!(v, 42);
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_exhaustion_names_the_operation() {
        let mut calls = 0;
        let err = retry_with_backoff::<()>("download buf 3", 3, 0, || {
            calls += 1;
            anyhow::bail!("device gone")
        })
        .unwrap_err();
        assert_eq!(calls, 3);
        let msg = format!("{err:#}");
        assert!(msg.contains("download buf 3") && msg.contains("3 attempts"), "{msg}");
        assert!(msg.contains("device gone"), "{msg}");
    }

    #[test]
    fn retry_deadline_stops_early() {
        let mut calls = 0;
        let deadline = Some(Instant::now()); // already expired
        let err = retry_with_backoff_deadline::<()>("poke worker", 10, 1000, deadline, || {
            calls += 1;
            anyhow::bail!("no route")
        })
        .unwrap_err();
        // One attempt runs, then the deadline check halts the loop without
        // sleeping through the remaining 9 backoffs.
        assert_eq!(calls, 1);
        let msg = format!("{err:#}");
        assert!(msg.contains("poke worker") && msg.contains("deadline exceeded"), "{msg}");
        assert!(msg.contains("no route"), "{msg}");
    }

    #[test]
    fn retry_deadline_none_matches_plain_variant() {
        let mut calls = 0;
        let v = retry_with_backoff_deadline("upload", 4, 0, None, || {
            calls += 1;
            if calls < 2 {
                anyhow::bail!("transient")
            }
            Ok(7)
        })
        .unwrap();
        assert_eq!((v, calls), (7, 2));
    }

    #[test]
    fn retry_deadline_caps_backoff_sleep() {
        let deadline = Some(Instant::now() + std::time::Duration::from_millis(30));
        let sw = Stopwatch::start();
        let mut calls = 0;
        let _ = retry_with_backoff_deadline::<()>("slow op", 3, 10_000, deadline, || {
            calls += 1;
            anyhow::bail!("still down")
        });
        // Without the cap the first backoff alone would sleep 10s; with it
        // the whole loop must finish shortly after the 30ms deadline.
        assert!(sw.secs() < 5.0, "took {}s", sw.secs());
        assert!(calls >= 1);
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["task", "acc"],
            &[vec!["sst2".into(), "91.2±0.3".into()], vec!["boolq-like".into(), "65.0".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("task"));
        assert!(lines[2].starts_with("sst2"));
        // columns aligned: "acc" column starts at same offset in all rows
        let col = lines[0].find("acc").unwrap();
        assert_eq!(&lines[2][col..col + 2], "91");
    }
}

//! Shared utilities: JSON parsing, logging, timing.

pub mod json;

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LOG_LEVEL: AtomicU8 = AtomicU8::new(1); // 0 = quiet, 1 = info, 2 = debug

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(1) { eprintln!("[lezo] {}", format!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) { eprintln!("[lezo:debug] {}", format!($($arg)*)); }
    };
}

/// Wall-clock stopwatch returning seconds.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let t = self.secs();
        self.start = Instant::now();
        t
    }
}

/// Render an aligned text table (used by the bench harness to print the
/// paper's tables).
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push_str("  ");
            }
            line.push_str(&format!("{:width$}", c, width = widths[i]));
        }
        line.trim_end().to_string()
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["task", "acc"],
            &[vec!["sst2".into(), "91.2±0.3".into()], vec!["boolq-like".into(), "65.0".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("task"));
        assert!(lines[2].starts_with("sst2"));
        // columns aligned: "acc" column starts at same offset in all rows
        let col = lines[0].find("acc").unwrap();
        assert_eq!(&lines[2][col..col + 2], "91");
    }
}

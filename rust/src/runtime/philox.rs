//! Counter-based Philox-4x32-10 RNG + Box-Muller — the pure-Rust twin of
//! `python/compile/kernels/philox.py`.
//!
//! This is the numerical core of LeZO's memory trick: the perturbation
//! vector `z ~ N(0, I)` is *regenerated* from `(seed, element_index)`
//! instead of being stored, so perturb (+mu), flip (-2mu), restore (+mu)
//! and update (-eta*g) all see the identical `z` with zero extra memory.
//! The native backend runs this implementation directly; the PJRT backend
//! runs the Pallas kernel lowered from the Python twin. Both follow the
//! same integer semantics: the u32 Philox words are bit-identical across
//! implementations (pinned by the known-answer tests below), and the f32
//! Gaussian mapping agrees to float rounding (|diff| < 3e-5 observed).
//!
//! Reference: Salmon et al., "Parallel random numbers: as easy as 1, 2, 3"
//! (SC'11). Constants are the canonical Philox-4x32 constants.

/// Canonical Philox-4x32 round constants.
pub const PHILOX_M0: u32 = 0xD251_1F53;
pub const PHILOX_M1: u32 = 0xCD9E_8D57;
pub const PHILOX_W0: u32 = 0x9E37_79B9; // golden ratio
pub const PHILOX_W1: u32 = 0xBB67_AE85; // sqrt(3) - 1

/// Key word 1 is a domain separator (b"LeZO") so the perturbation stream
/// can never collide with any other Philox user keyed on the same seed.
pub const LEZO_KEY1: u32 = 0x4C65_5A4F;

pub const ROUNDS: usize = 10;

/// Full 32x32 -> 64 bit product as (hi, lo) words.
#[inline(always)]
fn mulhilo32(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

/// Philox-4x32 block cipher over counter words c0..c3 with key (k0, k1).
#[inline]
pub fn philox4x32(counter: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    let [mut c0, mut c1, mut c2, mut c3] = counter;
    let [mut k0, mut k1] = key;
    for _ in 0..ROUNDS {
        let (hi0, lo0) = mulhilo32(PHILOX_M0, c0);
        let (hi1, lo1) = mulhilo32(PHILOX_M1, c2);
        c0 = hi1 ^ c1 ^ k0;
        c1 = lo1;
        c2 = hi0 ^ c3 ^ k1;
        c3 = lo0;
        k0 = k0.wrapping_add(PHILOX_W0);
        k1 = k1.wrapping_add(PHILOX_W1);
    }
    [c0, c1, c2, c3]
}

/// Map u32 bits -> f32 uniform in the *open* interval (0, 1).
///
/// Top 23 bits scaled by 2^-23 plus a 2^-24 offset: every value is exactly
/// representable in f32, max is 1 - 2^-24 < 1 and min is 2^-24 > 0, so
/// `ln(u)` stays finite. Bit-identical to the kernel's `uniform01`.
#[inline(always)]
pub fn uniform01(bits: u32) -> f32 {
    const TWO_NEG_23: f32 = 1.0 / (1u32 << 23) as f32;
    const TWO_NEG_24: f32 = 1.0 / (1u32 << 24) as f32;
    (bits >> 9) as f32 * TWO_NEG_23 + TWO_NEG_24
}

/// One standard normal per (r0, r1) pair of u32 words (cosine branch).
#[inline]
pub fn boxmuller(r0: u32, r1: u32) -> f32 {
    let u1 = uniform01(r0);
    let u2 = uniform01(r1);
    let radius = (-2.0f32 * u1.ln()).sqrt();
    let theta = 2.0f32 * std::f32::consts::PI * u2;
    radius * theta.cos()
}

/// `z[i] ~ N(0, 1)`, a pure function of `(seed, i)`.
///
/// `idx` is the global element index of the parameter inside its layer
/// unit; `seed` is the per-(step, layer) seed chosen by the coordinator.
/// Counter = (idx, 0, 0, 0), key = (seed, LEZO_KEY1) — identical to
/// `gauss_from_index` in the Pallas kernel.
#[inline]
pub fn gauss_from_index(idx: u32, seed: u32) -> f32 {
    let [r0, r1, _, _] = philox4x32([idx, 0, 0, 0], [seed, LEZO_KEY1]);
    boxmuller(r0, r1)
}

/// Independent Philox blocks pipelined per [`fill_gauss`] loop iteration.
/// The lanes share no state (counter-based RNG), so the CPU can overlap
/// their multiply/xor chains — the scalar `philox4x32` serializes 10
/// dependent rounds, which leaves most issue slots empty.
pub const GAUSS_LANES: usize = 4;

/// `GAUSS_LANES` independent Philox-4x32 blocks over counters `c[lane]`
/// with one shared key schedule. Per lane this is bit-identical to
/// [`philox4x32`] — the rounds are interleaved across lanes purely for
/// instruction-level parallelism.
#[inline]
fn philox4x32_lanes(mut c: [[u32; 4]; GAUSS_LANES], key: [u32; 2]) -> [[u32; 4]; GAUSS_LANES] {
    let [mut k0, mut k1] = key;
    for _ in 0..ROUNDS {
        for lane in c.iter_mut() {
            let (hi0, lo0) = mulhilo32(PHILOX_M0, lane[0]);
            let (hi1, lo1) = mulhilo32(PHILOX_M1, lane[2]);
            *lane = [hi1 ^ lane[1] ^ k0, lo1, hi0 ^ lane[3] ^ k1, lo0];
        }
        k0 = k0.wrapping_add(PHILOX_W0);
        k1 = k1.wrapping_add(PHILOX_W1);
    }
    c
}

/// Fill `out[i] = gauss_from_index(start_idx + i, seed)` — element-for-
/// element identical to the scalar path (pinned by KATs), but pipelining
/// [`GAUSS_LANES`] independent Philox blocks per loop iteration. This is
/// the bulk entry point the native zo_axpy kernels stream through; index
/// arithmetic wraps like the scalar path (`idx` is a u32 counter word).
pub fn fill_gauss(seed: u32, start_idx: u32, out: &mut [f32]) {
    let key = [seed, LEZO_KEY1];
    let mut base = start_idx;
    let mut chunks = out.chunks_exact_mut(GAUSS_LANES);
    for chunk in &mut chunks {
        let counters = [
            [base, 0, 0, 0],
            [base.wrapping_add(1), 0, 0, 0],
            [base.wrapping_add(2), 0, 0, 0],
            [base.wrapping_add(3), 0, 0, 0],
        ];
        let r = philox4x32_lanes(counters, key);
        for (o, words) in chunk.iter_mut().zip(&r) {
            *o = boxmuller(words[0], words[1]);
        }
        base = base.wrapping_add(GAUSS_LANES as u32);
    }
    for (i, o) in chunks.into_remainder().iter_mut().enumerate() {
        *o = gauss_from_index(base.wrapping_add(i as u32), seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mulhilo_matches_u64_product() {
        for &(a, b) in &[
            (0u32, 0u32),
            (1, 1),
            (0xFFFF_FFFF, 0xFFFF_FFFF),
            (PHILOX_M0, 0x1234_5678),
            (PHILOX_M1, 0xDEAD_BEEF),
        ] {
            let (hi, lo) = mulhilo32(a, b);
            let p = (a as u64) * (b as u64);
            assert_eq!(lo as u64, p & 0xFFFF_FFFF);
            assert_eq!(hi as u64, p >> 32);
        }
    }

    #[test]
    fn philox_random123_known_vectors() {
        // Canonical vectors from the Random123 distribution (and pinned by
        // python/tests/test_philox.py).
        let ff = 0xFFFF_FFFFu32;
        assert_eq!(
            philox4x32([ff, ff, ff, ff], [ff, ff]),
            [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]
        );
        assert_eq!(
            philox4x32([0, 0, 0, 0], [0, 0]),
            [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]
        );
    }

    #[test]
    fn philox_matches_pallas_kernel_stream() {
        // Known-answer vectors generated from the repo's own Python kernel
        // (compile.kernels.philox.philox4x32) with key1 = LEZO_KEY1, i.e.
        // the exact counter/key layout the zo_axpy kernels use.
        let cases: [(u32, u32, [u32; 4]); 4] = [
            (0, 0, [0xDC55_1D05, 0xB1B0_0326, 0xFDAF_5693, 0x15B1_F4F9]),
            (1, 42, [0x8ED4_BE03, 0x20EC_A53E, 0x2308_A71B, 0xF4FD_A200]),
            (12345, 7, [0xE450_752A, 0x6E7B_E0D0, 0x31A2_0DD8, 0x8510_56EF]),
            (
                0xFFFF_FFFF,
                0xFFFF_FFFF,
                [0x4791_F463, 0xD04B_CF9A, 0xFFEB_905D, 0x4384_8387],
            ),
        ];
        for (c0, k0, want) in cases {
            assert_eq!(philox4x32([c0, 0, 0, 0], [k0, LEZO_KEY1]), want, "c0={c0} k0={k0}");
        }
    }

    #[test]
    fn gauss_matches_pallas_kernel_values() {
        // Known-answer values generated from the Python kernel:
        // gauss_from_index(arange(8), seed) for several seeds, plus large
        // indices. The integer stream is bit-identical; the f32 Box-Muller
        // (ln/cos) may differ by float-library rounding, hence the 3e-5
        // tolerance (observed diffs are ~1e-7).
        let kat: [(u32, [f32; 8]); 4] = [
            (
                0,
                [
                    -0.188496381, 0.148865700, 1.820809007, -1.438824773,
                    -1.344397187, -0.957285702, 1.930997729, -0.818839848,
                ],
            ),
            (
                1,
                [
                    0.479184955, 0.896658242, -0.718323648, -0.562424064,
                    0.126851946, -0.854853392, 1.299600720, -0.639966130,
                ],
            ),
            (
                42,
                [
                    3.577432871, 0.746355414, 0.515587270, 0.478834301,
                    0.710283756, -0.230724618, -0.662807763, -2.121771574,
                ],
            ),
            (
                2_147_483_647,
                [
                    -0.649245739, -1.413566113, -0.022017676, -0.300866276,
                    -0.902329266, 0.612480938, 0.339282870, -0.033580218,
                ],
            ),
        ];
        for (seed, want) in kat {
            for (i, &w) in want.iter().enumerate() {
                let got = gauss_from_index(i as u32, seed);
                assert!((got - w).abs() < 3e-5, "seed={seed} idx={i}: {got} vs {w}");
            }
        }
        // large / wrap-around indices
        for (idx, want) in [
            (1_000_000u32, -0.756159604f32),
            (123_456_789, -0.523046255),
            (4_294_967_295, -0.716007948),
        ] {
            let got = gauss_from_index(idx, 7);
            assert!((got - want).abs() < 3e-5, "idx={idx}: {got} vs {want}");
        }
    }

    #[test]
    fn uniform01_open_interval_and_known_values() {
        for bits in [0u32, 1, 511, 512, u32::MAX, 1 << 31] {
            let u = uniform01(bits);
            assert!(u > 0.0 && u < 1.0, "bits={bits}: {u}");
        }
        // exact values from the Python kernel
        assert_eq!(uniform01(0), 5.960_464_5e-8);
        assert_eq!(uniform01(511), 5.960_464_5e-8); // low 9 bits dropped
        assert_eq!(uniform01(512), 1.788_139_3e-7);
        assert_eq!(uniform01(u32::MAX), 0.999_999_94);
        assert_eq!(uniform01(1 << 31), 0.500_000_06);
    }

    #[test]
    fn same_seed_index_regenerates_identically_across_phases() {
        // The whole ZO schedule relies on this: four separate "phases"
        // re-deriving z from the same (seed, idx) must agree bit-for-bit.
        for seed in [0u32, 3, 0x7FFF_FFFF] {
            for idx in [0u32, 1, 999, 1 << 20] {
                let a = gauss_from_index(idx, seed);
                let b = gauss_from_index(idx, seed);
                let c = gauss_from_index(idx, seed);
                assert_eq!(a.to_bits(), b.to_bits());
                assert_eq!(a.to_bits(), c.to_bits());
            }
        }
    }

    #[test]
    fn streams_differ_across_seeds_and_indices() {
        let a: Vec<f32> = (0..256).map(|i| gauss_from_index(i, 1)).collect();
        let b: Vec<f32> = (0..256).map(|i| gauss_from_index(i, 2)).collect();
        let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max_diff > 0.1, "distinct seeds must give distinct streams");
    }

    #[test]
    fn gauss_moments_are_standard_normal() {
        let n = 100_000u32;
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for i in 0..n {
            let z = gauss_from_index(i, 12345) as f64;
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn domain_separator_is_lezo() {
        assert_eq!(LEZO_KEY1.to_be_bytes(), *b"LeZO");
    }

    #[test]
    fn fill_gauss_matches_scalar_stream_bit_for_bit() {
        // The multi-lane fill must reproduce gauss_from_index element for
        // element — including across the GAUSS_LANES boundary (lengths that
        // are not multiples of the lane count) and at u32 counter wraps.
        for &start in &[0u32, 1, 3, 5, 1_000_000, u32::MAX - 5] {
            for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 63, 64, 65, 1000] {
                let mut out = vec![0.0f32; len];
                fill_gauss(7, start, &mut out);
                for (i, &got) in out.iter().enumerate() {
                    let want = gauss_from_index(start.wrapping_add(i as u32), 7);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "start={start} len={len} i={i}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn fill_gauss_seed_sensitivity() {
        let mut a = vec![0.0f32; 128];
        let mut b = vec![0.0f32; 128];
        fill_gauss(1, 0, &mut a);
        fill_gauss(2, 0, &mut b);
        assert!(a.iter().zip(&b).any(|(x, y)| x != y));
    }
}

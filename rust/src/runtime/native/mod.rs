//! NativeBackend: the pure-Rust CPU implementation of [`Backend`].
//!
//! Buffers are host [`NativeBuf`]s — an authoritative f32 master plus an
//! optional cached bf16 shadow; the ZO kernels regenerate the perturbation
//! stream with the in-crate Philox port ([`crate::runtime::philox`],
//! bit-compatible with the Pallas kernel's integer stream); the forward
//! families run the blocked, thread-parallel kernels in [`kernels`] with a
//! streaming (fused) LM head, against the naive dense reference kept in
//! [`forward`]; the PEFT families (LoRA / prefix, the paper's Table 4)
//! fold per-block adapter units into the same kernels, so
//! `supports_peft() == true` for every mode; and the first-order substrate
//! (`method=ft`, `pretrain`) runs on the reference backward pass in
//! [`backward`], so `supports_fo() == true` with zero artifacts.
//! Everything is derived from a [`ModelSpec`] preset — no AOT artifacts,
//! no PJRT plugin, no Python.
//!
//! Hot-path structure (this is the substrate the bench harness measures):
//!
//! - [`parallel`] — scoped worker threads with *fixed* chunk partitioning;
//!   results are bit-identical at any `threads` / `LEZO_THREADS` setting.
//! - [`kernels`] — in-place ZO sweeps over the multi-lane Philox fill,
//!   cache-blocked matmuls, (row, head)-parallel attention, the reusable
//!   [`kernels::ForwardScratch`] arena, and the fused LM head that never
//!   materializes the `rows*seq*vocab` logits tensor — each with a bf16
//!   twin for the reduced-precision path.
//! - [`bf16`] — software bfloat16 (u16 storage, round-to-nearest-even
//!   narrowing, exact widening) behind the `precision=bf16` forward path.
//! - [`quant`] — absmax block quantization (per-block f32 scale + packed
//!   i8/i4 codes) behind the `precision=int8|int4` forward paths; every
//!   quantized kernel is pinned *bitwise* to its f32 twin run on the
//!   dequantized weights.
//! - [`simd`] — runtime-dispatched AVX2 inner loops for the blocked
//!   matmuls, the fused LM head dot products, and the i8 decode, each
//!   bit-identical to its public scalar fallback (no FMA, fixed lane
//!   structure).
//! - [`forward`] — the forward families (f32, bf16, quant) plus the dense
//!   reference (`forward_logits` / `position_xent`) the fused paths are
//!   tested against.
//! - [`backward`] — the recording forward + full backward for FO-Adam,
//!   gradient-checked against `forward_loss` by central finite differences
//!   (and cross-checked against the Python twin's `jax.value_and_grad`).
//!
//! # Precision (`precision = f32 | bf16 | int8 | int4`, env `LEZO_PRECISION`)
//!
//! Under [`Precision::Bf16`] the forward families execute over bf16
//! *shadows* of the unit buffers — half the *streamed* bytes in every
//! bandwidth-bound kernel (the regime the ZO literature measures at 13B+
//! scale); the shadows cost ~0.5x extra resident parameter memory next to
//! the f32 masters, which is the price of keeping the trainable state
//! exact. Under [`Precision::Int8`] / [`Precision::Int4`] the shadows are
//! instead absmax block-quantized ([`quant`]): per-64-element f32 scale
//! plus packed integer codes, ~0.27x / ~0.14x of the f32 streamed bytes.
//! Activations, PEFT adapters, and attention scores stay f32 in every
//! mode. The f32 masters stay
//! authoritative: every ZO sweep mutates f32 exactly as in f32 mode, so
//! the Philox regeneration invariant and the perturb/flip/restore bitwise
//! round-trip are untouched, and the trainable state is bit-identical
//! between precision modes given identical update coefficients. The
//! in-place axpy kernels *invalidate* the shadow of the unit they touch (a
//! flag store); the next forward re-casts (or re-quantizes) stale shadows
//! only — under LeZO's layer-wise sparsity the per-step re-quantization
//! cost is proportional to the active layer set, compounding the
//! structural saving. PEFT adapter units are skinny and stay f32 end to
//! end. Shadows never reach a checkpoint: save/resume serializes the f32
//! masters, and the first forward after resume rebuilds the shadows. A
//! non-finite master value is a hard error at quantization time, naming
//! the unit and flat index.

pub mod backward;
pub mod bf16;
pub mod forward;
pub mod kernels;
pub mod parallel;
pub mod quant;
pub mod simd;

use crate::data::batch::Batch;
use crate::model::spec::ModelSpec;
use crate::peft::PeftMode;
use crate::runtime::backend::{Backend, Precision};
use anyhow::{ensure, Context, Result};
use std::cell::{Ref, RefCell};

/// Seed for the deterministic native initialization (runs start identical
/// across machines; override with the `checkpoint` config key).
pub const NATIVE_INIT_SEED: u64 = 0;

/// One native unit buffer: the authoritative f32 master plus optional
/// cached reduced-precision *shadows* — bf16 bits for `precision=bf16`,
/// absmax block-quantized scales+codes for `precision=int8|int4`.
///
/// The master is what the ZO sweeps mutate — perturb/flip/restore/update
/// are f32 bit-for-bit regardless of the forward precision. A shadow is
/// a lazily (re-)built reduced copy: mutation through
/// [`NativeBuf::make_mut`] only marks it stale, and the next
/// reduced-precision forward rebuilds exactly the stale units. Reads go
/// through [`std::ops::Deref`] (`&buf[..]` is the master).
pub struct NativeBuf {
    data: Vec<f32>,
    shadow: RefCell<Option<Bf16Shadow>>,
    qshadow: RefCell<Option<QuantShadow>>,
}

struct Bf16Shadow {
    bits: Vec<u16>,
    fresh: bool,
}

/// Block-quantized shadow: per-[`quant::QBLOCK`] f32 scales plus packed
/// integer codes (one byte per code for int8, two codes per byte for
/// int4). `fresh` mirrors the bf16 flag; a mode switch (int8 <-> int4)
/// rebuilds from scratch.
struct QuantShadow {
    mode: quant::QuantMode,
    len: usize,
    scales: Vec<f32>,
    codes: Vec<u8>,
    fresh: bool,
}

impl QuantShadow {
    fn view(&self) -> quant::QuantView<'_> {
        quant::QuantView::new(self.mode, &self.scales, &self.codes, self.len)
    }
}

impl NativeBuf {
    fn new(data: Vec<f32>) -> NativeBuf {
        NativeBuf { data, shadow: RefCell::new(None), qshadow: RefCell::new(None) }
    }

    /// The f32 master.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access to the master. Conservatively marks every shadow
    /// stale (a flag store — the re-cast / re-quantization happens lazily
    /// at the next reduced-precision forward, and only for units that
    /// were actually touched).
    pub fn make_mut(&mut self) -> &mut [f32] {
        if let Some(s) = self.shadow.get_mut() {
            s.fresh = false;
        }
        if let Some(q) = self.qshadow.get_mut() {
            q.fresh = false;
        }
        &mut self.data
    }

    /// Cast (or re-cast) the shadow if it is missing or stale.
    fn refresh_shadow(&self) {
        let mut guard = self.shadow.borrow_mut();
        let sh = guard
            .get_or_insert_with(|| Bf16Shadow { bits: vec![0; self.data.len()], fresh: false });
        if sh.bits.len() != self.data.len() {
            sh.bits.resize(self.data.len(), 0);
            sh.fresh = false;
        }
        if !sh.fresh {
            bf16::cast_into(&self.data, &mut sh.bits);
            sh.fresh = true;
        }
    }

    /// Borrow the bf16 shadow, refreshing it first if stale.
    fn shadow(&self) -> Ref<'_, [u16]> {
        self.refresh_shadow();
        Ref::map(self.shadow.borrow(), |s| s.as_ref().unwrap().bits.as_slice())
    }

    /// A copy of the (refreshed) shadow bits — introspection for the
    /// shadow-invalidation tests.
    pub fn shadow_bits(&self) -> Vec<u16> {
        self.shadow().to_vec()
    }

    /// Whether the cached shadow is fresh w.r.t. the master (i.e. the next
    /// bf16 forward would *not* re-cast this unit). A missing shadow
    /// counts as stale.
    pub fn shadow_is_fresh(&self) -> bool {
        self.shadow.borrow().as_ref().map_or(false, |s| s.fresh)
    }

    /// Quantize (or re-quantize) the quant shadow if it is missing, stale,
    /// or was built for a different mode. Fallible: a non-finite master
    /// value is a hard error (the shadow stays stale).
    fn refresh_quant_shadow(&self, mode: quant::QuantMode) -> Result<()> {
        let n = self.data.len();
        let mut guard = self.qshadow.borrow_mut();
        let sh = guard.get_or_insert_with(|| QuantShadow {
            mode,
            len: n,
            scales: vec![0.0; n.div_ceil(quant::QBLOCK)],
            codes: vec![0; mode.code_bytes(n)],
            fresh: false,
        });
        if sh.mode != mode || sh.len != n {
            sh.mode = mode;
            sh.len = n;
            sh.scales.clear();
            sh.scales.resize(n.div_ceil(quant::QBLOCK), 0.0);
            sh.codes.clear();
            sh.codes.resize(mode.code_bytes(n), 0);
            sh.fresh = false;
        }
        if !sh.fresh {
            quant::quantize_into(mode, &self.data, &mut sh.scales, &mut sh.codes)?;
            sh.fresh = true;
        }
        Ok(())
    }

    /// Borrow the quant shadow for `mode`, refreshing it first if stale.
    fn quant_shadow(&self, mode: quant::QuantMode) -> Result<Ref<'_, QuantShadow>> {
        self.refresh_quant_shadow(mode)?;
        Ok(Ref::map(self.qshadow.borrow(), |s| s.as_ref().unwrap()))
    }

    /// A copy of the (refreshed) quant shadow's `(scales, codes)` —
    /// introspection for the shadow-invalidation tests.
    pub fn quant_shadow_parts(&self, mode: quant::QuantMode) -> Result<(Vec<f32>, Vec<u8>)> {
        let sh = self.quant_shadow(mode)?;
        Ok((sh.scales.clone(), sh.codes.clone()))
    }

    /// Whether the cached quant shadow is fresh w.r.t. the master (i.e.
    /// the next quantized forward would *not* re-quantize this unit). A
    /// missing shadow counts as stale.
    pub fn quant_shadow_is_fresh(&self) -> bool {
        self.qshadow.borrow().as_ref().map_or(false, |s| s.fresh)
    }
}

impl From<Vec<f32>> for NativeBuf {
    fn from(data: Vec<f32>) -> NativeBuf {
        NativeBuf::new(data)
    }
}

impl std::ops::Deref for NativeBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl PartialEq for NativeBuf {
    fn eq(&self, other: &NativeBuf) -> bool {
        self.data == other.data
    }
}

impl std::fmt::Debug for NativeBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NativeBuf(len {}, shadow fresh: {})", self.data.len(), self.shadow_is_fresh())
    }
}

pub struct NativeBackend {
    spec: ModelSpec,
    /// Forward-path precision ([`Precision::F32`] default; see the module
    /// docs for the bf16 shadow lifecycle).
    precision: Precision,
    /// Optional adopted artifact manifest: runs then start from its
    /// params_init.bin / pretrained.ckpt (same initial state as the PJRT
    /// backend) instead of the synthetic native init — so results don't
    /// silently diverge between build flavors.
    manifest: Option<crate::model::Manifest>,
    /// Optional checkpoint directory for manifest-less (fully hermetic)
    /// runs: when `<ckpt_dir>/pretrained.ckpt` exists — written by the
    /// native `pretrain` path — runs start from it, mirroring
    /// `checkpoint::resolve_initial`'s rule for artifact dirs.
    ckpt_dir: Option<std::path::PathBuf>,
    /// Reusable forward arena: q/k/v/ctx/ffn (f32 and bf16 halves) and the
    /// residual stream are allocated once and reused across every forward
    /// this backend runs.
    scratch: RefCell<kernels::ForwardScratch>,
}

impl NativeBackend {
    pub fn new(spec: ModelSpec) -> Result<NativeBackend> {
        spec.validate()?;
        Ok(NativeBackend {
            spec,
            precision: Precision::F32,
            manifest: None,
            ckpt_dir: None,
            scratch: RefCell::new(kernels::ForwardScratch::new()),
        })
    }

    pub fn preset(name: &str) -> Result<NativeBackend> {
        NativeBackend::new(ModelSpec::preset(name)?)
    }

    /// Select the forward-path precision (builder style; default f32).
    pub fn with_precision(mut self, precision: Precision) -> NativeBackend {
        self.precision = precision;
        self
    }

    /// Adopt exported initial parameters via an already-loaded manifest
    /// (see the `manifest` field). A manifest that does not match the
    /// spec's unit layout is a hard error, not a silent fallback.
    pub fn with_artifacts(mut self, manifest: crate::model::Manifest) -> Result<NativeBackend> {
        ensure!(
            manifest.unit_lens == self.spec.unit_lens(),
            "artifacts in {} do not match the {} layout",
            manifest.dir.display(),
            self.spec.name
        );
        self.manifest = Some(manifest);
        Ok(self)
    }

    /// Adopt a checkpoint directory (no manifest needed): runs start from
    /// `<dir>/pretrained.ckpt` when it exists — this is how a hermetic
    /// `lezo pretrain` -> `lezo train` pipeline hands over weights. A
    /// checkpoint that does not match the spec's layout is a hard error.
    pub fn with_checkpoint_dir(mut self, dir: &std::path::Path) -> NativeBackend {
        self.ckpt_dir = Some(dir.to_path_buf());
        self
    }

    /// The adopted artifact manifest, if any (pretraining starts from its
    /// params_init.bin instead of the synthetic native init).
    pub fn manifest(&self) -> Option<&crate::model::Manifest> {
        self.manifest.as_ref()
    }

    /// Load a checkpoint and validate it against this spec's unit layout —
    /// a mismatch is a hard error, never a silent fallback.
    fn load_checked(&self, path: &std::path::Path) -> Result<Vec<Vec<f32>>> {
        let ck = crate::model::checkpoint::load(path)?;
        let lens = self.spec.unit_lens();
        ensure!(
            ck.units.len() == lens.len()
                && ck.units.iter().zip(&lens).all(|(u, &l)| u.len() == l),
            "checkpoint {} does not match model {}",
            path.display(),
            self.spec.name
        );
        Ok(ck.units)
    }

    /// Validate the forward-argument count for `peft` and return the base
    /// unit count: `n_units()` model units, then — under PEFT — one
    /// adapter unit per transformer block, the same order the AOT'd PJRT
    /// executables take. Per-unit lengths are validated in the kernels.
    fn base_unit_count(&self, peft: PeftMode, n_given: usize) -> Result<usize> {
        let n_base = self.spec.n_units();
        let n_adapters = match peft {
            PeftMode::Full => 0,
            _ => self.spec.n_layers,
        };
        ensure!(
            n_given == n_base + n_adapters,
            "peft={peft}: native forward takes {} units ({n_base} model units + {n_adapters} \
             adapter units), got {n_given}",
            n_base + n_adapters,
        );
        Ok(n_base)
    }

    /// Split the forward-argument prefix into (base units, adapter units)
    /// as f32 master slices — the f32 forward path.
    #[allow(clippy::type_complexity)]
    fn split_units<'a>(
        &self,
        peft: PeftMode,
        units: &[&'a NativeBuf],
    ) -> Result<(Vec<&'a [f32]>, Vec<&'a [f32]>)> {
        let n_base = self.base_unit_count(peft, units.len())?;
        Ok((
            units[..n_base].iter().map(|u| u.data()).collect(),
            units[n_base..].iter().map(|u| u.data()).collect(),
        ))
    }

    /// bf16 twin of [`NativeBackend::split_units`]: base units as
    /// (refreshed) bf16 shadow borrows, adapter units as f32 masters —
    /// the one place the shadow-borrow protocol of a forward call lives.
    #[allow(clippy::type_complexity)]
    fn split_units_bf16<'a>(
        &self,
        peft: PeftMode,
        units: &[&'a NativeBuf],
    ) -> Result<(Vec<Ref<'a, [u16]>>, Vec<&'a [f32]>)> {
        let n_base = self.base_unit_count(peft, units.len())?;
        Ok((
            units[..n_base].iter().map(|u| u.shadow()).collect(),
            units[n_base..].iter().map(|u| u.data()).collect(),
        ))
    }

    /// Quantized twin of [`NativeBackend::split_units`]: base units as
    /// (refreshed) block-quantized shadow borrows, adapter units as f32
    /// masters. Fallible — a non-finite master is a hard error naming the
    /// unit that failed to quantize.
    #[allow(clippy::type_complexity)]
    fn split_units_quant<'a>(
        &self,
        peft: PeftMode,
        mode: quant::QuantMode,
        units: &[&'a NativeBuf],
    ) -> Result<(Vec<Ref<'a, QuantShadow>>, Vec<&'a [f32]>)> {
        let n_base = self.base_unit_count(peft, units.len())?;
        let mut shadows = Vec::with_capacity(n_base);
        for (k, u) in units[..n_base].iter().enumerate() {
            let sh = u
                .quant_shadow(mode)
                .with_context(|| format!("quantizing unit {k} for the {mode} forward"))?;
            shadows.push(sh);
        }
        Ok((shadows, units[n_base..].iter().map(|u| u.data()).collect()))
    }
}

impl Backend for NativeBackend {
    type Buffer = NativeBuf;
    type PreparedBatch = Batch;

    fn name(&self) -> &'static str {
        "native"
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn upload(&self, data: &[f32]) -> Result<NativeBuf> {
        Ok(NativeBuf::from(data.to_vec()))
    }

    fn download(&self, buf: &NativeBuf) -> Result<Vec<f32>> {
        Ok(buf.data().to_vec())
    }

    fn zo_axpy(&self, unit: &NativeBuf, len: usize, seed: i32, coeff: f32) -> Result<NativeBuf> {
        ensure!(unit.len() == len, "zo_axpy: unit has {} elements, expected {len}", unit.len());
        let mut out = unit.data().to_vec();
        kernels::axpy_gauss_inplace(&mut out, seed as u32, coeff);
        Ok(NativeBuf::from(out))
    }

    fn zo_axpy_masked(
        &self,
        unit: &NativeBuf,
        pref: &NativeBuf,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<NativeBuf> {
        ensure!(unit.len() == len && pref.len() == len, "zo_axpy_masked: shape mismatch");
        let mut out = unit.data().to_vec();
        kernels::axpy_gauss_masked_inplace(&mut out, pref.data(), tau, seed as u32, coeff);
        Ok(NativeBuf::from(out))
    }

    fn zo_axpy_inplace(
        &self,
        unit: &mut NativeBuf,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        ensure!(
            unit.len() == len,
            "zo_axpy_inplace: unit has {} elements, expected {len}",
            unit.len()
        );
        // make_mut marks this unit's shadows (bf16 and quant) stale — the
        // only shadows rebuilt later are the units a sweep actually touched
        kernels::axpy_gauss_inplace(unit.make_mut(), seed as u32, coeff);
        Ok(())
    }

    fn zo_axpy_masked_inplace(
        &self,
        unit: &mut NativeBuf,
        pref: &NativeBuf,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        ensure!(
            unit.len() == len && pref.len() == len,
            "zo_axpy_masked_inplace: shape mismatch"
        );
        kernels::axpy_gauss_masked_inplace(unit.make_mut(), pref.data(), tau, seed as u32, coeff);
        Ok(())
    }

    fn prepare_batch(&self, batch: &Batch) -> Result<Batch> {
        Ok(batch.clone())
    }

    fn forward_loss(&self, peft: PeftMode, units: &[&NativeBuf], batch: &Batch) -> Result<f32> {
        match self.precision {
            Precision::F32 => {
                let (base, adapters) = self.split_units(peft, units)?;
                forward::mean_loss_peft(
                    &self.spec,
                    &base,
                    peft,
                    &adapters,
                    &batch.tokens,
                    &batch.targets,
                    &batch.mask,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
            Precision::Bf16 => {
                let (shadows, adapters) = self.split_units_bf16(peft, units)?;
                let base: Vec<&[u16]> = shadows.iter().map(|g| &**g).collect();
                forward::mean_loss_bf16_peft(
                    &self.spec,
                    &base,
                    peft,
                    &adapters,
                    &batch.tokens,
                    &batch.targets,
                    &batch.mask,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
            Precision::Int8 | Precision::Int4 => {
                let mode = quant::QuantMode::from_precision(self.precision).unwrap();
                let (shadows, adapters) = self.split_units_quant(peft, mode, units)?;
                let views: Vec<quant::QuantView<'_>> =
                    shadows.iter().map(|g| g.view()).collect();
                forward::mean_loss_quant_peft(
                    &self.spec,
                    &views,
                    peft,
                    &adapters,
                    &batch.tokens,
                    &batch.targets,
                    &batch.mask,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
        }
    }

    fn example_losses(
        &self,
        peft: PeftMode,
        units: &[&NativeBuf],
        batch: &Batch,
    ) -> Result<Vec<f32>> {
        match self.precision {
            Precision::F32 => {
                let (base, adapters) = self.split_units(peft, units)?;
                forward::example_losses_peft(
                    &self.spec,
                    &base,
                    peft,
                    &adapters,
                    &batch.tokens,
                    &batch.targets,
                    &batch.mask,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
            Precision::Bf16 => {
                let (shadows, adapters) = self.split_units_bf16(peft, units)?;
                let base: Vec<&[u16]> = shadows.iter().map(|g| &**g).collect();
                forward::example_losses_bf16_peft(
                    &self.spec,
                    &base,
                    peft,
                    &adapters,
                    &batch.tokens,
                    &batch.targets,
                    &batch.mask,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
            Precision::Int8 | Precision::Int4 => {
                let mode = quant::QuantMode::from_precision(self.precision).unwrap();
                let (shadows, adapters) = self.split_units_quant(peft, mode, units)?;
                let views: Vec<quant::QuantView<'_>> =
                    shadows.iter().map(|g| g.view()).collect();
                forward::example_losses_quant_peft(
                    &self.spec,
                    &views,
                    peft,
                    &adapters,
                    &batch.tokens,
                    &batch.targets,
                    &batch.mask,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
        }
    }

    fn predict(&self, peft: PeftMode, units: &[&NativeBuf], batch: &Batch) -> Result<Vec<i32>> {
        match self.precision {
            Precision::F32 => {
                let (base, adapters) = self.split_units(peft, units)?;
                forward::predict_peft(
                    &self.spec,
                    &base,
                    peft,
                    &adapters,
                    &batch.tokens,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
            Precision::Bf16 => {
                let (shadows, adapters) = self.split_units_bf16(peft, units)?;
                let base: Vec<&[u16]> = shadows.iter().map(|g| &**g).collect();
                forward::predict_bf16_peft(
                    &self.spec,
                    &base,
                    peft,
                    &adapters,
                    &batch.tokens,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
            Precision::Int8 | Precision::Int4 => {
                let mode = quant::QuantMode::from_precision(self.precision).unwrap();
                let (shadows, adapters) = self.split_units_quant(peft, mode, units)?;
                let views: Vec<quant::QuantView<'_>> =
                    shadows.iter().map(|g| g.view()).collect();
                forward::predict_quant_peft(
                    &self.spec,
                    &views,
                    peft,
                    &adapters,
                    &batch.tokens,
                    batch.rows,
                    batch.seq,
                    &mut self.scratch.borrow_mut(),
                )
            }
        }
    }

    fn initial_params(&self, explicit_checkpoint: &str) -> Result<(Vec<Vec<f32>>, String)> {
        if !explicit_checkpoint.is_empty() {
            let units = self
                .load_checked(std::path::Path::new(explicit_checkpoint))
                .with_context(|| format!("loading checkpoint {explicit_checkpoint}"))?;
            return Ok((units, explicit_checkpoint.to_string()));
        }
        if let Some(manifest) = &self.manifest {
            return crate::model::checkpoint::resolve_initial(manifest, "");
        }
        if let Some(dir) = &self.ckpt_dir {
            let pretrained = dir.join("pretrained.ckpt");
            if pretrained.exists() {
                let units = self.load_checked(&pretrained)?;
                return Ok((units, pretrained.display().to_string()));
            }
        }
        Ok((self.spec.init_units(NATIVE_INIT_SEED), "native-init".to_string()))
    }

    /// First-order substrate: the reference backward pass in [`backward`].
    /// Always f32 — gradients feed the f32 Adam state; `precision` only
    /// affects the (forward-only) ZO objective and evaluation.
    fn forward_backward(
        &self,
        host_units: &[Vec<f32>],
        batch: &Batch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let slices: Vec<&[f32]> = host_units.iter().map(|u| u.as_slice()).collect();
        backward::forward_backward(
            &self.spec,
            &slices,
            &batch.tokens,
            &batch.targets,
            &batch.mask,
            batch.rows,
            batch.seq,
        )
    }

    /// All PEFT modes run natively: the adapter forwards fold into the
    /// blocked kernels ([`kernels`]) with zero artifacts.
    fn supports_peft(&self, _mode: PeftMode) -> bool {
        true
    }

    fn supports_fo(&self) -> bool {
        true
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    /// Every precision runs natively (f32 kernels plus their bf16 and
    /// block-quantized twins).
    fn supports_precision(&self, _precision: Precision) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::preset("opt-nano").unwrap()
    }

    fn bf16_backend() -> NativeBackend {
        NativeBackend::preset("opt-nano").unwrap().with_precision(Precision::Bf16)
    }

    fn quant_backend(precision: Precision) -> NativeBackend {
        NativeBackend::preset("opt-nano").unwrap().with_precision(precision)
    }

    #[test]
    fn axpy_is_deterministic_and_standard_normal() {
        let b = backend();
        let n = 4096;
        let p = b.upload(&vec![0.0f32; n]).unwrap();
        let za = b.zo_axpy(&p, n, 42, 1.0).unwrap();
        let zb = b.zo_axpy(&p, n, 42, 1.0).unwrap();
        assert_eq!(za, zb, "same seed must regenerate the same z");
        let mean: f32 = za.iter().sum::<f32>() / n as f32;
        let var: f32 = za.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.1, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn inplace_axpy_is_bitwise_equal_to_allocating_axpy() {
        let b = backend();
        let n = 5000;
        let host: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let p = b.upload(&host).unwrap();
        let alloc = b.zo_axpy(&p, n, 13, 2.5e-3).unwrap();
        let mut inplace = b.upload(&host).unwrap();
        b.zo_axpy_inplace(&mut inplace, n, 13, 2.5e-3).unwrap();
        assert_eq!(alloc, inplace);

        let pref_host: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.07).cos()).collect();
        let pref = b.upload(&pref_host).unwrap();
        let alloc_m = b.zo_axpy_masked(&p, &pref, 0.5, n, 13, 2.5e-3).unwrap();
        let mut inplace_m = b.upload(&host).unwrap();
        b.zo_axpy_masked_inplace(&mut inplace_m, &pref, 0.5, n, 13, 2.5e-3).unwrap();
        assert_eq!(alloc_m, inplace_m);
    }

    #[test]
    fn axpy_perturb_restore_identity() {
        let b = backend();
        let n = 1000;
        let orig: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let mu = 1e-3f32;
        let p0 = b.upload(&orig).unwrap();
        let p1 = b.zo_axpy(&p0, n, 7, mu).unwrap();
        let p2 = b.zo_axpy(&p1, n, 7, -2.0 * mu).unwrap();
        let p3 = b.zo_axpy(&p2, n, 7, mu).unwrap();
        for (a, o) in p3.iter().zip(&orig) {
            assert!((a - o).abs() < 1e-5, "{a} vs {o}");
        }
    }

    #[test]
    fn masked_axpy_touches_only_small_magnitudes() {
        let b = backend();
        let pref = b.upload(&[0.0f32, 10.0, 0.1, 5.0]).unwrap();
        let p = b.upload(&[1.0f32; 4]).unwrap();
        let out = b.zo_axpy_masked(&p, &pref, 0.5, 4, 3, 1.0).unwrap();
        assert_ne!(out[0], 1.0, "|0.0| <= tau must be perturbed");
        assert_eq!(out[1], 1.0, "|10| > tau must be untouched");
        assert_ne!(out[2], 1.0);
        assert_eq!(out[3], 1.0);
    }

    #[test]
    fn masked_matches_dense_at_infinite_tau() {
        let b = backend();
        let host: Vec<f32> = (0..256).map(|i| i as f32 * 0.1).collect();
        let p = b.upload(&host).unwrap();
        let dense = b.zo_axpy(&p, 256, 11, 0.5).unwrap();
        let masked = b.zo_axpy_masked(&p, &p, f32::INFINITY, 256, 11, 0.5).unwrap();
        assert_eq!(dense, masked);
    }

    fn lm_prepared(b: &NativeBackend, seq: usize) -> Batch {
        let seqs: Vec<Vec<u32>> = (0..b.spec().train_batch)
            .map(|r| (0..12u32).map(|i| 20 + ((r as u32 + i) % 50)).collect())
            .collect();
        let batch = Batch::lm_batch(&seqs, b.spec().train_batch, seq).unwrap();
        b.prepare_batch(&batch).unwrap()
    }

    #[test]
    fn forward_loss_runs_without_artifacts() {
        let b = backend();
        let host = b.initial_params("").unwrap().0;
        let bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        let units: Vec<&NativeBuf> = bufs.iter().collect();
        let prepared = lm_prepared(&b, 16);
        let loss = b.forward_loss(PeftMode::Full, &units, &prepared).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        let per = b.example_losses(PeftMode::Full, &units, &prepared).unwrap();
        assert_eq!(per.len(), b.spec().train_batch);
        let preds = b.predict(PeftMode::Full, &units, &prepared).unwrap();
        assert_eq!(preds.len(), b.spec().train_batch * 16);
    }

    #[test]
    fn bf16_forward_families_run_and_track_f32() {
        // dispatch sanity for all three bf16 families + the calibrated loss
        // tolerance at the backend level (the kernel/forward suites pin the
        // numerics in detail; observed rel err ~1e-4, asserted 1e-2)
        let f = backend();
        let b = bf16_backend();
        assert_eq!(b.precision(), Precision::Bf16);
        let host = b.initial_params("").unwrap().0;
        let bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        let units: Vec<&NativeBuf> = bufs.iter().collect();
        let prepared = lm_prepared(&b, 16);
        let loss_b = b.forward_loss(PeftMode::Full, &units, &prepared).unwrap();
        let loss_f = f.forward_loss(PeftMode::Full, &units, &prepared).unwrap();
        let rel = (loss_b - loss_f).abs() / loss_f.abs().max(1e-6);
        assert!(rel <= 1e-2, "bf16 {loss_b} vs f32 {loss_f} (rel {rel})");
        let per = b.example_losses(PeftMode::Full, &units, &prepared).unwrap();
        assert_eq!(per.len(), b.spec().train_batch);
        assert!(per.iter().all(|l| l.is_finite()));
        let preds = b.predict(PeftMode::Full, &units, &prepared).unwrap();
        assert_eq!(preds.len(), b.spec().train_batch * 16);
    }

    #[test]
    fn bf16_shadow_invalidation_tracks_touched_units_only() {
        let b = bf16_backend();
        let host = b.initial_params("").unwrap().0;
        let mut bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        // a forward materializes every base unit's shadow
        let prepared = lm_prepared(&b, 16);
        let units: Vec<&NativeBuf> = bufs.iter().collect();
        b.forward_loss(PeftMode::Full, &units, &prepared).unwrap();
        assert!(bufs.iter().all(|u| u.shadow_is_fresh()), "forward must cast all shadows");
        let before: Vec<Vec<u16>> = bufs.iter().map(|u| u.shadow_bits()).collect();

        // touch only unit 1 (in-place sweep): its shadow goes stale, every
        // other unit's shadow must stay bit-unchanged without a re-cast
        let len = bufs[1].len();
        b.zo_axpy_inplace(&mut bufs[1], len, 9, 1e-2).unwrap();
        assert!(!bufs[1].shadow_is_fresh(), "touched unit must be invalidated");
        for (k, u) in bufs.iter().enumerate() {
            if k != 1 {
                assert!(u.shadow_is_fresh(), "unit {k} must stay fresh");
            }
        }
        // the refreshed shadow equals a fresh full re-cast of the master
        let recast = bufs[1].shadow_bits();
        assert_eq!(recast, crate::runtime::native::bf16::cast(bufs[1].data()));
        assert_ne!(recast, before[1], "perturbation must change the shadow");
        for (k, u) in bufs.iter().enumerate() {
            if k != 1 {
                assert_eq!(u.shadow_bits(), before[k], "unit {k} shadow must be bit-unchanged");
            }
        }
    }

    #[test]
    fn bf16_shadow_invalidation_after_masked_axpy() {
        let b = bf16_backend();
        let host = b.initial_params("").unwrap().0;
        let mut bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        for u in &bufs {
            u.shadow_bits(); // materialize
        }
        let before: Vec<Vec<u16>> = bufs.iter().map(|u| u.shadow_bits()).collect();
        let len = bufs[2].len();
        let pref = b.upload(bufs[2].data()).unwrap();
        b.zo_axpy_masked_inplace(&mut bufs[2], &pref, f32::INFINITY, len, 5, 0.5).unwrap();
        // touched: equals a fresh full re-cast; untouched: bit-unchanged
        assert_eq!(bufs[2].shadow_bits(), crate::runtime::native::bf16::cast(bufs[2].data()));
        for (k, u) in bufs.iter().enumerate() {
            if k != 2 {
                assert_eq!(u.shadow_bits(), before[k], "unit {k}");
            }
        }
    }

    #[test]
    fn peft_runs_natively_and_fo_is_supported() {
        let b = backend();
        let host = b.initial_params("").unwrap().0;
        let bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        let units: Vec<&NativeBuf> = bufs.iter().collect();
        let batch = Batch::lm_batch(&[vec![1, 2, 3]], 1, 16).unwrap();
        let prepared = b.prepare_batch(&batch).unwrap();
        // every PEFT mode is native now; base units alone are a shape error
        for mode in [PeftMode::Lora, PeftMode::Prefix] {
            assert!(b.supports_peft(mode), "{mode}");
            let err = b.forward_loss(mode, &units, &prepared).unwrap_err();
            assert!(err.to_string().contains("adapter"), "{err}");
            let spec = b.spec();
            let adapters =
                crate::peft::init_peft_units(mode, spec.n_layers, spec.d_model, 0);
            let adapter_bufs: Vec<NativeBuf> =
                adapters.iter().map(|u| b.upload(u).unwrap()).collect();
            let mut args = units.clone();
            args.extend(adapter_bufs.iter());
            let loss = b.forward_loss(mode, &args, &prepared).unwrap();
            assert!(loss.is_finite() && loss > 0.0, "{mode}");
            let per = b.example_losses(mode, &args, &prepared).unwrap();
            assert_eq!(per.len(), 1, "{mode}");
            let preds = b.predict(mode, &args, &prepared).unwrap();
            assert_eq!(preds.len(), 16, "{mode}");
        }
        assert!(b.supports_peft(PeftMode::Full));
        assert_eq!(
            b.peft_unit_len(PeftMode::Lora).unwrap(),
            crate::peft::lora_unit_len(b.spec().d_model)
        );
        // the native backend has a reference backward pass since PR 3
        assert!(b.supports_fo());
        let (loss, grads) = b.forward_backward(&host, &batch).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(grads.len(), host.len());
        for (g, u) in grads.iter().zip(&host) {
            assert_eq!(g.len(), u.len());
        }
        // mismatched host units are still a shape error
        assert!(b.forward_backward(&host[..2], &batch).is_err());
    }

    #[test]
    fn bf16_peft_forward_runs_with_f32_adapters() {
        let b = bf16_backend();
        let host = b.initial_params("").unwrap().0;
        let bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        let batch = Batch::lm_batch(&[vec![1, 2, 3]], 1, 16).unwrap();
        let prepared = b.prepare_batch(&batch).unwrap();
        for mode in [PeftMode::Lora, PeftMode::Prefix] {
            let spec = b.spec();
            let adapters = crate::peft::init_peft_units_nonzero_b(
                mode,
                spec.n_layers,
                spec.d_model,
                3,
            );
            let adapter_bufs: Vec<NativeBuf> =
                adapters.iter().map(|u| b.upload(u).unwrap()).collect();
            let mut args: Vec<&NativeBuf> = bufs.iter().collect();
            args.extend(adapter_bufs.iter());
            let loss = b.forward_loss(mode, &args, &prepared).unwrap();
            assert!(loss.is_finite() && loss > 0.0, "{mode}");
        }
    }

    #[test]
    fn precision_capability_and_default() {
        let b = backend();
        assert_eq!(b.precision(), Precision::F32);
        assert!(b.supports_precision(Precision::F32));
        assert!(b.supports_precision(Precision::Bf16));
        assert!(b.supports_precision(Precision::Int8));
        assert!(b.supports_precision(Precision::Int4));
    }

    #[test]
    fn quant_forward_families_run_and_track_f32() {
        // dispatch sanity for all three quant families + the calibrated
        // loss tolerance vs the f32 masters. The *bitwise* pin (quant
        // family == f32 family on the dequantized units) lives in the
        // forward/kernels suites and rust/tests/kernel_twins.rs; here the
        // bound is the quantization error itself: int8 codes carry ~11x
        // more resolution than int4 (qmax 127 vs 7), hence the per-mode
        // tolerances (observed rel err ~2e-4 int8 / ~2e-2 int4).
        let f = backend();
        for (precision, tol) in [(Precision::Int8, 1e-2f32), (Precision::Int4, 2e-1f32)] {
            let b = quant_backend(precision);
            assert_eq!(b.precision(), precision);
            let host = b.initial_params("").unwrap().0;
            let bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
            let units: Vec<&NativeBuf> = bufs.iter().collect();
            let prepared = lm_prepared(&b, 16);
            let loss_q = b.forward_loss(PeftMode::Full, &units, &prepared).unwrap();
            let loss_f = f.forward_loss(PeftMode::Full, &units, &prepared).unwrap();
            let rel = (loss_q - loss_f).abs() / loss_f.abs().max(1e-6);
            assert!(rel <= tol, "{precision} {loss_q} vs f32 {loss_f} (rel {rel})");
            let per = b.example_losses(PeftMode::Full, &units, &prepared).unwrap();
            assert_eq!(per.len(), b.spec().train_batch);
            assert!(per.iter().all(|l| l.is_finite()), "{precision}");
            let preds = b.predict(PeftMode::Full, &units, &prepared).unwrap();
            assert_eq!(preds.len(), b.spec().train_batch * 16);
            assert!(preds.iter().all(|&p| (0..b.spec().vocab as i32).contains(&p)));
        }
    }

    #[test]
    fn quant_peft_forward_runs_with_f32_adapters() {
        for precision in [Precision::Int8, Precision::Int4] {
            let b = quant_backend(precision);
            let host = b.initial_params("").unwrap().0;
            let bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
            let batch = Batch::lm_batch(&[vec![1, 2, 3]], 1, 16).unwrap();
            let prepared = b.prepare_batch(&batch).unwrap();
            for mode in [PeftMode::Lora, PeftMode::Prefix] {
                let spec = b.spec();
                let adapters = crate::peft::init_peft_units_nonzero_b(
                    mode,
                    spec.n_layers,
                    spec.d_model,
                    3,
                );
                let adapter_bufs: Vec<NativeBuf> =
                    adapters.iter().map(|u| b.upload(u).unwrap()).collect();
                let mut args: Vec<&NativeBuf> = bufs.iter().collect();
                args.extend(adapter_bufs.iter());
                let loss = b.forward_loss(mode, &args, &prepared).unwrap();
                assert!(loss.is_finite() && loss > 0.0, "{precision}/{mode}");
            }
        }
    }

    #[test]
    fn quant_shadow_invalidation_tracks_touched_units_only() {
        let b = quant_backend(Precision::Int8);
        let host = b.initial_params("").unwrap().0;
        let mut bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        // a forward quantizes every base unit's shadow
        let prepared = lm_prepared(&b, 16);
        let units: Vec<&NativeBuf> = bufs.iter().collect();
        b.forward_loss(PeftMode::Full, &units, &prepared).unwrap();
        assert!(
            bufs.iter().all(|u| u.quant_shadow_is_fresh()),
            "forward must quantize all shadows"
        );
        let mode = quant::QuantMode::Int8;
        let before: Vec<(Vec<f32>, Vec<u8>)> =
            bufs.iter().map(|u| u.quant_shadow_parts(mode).unwrap()).collect();

        // touch only unit 1 (in-place sweep): its shadow goes stale, every
        // other unit's shadow must stay bit-unchanged without a re-quant
        let len = bufs[1].len();
        b.zo_axpy_inplace(&mut bufs[1], len, 9, 1e-2).unwrap();
        assert!(!bufs[1].quant_shadow_is_fresh(), "touched unit must be invalidated");
        for (k, u) in bufs.iter().enumerate() {
            if k != 1 {
                assert!(u.quant_shadow_is_fresh(), "unit {k} must stay fresh");
            }
        }
        // the refreshed shadow equals a fresh full re-quantization of the
        // master; untouched units are bit-unchanged
        let requant = bufs[1].quant_shadow_parts(mode).unwrap();
        let (exp_scales, exp_codes) = quant::quantize(mode, bufs[1].data()).unwrap();
        assert_eq!(
            requant.0.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            exp_scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(requant.1, exp_codes);
        assert_ne!(requant.1, before[1].1, "perturbation must change the codes");
        for (k, u) in bufs.iter().enumerate() {
            if k != 1 {
                let now = u.quant_shadow_parts(mode).unwrap();
                assert_eq!(now.1, before[k].1, "unit {k} codes must be bit-unchanged");
            }
        }
        // a mode switch on the same buffer rebuilds rather than reuses
        let (s4, c4) = bufs[0].quant_shadow_parts(quant::QuantMode::Int4).unwrap();
        let (e4s, e4c) = quant::quantize(quant::QuantMode::Int4, bufs[0].data()).unwrap();
        assert_eq!(
            s4.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            e4s.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(c4, e4c);
    }

    #[test]
    fn non_finite_master_is_a_hard_error_naming_the_unit() {
        let b = quant_backend(Precision::Int4);
        let host = b.initial_params("").unwrap().0;
        let mut bufs: Vec<NativeBuf> = host.iter().map(|u| b.upload(u).unwrap()).collect();
        bufs[2].make_mut()[7] = f32::NAN;
        let units: Vec<&NativeBuf> = bufs.iter().collect();
        let prepared = lm_prepared(&b, 16);
        let err = b.forward_loss(PeftMode::Full, &units, &prepared).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unit 2"), "{msg}");
        assert!(msg.contains("non-finite"), "{msg}");
        assert!(msg.contains("flat index 7"), "{msg}");
    }

    #[test]
    fn checkpoint_dir_adoption_picks_up_pretrained_ckpt() {
        let b = backend();
        let dir = std::env::temp_dir().join(format!("lezo_ckpt_dir_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // no pretrained.ckpt yet: native init
        let b2 = NativeBackend::preset("opt-nano").unwrap().with_checkpoint_dir(&dir);
        assert_eq!(b2.initial_params("").unwrap().1, "native-init");
        // write one and it becomes the initial state
        let units = b.initial_params("").unwrap().0;
        crate::model::checkpoint::save(&dir.join("pretrained.ckpt"), 7, &units).unwrap();
        let (loaded, source) = b2.initial_params("").unwrap();
        assert_eq!(loaded, units);
        assert!(source.contains("pretrained.ckpt"), "{source}");
        // a mismatched checkpoint is a hard error, not a fallback
        let other = NativeBackend::preset("opt-micro").unwrap().with_checkpoint_dir(&dir);
        assert!(other.initial_params("").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn initial_params_checkpoint_round_trip() {
        let b = backend();
        let (init, source) = b.initial_params("").unwrap();
        assert_eq!(source, "native-init");
        let path = std::env::temp_dir().join(format!("lezo_native_ck_{}", std::process::id()));
        crate::model::checkpoint::save(&path, 5, &init).unwrap();
        let (loaded, src2) = b.initial_params(path.to_str().unwrap()).unwrap();
        assert_eq!(loaded, init);
        assert!(src2.contains("lezo_native_ck"));
        std::fs::remove_file(&path).ok();
        // mismatched checkpoint rejected
        let other = NativeBackend::preset("opt-micro").unwrap();
        let path2 = std::env::temp_dir().join(format!("lezo_native_ck2_{}", std::process::id()));
        crate::model::checkpoint::save(&path2, 0, &other.initial_params("").unwrap().0).unwrap();
        assert!(b.initial_params(path2.to_str().unwrap()).is_err());
        std::fs::remove_file(&path2).ok();
    }
}

//! NativeBackend: the pure-Rust CPU implementation of [`Backend`].
//!
//! Buffers are host `Vec<f32>`; the ZO kernels regenerate the perturbation
//! stream with the in-crate Philox port ([`crate::runtime::philox`],
//! bit-compatible with the Pallas kernel's integer stream); the forward
//! families run the blocked, thread-parallel kernels in [`kernels`] with a
//! streaming (fused) LM head, against the naive dense reference kept in
//! [`forward`]; the PEFT families (LoRA / prefix, the paper's Table 4)
//! fold per-block adapter units into the same kernels, so
//! `supports_peft() == true` for every mode; and the first-order substrate
//! (`method=ft`, `pretrain`) runs on the reference backward pass in
//! [`backward`], so `supports_fo() == true` with zero artifacts.
//! Everything is derived from a [`ModelSpec`] preset — no AOT artifacts,
//! no PJRT plugin, no Python.
//!
//! Hot-path structure (this is the substrate the bench harness measures):
//!
//! - [`parallel`] — scoped worker threads with *fixed* chunk partitioning;
//!   results are bit-identical at any `threads` / `LEZO_THREADS` setting.
//! - [`kernels`] — in-place ZO sweeps over the multi-lane Philox fill,
//!   cache-blocked matmuls, (row, head)-parallel attention, the reusable
//!   [`kernels::ForwardScratch`] arena, and the fused LM head that never
//!   materializes the `rows*seq*vocab` logits tensor.
//! - [`forward`] — the forward families plus the dense reference
//!   (`forward_logits` / `position_xent`) the fused paths are tested
//!   against.
//! - [`backward`] — the recording forward + full backward for FO-Adam,
//!   gradient-checked against `forward_loss` by central finite differences
//!   (and cross-checked against the Python twin's `jax.value_and_grad`).

pub mod backward;
pub mod forward;
pub mod kernels;
pub mod parallel;

use crate::data::batch::Batch;
use crate::model::spec::ModelSpec;
use crate::peft::PeftMode;
use crate::runtime::backend::Backend;
use anyhow::{ensure, Context, Result};
use std::cell::RefCell;

/// Seed for the deterministic native initialization (runs start identical
/// across machines; override with the `checkpoint` config key).
pub const NATIVE_INIT_SEED: u64 = 0;

pub struct NativeBackend {
    spec: ModelSpec,
    /// Optional adopted artifact manifest: runs then start from its
    /// params_init.bin / pretrained.ckpt (same initial state as the PJRT
    /// backend) instead of the synthetic native init — so results don't
    /// silently diverge between build flavors.
    manifest: Option<crate::model::Manifest>,
    /// Optional checkpoint directory for manifest-less (fully hermetic)
    /// runs: when `<ckpt_dir>/pretrained.ckpt` exists — written by the
    /// native `pretrain` path — runs start from it, mirroring
    /// `checkpoint::resolve_initial`'s rule for artifact dirs.
    ckpt_dir: Option<std::path::PathBuf>,
    /// Reusable forward arena: q/k/v/ctx/ffn and the residual stream are
    /// allocated once and reused across every forward this backend runs.
    scratch: RefCell<kernels::ForwardScratch>,
}

impl NativeBackend {
    pub fn new(spec: ModelSpec) -> Result<NativeBackend> {
        spec.validate()?;
        Ok(NativeBackend {
            spec,
            manifest: None,
            ckpt_dir: None,
            scratch: RefCell::new(kernels::ForwardScratch::new()),
        })
    }

    pub fn preset(name: &str) -> Result<NativeBackend> {
        NativeBackend::new(ModelSpec::preset(name)?)
    }

    /// Adopt exported initial parameters via an already-loaded manifest
    /// (see the `manifest` field). A manifest that does not match the
    /// spec's unit layout is a hard error, not a silent fallback.
    pub fn with_artifacts(mut self, manifest: crate::model::Manifest) -> Result<NativeBackend> {
        ensure!(
            manifest.unit_lens == self.spec.unit_lens(),
            "artifacts in {} do not match the {} layout",
            manifest.dir.display(),
            self.spec.name
        );
        self.manifest = Some(manifest);
        Ok(self)
    }

    /// Adopt a checkpoint directory (no manifest needed): runs start from
    /// `<dir>/pretrained.ckpt` when it exists — this is how a hermetic
    /// `lezo pretrain` -> `lezo train` pipeline hands over weights. A
    /// checkpoint that does not match the spec's layout is a hard error.
    pub fn with_checkpoint_dir(mut self, dir: &std::path::Path) -> NativeBackend {
        self.ckpt_dir = Some(dir.to_path_buf());
        self
    }

    /// The adopted artifact manifest, if any (pretraining starts from its
    /// params_init.bin instead of the synthetic native init).
    pub fn manifest(&self) -> Option<&crate::model::Manifest> {
        self.manifest.as_ref()
    }

    /// Load a checkpoint and validate it against this spec's unit layout —
    /// a mismatch is a hard error, never a silent fallback.
    fn load_checked(&self, path: &std::path::Path) -> Result<Vec<Vec<f32>>> {
        let ck = crate::model::checkpoint::load(path)?;
        let lens = self.spec.unit_lens();
        ensure!(
            ck.units.len() == lens.len()
                && ck.units.iter().zip(&lens).all(|(u, &l)| u.len() == l),
            "checkpoint {} does not match model {}",
            path.display(),
            self.spec.name
        );
        Ok(ck.units)
    }

    /// Split the forward-argument prefix into (base units, adapter units):
    /// `n_units()` model units, then — under PEFT — one adapter unit per
    /// transformer block, the same order the AOT'd PJRT executables take.
    /// Per-unit lengths are validated inside the kernels.
    #[allow(clippy::type_complexity)]
    fn split_units<'a>(
        &self,
        peft: PeftMode,
        units: &[&'a Vec<f32>],
    ) -> Result<(Vec<&'a [f32]>, Vec<&'a [f32]>)> {
        let n_base = self.spec.n_units();
        let n_adapters = match peft {
            PeftMode::Full => 0,
            _ => self.spec.n_layers,
        };
        ensure!(
            units.len() == n_base + n_adapters,
            "peft={peft}: native forward takes {} units ({n_base} model units + {n_adapters} \
             adapter units), got {}",
            n_base + n_adapters,
            units.len()
        );
        Ok((
            units[..n_base].iter().map(|u| u.as_slice()).collect(),
            units[n_base..].iter().map(|u| u.as_slice()).collect(),
        ))
    }
}

impl Backend for NativeBackend {
    type Buffer = Vec<f32>;
    type PreparedBatch = Batch;

    fn name(&self) -> &'static str {
        "native"
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn upload(&self, data: &[f32]) -> Result<Vec<f32>> {
        Ok(data.to_vec())
    }

    fn download(&self, buf: &Vec<f32>) -> Result<Vec<f32>> {
        Ok(buf.clone())
    }

    fn zo_axpy(&self, unit: &Vec<f32>, len: usize, seed: i32, coeff: f32) -> Result<Vec<f32>> {
        ensure!(unit.len() == len, "zo_axpy: unit has {} elements, expected {len}", unit.len());
        let mut out = unit.clone();
        kernels::axpy_gauss_inplace(&mut out, seed as u32, coeff);
        Ok(out)
    }

    fn zo_axpy_masked(
        &self,
        unit: &Vec<f32>,
        pref: &Vec<f32>,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<Vec<f32>> {
        ensure!(unit.len() == len && pref.len() == len, "zo_axpy_masked: shape mismatch");
        let mut out = unit.clone();
        kernels::axpy_gauss_masked_inplace(&mut out, pref, tau, seed as u32, coeff);
        Ok(out)
    }

    fn zo_axpy_inplace(
        &self,
        unit: &mut Vec<f32>,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        ensure!(
            unit.len() == len,
            "zo_axpy_inplace: unit has {} elements, expected {len}",
            unit.len()
        );
        kernels::axpy_gauss_inplace(unit, seed as u32, coeff);
        Ok(())
    }

    fn zo_axpy_masked_inplace(
        &self,
        unit: &mut Vec<f32>,
        pref: &Vec<f32>,
        tau: f32,
        len: usize,
        seed: i32,
        coeff: f32,
    ) -> Result<()> {
        ensure!(
            unit.len() == len && pref.len() == len,
            "zo_axpy_masked_inplace: shape mismatch"
        );
        kernels::axpy_gauss_masked_inplace(unit, pref, tau, seed as u32, coeff);
        Ok(())
    }

    fn prepare_batch(&self, batch: &Batch) -> Result<Batch> {
        Ok(batch.clone())
    }

    fn forward_loss(
        &self,
        peft: PeftMode,
        units: &[&Vec<f32>],
        batch: &Batch,
    ) -> Result<f32> {
        let (base, adapters) = self.split_units(peft, units)?;
        forward::mean_loss_peft(
            &self.spec,
            &base,
            peft,
            &adapters,
            &batch.tokens,
            &batch.targets,
            &batch.mask,
            batch.rows,
            batch.seq,
            &mut self.scratch.borrow_mut(),
        )
    }

    fn example_losses(
        &self,
        peft: PeftMode,
        units: &[&Vec<f32>],
        batch: &Batch,
    ) -> Result<Vec<f32>> {
        let (base, adapters) = self.split_units(peft, units)?;
        forward::example_losses_peft(
            &self.spec,
            &base,
            peft,
            &adapters,
            &batch.tokens,
            &batch.targets,
            &batch.mask,
            batch.rows,
            batch.seq,
            &mut self.scratch.borrow_mut(),
        )
    }

    fn predict(&self, peft: PeftMode, units: &[&Vec<f32>], batch: &Batch) -> Result<Vec<i32>> {
        let (base, adapters) = self.split_units(peft, units)?;
        forward::predict_peft(
            &self.spec,
            &base,
            peft,
            &adapters,
            &batch.tokens,
            batch.rows,
            batch.seq,
            &mut self.scratch.borrow_mut(),
        )
    }

    fn initial_params(&self, explicit_checkpoint: &str) -> Result<(Vec<Vec<f32>>, String)> {
        if !explicit_checkpoint.is_empty() {
            let units = self
                .load_checked(std::path::Path::new(explicit_checkpoint))
                .with_context(|| format!("loading checkpoint {explicit_checkpoint}"))?;
            return Ok((units, explicit_checkpoint.to_string()));
        }
        if let Some(manifest) = &self.manifest {
            return crate::model::checkpoint::resolve_initial(manifest, "");
        }
        if let Some(dir) = &self.ckpt_dir {
            let pretrained = dir.join("pretrained.ckpt");
            if pretrained.exists() {
                let units = self.load_checked(&pretrained)?;
                return Ok((units, pretrained.display().to_string()));
            }
        }
        Ok((self.spec.init_units(NATIVE_INIT_SEED), "native-init".to_string()))
    }

    /// First-order substrate: the reference backward pass in [`backward`].
    fn forward_backward(
        &self,
        host_units: &[Vec<f32>],
        batch: &Batch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let slices: Vec<&[f32]> = host_units.iter().map(|u| u.as_slice()).collect();
        backward::forward_backward(
            &self.spec,
            &slices,
            &batch.tokens,
            &batch.targets,
            &batch.mask,
            batch.rows,
            batch.seq,
        )
    }

    /// All PEFT modes run natively: the adapter forwards fold into the
    /// blocked kernels ([`kernels`]) with zero artifacts.
    fn supports_peft(&self, _mode: PeftMode) -> bool {
        true
    }

    fn supports_fo(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::preset("opt-nano").unwrap()
    }

    #[test]
    fn axpy_is_deterministic_and_standard_normal() {
        let b = backend();
        let n = 4096;
        let p = vec![0.0f32; n];
        let za = b.zo_axpy(&p, n, 42, 1.0).unwrap();
        let zb = b.zo_axpy(&p, n, 42, 1.0).unwrap();
        assert_eq!(za, zb, "same seed must regenerate the same z");
        let mean: f32 = za.iter().sum::<f32>() / n as f32;
        let var: f32 = za.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.1, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn inplace_axpy_is_bitwise_equal_to_allocating_axpy() {
        let b = backend();
        let n = 5000;
        let p: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let alloc = b.zo_axpy(&p, n, 13, 2.5e-3).unwrap();
        let mut inplace = p.clone();
        b.zo_axpy_inplace(&mut inplace, n, 13, 2.5e-3).unwrap();
        assert_eq!(alloc, inplace);

        let pref: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.07).cos()).collect();
        let alloc_m = b.zo_axpy_masked(&p, &pref, 0.5, n, 13, 2.5e-3).unwrap();
        let mut inplace_m = p.clone();
        b.zo_axpy_masked_inplace(&mut inplace_m, &pref, 0.5, n, 13, 2.5e-3).unwrap();
        assert_eq!(alloc_m, inplace_m);
    }

    #[test]
    fn axpy_perturb_restore_identity() {
        let b = backend();
        let n = 1000;
        let orig: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let mu = 1e-3f32;
        let p1 = b.zo_axpy(&orig, n, 7, mu).unwrap();
        let p2 = b.zo_axpy(&p1, n, 7, -2.0 * mu).unwrap();
        let p3 = b.zo_axpy(&p2, n, 7, mu).unwrap();
        for (a, o) in p3.iter().zip(&orig) {
            assert!((a - o).abs() < 1e-5, "{a} vs {o}");
        }
    }

    #[test]
    fn masked_axpy_touches_only_small_magnitudes() {
        let b = backend();
        let pref = vec![0.0f32, 10.0, 0.1, 5.0];
        let p = vec![1.0f32; 4];
        let out = b.zo_axpy_masked(&p, &pref, 0.5, 4, 3, 1.0).unwrap();
        assert_ne!(out[0], 1.0, "|0.0| <= tau must be perturbed");
        assert_eq!(out[1], 1.0, "|10| > tau must be untouched");
        assert_ne!(out[2], 1.0);
        assert_eq!(out[3], 1.0);
    }

    #[test]
    fn masked_matches_dense_at_infinite_tau() {
        let b = backend();
        let p: Vec<f32> = (0..256).map(|i| i as f32 * 0.1).collect();
        let dense = b.zo_axpy(&p, 256, 11, 0.5).unwrap();
        let masked = b.zo_axpy_masked(&p, &p, f32::INFINITY, 256, 11, 0.5).unwrap();
        assert_eq!(dense, masked);
    }

    #[test]
    fn forward_loss_runs_without_artifacts() {
        let b = backend();
        let host = b.initial_params("").unwrap().0;
        let units: Vec<&Vec<f32>> = host.iter().collect();
        let seqs: Vec<Vec<u32>> = (0..b.spec().train_batch)
            .map(|r| (0..12u32).map(|i| 20 + ((r as u32 + i) % 50)).collect())
            .collect();
        let batch = Batch::lm_batch(&seqs, b.spec().train_batch, 16).unwrap();
        let prepared = b.prepare_batch(&batch).unwrap();
        let loss = b.forward_loss(PeftMode::Full, &units, &prepared).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        let per = b.example_losses(PeftMode::Full, &units, &prepared).unwrap();
        assert_eq!(per.len(), b.spec().train_batch);
        let preds = b.predict(PeftMode::Full, &units, &prepared).unwrap();
        assert_eq!(preds.len(), b.spec().train_batch * 16);
    }

    #[test]
    fn peft_runs_natively_and_fo_is_supported() {
        let b = backend();
        let host = b.initial_params("").unwrap().0;
        let units: Vec<&Vec<f32>> = host.iter().collect();
        let batch = Batch::lm_batch(&[vec![1, 2, 3]], 1, 16).unwrap();
        let prepared = b.prepare_batch(&batch).unwrap();
        // every PEFT mode is native now; base units alone are a shape error
        for mode in [PeftMode::Lora, PeftMode::Prefix] {
            assert!(b.supports_peft(mode), "{mode}");
            let err = b.forward_loss(mode, &units, &prepared).unwrap_err();
            assert!(err.to_string().contains("adapter"), "{err}");
            let spec = b.spec();
            let adapters =
                crate::peft::init_peft_units(mode, spec.n_layers, spec.d_model, 0);
            let mut args = units.clone();
            args.extend(adapters.iter());
            let loss = b.forward_loss(mode, &args, &prepared).unwrap();
            assert!(loss.is_finite() && loss > 0.0, "{mode}");
            let per = b.example_losses(mode, &args, &prepared).unwrap();
            assert_eq!(per.len(), 1, "{mode}");
            let preds = b.predict(mode, &args, &prepared).unwrap();
            assert_eq!(preds.len(), 16, "{mode}");
        }
        assert!(b.supports_peft(PeftMode::Full));
        assert_eq!(
            b.peft_unit_len(PeftMode::Lora).unwrap(),
            crate::peft::lora_unit_len(b.spec().d_model)
        );
        // the native backend has a reference backward pass since PR 3
        assert!(b.supports_fo());
        let (loss, grads) = b.forward_backward(&host, &batch).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(grads.len(), host.len());
        for (g, u) in grads.iter().zip(&host) {
            assert_eq!(g.len(), u.len());
        }
        // mismatched host units are still a shape error
        assert!(b.forward_backward(&host[..2], &batch).is_err());
    }

    #[test]
    fn checkpoint_dir_adoption_picks_up_pretrained_ckpt() {
        let b = backend();
        let dir = std::env::temp_dir().join(format!("lezo_ckpt_dir_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // no pretrained.ckpt yet: native init
        let b2 = NativeBackend::preset("opt-nano").unwrap().with_checkpoint_dir(&dir);
        assert_eq!(b2.initial_params("").unwrap().1, "native-init");
        // write one and it becomes the initial state
        let units = b.initial_params("").unwrap().0;
        crate::model::checkpoint::save(&dir.join("pretrained.ckpt"), 7, &units).unwrap();
        let (loaded, source) = b2.initial_params("").unwrap();
        assert_eq!(loaded, units);
        assert!(source.contains("pretrained.ckpt"), "{source}");
        // a mismatched checkpoint is a hard error, not a fallback
        let other = NativeBackend::preset("opt-micro").unwrap().with_checkpoint_dir(&dir);
        assert!(other.initial_params("").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn initial_params_checkpoint_round_trip() {
        let b = backend();
        let (init, source) = b.initial_params("").unwrap();
        assert_eq!(source, "native-init");
        let path = std::env::temp_dir().join(format!("lezo_native_ck_{}", std::process::id()));
        crate::model::checkpoint::save(&path, 5, &init).unwrap();
        let (loaded, src2) = b.initial_params(path.to_str().unwrap()).unwrap();
        assert_eq!(loaded, init);
        assert!(src2.contains("lezo_native_ck"));
        std::fs::remove_file(&path).ok();
        // mismatched checkpoint rejected
        let other = NativeBackend::preset("opt-micro").unwrap();
        let path2 = std::env::temp_dir().join(format!("lezo_native_ck2_{}", std::process::id()));
        crate::model::checkpoint::save(&path2, 0, &other.initial_params("").unwrap().0).unwrap();
        assert!(b.initial_params(path2.to_str().unwrap()).is_err());
        std::fs::remove_file(&path2).ok();
    }
}

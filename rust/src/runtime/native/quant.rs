//! Block-quantized integer weight shadows (`precision = int8 | int4`).
//!
//! # Layout
//!
//! A unit's flat f32 buffer is split into fixed blocks of [`QBLOCK`] = 64
//! elements (the last block may be partial). Each block stores one f32
//! **scale** (`absmax / qmax`, where `qmax` is 127 for int8 and 7 for
//! int4) plus one signed integer **code** per element:
//!
//! - `int8`: one code byte per element (`i8` two's complement);
//! - `int4`: two codes per byte — the element at an **even** flat index
//!   occupies the **low** nibble, its odd successor the high nibble, each
//!   a signed two's-complement nibble in `[-7, 7]`. `QBLOCK` is even, so
//!   block boundaries are always byte-aligned (32 bytes per full block);
//!   an odd-length buffer leaves the final high nibble zero.
//!
//! The decoded weight is `code as f32 * scale`. Codes are produced by
//! `round(x / scale)` (f32 division, round half away from zero — Rust's
//! `f32::round`) clamped to `[-qmax, qmax]`; an all-zero block stores
//! `scale = 0` and zero codes. Non-finite inputs are a **hard error**
//! naming the first offending flat index — the caller (the shadow
//! lifecycle in `runtime/native/mod.rs`) attaches the unit name.
//!
//! Per element this streams `1 + 4/QBLOCK = 1.0625` bytes (int8) or
//! `0.5 + 4/QBLOCK = 0.5625` bytes (int4) instead of 4 — the modeled
//! bandwidth cut that BENCH_native.json's per-precision rows audit.
//!
//! # Exactness contract
//!
//! Decoding is deterministic and elementwise: [`QuantView::get`], the
//! bulk [`QuantView::dequant_range_into`], and the SIMD int8 fast path
//! ([`super::simd::decode_i8`]) all produce bitwise-identical values for
//! a given (codes, scale). The quantized kernels in `super::kernels`
//! decode a panel and then run the *same* f32 inner loops as the f32
//! kernels, so `kernel_q(view, x) == kernel_f32(view.dequant(), x)`
//! holds bitwise by construction — that is the pin `kernel_twins.rs`
//! sweeps.
//!
//! Quantization itself is chunk-parallel over blocks through the same
//! fixed partitioning as every other native kernel (bit-identical at any
//! thread count); the property tests below were validated against a
//! numpy twin (see the KAT table) with the achieved error margins
//! recorded inline.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Result};

use super::parallel::{par_ranges, SendPtr};
use super::simd;
use crate::runtime::backend::Precision;

/// Elements per quantization block. Even (so int4 blocks stay
/// byte-aligned) and small enough that one outlier only damages 64
/// weights' worth of resolution.
pub const QBLOCK: usize = 64;

/// Which integer grid a shadow is quantized onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    Int8,
    Int4,
}

impl QuantMode {
    /// The largest code magnitude on this grid.
    #[inline]
    pub fn qmax(self) -> f32 {
        match self {
            QuantMode::Int8 => 127.0,
            QuantMode::Int4 => 7.0,
        }
    }

    /// Packed code bytes needed for `n` elements.
    #[inline]
    pub fn code_bytes(self, n: usize) -> usize {
        match self {
            QuantMode::Int8 => n,
            QuantMode::Int4 => n.div_ceil(2),
        }
    }

    /// Modeled streamed bytes per weight element (codes + amortized
    /// per-block scale) — the factor BENCH_native.json's byte model uses.
    #[inline]
    pub fn bytes_per_element(self) -> f64 {
        let code_bits = match self {
            QuantMode::Int8 => 8.0,
            QuantMode::Int4 => 4.0,
        };
        code_bits / 8.0 + 4.0 / QBLOCK as f64
    }

    /// The quantized mode for a `Precision`, if it is one.
    #[inline]
    pub fn from_precision(p: Precision) -> Option<QuantMode> {
        match p {
            Precision::Int8 => Some(QuantMode::Int8),
            Precision::Int4 => Some(QuantMode::Int4),
            Precision::F32 | Precision::Bf16 => None,
        }
    }
}

impl std::fmt::Display for QuantMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            QuantMode::Int8 => "int8",
            QuantMode::Int4 => "int4",
        })
    }
}

/// Sign-extend a 4-bit two's-complement nibble to i32.
#[inline(always)]
fn sext4(n: u8) -> i32 {
    ((n & 0xF) as i32 ^ 8) - 8
}

/// Quantize `src` into caller-owned `scales` (`src.len().div_ceil(QBLOCK)`
/// entries) and `codes` (`mode.code_bytes(src.len())` bytes). Chunk-
/// parallel over blocks; bit-identical at any thread count. Errors on the
/// first non-finite input, naming its flat index.
pub fn quantize_into(
    mode: QuantMode,
    src: &[f32],
    scales: &mut [f32],
    codes: &mut [u8],
) -> Result<()> {
    let n = src.len();
    let nb = n.div_ceil(QBLOCK);
    assert_eq!(scales.len(), nb, "scale buffer sized for {nb} blocks");
    assert_eq!(codes.len(), mode.code_bytes(n), "code buffer size");
    let qmax = mode.qmax();
    // First non-finite flat index across all parallel chunks (usize::MAX
    // = none seen). fetch_min keeps the smallest, so the error is
    // deterministic regardless of thread schedule.
    let first_bad = AtomicUsize::new(usize::MAX);
    let scales_ptr = SendPtr(scales.as_mut_ptr());
    let codes_ptr = SendPtr(codes.as_mut_ptr());
    par_ranges(nb, 1024, |r| {
        // SAFETY: block ranges are disjoint; each block owns scale `b`
        // and (because QBLOCK is even) a disjoint byte range of `codes`.
        let out_scales = unsafe { scales_ptr.slice_mut(r.start, r.end - r.start) };
        for (bi, b) in (r.start..r.end).enumerate() {
            let lo = b * QBLOCK;
            let hi = (lo + QBLOCK).min(n);
            let blk = &src[lo..hi];
            let mut absmax = 0.0f32;
            let mut bad = usize::MAX;
            for (i, &v) in blk.iter().enumerate() {
                if !v.is_finite() {
                    bad = bad.min(lo + i);
                } else {
                    absmax = absmax.max(v.abs());
                }
            }
            if bad != usize::MAX {
                first_bad.fetch_min(bad, Ordering::Relaxed);
                continue;
            }
            let scale = absmax / qmax;
            out_scales[bi] = scale;
            match mode {
                QuantMode::Int8 => {
                    let out = unsafe { codes_ptr.slice_mut(lo, hi - lo) };
                    if scale == 0.0 {
                        out.fill(0);
                    } else {
                        for (o, &v) in out.iter_mut().zip(blk) {
                            let c = (v / scale).round().clamp(-qmax, qmax) as i32;
                            *o = c as i8 as u8;
                        }
                    }
                }
                QuantMode::Int4 => {
                    let byte_lo = lo / 2;
                    let byte_hi = hi.div_ceil(2);
                    let out = unsafe { codes_ptr.slice_mut(byte_lo, byte_hi - byte_lo) };
                    if scale == 0.0 {
                        out.fill(0);
                    } else {
                        for (j, o) in out.iter_mut().enumerate() {
                            let e = 2 * j; // even offset within the block
                            let clo = {
                                let v = blk[e];
                                (v / scale).round().clamp(-qmax, qmax) as i32
                            };
                            let chi = if e + 1 < blk.len() {
                                let v = blk[e + 1];
                                (v / scale).round().clamp(-qmax, qmax) as i32
                            } else {
                                0
                            };
                            *o = ((clo as u8) & 0xF) | (((chi as u8) & 0xF) << 4);
                        }
                    }
                }
            }
        }
    });
    let bad = first_bad.load(Ordering::Relaxed);
    if bad != usize::MAX {
        bail!(
            "non-finite weight {} at flat index {bad} cannot be {mode}-quantized",
            src[bad]
        );
    }
    Ok(())
}

/// Convenience: quantize into freshly allocated buffers.
pub fn quantize(mode: QuantMode, src: &[f32]) -> Result<(Vec<f32>, Vec<u8>)> {
    let mut scales = vec![0.0f32; src.len().div_ceil(QBLOCK)];
    let mut codes = vec![0u8; mode.code_bytes(src.len())];
    quantize_into(mode, src, &mut scales, &mut codes)?;
    Ok((scales, codes))
}

/// A read-only window onto a quantized unit: the unit's full per-block
/// `scales` and packed `codes` plus an element `offset`/`len`, so kernels
/// can split a unit into sub-tensors (weight panels, bias rows, embedding
/// rows) without re-aligning anything — block membership is always
/// computed from the *flat* unit index.
#[derive(Clone, Copy)]
pub struct QuantView<'a> {
    mode: QuantMode,
    offset: usize,
    len: usize,
    scales: &'a [f32],
    codes: &'a [u8],
}

impl<'a> QuantView<'a> {
    /// View over a whole unit of `len` elements.
    pub fn new(mode: QuantMode, scales: &'a [f32], codes: &'a [u8], len: usize) -> Self {
        debug_assert_eq!(scales.len(), len.div_ceil(QBLOCK));
        debug_assert_eq!(codes.len(), mode.code_bytes(len));
        QuantView { mode, offset: 0, len, scales, codes }
    }

    #[inline]
    pub fn mode(&self) -> QuantMode {
        self.mode
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Sub-view over elements `[start, end)` of this view (offsets are
    /// relative, like slicing).
    #[inline]
    pub fn split_to(&self, start: usize, end: usize) -> QuantView<'a> {
        debug_assert!(start <= end && end <= self.len);
        QuantView {
            mode: self.mode,
            offset: self.offset + start,
            len: end - start,
            scales: self.scales,
            codes: self.codes,
        }
    }

    /// The integer code of element `i` (tests and the scalar decode).
    #[inline]
    pub fn code_at(&self, i: usize) -> i32 {
        debug_assert!(i < self.len);
        let flat = self.offset + i;
        match self.mode {
            QuantMode::Int8 => self.codes[flat] as i8 as i32,
            QuantMode::Int4 => {
                let byte = self.codes[flat / 2];
                if flat % 2 == 0 {
                    sext4(byte)
                } else {
                    sext4(byte >> 4)
                }
            }
        }
    }

    /// Decode element `i`: `code * scale` (one exact int→f32 conversion,
    /// one correctly-rounded multiply).
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        let flat = self.offset + i;
        self.code_at(i) as f32 * self.scales[flat / QBLOCK]
    }

    /// Bulk-decode this view into `dst` (`dst.len() == self.len()`).
    /// int8 runs the SIMD block decoder over each block-run; int4 decodes
    /// scalar (nibble unpack dominates; documented trade-off). Bitwise
    /// identical to calling [`get`](Self::get) per element.
    pub fn dequant_range_into(&self, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), self.len);
        match self.mode {
            QuantMode::Int8 => {
                let mut i = 0;
                while i < self.len {
                    let flat = self.offset + i;
                    let block = flat / QBLOCK;
                    // run = elements of this view remaining in `block`
                    let run = ((block + 1) * QBLOCK - flat).min(self.len - i);
                    simd::decode_i8(
                        &self.codes[flat..flat + run],
                        self.scales[block],
                        &mut dst[i..i + run],
                    );
                    i += run;
                }
            }
            QuantMode::Int4 => {
                for (i, o) in dst.iter_mut().enumerate() {
                    *o = self.get(i);
                }
            }
        }
    }

    /// Convenience: decode into a fresh Vec (tests, twin references).
    pub fn dequant(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.dequant_range_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(mode: QuantMode, scales: &'a [f32], codes: &'a [u8], n: usize) -> QuantView<'a> {
        QuantView::new(mode, scales, codes, n)
    }

    /// Exhaustive code round-trip: with a power-of-two scale pinned by a
    /// ±qmax element in every block, `quantize(c * s)` must recover every
    /// code `c` exactly, and dequantization reproduces the input
    /// **bitwise** (margin: 0.0 — every `c * s` is representable).
    #[test]
    fn exhaustive_i8_code_round_trip() {
        let s = 0.125f32;
        // interleave [c*s, 127*s] so each QBLOCK-block contains an
        // absmax of exactly 127*s => derived scale == s in every block
        let mut src = Vec::new();
        let mut expect = Vec::new();
        for c in -127i32..=127 {
            src.push(c as f32 * s);
            src.push(127.0 * s);
            expect.push(c);
            expect.push(127);
        }
        let (scales, codes) = quantize(QuantMode::Int8, &src).unwrap();
        for sc in &scales {
            assert_eq!(sc.to_bits(), s.to_bits(), "derived scale must be exact");
        }
        let v = view(QuantMode::Int8, &scales, &codes, src.len());
        for (i, &c) in expect.iter().enumerate() {
            assert_eq!(v.code_at(i), c, "code at {i}");
        }
        let deq = v.dequant();
        for (i, (&d, &x)) in deq.iter().zip(&src).enumerate() {
            assert_eq!(d.to_bits(), x.to_bits(), "round-trip at {i}");
        }
    }

    #[test]
    fn exhaustive_i4_code_round_trip() {
        let s = 0.25f32;
        let mut src = Vec::new();
        let mut expect = Vec::new();
        for c in -7i32..=7 {
            src.push(c as f32 * s);
            src.push(7.0 * s);
            expect.push(c);
            expect.push(7);
        }
        let (scales, codes) = quantize(QuantMode::Int4, &src).unwrap();
        for sc in &scales {
            assert_eq!(sc.to_bits(), s.to_bits(), "derived scale must be exact");
        }
        let v = view(QuantMode::Int4, &scales, &codes, src.len());
        for (i, &c) in expect.iter().enumerate() {
            assert_eq!(v.code_at(i), c, "code at {i}");
        }
        let deq = v.dequant();
        for (i, (&d, &x)) in deq.iter().zip(&src).enumerate() {
            assert_eq!(d.to_bits(), x.to_bits(), "round-trip at {i}");
        }
    }

    /// Partial tails, odd lengths, and views that start mid-block all
    /// decode identically element-wise and in bulk.
    #[test]
    fn partial_blocks_and_offsets_decode_consistently() {
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            for n in [1usize, 2, 63, 64, 65, 127, 128, 129, 254] {
                let src: Vec<f32> =
                    (0..n).map(|i| ((i * 37 + 11) % 97) as f32 - 48.0).collect();
                let (scales, codes) = quantize(mode, &src).unwrap();
                let v = view(mode, &scales, &codes, n);
                let bulk = v.dequant();
                for i in 0..n {
                    assert_eq!(bulk[i].to_bits(), v.get(i).to_bits(), "{mode} n={n} i={i}");
                }
                // mid-block sub-view (embedding-row shape)
                if n > 3 {
                    let sub = v.split_to(1, n - 1);
                    let sub_bulk = sub.dequant();
                    for i in 0..sub.len() {
                        assert_eq!(sub_bulk[i].to_bits(), bulk[i + 1].to_bits());
                    }
                }
            }
        }
    }

    /// All-zero block: scale 0, codes 0, decodes to exact +0.0 — and a
    /// zero block sandwiched between live blocks doesn't disturb them.
    #[test]
    fn all_zero_block_has_zero_scale_and_codes() {
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let mut src = vec![1.0f32; QBLOCK];
            src.extend(std::iter::repeat(0.0f32).take(QBLOCK));
            src.extend(std::iter::repeat(2.0f32).take(10));
            let (scales, codes) = quantize(mode, &src).unwrap();
            assert_eq!(scales.len(), 3);
            assert_eq!(scales[1], 0.0);
            assert!(scales[0] > 0.0 && scales[2] > 0.0);
            let v = view(mode, &scales, &codes, src.len());
            for i in QBLOCK..2 * QBLOCK {
                assert_eq!(v.code_at(i), 0);
                assert_eq!(v.get(i).to_bits(), 0.0f32.to_bits(), "exact +0.0");
            }
            for i in 2 * QBLOCK..src.len() {
                assert_eq!(v.get(i), 2.0, "{mode}: live block after zero block");
            }
        }
    }

    /// Subnormal blocks must not error. When `absmax / qmax` underflows
    /// to zero the whole block quantizes to zero — the error is bounded
    /// by absmax itself (here ~1.4e-45, far below any weight that can
    /// affect a forward), and that behavior is the documented edge.
    #[test]
    fn subnormal_block_quantizes_without_error() {
        let tiny = f32::from_bits(1); // smallest positive subnormal
        let small = f32::from_bits(300); // larger subnormal
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let src = vec![tiny, -tiny, 0.0, tiny];
            let (scales, codes) = quantize(mode, &src).unwrap();
            assert!(scales[0].is_finite());
            let v = view(mode, &scales, &codes, src.len());
            for i in 0..src.len() {
                let d = v.get(i);
                assert!(d.is_finite());
                assert!((d - src[i]).abs() <= tiny, "margin bounded by absmax");
            }
            let src2 = vec![small, -small, small * 0.5];
            let (scales2, codes2) = quantize(mode, &src2).unwrap();
            let v2 = view(mode, &scales2, &codes2, src2.len());
            for i in 0..src2.len() {
                assert!(v2.get(i).is_finite());
                assert!((v2.get(i) - src2[i]).abs() <= small);
            }
        }
    }

    /// NaN / inf hard-error naming the first offending flat index (the
    /// backend wraps this with the unit name).
    #[test]
    fn non_finite_input_errors_with_flat_index() {
        for (bad, tag) in [(f32::NAN, "NaN"), (f32::INFINITY, "inf"), (f32::NEG_INFINITY, "-inf")]
        {
            let mut src = vec![1.0f32; 100];
            src[70] = bad;
            src[90] = bad; // only the first is named
            let err = quantize(QuantMode::Int8, &src).unwrap_err().to_string();
            assert!(err.contains("flat index 70"), "{tag}: {err}");
            assert!(err.contains("non-finite"), "{tag}: {err}");
            let err4 = quantize(QuantMode::Int4, &src).unwrap_err().to_string();
            assert!(err4.contains("flat index 70"), "{tag}: {err4}");
        }
    }

    /// int4 nibble order is part of the format: codes [1, -2] pack to a
    /// single byte 0xE1 (low nibble = even flat index), and an odd-length
    /// buffer zeroes the dangling high nibble.
    #[test]
    fn int4_pack_nibble_order_and_odd_tail() {
        // scale pinned to 1.0 by a 7.0 absmax element
        let src = [1.0f32, -2.0, 7.0];
        let (scales, codes) = quantize(QuantMode::Int4, &src).unwrap();
        assert_eq!(scales[0], 1.0);
        assert_eq!(codes.len(), 2);
        assert_eq!(codes[0], 0xE1, "low nibble 1, high nibble -2 (0xE)");
        assert_eq!(codes[1], 0x07, "odd tail: high nibble zero");
        let v = view(QuantMode::Int4, &scales, &codes, 3);
        assert_eq!(v.code_at(0), 1);
        assert_eq!(v.code_at(1), -2);
        assert_eq!(v.code_at(2), 7);
        // sign extension across the whole nibble range
        for n in 0..16u8 {
            let expect = if n < 8 { n as i32 } else { n as i32 - 16 };
            assert_eq!(sext4(n), expect);
        }
    }

    /// Known-answer vectors generated by a numpy twin of this quantizer
    /// (f32 arithmetic throughout; inputs screened so no code sits near a
    /// rounding tie, making numpy's and Rust's rounding agree exactly).
    /// Tuples: (input f32 bits, expected scale f32 bits, expected codes).
    /// Achieved margins (recorded from the twin): int8 max |dequant - x|
    /// = 8.71e-3 vs scale/2 = 8.88e-3; int4 2.35e-1 vs scale/2 = 2.37e-1.
    #[test]
    fn numpy_twin_kat() {
        type Kat = (&'static [u32], &'static [u32], &'static [i32]);
        const KAT_I8: &[Kat] = &[
            (
                &[
                    0x3EF0E607, 0xBEAC587F, 0x3F791C77, 0x3FCECC83, 0xBF873339, 0x3F29B9EA,
                    0xBF2B7251, 0x3E90C398, 0xBFF16078, 0xBF5B0BFA,
                ],
                &[0x3C734706],
                &[32, -23, 66, 109, -71, 45, -45, 19, -127, -58],
            ),
            (
                &[
                    0xBE20DFE0, 0xBEB7D823, 0x3F8EB191, 0xBF482322, 0x3E4DE2CC, 0x3E5EB638,
                    0xBE3801E8, 0x3D7E010D, 0xBDE92496, 0x3DFC3392, 0x3F8A3D52, 0x3BEAB06F,
                    0x3F960F4E, 0xBE36651D, 0x3FE3CDE6, 0x3F9120A3, 0x3D8B8546, 0x3F97E385,
                    0xBD955B0F, 0xBF5493AE, 0xBF854B8E, 0x3E306207, 0x3FD72209, 0xBF4272C4,
                    0x3F31691A, 0xBE322C4B, 0x3F8D1F16, 0x3F94E64A, 0x3F5EA8B4, 0x3E8A408E,
                    0x3D6779ED, 0x3FA39963, 0x400A8AB2, 0x3EDF0F01, 0xC0106B2D, 0xBE59DEA8,
                    0x3E75652C, 0xBFBAF811, 0xBF1B13A5, 0xBF1B67B6, 0xBE359D83, 0xBF1CA5A3,
                    0x3FA58D23, 0x3F892F62, 0xBF08DEC0, 0x3E5D2601, 0xBFB2F6F2, 0x3FD39BF0,
                    0x3FE6880B, 0x3F9C8DC3, 0xBFEA53C2, 0x3E069E49, 0xBE921051, 0xBDDBCED8,
                    0xBF358D5B, 0x3F6040BE, 0x3F886DA1, 0x3FA5D599, 0x3F284BB0, 0xBCAE08BD,
                    0xBF8FCCF4, 0x3F05F798, 0xBF00B26A, 0x3E19C15B, 0xBE08AB98, 0x3F666D91,
                    0x3E22BD75, 0xBF734854, 0x3F98513D, 0xBF792D0D,
                ],
                &[0x3C918E4A, 0x3C198446],
                &[
                    -9, -20, 63, -44, 11, 12, -10, 3, -6, 7, 61, 0, 66, -10, 100, 64, 4, 67,
                    -4, -47, -59, 10, 95, -43, 39, -10, 62, 65, 49, 15, 3, 72, 122, 25, -127,
                    -12, 13, -82, -34, -34, -10, -34, 73, 60, -30, 12, -79, 93, 101, 69, -103,
                    7, -16, -6, -40, 49, 60, 73, 37, -1, -63, 29, -28, 8, -14, 96, 17, -101,
                    127, -104,
                ],
            ),
        ];
        const KAT_I4: &[Kat] = &[
            (
                &[
                    0x3D385BAD, 0x3F3D8B68, 0x3FB5B6C5, 0x3F1F878E, 0xBEAFAB63, 0x3F25ADC2,
                    0xBF0D9090, 0x3E3F25E3, 0xBF468534, 0x3FF55A77,
                ],
                &[0x3E8C33B2],
                &[0, 3, 5, 2, -1, 2, -2, 1, -3, 7],
            ),
            (
                &[
                    0xBF0B8697, 0x3F9A652E, 0x3FA54072, 0xBED12F2F, 0xBF399291, 0xBFE3B115,
                    0xBF8FB0DA, 0xBE5198F3, 0x3FD1BECD, 0xBECAD759, 0xBF0B3363, 0x3F67A723,
                    0x3C6BA863, 0x3DF7B514, 0xBEE4A069, 0x3F8214E3, 0x3F3562BB, 0x3DBD8FBF,
                    0x3F725690, 0xBFB94AD8, 0xBF5854A5, 0x3EFB6AC1, 0x3E899140, 0xBD1079A0,
                    0xBE6DFADA, 0x3EF16CF4, 0x3FB7A615, 0xBDFB04D1, 0xC0004581, 0x40540F76,
                    0x3E6C6AB6, 0x3E620D93, 0xBF8BA274, 0x40001DA4, 0x3EB87F0D, 0xBE82F00B,
                    0xC0146719, 0xBEE7A52F, 0xBF555107, 0x3F219F9A, 0x401C489F, 0xBFA44F8C,
                    0x3FBBB194, 0x3FCCBAEF, 0xBE16F2D9, 0x3F8710EC, 0x3E0E8B69, 0xBECD9DE7,
                    0x3F7F4161, 0x3F1303BE, 0xBEF9CAA6, 0xBD807F22, 0xBE5D0EB2, 0xBEED5EA8,
                    0xBF12DBBF, 0xBFA25951, 0xBEE40A33, 0xBE00FBE8, 0xBFE2A954, 0xBE85E033,
                    0x3F82CB67, 0x3F1142F0, 0xBF86B330, 0xBFB4349A, 0x3EFB12BA, 0xBF093603,
                    0x3EB19562, 0x3E6C6BEA, 0x3F5CE384, 0xBFD2EAAA,
                ],
                &[0x3EF25AD0, 0x3E710C30],
                &[
                    -1, 3, 3, -1, -2, -4, -2, 0, 3, -1, -1, 2, 0, 0, -1, 2, 1, 0, 2, -3, -2,
                    1, 1, 0, 0, 1, 3, 0, -4, 7, 0, 0, -2, 4, 1, -1, -5, -1, -2, 1, 5, -3, 3,
                    3, 0, 2, 0, -1, 2, 1, -1, 0, 0, -1, -1, -3, -1, 0, -4, -1, 2, 1, -2, -3,
                    2, -2, 1, 1, 4, -7,
                ],
            ),
        ];
        for (mode, kats) in [(QuantMode::Int8, KAT_I8), (QuantMode::Int4, KAT_I4)] {
            for (k, &(src_bits, scale_bits, expect)) in kats.iter().enumerate() {
                let src: Vec<f32> = src_bits.iter().map(|&b| f32::from_bits(b)).collect();
                let (scales, codes) = quantize(mode, &src).unwrap();
                let got_bits: Vec<u32> = scales.iter().map(|s| s.to_bits()).collect();
                assert_eq!(got_bits, scale_bits, "{mode} KAT {k}: scales");
                let v = view(mode, &scales, &codes, src.len());
                let got: Vec<i32> = (0..src.len()).map(|i| v.code_at(i)).collect();
                assert_eq!(got, expect, "{mode} KAT {k}: codes");
            }
        }
    }

    /// Quantization is chunk-parallel; results must be byte-identical at
    /// any thread count (same fixed partitioning as every other kernel).
    #[test]
    fn quantize_is_thread_count_invariant() {
        use super::super::parallel::with_threads;
        let src: Vec<f32> = (0..5000).map(|i| ((i * 71 + 5) % 203) as f32 - 101.0).collect();
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let base = with_threads(1, || quantize(mode, &src).unwrap());
            for t in [2usize, 4, 7] {
                let got = with_threads(t, || quantize(mode, &src).unwrap());
                assert_eq!(
                    base.0.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                    got.0.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                    "{mode} scales at {t} threads"
                );
                assert_eq!(base.1, got.1, "{mode} codes at {t} threads");
            }
        }
    }
}

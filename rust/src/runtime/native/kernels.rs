//! Blocked, thread-parallel, allocation-free CPU kernels for the native
//! hot path — the fast twin of the naive reference in [`super::forward`].
//!
//! Everything here is built on [`super::parallel`]'s fixed-chunk scoped
//! threads, so results are bit-identical at any thread count:
//!
//! - [`axpy_gauss_inplace`] / [`axpy_gauss_masked_inplace`] — in-place ZO
//!   perturb/update sweeps streaming the multi-lane Philox fill
//!   ([`crate::runtime::philox::fill_gauss`]); zero allocations per sweep.
//! - [`matmul_bias_into`] — cache-blocked over the `din` axis (each weight
//!   panel is reused across every row of a chunk), row-parallel.
//! - [`layernorm_into`] / [`gelu_inplace`] — row-/element-parallel with the
//!   reference f64 reductions.
//! - [`attention_into`] — causal multi-head attention parallel over
//!   (row, head) tasks, each writing a disjoint `[seq, d_head]` column
//!   slice of the context buffer.
//! - [`forward_hidden`] / [`forward_hidden_peft`] — the full transformer
//!   forward into a reusable [`ForwardScratch`] arena (buffers allocated
//!   once, reused across matmuls, blocks, and forward calls). The PEFT
//!   variant folds per-block LoRA deltas into the q/v projections as two
//!   skinny matmuls ([`matmul_scaled_acc_into`] — the dense `B·A` delta is
//!   never materialized) and prepends prefix-tuning KV positions inside
//!   [`attention_ctx`] (always visible; the causal window applies to real
//!   positions only).
//! - [`fused_masked_xent`] / [`fused_argmax`] — the streaming LM head: a
//!   per-position logsumexp + gold-logit (or argmax) over vocab tiles that
//!   never materializes the `rows*seq*vocab` logits tensor, the dominant
//!   memory + bandwidth cost at real vocab sizes. The dense tensor remains
//!   available from [`super::forward::forward_logits`] as the slow
//!   reference the fused paths are tested against (≤ 1e-4).
//! - **bf16 twins** (`*_bf16`, the `precision=bf16` forward path): the
//!   bandwidth-bound kernels above re-implemented over [`super::bf16`]
//!   storage — parameters *and* activations held as `u16` bf16 bits,
//!   widened on the fly, accumulated in f32 (f64 exactly where the f32
//!   twin uses f64), rounded once on store. Accumulation order mirrors the
//!   f32 twin element for element, so each bf16 kernel's output equals the
//!   **bitwise** bf16 rounding of its f32 twin run on the widened inputs
//!   (pinned by the `bf16_*` tests below), and results stay bit-identical
//!   at any thread count. PEFT adapters are skinny and stay f32
//!   ([`attention_ctx_bf16`] takes the prefix KV pair as f32;
//!   [`matmul_scaled_acc_into_bf16`] folds the f32 LoRA delta into a bf16
//!   projection, keeping the zero-init-LoRA == base bitwise property).
//! - **quant twins** (`*_quant`, the `precision=int8|int4` forward path):
//!   only the *weights* are block-quantized ([`super::quant`]); activations
//!   stay f32. Each quant kernel decodes a weight panel/row into a small
//!   per-chunk buffer (decoding is elementwise-exact) and then runs the
//!   *identical* f32 inner loop, so `kernel_q(view, x) ==
//!   kernel_f32(view.dequant(), x)` holds **bitwise** by construction —
//!   while the streamed weight bytes drop ~4x (int8) / ~7x (int4).
//! - The innermost blocked-matmul / fused-LM-head loops across *all*
//!   precisions route through [`super::simd`]: runtime-dispatched vector
//!   paths pinned bit-identical to their scalar references (the scalar
//!   twins fix the accumulation lane structure, so vectorizing is legal).

use super::bf16;
use super::parallel::{par_ranges, par_row_chunks, SendPtr};
use super::quant::QuantView;
use super::simd;
use crate::model::spec::ModelSpec;
use crate::peft::PeftMode;
use crate::runtime::philox::fill_gauss;
use anyhow::{ensure, Result};

pub(crate) const LN_EPS: f32 = 1e-5;

/// Stack buffer for streamed Gaussian blocks (8 cache lines).
const ZBUF: usize = 256;
/// Vocab tile width of the streaming LM head (stack-resident logits).
const VOCAB_TILE: usize = 64;
/// `din`-axis block of the matmul: one `MM_IBLOCK x dout` weight panel
/// stays cache-hot across every row of a chunk.
const MM_IBLOCK: usize = 64;

/// Minimum items per chunk so one chunk is worth a thread dispatch:
/// `target_ops / per_item_ops`, floored at 1.
fn grain_for(per_item_ops: usize, target_ops: usize) -> usize {
    (target_ops / per_item_ops.max(1)).max(1)
}

// ---------------------------------------------------------------------------
// ZO sweeps (perturb / flip / restore / update)
// ---------------------------------------------------------------------------

/// In-place `p[i] += coeff * z(seed, i)` — the allocation-free fast path of
/// the four full-parameter sweeps of a ZO step. Chunk-parallel; each
/// element's arithmetic is independent, so any thread count produces the
/// same bits as the allocating reference (`out[i] = p[i] + coeff * z`).
pub fn axpy_gauss_inplace(p: &mut [f32], seed: u32, coeff: f32) {
    let ptr = SendPtr(p.as_mut_ptr());
    let grain = grain_for(160, 500_000); // ~160 ops per Philox+Box-Muller draw
    par_ranges(p.len(), grain, |r| {
        // SAFETY: par_ranges chunks are disjoint element ranges of `p`.
        let chunk = unsafe { ptr.slice_mut(r.start, r.end - r.start) };
        axpy_chunk(chunk, r.start as u32, seed, coeff);
    });
}

fn axpy_chunk(p: &mut [f32], start_idx: u32, seed: u32, coeff: f32) {
    let mut z = [0.0f32; ZBUF];
    let mut done = 0usize;
    while done < p.len() {
        let m = (p.len() - done).min(ZBUF);
        fill_gauss(seed, start_idx.wrapping_add(done as u32), &mut z[..m]);
        for (pv, &zv) in p[done..done + m].iter_mut().zip(&z[..m]) {
            *pv += coeff * zv;
        }
        done += m;
    }
}

/// In-place Sparse-MeZO sweep: `p[i] += coeff * z(seed, i)` where
/// `|pref[i]| <= tau`, else untouched. Same chunking as the dense sweep.
pub fn axpy_gauss_masked_inplace(p: &mut [f32], pref: &[f32], tau: f32, seed: u32, coeff: f32) {
    debug_assert_eq!(p.len(), pref.len());
    let ptr = SendPtr(p.as_mut_ptr());
    let grain = grain_for(160, 500_000);
    par_ranges(p.len(), grain, |r| {
        // SAFETY: par_ranges chunks are disjoint element ranges of `p`.
        let chunk = unsafe { ptr.slice_mut(r.start, r.end - r.start) };
        masked_axpy_chunk(chunk, &pref[r.start..r.end], tau, r.start as u32, seed, coeff);
    });
}

fn masked_axpy_chunk(
    p: &mut [f32],
    pref: &[f32],
    tau: f32,
    start_idx: u32,
    seed: u32,
    coeff: f32,
) {
    let mut z = [0.0f32; ZBUF];
    let mut done = 0usize;
    while done < p.len() {
        let m = (p.len() - done).min(ZBUF);
        fill_gauss(seed, start_idx.wrapping_add(done as u32), &mut z[..m]);
        let zs = &z[..m];
        for ((pv, &q), &zv) in p[done..done + m].iter_mut().zip(&pref[done..done + m]).zip(zs) {
            if q.abs() <= tau {
                *pv += coeff * zv;
            }
        }
        done += m;
    }
}

// ---------------------------------------------------------------------------
// Dense linear algebra
// ---------------------------------------------------------------------------

/// `out[r, o] = b[o] + sum_i x[r, i] * w[i, o]` (`w` row-major
/// `(din, dout)`), cache-blocked and row-parallel. Accumulation order over
/// `i` is ascending regardless of blocking or chunking, so every output
/// element is a pure function of its inputs.
pub fn matmul_bias_into(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n_rows: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(x.len(), n_rows * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(b.len(), dout);
    debug_assert_eq!(out.len(), n_rows * dout);
    let grain = grain_for(din * dout, 250_000); // rows per chunk
    par_row_chunks(out, dout, grain, |r0, orows| {
        for orow in orows.chunks_exact_mut(dout) {
            orow.copy_from_slice(b);
        }
        let mut i0 = 0;
        while i0 < din {
            let i1 = (i0 + MM_IBLOCK).min(din);
            let wpanel = &w[i0 * dout..i1 * dout];
            for (rr, orow) in orows.chunks_exact_mut(dout).enumerate() {
                let xrow = &x[(r0 + rr) * din + i0..(r0 + rr) * din + i1];
                for (&xi, wrow) in xrow.iter().zip(wpanel.chunks_exact(dout)) {
                    simd::axpy_row(orow, xi, wrow);
                }
            }
            i0 = i1;
        }
    });
}

/// `out[r, o] += scale * sum_i x[r, i] * w[i, o]` (`w` row-major
/// `(din, dout)`), row-parallel — the accumulate-into twin of
/// [`matmul_bias_into`], used to fold the skinny LoRA delta
/// `scale * (x A) B` into an already-projected q/v buffer without ever
/// materializing the dense `B·A` matrix. Each output element's inner
/// product over `i` is summed in full (ascending) *before* scaling and
/// adding, so a zero `w` contributes an exact `+0.0` and the destination
/// bits are unchanged — that is what makes a zero-init (B = 0) LoRA
/// forward bitwise-equal to the base forward.
pub fn matmul_scaled_acc_into(
    x: &[f32],
    w: &[f32],
    scale: f32,
    out: &mut [f32],
    n_rows: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(x.len(), n_rows * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(out.len(), n_rows * dout);
    let grain = grain_for(2 * din * dout, 250_000); // rows per chunk
    par_row_chunks(out, dout, grain, |r0, orows| {
        for (rr, orow) in orows.chunks_exact_mut(dout).enumerate() {
            let xrow = &x[(r0 + rr) * din..(r0 + rr + 1) * din];
            for (o, ov) in orow.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (i, &xi) in xrow.iter().enumerate() {
                    acc += xi * w[i * dout + o];
                }
                *ov += scale * acc;
            }
        }
    });
}

/// `h += m`, elementwise.
pub fn add_inplace(h: &mut [f32], m: &[f32]) {
    debug_assert_eq!(h.len(), m.len());
    for (hv, &mv) in h.iter_mut().zip(m) {
        *hv += mv;
    }
}

/// Row-parallel LayerNorm with the reference f64 mean/variance reductions
/// (eps matches kernels/layernorm.py).
pub fn layernorm_into(x: &[f32], gamma: &[f32], beta: &[f32], out: &mut [f32], d: usize) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert!(gamma.len() == d && beta.len() == d);
    let grain = grain_for(4 * d, 65_536);
    par_row_chunks(out, d, grain, |r0, orows| {
        for (rr, orow) in orows.chunks_exact_mut(d).enumerate() {
            let row = &x[(r0 + rr) * d..(r0 + rr + 1) * d];
            let mean = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
            let var = row.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>()
                / d as f64;
            let inv = 1.0 / (var as f32 + LN_EPS).sqrt();
            let mean = mean as f32;
            for ((o, &v), (&g, &bv)) in orow.iter_mut().zip(row).zip(gamma.iter().zip(beta)) {
                *o = (v - mean) * inv * g + bv;
            }
        }
    });
}

pub(crate) fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Elementwise tanh-GELU, chunk-parallel.
pub fn gelu_inplace(a: &mut [f32]) {
    let ptr = SendPtr(a.as_mut_ptr());
    par_ranges(a.len(), grain_for(24, 250_000), |r| {
        // SAFETY: par_ranges chunks are disjoint element ranges of `a`.
        let chunk = unsafe { ptr.slice_mut(r.start, r.end - r.start) };
        for v in chunk.iter_mut() {
            *v = gelu(*v);
        }
    });
}

/// Dot product with four independent accumulators so the reduction
/// vectorizes. The accumulation pattern is fixed per (a, b) pair — it never
/// depends on threads or chunking. Delegates to [`super::simd::dot`],
/// whose vector path is pinned bit-identical to the scalar reference.
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot(a, b)
}

// ---------------------------------------------------------------------------
// Transformer forward
// ---------------------------------------------------------------------------

/// Named views into one flat block unit (layout documented in
/// [`crate::model::spec::ModelSpec`]). Generic over the storage element so
/// the f32 path (`T = f32`, the default) and the bf16 path (`T = u16` bf16
/// bits) split the identical flat layout.
pub(crate) struct BlockParams<'a, T = f32> {
    pub ln1_g: &'a [T],
    pub ln1_b: &'a [T],
    pub wq: &'a [T],
    pub bq: &'a [T],
    pub wk: &'a [T],
    pub bk: &'a [T],
    pub wv: &'a [T],
    pub bv: &'a [T],
    pub wo: &'a [T],
    pub bo: &'a [T],
    pub ln2_g: &'a [T],
    pub ln2_b: &'a [T],
    pub w1: &'a [T],
    pub b1: &'a [T],
    pub w2: &'a [T],
    pub b2: &'a [T],
}

pub(crate) fn split_block<'a, T>(spec: &ModelSpec, mut p: &'a [T]) -> BlockParams<'a, T> {
    let d = spec.d_model;
    let f = spec.d_ff();
    let mut take = |n: usize| -> &'a [T] {
        let (head, rest) = p.split_at(n);
        p = rest;
        head
    };
    BlockParams {
        ln1_g: take(d),
        ln1_b: take(d),
        wq: take(d * d),
        bq: take(d),
        wk: take(d * d),
        bk: take(d),
        wv: take(d * d),
        bv: take(d),
        wo: take(d * d),
        bo: take(d),
        ln2_g: take(d),
        ln2_b: take(d),
        w1: take(d * f),
        b1: take(f),
        w2: take(f * d),
        b2: take(d),
    }
}

/// Per-block adapter views for the PEFT forward (flat layout defined in
/// [`crate::peft`], synced with `python/compile/peft.py`).
pub(crate) enum PeftBlock<'a> {
    None,
    Lora { a_q: &'a [f32], b_q: &'a [f32], a_v: &'a [f32], b_v: &'a [f32] },
    Prefix { k_pre: &'a [f32], v_pre: &'a [f32] },
}

/// View one flat adapter unit as its per-block matrices.
pub(crate) fn peft_block<'a>(mode: PeftMode, unit: &'a [f32], d: usize) -> PeftBlock<'a> {
    match mode {
        PeftMode::Full => PeftBlock::None,
        PeftMode::Lora => {
            let (a_q, b_q, a_v, b_v) = crate::peft::split_lora(unit, d);
            PeftBlock::Lora { a_q, b_q, a_v, b_v }
        }
        PeftMode::Prefix => {
            let (k_pre, v_pre) = crate::peft::split_prefix(unit, d);
            PeftBlock::Prefix { k_pre, v_pre }
        }
    }
}

/// Adapter-argument validation shared by the fast and reference PEFT
/// forwards: one unit per transformer block, each with the exact flat
/// length of [`crate::peft::lora_unit_len`] / [`crate::peft::prefix_unit_len`].
pub(crate) fn validate_peft_args(
    spec: &ModelSpec,
    peft: PeftMode,
    peft_units: &[&[f32]],
) -> Result<()> {
    let want = match peft {
        PeftMode::Full => {
            ensure!(peft_units.is_empty(), "peft=full takes no adapter units");
            return Ok(());
        }
        PeftMode::Lora => crate::peft::lora_unit_len(spec.d_model),
        PeftMode::Prefix => crate::peft::prefix_unit_len(spec.d_model),
    };
    ensure!(
        peft_units.len() == spec.n_layers,
        "peft={peft}: expected {} adapter units (one per block), got {}",
        spec.n_layers,
        peft_units.len()
    );
    for (l, u) in peft_units.iter().enumerate() {
        ensure!(
            u.len() == want,
            "peft={peft}: adapter unit {l} has {} elements, expected {want}",
            u.len()
        );
    }
    Ok(())
}

/// Shared argument validation of every forward family (fast, reference,
/// and the bf16 twins — generic over the unit storage element, it only
/// checks lengths).
pub(crate) fn validate_forward_args<T>(
    spec: &ModelSpec,
    units: &[&[T]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
) -> Result<()> {
    ensure!(
        units.len() == spec.n_units(),
        "expected {} units, got {}",
        spec.n_units(),
        units.len()
    );
    for (k, (u, len)) in units.iter().zip(spec.unit_lens()).enumerate() {
        ensure!(u.len() == len, "unit {k}: expected {len} elements, got {}", u.len());
    }
    ensure!(tokens.len() == rows * seq, "tokens shape mismatch");
    ensure!(seq <= spec.max_seq, "seq {seq} exceeds max_seq {}", spec.max_seq);
    ensure!(
        tokens.iter().all(|&t| t >= 0 && (t as usize) < spec.vocab),
        "token id out of vocab range"
    );
    Ok(())
}

/// Loss-target validation: an in-mask target must be a valid vocab id (a
/// hard error otherwise — a silently clamped gold index scores the wrong
/// token); out-of-mask positions may hold anything (padding) because they
/// never reach the gold-logit lookup.
pub(crate) fn validate_targets(
    targets: &[i32],
    mask: &[f32],
    n: usize,
    vocab: usize,
) -> Result<()> {
    ensure!(targets.len() == n && mask.len() == n, "targets/mask shape mismatch");
    for (p, (&t, &m)) in targets.iter().zip(mask).enumerate() {
        if m > 0.0 {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "target {t} at loss-masked position {p} is outside the vocab (0..{vocab})"
            );
        }
    }
    Ok(())
}

/// Reusable forward arena: the per-block q/k/v/ctx/ffn buffers plus the
/// residual stream, allocated once and reused across matmuls, blocks, and
/// forward calls (`ensure` only grows them). The final-LN hidden states
/// land in `x`; `xent` holds per-position losses for the fused head.
///
/// The bf16 path has its own half of the arena (`*b` buffers, `u16` bf16
/// bits — the final-LN hidden states land in `xb`): a bf16 forward streams
/// half the activation bytes of an f32 one, and the two precision paths
/// never alias each other's buffers. `lora_tmp` is the skinny f32 LoRA
/// projection temporary of the bf16 path (the f32 path borrows the idle
/// `ffn` buffer instead). The bf16 path keeps exactly one f32
/// activation-sized buffer: `ffn` doubles as the bf16 matmuls' f32
/// accumulation arena, so they stay allocation-free.
#[derive(Default)]
pub struct ForwardScratch {
    pub h: Vec<f32>,
    pub x: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub ctx: Vec<f32>,
    pub ffn: Vec<f32>,
    pub xent: Vec<f32>,
    pub hb: Vec<u16>,
    pub xb: Vec<u16>,
    pub qb: Vec<u16>,
    pub kb: Vec<u16>,
    pub vb: Vec<u16>,
    pub ctxb: Vec<u16>,
    pub ffnb: Vec<u16>,
    pub lora_tmp: Vec<f32>,
}

impl ForwardScratch {
    pub fn new() -> ForwardScratch {
        ForwardScratch::default()
    }

    fn ensure(&mut self, n: usize, d: usize, f: usize) {
        for buf in [&mut self.h, &mut self.x, &mut self.q, &mut self.k, &mut self.v, &mut self.ctx]
        {
            if buf.len() < n * d {
                buf.resize(n * d, 0.0);
            }
        }
        if self.ffn.len() < n * f {
            self.ffn.resize(n * f, 0.0);
        }
        if self.xent.len() < n {
            self.xent.resize(n, 0.0);
        }
    }

    fn ensure_bf16(&mut self, n: usize, d: usize, f: usize) {
        for buf in
            [&mut self.hb, &mut self.xb, &mut self.qb, &mut self.kb, &mut self.vb, &mut self.ctxb]
        {
            if buf.len() < n * d {
                buf.resize(n * d, 0);
            }
        }
        if self.ffnb.len() < n * f {
            self.ffnb.resize(n * f, 0);
        }
        // the one f32 activation-sized buffer the bf16 path keeps: `ffn`
        // doubles as the matmul f32 accumulation arena (`f >= d` covers
        // every projection), so bf16 matmuls allocate nothing per call
        if self.ffn.len() < n * f {
            self.ffn.resize(n * f, 0.0);
        }
        if self.xent.len() < n {
            self.xent.resize(n, 0.0);
        }
        if self.lora_tmp.len() < n * crate::peft::LORA_RANK {
            self.lora_tmp.resize(n * crate::peft::LORA_RANK, 0.0);
        }
    }
}

/// Causal softmax attention context from projected q/k/v: the per-(row,
/// head) weighted sum of values, written into `ctx`. Parallel over (row,
/// head) tasks; task `(r, head)` writes only the `[seq, d_head]` column
/// slice of `ctx` at head offset `head * d_head` within batch row `r` —
/// disjoint across tasks. Shared by the forward fast path and the FO
/// backward pass (which records `ctx` for the Wo gradient).
///
/// `prefix` is prefix tuning's `(K_pre, V_pre)` pair of learned virtual KV
/// positions, each row-major `[n_pre, d]` and shared across batch rows.
/// Prefix positions sit *before* the real positions in the score layout
/// (matching the python twin's concatenation order) and are visible to
/// every query — the causal window applies to real positions only. With
/// `None` the score loop degenerates to the plain causal case and the
/// emitted bits are identical to the pre-PEFT kernel.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_ctx(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    prefix: Option<(&[f32], &[f32])>,
    ctx: &mut [f32],
    d: usize,
    nh: usize,
    rows: usize,
    seq: usize,
) {
    let dh = d / nh;
    let scale = 1.0 / (dh as f32).sqrt();
    let n_pre = prefix.map_or(0, |(k_pre, _)| k_pre.len() / d);
    debug_assert!(prefix
        .map_or(true, |(kp, vp)| kp.len() == n_pre * d && vp.len() == n_pre * d));
    let ctx_ptr = SendPtr(ctx.as_mut_ptr());
    let grain = grain_for(seq * (n_pre + seq) * dh, 100_000);
    par_ranges(rows * nh, grain, |tasks| {
        let mut scores = vec![0.0f32; n_pre + seq];
        for t in tasks {
            let (r, head) = (t / nh, t % nh);
            let hoff = head * dh;
            for s1 in 0..seq {
                let qrow = &q[(r * seq + s1) * d + hoff..][..dh];
                let visible = n_pre + s1 + 1;
                let mut max = f32::NEG_INFINITY;
                // prefix keys: always visible, before the causal window
                if let Some((k_pre, _)) = prefix {
                    for (p, sv) in scores[..n_pre].iter_mut().enumerate() {
                        let krow = &k_pre[p * d + hoff..][..dh];
                        let s = dot(qrow, krow) * scale;
                        *sv = s;
                        max = max.max(s);
                    }
                }
                // causal scores over real positions s2 <= s1
                for (s2, sv) in scores[n_pre..visible].iter_mut().enumerate() {
                    let krow = &k[(r * seq + s2) * d + hoff..][..dh];
                    let s = dot(qrow, krow) * scale;
                    *sv = s;
                    max = max.max(s);
                }
                let mut denom = 0.0f32;
                for sv in scores[..visible].iter_mut() {
                    *sv = (*sv - max).exp();
                    denom += *sv;
                }
                // SAFETY: (r, head) tasks own disjoint (row, head-column)
                // slices of ctx; s1 iterates rows within the task.
                let orow = unsafe { ctx_ptr.slice_mut((r * seq + s1) * d + hoff, dh) };
                orow.fill(0.0);
                if let Some((_, v_pre)) = prefix {
                    for (p, &sv) in scores[..n_pre].iter().enumerate() {
                        let w = sv / denom;
                        let vrow = &v_pre[p * d + hoff..][..dh];
                        for (o, &vv) in orow.iter_mut().zip(vrow) {
                            *o += w * vv;
                        }
                    }
                }
                for (s2, &sv) in scores[n_pre..visible].iter().enumerate() {
                    let w = sv / denom;
                    let vrow = &v[(r * seq + s2) * d + hoff..][..dh];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            }
        }
    });
}

/// Causal multi-head attention + output projection added into `h`, with
/// the block's PEFT adapter folded in. LoRA deltas run as two skinny
/// matmuls through `lora_tmp` (`[n, LORA_RANK]`, borrowed from the free
/// ffn arena — attention never touches it); prefix KV positions are handed
/// straight to [`attention_ctx`]. `q` is reused as the projection buffer
/// afterwards.
#[allow(clippy::too_many_arguments)]
fn attention_into(
    h: &mut [f32],
    x: &[f32],
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
    ctx: &mut [f32],
    p: &BlockParams<'_>,
    peft: &PeftBlock<'_>,
    d: usize,
    nh: usize,
    rows: usize,
    seq: usize,
    lora_tmp: &mut [f32],
) {
    const LORA_ZERO_BIAS: [f32; crate::peft::LORA_RANK] = [0.0; crate::peft::LORA_RANK];
    let n = rows * seq;
    matmul_bias_into(x, p.wq, p.bq, q, n, d, d);
    matmul_bias_into(x, p.wk, p.bk, k, n, d, d);
    matmul_bias_into(x, p.wv, p.bv, v, n, d, d);
    let mut prefix = None;
    match peft {
        PeftBlock::None => {}
        PeftBlock::Lora { a_q, b_q, a_v, b_v } => {
            let r = crate::peft::LORA_RANK;
            let scale = (crate::peft::LORA_ALPHA / r as f64) as f32;
            let tmp = &mut lora_tmp[..n * r];
            matmul_bias_into(x, a_q, &LORA_ZERO_BIAS, tmp, n, d, r);
            matmul_scaled_acc_into(tmp, b_q, scale, q, n, r, d);
            matmul_bias_into(x, a_v, &LORA_ZERO_BIAS, tmp, n, d, r);
            matmul_scaled_acc_into(tmp, b_v, scale, v, n, r, d);
        }
        PeftBlock::Prefix { k_pre, v_pre } => prefix = Some((*k_pre, *v_pre)),
    }
    attention_ctx(q, k, v, prefix, ctx, d, nh, rows, seq);
    matmul_bias_into(ctx, p.wo, p.bo, q, n, d, d);
    add_inplace(h, q);
}

/// Full transformer forward. On success the final-LN hidden states (the LM
/// head input) are in `scratch.x[..rows*seq*d_model]`. Delegates to
/// [`forward_hidden_peft`] with no adapters.
pub fn forward_hidden(
    spec: &ModelSpec,
    units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<()> {
    forward_hidden_peft(spec, units, PeftMode::Full, &[], tokens, rows, seq, scratch)
}

/// Full transformer forward with optional per-block PEFT adapters
/// (`peft_units`: one flat unit per transformer block, layout from
/// [`crate::peft`]). LoRA folds `(alpha/r) * (x A) B` into the q/v
/// projections; prefix tuning prepends its learned KV positions inside
/// [`attention_ctx`]. Runs entirely in the reusable scratch arena — PEFT
/// forwards stay allocation-free like the base path (the LoRA temporary
/// borrows the ffn buffer, which is idle during attention).
#[allow(clippy::too_many_arguments)]
pub fn forward_hidden_peft(
    spec: &ModelSpec,
    units: &[&[f32]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<()> {
    validate_forward_args(spec, units, tokens, rows, seq)?;
    validate_peft_args(spec, peft, peft_units)?;
    let d = spec.d_model;
    let f = spec.d_ff();
    let n = rows * seq;
    scratch.ensure(n, d, f);
    let ForwardScratch { h, x, q, k, v, ctx, ffn, .. } = scratch;
    let h = &mut h[..n * d];
    let x = &mut x[..n * d];
    let q = &mut q[..n * d];
    let k = &mut k[..n * d];
    let v = &mut v[..n * d];
    let ctx = &mut ctx[..n * d];
    let ffn = &mut ffn[..n * f];

    // embed
    let emb = units[0];
    let tok_emb = &emb[..spec.vocab * d];
    let pos_emb = &emb[spec.vocab * d..];
    for r in 0..rows {
        for s in 0..seq {
            let t = tokens[r * seq + s] as usize;
            let hrow = &mut h[(r * seq + s) * d..(r * seq + s + 1) * d];
            let te = &tok_emb[t * d..(t + 1) * d];
            let pe = &pos_emb[s * d..(s + 1) * d];
            for ((hv, &tv), &pv) in hrow.iter_mut().zip(te).zip(pe) {
                *hv = tv + pv;
            }
        }
    }

    // blocks
    for l in 0..spec.n_layers {
        let p = split_block(spec, units[1 + l]);
        let pb = match peft {
            PeftMode::Full => PeftBlock::None,
            _ => peft_block(peft, peft_units[l], d),
        };
        layernorm_into(h, p.ln1_g, p.ln1_b, x, d);
        attention_into(h, x, q, k, v, ctx, &p, &pb, d, spec.n_heads, rows, seq, ffn);
        layernorm_into(h, p.ln2_g, p.ln2_b, x, d);
        matmul_bias_into(x, p.w1, p.b1, ffn, n, d, f);
        gelu_inplace(ffn);
        matmul_bias_into(ffn, p.w2, p.b2, q, n, f, d);
        add_inplace(h, q);
    }

    // final LN (the tied LM head consumes scratch.x)
    let fin = units[spec.n_units() - 1];
    layernorm_into(h, &fin[..d], &fin[d..], x, d);
    Ok(())
}

// ---------------------------------------------------------------------------
// Streaming (fused) LM head
// ---------------------------------------------------------------------------

/// Per-position masked cross-entropy via a streaming logsumexp + gold-logit
/// over vocab tiles — the `n * vocab` logits tensor is never materialized.
/// `xent[p] = 0` where `mask[p] == 0` (those positions are skipped
/// entirely). Targets must already be validated by [`validate_targets`].
/// Position-parallel; each position's reduction order is fixed (ascending
/// vocab tiles), so results are thread-count invariant.
#[allow(clippy::too_many_arguments)]
pub fn fused_masked_xent(
    hf: &[f32],
    tok_emb: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    vocab: usize,
    d: usize,
    xent: &mut [f32],
) {
    debug_assert!(hf.len() == n * d && tok_emb.len() == vocab * d);
    debug_assert!(targets.len() == n && mask.len() == n && xent.len() == n);
    let ptr = SendPtr(xent.as_mut_ptr());
    let grain = grain_for(2 * vocab * d, 2_000_000);
    par_ranges(n, grain, |range| {
        // SAFETY: par_ranges chunks are disjoint position ranges of `xent`.
        let out = unsafe { ptr.slice_mut(range.start, range.end - range.start) };
        for (o, p) in out.iter_mut().zip(range) {
            if mask[p] <= 0.0 {
                *o = 0.0;
                continue;
            }
            let hrow = &hf[p * d..(p + 1) * d];
            let gold_t = targets[p] as usize; // validated in-range
            let mut running_max = f32::NEG_INFINITY;
            let mut sum = 0.0f64;
            let mut gold = 0.0f32;
            let mut tile = [0.0f32; VOCAB_TILE];
            let mut t0 = 0;
            while t0 < vocab {
                let t1 = (t0 + VOCAB_TILE).min(vocab);
                let tile = &mut tile[..t1 - t0];
                for (lv, erow) in tile.iter_mut().zip(tok_emb[t0 * d..t1 * d].chunks_exact(d)) {
                    *lv = dot(hrow, erow);
                }
                let tile_max = tile.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                if tile_max > running_max {
                    sum *= ((running_max - tile_max) as f64).exp();
                    running_max = tile_max;
                }
                for &l in tile.iter() {
                    sum += ((l - running_max) as f64).exp();
                }
                if gold_t >= t0 && gold_t < t1 {
                    gold = tile[gold_t - t0];
                }
                t0 = t1;
            }
            let logz = running_max as f64 + sum.ln();
            *o = (logz - gold as f64) as f32;
        }
    });
}

/// Streaming greedy argmax over vocab tiles (ties resolve to the lowest
/// token id via strict `>` in ascending order, like the dense reference).
pub fn fused_argmax(
    hf: &[f32],
    tok_emb: &[f32],
    n: usize,
    vocab: usize,
    d: usize,
    preds: &mut [i32],
) {
    debug_assert!(hf.len() == n * d && tok_emb.len() == vocab * d && preds.len() == n);
    let ptr = SendPtr(preds.as_mut_ptr());
    let grain = grain_for(2 * vocab * d, 2_000_000);
    par_ranges(n, grain, |range| {
        // SAFETY: par_ranges chunks are disjoint position ranges of `preds`.
        let out = unsafe { ptr.slice_mut(range.start, range.end - range.start) };
        for (o, p) in out.iter_mut().zip(range) {
            let hrow = &hf[p * d..(p + 1) * d];
            let mut best = 0usize;
            let mut best_val = f32::NEG_INFINITY;
            for (t, erow) in tok_emb.chunks_exact(d).enumerate() {
                let l = dot(hrow, erow);
                if l > best_val {
                    best_val = l;
                    best = t;
                }
            }
            *o = best as i32;
        }
    });
}

// ---------------------------------------------------------------------------
// bf16 twins: reduced-precision storage, f32 accumulation
// ---------------------------------------------------------------------------
//
// Every kernel below mirrors its f32 twin's accumulation order element for
// element — operands are widened on the fly, summed in f32 (f64 where the
// twin uses f64), and rounded to bf16 exactly once on store. The payoff is
// a strong invariant the tests pin bitwise: `twin_bf16(inputs) ==
// bf16(twin_f32(widen(inputs)))`. It also inherits the determinism rule
// for free: fixed chunking + per-element fixed reduction order means
// results are bit-identical at any thread count.

/// [`dot`] over bf16 operands: widen on the fly, same 4-accumulator
/// pattern, so the f32 result equals `dot(widen(a), widen(b))` bitwise.
/// Delegates to [`super::simd::dot_bf16`] (vector path pinned bit-identical
/// to the scalar reference).
#[inline]
pub(crate) fn dot_bf16(a: &[u16], b: &[u16]) -> f32 {
    simd::dot_bf16(a, b)
}

/// Mixed dot: bf16 activations against f32 parameters (the prefix-tuning
/// KV pairs, which stay f32 — adapters are skinny).
#[inline]
fn dot_bf16_f32(a: &[u16], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() - a.len() % 4;
    let mut acc = [0.0f32; 4];
    for (pa, pb) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        acc[0] += bf16::to_f32(pa[0]) * pb[0];
        acc[1] += bf16::to_f32(pa[1]) * pb[1];
        acc[2] += bf16::to_f32(pa[2]) * pb[2];
        acc[3] += bf16::to_f32(pa[3]) * pb[3];
    }
    let mut tail = 0.0f32;
    for (&xv, &yv) in a[n4..].iter().zip(&b[n4..]) {
        tail += bf16::to_f32(xv) * yv;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// bf16 twin of [`matmul_bias_into`]: bf16 x/w/b and output, cache-blocked
/// with the identical `MM_IBLOCK` / ascending-`i` accumulation in the
/// caller-provided f32 panel `acc` (`>= n_rows * dout`; the forward passes
/// the idle f32 `ffn` arena, so the hot path stays allocation-free),
/// rounded once on store. Chunks write disjoint row ranges of both `out`
/// and `acc`.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias_into_bf16(
    x: &[u16],
    w: &[u16],
    b: &[u16],
    out: &mut [u16],
    acc: &mut [f32],
    n_rows: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(x.len(), n_rows * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(b.len(), dout);
    debug_assert_eq!(out.len(), n_rows * dout);
    debug_assert!(acc.len() >= n_rows * dout);
    let acc_ptr = SendPtr(acc.as_mut_ptr());
    let grain = grain_for(din * dout, 250_000); // rows per chunk
    par_row_chunks(out, dout, grain, |r0, orows| {
        // SAFETY: chunks are disjoint row ranges, so the acc panel slices
        // are disjoint exactly like the `out` slices.
        let acc = unsafe { acc_ptr.slice_mut(r0 * dout, orows.len()) };
        for arow in acc.chunks_exact_mut(dout) {
            for (a, &bv) in arow.iter_mut().zip(b) {
                *a = bf16::to_f32(bv);
            }
        }
        let mut i0 = 0;
        while i0 < din {
            let i1 = (i0 + MM_IBLOCK).min(din);
            let wpanel = &w[i0 * dout..i1 * dout];
            for (rr, arow) in acc.chunks_exact_mut(dout).enumerate() {
                let xrow = &x[(r0 + rr) * din + i0..(r0 + rr) * din + i1];
                for (&xi, wrow) in xrow.iter().zip(wpanel.chunks_exact(dout)) {
                    simd::axpy_row_bf16(arow, bf16::to_f32(xi), wrow);
                }
            }
            i0 = i1;
        }
        for (o, &a) in orows.iter_mut().zip(acc.iter()) {
            *o = bf16::to_bits(a);
        }
    });
}

/// The LoRA `tmp = x @ A` projection of the bf16 path: bf16 activations
/// against the f32 adapter matrix into an f32 temporary (skinny — `dout`
/// is the LoRA rank), mirroring [`matmul_bias_into`]'s zero-bias blocked
/// accumulation.
pub fn lora_a_proj_bf16(
    x: &[u16],
    a: &[f32],
    out: &mut [f32],
    n_rows: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(x.len(), n_rows * din);
    debug_assert_eq!(a.len(), din * dout);
    debug_assert_eq!(out.len(), n_rows * dout);
    let grain = grain_for(din * dout, 250_000);
    par_row_chunks(out, dout, grain, |r0, orows| {
        for orow in orows.chunks_exact_mut(dout) {
            orow.fill(0.0);
        }
        let mut i0 = 0;
        while i0 < din {
            let i1 = (i0 + MM_IBLOCK).min(din);
            let wpanel = &a[i0 * dout..i1 * dout];
            for (rr, orow) in orows.chunks_exact_mut(dout).enumerate() {
                let xrow = &x[(r0 + rr) * din + i0..(r0 + rr) * din + i1];
                for (&xi, wrow) in xrow.iter().zip(wpanel.chunks_exact(dout)) {
                    simd::axpy_row(orow, bf16::to_f32(xi), wrow);
                }
            }
            i0 = i1;
        }
    });
}

/// bf16 twin of [`matmul_scaled_acc_into`]: fold `scale * (tmp @ B)` (both
/// f32 — the skinny LoRA delta) into a bf16 projection. The inner product
/// is summed in full before scaling and adding to the *widened*
/// destination, then rounded — so a zero `w` adds an exact `+0.0` to an
/// exactly-representable value and the destination bits are unchanged:
/// zero-init LoRA stays bitwise-equal to the base forward in bf16 too.
pub fn matmul_scaled_acc_into_bf16(
    x: &[f32],
    w: &[f32],
    scale: f32,
    out: &mut [u16],
    n_rows: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(x.len(), n_rows * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(out.len(), n_rows * dout);
    let grain = grain_for(2 * din * dout, 250_000);
    par_row_chunks(out, dout, grain, |r0, orows| {
        for (rr, orow) in orows.chunks_exact_mut(dout).enumerate() {
            let xrow = &x[(r0 + rr) * din..(r0 + rr + 1) * din];
            for (o, ov) in orow.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (i, &xi) in xrow.iter().enumerate() {
                    acc += xi * w[i * dout + o];
                }
                *ov = bf16::to_bits(bf16::to_f32(*ov) + scale * acc);
            }
        }
    });
}

/// bf16 residual add: `h = bf16(widen(h) + widen(m))`, elementwise.
pub fn add_inplace_bf16(h: &mut [u16], m: &[u16]) {
    debug_assert_eq!(h.len(), m.len());
    for (hv, &mv) in h.iter_mut().zip(m) {
        *hv = bf16::to_bits(bf16::to_f32(*hv) + bf16::to_f32(mv));
    }
}

/// bf16 twin of [`layernorm_into`]: identical f64 mean/variance reductions
/// over the widened row, normalized output rounded on store.
pub fn layernorm_into_bf16(x: &[u16], gamma: &[u16], beta: &[u16], out: &mut [u16], d: usize) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert!(gamma.len() == d && beta.len() == d);
    let grain = grain_for(4 * d, 65_536);
    par_row_chunks(out, d, grain, |r0, orows| {
        for (rr, orow) in orows.chunks_exact_mut(d).enumerate() {
            let row = &x[(r0 + rr) * d..(r0 + rr + 1) * d];
            let mean = row.iter().map(|&v| bf16::to_f32(v) as f64).sum::<f64>() / d as f64;
            let var = row
                .iter()
                .map(|&v| (bf16::to_f32(v) as f64 - mean) * (bf16::to_f32(v) as f64 - mean))
                .sum::<f64>()
                / d as f64;
            let inv = 1.0 / (var as f32 + LN_EPS).sqrt();
            let mean = mean as f32;
            for ((o, &v), (&g, &bv)) in orow.iter_mut().zip(row).zip(gamma.iter().zip(beta)) {
                *o = bf16::to_bits(
                    (bf16::to_f32(v) - mean) * inv * bf16::to_f32(g) + bf16::to_f32(bv),
                );
            }
        }
    });
}

/// bf16 elementwise tanh-GELU, chunk-parallel.
pub fn gelu_inplace_bf16(a: &mut [u16]) {
    let ptr = SendPtr(a.as_mut_ptr());
    par_ranges(a.len(), grain_for(24, 250_000), |r| {
        // SAFETY: par_ranges chunks are disjoint element ranges of `a`.
        let chunk = unsafe { ptr.slice_mut(r.start, r.end - r.start) };
        for v in chunk.iter_mut() {
            *v = bf16::to_bits(gelu(bf16::to_f32(*v)));
        }
    });
}

/// bf16 twin of [`attention_ctx`]: bf16 q/k/v and context, f32 scores and
/// softmax, per-(row, head) f32 context accumulator rounded on store. The
/// prefix KV pair stays f32 (prefix tuning's adapters are skinny); its
/// score/value loops mirror the f32 kernel with the widened query.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_ctx_bf16(
    q: &[u16],
    k: &[u16],
    v: &[u16],
    prefix: Option<(&[f32], &[f32])>,
    ctx: &mut [u16],
    d: usize,
    nh: usize,
    rows: usize,
    seq: usize,
) {
    let dh = d / nh;
    let scale = 1.0 / (dh as f32).sqrt();
    let n_pre = prefix.map_or(0, |(k_pre, _)| k_pre.len() / d);
    debug_assert!(prefix.map_or(true, |(kp, vp)| kp.len() == n_pre * d && vp.len() == n_pre * d));
    let ctx_ptr = SendPtr(ctx.as_mut_ptr());
    let grain = grain_for(seq * (n_pre + seq) * dh, 100_000);
    par_ranges(rows * nh, grain, |tasks| {
        let mut scores = vec![0.0f32; n_pre + seq];
        let mut acc = vec![0.0f32; dh];
        for t in tasks {
            let (r, head) = (t / nh, t % nh);
            let hoff = head * dh;
            for s1 in 0..seq {
                let qrow = &q[(r * seq + s1) * d + hoff..][..dh];
                let visible = n_pre + s1 + 1;
                let mut max = f32::NEG_INFINITY;
                if let Some((k_pre, _)) = prefix {
                    for (p, sv) in scores[..n_pre].iter_mut().enumerate() {
                        let krow = &k_pre[p * d + hoff..][..dh];
                        let s = dot_bf16_f32(qrow, krow) * scale;
                        *sv = s;
                        max = max.max(s);
                    }
                }
                for (s2, sv) in scores[n_pre..visible].iter_mut().enumerate() {
                    let krow = &k[(r * seq + s2) * d + hoff..][..dh];
                    let s = dot_bf16(qrow, krow) * scale;
                    *sv = s;
                    max = max.max(s);
                }
                let mut denom = 0.0f32;
                for sv in scores[..visible].iter_mut() {
                    *sv = (*sv - max).exp();
                    denom += *sv;
                }
                acc.fill(0.0);
                if let Some((_, v_pre)) = prefix {
                    for (p, &sv) in scores[..n_pre].iter().enumerate() {
                        let w = sv / denom;
                        let vrow = &v_pre[p * d + hoff..][..dh];
                        for (o, &vv) in acc.iter_mut().zip(vrow) {
                            *o += w * vv;
                        }
                    }
                }
                for (s2, &sv) in scores[n_pre..visible].iter().enumerate() {
                    let w = sv / denom;
                    let vrow = &v[(r * seq + s2) * d + hoff..][..dh];
                    for (o, &vv) in acc.iter_mut().zip(vrow) {
                        *o += w * bf16::to_f32(vv);
                    }
                }
                // SAFETY: (r, head) tasks own disjoint (row, head-column)
                // slices of ctx; s1 iterates rows within the task.
                let orow = unsafe { ctx_ptr.slice_mut((r * seq + s1) * d + hoff, dh) };
                for (o, &a) in orow.iter_mut().zip(&acc) {
                    *o = bf16::to_bits(a);
                }
            }
        }
    });
}

/// bf16 twin of the private f32 `attention_into`: projections, adapter
/// fold, context, output projection, residual add — all on bf16 buffers
/// with f32 adapters. `q` is reused as the projection buffer afterwards;
/// `acc` is the shared f32 matmul accumulation arena.
#[allow(clippy::too_many_arguments)]
fn attention_into_bf16(
    h: &mut [u16],
    x: &[u16],
    q: &mut [u16],
    k: &mut [u16],
    v: &mut [u16],
    ctx: &mut [u16],
    p: &BlockParams<'_, u16>,
    peft: &PeftBlock<'_>,
    d: usize,
    nh: usize,
    rows: usize,
    seq: usize,
    lora_tmp: &mut [f32],
    acc: &mut [f32],
) {
    let n = rows * seq;
    matmul_bias_into_bf16(x, p.wq, p.bq, q, acc, n, d, d);
    matmul_bias_into_bf16(x, p.wk, p.bk, k, acc, n, d, d);
    matmul_bias_into_bf16(x, p.wv, p.bv, v, acc, n, d, d);
    let mut prefix = None;
    match peft {
        PeftBlock::None => {}
        PeftBlock::Lora { a_q, b_q, a_v, b_v } => {
            let r = crate::peft::LORA_RANK;
            let scale = (crate::peft::LORA_ALPHA / r as f64) as f32;
            let tmp = &mut lora_tmp[..n * r];
            lora_a_proj_bf16(x, a_q, tmp, n, d, r);
            matmul_scaled_acc_into_bf16(tmp, b_q, scale, q, n, r, d);
            lora_a_proj_bf16(x, a_v, tmp, n, d, r);
            matmul_scaled_acc_into_bf16(tmp, b_v, scale, v, n, r, d);
        }
        PeftBlock::Prefix { k_pre, v_pre } => prefix = Some((*k_pre, *v_pre)),
    }
    attention_ctx_bf16(q, k, v, prefix, ctx, d, nh, rows, seq);
    matmul_bias_into_bf16(ctx, p.wo, p.bo, q, acc, n, d, d);
    add_inplace_bf16(h, q);
}

/// bf16 twin of [`forward_hidden_peft`]: the full transformer forward over
/// bf16 unit shadows and bf16 activations (f32 adapters under PEFT). On
/// success the final-LN hidden states are in `scratch.xb[..rows*seq*d]`.
#[allow(clippy::too_many_arguments)]
pub fn forward_hidden_bf16_peft(
    spec: &ModelSpec,
    units: &[&[u16]],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<()> {
    validate_forward_args(spec, units, tokens, rows, seq)?;
    validate_peft_args(spec, peft, peft_units)?;
    let d = spec.d_model;
    let f = spec.d_ff();
    let n = rows * seq;
    scratch.ensure_bf16(n, d, f);
    let ForwardScratch { hb, xb, qb, kb, vb, ctxb, ffnb, lora_tmp, ffn: acc, .. } = scratch;
    let h = &mut hb[..n * d];
    let x = &mut xb[..n * d];
    let q = &mut qb[..n * d];
    let k = &mut kb[..n * d];
    let v = &mut vb[..n * d];
    let ctx = &mut ctxb[..n * d];
    let ffn = &mut ffnb[..n * f];
    let acc = &mut acc[..n * f]; // shared f32 matmul accumulation arena

    // embed
    let emb = units[0];
    let tok_emb = &emb[..spec.vocab * d];
    let pos_emb = &emb[spec.vocab * d..];
    for r in 0..rows {
        for s in 0..seq {
            let t = tokens[r * seq + s] as usize;
            let hrow = &mut h[(r * seq + s) * d..(r * seq + s + 1) * d];
            let te = &tok_emb[t * d..(t + 1) * d];
            let pe = &pos_emb[s * d..(s + 1) * d];
            for ((hv, &tv), &pv) in hrow.iter_mut().zip(te).zip(pe) {
                *hv = bf16::to_bits(bf16::to_f32(tv) + bf16::to_f32(pv));
            }
        }
    }

    // blocks
    for l in 0..spec.n_layers {
        let p = split_block(spec, units[1 + l]);
        let pb = match peft {
            PeftMode::Full => PeftBlock::None,
            _ => peft_block(peft, peft_units[l], d),
        };
        layernorm_into_bf16(h, p.ln1_g, p.ln1_b, x, d);
        attention_into_bf16(
            h, x, q, k, v, ctx, &p, &pb, d, spec.n_heads, rows, seq, lora_tmp, acc,
        );
        layernorm_into_bf16(h, p.ln2_g, p.ln2_b, x, d);
        matmul_bias_into_bf16(x, p.w1, p.b1, ffn, acc, n, d, f);
        gelu_inplace_bf16(ffn);
        matmul_bias_into_bf16(ffn, p.w2, p.b2, q, acc, n, f, d);
        add_inplace_bf16(h, q);
    }

    // final LN (the tied bf16 LM head consumes scratch.xb)
    let fin = units[spec.n_units() - 1];
    layernorm_into_bf16(h, &fin[..d], &fin[d..], x, d);
    Ok(())
}

/// bf16 twin of [`fused_masked_xent`]: streaming logsumexp + gold logit
/// over bf16 hidden states and bf16 tok_emb, f32 logits / f64 sums — the
/// per-position xent output stays f32 (it feeds an f64 mean).
#[allow(clippy::too_many_arguments)]
pub fn fused_masked_xent_bf16(
    hf: &[u16],
    tok_emb: &[u16],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    vocab: usize,
    d: usize,
    xent: &mut [f32],
) {
    debug_assert!(hf.len() == n * d && tok_emb.len() == vocab * d);
    debug_assert!(targets.len() == n && mask.len() == n && xent.len() == n);
    let ptr = SendPtr(xent.as_mut_ptr());
    let grain = grain_for(2 * vocab * d, 2_000_000);
    par_ranges(n, grain, |range| {
        // SAFETY: par_ranges chunks are disjoint position ranges of `xent`.
        let out = unsafe { ptr.slice_mut(range.start, range.end - range.start) };
        for (o, p) in out.iter_mut().zip(range) {
            if mask[p] <= 0.0 {
                *o = 0.0;
                continue;
            }
            let hrow = &hf[p * d..(p + 1) * d];
            let gold_t = targets[p] as usize; // validated in-range
            let mut running_max = f32::NEG_INFINITY;
            let mut sum = 0.0f64;
            let mut gold = 0.0f32;
            let mut tile = [0.0f32; VOCAB_TILE];
            let mut t0 = 0;
            while t0 < vocab {
                let t1 = (t0 + VOCAB_TILE).min(vocab);
                let tile = &mut tile[..t1 - t0];
                for (lv, erow) in tile.iter_mut().zip(tok_emb[t0 * d..t1 * d].chunks_exact(d)) {
                    *lv = dot_bf16(hrow, erow);
                }
                let tile_max = tile.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                if tile_max > running_max {
                    sum *= ((running_max - tile_max) as f64).exp();
                    running_max = tile_max;
                }
                for &l in tile.iter() {
                    sum += ((l - running_max) as f64).exp();
                }
                if gold_t >= t0 && gold_t < t1 {
                    gold = tile[gold_t - t0];
                }
                t0 = t1;
            }
            let logz = running_max as f64 + sum.ln();
            *o = (logz - gold as f64) as f32;
        }
    });
}

/// bf16 twin of [`fused_argmax`] (ties resolve to the lowest token id).
pub fn fused_argmax_bf16(
    hf: &[u16],
    tok_emb: &[u16],
    n: usize,
    vocab: usize,
    d: usize,
    preds: &mut [i32],
) {
    debug_assert!(hf.len() == n * d && tok_emb.len() == vocab * d && preds.len() == n);
    let ptr = SendPtr(preds.as_mut_ptr());
    let grain = grain_for(2 * vocab * d, 2_000_000);
    par_ranges(n, grain, |range| {
        // SAFETY: par_ranges chunks are disjoint position ranges of `preds`.
        let out = unsafe { ptr.slice_mut(range.start, range.end - range.start) };
        for (o, p) in out.iter_mut().zip(range) {
            let hrow = &hf[p * d..(p + 1) * d];
            let mut best = 0usize;
            let mut best_val = f32::NEG_INFINITY;
            for (t, erow) in tok_emb.chunks_exact(d).enumerate() {
                let l = dot_bf16(hrow, erow);
                if l > best_val {
                    best_val = l;
                    best = t;
                }
            }
            *o = best as i32;
        }
    });
}

// ---------------------------------------------------------------------------
// quant twins: block-quantized weights, f32 activations
// ---------------------------------------------------------------------------
//
// `precision=int8|int4` quantizes only the *weight* shadows
// ([`super::quant`]); activations, scratch, and adapters stay f32. Each
// kernel below decodes the weight panel/row it is about to consume into a
// small per-chunk buffer (decoding is elementwise-exact: one exact int→f32
// conversion and one correctly-rounded multiply per element) and then runs
// the *identical* f32 inner loop as its f32 twin. The pinned invariant is
// therefore exact by construction:
//
//     kernel_quant(view, x) == kernel_f32(view.dequant(), x)   (bitwise)
//
// and thread-count invariance is inherited from the f32 kernels (fixed
// chunking, per-element fixed reduction order). The bandwidth win is what
// changes: a weight element streams 1.0625 bytes (int8) or 0.5625 bytes
// (int4) instead of 4.

/// Quant twin of [`matmul_bias_into`]: f32 activations against a
/// block-quantized weight matrix and bias. Each row-chunk decodes the bias
/// once and each `MM_IBLOCK x dout` weight panel on the fly, then runs the
/// identical blocked ascending-`i` accumulation.
pub fn matmul_bias_into_quant(
    x: &[f32],
    w: &QuantView<'_>,
    b: &QuantView<'_>,
    out: &mut [f32],
    n_rows: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(x.len(), n_rows * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(b.len(), dout);
    debug_assert_eq!(out.len(), n_rows * dout);
    let grain = grain_for(din * dout, 250_000); // rows per chunk
    par_row_chunks(out, dout, grain, |r0, orows| {
        let mut bias = vec![0.0f32; dout];
        b.dequant_range_into(&mut bias);
        let mut panel = vec![0.0f32; MM_IBLOCK.min(din) * dout];
        for orow in orows.chunks_exact_mut(dout) {
            orow.copy_from_slice(&bias);
        }
        let mut i0 = 0;
        while i0 < din {
            let i1 = (i0 + MM_IBLOCK).min(din);
            let wpanel = &mut panel[..(i1 - i0) * dout];
            w.split_to(i0 * dout, i1 * dout).dequant_range_into(wpanel);
            for (rr, orow) in orows.chunks_exact_mut(dout).enumerate() {
                let xrow = &x[(r0 + rr) * din + i0..(r0 + rr) * din + i1];
                for (&xi, wrow) in xrow.iter().zip(wpanel.chunks_exact(dout)) {
                    simd::axpy_row(orow, xi, wrow);
                }
            }
            i0 = i1;
        }
    });
}

/// Quant twin of [`layernorm_into`]: f32 rows, block-quantized gain/bias
/// decoded once per row-chunk. Identical f64 reductions.
pub fn layernorm_into_quant(
    x: &[f32],
    gamma: &QuantView<'_>,
    beta: &QuantView<'_>,
    out: &mut [f32],
    d: usize,
) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert!(gamma.len() == d && beta.len() == d);
    let grain = grain_for(4 * d, 65_536);
    par_row_chunks(out, d, grain, |r0, orows| {
        let mut g = vec![0.0f32; d];
        let mut bv = vec![0.0f32; d];
        gamma.dequant_range_into(&mut g);
        beta.dequant_range_into(&mut bv);
        for (rr, orow) in orows.chunks_exact_mut(d).enumerate() {
            let row = &x[(r0 + rr) * d..(r0 + rr + 1) * d];
            let mean = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
            let var = row.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>()
                / d as f64;
            let inv = 1.0 / (var as f32 + LN_EPS).sqrt();
            let mean = mean as f32;
            for ((o, &v), (&gg, &bb)) in orow.iter_mut().zip(row).zip(g.iter().zip(&bv)) {
                *o = (v - mean) * inv * gg + bb;
            }
        }
    });
}

/// Named [`QuantView`] windows into one flat block unit — the quantized
/// counterpart of [`BlockParams`], splitting the identical flat layout.
pub(crate) struct QuantBlock<'a> {
    pub ln1_g: QuantView<'a>,
    pub ln1_b: QuantView<'a>,
    pub wq: QuantView<'a>,
    pub bq: QuantView<'a>,
    pub wk: QuantView<'a>,
    pub bk: QuantView<'a>,
    pub wv: QuantView<'a>,
    pub bv: QuantView<'a>,
    pub wo: QuantView<'a>,
    pub bo: QuantView<'a>,
    pub ln2_g: QuantView<'a>,
    pub ln2_b: QuantView<'a>,
    pub w1: QuantView<'a>,
    pub b1: QuantView<'a>,
    pub w2: QuantView<'a>,
    pub b2: QuantView<'a>,
}

pub(crate) fn split_block_quant<'a>(spec: &ModelSpec, p: &QuantView<'a>) -> QuantBlock<'a> {
    let d = spec.d_model;
    let f = spec.d_ff();
    let mut off = 0usize;
    let mut take = |n: usize| -> QuantView<'a> {
        let v = p.split_to(off, off + n);
        off += n;
        v
    };
    QuantBlock {
        ln1_g: take(d),
        ln1_b: take(d),
        wq: take(d * d),
        bq: take(d),
        wk: take(d * d),
        bk: take(d),
        wv: take(d * d),
        bv: take(d),
        wo: take(d * d),
        bo: take(d),
        ln2_g: take(d),
        ln2_b: take(d),
        w1: take(d * f),
        b1: take(f),
        w2: take(f * d),
        b2: take(d),
    }
}

/// [`validate_forward_args`] over quantized unit views (length checks only,
/// identical messages).
pub(crate) fn validate_forward_args_quant(
    spec: &ModelSpec,
    units: &[QuantView<'_>],
    tokens: &[i32],
    rows: usize,
    seq: usize,
) -> Result<()> {
    ensure!(
        units.len() == spec.n_units(),
        "expected {} units, got {}",
        spec.n_units(),
        units.len()
    );
    for (k, (u, len)) in units.iter().zip(spec.unit_lens()).enumerate() {
        ensure!(u.len() == len, "unit {k}: expected {len} elements, got {}", u.len());
    }
    ensure!(tokens.len() == rows * seq, "tokens shape mismatch");
    ensure!(seq <= spec.max_seq, "seq {seq} exceeds max_seq {}", spec.max_seq);
    ensure!(
        tokens.iter().all(|&t| t >= 0 && (t as usize) < spec.vocab),
        "token id out of vocab range"
    );
    Ok(())
}

/// Quant twin of the private f32 `attention_into`: the four projections
/// decode quantized weights; activations, the PEFT adapter fold, and
/// [`attention_ctx`] are the plain f32 kernels (adapters stay f32, like
/// the bf16 path).
#[allow(clippy::too_many_arguments)]
fn attention_into_quant(
    h: &mut [f32],
    x: &[f32],
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
    ctx: &mut [f32],
    p: &QuantBlock<'_>,
    peft: &PeftBlock<'_>,
    d: usize,
    nh: usize,
    rows: usize,
    seq: usize,
    lora_tmp: &mut [f32],
) {
    const LORA_ZERO_BIAS: [f32; crate::peft::LORA_RANK] = [0.0; crate::peft::LORA_RANK];
    let n = rows * seq;
    matmul_bias_into_quant(x, &p.wq, &p.bq, q, n, d, d);
    matmul_bias_into_quant(x, &p.wk, &p.bk, k, n, d, d);
    matmul_bias_into_quant(x, &p.wv, &p.bv, v, n, d, d);
    let mut prefix = None;
    match peft {
        PeftBlock::None => {}
        PeftBlock::Lora { a_q, b_q, a_v, b_v } => {
            let r = crate::peft::LORA_RANK;
            let scale = (crate::peft::LORA_ALPHA / r as f64) as f32;
            let tmp = &mut lora_tmp[..n * r];
            matmul_bias_into(x, a_q, &LORA_ZERO_BIAS, tmp, n, d, r);
            matmul_scaled_acc_into(tmp, b_q, scale, q, n, r, d);
            matmul_bias_into(x, a_v, &LORA_ZERO_BIAS, tmp, n, d, r);
            matmul_scaled_acc_into(tmp, b_v, scale, v, n, r, d);
        }
        PeftBlock::Prefix { k_pre, v_pre } => prefix = Some((*k_pre, *v_pre)),
    }
    attention_ctx(q, k, v, prefix, ctx, d, nh, rows, seq);
    matmul_bias_into_quant(ctx, &p.wo, &p.bo, q, n, d, d);
    add_inplace(h, q);
}

/// Quant twin of [`forward_hidden_peft`]: the full transformer forward
/// over block-quantized unit shadows with **f32 activations** — it shares
/// the f32 scratch arena, and on success the final-LN hidden states are in
/// `scratch.x[..rows*seq*d]`, exactly like the f32 path. Bitwise equal to
/// [`forward_hidden_peft`] run on the dequantized units.
#[allow(clippy::too_many_arguments)]
pub fn forward_hidden_quant_peft(
    spec: &ModelSpec,
    units: &[QuantView<'_>],
    peft: PeftMode,
    peft_units: &[&[f32]],
    tokens: &[i32],
    rows: usize,
    seq: usize,
    scratch: &mut ForwardScratch,
) -> Result<()> {
    validate_forward_args_quant(spec, units, tokens, rows, seq)?;
    validate_peft_args(spec, peft, peft_units)?;
    let d = spec.d_model;
    let f = spec.d_ff();
    let n = rows * seq;
    scratch.ensure(n, d, f);
    let ForwardScratch { h, x, q, k, v, ctx, ffn, .. } = scratch;
    let h = &mut h[..n * d];
    let x = &mut x[..n * d];
    let q = &mut q[..n * d];
    let k = &mut k[..n * d];
    let v = &mut v[..n * d];
    let ctx = &mut ctx[..n * d];
    let ffn = &mut ffn[..n * f];

    // embed: decode one tok_emb / pos_emb row at a time
    let emb = &units[0];
    let vocab_d = spec.vocab * d;
    let mut te = vec![0.0f32; d];
    let mut pe = vec![0.0f32; d];
    for r in 0..rows {
        for s in 0..seq {
            let t = tokens[r * seq + s] as usize;
            let hrow = &mut h[(r * seq + s) * d..(r * seq + s + 1) * d];
            emb.split_to(t * d, (t + 1) * d).dequant_range_into(&mut te);
            emb.split_to(vocab_d + s * d, vocab_d + (s + 1) * d).dequant_range_into(&mut pe);
            for ((hv, &tv), &pv) in hrow.iter_mut().zip(&te).zip(&pe) {
                *hv = tv + pv;
            }
        }
    }

    // blocks
    for l in 0..spec.n_layers {
        let p = split_block_quant(spec, &units[1 + l]);
        let pb = match peft {
            PeftMode::Full => PeftBlock::None,
            _ => peft_block(peft, peft_units[l], d),
        };
        layernorm_into_quant(h, &p.ln1_g, &p.ln1_b, x, d);
        attention_into_quant(h, x, q, k, v, ctx, &p, &pb, d, spec.n_heads, rows, seq, ffn);
        layernorm_into_quant(h, &p.ln2_g, &p.ln2_b, x, d);
        matmul_bias_into_quant(x, &p.w1, &p.b1, ffn, n, d, f);
        gelu_inplace(ffn);
        matmul_bias_into_quant(ffn, &p.w2, &p.b2, q, n, f, d);
        add_inplace(h, q);
    }

    // final LN (the tied LM head consumes scratch.x, like the f32 path)
    let fin = &units[spec.n_units() - 1];
    layernorm_into_quant(h, &fin.split_to(0, d), &fin.split_to(d, 2 * d), x, d);
    Ok(())
}

/// Quant twin of [`fused_masked_xent`]: f32 hidden states against the
/// block-quantized tied embedding, decoded one vocab tile at a time into a
/// per-chunk buffer. Streaming logsumexp / gold logit identical to the f32
/// twin on the decoded rows.
#[allow(clippy::too_many_arguments)]
pub fn fused_masked_xent_quant(
    hf: &[f32],
    tok_emb: &QuantView<'_>,
    targets: &[i32],
    mask: &[f32],
    n: usize,
    vocab: usize,
    d: usize,
    xent: &mut [f32],
) {
    debug_assert!(hf.len() == n * d && tok_emb.len() == vocab * d);
    debug_assert!(targets.len() == n && mask.len() == n && xent.len() == n);
    let ptr = SendPtr(xent.as_mut_ptr());
    let grain = grain_for(2 * vocab * d, 2_000_000);
    par_ranges(n, grain, |range| {
        // SAFETY: par_ranges chunks are disjoint position ranges of `xent`.
        let out = unsafe { ptr.slice_mut(range.start, range.end - range.start) };
        let mut etile = vec![0.0f32; VOCAB_TILE.min(vocab) * d];
        for (o, p) in out.iter_mut().zip(range) {
            if mask[p] <= 0.0 {
                *o = 0.0;
                continue;
            }
            let hrow = &hf[p * d..(p + 1) * d];
            let gold_t = targets[p] as usize; // validated in-range
            let mut running_max = f32::NEG_INFINITY;
            let mut sum = 0.0f64;
            let mut gold = 0.0f32;
            let mut tile = [0.0f32; VOCAB_TILE];
            let mut t0 = 0;
            while t0 < vocab {
                let t1 = (t0 + VOCAB_TILE).min(vocab);
                let tile = &mut tile[..t1 - t0];
                let erows = &mut etile[..(t1 - t0) * d];
                tok_emb.split_to(t0 * d, t1 * d).dequant_range_into(erows);
                for (lv, erow) in tile.iter_mut().zip(erows.chunks_exact(d)) {
                    *lv = dot(hrow, erow);
                }
                let tile_max = tile.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                if tile_max > running_max {
                    sum *= ((running_max - tile_max) as f64).exp();
                    running_max = tile_max;
                }
                for &l in tile.iter() {
                    sum += ((l - running_max) as f64).exp();
                }
                if gold_t >= t0 && gold_t < t1 {
                    gold = tile[gold_t - t0];
                }
                t0 = t1;
            }
            let logz = running_max as f64 + sum.ln();
            *o = (logz - gold as f64) as f32;
        }
    });
}

/// Quant twin of [`fused_argmax`] (ties resolve to the lowest token id):
/// decodes the tied embedding one vocab tile at a time.
pub fn fused_argmax_quant(
    hf: &[f32],
    tok_emb: &QuantView<'_>,
    n: usize,
    vocab: usize,
    d: usize,
    preds: &mut [i32],
) {
    debug_assert!(hf.len() == n * d && tok_emb.len() == vocab * d && preds.len() == n);
    let ptr = SendPtr(preds.as_mut_ptr());
    let grain = grain_for(2 * vocab * d, 2_000_000);
    par_ranges(n, grain, |range| {
        // SAFETY: par_ranges chunks are disjoint position ranges of `preds`.
        let out = unsafe { ptr.slice_mut(range.start, range.end - range.start) };
        let mut etile = vec![0.0f32; VOCAB_TILE.min(vocab) * d];
        for (o, p) in out.iter_mut().zip(range) {
            let hrow = &hf[p * d..(p + 1) * d];
            let mut best = 0usize;
            let mut best_val = f32::NEG_INFINITY;
            let mut t0 = 0;
            while t0 < vocab {
                let t1 = (t0 + VOCAB_TILE).min(vocab);
                let erows = &mut etile[..(t1 - t0) * d];
                tok_emb.split_to(t0 * d, t1 * d).dequant_range_into(erows);
                for (tt, erow) in erows.chunks_exact(d).enumerate() {
                    let l = dot(hrow, erow);
                    if l > best_val {
                        best_val = l;
                        best = t0 + tt;
                    }
                }
                t0 = t1;
            }
            *o = best as i32;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * 0.5).collect()
    }

    /// Naive row-major reference matmul (same as the dense forward path).
    fn matmul_ref(x: &[f32], w: &[f32], b: &[f32], n: usize, din: usize, dout: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * dout];
        for r in 0..n {
            let orow = &mut out[r * dout..(r + 1) * dout];
            orow.copy_from_slice(b);
            for (i, &xi) in x[r * din..(r + 1) * din].iter().enumerate() {
                for (o, &wv) in orow.iter_mut().zip(&w[i * dout..(i + 1) * dout]) {
                    *o += xi * wv;
                }
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_matches_naive_reference_bitwise() {
        let mut rng = Rng::new(1);
        for (n, din, dout) in [(1usize, 3usize, 5usize), (7, 16, 9), (13, 65, 130), (64, 64, 256)]
        {
            let x = randv(&mut rng, n * din);
            let w = randv(&mut rng, din * dout);
            let b = randv(&mut rng, dout);
            let want = matmul_ref(&x, &w, &b, n, din, dout);
            let mut got = vec![0.0f32; n * dout];
            matmul_bias_into(&x, &w, &b, &mut got, n, din, dout);
            // ascending-i accumulation order is preserved by the blocking,
            // so the result is bit-identical, not just close
            assert_eq!(got, want, "n={n} din={din} dout={dout}");
        }
    }

    #[test]
    fn layernorm_matches_reference() {
        let mut rng = Rng::new(2);
        let (n, d) = (9, 33);
        let x = randv(&mut rng, n * d);
        let g = randv(&mut rng, d);
        let b = randv(&mut rng, d);
        let mut got = vec![0.0f32; n * d];
        layernorm_into(&x, &g, &b, &mut got, d);
        for r in 0..n {
            let row = &x[r * d..(r + 1) * d];
            let mean = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
            let var = row.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>()
                / d as f64;
            let inv = 1.0 / (var as f32 + LN_EPS).sqrt();
            for j in 0..d {
                let want = (row[j] - mean as f32) * inv * g[j] + b[j];
                assert_eq!(got[r * d + j], want, "r={r} j={j}");
            }
        }
    }

    #[test]
    fn inplace_axpy_matches_allocating_formula() {
        let mut rng = Rng::new(3);
        let n = 10_000;
        let p0 = randv(&mut rng, n);
        let mut p = p0.clone();
        axpy_gauss_inplace(&mut p, 42, 1e-2);
        for (i, (&got, &orig)) in p.iter().zip(&p0).enumerate() {
            let want = orig + 1e-2 * crate::runtime::philox::gauss_from_index(i as u32, 42);
            assert_eq!(got.to_bits(), want.to_bits(), "i={i}");
        }
    }

    #[test]
    fn inplace_masked_axpy_respects_mask_and_matches_dense_at_inf_tau() {
        let mut rng = Rng::new(4);
        let n = 4_097;
        let p0 = randv(&mut rng, n);
        let pref = randv(&mut rng, n);

        let mut dense = p0.clone();
        axpy_gauss_inplace(&mut dense, 9, 0.5);
        let mut masked_inf = p0.clone();
        axpy_gauss_masked_inplace(&mut masked_inf, &pref, f32::INFINITY, 9, 0.5);
        assert_eq!(dense, masked_inf);

        let tau = 0.3f32;
        let mut masked = p0.clone();
        axpy_gauss_masked_inplace(&mut masked, &pref, tau, 9, 0.5);
        for i in 0..n {
            if pref[i].abs() <= tau {
                assert_eq!(masked[i].to_bits(), dense[i].to_bits(), "i={i} in-mask");
            } else {
                assert_eq!(masked[i].to_bits(), p0[i].to_bits(), "i={i} out-of-mask");
            }
        }
    }

    #[test]
    fn scaled_acc_matmul_matches_reference_and_zero_w_is_bitwise_noop() {
        let mut rng = Rng::new(5);
        let (n, din, dout) = (9usize, 8usize, 33usize);
        let x = randv(&mut rng, n * din);
        let w = randv(&mut rng, din * dout);
        let out0 = randv(&mut rng, n * dout);
        let mut got = out0.clone();
        matmul_scaled_acc_into(&x, &w, 2.0, &mut got, n, din, dout);
        for r in 0..n {
            for o in 0..dout {
                let mut acc = 0.0f32;
                for i in 0..din {
                    acc += x[r * din + i] * w[i * dout + o];
                }
                let want = out0[r * dout + o] + 2.0 * acc;
                assert_eq!(got[r * dout + o], want, "r={r} o={o}");
            }
        }
        // w = 0: a zero-init LoRA B must leave the projection bits untouched
        let zeros = vec![0.0f32; din * dout];
        let mut same = out0.clone();
        matmul_scaled_acc_into(&x, &zeros, 2.0, &mut same, n, din, dout);
        assert!(
            same.iter().zip(&out0).all(|(a, b)| a.to_bits() == b.to_bits()),
            "zero-w scaled-acc must be a bitwise no-op"
        );
    }

    #[test]
    fn attention_ctx_empty_prefix_matches_none_bitwise() {
        // Some((empty, empty)) must take the exact same code path as None.
        let mut rng = Rng::new(6);
        let (rows, seq, d, nh) = (2usize, 8usize, 16usize, 2usize);
        let q = randv(&mut rng, rows * seq * d);
        let k = randv(&mut rng, rows * seq * d);
        let v = randv(&mut rng, rows * seq * d);
        let mut a = vec![0.0f32; rows * seq * d];
        let mut b = vec![0.0f32; rows * seq * d];
        attention_ctx(&q, &k, &v, None, &mut a, d, nh, rows, seq);
        attention_ctx(&q, &k, &v, Some((&[], &[])), &mut b, d, nh, rows, seq);
        assert_eq!(a, b);
    }

    #[test]
    fn target_validation_rejects_in_mask_oov_only() {
        let targets = [3i32, 600, -1, 2];
        // oov targets are fine while masked out...
        assert!(validate_targets(&targets, &[1.0, 0.0, 0.0, 1.0], 4, 512).is_ok());
        // ...and hard errors once the mask includes them
        let err = validate_targets(&targets, &[1.0, 1.0, 0.0, 1.0], 4, 512).unwrap_err();
        assert!(err.to_string().contains("position 1"), "{err}");
        let err = validate_targets(&targets, &[1.0, 0.0, 1.0, 1.0], 4, 512).unwrap_err();
        assert!(err.to_string().contains("position 2"), "{err}");
    }

    #[test]
    fn scratch_reuse_keeps_results_identical() {
        let spec = ModelSpec::preset("opt-nano").unwrap();
        let host = spec.init_units(5);
        let units: Vec<&[f32]> = host.iter().map(|u| u.as_slice()).collect();
        let (rows, seq) = (2usize, 8usize);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 90) as i32).collect();
        let n = rows * seq;
        let d = spec.d_model;

        let mut fresh = ForwardScratch::new();
        forward_hidden(&spec, &units, &tokens, rows, seq, &mut fresh).unwrap();
        let want = fresh.x[..n * d].to_vec();

        // a scratch polluted by a *larger* forward must give the same bits
        let mut reused = ForwardScratch::new();
        let big_tokens: Vec<i32> = (0..4 * 16).map(|i| (i % 100) as i32).collect();
        forward_hidden(&spec, &units, &big_tokens, 4, 16, &mut reused).unwrap();
        forward_hidden(&spec, &units, &tokens, rows, seq, &mut reused).unwrap();
        assert_eq!(&reused.x[..n * d], &want[..]);
    }

    // -- bf16 twins: each kernel is pinned BITWISE to the bf16 rounding of
    // -- its f32 twin run on the widened inputs (accumulation order mirrors
    // -- the f32 kernel element for element, so the only difference is the
    // -- single rounding on store).

    fn randb(rng: &mut Rng, n: usize) -> Vec<u16> {
        use crate::runtime::native::bf16;
        bf16::cast(&randv(rng, n))
    }

    #[test]
    fn bf16_dot_matches_f32_dot_on_widened_operands_bitwise() {
        use crate::runtime::native::bf16;
        let mut rng = Rng::new(10);
        for n in [1usize, 3, 4, 7, 64, 257] {
            let a = randb(&mut rng, n);
            let b = randb(&mut rng, n);
            let got = dot_bf16(&a, &b);
            let want = dot(&bf16::widen(&a), &bf16::widen(&b));
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn bf16_matmul_is_bitwise_rounding_of_f32_twin() {
        use crate::runtime::native::bf16;
        let mut rng = Rng::new(11);
        for (n, din, dout) in [(1usize, 3usize, 5usize), (7, 16, 9), (13, 65, 130), (64, 64, 256)]
        {
            let x = randb(&mut rng, n * din);
            let w = randb(&mut rng, din * dout);
            let b = randb(&mut rng, dout);
            let mut got = vec![0u16; n * dout];
            let mut acc = vec![0.0f32; n * dout];
            matmul_bias_into_bf16(&x, &w, &b, &mut got, &mut acc, n, din, dout);
            let mut f32_out = vec![0.0f32; n * dout];
            let (xw, ww, bw) = (bf16::widen(&x), bf16::widen(&w), bf16::widen(&b));
            matmul_bias_into(&xw, &ww, &bw, &mut f32_out, n, din, dout);
            assert_eq!(got, bf16::cast(&f32_out), "n={n} din={din} dout={dout}");
        }
    }

    #[test]
    fn bf16_layernorm_gelu_add_are_bitwise_roundings_of_f32_twins() {
        use crate::runtime::native::bf16;
        let mut rng = Rng::new(12);
        let (n, d) = (9, 33);
        let x = randb(&mut rng, n * d);
        let g = randb(&mut rng, d);
        let b = randb(&mut rng, d);
        let mut got = vec![0u16; n * d];
        layernorm_into_bf16(&x, &g, &b, &mut got, d);
        let mut f32_out = vec![0.0f32; n * d];
        layernorm_into(&bf16::widen(&x), &bf16::widen(&g), &bf16::widen(&b), &mut f32_out, d);
        assert_eq!(got, bf16::cast(&f32_out), "layernorm");

        let mut gb = x.clone();
        gelu_inplace_bf16(&mut gb);
        let mut gf = bf16::widen(&x);
        gelu_inplace(&mut gf);
        assert_eq!(gb, bf16::cast(&gf), "gelu");

        let m = randb(&mut rng, n * d);
        let mut hb = x.clone();
        add_inplace_bf16(&mut hb, &m);
        let mut hf = bf16::widen(&x);
        add_inplace(&mut hf, &bf16::widen(&m));
        assert_eq!(hb, bf16::cast(&hf), "residual add");
    }

    #[test]
    fn bf16_attention_ctx_is_bitwise_rounding_of_f32_twin() {
        use crate::runtime::native::bf16;
        let mut rng = Rng::new(13);
        let (rows, seq, d, nh) = (2usize, 8usize, 16usize, 2usize);
        let q = randb(&mut rng, rows * seq * d);
        let k = randb(&mut rng, rows * seq * d);
        let v = randb(&mut rng, rows * seq * d);
        // plain causal
        let mut got = vec![0u16; rows * seq * d];
        attention_ctx_bf16(&q, &k, &v, None, &mut got, d, nh, rows, seq);
        let mut f32_out = vec![0.0f32; rows * seq * d];
        let (qw, kw, vw) = (bf16::widen(&q), bf16::widen(&k), bf16::widen(&v));
        attention_ctx(&qw, &kw, &vw, None, &mut f32_out, d, nh, rows, seq);
        assert_eq!(got, bf16::cast(&f32_out), "no prefix");
        // empty prefix degenerates to None
        let mut got_e = vec![0u16; rows * seq * d];
        attention_ctx_bf16(&q, &k, &v, Some((&[], &[])), &mut got_e, d, nh, rows, seq);
        assert_eq!(got, got_e, "empty prefix must equal None");
        // f32 prefix KV (adapters stay f32 in the bf16 path)
        let n_pre = crate::peft::PREFIX_TOKENS;
        let k_pre = randv(&mut rng, n_pre * d);
        let v_pre = randv(&mut rng, n_pre * d);
        let mut got_p = vec![0u16; rows * seq * d];
        attention_ctx_bf16(&q, &k, &v, Some((&k_pre, &v_pre)), &mut got_p, d, nh, rows, seq);
        let mut f32_p = vec![0.0f32; rows * seq * d];
        attention_ctx(&qw, &kw, &vw, Some((&k_pre, &v_pre)), &mut f32_p, d, nh, rows, seq);
        assert_eq!(got_p, bf16::cast(&f32_p), "f32 prefix");
        assert_ne!(got_p, got, "prefix must change the context");
    }

    #[test]
    fn bf16_fused_head_matches_f32_twin_on_widened_inputs() {
        use crate::runtime::native::bf16;
        let mut rng = Rng::new(14);
        let (n, vocab, d) = (10usize, 130usize, 16usize);
        let hf = randb(&mut rng, n * d);
        let emb = randb(&mut rng, vocab * d);
        let targets: Vec<i32> = (0..n).map(|i| (i * 13 % vocab) as i32).collect();
        let mut mask = vec![1.0f32; n];
        mask[3] = 0.0;
        mask[7] = 0.0;
        let mut got = vec![0.0f32; n];
        fused_masked_xent_bf16(&hf, &emb, &targets, &mask, n, vocab, d, &mut got);
        let mut want = vec![0.0f32; n];
        let (hw, ew) = (bf16::widen(&hf), bf16::widen(&emb));
        fused_masked_xent(&hw, &ew, &targets, &mask, n, vocab, d, &mut want);
        // xent output is f32 in both paths; the streams are op-identical
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "xent position {i}");
        }
        let mut pb = vec![0i32; n];
        fused_argmax_bf16(&hf, &emb, n, vocab, d, &mut pb);
        let mut pf = vec![0i32; n];
        fused_argmax(&hw, &ew, n, vocab, d, &mut pf);
        assert_eq!(pb, pf, "argmax");
    }

    #[test]
    fn bf16_scaled_acc_zero_w_is_bitwise_noop() {
        use crate::runtime::native::bf16;
        let mut rng = Rng::new(15);
        let (n, din, dout) = (9usize, 8usize, 33usize);
        let x = randv(&mut rng, n * din);
        let w = randv(&mut rng, din * dout);
        let out0 = randb(&mut rng, n * dout);
        let mut got = out0.clone();
        matmul_scaled_acc_into_bf16(&x, &w, 2.0, &mut got, n, din, dout);
        // matches the reference formula, rounded once
        for r in 0..n {
            for o in 0..dout {
                let mut acc = 0.0f32;
                for i in 0..din {
                    acc += x[r * din + i] * w[i * dout + o];
                }
                let want = bf16::to_bits(bf16::to_f32(out0[r * dout + o]) + 2.0 * acc);
                assert_eq!(got[r * dout + o], want, "r={r} o={o}");
            }
        }
        // w = 0: a zero-init LoRA B must leave the bf16 projection untouched
        let zeros = vec![0.0f32; din * dout];
        let mut same = out0.clone();
        matmul_scaled_acc_into_bf16(&x, &zeros, 2.0, &mut same, n, din, dout);
        assert_eq!(same, out0, "zero-w bf16 scaled-acc must be a bitwise no-op");
    }

    #[test]
    fn bf16_scratch_reuse_keeps_results_identical() {
        use crate::runtime::native::bf16;
        let spec = ModelSpec::preset("opt-nano").unwrap();
        let host = spec.init_units(5);
        let shadows: Vec<Vec<u16>> = host.iter().map(|u| bf16::cast(u)).collect();
        let units: Vec<&[u16]> = shadows.iter().map(|u| u.as_slice()).collect();
        let (rows, seq) = (2usize, 8usize);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 90) as i32).collect();
        let n = rows * seq;
        let d = spec.d_model;

        let mut fresh = ForwardScratch::new();
        forward_hidden_bf16_peft(
            &spec, &units, PeftMode::Full, &[], &tokens, rows, seq, &mut fresh,
        )
        .unwrap();
        let want = fresh.xb[..n * d].to_vec();

        let mut reused = ForwardScratch::new();
        let big_tokens: Vec<i32> = (0..4 * 16).map(|i| (i % 100) as i32).collect();
        forward_hidden_bf16_peft(
            &spec, &units, PeftMode::Full, &[], &big_tokens, 4, 16, &mut reused,
        )
        .unwrap();
        forward_hidden_bf16_peft(
            &spec, &units, PeftMode::Full, &[], &tokens, rows, seq, &mut reused,
        )
        .unwrap();
        assert_eq!(&reused.xb[..n * d], &want[..]);
    }

    // -- quant twins: weights are block-quantized, activations stay f32;
    // -- each kernel decodes (elementwise-exact) and runs the identical
    // -- f32 inner loop, so `kernel_q(view, x)` is pinned BITWISE to
    // -- `kernel_f32(view.dequant(), x)`.

    use crate::runtime::native::quant::{self, QuantMode};

    /// Quantize an f32 buffer and hand back owned (scales, codes) pairs
    /// the tests build `QuantView`s over.
    fn qpair(mode: QuantMode, src: &[f32]) -> (Vec<f32>, Vec<u8>) {
        quant::quantize(mode, src).unwrap()
    }

    #[test]
    fn quant_matmul_is_bitwise_equal_to_f32_twin_on_dequantized_weights() {
        let mut rng = Rng::new(20);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            for (n, din, dout) in
                [(1usize, 3usize, 5usize), (7, 16, 9), (13, 65, 130), (64, 64, 256)]
            {
                let x = randv(&mut rng, n * din);
                let (ws, wc) = qpair(mode, &randv(&mut rng, din * dout));
                let (bs, bc) = qpair(mode, &randv(&mut rng, dout));
                let w = QuantView::new(mode, &ws, &wc, din * dout);
                let b = QuantView::new(mode, &bs, &bc, dout);
                let mut got = vec![0.0f32; n * dout];
                matmul_bias_into_quant(&x, &w, &b, &mut got, n, din, dout);
                let mut want = vec![0.0f32; n * dout];
                matmul_bias_into(&x, &w.dequant(), &b.dequant(), &mut want, n, din, dout);
                assert!(
                    got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{mode} n={n} din={din} dout={dout}"
                );
            }
        }
    }

    #[test]
    fn quant_layernorm_is_bitwise_equal_to_f32_twin() {
        let mut rng = Rng::new(21);
        let (n, d) = (9, 33);
        let x = randv(&mut rng, n * d);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let (gs, gc) = qpair(mode, &randv(&mut rng, d));
            let (bs, bc) = qpair(mode, &randv(&mut rng, d));
            let g = QuantView::new(mode, &gs, &gc, d);
            let b = QuantView::new(mode, &bs, &bc, d);
            let mut got = vec![0.0f32; n * d];
            layernorm_into_quant(&x, &g, &b, &mut got, d);
            let mut want = vec![0.0f32; n * d];
            layernorm_into(&x, &g.dequant(), &b.dequant(), &mut want, d);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{mode}"
            );
        }
    }

    #[test]
    fn quant_fused_head_matches_f32_twin_on_dequantized_emb() {
        let mut rng = Rng::new(22);
        let (n, vocab, d) = (10usize, 130usize, 16usize);
        let hf = randv(&mut rng, n * d);
        let targets: Vec<i32> = (0..n).map(|i| (i * 13 % vocab) as i32).collect();
        let mut mask = vec![1.0f32; n];
        mask[3] = 0.0;
        mask[7] = 0.0;
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let (es, ec) = qpair(mode, &randv(&mut rng, vocab * d));
            let emb = QuantView::new(mode, &es, &ec, vocab * d);
            let mut got = vec![0.0f32; n];
            fused_masked_xent_quant(&hf, &emb, &targets, &mask, n, vocab, d, &mut got);
            let mut want = vec![0.0f32; n];
            fused_masked_xent(&hf, &emb.dequant(), &targets, &mask, n, vocab, d, &mut want);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{mode} xent position {i}");
            }
            let mut pq = vec![0i32; n];
            fused_argmax_quant(&hf, &emb, n, vocab, d, &mut pq);
            let mut pf = vec![0i32; n];
            fused_argmax(&hf, &emb.dequant(), n, vocab, d, &mut pf);
            assert_eq!(pq, pf, "{mode} argmax");
        }
    }

    #[test]
    fn quant_forward_is_bitwise_equal_to_f32_forward_on_dequantized_units() {
        let spec = ModelSpec::preset("opt-nano").unwrap();
        let host = spec.init_units(5);
        let (rows, seq) = (2usize, 8usize);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 20 + (i % 90) as i32).collect();
        let n = rows * seq;
        let d = spec.d_model;
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let pairs: Vec<(Vec<f32>, Vec<u8>)> =
                host.iter().map(|u| qpair(mode, u)).collect();
            let views: Vec<QuantView<'_>> = pairs
                .iter()
                .zip(&host)
                .map(|((s, c), u)| QuantView::new(mode, s, c, u.len()))
                .collect();
            let mut qs = ForwardScratch::new();
            forward_hidden_quant_peft(
                &spec, &views, PeftMode::Full, &[], &tokens, rows, seq, &mut qs,
            )
            .unwrap();

            let deq: Vec<Vec<f32>> = views.iter().map(|v| v.dequant()).collect();
            let deq_refs: Vec<&[f32]> = deq.iter().map(|u| u.as_slice()).collect();
            let mut fs = ForwardScratch::new();
            forward_hidden_peft(
                &spec, &deq_refs, PeftMode::Full, &[], &tokens, rows, seq, &mut fs,
            )
            .unwrap();
            assert!(
                qs.x[..n * d]
                    .iter()
                    .zip(&fs.x[..n * d])
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "{mode}: quant forward must equal f32 forward on dequantized units"
            );
        }
    }

    #[test]
    fn quant_forward_with_f32_adapters_matches_dequantized_twin() {
        // LoRA and prefix adapters stay f32 in the quant path; the mixed
        // forward must still be bitwise-equal to the dequantized f32 run.
        let spec = ModelSpec::preset("opt-nano").unwrap();
        let host = spec.init_units(6);
        let (rows, seq) = (2usize, 6usize);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 30 + (i % 80) as i32).collect();
        let n = rows * seq;
        let d = spec.d_model;
        let mut rng = Rng::new(23);
        for peft in [PeftMode::Lora, PeftMode::Prefix] {
            let unit_len = match peft {
                PeftMode::Lora => crate::peft::lora_unit_len(d),
                PeftMode::Prefix => crate::peft::prefix_unit_len(d),
                PeftMode::Full => unreachable!(),
            };
            let adapters: Vec<Vec<f32>> = (0..spec.n_layers)
                .map(|_| (0..unit_len).map(|_| rng.gaussian() as f32 * 0.1).collect())
                .collect();
            let adapter_refs: Vec<&[f32]> = adapters.iter().map(|u| u.as_slice()).collect();
            for mode in [QuantMode::Int8, QuantMode::Int4] {
                let pairs: Vec<(Vec<f32>, Vec<u8>)> =
                    host.iter().map(|u| qpair(mode, u)).collect();
                let views: Vec<QuantView<'_>> = pairs
                    .iter()
                    .zip(&host)
                    .map(|((s, c), u)| QuantView::new(mode, s, c, u.len()))
                    .collect();
                let mut qs = ForwardScratch::new();
                forward_hidden_quant_peft(
                    &spec, &views, peft, &adapter_refs, &tokens, rows, seq, &mut qs,
                )
                .unwrap();
                let deq: Vec<Vec<f32>> = views.iter().map(|v| v.dequant()).collect();
                let deq_refs: Vec<&[f32]> = deq.iter().map(|u| u.as_slice()).collect();
                let mut fs = ForwardScratch::new();
                forward_hidden_peft(
                    &spec, &deq_refs, peft, &adapter_refs, &tokens, rows, seq, &mut fs,
                )
                .unwrap();
                assert!(
                    qs.x[..n * d]
                        .iter()
                        .zip(&fs.x[..n * d])
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{mode} peft={peft}"
                );
            }
        }
    }
}

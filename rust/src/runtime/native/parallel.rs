//! Scoped worker threads for the native hot path — zero new dependencies
//! (the offline image vendors everything; `std::thread::scope` is enough).
//!
//! Design rules, in priority order:
//!
//! 1. **Determinism.** Work is split into *fixed* chunks whose boundaries
//!    depend only on the item count (never on the thread count), and every
//!    kernel routed through here writes disjoint elements with no
//!    cross-chunk reductions. Results are therefore bit-identical at any
//!    `LEZO_THREADS` setting — pinned by the thread-invariance test in
//!    `rust/tests/native_backend.rs`.
//! 2. **No overhead for tiny work.** Callers pass a `grain` (minimum items
//!    per chunk, sized so one chunk is worth a dispatch); when the whole
//!    range fits one chunk the closure runs inline on the caller's thread,
//!    so opt-nano tests never pay a spawn.
//! 3. **Simplicity.** Threads are scoped per parallel region
//!    (`std::thread::scope`) and pull chunks from an atomic counter; there
//!    is no persistent pool to shut down or poison.
//!
//! Thread count resolution (highest precedence first): the `LEZO_THREADS`
//! env var, a scoped this-thread override ([`with_threads`], what the
//! `threads` config key uses for the duration of a run), the global
//! default ([`set_threads`]), then `std::thread::available_parallelism()`.

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Upper bound on the number of fixed chunks a parallel region is split
/// into. Chunk boundaries derive from this constant and the item count
/// alone, so partitioning is identical at any thread count.
pub const MAX_PARTS: usize = 64;

/// Process-wide default; 0 = auto (available parallelism).
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Scoped per-thread override (0 = none). Parallel regions are always
    /// entered from the caller's thread, so this cleanly scopes a worker
    /// count to one run without touching process-global state.
    static TL_THREADS: Cell<usize> = const { Cell::new(0) };
}

/// Set the process-wide default worker-thread count (0 restores auto).
/// `LEZO_THREADS` and [`with_threads`] both take precedence.
pub fn set_threads(n: usize) {
    CONFIGURED.store(n, Ordering::Relaxed);
}

/// Run `f` with a worker-count override scoped to the current thread
/// (restored on exit, including on panic; 0 = no override). This is how
/// the `threads` config key is applied per run — concurrent runs in one
/// process cannot clobber each other's setting.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            TL_THREADS.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(TL_THREADS.with(|c| c.replace(n)));
    f()
}

/// Parse a `LEZO_THREADS` value: empty/unset means "no override", anything
/// else must be a positive integer — an unparseable or zero value is a hard
/// error naming the bad value, never a silent fall-through to the default.
fn parse_env_threads(v: &str) -> Result<Option<usize>, String> {
    if v.is_empty() {
        return Ok(None);
    }
    match v.parse::<usize>() {
        Ok(n) if n > 0 => Ok(Some(n)),
        _ => Err(format!(
            "LEZO_THREADS='{v}' is not a positive worker-thread count (unset it for auto)"
        )),
    }
}

/// `LEZO_THREADS`, parsed once per process (region entry is on the hot
/// path; an env read takes a lock and allocates). A bad value panics here
/// as a backstop; [`check_env`] surfaces the same error cleanly up front.
fn env_threads() -> Option<usize> {
    static ENV: std::sync::OnceLock<Result<Option<usize>, String>> = std::sync::OnceLock::new();
    match ENV.get_or_init(|| parse_env_threads(&std::env::var("LEZO_THREADS").unwrap_or_default()))
    {
        Ok(n) => *n,
        Err(e) => panic!("{e}"),
    }
}

/// Validate `LEZO_THREADS` as a `Result` so entry points (trainer, bench
/// harness) can report a bad value as a normal CLI error instead of the
/// kernel-entry panic backstop.
pub fn check_env() -> anyhow::Result<()> {
    parse_env_threads(&std::env::var("LEZO_THREADS").unwrap_or_default())
        .map(|_| ())
        .map_err(anyhow::Error::msg)
}

/// The worker-thread count a parallel region entered from this thread
/// will use right now.
pub fn effective_threads() -> usize {
    if let Some(n) = env_threads() {
        return n;
    }
    let scoped = TL_THREADS.with(Cell::get);
    if scoped > 0 {
        return scoped;
    }
    match CONFIGURED.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// Fixed chunk length for `n_items` at minimum-`grain` granularity —
/// a pure function of the two arguments (never of the thread count).
pub fn chunk_len(n_items: usize, grain: usize) -> usize {
    n_items.div_ceil(MAX_PARTS).max(grain).max(1)
}

/// Run `f` over `0..n_items` split into fixed chunks. `f(range)` must be
/// safe to call concurrently for disjoint ranges and must not depend on
/// which chunk an item lands in (elementwise work, per-item reductions).
/// Runs inline when one chunk covers everything or only one thread is
/// configured.
pub fn par_ranges<F>(n_items: usize, grain: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    if n_items == 0 {
        return;
    }
    let chunk = chunk_len(n_items, grain);
    let n_parts = n_items.div_ceil(chunk);
    let threads = effective_threads().min(n_parts);
    if threads <= 1 {
        f(0..n_items);
        return;
    }
    let next = AtomicUsize::new(0);
    let work = || loop {
        let p = next.fetch_add(1, Ordering::Relaxed);
        if p >= n_parts {
            break;
        }
        let start = p * chunk;
        f(start..(start + chunk).min(n_items));
    };
    std::thread::scope(|s| {
        // the caller is worker 0 — spawn only the extra threads
        for _ in 1..threads {
            s.spawn(&work);
        }
        work();
    });
}

/// Raw-pointer wrapper so kernels can hand disjoint `&mut` sub-slices of
/// one output buffer to concurrent chunks. Every use site documents the
/// disjoint write pattern that makes it sound.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// # Safety
    /// `start..start + len` must be in bounds of the original allocation
    /// and must not alias any slice handed to another thread.
    pub(crate) unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

/// Parallel loop over disjoint row-chunks of a row-major `out` buffer
/// (`width` elements per row): `f(first_row, rows_slice)`. Generic over the
/// element type so the f32 kernels and their bf16 twins share one chunker.
pub fn par_row_chunks<T: Send, F>(out: &mut [T], width: usize, grain_rows: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    debug_assert!(width > 0 && out.len() % width == 0);
    let n_rows = out.len() / width;
    let ptr = SendPtr(out.as_mut_ptr());
    par_ranges(n_rows, grain_rows, |r| {
        // SAFETY: par_ranges chunks are disjoint row ranges of `out`.
        let rows = unsafe { ptr.slice_mut(r.start * width, (r.end - r.start) * width) };
        f(r.start, rows);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn chunking_is_fixed_and_covers_everything() {
        for n in [1usize, 7, 64, 65, 1000, 12345] {
            for grain in [1usize, 8, 4096] {
                let c = chunk_len(n, grain);
                assert!(c >= 1);
                assert!(n.div_ceil(c) <= MAX_PARTS.max(1));
                // chunk_len is a pure function of (n, grain)
                assert_eq!(c, chunk_len(n, grain));
            }
        }
    }

    #[test]
    fn par_ranges_visits_each_index_exactly_once() {
        let n = 1537;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        par_ranges(n, 16, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_row_chunks_writes_disjoint_rows() {
        let (rows, width) = (37, 5);
        let mut out = vec![0.0f32; rows * width];
        par_row_chunks(&mut out, width, 1, |r0, chunk| {
            for (rr, row) in chunk.chunks_exact_mut(width).enumerate() {
                for v in row.iter_mut() {
                    *v = (r0 + rr) as f32;
                }
            }
        });
        for (r, row) in out.chunks_exact(width).enumerate() {
            assert!(row.iter().all(|&v| v == r as f32), "row {r}");
        }
    }

    #[test]
    fn empty_range_is_a_noop() {
        par_ranges(0, 8, |_| panic!("must not be called"));
    }

    #[test]
    fn effective_threads_is_positive() {
        assert!(effective_threads() >= 1);
    }

    #[test]
    fn env_threads_parse_is_strict() {
        // unset / empty: no override
        assert_eq!(parse_env_threads(""), Ok(None));
        // positive integers are accepted
        assert_eq!(parse_env_threads("1"), Ok(Some(1)));
        assert_eq!(parse_env_threads("16"), Ok(Some(16)));
        // unparseable or zero values are hard errors naming the bad value
        for bad in ["abc", "0", "-3", "1.5", " 4"] {
            let err = parse_env_threads(bad).unwrap_err();
            assert!(err.contains(bad), "'{bad}': {err}");
            assert!(err.contains("LEZO_THREADS"), "'{bad}': {err}");
        }
    }

    #[test]
    fn with_threads_scopes_and_restores_including_on_panic() {
        if std::env::var("LEZO_THREADS").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED with_threads_scopes_and_restores: LEZO_THREADS wins");
            return;
        }
        let outer = effective_threads();
        let inner = with_threads(3, || {
            // nesting: innermost scope wins, then restores
            assert_eq!(with_threads(2, effective_threads), 2);
            effective_threads()
        });
        assert_eq!(inner, 3);
        assert_eq!(effective_threads(), outer, "override must be restored");
        let caught = std::panic::catch_unwind(|| with_threads(5, || panic!("boom")));
        assert!(caught.is_err());
        assert_eq!(effective_threads(), outer, "restored even on panic");
    }

    #[test]
    fn concurrent_scoped_overrides_are_isolated_per_thread() {
        // the sharded-backend contract: each worker thread sets its own
        // budget via with_threads, and no worker's override may leak into a
        // sibling's — the override is a thread-local, not process state
        if std::env::var("LEZO_THREADS").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED concurrent_scoped_overrides_are_isolated: LEZO_THREADS wins");
            return;
        }
        use std::sync::Barrier;
        let outer = effective_threads();
        let barrier = Barrier::new(2);
        let seen = std::thread::scope(|s| {
            let spawn_worker = |budget: usize| {
                let barrier = &barrier;
                s.spawn(move || {
                    // a fresh thread starts un-overridden (TL_THREADS does
                    // not propagate to spawned threads)
                    let before = effective_threads();
                    let inside = with_threads(budget, || {
                        // both workers hold their overrides at once; each
                        // must read only its own
                        barrier.wait();
                        let mine = effective_threads();
                        barrier.wait();
                        mine
                    });
                    (before, inside, effective_threads())
                })
            };
            let a = spawn_worker(2);
            let b = spawn_worker(7);
            (a.join().unwrap(), b.join().unwrap())
        });
        let ((a_before, a_in, a_after), (b_before, b_in, b_after)) = seen;
        assert_eq!(a_in, 2, "worker A reads its own override");
        assert_eq!(b_in, 7, "worker B reads its own override");
        assert_eq!(a_after, a_before, "A restored on exit");
        assert_eq!(b_after, b_before, "B restored on exit");
        assert_eq!(effective_threads(), outer, "coordinator untouched");
    }
}

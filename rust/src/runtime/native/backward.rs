//! Reference backward pass — the autodiff twin of [`super::forward`].
//!
//! [`forward_backward`] runs the flat-unit transformer forward while
//! recording the per-layer activations, then backpropagates the mean masked
//! cross-entropy through the tied LM head, final LN, every block (FFN,
//! causal attention, both LNs) and the embedding, producing one gradient
//! vector per layer unit in exactly the parameter layout of
//! [`crate::model::spec::ModelSpec`]. This is what makes `method=ft` and
//! `pretrain` run on the native backend with zero artifacts — the FO
//! baseline every headline claim of the paper is measured against.
//!
//! Design notes:
//!
//! - **Same math as the forward fast path.** The recording forward reuses
//!   the blocked kernels ([`kernels::matmul_bias_into`],
//!   [`kernels::layernorm_into`], [`kernels::attention_ctx`],
//!   [`kernels::gelu_inplace`]), so the hidden states are bit-identical to
//!   [`kernels::forward_hidden`]; the gradient formulas were cross-checked
//!   against `jax.value_and_grad` of the Python twin
//!   (`python/compile/model.py::loss_and_grads`) to float rounding, and are
//!   pinned in-tree by central finite-difference checks against
//!   [`super::forward::mean_loss`].
//! - **Deterministic parallelism.** Every parallel region goes through
//!   [`super::parallel`]'s fixed chunking with disjoint writes and fixed
//!   (ascending) reduction orders, so gradients are bit-identical at any
//!   thread count, like the forward families.
//! - **FO pays for activations — by design.** Unlike the fused ZO head,
//!   the backward materializes the `rows*seq*vocab` logits buffer and one
//!   activation record per block (~10 residual-width tensors, matching
//!   `metrics::MemoryModel::activation_bytes`). That asymmetry *is* the
//!   paper's "FT costs 12x memory" argument, reproduced structurally. The
//!   buffers are allocated per call (not arena-pooled like the ZO
//!   [`kernels::ForwardScratch`]): an FO step's compute dwarfs a handful
//!   of large allocations, and it keeps this entry point a pure function.

use super::kernels::{
    self, attention_ctx, dot, gelu_inplace, split_block, validate_forward_args,
    validate_targets, LN_EPS,
};
use super::parallel::{par_ranges, par_row_chunks, SendPtr};
use crate::model::spec::ModelSpec;
use anyhow::Result;

/// Minimum items per chunk for a parallel region (same rule as kernels.rs).
fn grain_for(per_item_ops: usize, target_ops: usize) -> usize {
    (target_ops / per_item_ops.max(1)).max(1)
}

// ---------------------------------------------------------------------------
// Backward linear algebra
// ---------------------------------------------------------------------------

/// `dx[r, i] = dot(dy[r, :], w[i, :])` — the input gradient of
/// `y = x @ w + b` with `w` row-major `(din, dout)`. Also doubles as the
/// dense `x @ w^T` product (the LM-head logits against the tied embedding).
/// Row-parallel over `dx`; each element is one fixed-order [`dot`].
fn matmul_dx_into(dy: &[f32], w: &[f32], dx: &mut [f32], n: usize, din: usize, dout: usize) {
    debug_assert_eq!(dy.len(), n * dout);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(dx.len(), n * din);
    let grain = grain_for(din * dout, 250_000);
    par_row_chunks(dx, din, grain, |r0, xrows| {
        for (rr, xrow) in xrows.chunks_exact_mut(din).enumerate() {
            let dyrow = &dy[(r0 + rr) * dout..(r0 + rr + 1) * dout];
            for (o, wrow) in xrow.iter_mut().zip(w.chunks_exact(dout)) {
                *o = dot(dyrow, wrow);
            }
        }
    });
}

/// `dw[i, o] = sum_r x[r, i] * dy[r, o]` — the weight gradient of
/// `y = x @ w + b`, accumulated in ascending-`r` order. Row-parallel over
/// `dw` (each weight row is owned by exactly one chunk).
fn matmul_dw_into(x: &[f32], dy: &[f32], dw: &mut [f32], n: usize, din: usize, dout: usize) {
    debug_assert_eq!(x.len(), n * din);
    debug_assert_eq!(dy.len(), n * dout);
    debug_assert_eq!(dw.len(), din * dout);
    let grain = grain_for(n * dout, 250_000);
    par_row_chunks(dw, dout, grain, |i0, wrows| {
        wrows.fill(0.0);
        for r in 0..n {
            let dyrow = &dy[r * dout..(r + 1) * dout];
            let xrow = &x[r * din + i0..r * din + i0 + wrows.len() / dout];
            for (&xv, wrow) in xrow.iter().zip(wrows.chunks_exact_mut(dout)) {
                for (o, &dv) in wrow.iter_mut().zip(dyrow) {
                    *o += xv * dv;
                }
            }
        }
    });
}

/// `db[o] = sum_r dy[r, o]`, ascending `r` (serial: bias gradients are a
/// vanishing fraction of the backward work).
fn bias_grad_into(dy: &[f32], db: &mut [f32], dout: usize) {
    db.fill(0.0);
    for dyrow in dy.chunks_exact(dout) {
        for (o, &dv) in db.iter_mut().zip(dyrow) {
            *o += dv;
        }
    }
}

/// Backward of the row-wise LayerNorm in [`kernels::layernorm_into`]:
/// recomputes each row's statistics from the saved *input* `x_in` (f64
/// reductions, f32 `inv`, exactly like the forward), then
/// `dx = inv * (dy*g - mean(dy*g) - xhat * mean(dy*g*xhat))`.
/// `dgamma[j] += sum_rows dy*xhat`, `dbeta[j] += sum_rows dy` (ascending
/// rows). Row-parallel for `dx`; the parameter gradients are a serial
/// second pass (they reduce *across* rows).
fn layernorm_bwd(
    dy: &[f32],
    x_in: &[f32],
    gamma: &[f32],
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    d: usize,
) {
    debug_assert!(dy.len() == x_in.len() && dx.len() == dy.len());
    debug_assert!(gamma.len() == d && dgamma.len() == d && dbeta.len() == d);
    let row_stats = |row: &[f32]| -> (f32, f32) {
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var = row.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>()
            / d as f64;
        (mean as f32, 1.0 / (var as f32 + LN_EPS).sqrt())
    };
    let grain = grain_for(8 * d, 65_536);
    par_row_chunks(dx, d, grain, |r0, orows| {
        for (rr, orow) in orows.chunks_exact_mut(d).enumerate() {
            let row = &x_in[(r0 + rr) * d..(r0 + rr + 1) * d];
            let dyrow = &dy[(r0 + rr) * d..(r0 + rr + 1) * d];
            let (mean, inv) = row_stats(row);
            let mut m1 = 0.0f64;
            let mut m2 = 0.0f64;
            for ((&dv, &g), &xv) in dyrow.iter().zip(gamma).zip(row) {
                let dxhat = (dv * g) as f64;
                m1 += dxhat;
                m2 += dxhat * ((xv - mean) * inv) as f64;
            }
            let m1 = (m1 / d as f64) as f32;
            let m2 = (m2 / d as f64) as f32;
            for ((o, (&dv, &g)), &xv) in orow.iter_mut().zip(dyrow.iter().zip(gamma)).zip(row) {
                let xhat = (xv - mean) * inv;
                *o = inv * (dv * g - m1 - xhat * m2);
            }
        }
    });
    for (dyrow, row) in dy.chunks_exact(d).zip(x_in.chunks_exact(d)) {
        let (mean, inv) = row_stats(row);
        for ((dg, db), (&dv, &xv)) in
            dgamma.iter_mut().zip(dbeta.iter_mut()).zip(dyrow.iter().zip(row))
        {
            *dg += dv * (xv - mean) * inv;
            *db += dv;
        }
    }
}

/// Derivative of the tanh-approximated GELU in [`kernels`].
#[inline]
fn dgelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    let u = C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Backward of the causal softmax attention in [`kernels::attention_ctx`]:
/// recomputes each (row, head) probability row from the saved q/k (cheap at
/// these sequence lengths — no `[seq, seq]` record per layer), then
/// `dv += probs^T dctx`, `ds = probs * (dp - sum(probs * dp))`,
/// `dq += scale * ds K`, `dk += scale * ds^T q`. Parallel over (row, head)
/// tasks writing disjoint head-column slices, like the forward.
#[allow(clippy::too_many_arguments)]
fn attention_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    d: usize,
    nh: usize,
    rows: usize,
    seq: usize,
) {
    let dh = d / nh;
    let scale = 1.0 / (dh as f32).sqrt();
    let dq_ptr = SendPtr(dq.as_mut_ptr());
    let dk_ptr = SendPtr(dk.as_mut_ptr());
    let dv_ptr = SendPtr(dv.as_mut_ptr());
    let grain = grain_for(2 * seq * seq * dh, 100_000);
    par_ranges(rows * nh, grain, |tasks| {
        let mut probs = vec![0.0f32; seq];
        let mut dp = vec![0.0f32; seq];
        for t in tasks {
            let (r, head) = (t / nh, t % nh);
            let hoff = head * dh;
            // SAFETY: (r, head) tasks own disjoint (row, head-column)
            // slices of dq/dk/dv; each task zeroes its own slices first.
            for s in 0..seq {
                unsafe { dq_ptr.slice_mut((r * seq + s) * d + hoff, dh) }.fill(0.0);
                unsafe { dk_ptr.slice_mut((r * seq + s) * d + hoff, dh) }.fill(0.0);
                unsafe { dv_ptr.slice_mut((r * seq + s) * d + hoff, dh) }.fill(0.0);
            }
            for s1 in 0..seq {
                let qrow = &q[(r * seq + s1) * d + hoff..][..dh];
                // recompute the causal softmax row (same order as forward)
                let mut max = f32::NEG_INFINITY;
                for (s2, sv) in probs[..=s1].iter_mut().enumerate() {
                    let krow = &k[(r * seq + s2) * d + hoff..][..dh];
                    let s = dot(qrow, krow) * scale;
                    *sv = s;
                    max = max.max(s);
                }
                let mut denom = 0.0f32;
                for sv in probs[..=s1].iter_mut() {
                    *sv = (*sv - max).exp();
                    denom += *sv;
                }
                for sv in probs[..=s1].iter_mut() {
                    *sv /= denom;
                }
                let dcrow = &dctx[(r * seq + s1) * d + hoff..][..dh];
                for (s2, dpv) in dp[..=s1].iter_mut().enumerate() {
                    let vrow = &v[(r * seq + s2) * d + hoff..][..dh];
                    *dpv = dot(dcrow, vrow);
                }
                let mut pdp = 0.0f32;
                for (&pv, &dpv) in probs[..=s1].iter().zip(&dp[..=s1]) {
                    pdp += pv * dpv;
                }
                // ds overwrites dp in place
                for (sv, dpv) in probs[..=s1].iter().zip(dp[..=s1].iter_mut()) {
                    *dpv = sv * (*dpv - pdp);
                }
                let dqrow = unsafe { dq_ptr.slice_mut((r * seq + s1) * d + hoff, dh) };
                for (s2, (&ds, &pv)) in dp[..=s1].iter().zip(&probs[..=s1]).enumerate() {
                    let krow = &k[(r * seq + s2) * d + hoff..][..dh];
                    for (o, &kv) in dqrow.iter_mut().zip(krow) {
                        *o += scale * ds * kv;
                    }
                    let dkrow = unsafe { dk_ptr.slice_mut((r * seq + s2) * d + hoff, dh) };
                    for (o, &qv) in dkrow.iter_mut().zip(qrow) {
                        *o += scale * ds * qv;
                    }
                    let dvrow = unsafe { dv_ptr.slice_mut((r * seq + s2) * d + hoff, dh) };
                    for (o, &cv) in dvrow.iter_mut().zip(dcrow) {
                        *o += pv * cv;
                    }
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Mutable block-unit views (gradient packing)
// ---------------------------------------------------------------------------

/// Mutable twin of [`kernels::split_block`]: named gradient views into one
/// flat block unit, same field order as the parameter layout.
struct BlockGrads<'a> {
    ln1_g: &'a mut [f32],
    ln1_b: &'a mut [f32],
    wq: &'a mut [f32],
    bq: &'a mut [f32],
    wk: &'a mut [f32],
    bk: &'a mut [f32],
    wv: &'a mut [f32],
    bv: &'a mut [f32],
    wo: &'a mut [f32],
    bo: &'a mut [f32],
    ln2_g: &'a mut [f32],
    ln2_b: &'a mut [f32],
    w1: &'a mut [f32],
    b1: &'a mut [f32],
    w2: &'a mut [f32],
    b2: &'a mut [f32],
}

fn split_block_mut<'a>(spec: &ModelSpec, mut g: &'a mut [f32]) -> BlockGrads<'a> {
    let d = spec.d_model;
    let f = spec.d_ff();
    let mut take = |n: usize| -> &'a mut [f32] {
        let (head, rest) = std::mem::take(&mut g).split_at_mut(n);
        g = rest;
        head
    };
    BlockGrads {
        ln1_g: take(d),
        ln1_b: take(d),
        wq: take(d * d),
        bq: take(d),
        wk: take(d * d),
        bk: take(d),
        wv: take(d * d),
        bv: take(d),
        wo: take(d * d),
        bo: take(d),
        ln2_g: take(d),
        ln2_b: take(d),
        w1: take(d * f),
        b1: take(f),
        w2: take(f * d),
        b2: take(d),
    }
}

// ---------------------------------------------------------------------------
// Forward with activation recording
// ---------------------------------------------------------------------------

/// Per-block activation record: everything the backward needs that is
/// cheaper to store than to recompute. LN statistics and attention
/// probabilities are recomputed from these instead (they are cheap).
struct LayerRec {
    /// Residual stream entering the block (ln1 input).
    h_in: Vec<f32>,
    /// ln1 output (q/k/v matmul input).
    x1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context (Wo matmul input).
    ctx: Vec<f32>,
    /// Residual stream after attention (ln2 input).
    h_mid: Vec<f32>,
    /// ln2 output (W1 matmul input).
    x2: Vec<f32>,
    /// FFN pre-activation (gelu input; gelu(a) is recomputed for dW2).
    a: Vec<f32>,
}

// ---------------------------------------------------------------------------
// Entry point
// ---------------------------------------------------------------------------

/// `(mean masked LM loss, per-unit gradients)` for one batch — the native
/// implementation of [`crate::runtime::backend::Backend::forward_backward`].
///
/// Gradient vectors have exactly the flat layout of their parameter units
/// (`spec.unit_lens()`), so `FoOptimizer::update` applies elementwise.
#[allow(clippy::too_many_arguments)]
pub fn forward_backward(
    spec: &ModelSpec,
    units: &[&[f32]],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    seq: usize,
) -> Result<(f32, Vec<Vec<f32>>)> {
    validate_forward_args(spec, units, tokens, rows, seq)?;
    let n = rows * seq;
    validate_targets(targets, mask, n, spec.vocab)?;
    let d = spec.d_model;
    let f = spec.d_ff();
    let v = spec.vocab;
    let nh = spec.n_heads;
    let emb = units[0];
    let tok_emb = &emb[..v * d];

    // ---- forward, recording per-block activations ----
    let mut h = vec![0.0f32; n * d];
    {
        let pos_emb = &emb[v * d..];
        for r in 0..rows {
            for s in 0..seq {
                let t = tokens[r * seq + s] as usize;
                let hrow = &mut h[(r * seq + s) * d..(r * seq + s + 1) * d];
                let te = &tok_emb[t * d..(t + 1) * d];
                let pe = &pos_emb[s * d..(s + 1) * d];
                for ((hv, &tv), &pv) in hrow.iter_mut().zip(te).zip(pe) {
                    *hv = tv + pv;
                }
            }
        }
    }

    let mut rec = Vec::with_capacity(spec.n_layers);
    let mut proj = vec![0.0f32; n * d]; // attention/FFN projection buffer
    for l in 0..spec.n_layers {
        let p = split_block(spec, units[1 + l]);
        let h_in = h.clone();
        let mut x1 = vec![0.0f32; n * d];
        kernels::layernorm_into(&h_in, p.ln1_g, p.ln1_b, &mut x1, d);
        let mut q = vec![0.0f32; n * d];
        let mut k = vec![0.0f32; n * d];
        let mut vv = vec![0.0f32; n * d];
        kernels::matmul_bias_into(&x1, p.wq, p.bq, &mut q, n, d, d);
        kernels::matmul_bias_into(&x1, p.wk, p.bk, &mut k, n, d, d);
        kernels::matmul_bias_into(&x1, p.wv, p.bv, &mut vv, n, d, d);
        let mut ctx = vec![0.0f32; n * d];
        attention_ctx(&q, &k, &vv, None, &mut ctx, d, nh, rows, seq);
        kernels::matmul_bias_into(&ctx, p.wo, p.bo, &mut proj, n, d, d);
        kernels::add_inplace(&mut h, &proj);
        let h_mid = h.clone();
        let mut x2 = vec![0.0f32; n * d];
        kernels::layernorm_into(&h_mid, p.ln2_g, p.ln2_b, &mut x2, d);
        let mut a = vec![0.0f32; n * f];
        kernels::matmul_bias_into(&x2, p.w1, p.b1, &mut a, n, d, f);
        let mut gact = a.clone();
        gelu_inplace(&mut gact);
        let mut m = vec![0.0f32; n * d];
        kernels::matmul_bias_into(&gact, p.w2, p.b2, &mut m, n, f, d);
        kernels::add_inplace(&mut h, &m);
        rec.push(LayerRec { h_in, x1, q, k, v: vv, ctx, h_mid, x2, a });
    }

    let fin = units[spec.n_units() - 1];
    let hf = h; // block-stack output (final-LN input)
    let mut xf = vec![0.0f32; n * d];
    kernels::layernorm_into(&hf, &fin[..d], &fin[d..], &mut xf, d);

    // ---- LM head: dense logits (FO pays activation memory, see module docs)
    let mut logits = vec![0.0f32; n * v];
    matmul_dx_into(&xf, tok_emb, &mut logits, n, v, d);

    // per-position logsumexp (masked positions only; serial loss reduction)
    let mut logz = vec![0.0f64; n];
    {
        let ptr = SendPtr(logz.as_mut_ptr());
        let grain = grain_for(2 * v, 2_000_000);
        par_ranges(n, grain, |range| {
            // SAFETY: par_ranges chunks are disjoint position ranges.
            let out = unsafe { ptr.slice_mut(range.start, range.end - range.start) };
            for (o, p) in out.iter_mut().zip(range) {
                if mask[p] <= 0.0 {
                    *o = 0.0;
                    continue;
                }
                let row = &logits[p * v..(p + 1) * v];
                let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let sum: f64 = row.iter().map(|&l| ((l - max) as f64).exp()).sum();
                *o = max as f64 + sum.ln();
            }
        });
    }
    let den = mask.iter().map(|&m| m as f64).sum::<f64>().max(1.0);
    let mut num = 0.0f64;
    for (p, (&m, &lz)) in mask.iter().zip(&logz).enumerate() {
        if m > 0.0 {
            num += m as f64 * (lz - logits[p * v + targets[p] as usize] as f64);
        }
    }
    let loss = (num / den) as f32;

    // logits -> dlogits in place: w_p * (softmax - onehot(target)), 0 off-mask
    {
        let grain = grain_for(2 * v, 2_000_000);
        par_row_chunks(&mut logits, v, grain, |p0, lrows| {
            for (pp, lrow) in lrows.chunks_exact_mut(v).enumerate() {
                let p = p0 + pp;
                if mask[p] <= 0.0 {
                    lrow.fill(0.0);
                    continue;
                }
                let w = mask[p] as f64 / den;
                let lz = logz[p];
                for lv in lrow.iter_mut() {
                    *lv = (w * (*lv as f64 - lz).exp()) as f32;
                }
                lrow[targets[p] as usize] -= w as f32;
            }
        });
    }
    let dlogits = logits;

    // ---- backward ----
    let mut grads: Vec<Vec<f32>> =
        spec.unit_lens().into_iter().map(|len| vec![0.0f32; len]).collect();

    // tied head: d_xf = dlogits @ E, d_tok_emb = dlogits^T @ xf
    let mut dxf = vec![0.0f32; n * d];
    let zero_bias = vec![0.0f32; d];
    kernels::matmul_bias_into(&dlogits, tok_emb, &zero_bias, &mut dxf, n, v, d);
    matmul_dw_into(&dlogits, &xf, &mut grads[0][..v * d], n, v, d);
    drop(dlogits);

    // final LN
    let mut dh = vec![0.0f32; n * d];
    {
        let (gfin_g, gfin_b) = grads[spec.n_units() - 1].split_at_mut(d);
        layernorm_bwd(&dxf, &hf, &fin[..d], &mut dh, gfin_g, gfin_b, d);
    }

    let mut dbuf = vec![0.0f32; n * d];
    let mut dln = vec![0.0f32; n * d];
    let mut da = vec![0.0f32; n * f];
    let mut gact = vec![0.0f32; n * f];
    let mut dctx = vec![0.0f32; n * d];
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dvv = vec![0.0f32; n * d];
    for l in (0..spec.n_layers).rev() {
        let p = split_block(spec, units[1 + l]);
        let r = &rec[l];
        let gb = split_block_mut(spec, &mut grads[1 + l]);

        // FFN: h_out = h_mid + gelu(x2 @ w1 + b1) @ w2 + b2
        gact.copy_from_slice(&r.a);
        gelu_inplace(&mut gact);
        matmul_dw_into(&gact, &dh, gb.w2, n, f, d);
        bias_grad_into(&dh, gb.b2, d);
        matmul_dx_into(&dh, p.w2, &mut da, n, f, d);
        {
            let a = &r.a;
            let ptr = SendPtr(da.as_mut_ptr());
            par_ranges(a.len(), grain_for(48, 250_000), |range| {
                // SAFETY: par_ranges chunks are disjoint element ranges.
                let chunk = unsafe { ptr.slice_mut(range.start, range.end - range.start) };
                for (o, &av) in chunk.iter_mut().zip(&a[range]) {
                    *o *= dgelu(av);
                }
            });
        }
        matmul_dw_into(&r.x2, &da, gb.w1, n, d, f);
        bias_grad_into(&da, gb.b1, f);
        matmul_dx_into(&da, p.w1, &mut dbuf, n, d, f);
        layernorm_bwd(&dbuf, &r.h_mid, p.ln2_g, &mut dln, gb.ln2_g, gb.ln2_b, d);
        kernels::add_inplace(&mut dh, &dln); // dh = d h_mid

        // attention: h_mid = h_in + ctx @ wo + bo
        matmul_dw_into(&r.ctx, &dh, gb.wo, n, d, d);
        bias_grad_into(&dh, gb.bo, d);
        matmul_dx_into(&dh, p.wo, &mut dctx, n, d, d);
        attention_bwd(&r.q, &r.k, &r.v, &dctx, &mut dq, &mut dk, &mut dvv, d, nh, rows, seq);
        matmul_dw_into(&r.x1, &dq, gb.wq, n, d, d);
        bias_grad_into(&dq, gb.bq, d);
        matmul_dw_into(&r.x1, &dk, gb.wk, n, d, d);
        bias_grad_into(&dk, gb.bk, d);
        matmul_dw_into(&r.x1, &dvv, gb.wv, n, d, d);
        bias_grad_into(&dvv, gb.bv, d);
        matmul_dx_into(&dq, p.wq, &mut dbuf, n, d, d);
        matmul_dx_into(&dk, p.wk, &mut dln, n, d, d);
        kernels::add_inplace(&mut dbuf, &dln);
        matmul_dx_into(&dvv, p.wv, &mut dln, n, d, d);
        kernels::add_inplace(&mut dbuf, &dln);
        layernorm_bwd(&dbuf, &r.h_in, p.ln1_g, &mut dln, gb.ln1_g, gb.ln1_b, d);
        kernels::add_inplace(&mut dh, &dln); // dh = d h_in
    }

    // embedding: h0[p] = tok_emb[tokens[p]] + pos_emb[s]. Serial scatter —
    // duplicate tokens alias the same gradient row, so the ascending-p
    // order is the determinism contract here.
    {
        let gemb = &mut grads[0];
        for (p, dhrow) in dh.chunks_exact(d).enumerate() {
            let t = tokens[p] as usize;
            let grow = &mut gemb[t * d..(t + 1) * d];
            for (o, &dv) in grow.iter_mut().zip(dhrow) {
                *o += dv;
            }
        }
        let gpos = &mut gemb[v * d..];
        for r in 0..rows {
            for s in 0..seq {
                let dhrow = &dh[(r * seq + s) * d..(r * seq + s + 1) * d];
                let grow = &mut gpos[s * d..(s + 1) * d];
                for (o, &dv) in grow.iter_mut().zip(dhrow) {
                    *o += dv;
                }
            }
        }
    }

    Ok((loss, grads))
}

#[cfg(test)]
mod tests {
    use super::super::forward;
    use super::super::kernels::ForwardScratch;
    use super::*;
    use crate::runtime::philox::gauss_from_index;

    fn spec() -> ModelSpec {
        ModelSpec::preset("opt-nano").unwrap()
    }

    fn refs(host: &[Vec<f32>]) -> Vec<&[f32]> {
        host.iter().map(|u| u.as_slice()).collect()
    }

    /// Deterministic batch with a mixed mask (mirrors the calibration run
    /// against the Python twin's `jax.value_and_grad`).
    fn batch(s: &ModelSpec, rows: usize, seq: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let tokens: Vec<i32> =
            (0..rows * seq).map(|i| 20 + ((i * 7 + i / seq) % 200) as i32).collect();
        let targets: Vec<i32> =
            tokens.iter().map(|&t| (t + 3) % s.vocab as i32).collect();
        let mask: Vec<f32> = (0..rows * seq)
            .map(|i| if i / seq == 0 || i % 3 != 1 { 1.0 } else { 0.0 })
            .collect();
        (tokens, targets, mask)
    }

    /// Generic parameter point: init + 0.05 * Philox draw per unit, so no
    /// gradient is pinned at an init symmetry (final-LN betas are exactly
    /// zero at init, which makes their gradient signal tiny).
    fn generic_point(s: &ModelSpec) -> Vec<Vec<f32>> {
        let mut host = s.init_units(0);
        for (k, u) in host.iter_mut().enumerate() {
            kernels::axpy_gauss_inplace(u, 7000 + k as u32, 0.05);
        }
        host
    }

    #[test]
    fn loss_matches_forward_loss() {
        let s = spec();
        let host = generic_point(&s);
        let (rows, seq) = (4, 16);
        let (tokens, targets, mask) = batch(&s, rows, seq);
        let (loss, grads) =
            forward_backward(&s, &refs(&host), &tokens, &targets, &mask, rows, seq).unwrap();
        let mut scratch = ForwardScratch::new();
        let want =
            forward::mean_loss(&s, &refs(&host), &tokens, &targets, &mask, rows, seq, &mut scratch)
                .unwrap();
        assert!((loss - want).abs() < 1e-5, "fb loss {loss} vs forward {want}");
        assert_eq!(grads.len(), s.n_units());
        for (g, len) in grads.iter().zip(s.unit_lens()) {
            assert_eq!(g.len(), len);
            assert!(g.iter().all(|x| x.is_finite()));
        }
    }

    /// The acceptance criterion: a high-order central finite difference of
    /// `forward_loss` along a Philox probe direction pins every unit's
    /// gradient to <= 1e-3 relative error. The scheme was calibrated
    /// against the Python twin (`jax.value_and_grad`) in f32: a plain
    /// 2nd-order difference cannot reach 1e-3 (truncation vs f32-rounding
    /// trade-off), so the check evaluates the loss at +-eps, +-2eps, +-4eps
    /// and takes the best of the two 4th-order estimates and their
    /// 6th-order Richardson combination — worst observed error across
    /// batches/inits in calibration was 3.5e-4 (~3x headroom).
    #[test]
    fn grads_match_finite_difference_on_every_unit() {
        let s = spec();
        let host = generic_point(&s);
        let (rows, seq) = (4, 16);
        let (tokens, targets, mask) = batch(&s, rows, seq);
        let (_, grads) =
            forward_backward(&s, &refs(&host), &tokens, &targets, &mask, rows, seq).unwrap();

        let mut scratch = ForwardScratch::new();
        let mut loss_at = |k: usize, probe_seed: u32, c: f32| -> f64 {
            let mut probed = host.clone();
            kernels::axpy_gauss_inplace(&mut probed[k], probe_seed, c);
            let pr = refs(&probed);
            let l = forward::mean_loss(&s, &pr, &tokens, &targets, &mask, rows, seq, &mut scratch);
            l.unwrap() as f64
        };

        for (k, g) in grads.iter().enumerate() {
            // Probe-seed scan: a random direction occasionally lands nearly
            // orthogonal to the gradient, where the FD quotient is all
            // rounding noise; take the first Philox seed with real signal.
            // Small units (the LNs) have small gradient norms, so they get
            // a lower signal bar and a larger eps (still << 1 relative to
            // their O(1) gamma values).
            let small = g.len() < 1024;
            let floor: f64 = if small { 0.05 } else { 1.0 };
            let eps: f32 = if small { 2e-2 } else { 1e-3 };
            let mut chosen = (1000 + 16 * k as u32, 0.0f64);
            for trial in 0..16u32 {
                let seed = 1000 + 16 * k as u32 + trial;
                let analytic: f64 = g
                    .iter()
                    .enumerate()
                    .map(|(i, &gv)| gv as f64 * gauss_from_index(i as u32, seed) as f64)
                    .sum();
                if analytic.abs() >= chosen.1.abs() {
                    chosen = (seed, analytic);
                }
                if analytic.abs() >= floor {
                    break;
                }
            }
            let (seed, analytic) = chosen;
            assert!(
                analytic.abs() >= floor / 2.0,
                "unit {k}: no probe with usable signal (best |g.z| = {})",
                analytic.abs()
            );
            let e = eps as f64;
            let d1 = loss_at(k, seed, eps) - loss_at(k, seed, -eps);
            let d2 = loss_at(k, seed, 2.0 * eps) - loss_at(k, seed, -2.0 * eps);
            let d4 = loss_at(k, seed, 4.0 * eps) - loss_at(k, seed, -4.0 * eps);
            let fd4a = (8.0 * d1 - d2) / (12.0 * e);
            let fd4b = (8.0 * d2 - d4) / (24.0 * e);
            let fd6 = (64.0 * fd4a - fd4b) / 63.0;
            let rel = [fd4a, fd4b, fd6]
                .iter()
                .map(|fd| (fd - analytic).abs() / analytic.abs())
                .fold(f64::INFINITY, f64::min);
            assert!(
                rel <= 1e-3,
                "unit {k}: fd {fd4a:.6}/{fd4b:.6}/{fd6:.6} vs analytic {analytic:.6} \
                 (rel {rel:.2e}, seed {seed})"
            );
        }
    }

    #[test]
    fn grads_are_deterministic_and_thread_count_invariant() {
        use super::super::parallel::with_threads;
        if std::env::var("LEZO_THREADS").map(|s| !s.is_empty()).unwrap_or(false) {
            eprintln!("SKIPPED grads_are_deterministic: LEZO_THREADS overrides the scope");
            return;
        }
        let s = spec();
        let host = generic_point(&s);
        let (rows, seq) = (2, 16);
        let (tokens, targets, mask) = batch(&s, rows, seq);
        let run = |threads: usize| {
            with_threads(threads, || {
                forward_backward(&s, &refs(&host), &tokens, &targets, &mask, rows, seq).unwrap()
            })
        };
        let (l1, g1) = run(1);
        let (l8, g8) = run(8);
        assert_eq!(l1.to_bits(), l8.to_bits(), "loss must be bit-identical");
        assert_eq!(g1, g8, "grads must be bit-identical across thread counts");
    }

    #[test]
    fn masked_out_positions_contribute_no_gradient() {
        // An all-masked-out batch: loss 0, every gradient exactly 0 (no
        // position reaches the head, so nothing flows back).
        let s = spec();
        let host = generic_point(&s);
        let (rows, seq) = (2, 8);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| 30 + (i % 64) as i32).collect();
        let targets = vec![0i32; rows * seq];
        let mask = vec![0.0f32; rows * seq];
        let (loss, grads) =
            forward_backward(&s, &refs(&host), &tokens, &targets, &mask, rows, seq).unwrap();
        assert_eq!(loss, 0.0);
        for (k, g) in grads.iter().enumerate() {
            assert!(g.iter().all(|&x| x == 0.0), "unit {k} must have zero grads");
        }
    }

    #[test]
    fn rejects_bad_shapes_and_in_mask_oov_targets() {
        let s = spec();
        let host = s.init_units(0);
        let (rows, seq) = (1, 4);
        let tokens = vec![10, 11, 12, 13];
        let mut targets = vec![11, 12, 13, 0];
        // masked-out OOV target is fine (padding), in-mask is a hard error
        targets[3] = s.vocab as i32 + 7;
        let mask_out = vec![1.0, 1.0, 1.0, 0.0];
        assert!(forward_backward(&s, &refs(&host), &tokens, &targets, &mask_out, rows, seq)
            .is_ok());
        let mask_in = vec![1.0; 4];
        let err = forward_backward(&s, &refs(&host), &tokens, &targets, &mask_in, rows, seq)
            .unwrap_err();
        assert!(err.to_string().contains("outside the vocab"), "{err}");
        // wrong unit count
        assert!(forward_backward(&s, &refs(&host[..2]), &tokens, &targets, &mask_out, rows, seq)
            .is_err());
    }

    #[test]
    fn gelu_derivative_matches_finite_difference() {
        for &x in &[-3.0f32, -1.0, -0.1, 0.0, 0.1, 1.0, 3.0] {
            let e = 1e-3f32;
            let fd = (kernels::gelu(x + e) as f64 - kernels::gelu(x - e) as f64) / (2.0 * e as f64);
            let an = dgelu(x) as f64;
            // 2nd-order FD of the f32 gelu: ~1e-4 rounding noise floor
            assert!((fd - an).abs() < 5e-4, "x={x}: fd {fd} vs {an}");
        }
    }
}

//! Software bfloat16 for the native reduced-precision path — no new deps.
//!
//! Storage is a plain `u16` holding the top half of the IEEE-754 binary32
//! layout (1 sign, 8 exponent, 7 mantissa bits): widening back to f32 is an
//! exact bit shift, and narrowing rounds the low 16 bits to nearest, ties
//! to even — the same rule `ml_dtypes.bfloat16` (the numpy/jax reference)
//! applies, verified in-container against it over an exhaustive sweep of
//! every 16-bit high half times adversarial low halves plus 200k random
//! finite floats (0 mismatches). The conversion KATs below pin that
//! agreement in-tree.
//!
//! Semantics worth naming:
//!
//! - **Round to nearest, ties to even** on the discarded 16 bits
//!   (`0x3F808000` — exactly halfway between 1.0 and the next bf16 —
//!   rounds *down* to even `0x3F80`; `0x3F818000` rounds *up* to even
//!   `0x3F82`).
//! - **Subnormals are kept, not flushed**: bf16 shares f32's exponent
//!   range, so every f32 subnormal rounds onto the bf16 subnormal grid by
//!   the same integer arithmetic (no special case); f32 values below half
//!   the smallest bf16 subnormal round to (signed) zero.
//! - **NaN stays NaN**: rounding arithmetic could carry a NaN mantissa up
//!   into the infinity encoding, so NaNs are truncated instead, with a
//!   quiet bit forced only when the payload lived entirely in the
//!   discarded half. Infinities and signed zeros pass through exactly.
//! - **`bf16 -> f32 -> bf16` is the identity for all 65536 bit patterns**
//!   (widening is exact and exactly-representable values round to
//!   themselves; NaN truncation preserves an already-16-bit payload) —
//!   pinned exhaustively below.
//!
//! The hot-path kernels ([`super::kernels`]) never round intermediates:
//! they widen operands on the fly, accumulate in f32 (f64 where the f32
//! twin does), and round once on store. [`cast_into`] is the bulk
//! f32 -> bf16 shadow re-cast, chunk-parallel through the same fixed
//! partitioning as every other native kernel.

use super::parallel::{par_ranges, SendPtr};

/// Round an f32 to bf16 storage bits (round to nearest, ties to even).
#[inline(always)]
pub fn to_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // truncate the payload; force a quiet bit only if truncation would
        // otherwise produce an infinity encoding
        let mut r = (bits >> 16) as u16;
        if r & 0x7F == 0 {
            r |= 0x40;
        }
        return r;
    }
    // round-half-even: add 0x7FFF plus the parity of the kept LSB, so an
    // exact tie (low half == 0x8000) carries only when the kept half is odd
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// Widen bf16 storage bits to f32 — exact (a pure bit shift).
#[inline(always)]
pub fn to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Bulk f32 -> bf16 cast (the shadow re-cast of a touched unit).
/// Chunk-parallel with fixed partitioning; elementwise, so results are
/// identical at any thread count.
pub fn cast_into(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    let ptr = SendPtr(dst.as_mut_ptr());
    par_ranges(src.len(), 64 * 1024, |r| {
        // SAFETY: par_ranges chunks are disjoint element ranges of `dst`.
        let out = unsafe { ptr.slice_mut(r.start, r.end - r.start) };
        for (o, &x) in out.iter_mut().zip(&src[r.start..r.end]) {
            *o = to_bits(x);
        }
    });
}

/// Bulk bf16 -> f32 widening (tests and the dense bf16 reference).
pub fn widen_into(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (o, &b) in dst.iter_mut().zip(src) {
        *o = to_f32(b);
    }
}

/// Convenience: widen a bf16 slice into a fresh Vec (tests, references).
pub fn widen(src: &[u16]) -> Vec<f32> {
    src.iter().map(|&b| to_f32(b)).collect()
}

/// Convenience: round an f32 slice onto the bf16 grid (tests, references).
pub fn cast(src: &[f32]) -> Vec<u16> {
    src.iter().map(|&x| to_bits(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Known-answer pairs generated with `ml_dtypes.bfloat16` (numpy), the
    /// reference rounding both jax and XLA use: (f32 bits, bf16 bits).
    /// Covers signed zeros, exact values, round-half-even ties in both
    /// directions, inf/overflow-to-inf, normals at the subnormal boundary,
    /// subnormal keep/flush-to-zero, and repeating-fraction rounding.
    const KAT: &[(u32, u16)] = &[
        (0x00000000, 0x0000), // 0.0
        (0x80000000, 0x8000), // -0.0
        (0x3F800000, 0x3F80), // 1.0
        (0xBF800000, 0xBF80), // -1.0
        (0x40000000, 0x4000), // 2.0
        (0x3F000000, 0x3F00), // 0.5
        (0x3F808000, 0x3F80), // 1 + 2^-8: tie, rounds down to even
        (0x3F818000, 0x3F82), // 1 + 3*2^-8: tie, rounds up to even
        (0x40490FDB, 0x4049), // pi
        (0xC0490FDB, 0xC049), // -pi
        (0x477FE000, 0x4780), // 65504.0 (fp16 max) rounds up
        (0x7F7F0000, 0x7F7F), // largest bf16 normal, exact
        (0x7F7FFFFF, 0x7F80), // f32::MAX rounds to +inf
        (0x7F800000, 0x7F80), // +inf
        (0xFF800000, 0xFF80), // -inf
        (0x006CE3EE, 0x006D), // 1e-38 (f32 subnormal regime boundary area)
        (0x00800000, 0x0080), // smallest f32 normal
        (0x000116C2, 0x0001), // 1e-40: subnormal, kept (not flushed)
        (0x00010000, 0x0001), // smallest bf16 subnormal, exact
        (0x00000001, 0x0000), // below half the smallest subnormal -> +0
        (0x00400000, 0x0040), // 2^-127 subnormal, exact
        (0x3E200000, 0x3E20), // 0.15625, exact in bf16
        (0x3DCCCCCD, 0x3DCD), // 0.1 rounds up
        (0x3E4CCCCD, 0x3E4D), // 0.2 rounds up
        (0x3E99999A, 0x3E9A), // 0.3 rounds up
        (0x3EAAAAAB, 0x3EAB), // 1/3 rounds up
    ];

    #[test]
    fn conversion_known_answers_match_ml_dtypes() {
        for &(f32_bits, want) in KAT {
            let x = f32::from_bits(f32_bits);
            let got = to_bits(x);
            assert_eq!(
                got, want,
                "f32 0x{f32_bits:08X} ({x}): got 0x{got:04X}, want 0x{want:04X}"
            );
        }
    }

    #[test]
    fn nan_and_inf_are_preserved() {
        assert!(to_f32(to_bits(f32::NAN)).is_nan());
        assert_eq!(to_f32(to_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(to_f32(to_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        // a NaN whose payload lives entirely in the discarded low half must
        // not truncate into an infinity encoding
        let low_payload_nan = f32::from_bits(0x7F80_0001);
        assert!(low_payload_nan.is_nan());
        let b = to_bits(low_payload_nan);
        assert!(to_f32(b).is_nan(), "0x{b:04X} decoded as non-NaN");
        // sign of NaN survives
        let neg = f32::from_bits(0xFF80_0001);
        assert_eq!(to_bits(neg) >> 15, 1);
    }

    #[test]
    fn round_half_even_tie_cases() {
        // halfway values: kept-LSB even -> down, odd -> up
        for (f32_bits, want) in [
            (0x3F80_8000u32, 0x3F80u16), // 1.0 + half ulp -> stays 1.0 (even)
            (0x3F81_8000, 0x3F82),       // next: rounds up to even
            (0x4000_8000, 0x4000),       // 2.0 + half ulp -> stays (even)
            (0x4001_8000, 0x4002),       // odd kept half rounds up
            (0xBF80_8000, 0xBF80),       // same, negative sign
            (0xBF81_8000, 0xBF82),
        ] {
            assert_eq!(to_bits(f32::from_bits(f32_bits)), want, "0x{f32_bits:08X}");
        }
        // just above / below the tie break the tie normally
        assert_eq!(to_bits(f32::from_bits(0x3F80_8001)), 0x3F81);
        assert_eq!(to_bits(f32::from_bits(0x3F80_7FFF)), 0x3F80);
    }

    #[test]
    fn subnormals_round_onto_the_bf16_grid_not_flushed() {
        // bf16 shares f32's exponent range: subnormal f32 values stay
        // subnormal bf16 values under the same integer rounding
        let smallest_bf16_sub = f32::from_bits(0x0001_0000);
        assert_eq!(to_f32(to_bits(smallest_bf16_sub)), smallest_bf16_sub);
        // half of it (a tie against zero with even kept half) rounds to +0
        assert_eq!(to_bits(f32::from_bits(0x0000_8000)), 0x0000);
        // just above half rounds up to the smallest subnormal
        assert_eq!(to_bits(f32::from_bits(0x0000_8001)), 0x0001);
        // negative side keeps the sign
        assert_eq!(to_bits(f32::from_bits(0x8000_8000)), 0x8000);
        assert_eq!(to_bits(f32::from_bits(0x8001_0000)), 0x8001);
    }

    #[test]
    fn round_trip_is_identity_for_all_65536_patterns() {
        for b in 0..=u16::MAX {
            let widened = to_f32(b);
            let back = to_bits(widened);
            assert_eq!(
                back, b,
                "bf16 0x{b:04X} -> f32 {widened} -> 0x{back:04X} is not the identity"
            );
        }
    }

    #[test]
    fn widening_is_exact_and_monotone_on_normals() {
        // widening is a bit shift: the produced f32 re-narrows exactly, and
        // relative error of narrowing a finite normal is bounded by 2^-8
        for i in 0..10_000u32 {
            let x = (i as f32 - 5_000.0) * 0.37 + 0.001;
            let r = to_f32(to_bits(x));
            let rel = ((r - x) / x).abs();
            assert!(rel <= 1.0 / 256.0, "x={x} r={r} rel={rel}");
        }
    }

    #[test]
    fn bulk_casts_match_scalar_and_round_trip() {
        let src: Vec<f32> = (0..10_000).map(|i| ((i as f32) * 0.731).sin() * 3.0).collect();
        let mut bits = vec![0u16; src.len()];
        cast_into(&src, &mut bits);
        for (i, (&b, &x)) in bits.iter().zip(&src).enumerate() {
            assert_eq!(b, to_bits(x), "i={i}");
        }
        let mut wide = vec![0.0f32; src.len()];
        widen_into(&bits, &mut wide);
        let mut again = vec![0u16; src.len()];
        cast_into(&wide, &mut again);
        assert_eq!(bits, again, "cast -> widen -> cast must be stable");
        assert_eq!(cast(&src), bits);
        assert_eq!(widen(&bits), wide);
    }
}

//! Runtime-dispatched SIMD inner loops for the native kernels.
//!
//! Every primitive here has two implementations: a **scalar reference**
//! (`*_scalar`, public so differential tests can call it directly) and an
//! x86-64 vector path selected at runtime behind a single AVX2 feature
//! check. The public entry points dispatch between them; on non-x86_64
//! targets they compile straight down to the scalar reference.
//!
//! # Bit-identity contract
//!
//! The vector paths are required to produce **bitwise identical** results
//! to their scalar twins, not merely close ones. That is possible because
//! each primitive is either
//!
//! - purely elementwise (`axpy_row*`, `decode_i8`): each output lane is
//!   one IEEE multiply + one IEEE add of the same operands the scalar
//!   loop uses, and vector `mul_ps`/`add_ps` are correctly rounded exactly
//!   like their scalar counterparts; or
//! - a reduction whose *scalar reference already fixes the lane
//!   structure*: `dot`/`dot_bf16` accumulate into four independent
//!   partial sums over `chunks_exact(4)` (see `kernels::dot`), so a
//!   128-bit accumulator vector carries exactly those four partials —
//!   lane `i` of the vector equals `acc[i]` of the scalar loop after
//!   every chunk — and the final reduction `(a0+a1) + (a2+a3) + tail`
//!   is performed in the same scalar order by both paths.
//!
//! No FMA contraction is used anywhere (a fused multiply-add would round
//! once where the scalar twin rounds twice, breaking the contract).
//!
//! `kernel_twins.rs` and the in-module tests below pin `f(x) ==
//! f_scalar(x)` bitwise on every input they generate; on machines without
//! AVX2 the dispatchers run the scalar path and the pin is trivial.

/// True when the vector paths are eligible on this machine (x86-64 with
/// AVX2). `std::arch::is_x86_feature_detected!` caches the CPUID probe,
/// so calling this in inner-kernel prologues is cheap.
pub fn active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Widen one bf16 pattern (matches `bf16::to_f32`: bits shifted into the
/// high half of an f32). Inlined here so the scalar tails below are
/// self-contained.
#[inline(always)]
fn widen_bf16(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

// ---------------------------------------------------------------------------
// axpy over a row: acc[j] += x * w[j]
// ---------------------------------------------------------------------------

/// Scalar reference: `acc[j] += x * w[j]` for every `j`.
pub fn axpy_row_scalar(acc: &mut [f32], x: f32, w: &[f32]) {
    debug_assert_eq!(acc.len(), w.len());
    for (o, &wv) in acc.iter_mut().zip(w) {
        *o += x * wv;
    }
}

/// `acc[j] += x * w[j]`, vectorized 8-wide when AVX2 is available.
/// Elementwise, so bit-identical to [`axpy_row_scalar`] by construction.
#[inline]
pub fn axpy_row(acc: &mut [f32], x: f32, w: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: `active()` verified AVX2 support at runtime.
        unsafe { axpy_row_avx2(acc, x, w) };
        return;
    }
    axpy_row_scalar(acc, x, w);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_row_avx2(acc: &mut [f32], x: f32, w: &[f32]) {
    use std::arch::x86_64::*;
    debug_assert_eq!(acc.len(), w.len());
    let n = acc.len();
    let n8 = n - n % 8;
    let xv = _mm256_set1_ps(x);
    let mut j = 0;
    while j < n8 {
        let wv = _mm256_loadu_ps(w.as_ptr().add(j));
        let av = _mm256_loadu_ps(acc.as_ptr().add(j));
        _mm256_storeu_ps(acc.as_mut_ptr().add(j), _mm256_add_ps(av, _mm256_mul_ps(xv, wv)));
        j += 8;
    }
    for jj in n8..n {
        *acc.get_unchecked_mut(jj) += x * *w.get_unchecked(jj);
    }
}

/// Scalar reference for the bf16-weight axpy: widen each weight, then the
/// same multiply-add as [`axpy_row_scalar`].
pub fn axpy_row_bf16_scalar(acc: &mut [f32], x: f32, w: &[u16]) {
    debug_assert_eq!(acc.len(), w.len());
    for (o, &wb) in acc.iter_mut().zip(w) {
        *o += x * widen_bf16(wb);
    }
}

/// `acc[j] += x * widen(w[j])` over bf16 weight bits; the AVX2 path
/// widens 8 lanes with a shift (exact — bf16→f32 is lossless) and then
/// performs the identical elementwise multiply-add.
#[inline]
pub fn axpy_row_bf16(acc: &mut [f32], x: f32, w: &[u16]) {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: `active()` verified AVX2 support at runtime.
        unsafe { axpy_row_bf16_avx2(acc, x, w) };
        return;
    }
    axpy_row_bf16_scalar(acc, x, w);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_row_bf16_avx2(acc: &mut [f32], x: f32, w: &[u16]) {
    use std::arch::x86_64::*;
    debug_assert_eq!(acc.len(), w.len());
    let n = acc.len();
    let n8 = n - n % 8;
    let xv = _mm256_set1_ps(x);
    let mut j = 0;
    while j < n8 {
        // 8 bf16 patterns -> zero-extend to 32 bits -> shift into the
        // high half: exactly `widen_bf16` per lane.
        let bits16 = _mm_loadu_si128(w.as_ptr().add(j) as *const __m128i);
        let bits32 = _mm256_slli_epi32(_mm256_cvtepu16_epi32(bits16), 16);
        let wv = _mm256_castsi256_ps(bits32);
        let av = _mm256_loadu_ps(acc.as_ptr().add(j));
        _mm256_storeu_ps(acc.as_mut_ptr().add(j), _mm256_add_ps(av, _mm256_mul_ps(xv, wv)));
        j += 8;
    }
    for jj in n8..n {
        *acc.get_unchecked_mut(jj) += x * widen_bf16(*w.get_unchecked(jj));
    }
}

// ---------------------------------------------------------------------------
// dot products (four-partial-sum reference semantics)
// ---------------------------------------------------------------------------

/// Scalar reference dot product: four independent partial sums over
/// `chunks_exact(4)`, a scalar tail, and the reduction
/// `(acc0 + acc1) + (acc2 + acc3) + tail`. This *is* the historical
/// `kernels::dot` accumulation order — the vector path below mirrors its
/// lane structure exactly.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let n4 = n - n % 4;
    let mut acc = [0.0f32; 4];
    for (pa, pb) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        acc[0] += pa[0] * pb[0];
        acc[1] += pa[1] * pb[1];
        acc[2] += pa[2] * pb[2];
        acc[3] += pa[3] * pb[3];
    }
    let mut tail = 0.0f32;
    for i in n4..n {
        tail += a[i] * b[i];
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// Dot product with the four-partial-sum reference semantics. The vector
/// path keeps the four partials in one 128-bit accumulator (lane `i` ==
/// scalar `acc[i]` after every chunk) and reduces in the same order.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: `active()` verified AVX2 (superset of SSE2) support.
        return unsafe { dot_sse(a, b) };
    }
    dot_scalar(a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_sse(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let n4 = n - n % 4;
    let mut accv = _mm_setzero_ps();
    let mut i = 0;
    while i < n4 {
        let av = _mm_loadu_ps(a.as_ptr().add(i));
        let bv = _mm_loadu_ps(b.as_ptr().add(i));
        accv = _mm_add_ps(accv, _mm_mul_ps(av, bv));
        i += 4;
    }
    let mut acc = [0.0f32; 4];
    _mm_storeu_ps(acc.as_mut_ptr(), accv);
    let mut tail = 0.0f32;
    for j in n4..n {
        tail += *a.get_unchecked(j) * *b.get_unchecked(j);
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// Scalar reference bf16×bf16 dot: widen both operands, then the same
/// four-partial-sum structure as [`dot_scalar`].
pub fn dot_bf16_scalar(a: &[u16], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let n4 = n - n % 4;
    let mut acc = [0.0f32; 4];
    for (pa, pb) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        acc[0] += widen_bf16(pa[0]) * widen_bf16(pb[0]);
        acc[1] += widen_bf16(pa[1]) * widen_bf16(pb[1]);
        acc[2] += widen_bf16(pa[2]) * widen_bf16(pb[2]);
        acc[3] += widen_bf16(pa[3]) * widen_bf16(pb[3]);
    }
    let mut tail = 0.0f32;
    for i in n4..n {
        tail += widen_bf16(a[i]) * widen_bf16(b[i]);
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// bf16×bf16 dot with the four-partial-sum reference semantics; the
/// vector path widens 4 lanes per side with shifts (lossless) and keeps
/// the same lane/reduction structure as [`dot_bf16_scalar`].
#[inline]
pub fn dot_bf16(a: &[u16], b: &[u16]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: `active()` verified AVX2 (superset of SSE2) support.
        return unsafe { dot_bf16_sse(a, b) };
    }
    dot_bf16_scalar(a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_bf16_sse(a: &[u16], b: &[u16]) -> f32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let n4 = n - n % 4;
    let zero = _mm_setzero_si128();
    let mut accv = _mm_setzero_ps();
    let mut i = 0;
    while i < n4 {
        // 4 bf16 patterns per side (8 bytes) -> zero-extend to 32-bit
        // lanes -> shift into the high half: `widen_bf16` per lane.
        let ab = _mm_loadl_epi64(a.as_ptr().add(i) as *const __m128i);
        let bb = _mm_loadl_epi64(b.as_ptr().add(i) as *const __m128i);
        let av = _mm_castsi128_ps(_mm_slli_epi32(_mm_unpacklo_epi16(ab, zero), 16));
        let bv = _mm_castsi128_ps(_mm_slli_epi32(_mm_unpacklo_epi16(bb, zero), 16));
        accv = _mm_add_ps(accv, _mm_mul_ps(av, bv));
        i += 4;
    }
    let mut acc = [0.0f32; 4];
    _mm_storeu_ps(acc.as_mut_ptr(), accv);
    let mut tail = 0.0f32;
    for j in n4..n {
        tail += widen_bf16(*a.get_unchecked(j)) * widen_bf16(*b.get_unchecked(j));
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

// ---------------------------------------------------------------------------
// int8 block decode: dst[j] = (codes[j] as i8 as f32) * scale
// ---------------------------------------------------------------------------

/// Scalar reference int8 decode: sign-interpret each code byte, convert
/// (exact for |code| <= 127), multiply by the block scale.
pub fn decode_i8_scalar(codes: &[u8], scale: f32, dst: &mut [f32]) {
    debug_assert_eq!(codes.len(), dst.len());
    for (o, &c) in dst.iter_mut().zip(codes) {
        *o = (c as i8 as f32) * scale;
    }
}

/// int8 block decode, vectorized 8-wide when AVX2 is available.
/// Elementwise and exact per lane (int→f32 conversion is exact for
/// |code| ≤ 127; the scale multiply is one correctly-rounded IEEE op),
/// so bit-identical to [`decode_i8_scalar`].
#[inline]
pub fn decode_i8(codes: &[u8], scale: f32, dst: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: `active()` verified AVX2 support at runtime.
        unsafe { decode_i8_avx2(codes, scale, dst) };
        return;
    }
    decode_i8_scalar(codes, scale, dst);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn decode_i8_avx2(codes: &[u8], scale: f32, dst: &mut [f32]) {
    use std::arch::x86_64::*;
    debug_assert_eq!(codes.len(), dst.len());
    let n = dst.len();
    let n8 = n - n % 8;
    let sv = _mm256_set1_ps(scale);
    let mut j = 0;
    while j < n8 {
        let bytes = _mm_loadl_epi64(codes.as_ptr().add(j) as *const __m128i);
        let ints = _mm256_cvtepi8_epi32(bytes);
        let vals = _mm256_cvtepi32_ps(ints);
        _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_mul_ps(vals, sv));
        j += 8;
    }
    for jj in n8..n {
        *dst.get_unchecked_mut(jj) = (*codes.get_unchecked(jj) as i8 as f32) * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * 0.5).collect()
    }

    // Awkward lengths on purpose: exercise the vector body and the
    // scalar tail together (n % 8 and n % 4 both nonzero in the mix).
    const LENS: &[usize] = &[0, 1, 3, 4, 7, 8, 15, 16, 31, 64, 257];

    #[test]
    fn axpy_row_matches_scalar_bitwise() {
        let mut rng = Rng::new(11);
        for &n in LENS {
            let w = randv(&mut rng, n);
            let base = randv(&mut rng, n);
            let x = rng.gaussian() as f32;
            let mut a = base.clone();
            let mut b = base.clone();
            axpy_row(&mut a, x, &w);
            axpy_row_scalar(&mut b, x, &w);
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "axpy_row diverged from scalar at n={n}"
            );
        }
    }

    #[test]
    fn axpy_row_bf16_matches_scalar_bitwise() {
        let mut rng = Rng::new(12);
        for &n in LENS {
            let w = super::super::bf16::cast(&randv(&mut rng, n));
            let base = randv(&mut rng, n);
            let x = rng.gaussian() as f32;
            let mut a = base.clone();
            let mut b = base.clone();
            axpy_row_bf16(&mut a, x, &w);
            axpy_row_bf16_scalar(&mut b, x, &w);
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "axpy_row_bf16 diverged from scalar at n={n}"
            );
        }
    }

    #[test]
    fn dot_matches_scalar_bitwise() {
        let mut rng = Rng::new(13);
        for &n in LENS {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_scalar(&a, &b).to_bits(),
                "dot diverged from scalar at n={n}"
            );
        }
    }

    #[test]
    fn dot_bf16_matches_scalar_bitwise() {
        let mut rng = Rng::new(14);
        for &n in LENS {
            let a = super::super::bf16::cast(&randv(&mut rng, n));
            let b = super::super::bf16::cast(&randv(&mut rng, n));
            assert_eq!(
                dot_bf16(&a, &b).to_bits(),
                dot_bf16_scalar(&a, &b).to_bits(),
                "dot_bf16 diverged from scalar at n={n}"
            );
        }
    }

    #[test]
    fn decode_i8_matches_scalar_bitwise() {
        let mut rng = Rng::new(15);
        for &n in LENS {
            let codes: Vec<u8> =
                (0..n).map(|_| ((rng.gaussian() * 50.0) as i32).clamp(-127, 127) as i8 as u8).collect();
            let scale = (rng.gaussian() as f32).abs() * 0.01;
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            decode_i8(&codes, scale, &mut a);
            decode_i8_scalar(&codes, scale, &mut b);
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "decode_i8 diverged from scalar at n={n}"
            );
        }
    }

    #[test]
    fn widen_bf16_matches_bf16_module() {
        for bits in [0u16, 1, 0x3F80, 0x8000, 0x7F80, 0xFF80, 0x7FC0, 0xABCD] {
            assert_eq!(
                widen_bf16(bits).to_bits(),
                super::super::bf16::to_f32(bits).to_bits()
            );
        }
    }
}
